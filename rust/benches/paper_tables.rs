//! Bench + regeneration harness for the paper's static tables
//! (Table I, Table II, Table IV, §III-C probe, §IV-B probe).
//!
//!     cargo bench --offline --bench paper_tables

use migsim::bench::Bencher;
use migsim::config::SimConfig;
use migsim::experiments;

fn main() {
    let cfg = SimConfig::default();
    let mut b = Bencher::new();
    // Regenerate each table once (the harness output is the paper
    // row-set); smoke mode skips it — the bench loop below already
    // executes each driver once.
    if !b.smoke() {
        for id in ["table1", "table2", "table4", "smcount", "ctx"] {
            let out = experiments::run(id, &cfg).expect(id);
            print!("{}", out.render());
        }
    }

    // Time the generation paths.
    for id in ["table1", "table2", "table4", "smcount", "ctx"] {
        b.bench(&format!("experiment/{id}"), || {
            experiments::run(id, &cfg).unwrap().json.compact().len()
        });
    }
    b.bench_with_work("nvlink/direct_bw_sweep", Some(18.0), "queries", || {
        let m = migsim::gpu::NvlinkModel::default();
        let mut acc = 0.0;
        for sms in [16u32, 26, 32, 60, 64, 132] {
            for dir in [
                migsim::gpu::nvlink::Dir::H2D,
                migsim::gpu::nvlink::Dir::D2H,
                migsim::gpu::nvlink::Dir::Both,
            ] {
                acc += m.direct_bw_gibs(sms, dir);
            }
        }
        acc
    });
    b.bench_with_work("probe/sm_count_132", Some(1.0), "probes", || {
        migsim::gpu::sm::measure_sm_count(132)
    });
    b.finish("paper_tables");
}
