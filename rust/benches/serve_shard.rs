//! Sharded-serve scaling benchmark: end-to-end `cluster::serve_sharded`
//! wall time and events/s as the worker-thread count grows, on a
//! 512-GPU, 10k-job near-saturated trace split across 8 node shards
//! (16 GPUs / 2 shards / 400 jobs in `--smoke` mode), plus the unsharded
//! single-loop baseline the shards are differentially tested against.
//!
//! Besides the human-readable report (and the standard
//! `results/bench/serve_shard.json`), this bench emits
//! `BENCH_serve_shard.json` — machine-readable wall time, events/s,
//! speedup-vs-1-thread and speedup-vs-unsharded per thread count — so the
//! scaling trajectory is tracked across PRs. The merged `ServeReport` is
//! asserted bit-identical across every thread count before anything is
//! timed.
//!
//!     cargo bench --offline --bench serve_shard          # full measurement
//!     cargo bench --offline --bench serve_shard -- --smoke   # CI check (runs the 2-thread cell)

use migsim::bench::{BenchConfig, Bencher};
use migsim::cluster::{
    serve, serve_sharded, LayoutPreset, PolicyKind, ServeConfig, ShardServeConfig,
};
use migsim::util::json::Json;
use std::time::Duration;

fn main() {
    let mut b = Bencher::new().with_config(BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        min_time: Duration::from_millis(300),
        max_iters: 6,
    });
    let smoke = b.smoke();
    let gpus: u32 = if smoke { 16 } else { 512 };
    let nodes: u32 = if smoke { 2 } else { 8 };
    let jobs: u32 = if smoke { 400 } else { 10_000 };
    let threads: &[u32] = if smoke { &[1, 2] } else { &[1, 2, 4, 8] };

    // Near-saturated: per-GPU offered load matches the serve-scale
    // experiment, so queues stay deep and dispatch dominates.
    let base = ServeConfig {
        gpus,
        policy: PolicyKind::OffloadAware { alpha_centi: 10 },
        layout: LayoutPreset::Mixed,
        arrival_rate_hz: gpus as f64 * 2.5,
        jobs,
        deadline_s: 45.0,
        reconfig: true,
        seed: 7,
        workload_scale: 0.05,
        batch: 1,
        ..ServeConfig::default()
    };

    // Unsharded single-loop baseline: one queue, one clock, one core —
    // what the sharded control plane is replacing at this scale.
    let single = serve(&base).unwrap();
    let single_res = b
        .bench_with_work(
            &format!("serve_shard/unsharded_{jobs}jobs_{gpus}gpus"),
            Some(single.events as f64),
            "events",
            || serve(&base).unwrap().completed,
        )
        .cloned();

    let mut canonical: Option<String> = None;
    let mut runs = Vec::new();
    let mut wall_1t: Option<f64> = None;
    for &th in threads {
        let scfg = ShardServeConfig::new(base.clone(), nodes, th);
        let report = serve_sharded(&scfg).unwrap();
        let rendered = report.report.to_json().pretty();
        match &canonical {
            None => canonical = Some(rendered),
            Some(c) => assert_eq!(
                *c, rendered,
                "sharded serve diverged at {th} threads — determinism bug"
            ),
        }
        let res = b
            .bench_with_work(
                &format!("serve_shard/{nodes}nodes_{th}threads_{jobs}jobs_{gpus}gpus"),
                Some(report.report.events as f64),
                "events",
                || serve_sharded(&scfg).unwrap().report.completed,
            )
            .cloned();
        if let Some(res) = res {
            if th == 1 {
                wall_1t = Some(res.mean_s);
            }
            let mut o = Json::obj();
            o.set("threads", th)
                .set("nodes", nodes)
                .set("wall_s", res.mean_s)
                .set("events", report.report.events)
                .set("events_per_s", report.report.events as f64 / res.mean_s)
                .set("handoffs", report.handoffs)
                .set("epochs", report.epochs);
            // Speedups only when their baseline actually ran this
            // invocation (a `-- <filter>` can skip the 1-thread or
            // unsharded cells; a fabricated 1.0/0.0 would poison the
            // perf-trajectory artifact).
            if let Some(w) = wall_1t {
                o.set("speedup_vs_1thread", w / res.mean_s);
            }
            if let Some(s) = &single_res {
                o.set("speedup_vs_unsharded", s.mean_s / res.mean_s);
            }
            runs.push(o);
        }
    }

    // Machine-readable scaling trajectory for the PR log.
    let mut doc = Json::obj();
    doc.set("suite", "serve_shard")
        .set("smoke", smoke)
        .set("gpus", gpus)
        .set("nodes", nodes)
        .set("jobs", jobs)
        .set("lookahead_s", ShardServeConfig::new(base.clone(), nodes, 1).lookahead_s)
        .set(
            "unsharded",
            match &single_res {
                Some(s) => {
                    let mut o = Json::obj();
                    o.set("wall_s", s.mean_s)
                        .set("events", single.events)
                        .set("events_per_s", single.events as f64 / s.mean_s);
                    o
                }
                None => Json::Null,
            },
        )
        .set("runs", Json::Arr(runs));
    if std::fs::write("BENCH_serve_shard.json", doc.pretty()).is_ok() {
        println!("-- wrote BENCH_serve_shard.json");
    }

    b.finish("serve_shard");
}
