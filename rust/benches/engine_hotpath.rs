//! Hot-path microbenchmarks for the L3 coordinator: event-queue
//! throughput, bandwidth arbitration, the kernel duration model, and the
//! full co-run simulation rate (sim-events per second — the §Perf L3
//! target is ≥1M events/s through the queue and a seconds-scale Fig. 5).
//!
//!     cargo bench --offline --bench engine_hotpath

use migsim::bench::Bencher;
use migsim::config::SimConfig;
use migsim::coordinator::corun::{simulate, water_fill, CorunSpec};
use migsim::gpu::GpuSpec;
use migsim::mig::ProfileId;
use migsim::sharing::Scheme;
use migsim::sim::Engine;
use migsim::util::Rng;
use migsim::workload::{apps, AppId, ExecEnv};

fn main() {
    let mut b = Bencher::new();

    // Event queue: schedule+pop churn.
    const N_EV: u64 = 100_000;
    b.bench_with_work("engine/schedule_pop", Some(N_EV as f64), "events", || {
        let mut e: Engine<u64> = Engine::new();
        let mut rng = Rng::new(1);
        for i in 0..N_EV {
            e.schedule_at(rng.below(1 << 30), i);
        }
        let mut acc = 0u64;
        while let Some(s) = e.pop() {
            acc = acc.wrapping_add(s.event);
        }
        acc
    });

    // Event queue with cancellation churn (the re-rating pattern).
    b.bench_with_work("engine/cancel_rerate", Some(50_000.0), "events", || {
        let mut e: Engine<u32> = Engine::new();
        let mut rng = Rng::new(2);
        let mut token = e.schedule_at(10, 0);
        for i in 0..50_000u32 {
            e.cancel(token);
            token = e.schedule_in(rng.below(1000) + 1, i);
            if i % 4 == 0 {
                e.pop();
            }
        }
        e.len()
    });

    // Bandwidth arbitration.
    let desires = [406.0, 380.0, 0.0, 812.0, 55.0, 406.0, 120.0];
    let caps = [406.0; 7];
    b.bench_with_work("corun/water_fill_7way", Some(1.0), "calls", || {
        water_fill(&desires, &caps, 3175.0)
    });

    // Kernel duration model.
    let spec = GpuSpec::gh_h100_96gb();
    let app = apps::model(AppId::LlmcTinystories);
    let kernel = app.phases[0].kernels[0].clone();
    let env = ExecEnv {
        sms: 16,
        clock_frac: 0.95,
        bw_gibs: 406.0,
        c2c_bw_gibs: 282.0,
        interference: 1.0,
        time_share: 1.0,
    };
    b.bench_with_work("model/kernel_duration", Some(1.0), "calls", || {
        kernel.duration_s(&spec, &env)
    });

    // Full co-run simulations (the Fig. 5 inner loop).
    let cfg = SimConfig {
        workload_scale: 0.05,
        ..SimConfig::default()
    };
    for (label, scheme) in [
        (
            "corun/mig_7x1g_lammps",
            Scheme::Mig {
                profile: ProfileId::P1g12gb,
                copies: 7,
            },
        ),
        (
            "corun/mps_7x13_lammps",
            Scheme::Mps {
                sm_pct: 13,
                copies: 7,
            },
        ),
        ("corun/timeslice_7_lammps", Scheme::TimeSlice { copies: 7 }),
    ] {
        // Report throughput in simulator events per second of wall time.
        let (m, _) = simulate(&CorunSpec::homogeneous(scheme, AppId::Lammps), &cfg).unwrap();
        let events = m.events as f64;
        b.bench_with_work(label, Some(events), "sim-events", || {
            simulate(&CorunSpec::homogeneous(scheme, AppId::Lammps), &cfg)
                .unwrap()
                .0
                .events
        });
    }

    b.finish("engine_hotpath");
}
