//! PJRT runtime benchmarks: per-artifact execute latency/throughput —
//! the real-compute path of the e2e driver. Skips gracefully when
//! artifacts are not built.
//!
//!     make artifacts && cargo bench --offline --bench runtime_pjrt

use migsim::bench::{BenchConfig, Bencher};
use migsim::runtime::{Executor, Registry};
use std::path::Path;
use std::time::Duration;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("runtime_pjrt: no artifacts/ (run `make artifacts`); skipping");
        return;
    }
    let reg = Registry::load(dir).expect("manifest");
    let mut exec = Executor::new().expect("PJRT client");
    let mut b = Bencher::new().with_config(BenchConfig {
        warmup_iters: 2,
        min_iters: 5,
        min_time: Duration::from_millis(200),
        max_iters: 200,
    });
    for name in reg.names() {
        let art = reg.get(&name).unwrap().clone();
        let inputs = Executor::synthetic_inputs(&art, 3).unwrap();
        exec.compile(&reg, &name).unwrap();
        b.bench_with_work(
            &format!("pjrt/{name}"),
            Some(art.flops),
            "FLOP",
            || {
                let ins: Vec<xla::Literal> = inputs
                    .iter()
                    .map(|l| {
                        let dims: Vec<i64> =
                            l.array_shape().unwrap().dims().to_vec();
                        let v: Vec<f32> = l.to_vec().unwrap();
                        if dims.is_empty() {
                            xla::Literal::scalar(v[0])
                        } else {
                            xla::Literal::vec1(&v).reshape(&dims).unwrap()
                        }
                    })
                    .collect();
                exec.execute(&reg, &name, &ins).unwrap().len()
            },
        );
    }
    b.finish("runtime_pjrt");
}
