//! Online-profiling-plane overhead benchmark: end-to-end `cluster::serve`
//! with the estimator stepped on one knob at a time — plane off (the
//! oracle planner, the pre-plane serve loop), the cold estimator (probe
//! phase, structural extrapolation, cell means), and the oracle-seeded
//! estimator (every cell warm from the first decision) — on a
//! near-saturated fleet under the estimate-consuming offload-aware
//! policy.
//!
//! The "off" cell is the zero-cost-when-off claim for this PR: with the
//! plane disabled no `EstPlane` is built, dispatch ranks on the oracle
//! tables, and the serve loop's bits and speed match the pre-plane
//! system. The "estimated" cell prices the full learning machinery on
//! the placement hot path; the "seeded" cell isolates the table-lookup
//! cost from the learning transient (and re-checks the regret==0
//! anchor before anything is timed).
//!
//! Besides the human-readable report (and the standard
//! `results/bench/estimate.json`), this bench emits
//! `BENCH_estimate.json` — machine-readable events/s for every cell, the
//! per-cell overhead ratio over the plane-off baseline, and the
//! per-policy estimate-vs-oracle regret trajectory (decisions, probes,
//! mean and max regret for first-fit, best-fit and offload-aware) — so
//! the profiling plane's cost and accuracy are tracked across PRs.
//!
//!     cargo bench --offline --bench estimate          # full measurement
//!     cargo bench --offline --bench estimate -- --smoke   # CI bit-rot check

use migsim::bench::{BenchConfig, Bencher};
use migsim::cluster::{serve, EstimatorConfig, LayoutPreset, PolicyKind, ServeConfig};
use migsim::util::json::Json;
use migsim::util::units::ns_to_sec;
use std::time::Duration;

fn main() {
    let mut b = Bencher::new().with_config(BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        min_time: Duration::from_millis(300),
        max_iters: 8,
    });
    let smoke = b.smoke();
    let gpus: u32 = if smoke { 8 } else { 64 };
    let jobs: u32 = if smoke { 300 } else { 5_000 };

    let cfg_with = |policy: PolicyKind, estimator: EstimatorConfig| ServeConfig {
        gpus,
        policy,
        layout: LayoutPreset::Mixed,
        arrival_rate_hz: gpus as f64 * 2.5,
        jobs,
        deadline_s: 45.0,
        reconfig: true,
        seed: 7,
        workload_scale: 0.05,
        batch: 1,
        estimator,
        ..ServeConfig::default()
    };
    let aware = PolicyKind::OffloadAware { alpha_centi: 10 };
    let on = EstimatorConfig {
        enabled: true,
        ..EstimatorConfig::default()
    };
    let off = cfg_with(aware, EstimatorConfig::default());
    let estimated = cfg_with(aware, on.clone());
    let seeded = cfg_with(
        aware,
        EstimatorConfig {
            enabled: true,
            seed_oracle: true,
            ..EstimatorConfig::default()
        },
    );

    // The plane's contracts, re-checked before anything is timed: the
    // estimated run is still a conserving serve that probes and decides,
    // and the oracle-seeded estimator measures exactly zero regret.
    let r_est = serve(&estimated).unwrap();
    assert_eq!(
        r_est.completed + r_est.expired + r_est.rejected,
        r_est.jobs,
        "job conservation broken under estimation"
    );
    assert!(
        r_est.estimator.probes > 0 && r_est.estimator.decisions > 0,
        "the estimated cell never probed or decided"
    );
    let r_seeded = serve(&seeded).unwrap();
    assert_eq!(
        r_seeded.estimator.regret_sum_ns, 0,
        "oracle-seeded estimator accrued regret"
    );

    let mut doc = Json::obj();
    doc.set("suite", "estimate")
        .set("smoke", smoke)
        .set("gpus", gpus)
        .set("jobs", jobs)
        .set("seeded_regret_ns", r_seeded.estimator.regret_sum_ns);
    // The per-policy regret trajectory: how far the learned tables sit
    // from the retained oracle under each placement policy.
    let policies: [(&str, PolicyKind); 3] = [
        ("first-fit", PolicyKind::FirstFit),
        ("best-fit", PolicyKind::BestFit),
        ("offload-aware", aware),
    ];
    let mut regret = Json::obj();
    for (label, policy) in policies {
        let r = serve(&cfg_with(policy, on.clone())).unwrap();
        let st = &r.estimator;
        let mean_ns = if st.decisions > 0 {
            st.regret_sum_ns / st.decisions
        } else {
            0
        };
        let mut p = Json::obj();
        p.set("probes", st.probes)
            .set("decisions", st.decisions)
            .set("regret_mean_s", ns_to_sec(mean_ns))
            .set("regret_max_s", ns_to_sec(st.regret_max_ns))
            .set("completed", r.completed);
        regret.set(label, p);
    }
    doc.set("regret_by_policy", regret);

    let cells: [(&str, &ServeConfig); 3] =
        [("off", &off), ("estimated", &estimated), ("seeded", &seeded)];
    let mut off_wall = None;
    for (label, sc) in cells {
        let probe = serve(sc).unwrap();
        let res = b
            .bench_with_work(
                &format!("estimate/{label}_{jobs}jobs_{gpus}gpus"),
                Some(probe.events as f64),
                "events",
                || serve(sc).unwrap().completed,
            )
            .cloned();
        if let Some(r) = res {
            doc.set(&format!("{label}_wall_s"), r.mean_s)
                .set(
                    &format!("{label}_events_per_s"),
                    probe.events as f64 / r.mean_s,
                );
            match off_wall {
                None => off_wall = Some(r.mean_s),
                Some(bw) => {
                    doc.set(&format!("{label}_overhead_ratio"), r.mean_s / bw);
                }
            }
        }
    }
    if std::fs::write("BENCH_estimate.json", doc.pretty()).is_ok() {
        println!("-- wrote BENCH_estimate.json");
    }

    b.finish("estimate");
}
