//! Bench + regeneration harness for the paper's figures (Figs. 2-8).
//!
//! Regenerates each figure's series at a reduced workload scale (the
//! figure *shapes* are scale-invariant — asserted by the integration
//! tests) and times each experiment driver end-to-end.
//!
//!     cargo bench --offline --bench paper_figures

use migsim::bench::{BenchConfig, Bencher};
use migsim::config::SimConfig;
use migsim::experiments;
use std::time::Duration;

fn main() {
    let cfg = SimConfig {
        workload_scale: 0.05,
        ..SimConfig::default()
    };
    let mut b = Bencher::new().with_config(BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        min_time: Duration::from_millis(200),
        max_iters: 20,
    });
    // Smoke mode (CI bit-rot check) skips the regeneration pass — the
    // bench loop below already executes each driver once.
    if !b.smoke() {
        for id in ["fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8"] {
            let out = experiments::run(id, &cfg).expect(id);
            print!("{}", out.render());
        }
    }
    for id in ["fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8"] {
        b.bench(&format!("experiment/{id}@0.05"), || {
            experiments::run(id, &cfg).unwrap().json.compact().len()
        });
    }
    b.finish("paper_figures");
}
