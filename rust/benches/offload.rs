//! Host-memory-plane benchmarks: per-decision cost of the contended
//! offload-aware walk (per-share class enumeration + host-pool gate)
//! through the indexed path (`Planner::place`) vs the naive full fleet
//! scan (`Planner::place_scan`), and end-to-end `cluster::serve` runs on
//! an offload-heavy all-small fleet with the plane off, with C2C link
//! contention on, and with a finite Grace pool.
//!
//! Besides the human-readable report (and the standard
//! `results/bench/offload.json`), this bench emits `BENCH_offload.json`
//! — machine-readable ns/decision, contended-vs-naive speedups, and
//! serve events/s per plane configuration — so the perf trajectory of
//! the contended path is tracked across PRs.
//!
//!     cargo bench --offline --bench offload          # full measurement
//!     cargo bench --offline --bench offload -- --smoke   # CI bit-rot check

use migsim::bench::{black_box, BenchConfig, BenchResult, Bencher};
use migsim::cluster::hostmem::gib_to_bytes;
use migsim::cluster::{serve, Fleet, LayoutPreset, Planner, PolicyKind, ServeConfig};
use migsim::util::json::Json;
use migsim::workload::AppId;
use std::time::Duration;

const APPS: [AppId; 5] = [
    AppId::Faiss,
    AppId::Hotspot,
    AppId::Llama3Fp16,
    AppId::Qiskit31,
    AppId::FaissLarge,
];

fn ns_per_work(r: &BenchResult) -> f64 {
    r.mean_s * 1e9 / r.work_per_iter.unwrap_or(1.0)
}

fn main() {
    let mut b = Bencher::new();
    let smoke = b.smoke();
    let gpus: u32 = if smoke { 8 } else { 64 };
    let policy = PolicyKind::OffloadAware { alpha_centi: 10 };

    // An offload-heavy steady state: all-small fleet where every GPU but
    // the last carries one offloaded llama (a distinct link-share level
    // mix) plus direct residents on part of its remaining slots — the
    // regime where the contended walk has real per-share classes and the
    // memory/host gates actually fire.
    let mut fleet = Fleet::with_batch(gpus, LayoutPreset::AllSmall, 1).unwrap();
    let mut seed_pl = Planner::with_opts(0.05, 1, true, 0.0);
    let mut job = 0u32;
    for g in 0..(gpus as usize - 1) {
        let c = seed_pl.cost(AppId::Llama3Fp16, migsim::mig::ProfileId::P1g12gb, true).unwrap();
        fleet.start_job(
            g,
            0,
            job,
            0.0,
            1e9,
            c.resident_gib + seed_pl.ctx_gib(),
            gib_to_bytes(c.host_gib),
        );
        job += 1;
        // Fill slots 1..4 with direct residents so first-fit shortcuts
        // cannot trivialize the walk.
        for s in 1..4 {
            fleet.start_job(g, s, job, 0.0, 1e9, 0.5, 0);
            job += 1;
        }
    }

    let mut decisions = Vec::new();
    for (tag, contention) in [("private_link", false), ("contended_link", true)] {
        let mut planner = Planner::with_opts(0.05, 1, contention, 0.0);
        for app in APPS {
            black_box(planner.place(&fleet, app, policy));
            black_box(planner.place_scan(&fleet, app, policy));
        }
        let warm = b
            .bench_with_work(
                &format!("offload/warm_{tag}"),
                Some(APPS.len() as f64),
                "decisions",
                || {
                    let mut acc = 0usize;
                    for app in APPS {
                        if planner.place(&fleet, app, policy).is_some() {
                            acc += 1;
                        }
                    }
                    acc
                },
            )
            .cloned();
        let naive = b
            .bench_with_work(
                &format!("offload/naive_{tag}"),
                Some(APPS.len() as f64),
                "decisions",
                || {
                    let mut acc = 0usize;
                    for app in APPS {
                        if planner.place_scan(&fleet, app, policy).is_some() {
                            acc += 1;
                        }
                    }
                    acc
                },
            )
            .cloned();
        if let (Some(warm), Some(naive)) = (warm, naive) {
            let (wi, ni) = (ns_per_work(&warm), ns_per_work(&naive));
            let mut o = Json::obj();
            o.set("mode", tag)
                .set("indexed_ns_per_decision", wi)
                .set("naive_ns_per_decision", ni)
                .set("speedup", ni / wi.max(1e-12));
            decisions.push(o);
        }
    }

    // End-to-end serving: the same offload-heavy stream with the plane
    // off, with link contention, and with a finite Grace pool gating
    // admission. Macro runs get their own (lighter) iteration budget.
    let jobs: u32 = if smoke { 300 } else { 5_000 };
    let mut mb = Bencher::new().with_config(BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        min_time: Duration::from_millis(200),
        max_iters: 8,
    });
    let mut serve_results = Vec::new();
    for (tag, contention, pool) in [
        ("plane_off", false, f64::INFINITY),
        ("contended_inf_pool", true, f64::INFINITY),
        ("contended_finite_pool", true, 16.0),
    ] {
        let cfg = ServeConfig {
            gpus,
            policy,
            layout: LayoutPreset::AllSmall,
            arrival_rate_hz: if smoke { 4.0 } else { 20.0 },
            jobs,
            deadline_s: 45.0,
            reconfig: false,
            seed: 7,
            workload_scale: 0.05,
            batch: 1,
            host_pool_gib: pool,
            c2c_contention: contention,
            energy_weight: 0.0,
            ..ServeConfig::default()
        };
        let report = serve(&cfg).unwrap();
        let res = mb
            .bench_with_work(
                &format!("serve_offload/{tag}_{jobs}jobs_{gpus}gpus"),
                Some(report.events as f64),
                "events",
                || serve(&cfg).unwrap().completed,
            )
            .cloned();
        if let Some(res) = res {
            let mut o = Json::obj();
            o.set("mode", tag)
                .set("c2c_contention", contention)
                .set(
                    "pool_gib",
                    if pool.is_infinite() {
                        Json::Str("inf".into())
                    } else {
                        Json::Num(pool)
                    },
                )
                .set("gpus", cfg.gpus)
                .set("jobs", cfg.jobs)
                .set("completed", report.completed)
                .set("offloaded", report.offloaded)
                .set("events", report.events)
                .set("events_per_s", report.events as f64 / res.mean_s)
                .set("wall_s_per_run", res.mean_s);
            serve_results.push(o);
        }
    }

    // Machine-readable perf trajectory for the PR log.
    let mut doc = Json::obj();
    doc.set("suite", "offload")
        .set("smoke", smoke)
        .set("gpus", gpus)
        .set("decisions", Json::Arr(decisions))
        .set("serve", Json::Arr(serve_results));
    if std::fs::write("BENCH_offload.json", doc.pretty()).is_ok() {
        println!("-- wrote BENCH_offload.json");
    }

    b.finish("offload");
    mb.finish("offload_serve");
}
