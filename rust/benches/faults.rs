//! Fault-plane overhead benchmark: end-to-end `cluster::serve` with the
//! plane inert (the default path every other bench measures) against the
//! same fleet under an active gpu+slice+reconfig fault plan with bounded
//! retries and fine-grained checkpointing, on a near-saturated fleet.
//!
//! The "off" cell is the zero-cost-when-off claim: an inert `FaultConfig`
//! schedules no events and every per-dispatch retry lookup is guarded by
//! an emptiness check, so the loop's bits and its speed match the
//! pre-plane serve loop. The "on" cell prices the full failure pipeline —
//! cordon-and-drain, orphan requeue, checkpoint-shrunk retries, repair.
//!
//! Besides the human-readable report (and the standard
//! `results/bench/faults.json`), this bench emits `BENCH_faults.json` —
//! machine-readable events/s for both cells, the on/off overhead ratio,
//! and the injected fault/retry/failure counts — so the recovery plane's
//! cost is tracked across PRs.
//!
//!     cargo bench --offline --bench faults          # full measurement
//!     cargo bench --offline --bench faults -- --smoke   # CI bit-rot check

use migsim::bench::{BenchConfig, Bencher};
use migsim::cluster::{serve, FaultConfig, LayoutPreset, PolicyKind, ServeConfig};
use migsim::util::json::Json;
use std::time::Duration;

fn main() {
    let mut b = Bencher::new().with_config(BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        min_time: Duration::from_millis(300),
        max_iters: 8,
    });
    let smoke = b.smoke();
    let gpus: u32 = if smoke { 8 } else { 64 };
    let jobs: u32 = if smoke { 300 } else { 5_000 };

    // Near-saturated, same regime as the telemetry bench: the loop spends
    // its time in dispatch, where the retry-fraction lookup sits.
    let base = ServeConfig {
        gpus,
        policy: PolicyKind::OffloadAware { alpha_centi: 10 },
        layout: LayoutPreset::Mixed,
        arrival_rate_hz: gpus as f64 * 2.5,
        jobs,
        deadline_s: 45.0,
        reconfig: true,
        seed: 7,
        workload_scale: 0.05,
        batch: 1,
        ..ServeConfig::default()
    };
    // Per-GPU MTTF of 30 s over a tens-of-seconds horizon: every GPU is
    // expected to fault at least once, so the recovery pipeline (cordon,
    // drain, requeue, repair) is genuinely hot.
    let faulted = ServeConfig {
        faults: FaultConfig::from_spec("gpu,slice:2,reconfig", 30.0, 5.0, 2, 1.0).unwrap(),
        ..base.clone()
    };

    let off = serve(&base).unwrap();
    // An enabled-but-empty plan must reproduce the inert bytes exactly —
    // the contract the golden fixtures rely on — before anything is timed.
    let empty = ServeConfig {
        faults: FaultConfig::from_spec("gpu:0", 3600.0, 60.0, 2, f64::INFINITY).unwrap(),
        ..base.clone()
    };
    assert_eq!(
        off.to_json().pretty(),
        serve(&empty).unwrap().to_json().pretty(),
        "an empty fault plan must be byte-inert before anything is timed"
    );
    let on = serve(&faulted).unwrap();
    assert!(on.faults > 0, "the faulted cell injected nothing");
    assert_eq!(
        on.completed + on.expired + on.rejected + on.failed,
        on.jobs,
        "job conservation broken under faults"
    );

    let off_res = b
        .bench_with_work(
            &format!("faults/off_{jobs}jobs_{gpus}gpus"),
            Some(off.events as f64),
            "events",
            || serve(&base).unwrap().completed,
        )
        .cloned();
    let on_res = b
        .bench_with_work(
            &format!("faults/on_{jobs}jobs_{gpus}gpus"),
            Some(on.events as f64),
            "events",
            || serve(&faulted).unwrap().completed,
        )
        .cloned();

    // Machine-readable cost trajectory for the PR log.
    let mut doc = Json::obj();
    doc.set("suite", "faults")
        .set("smoke", smoke)
        .set("gpus", gpus)
        .set("jobs", jobs)
        .set("sim_events_off", off.events)
        .set("sim_events_on", on.events)
        .set("faults", on.faults)
        .set("retries", on.retries)
        .set("failed", on.failed)
        .set("completed_off", off.completed)
        .set("completed_on", on.completed);
    if let (Some(off_r), Some(on_r)) = (&off_res, &on_res) {
        doc.set("off_wall_s", off_r.mean_s)
            .set("off_events_per_s", off.events as f64 / off_r.mean_s)
            .set("on_wall_s", on_r.mean_s)
            .set("on_events_per_s", on.events as f64 / on_r.mean_s)
            .set("overhead_ratio", on_r.mean_s / off_r.mean_s);
    }
    if std::fs::write("BENCH_faults.json", doc.pretty()).is_ok() {
        println!("-- wrote BENCH_faults.json");
    }

    b.finish("faults");
}
