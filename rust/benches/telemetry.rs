//! Telemetry-plane overhead benchmark: end-to-end `cluster::serve` with
//! the plane compiled out (`NullSink` — the default path every other
//! bench measures) against `serve_traced` with the recording sink live,
//! on a near-saturated fleet, plus micro-benchmarks of the histogram
//! record/merge algebra and JSONL serialization.
//!
//! The "off" cell is the zero-cost-when-off claim: the serve loop is
//! generic over the sink, so with `NullSink` every hook monomorphizes to
//! nothing and the bits match the pre-telemetry loop. The "on" cell
//! prices full structured tracing + sampling + histograms.
//!
//! Besides the human-readable report (and the standard
//! `results/bench/telemetry.json`), this bench emits
//! `BENCH_telemetry.json` — machine-readable events/s for both cells,
//! the on/off overhead ratio, and emitted trace volume — so the
//! observability tax is tracked across PRs.
//!
//!     cargo bench --offline --bench telemetry          # full measurement
//!     cargo bench --offline --bench telemetry -- --smoke   # CI bit-rot check

use migsim::bench::{black_box, BenchConfig, Bencher};
use migsim::cluster::telemetry::hist::Hist;
use migsim::cluster::{
    serve, serve_traced, LayoutPreset, PolicyKind, ServeConfig, ServeMode, TelemetryConfig,
};
use migsim::util::json::Json;
use std::time::Duration;

fn main() {
    let mut b = Bencher::new().with_config(BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        min_time: Duration::from_millis(300),
        max_iters: 8,
    });
    let smoke = b.smoke();
    let gpus: u32 = if smoke { 8 } else { 64 };
    let jobs: u32 = if smoke { 300 } else { 5_000 };

    // Near-saturated: per-GPU offered load matches the serve-scale
    // experiment, so the loop spends its time in dispatch — the regime
    // where per-event hooks would hurt if they cost anything.
    let cfg = ServeConfig {
        gpus,
        policy: PolicyKind::OffloadAware { alpha_centi: 10 },
        layout: LayoutPreset::Mixed,
        arrival_rate_hz: gpus as f64 * 2.5,
        jobs,
        deadline_s: 45.0,
        reconfig: true,
        seed: 7,
        workload_scale: 0.05,
        batch: 1,
        ..ServeConfig::default()
    };
    let tcfg = TelemetryConfig::default();

    let off = serve(&cfg).unwrap();
    let (on, tel) = serve_traced(&cfg, ServeMode::Indexed, &tcfg).unwrap();
    assert_eq!(
        off.to_json().pretty(),
        on.to_json().pretty(),
        "telemetry must be plane-inert before anything is timed"
    );

    let off_res = b
        .bench_with_work(
            &format!("telemetry/off_{jobs}jobs_{gpus}gpus"),
            Some(off.events as f64),
            "events",
            || serve(&cfg).unwrap().completed,
        )
        .cloned();
    let on_res = b
        .bench_with_work(
            &format!("telemetry/on_{jobs}jobs_{gpus}gpus"),
            Some(on.events as f64),
            "events",
            || {
                serve_traced(&cfg, ServeMode::Indexed, &tcfg)
                    .unwrap()
                    .0
                    .completed
            },
        )
        .cloned();
    let jsonl_res = b
        .bench_with_work(
            "telemetry/jsonl_serialize",
            Some(tel.events.len() as f64),
            "events",
            || tel.to_jsonl().len(),
        )
        .cloned();

    // Histogram algebra micro-benchmarks: the per-completion record and
    // the per-barrier merge the coordinator folds shard chunks with.
    const N: u64 = 100_000;
    b.bench_with_work("telemetry/hist_record_100k", Some(N as f64), "records", || {
        let mut h = Hist::new();
        for i in 0..N {
            h.record_ns(black_box(i.wrapping_mul(0x9E37_79B9_7F4A_7C15)));
        }
        h.count()
    });
    let mut full = Hist::new();
    for i in 0..N {
        full.record_ns(i.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    }
    b.bench_with_work("telemetry/hist_merge", Some(1.0), "merges", || {
        let mut acc = Hist::new();
        acc.merge(black_box(&full));
        acc.count()
    });

    // Machine-readable overhead trajectory for the PR log.
    let mut doc = Json::obj();
    doc.set("suite", "telemetry")
        .set("smoke", smoke)
        .set("gpus", gpus)
        .set("jobs", jobs)
        .set("sim_events", on.events)
        .set("trace_events", tel.events.len() as u64)
        .set("trace_samples", tel.samples.len() as u64)
        .set("trace_bytes", tel.to_jsonl().len() as u64);
    if let (Some(off_r), Some(on_r)) = (&off_res, &on_res) {
        doc.set("off_wall_s", off_r.mean_s)
            .set("off_events_per_s", off.events as f64 / off_r.mean_s)
            .set("on_wall_s", on_r.mean_s)
            .set("on_events_per_s", on.events as f64 / on_r.mean_s)
            .set("overhead_ratio", on_r.mean_s / off_r.mean_s);
    }
    if let Some(j) = &jsonl_res {
        doc.set("jsonl_serialize_s", j.mean_s);
    }
    if std::fs::write("BENCH_telemetry.json", doc.pretty()).is_ok() {
        println!("-- wrote BENCH_telemetry.json");
    }

    b.finish("telemetry");
}
