//! Placement hot-path benchmarks: per-decision cost of the three serving
//! policies through the indexed per-profile walk (`Planner::place`) vs
//! the naive full fleet scan (`Planner::place_scan`), the cold-cache cost
//! model evaluation, and end-to-end `cluster::serve` runs at fleet scale
//! (64 GPUs, 10k jobs) in events/s.
//!
//! The warm-decision fleet is in loaded steady state (every GPU busy
//! except the last) — the regime a long serving run actually dispatches
//! in, where the naive scan walks ~240 slots per decision and the indexed
//! walk touches ≤6 profile classes.
//!
//! Note the default serve path measured here *is* the telemetry-
//! instrumented path with the inert `NullSink`: every hook is guarded by
//! a monomorphized `const ENABLED` and compiles to nothing, so this
//! bench doubles as the regression watch on the zero-cost-when-off
//! claim (the `telemetry` bench prices the plane when it is on).
//!
//! Besides the human-readable report (and the standard
//! `results/bench/placement.json`), this bench emits
//! `BENCH_placement.json` — machine-readable ns/decision, naive-vs-indexed
//! speedups, and serve events/s — so the perf trajectory is tracked
//! across PRs.
//!
//!     cargo bench --offline --bench placement          # full measurement
//!     cargo bench --offline --bench placement -- --smoke   # CI bit-rot check

use migsim::bench::{black_box, BenchConfig, BenchResult, Bencher};
use migsim::cluster::{serve, Fleet, LayoutPreset, Planner, PolicyKind, ServeConfig};
use migsim::util::json::Json;
use migsim::workload::AppId;
use std::time::Duration;

const APPS: [AppId; 5] = [
    AppId::Faiss,
    AppId::Hotspot,
    AppId::Llama3Fp16,
    AppId::Qiskit30,
    AppId::NekRs,
];

fn ns_per_work(r: &BenchResult) -> f64 {
    r.mean_s * 1e9 / r.work_per_iter.unwrap_or(1.0)
}

fn main() {
    let mut b = Bencher::new();
    let smoke = b.smoke();
    let gpus: u32 = if smoke { 8 } else { 64 };

    // A loaded steady-state fleet: every GPU fully busy except the last,
    // so naive first-fit cannot shortcut on slot (0, 0).
    let mut fleet = Fleet::new(gpus, LayoutPreset::Mixed).unwrap();
    let mut job = 0u32;
    for g in 0..(gpus as usize - 1) {
        for s in 0..fleet.gpus[g].slots.len() {
            fleet.start_job(g, s, job, 0.0, 1e9, 0.5, 0);
            job += 1;
        }
    }

    let policies = [
        PolicyKind::FirstFit,
        PolicyKind::BestFit,
        PolicyKind::OffloadAware { alpha_centi: 10 },
    ];
    let mut decisions = Vec::new();
    for policy in policies {
        let mut planner = Planner::new(0.05);
        // Warm the cost/reward caches through both paths.
        for app in APPS {
            black_box(planner.place(&fleet, app, policy));
            black_box(planner.place_scan(&fleet, app, policy));
        }
        let warm = b
            .bench_with_work(
                &format!("place/warm_{}", policy.label()),
                Some(APPS.len() as f64),
                "decisions",
                || {
                    let mut acc = 0usize;
                    for app in APPS {
                        if planner.place(&fleet, app, policy).is_some() {
                            acc += 1;
                        }
                    }
                    acc
                },
            )
            .cloned();
        let naive = b
            .bench_with_work(
                &format!("place/naive_{}", policy.label()),
                Some(APPS.len() as f64),
                "decisions",
                || {
                    let mut acc = 0usize;
                    for app in APPS {
                        if planner.place_scan(&fleet, app, policy).is_some() {
                            acc += 1;
                        }
                    }
                    acc
                },
            )
            .cloned();
        if let (Some(warm), Some(naive)) = (warm, naive) {
            let (wi, ni) = (ns_per_work(&warm), ns_per_work(&naive));
            let mut o = Json::obj();
            o.set("policy", policy.label().as_str())
                .set("indexed_ns_per_decision", wi)
                .set("naive_ns_per_decision", ni)
                .set("speedup", ni / wi.max(1e-12));
            decisions.push(o);
        }
    }

    // Batched (MPS-within-MIG) placement: same loaded regime at batch 4
    // with every occupied slot holding one resident, so each decision
    // walks the per-(profile, occupancy) classes and the memory gate.
    const BATCH: u32 = 4;
    let mut bfleet = Fleet::with_batch(gpus, LayoutPreset::Mixed, BATCH).unwrap();
    let mut job = 0u32;
    for g in 0..(gpus as usize - 1) {
        for s in 0..bfleet.gpus[g].slots.len() {
            bfleet.start_job(g, s, job, 0.0, 1e9, 0.5, 0);
            job += 1;
        }
    }
    for policy in policies {
        let mut planner = Planner::with_batch(0.05, BATCH);
        for app in APPS {
            black_box(planner.place(&bfleet, app, policy));
            black_box(planner.place_scan(&bfleet, app, policy));
        }
        let warm = b
            .bench_with_work(
                &format!("place_batch{BATCH}/warm_{}", policy.label()),
                Some(APPS.len() as f64),
                "decisions",
                || {
                    let mut acc = 0usize;
                    for app in APPS {
                        if planner.place(&bfleet, app, policy).is_some() {
                            acc += 1;
                        }
                    }
                    acc
                },
            )
            .cloned();
        let naive = b
            .bench_with_work(
                &format!("place_batch{BATCH}/naive_{}", policy.label()),
                Some(APPS.len() as f64),
                "decisions",
                || {
                    let mut acc = 0usize;
                    for app in APPS {
                        if planner.place_scan(&bfleet, app, policy).is_some() {
                            acc += 1;
                        }
                    }
                    acc
                },
            )
            .cloned();
        if let (Some(warm), Some(naive)) = (warm, naive) {
            let (wi, ni) = (ns_per_work(&warm), ns_per_work(&naive));
            let mut o = Json::obj();
            o.set("policy", policy.label().as_str())
                .set("batch", BATCH)
                .set("indexed_ns_per_decision", wi)
                .set("naive_ns_per_decision", ni)
                .set("speedup", ni / wi.max(1e-12));
            decisions.push(o);
        }
    }

    // Cold cost-model evaluation (runtime + rates for app x profile).
    b.bench_with_work("place/cold_cost_model", Some(APPS.len() as f64), "evals", || {
        let mut planner = Planner::new(0.05);
        let mut acc = 0usize;
        for app in APPS {
            if planner
                .cost(app, migsim::mig::ProfileId::P1g12gb, true)
                .is_some()
            {
                acc += 1;
            }
        }
        acc
    });

    // End-to-end serving at fleet scale: arrivals + indexed placement +
    // incremental integrals + completions. Macro runs get their own
    // (lighter) iteration budget.
    let jobs: u32 = if smoke { 300 } else { 10_000 };
    let mut mb = Bencher::new().with_config(BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        min_time: Duration::from_millis(200),
        max_iters: 8,
    });
    let mut serve_results = Vec::new();
    for (tag, policy, batch) in [
        ("first_fit", PolicyKind::FirstFit, 1u32),
        ("offload_aware", PolicyKind::OffloadAware { alpha_centi: 10 }, 1),
        // End-to-end batched serving: the same stream with 4-deep
        // MPS-within-MIG co-residency.
        ("offload_aware_b4", PolicyKind::OffloadAware { alpha_centi: 10 }, 4),
    ] {
        let cfg = ServeConfig {
            gpus,
            policy,
            layout: LayoutPreset::Mixed,
            arrival_rate_hz: if smoke { 4.0 } else { 30.0 },
            jobs,
            deadline_s: 45.0,
            reconfig: true,
            seed: 7,
            workload_scale: 0.05,
            batch,
            ..ServeConfig::default()
        };
        let report = serve(&cfg).unwrap();
        let res = mb
            .bench_with_work(
                &format!("serve/{tag}_{jobs}jobs_{gpus}gpus"),
                Some(report.events as f64),
                "events",
                || serve(&cfg).unwrap().completed,
            )
            .cloned();
        if let Some(res) = res {
            let mut o = Json::obj();
            o.set("policy", policy.label().as_str())
                .set("batch", cfg.batch)
                .set("gpus", cfg.gpus)
                .set("jobs", cfg.jobs)
                .set("completed", report.completed)
                .set("events", report.events)
                .set("events_per_s", report.events as f64 / res.mean_s)
                .set("jobs_per_s", cfg.jobs as f64 / res.mean_s)
                .set("wall_s_per_run", res.mean_s);
            serve_results.push(o);
        }
    }

    // Machine-readable perf trajectory for the PR log.
    let mut doc = Json::obj();
    doc.set("suite", "placement")
        .set("smoke", smoke)
        .set("gpus", gpus)
        .set("decisions", Json::Arr(decisions))
        .set("serve", Json::Arr(serve_results));
    if std::fs::write("BENCH_placement.json", doc.pretty()).is_ok() {
        println!("-- wrote BENCH_placement.json");
    }

    b.finish("placement");
    mb.finish("placement_serve");
}
