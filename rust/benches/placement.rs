//! Placement hot-path microbenchmarks: per-decision cost of the three
//! serving policies over a warm cost cache (the steady state of a long
//! serving run), the cold-cache cost model evaluation, and a full
//! `cluster::serve` run in events/s.
//!
//!     cargo bench --offline --bench placement

use migsim::bench::Bencher;
use migsim::cluster::{serve, Fleet, LayoutPreset, Planner, PolicyKind, ServeConfig};
use migsim::workload::AppId;

fn main() {
    let mut b = Bencher::new();

    // Per-decision placement cost with a warm cache: a table scan over
    // the fleet's idle slots. 8 GPUs of mixed layouts ≈ 30 slots.
    let fleet = Fleet::new(8, LayoutPreset::Mixed).unwrap();
    let apps = [
        AppId::Faiss,
        AppId::Hotspot,
        AppId::Llama3Fp16,
        AppId::Qiskit30,
        AppId::NekRs,
    ];
    for policy in [
        PolicyKind::FirstFit,
        PolicyKind::BestFit,
        PolicyKind::OffloadAware { alpha_centi: 10 },
    ] {
        let mut planner = Planner::new(0.05);
        // Warm the cache.
        for app in apps {
            migsim::bench::black_box(planner.place(&fleet, app, policy));
        }
        b.bench_with_work(
            &format!("place/warm_{}", policy.label()),
            Some(apps.len() as f64),
            "decisions",
            || {
                let mut acc = 0usize;
                for app in apps {
                    if planner.place(&fleet, app, policy).is_some() {
                        acc += 1;
                    }
                }
                acc
            },
        );
    }

    // Cold cost-model evaluation (runtime + rates for app x profile).
    b.bench_with_work("place/cold_cost_model", Some(apps.len() as f64), "evals", || {
        let mut planner = Planner::new(0.05);
        let mut acc = 0usize;
        for app in apps {
            if planner
                .cost(app, migsim::mig::ProfileId::P1g12gb, true)
                .is_some()
            {
                acc += 1;
            }
        }
        acc
    });

    // End-to-end serving runs (arrivals + placement + completion events).
    for (label, policy) in [
        ("serve/first_fit_60jobs", PolicyKind::FirstFit),
        (
            "serve/offload_aware_60jobs",
            PolicyKind::OffloadAware { alpha_centi: 10 },
        ),
    ] {
        let cfg = ServeConfig {
            gpus: 4,
            policy,
            layout: LayoutPreset::AllSmall,
            arrival_rate_hz: 2.0,
            jobs: 60,
            deadline_s: 30.0,
            reconfig: true,
            seed: 7,
            workload_scale: 0.05,
        };
        b.bench_with_work(label, Some(60.0), "jobs", || {
            serve(&cfg).unwrap().completed
        });
    }

    b.finish("placement");
}
