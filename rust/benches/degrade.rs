//! Graceful-degradation overhead benchmark: end-to-end `cluster::serve`
//! under an active gpu-fault plan, stepping the degradation knobs on one
//! at a time — knobless baseline (the PR 7 fault plane), correlated rack
//! domains, a single repair crew, and the full stack with watermark
//! shedding — on a near-saturated fleet.
//!
//! The "base" cell is the zero-cost-when-off claim for this PR: with the
//! knobs at their defaults the domain scheduler arms nothing, repairs
//! bypass the crew queue, and the shed check is a single enum match, so
//! the loop's bits and its speed match the pre-degrade fault plane. The
//! "full" cell prices the whole degradation pipeline — domain cordons,
//! FIFO crew service, proportional shedding, cross-shard restore costs.
//!
//! Besides the human-readable report (and the standard
//! `results/bench/degrade.json`), this bench emits `BENCH_degrade.json` —
//! machine-readable events/s for every cell, the full/base overhead
//! ratio, and the domain/shed counts — so the degradation plane's cost is
//! tracked across PRs.
//!
//!     cargo bench --offline --bench degrade          # full measurement
//!     cargo bench --offline --bench degrade -- --smoke   # CI bit-rot check

use migsim::bench::{BenchConfig, Bencher};
use migsim::cluster::{
    serve, FaultConfig, FaultDomains, LayoutPreset, PolicyKind, ServeConfig, ShedPolicy,
};
use migsim::util::json::Json;
use std::time::Duration;

fn main() {
    let mut b = Bencher::new().with_config(BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        min_time: Duration::from_millis(300),
        max_iters: 8,
    });
    let smoke = b.smoke();
    let gpus: u32 = if smoke { 8 } else { 64 };
    let jobs: u32 = if smoke { 300 } else { 5_000 };

    // Hot per-GPU hazard with long repairs, same near-saturated regime as
    // the faults bench: cordons overlap, so finite crews genuinely queue
    // and the watermark genuinely trips.
    let faults = FaultConfig::from_spec("gpu", 30.0, 8.0, 2, 1.0).unwrap();
    let cfg_with = |f: FaultConfig| ServeConfig {
        gpus,
        policy: PolicyKind::OffloadAware { alpha_centi: 10 },
        layout: LayoutPreset::Mixed,
        arrival_rate_hz: gpus as f64 * 2.5,
        jobs,
        deadline_s: 45.0,
        reconfig: true,
        seed: 7,
        workload_scale: 0.05,
        batch: 1,
        faults: f,
        ..ServeConfig::default()
    };
    let base = cfg_with(faults);
    let domains = cfg_with(
        faults
            .with_degrade(FaultDomains::Rack(4), 0, ShedPolicy::None)
            .unwrap(),
    );
    let crews = cfg_with(
        faults
            .with_degrade(FaultDomains::Rack(4), 1, ShedPolicy::None)
            .unwrap(),
    );
    let full = cfg_with(
        faults
            .with_degrade(FaultDomains::Rack(4), 1, ShedPolicy::Watermark(0.75))
            .unwrap(),
    );

    let r_base = serve(&base).unwrap();
    // Default knobs must reproduce the knobless fault plane exactly —
    // the contract the golden fixtures rely on — before anything is timed.
    let inert = cfg_with(
        faults
            .with_degrade(FaultDomains::None, 0, ShedPolicy::None)
            .unwrap(),
    );
    assert_eq!(
        r_base.to_json().pretty(),
        serve(&inert).unwrap().to_json().pretty(),
        "default degradation knobs must be byte-inert before anything is timed"
    );
    let r_full = serve(&full).unwrap();
    assert!(r_full.domain_faults > 0, "the full cell fired no domain events");
    assert_eq!(
        r_full.completed + r_full.expired + r_full.rejected + r_full.failed + r_full.shed,
        r_full.jobs,
        "job conservation broken under degraded operation"
    );

    let cells: [(&str, &ServeConfig); 4] = [
        ("base", &base),
        ("domains", &domains),
        ("crews", &crews),
        ("full", &full),
    ];
    let mut doc = Json::obj();
    doc.set("suite", "degrade")
        .set("smoke", smoke)
        .set("gpus", gpus)
        .set("jobs", jobs)
        .set("domain_faults_full", r_full.domain_faults)
        .set("shed_full", r_full.shed)
        .set("retries_full", r_full.retries)
        .set("completed_base", r_base.completed)
        .set("completed_full", r_full.completed);
    let mut base_wall = None;
    for (label, sc) in cells {
        let probe = serve(sc).unwrap();
        let res = b
            .bench_with_work(
                &format!("degrade/{label}_{jobs}jobs_{gpus}gpus"),
                Some(probe.events as f64),
                "events",
                || serve(sc).unwrap().completed,
            )
            .cloned();
        if let Some(r) = res {
            doc.set(&format!("{label}_wall_s"), r.mean_s)
                .set(
                    &format!("{label}_events_per_s"),
                    probe.events as f64 / r.mean_s,
                );
            match base_wall {
                None => base_wall = Some(r.mean_s),
                Some(bw) => {
                    doc.set(&format!("{label}_overhead_ratio"), r.mean_s / bw);
                }
            }
        }
    }
    if std::fs::write("BENCH_degrade.json", doc.pretty()).is_ok() {
        println!("-- wrote BENCH_degrade.json");
    }

    b.finish("degrade");
}
