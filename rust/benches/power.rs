//! Power-plane overhead benchmark: end-to-end `cluster::serve` with the
//! fleet power plane stepped on one knob at a time — plane off (the
//! pre-plane serve loop), enabled with unbounded caps (governor armed,
//! nothing bites), a moderate per-GPU cap, a node-wide activity budget,
//! and the full stack with a harsh cap that throttles every placement —
//! on a near-saturated fleet.
//!
//! The "off" cell is the zero-cost-when-off claim for this PR: with the
//! plane disabled the tracker holds no per-GPU state, dispatch never
//! computes a throttle level, and the serve loop's bits and speed match
//! the pre-plane system. The "unbounded" cell prices the governor
//! bookkeeping alone (usage aggregation, equilibrium levels, parked-idle
//! repricing); the capped cells add throttle-priced placement and the
//! integer-milliwatt admission gate.
//!
//! Besides the human-readable report (and the standard
//! `results/bench/power.json`), this bench emits `BENCH_power.json` —
//! machine-readable events/s for every cell, the per-cell overhead ratio
//! over the plane-off baseline, and the throttle/starve counters — so the
//! power plane's cost is tracked across PRs.
//!
//!     cargo bench --offline --bench power          # full measurement
//!     cargo bench --offline --bench power -- --smoke   # CI bit-rot check

use migsim::bench::{BenchConfig, Bencher};
use migsim::cluster::{serve, LayoutPreset, PolicyKind, PowerPlaneConfig, ServeConfig};
use migsim::util::json::Json;
use std::time::Duration;

fn main() {
    let mut b = Bencher::new().with_config(BenchConfig {
        warmup_iters: 1,
        min_iters: 3,
        min_time: Duration::from_millis(300),
        max_iters: 8,
    });
    let smoke = b.smoke();
    let gpus: u32 = if smoke { 8 } else { 64 };
    let jobs: u32 = if smoke { 300 } else { 5_000 };

    let cfg_with = |power: PowerPlaneConfig| ServeConfig {
        gpus,
        policy: PolicyKind::OffloadAware { alpha_centi: 10 },
        layout: LayoutPreset::Mixed,
        arrival_rate_hz: gpus as f64 * 2.5,
        jobs,
        deadline_s: 45.0,
        reconfig: true,
        seed: 7,
        workload_scale: 0.05,
        batch: 1,
        power,
        ..ServeConfig::default()
    };
    let plane = |gpu_cap_w: f64, node_cap_w: f64| PowerPlaneConfig {
        enabled: true,
        gpu_cap_w,
        node_cap_w,
    };
    let off = cfg_with(PowerPlaneConfig::default());
    let unbounded = cfg_with(plane(f64::INFINITY, f64::INFINITY));
    let gpu_cap = cfg_with(plane(450.0, f64::INFINITY));
    let node_cap = cfg_with(plane(f64::INFINITY, gpus as f64 * 280.0));
    // Below even a single busy 1g slice's demand: every placement prices
    // at a throttled level, the worst case for the memoized cost tables.
    let full = cfg_with(plane(250.0, gpus as f64 * 280.0));

    let r_off = serve(&off).unwrap();
    // An enabled-but-unbounded plane must preserve every scheduling
    // outcome of the plane-off run — the governor only reprices the
    // energy integral — before anything is timed.
    let r_unbounded = serve(&unbounded).unwrap();
    assert_eq!(r_off.completed, r_unbounded.completed);
    assert_eq!(r_off.expired, r_unbounded.expired);
    assert_eq!(r_off.reconfigs, r_unbounded.reconfigs);
    assert_eq!(
        r_off.makespan_s.to_bits(),
        r_unbounded.makespan_s.to_bits(),
        "an unbounded power plane moved the horizon before anything was timed"
    );
    assert_eq!(r_unbounded.throttled_gpu_s, 0.0, "infinite caps throttled");
    let r_full = serve(&full).unwrap();
    assert!(r_full.throttled_gpu_s > 0.0, "the full cell never throttled");
    assert_eq!(
        r_full.completed + r_full.expired + r_full.rejected,
        r_full.jobs,
        "job conservation broken under power caps"
    );

    let cells: [(&str, &ServeConfig); 5] = [
        ("off", &off),
        ("unbounded", &unbounded),
        ("gpu_cap", &gpu_cap),
        ("node_cap", &node_cap),
        ("full", &full),
    ];
    let mut doc = Json::obj();
    doc.set("suite", "power")
        .set("smoke", smoke)
        .set("gpus", gpus)
        .set("jobs", jobs)
        .set("throttled_gpu_s_full", r_full.throttled_gpu_s)
        .set("parked_gpu_s_full", r_full.parked_gpu_s)
        .set("power_starved_full", r_full.power_starved)
        .set("completed_off", r_off.completed)
        .set("completed_full", r_full.completed);
    let mut off_wall = None;
    for (label, sc) in cells {
        let probe = serve(sc).unwrap();
        let res = b
            .bench_with_work(
                &format!("power/{label}_{jobs}jobs_{gpus}gpus"),
                Some(probe.events as f64),
                "events",
                || serve(sc).unwrap().completed,
            )
            .cloned();
        if let Some(r) = res {
            doc.set(&format!("{label}_wall_s"), r.mean_s)
                .set(
                    &format!("{label}_events_per_s"),
                    probe.events as f64 / r.mean_s,
                );
            match off_wall {
                None => off_wall = Some(r.mean_s),
                Some(bw) => {
                    doc.set(&format!("{label}_overhead_ratio"), r.mean_s / bw);
                }
            }
        }
    }
    if std::fs::write("BENCH_power.json", doc.pretty()).is_ok() {
        println!("-- wrote BENCH_power.json");
    }

    b.finish("power");
}
