//! Rendering and persistence helpers shared by the experiment drivers.

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Where an experiment's JSON output lands.
pub fn results_path(results_dir: &str, experiment: &str) -> PathBuf {
    Path::new(results_dir).join(format!("{experiment}.json"))
}

/// Persist an experiment result as pretty JSON; creates the directory.
pub fn write_results(results_dir: &str, experiment: &str, doc: &Json) -> crate::Result<PathBuf> {
    std::fs::create_dir_all(results_dir)?;
    let path = results_path(results_dir, experiment);
    std::fs::write(&path, doc.pretty())?;
    Ok(path)
}

/// Render a simple ASCII bar for figure-like series (the paper's bar
/// charts become rows of bars in the terminal).
pub fn bar(value: f64, max: f64, width: usize) -> String {
    let filled = if max > 0.0 {
        ((value / max).clamp(0.0, 1.0) * width as f64).round() as usize
    } else {
        0
    };
    let mut s = String::with_capacity(width);
    for i in 0..width {
        s.push(if i < filled { '#' } else { '.' });
    }
    s
}

/// Render a time-series sparkline (for the Fig. 7 power traces).
pub fn sparkline(values: &[f64], lo: f64, hi: f64) -> String {
    const LEVELS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    values
        .iter()
        .map(|&v| {
            let t = if hi > lo { ((v - lo) / (hi - lo)).clamp(0.0, 1.0) } else { 0.0 };
            LEVELS[((t * 7.0).round()) as usize]
        })
        .collect()
}

/// Downsample a trace to at most `n` points (mean pooling), for terminal
/// rendering of long power traces.
pub fn downsample(values: &[f64], n: usize) -> Vec<f64> {
    if values.len() <= n || n == 0 {
        return values.to_vec();
    }
    let chunk = values.len() as f64 / n as f64;
    (0..n)
        .map(|i| {
            let a = (i as f64 * chunk) as usize;
            let b = (((i + 1) as f64 * chunk) as usize).min(values.len()).max(a + 1);
            values[a..b].iter().sum::<f64>() / (b - a) as f64
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bar_rendering() {
        assert_eq!(bar(0.5, 1.0, 10), "#####.....");
        assert_eq!(bar(2.0, 1.0, 4), "####");
        assert_eq!(bar(0.0, 1.0, 4), "....");
        assert_eq!(bar(1.0, 0.0, 4), "....");
    }

    #[test]
    fn sparkline_monotone() {
        let s = sparkline(&[0.0, 0.5, 1.0], 0.0, 1.0);
        assert_eq!(s.chars().count(), 3);
        let levels: Vec<char> = s.chars().collect();
        assert!(levels[0] < levels[2]);
    }

    #[test]
    fn downsample_preserves_mean() {
        let v: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let d = downsample(&v, 10);
        assert_eq!(d.len(), 10);
        let mean_orig = v.iter().sum::<f64>() / v.len() as f64;
        let mean_ds = d.iter().sum::<f64>() / d.len() as f64;
        assert!((mean_orig - mean_ds).abs() < 1.0);
    }

    #[test]
    fn write_and_read_results() {
        let dir = std::env::temp_dir().join("migsim-test-results");
        let mut doc = Json::obj();
        doc.set("x", 1u64);
        let p = write_results(dir.to_str().unwrap(), "unit", &doc).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert_eq!(Json::parse(&text).unwrap(), doc);
        let _ = std::fs::remove_file(p);
    }
}
