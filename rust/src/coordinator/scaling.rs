//! Performance-resource scaling runs (Fig. 4): one copy of an app on each
//! MIG profile, 1g.12gb → 7g.96gb, performance normalized to the 1g run.

use crate::config::SimConfig;
use crate::coordinator::corun::{simulate, CorunSpec};
use crate::mig::profile::{GiProfile, ALL_PROFILES};
use crate::sharing::Scheme;
use crate::workload::AppId;

/// One app's scaling curve.
#[derive(Debug, Clone)]
pub struct ScalingCurve {
    pub app: &'static str,
    /// (profile name, runtime s, relative performance vs 1g).
    pub points: Vec<(&'static str, f64, f64)>,
}

/// Run the Fig. 4 sweep for one app. Profiles whose memory cannot hold the
/// app are skipped (None runtime is not recorded).
pub fn scaling_curve(app: AppId, cfg: &SimConfig) -> crate::Result<ScalingCurve> {
    let mut runtimes = Vec::new();
    for &pid in ALL_PROFILES.iter() {
        let p = GiProfile::get(pid);
        let spec = CorunSpec::homogeneous(
            Scheme::Mig {
                profile: pid,
                copies: 1,
            },
            app,
        );
        match simulate(&spec, cfg) {
            Ok((m, _)) => runtimes.push((p.name, m.makespan_s)),
            Err(_) => continue, // footprint too large for this profile
        }
    }
    anyhow::ensure!(!runtimes.is_empty(), "no profile could run {app:?}");
    let t_1g = runtimes[0].1;
    Ok(ScalingCurve {
        app: app.name(),
        points: runtimes
            .into_iter()
            .map(|(name, t)| (name, t, t_1g / t))
            .collect(),
    })
}

/// The ideal-scaling reference of Fig. 4's dashed line: resources
/// (memory slices) double along the profile ladder.
pub fn ideal_scaling() -> Vec<(&'static str, f64)> {
    ALL_PROFILES
        .iter()
        .map(|&pid| {
            let p = GiProfile::get(pid);
            (p.name, p.memory_slices as f64 / 1.0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qiskit_scales_near_ideal() {
        let c = scaling_curve(AppId::Qiskit30, &SimConfig::fast_test()).unwrap();
        assert_eq!(c.points.first().unwrap().0, "1g.12gb");
        let last = c.points.last().unwrap();
        assert_eq!(last.0, "7g.96gb");
        assert!(
            last.2 > 6.0 && last.2 < 9.0,
            "qiskit 7g speedup {}",
            last.2
        );
        // Monotone non-decreasing performance along the ladder.
        for w in c.points.windows(2) {
            assert!(w[1].2 >= w[0].2 * 0.98, "{:?}", c.points);
        }
    }

    #[test]
    fn nekrs_scales_poorly() {
        let c = scaling_curve(AppId::NekRs, &SimConfig::fast_test()).unwrap();
        let last = c.points.last().unwrap();
        assert!(last.2 < 2.8, "nekrs should scale poorly, got {}", last.2);
    }

    #[test]
    fn large_apps_skip_small_profiles() {
        let c = scaling_curve(AppId::Llama3Fp16, &SimConfig::fast_test()).unwrap();
        // 16.5 GiB does not fit 11 GiB: first feasible profile is 24gb.
        assert!(c.points.iter().all(|(n, _, _)| !n.contains("12gb")));
        assert!(!c.points.is_empty());
    }

    #[test]
    fn ideal_reference_doubles() {
        let ideal = ideal_scaling();
        assert_eq!(ideal[0].1, 1.0);
        assert_eq!(ideal.last().unwrap().1, 8.0);
    }
}
