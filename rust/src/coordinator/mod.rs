//! The L3 coordinator: orchestrates workloads over sharing schemes on the
//! simulated GPU, collects GPM metrics, and exposes experiment drivers.
//!
//! - `corun`: the co-run discrete-event simulator (Figs. 2-7 engine) —
//!   processor-sharing of HBM/C2C bandwidth, DVFS/power coupling,
//!   time-slice serialization, MPS interference.
//! - `scaling`: per-profile single-app runs (Fig. 4).
//! - `scheduler`: cluster-level trace-driven job scheduler over static
//!   MIG layouts, with a reward-driven offload-aware policy (the system
//!   the §VI-B metric is meant to serve).
//! - `report`: rendering helpers shared by the experiment drivers.

pub mod corun;
pub mod report;
pub mod scaling;
pub mod scheduler;

pub use corun::{simulate, CorunSpec};
