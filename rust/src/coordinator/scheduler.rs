//! Cluster-level job scheduler over a statically-partitioned GPU — the
//! system the paper's reward metric exists to serve ("to facilitate the
//! choice of a suitable MIG configuration for GPU sharing").
//!
//! A `StaticConfig` fixes the MIG layout (MIG cannot be reconfigured
//! while jobs run, §II-B3). Jobs arrive from a `JobTrace`, wait FIFO,
//! and are dispatched by a `Policy`:
//!
//! - `FirstFit`: first free instance with enough memory.
//! - `SmallestFit`: smallest free instance that fits (classic best-fit
//!   against SM waste).
//! - `OffloadAware`: smallest-fit, but also considers squeezing the job
//!   onto one-size-smaller instances via NVLink-C2C offloading when the
//!   §VI-B reward at the configured α favours it.
//!
//! Job runtimes come from the calibrated workload models (quiet-partition
//! analytic runtimes — queueing, not power, is the object here); the
//! simulator is a simple event loop over arrivals/completions.

use crate::gpu::GpuSpec;
use crate::mig::profile::GiProfile;
use crate::mig::{MigManager, ProfileId};
use crate::offload::OffloadPlan;
use crate::sharing::ContextModel;
use crate::util::stats::{percentile, Accum};
use crate::workload::trace::{Job, JobTrace};
use crate::workload::{apps, ExecEnv};
use anyhow::bail;
use std::collections::VecDeque;

/// A static MIG layout for the whole GPU.
#[derive(Debug, Clone)]
pub struct StaticConfig {
    pub name: String,
    pub profiles: Vec<ProfileId>,
}

impl StaticConfig {
    /// The configurations compared by the scheduler experiment.
    pub fn candidates() -> Vec<StaticConfig> {
        use ProfileId::*;
        vec![
            StaticConfig {
                name: "7x1g.12gb".into(),
                profiles: vec![P1g12gb; 7],
            },
            StaticConfig {
                name: "3x2g.24gb+1g.12gb".into(),
                profiles: vec![P2g24gb, P2g24gb, P2g24gb, P1g12gb],
            },
            StaticConfig {
                // 2x3g uses all 8 memory slices: nothing else fits.
                name: "2x3g.48gb".into(),
                profiles: vec![P3g48gb, P3g48gb],
            },
            StaticConfig {
                name: "4g.48gb+3g.48gb".into(),
                profiles: vec![P4g48gb, P3g48gb],
            },
            StaticConfig {
                name: "1x7g.96gb".into(),
                profiles: vec![P7g96gb],
            },
        ]
    }

    /// Validate against the slice budget.
    pub fn validate(&self, spec: &GpuSpec) -> crate::Result<()> {
        let mut mgr = MigManager::new(spec.clone());
        for p in &self.profiles {
            mgr.create_full(*p)?;
        }
        Ok(())
    }
}

/// Dispatch policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    FirstFit,
    SmallestFit,
    /// Smallest-fit extended with §VI offloading at the given α.
    OffloadAware { alpha_centi: u32 },
}

impl Policy {
    pub fn label(&self) -> String {
        match self {
            Policy::FirstFit => "first-fit".into(),
            Policy::SmallestFit => "smallest-fit".into(),
            // Same rendering as cluster::PolicyKind::label, so the sched
            // and serve experiment outputs label the policy identically.
            Policy::OffloadAware { alpha_centi } => {
                format!("offload-aware:{:.2}", *alpha_centi as f64 / 100.0)
            }
        }
    }
}

/// Outcome of one scheduled trace.
#[derive(Debug, Clone)]
pub struct ScheduleReport {
    pub config: String,
    pub policy: String,
    pub jobs: u32,
    pub makespan_s: f64,
    pub mean_wait_s: f64,
    pub p95_wait_s: f64,
    pub mean_turnaround_s: f64,
    /// Fraction of instance-seconds busy over the makespan.
    pub instance_utilization: f64,
    /// Jobs that ran with offloading.
    pub offloaded_jobs: u32,
    /// Jobs that could not run on any instance of the config.
    pub rejected_jobs: u32,
}

struct Instance {
    profile: GiProfile,
    busy_until: f64,
    busy_accum: f64,
}

/// Simulate a trace over a static config with a policy.
pub fn schedule(
    trace: &JobTrace,
    config: &StaticConfig,
    policy: Policy,
    workload_scale: f64,
) -> crate::Result<ScheduleReport> {
    let spec = GpuSpec::gh_h100_96gb();
    config.validate(&spec)?;
    let ctx = ContextModel::default();
    let ctx_gib = ctx.mig_per_process_gib;
    let mut instances: Vec<Instance> = config
        .profiles
        .iter()
        .map(|&p| Instance {
            profile: GiProfile::get(p),
            busy_until: 0.0,
            busy_accum: 0.0,
        })
        .collect();

    // Precompute per-app runtime on each distinct profile (quiet).
    let runtime_on = |app: crate::workload::AppId,
                      prof: &GiProfile,
                      offload: bool|
     -> crate::Result<Option<(f64, bool)>> {
        let model = apps::model(app).scaled(workload_scale);
        let cap = prof.mem_gib - ctx_gib;
        let plan = if model.footprint_gib <= cap {
            None
        } else if offload {
            match OffloadPlan::plan(&model, cap) {
                Ok(p) => Some(p),
                Err(_) => return Ok(None),
            }
        } else {
            return Ok(None);
        };
        let offloaded = plan.is_some();
        let run_model = plan.as_ref().map(|p| p.apply(&model)).unwrap_or(model);
        let env = ExecEnv {
            sms: prof.sms,
            clock_frac: 1.0,
            bw_gibs: prof.mem_bw_gibs,
            c2c_bw_gibs: 207.0,
            interference: 1.0,
            time_share: 1.0,
        };
        let t = run_model.runtime_quiet_s(&spec, &env)
            + run_model.startup_s * workload_scale;
        Ok(Some((t, offloaded)))
    };

    let mut queue: VecDeque<&Job> = VecDeque::new();
    let mut job_iter = trace.jobs.iter().peekable();
    let mut now = 0.0f64;
    let mut wait = Accum::new();
    let mut waits = Vec::new();
    let mut turnaround = Accum::new();
    let mut completed = 0u32;
    let mut offloaded_jobs = 0u32;
    let mut rejected = 0u32;
    let mut makespan = 0.0f64;

    // Event loop: advance to the earlier of (next arrival, earliest
    // instance free time) and try to dispatch the queue head.
    loop {
        // Pull all arrivals at or before `now`.
        while let Some(j) = job_iter.peek() {
            if j.arrival_s <= now {
                queue.push_back(job_iter.next().unwrap());
            } else {
                break;
            }
        }
        // Try to dispatch queued jobs.
        let mut dispatched_any = true;
        while dispatched_any && !queue.is_empty() {
            dispatched_any = false;
            let job = *queue.front().unwrap();
            // Candidate instances free now, per policy ordering.
            let mut free: Vec<usize> = instances
                .iter()
                .enumerate()
                .filter(|(_, ins)| ins.busy_until <= now)
                .map(|(i, _)| i)
                .collect();
            if let Policy::SmallestFit | Policy::OffloadAware { .. } = policy {
                free.sort_by_key(|&i| instances[i].profile.sms);
            }
            let mut choice: Option<(usize, f64, bool)> = None;
            for &i in &free {
                let allow_offload = matches!(policy, Policy::OffloadAware { .. });
                if let Some((t, off)) = runtime_on(job.app, &instances[i].profile, allow_offload)? {
                    // Offload-aware: accept an offloaded placement only if
                    // the reward at α favours it over waiting for the next
                    // bigger class (approximated: reject offload when the
                    // perf hit exceeds 1/(α+0.1) x the fit's runtime).
                    if off {
                        let alpha = match policy {
                            Policy::OffloadAware { alpha_centi } => alpha_centi as f64 / 100.0,
                            _ => 0.0,
                        };
                        if let Some(Some((t_fit, _))) = instances
                            .iter()
                            .find(|ins| {
                                apps::model(job.app).footprint_gib
                                    <= ins.profile.mem_gib - ctx_gib
                            })
                            .map(|ins| runtime_on(job.app, &ins.profile, false).ok().flatten())
                        {
                            if t > t_fit * (1.0 + 1.0 / (alpha + 0.1)) {
                                continue; // offload too costly at this α
                            }
                        }
                    }
                    choice = Some((i, t, off));
                    break;
                }
            }
            match choice {
                Some((i, t, off)) => {
                    queue.pop_front();
                    let w = now - job.arrival_s;
                    wait.push(w);
                    waits.push(w);
                    turnaround.push(w + t);
                    instances[i].busy_until = now + t;
                    instances[i].busy_accum += t;
                    makespan = makespan.max(now + t);
                    completed += 1;
                    if off {
                        offloaded_jobs += 1;
                    }
                    dispatched_any = true;
                }
                None => {
                    // Either all instances busy, or the job fits nowhere
                    // in this config at all.
                    let fits_somewhere = instances.iter().any(|ins| {
                        let allow = matches!(policy, Policy::OffloadAware { .. });
                        runtime_on(job.app, &ins.profile, allow)
                            .ok()
                            .flatten()
                            .is_some()
                    });
                    if !fits_somewhere {
                        queue.pop_front();
                        rejected += 1;
                        dispatched_any = true;
                    }
                }
            }
        }
        // Advance time.
        let next_arrival = job_iter.peek().map(|j| j.arrival_s);
        let next_free = instances
            .iter()
            .map(|i| i.busy_until)
            .filter(|&t| t > now)
            .fold(f64::INFINITY, f64::min);
        now = match (next_arrival, queue.is_empty()) {
            (Some(a), true) => a.min(if next_free.is_finite() { next_free } else { a }),
            (Some(a), false) => {
                if next_free.is_finite() {
                    a.min(next_free)
                } else {
                    a
                }
            }
            (None, false) => {
                if !next_free.is_finite() {
                    bail!("deadlock: queued jobs but no instance will free");
                }
                next_free
            }
            (None, true) => break,
        };
    }

    let util = if makespan > 0.0 {
        instances.iter().map(|i| i.busy_accum).sum::<f64>()
            / (makespan * instances.len() as f64)
    } else {
        0.0
    };
    Ok(ScheduleReport {
        config: config.name.clone(),
        policy: policy.label(),
        jobs: completed,
        makespan_s: makespan,
        mean_wait_s: wait.mean(),
        p95_wait_s: if waits.is_empty() { 0.0 } else { percentile(&waits, 95.0) },
        mean_turnaround_s: turnaround.mean(),
        instance_utilization: util,
        offloaded_jobs,
        rejected_jobs: rejected,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::AppId;

    fn trace() -> JobTrace {
        JobTrace::poisson(60, 1.2, &JobTrace::suite_mix(), 11)
    }

    #[test]
    fn all_candidate_configs_are_valid() {
        let spec = GpuSpec::gh_h100_96gb();
        for c in StaticConfig::candidates() {
            c.validate(&spec).unwrap_or_else(|e| panic!("{}: {e}", c.name));
        }
    }

    #[test]
    fn finer_partitioning_cuts_waiting_for_small_jobs() {
        let t = trace();
        let seven = schedule(
            &t,
            &StaticConfig::candidates()[0],
            Policy::SmallestFit,
            0.05,
        )
        .unwrap();
        let one = schedule(
            &t,
            &StaticConfig::candidates()[4],
            Policy::SmallestFit,
            0.05,
        )
        .unwrap();
        assert_eq!(seven.jobs + seven.rejected_jobs, 60);
        assert!(
            seven.mean_wait_s < one.mean_wait_s,
            "7x1g wait {:.2}s should beat 1x7g wait {:.2}s",
            seven.mean_wait_s,
            one.mean_wait_s
        );
    }

    #[test]
    fn smallest_fit_beats_first_fit_on_mixed_config() {
        let t = trace();
        let cfg = &StaticConfig::candidates()[3]; // 4g+3g
        let ff = schedule(&t, cfg, Policy::FirstFit, 0.05).unwrap();
        let sf = schedule(&t, cfg, Policy::SmallestFit, 0.05).unwrap();
        // Best-fit should never be materially worse on turnaround.
        assert!(sf.mean_turnaround_s <= ff.mean_turnaround_s * 1.10);
    }

    #[test]
    fn offload_aware_places_large_jobs_on_small_slices() {
        // A trace of only large llama jobs on 7x1g: without offloading
        // everything is rejected; offload-aware runs them.
        let jobs: Vec<Job> = (0..6)
            .map(|i| Job {
                id: i,
                app: AppId::Llama3Fp16,
                arrival_s: i as f64 * 2.0,
            })
            .collect();
        let t = JobTrace { jobs };
        let cfg = &StaticConfig::candidates()[0];
        let plain = schedule(&t, cfg, Policy::SmallestFit, 0.05).unwrap();
        assert_eq!(plain.rejected_jobs, 6, "16.5 GiB cannot fit 11 GiB");
        let off = schedule(&t, cfg, Policy::OffloadAware { alpha_centi: 0 }, 0.05).unwrap();
        assert_eq!(off.rejected_jobs, 0);
        assert_eq!(off.offloaded_jobs, 6);
        assert!(off.jobs == 6 && off.makespan_s > 0.0);
    }

    #[test]
    fn utilization_bounded_and_consistent() {
        let t = trace();
        for c in StaticConfig::candidates() {
            let r = schedule(&t, &c, Policy::SmallestFit, 0.05).unwrap();
            assert!(
                (0.0..=1.0 + 1e-9).contains(&r.instance_utilization),
                "{}: util {}",
                c.name,
                r.instance_utilization
            );
            assert!(r.mean_turnaround_s >= r.mean_wait_s);
            assert_eq!(r.jobs + r.rejected_jobs, 60);
        }
    }
}
