//! The co-run discrete-event simulator.
//!
//! Each copy runs its app's phase sequence on its partition. Kernel
//! durations follow the roofline model in `workload::model`; shared-
//! bandwidth schemes arbitrate HBM via max-min fairness (water-filling);
//! the NVLink-C2C link is max-min shared across *all* instances (it is
//! not partitioned by MIG — §III-D); the power governor couples copies
//! through the 700 W cap (§V-B1); time-slicing serializes kernels with a
//! context-switch penalty (§II-B1).
//!
//! Active kernels are re-rated (remaining work rescaled to the new
//! duration) whenever their environment changes: a kernel starting or
//! ending on a shared scheme, or a DVFS step.

use crate::config::SimConfig;
use crate::gpu::nvlink::{Dir, NvlinkModel};
use crate::gpu::{GpuSpec, GpuUsage, PowerModel, PowerState};
use crate::metrics::{Collector, GpmSample, PowerSample, RunMetrics};
use crate::offload::OffloadPlan;
use crate::sharing::scheme::{partitions, Partition, Scheme};
use crate::sim::{Engine, EventToken};
use crate::util::units::{gibs, ns_to_sec, sec_to_ns};
use crate::util::Rng;
use crate::workload::{apps, AppId, AppModel, ExecEnv};
use anyhow::bail;

/// Relative rate penalty when time-slicing switches between >1 process.
const TS_SWITCH_PENALTY: f64 = 0.06;

/// Specification of one co-run experiment.
#[derive(Debug, Clone)]
pub struct CorunSpec {
    pub scheme: Scheme,
    /// One app per copy. Length must equal `scheme.copies()` unless
    /// `sequential`, in which case any length works (they run back to
    /// back on the single partition).
    pub apps: Vec<AppId>,
    /// Run copies back-to-back instead of concurrently (the serial
    /// baseline of Figs. 5/6). Requires `Scheme::Full`.
    pub sequential: bool,
    /// Offload plans per copy (None = data must fit).
    pub offload: Vec<Option<OffloadPlan>>,
    pub record_traces: bool,
    /// Fault injection: (copy index, sim time in seconds) at which the
    /// copy's kernel raises a fatal GPU fault. Under schemes without
    /// error isolation (MPS, §II-B2) the fault kills every co-runner.
    pub fault_at: Option<(usize, f64)>,
}

impl CorunSpec {
    /// Concurrent co-run of `copies` identical apps under `scheme`.
    pub fn homogeneous(scheme: Scheme, app: AppId) -> CorunSpec {
        let n = scheme.copies() as usize;
        CorunSpec {
            scheme,
            apps: vec![app; n],
            sequential: false,
            offload: vec![None; n],
            record_traces: false,
            fault_at: None,
        }
    }

    /// The serial baseline: `copies` runs of `app` back-to-back on the
    /// full GPU.
    pub fn serial(app: AppId, copies: u32) -> CorunSpec {
        CorunSpec {
            scheme: Scheme::Full,
            apps: vec![app; copies as usize],
            sequential: true,
            offload: vec![None; copies as usize],
            record_traces: false,
            fault_at: None,
        }
    }

    pub fn with_traces(mut self) -> CorunSpec {
        self.record_traces = true;
        self
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    /// Current phase of copy `i` completes.
    PhaseEnd(usize),
    /// Injected fatal GPU fault in copy `i` (§II-B2 error-isolation).
    Fault(usize),
    /// Copy `i` begins (used for sequential mode chaining).
    CopyStart(usize),
    PowerPoll,
    GpmSample,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Step {
    Cpu,
    Kernel(usize),
}

#[derive(Debug, Clone)]
struct Cursor {
    phase: usize,
    iter: u32,
    step: Step,
}

#[derive(Debug)]
struct ActiveKernel {
    kernel_idx: (usize, usize),
    /// Fraction of the kernel's work already completed.
    frac_done: f64,
    /// Simulation time the current rating started.
    rated_at_ns: u64,
    /// Duration under the current rating (s).
    cur_duration_s: f64,
    /// Compute-only duration at boost clock (cached at kernel start so
    /// the per-rebalance bandwidth-desire computation allocates nothing).
    t_compute_boost_s: f64,
    token: EventToken,
}

#[derive(Debug)]
struct CopyState {
    app: AppModel,
    part: Partition,
    cursor: Cursor,
    active: Option<ActiveKernel>,
    /// Pending CPU-phase end token (no re-rating needed for CPU phases).
    started_s: f64,
    finished_s: Option<f64>,
    started: bool,
    failed: bool,
}

impl CopyState {
    fn finished(&self) -> bool {
        self.finished_s.is_some()
    }
}

/// Run a co-run simulation and return metrics + collector.
pub fn simulate(spec: &CorunSpec, cfg: &SimConfig) -> crate::Result<(RunMetrics, Collector)> {
    Corun::new(spec, cfg)?.run()
}

struct Corun {
    gpu: GpuSpec,
    nvlink: NvlinkModel,
    power_model: PowerModel,
    power: PowerState,
    copies: Vec<CopyState>,
    engine: Engine<Ev>,
    collector: Collector,
    rng: Rng,
    cfg: SimConfig,
    scheme: Scheme,
    sequential: bool,
    /// Aggregate context overhead charged GPU-wide (GiB).
    ctx_total_gib: f64,
    fault_at: Option<(usize, f64)>,
    /// True when partitions cannot affect each other through bandwidth
    /// (dedicated MIG caps, no C2C users, no time-slicing): kernel
    /// start/end events then need no global rebalance — only DVFS steps
    /// do. Cuts event-handling cost ~2x for pure-MIG runs.
    isolated: bool,
    /// Scratch buffers reused across rebalances (no allocation in the
    /// event hot loop — §Perf L3 target).
    scratch: Scratch,
}

#[derive(Debug, Default)]
struct Scratch {
    active: Vec<usize>,
    hbm_desire: Vec<f64>,
    hbm_cap: Vec<f64>,
    c2c_desire: Vec<f64>,
    c2c_cap: Vec<f64>,
    envs: Vec<ExecEnv>,
}

impl Corun {
    fn new(spec: &CorunSpec, cfg: &SimConfig) -> crate::Result<Corun> {
        let gpu = GpuSpec::gh_h100_96gb();
        let parts = partitions(&spec.scheme, &gpu)?;
        let n = spec.apps.len();
        if spec.sequential {
            if spec.scheme != Scheme::Full {
                bail!("sequential baseline requires Scheme::Full");
            }
        } else if n != parts.len() {
            bail!(
                "{} apps for {} partitions under {}",
                n,
                parts.len(),
                spec.scheme.label()
            );
        }
        if spec.offload.len() != n {
            bail!("offload plan list must match app list");
        }

        let concurrent = !spec.sequential && n > 1;
        let mut copies = Vec::with_capacity(n);
        let mut shared_footprint = 0.0;
        for (i, &app_id) in spec.apps.iter().enumerate() {
            let part = if spec.sequential {
                parts[0].clone()
            } else {
                parts[i].clone()
            };
            let mut app = apps::model(app_id).scaled(cfg.workload_scale);
            // Apply CPU contention when concurrent.
            if concurrent {
                let infl = app.cpu_corun_inflation;
                for ph in &mut app.phases {
                    ph.cpu_s *= infl;
                }
            }
            // Prepend the one-time startup (context init / data load):
            // GPU-idle time the serial baseline pays once per copy but a
            // co-run overlaps across copies. Scaled with the workload so
            // quick test runs keep the paper's proportions.
            if app.startup_s > 0.0 {
                app.phases.insert(
                    0,
                    crate::workload::MacroPhase {
                        cpu_s: app.startup_s * cfg.workload_scale,
                        kernels: Vec::new(),
                        repeats: 1,
                    },
                );
            }
            // Apply the offload plan (rewrites HBM traffic to C2C).
            let resident_gib = match &spec.offload[i] {
                Some(plan) => {
                    app = plan.apply(&app);
                    // Only the resident set occupies instance memory now.
                    app.footprint_gib = plan.effective_footprint_gib();
                    app.footprint_gib
                }
                None => app.footprint_gib,
            };
            // Capacity admission check.
            let need = resident_gib + part.context_overhead_gib;
            if part.bw_shared || spec.sequential {
                shared_footprint += need;
                if !spec.sequential && shared_footprint > part.mem_capacity_gib {
                    bail!(
                        "aggregate footprint {shared_footprint:.1} GiB exceeds shared capacity {:.1} GiB under {}",
                        part.mem_capacity_gib,
                        spec.scheme.label()
                    );
                }
            } else if need > part.mem_capacity_gib {
                bail!(
                    "{}: footprint {need:.1} GiB exceeds {} capacity {:.1} GiB (use offloading or a larger profile)",
                    app.name,
                    part.label,
                    part.mem_capacity_gib
                );
            }
            copies.push(CopyState {
                app,
                part,
                cursor: Cursor {
                    phase: 0,
                    iter: 0,
                    step: Step::Cpu,
                },
                active: None,
                started_s: 0.0,
                finished_s: None,
                started: false,
                failed: false,
            });
        }

        let ctx = crate::sharing::ContextModel::default();
        let ctx_total_gib = ctx.total_gib(&spec.scheme, n as u32);

        let any_c2c = copies.iter().any(|c| {
            c.app
                .phases
                .iter()
                .any(|ph| ph.kernels.iter().any(|k| k.c2c_bytes > 0.0))
        });
        let isolated = !any_c2c
            && copies
                .iter()
                .all(|c| !c.part.bw_shared && !c.part.exclusive_time);

        let mut power_model = PowerModel::h100();
        power_model.cap_w = cfg.power_cap_w;

        Ok(Corun {
            power: PowerState::new(&gpu),
            gpu,
            nvlink: NvlinkModel::default(),
            power_model,
            copies,
            engine: Engine::new(),
            collector: Collector::new(spec.record_traces),
            rng: Rng::new(cfg.seed),
            cfg: cfg.clone(),
            scheme: spec.scheme,
            sequential: spec.sequential,
            ctx_total_gib,
            fault_at: spec.fault_at,
            isolated,
            scratch: Scratch::default(),
        })
    }

    fn run(mut self) -> crate::Result<(RunMetrics, Collector)> {
        // Kick off copies.
        if self.sequential {
            self.engine.schedule_at(0, Ev::CopyStart(0));
        } else {
            for i in 0..self.copies.len() {
                self.engine.schedule_at(0, Ev::CopyStart(i));
            }
        }
        if let Some((i, at_s)) = self.fault_at {
            anyhow::ensure!(i < self.copies.len(), "fault index out of range");
            self.engine.schedule_at(sec_to_ns(at_s), Ev::Fault(i));
        }
        let power_period = sec_to_ns(self.cfg.power_period_s);
        let gpm_period = sec_to_ns(self.cfg.gpm_period_s);
        self.engine.schedule_at(power_period, Ev::PowerPoll);
        self.engine.schedule_at(gpm_period, Ev::GpmSample);
        // Initial samples at t=0.
        self.sample_power(0.0);
        self.sample_gpm(0.0);

        while let Some(ev) = self.engine.pop() {
            let now = ns_to_sec(ev.time_ns);
            match ev.event {
                Ev::CopyStart(i) => {
                    self.copies[i].started = true;
                    self.copies[i].started_s = now;
                    self.begin_step(i);
                    if !self.isolated {
                        self.rebalance(false);
                    }
                }
                Ev::PhaseEnd(i) => {
                    if self.copies[i].failed {
                        continue; // stale event from a killed copy
                    }
                    let shared = self.advance(i, now);
                    // Isolated partitions rate kernels exactly at start
                    // (env_placeholder uses the true caps and the current
                    // clock); only shared schemes need a global rebalance.
                    if shared && !self.isolated {
                        self.rebalance(true);
                    }
                }
                Ev::Fault(i) => {
                    self.inject_fault(i, now);
                    self.rebalance(true);
                }
                Ev::PowerPoll => {
                    self.sample_power(now);
                    if self.any_running() {
                        self.engine.schedule_in(power_period, Ev::PowerPoll);
                    }
                }
                Ev::GpmSample => {
                    self.sample_gpm(now);
                    if self.any_running() {
                        self.engine.schedule_in(gpm_period, Ev::GpmSample);
                    }
                }
            }
        }

        let makespan = self
            .copies
            .iter()
            .filter_map(|c| c.finished_s)
            .fold(0.0f64, f64::max);
        // Final samples to close integration windows.
        self.sample_power(makespan);
        self.sample_gpm(makespan);

        let runtimes: Vec<f64> = self
            .copies
            .iter()
            .map(|c| c.finished_s.unwrap_or(makespan) - c.started_s)
            .collect();
        let failed_copies = self.copies.iter().filter(|c| c.failed).count() as u32;
        let metrics = RunMetrics {
            scheme: if self.sequential {
                format!("serial x{}", self.copies.len())
            } else {
                self.scheme.label()
            },
            makespan_s: makespan,
            energy_j: self.collector.energy_j(),
            avg_power_w: self.collector.avg_power_w(),
            max_power_w: self.collector.max_power_w(),
            throttled_time_s: self.collector.throttled_time_s(),
            avg_occupancy: self.collector.avg_occupancy(),
            avg_sm_util: self.collector.avg_sm_util(),
            avg_bw_util: self.collector.avg_bw_util(),
            avg_mem_used_gib: self.collector.avg_mem_used_gib(),
            peak_mem_gib: self.collector.peak_mem_gib(),
            copy_runtimes_s: runtimes,
            failed_copies,
            events: self.engine.popped(),
        };
        Ok((metrics, self.collector))
    }

    /// Kill copy `i`; without error isolation every running co-runner's
    /// kernels return with an error too (§II-B2: "When a GPU kernel in
    /// one MPS process generates a fatal GPU fault, all other processes'
    /// GPU kernels ... also return with an error").
    fn inject_fault(&mut self, i: usize, now: f64) {
        let isolated = self.copies[i].part.error_isolated;
        let victims: Vec<usize> = if isolated {
            vec![i]
        } else {
            self.copies
                .iter()
                .enumerate()
                .filter(|(_, c)| c.started && !c.finished())
                .map(|(j, _)| j)
                .collect()
        };
        for v in victims {
            let c = &mut self.copies[v];
            if let Some(a) = c.active.take() {
                self.engine.cancel(a.token);
            }
            c.failed = true;
            c.finished_s = Some(now);
        }
    }

    fn any_running(&self) -> bool {
        self.copies.iter().any(|c| c.started && !c.finished())
    }

    /// Begin the step currently pointed at by copy `i`'s cursor.
    fn begin_step(&mut self, i: usize) {
        let now_ns = self.engine.now_ns();
        let jitter = if self.cfg.jitter_rel > 0.0 {
            self.rng.jitter(1.0, self.cfg.jitter_rel).max(0.1)
        } else {
            1.0
        };
        let c = &self.copies[i];
        let ph = &c.app.phases[c.cursor.phase];
        match c.cursor.step {
            Step::Cpu => {
                let d = ph.cpu_s * jitter;
                let tok = self
                    .engine
                    .schedule_in(sec_to_ns(d.max(0.0)), Ev::PhaseEnd(i));
                // CPU phases never need re-rating; reuse ActiveKernel slot
                // with a sentinel kernel index.
                self.copies[i].active = Some(ActiveKernel {
                    kernel_idx: (usize::MAX, 0),
                    frac_done: 0.0,
                    rated_at_ns: now_ns,
                    cur_duration_s: d,
                    t_compute_boost_s: 0.0,
                    token: tok,
                });
            }
            Step::Kernel(k) => {
                let env = self.env_placeholder(i);
                let d = ph.kernels[k].duration_s(&self.gpu, &env) * jitter;
                // Compute-only duration at boost (no memory/C2C terms).
                let t_c = {
                    let kernel = &ph.kernels[k];
                    let tail = crate::gpu::tail_efficiency(
                        kernel.blocks,
                        c.part.sms,
                        kernel.resident_per_sm,
                    );
                    let peak = kernel.mix.effective_flops(|p| {
                        self.gpu
                            .pipeline_flops(p, c.part.sms, self.gpu.clock_max_mhz)
                    });
                    if kernel.flops > 0.0 {
                        kernel.flops / (peak * tail)
                    } else {
                        0.0
                    }
                };
                let tok = self.engine.schedule_in(sec_to_ns(d), Ev::PhaseEnd(i));
                self.copies[i].active = Some(ActiveKernel {
                    kernel_idx: (self.copies[i].cursor.phase, k),
                    frac_done: 0.0,
                    rated_at_ns: now_ns,
                    cur_duration_s: d,
                    t_compute_boost_s: t_c,
                    token: tok,
                });
            }
        }
    }

    /// A provisional env for initial rating; `rebalance` immediately
    /// re-rates with the true contended environment.
    fn env_placeholder(&self, i: usize) -> ExecEnv {
        let p = &self.copies[i].part;
        ExecEnv {
            sms: p.sms,
            clock_frac: self.power.clock_frac(&self.gpu),
            bw_gibs: p.mem_bw_cap_gibs,
            c2c_bw_gibs: self.nvlink.direct_bw_gibs(p.sms, Dir::Both),
            interference: 1.0,
            time_share: 1.0,
        }
    }

    /// Advance copy `i` past its finished phase. Returns true if the
    /// change can affect other copies (kernel started/ended on a shared
    /// resource).
    fn advance(&mut self, i: usize, now: f64) -> bool {
        let was_kernel = {
            let c = &mut self.copies[i];
            let was_kernel = matches!(c.cursor.step, Step::Kernel(_));
            c.active = None;
            // Move cursor.
            let ph_len = c.app.phases[c.cursor.phase].kernels.len();
            let next = match c.cursor.step {
                Step::Cpu if ph_len > 0 => Some(Step::Kernel(0)),
                Step::Cpu => None,
                Step::Kernel(k) if k + 1 < ph_len => Some(Step::Kernel(k + 1)),
                Step::Kernel(_) => None,
            };
            match next {
                Some(step) => c.cursor.step = step,
                None => {
                    // Iteration finished.
                    c.cursor.iter += 1;
                    c.cursor.step = Step::Cpu;
                    if c.cursor.iter >= c.app.phases[c.cursor.phase].repeats {
                        c.cursor.iter = 0;
                        c.cursor.phase += 1;
                        if c.cursor.phase >= c.app.phases.len() {
                            c.finished_s = Some(now);
                        }
                    }
                }
            }
            was_kernel
        };
        if self.copies[i].finished() {
            // Sequential chaining: start the next pending copy.
            if self.sequential {
                if let Some(nxt) = self.copies.iter().position(|c| !c.started) {
                    self.engine.schedule_in(0, Ev::CopyStart(nxt));
                }
            }
            return was_kernel;
        }
        self.begin_step(i);
        let now_kernel = matches!(self.copies[i].cursor.step, Step::Kernel(_));
        was_kernel || now_kernel
    }

    /// Fill `buf` with indices of copies currently running a GPU kernel.
    fn fill_active_kernels(&self, buf: &mut Vec<usize>) {
        buf.clear();
        buf.extend(
            self.copies
                .iter()
                .enumerate()
                .filter(|(_, c)| {
                    c.active
                        .as_ref()
                        .map(|a| a.kernel_idx.0 != usize::MAX)
                        .unwrap_or(false)
                })
                .map(|(i, _)| i),
        );
    }

    /// Recompute environments for all active kernels and re-rate them.
    /// `shared_change`: whether a shared-resource change occurred (always
    /// re-rate then); otherwise only re-rate on clock changes.
    fn rebalance(&mut self, _shared_change: bool) {
        let mut active = std::mem::take(&mut self.scratch.active);
        self.fill_active_kernels(&mut active);
        if active.is_empty() {
            self.scratch.active = active;
            return;
        }
        let mut envs = std::mem::take(&mut self.scratch.envs);
        self.compute_envs(&active, &mut envs);
        let now_ns = self.engine.now_ns();
        for (&i, env) in active.iter().zip(envs.iter()) {
            let (phase, k) = self.copies[i].active.as_ref().unwrap().kernel_idx;
            let kernel = &self.copies[i].app.phases[phase].kernels[k];
            let new_d = kernel.duration_s(&self.gpu, env);
            let a = self.copies[i].active.as_mut().unwrap();
            // Progress under the old rating.
            let elapsed = ns_to_sec(now_ns - a.rated_at_ns);
            if a.cur_duration_s > 0.0 {
                a.frac_done = (a.frac_done + elapsed / a.cur_duration_s).min(1.0);
            }
            let remaining = ((1.0 - a.frac_done) * new_d).max(0.0);
            // Only reschedule when the estimate moved by >0.01% (avoids
            // event churn from no-op rebalances).
            let old_remaining = a.cur_duration_s * (1.0 - a.frac_done);
            a.rated_at_ns = now_ns;
            a.cur_duration_s = new_d;
            if (remaining - old_remaining).abs() > old_remaining * 1e-4 + 1e-9 {
                self.engine.cancel(a.token);
                let tok = self.engine.schedule_in(sec_to_ns(remaining), Ev::PhaseEnd(i));
                let a = self.copies[i].active.as_mut().unwrap();
                a.token = tok;
            }
        }
        self.scratch.active = active;
        self.scratch.envs = envs;
    }

    /// Environments for the active kernels, applying bandwidth
    /// arbitration, C2C sharing, time-slice serialization and MPS
    /// interference.
    fn compute_envs(&mut self, active: &[usize], envs: &mut Vec<ExecEnv>) {
        let clock_frac = self.power.clock_frac(&self.gpu);
        let n_active = active.len();
        let exclusive = self
            .copies
            .first()
            .map(|c| c.part.exclusive_time)
            .unwrap_or(false);

        // --- HBM arbitration ---
        // Desired bandwidth per kernel: what it needs to not be memory-
        // bound, capped by its partition allocation (or the GPU total for
        // shared schemes).
        let mut hbm_desire = std::mem::take(&mut self.scratch.hbm_desire);
        let mut hbm_cap = std::mem::take(&mut self.scratch.hbm_cap);
        hbm_desire.clear();
        hbm_desire.resize(n_active, 0.0);
        hbm_cap.clear();
        hbm_cap.resize(n_active, 0.0);
        let mut shared_pool = 0.0;
        let mut any_shared = false;
        for (j, &i) in active.iter().enumerate() {
            let c = &self.copies[i];
            let (phase, k) = c.active.as_ref().unwrap().kernel_idx;
            let kernel = &c.app.phases[phase].kernels[k];
            let cap = if c.part.bw_shared {
                any_shared = true;
                // Contended shared pool loses efficiency per extra sharer
                // (row conflicts, arbitration): MIG's hard caps avoid
                // this, which is why 7x1g generally wins Fig. 5 except
                // for bandwidth-hungry Qiskit/NekRS (§V-A).
                shared_pool =
                    self.gpu.mem_bw_gibs * (1.0 - 0.01 * (n_active - 1) as f64).max(0.85);
                shared_pool
            } else {
                c.part.mem_bw_cap_gibs
            };
            hbm_cap[j] = cap;
            // Time needed by compute alone at the current clock, from
            // the cache filled at kernel start (compute scales 1/clock).
            let t_c = c.active.as_ref().unwrap().t_compute_boost_s / clock_frac.max(1e-9);
            let desire = if kernel.hbm_bytes > 0.0 {
                if t_c > 0.0 {
                    (kernel.hbm_bytes / gibs(1.0) / t_c / kernel.bw_eff).min(cap)
                } else {
                    cap
                }
            } else {
                0.0
            };
            hbm_desire[j] = desire;
        }
        let hbm_grant = if any_shared && !exclusive {
            water_fill(&hbm_desire, &hbm_cap, shared_pool)
        } else {
            // Dedicated caps (MIG) or time-sliced (serialized anyway).
            hbm_cap.clone()
        };

        // --- C2C arbitration (shared across ALL instances, §III-D) ---
        let c2c_pool = self.nvlink.direct_both_cap_gibs;
        let mut c2c_desire = std::mem::take(&mut self.scratch.c2c_desire);
        let mut c2c_cap = std::mem::take(&mut self.scratch.c2c_cap);
        c2c_desire.clear();
        c2c_desire.resize(n_active, 0.0);
        c2c_cap.clear();
        c2c_cap.resize(n_active, 0.0);
        for (j, &i) in active.iter().enumerate() {
            let c = &self.copies[i];
            let (phase, k) = c.active.as_ref().unwrap().kernel_idx;
            let kernel = &c.app.phases[phase].kernels[k];
            // Offloaded data reads are host→device; STREAM-Nvlink drives
            // both directions (Table IVb rates differ per direction).
            let dir = if kernel.c2c_read_only { Dir::H2D } else { Dir::Both };
            c2c_cap[j] = self.nvlink.direct_bw_gibs(c.part.sms, dir);
            c2c_desire[j] = if kernel.c2c_bytes > 0.0 { c2c_cap[j] } else { 0.0 };
        }
        // Time-sliced kernels are serialized: each sees the whole link
        // while it runs (the serialization is charged via `interference`),
        // so only concurrent schemes share the C2C pool.
        let c2c_grant = if exclusive {
            c2c_cap.clone()
        } else {
            water_fill(&c2c_desire, &c2c_cap, c2c_pool)
        };

        // --- Assemble ---
        envs.clear();
        envs.extend(active.iter().enumerate().map(|(j, &i)| {
            let c = &self.copies[i];
            let mut interference = 1.0;
            let mut time_share = 1.0;
            if exclusive && n_active > 1 {
                // Round-robin serialization + context-switch cost
                // stretches the whole kernel.
                time_share = n_active as f64 * (1.0 + TS_SWITCH_PENALTY);
            } else if c.part.interference > 0.0 && n_active > 1 {
                // Shared-L2/cache interference grows with co-runner
                // count and slows the compute pipeline (§IV-A: "MPS
                // always underperforms by 1-5% compared to MIG").
                interference = 1.0 + c.part.interference * (n_active - 1) as f64;
            }
            ExecEnv {
                sms: c.part.sms,
                clock_frac,
                bw_gibs: hbm_grant[j].max(1.0),
                c2c_bw_gibs: c2c_grant[j].max(1.0),
                interference,
                time_share,
            }
        }));
        self.scratch.hbm_desire = hbm_desire;
        self.scratch.hbm_cap = hbm_cap;
        self.scratch.c2c_desire = c2c_desire;
        self.scratch.c2c_cap = c2c_cap;
    }

    /// Aggregate instantaneous usage for the power model and GPM sampler.
    fn usage(&self) -> GpuUsage {
        let mut active = Vec::with_capacity(self.copies.len());
        self.fill_active_kernels(&mut active);
        let mut u = GpuUsage {
            context_active: self.any_running(),
            ..GpuUsage::default()
        };
        if active.is_empty() {
            return u;
        }
        let exclusive = self.copies[active[0]].part.exclusive_time;
        let n = active.len() as f64;
        for &i in &active {
            let c = &self.copies[i];
            let a = c.active.as_ref().unwrap();
            let (phase, k) = a.kernel_idx;
            let kernel = &c.app.phases[phase].kernels[k];
            let d = a.cur_duration_s;
            let share = if exclusive { 1.0 / n } else { 1.0 };
            u.sm_busy_frac += share * c.part.sms as f64 / self.gpu.sms as f64;
            let fr = kernel.flop_rate_tflops(d);
            for p in crate::gpu::pipelines::ALL_PIPELINES {
                u.flop_rate_tflops[p.index()] += fr * kernel.mix.frac(p);
            }
            u.hbm_rate_tbs += kernel.hbm_rate_tbs(d);
            u.c2c_rate_tbs += kernel.c2c_rate_tbs(d);
        }
        u.sm_busy_frac = u.sm_busy_frac.min(1.0);
        u
    }

    fn sample_power(&mut self, now: f64) {
        let usage = self.usage();
        let changed = self.power.govern(
            &self.gpu,
            &self.power_model,
            &usage,
            self.cfg.power_period_s,
        );
        let w = self
            .power_model
            .reported_w(&self.gpu, &usage, self.power.clock_mhz);
        self.collector.push_power(PowerSample {
            t_s: now,
            power_w: w,
            clock_mhz: self.power.clock_mhz,
            throttled: self.power.throttled,
        });
        if changed {
            self.rebalance(false);
        }
    }

    fn sample_gpm(&mut self, now: f64) {
        let mut active = std::mem::take(&mut self.scratch.active);
        self.fill_active_kernels(&mut active);
        let mut occ = 0.0;
        let mut pipe = [0.0f64; 5];
        let usage = self.usage();
        let exclusive = !active.is_empty() && self.copies[active[0]].part.exclusive_time;
        let n = active.len().max(1) as f64;
        for &i in &active {
            let c = &self.copies[i];
            let (phase, k) = c.active.as_ref().unwrap().kernel_idx;
            let kernel = &c.app.phases[phase].kernels[k];
            let share = if exclusive { 1.0 / n } else { 1.0 };
            occ += share * kernel.occupancy(&self.gpu, c.part.sms) * c.part.sms as f64
                / self.gpu.sms as f64;
            for p in crate::gpu::pipelines::ALL_PIPELINES {
                // Utilization = achieved/peak for that pipeline GPU-wide.
                let peak =
                    self.gpu.pipeline_flops(p, self.gpu.sms, self.power.clock_mhz) / 1e12;
                if peak > 0.0 {
                    pipe[p.index()] += usage.flop_rate_tflops[p.index()] / peak;
                }
            }
        }
        // Memory in use: running copies' resident footprints + contexts.
        let mem_used: f64 = self
            .copies
            .iter()
            .filter(|c| c.started && !c.finished())
            .map(|c| c.app.footprint_gib.min(c.part.mem_capacity_gib))
            .sum::<f64>()
            + self.ctx_total_gib;
        self.collector.push_gpm(GpmSample {
            t_s: now,
            sm_util: usage.sm_busy_frac,
            sm_occupancy: occ,
            pipe_util: pipe,
            bw_util: usage.hbm_rate_tbs * 1e12 / gibs(self.gpu.mem_bw_gibs),
            mem_used_gib: mem_used,
        });
        self.scratch.active = active;
    }
}

/// Max-min fair allocation: distribute `pool` across demands, each capped
/// by `caps[i]`; unsatisfied demands share the surplus evenly
/// (water-filling). Zero-demand entries get their cap (uncontended).
pub fn water_fill(desires: &[f64], caps: &[f64], pool: f64) -> Vec<f64> {
    assert_eq!(desires.len(), caps.len());
    let n = desires.len();
    let mut grant = vec![0.0; n];
    let mut remaining = pool;
    let mut unsat: Vec<usize> = (0..n).filter(|&i| desires[i] > 0.0).collect();
    // Entries with no demand are uncontended: give them their cap.
    for i in 0..n {
        if desires[i] == 0.0 {
            grant[i] = caps[i];
        }
    }
    while !unsat.is_empty() && remaining > 1e-9 {
        let share = remaining / unsat.len() as f64;
        let mut satisfied = Vec::new();
        for &i in &unsat {
            let want = desires[i].min(caps[i]);
            if want <= share {
                grant[i] = want;
                remaining -= want;
                satisfied.push(i);
            }
        }
        if satisfied.is_empty() {
            for &i in &unsat {
                grant[i] = share.min(caps[i]);
            }
            break;
        }
        unsat.retain(|i| !satisfied.contains(i));
    }
    grant
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::ProfileId;

    fn cfg() -> SimConfig {
        SimConfig::fast_test()
    }

    #[test]
    fn water_fill_basics() {
        // Pool 100, demands 80/80, caps 100: each gets 50.
        let g = water_fill(&[80.0, 80.0], &[100.0, 100.0], 100.0);
        assert!((g[0] - 50.0).abs() < 1e-9 && (g[1] - 50.0).abs() < 1e-9);
        // Small demand satisfied, big one takes the rest.
        let g = water_fill(&[10.0, 200.0], &[100.0, 100.0], 100.0);
        assert!((g[0] - 10.0).abs() < 1e-9);
        assert!((g[1] - 90.0).abs() < 1e-9);
        // Zero demand -> cap (uncontended).
        let g = water_fill(&[0.0, 50.0], &[70.0, 70.0], 100.0);
        assert_eq!(g[0], 70.0);
        assert!((g[1] - 50.0).abs() < 1e-9);
    }

    #[test]
    fn single_full_run_close_to_analytic() {
        let spec = CorunSpec::homogeneous(Scheme::Full, AppId::Lammps);
        let (m, _) = simulate(&spec, &cfg()).unwrap();
        let app = apps::model(AppId::Lammps).scaled(cfg().workload_scale);
        let env = ExecEnv {
            sms: 132,
            clock_frac: 1.0,
            bw_gibs: 3175.0,
            c2c_bw_gibs: 331.0,
            interference: 1.0,
            time_share: 1.0,
        };
        // The sim additionally charges the one-time startup phase.
        let analytic = app.runtime_quiet_s(&GpuSpec::gh_h100_96gb(), &env)
            + app.startup_s * cfg().workload_scale;
        assert!(
            (m.makespan_s - analytic).abs() / analytic < 0.05,
            "sim {} vs analytic {}",
            m.makespan_s,
            analytic
        );
        assert_eq!(m.copy_runtimes_s.len(), 1);
    }

    #[test]
    fn serial_is_n_times_single() {
        let one = CorunSpec::homogeneous(Scheme::Full, AppId::Hotspot);
        let (m1, _) = simulate(&one, &cfg()).unwrap();
        let ser = CorunSpec::serial(AppId::Hotspot, 3);
        let (m3, _) = simulate(&ser, &cfg()).unwrap();
        assert!(
            (m3.makespan_s - 3.0 * m1.makespan_s).abs() / m3.makespan_s < 0.02,
            "serial {} vs 3x single {}",
            m3.makespan_s,
            3.0 * m1.makespan_s
        );
        // Serial energy ~ 3x single energy.
        assert!((m3.energy_j - 3.0 * m1.energy_j).abs() / m3.energy_j < 0.05);
    }

    #[test]
    fn mig_corun_isolated_runtimes_equal() {
        let spec = CorunSpec::homogeneous(
            Scheme::Mig {
                profile: ProfileId::P1g12gb,
                copies: 7,
            },
            AppId::Lammps,
        );
        let (m, _) = simulate(&spec, &cfg()).unwrap();
        assert_eq!(m.copy_runtimes_s.len(), 7);
        let t0 = m.copy_runtimes_s[0];
        for t in &m.copy_runtimes_s {
            assert!((t - t0).abs() / t0 < 0.02, "MIG copies should be isolated");
        }
    }

    #[test]
    fn nekrs_corun_speedup_matches_fig5_band() {
        let (serial, _) = simulate(&CorunSpec::serial(AppId::NekRs, 7), &cfg()).unwrap();
        let (mig, _) = simulate(
            &CorunSpec::homogeneous(
                Scheme::Mig {
                    profile: ProfileId::P1g12gb,
                    copies: 7,
                },
                AppId::NekRs,
            ),
            &cfg(),
        )
        .unwrap();
        let speedup = serial.makespan_s / mig.makespan_s;
        assert!(
            (1.9..3.0).contains(&speedup),
            "NekRS 7x1g speedup {speedup:.2} (paper: 2.4)"
        );
    }

    #[test]
    fn qiskit_corun_near_flat() {
        let (serial, _) = simulate(&CorunSpec::serial(AppId::Qiskit30, 7), &cfg()).unwrap();
        let (mig, _) = simulate(
            &CorunSpec::homogeneous(
                Scheme::Mig {
                    profile: ProfileId::P1g12gb,
                    copies: 7,
                },
                AppId::Qiskit30,
            ),
            &cfg(),
        )
        .unwrap();
        let speedup = serial.makespan_s / mig.makespan_s;
        assert!(
            (0.80..1.10).contains(&speedup),
            "Qiskit 7x1g speedup {speedup:.2} (paper: ~1)"
        );
    }

    #[test]
    fn timeslice_serializes() {
        let (ts, _) = simulate(
            &CorunSpec::homogeneous(Scheme::TimeSlice { copies: 7 }, AppId::Hotspot),
            &cfg(),
        )
        .unwrap();
        let (serial, _) = simulate(&CorunSpec::serial(AppId::Hotspot, 7), &cfg()).unwrap();
        // Compute-bound: time-slicing ≈ serial + switch overhead.
        let ratio = ts.makespan_s / serial.makespan_s;
        assert!(
            (1.0..1.2).contains(&ratio),
            "TS/serial ratio {ratio:.3} for compute-bound app"
        );
    }

    #[test]
    fn qiskit_full_gpu_throttles_but_7x1g_does_not() {
        // Fig. 7a.
        let (full, _) = simulate(
            &CorunSpec::homogeneous(Scheme::Full, AppId::Qiskit30),
            &cfg(),
        )
        .unwrap();
        assert!(
            full.throttled_time_s > 0.3 * full.makespan_s,
            "full-GPU Qiskit should throttle (throttled {:.1}s of {:.1}s)",
            full.throttled_time_s,
            full.makespan_s
        );
        let (mig, _) = simulate(
            &CorunSpec::homogeneous(
                Scheme::Mig {
                    profile: ProfileId::P1g12gb,
                    copies: 7,
                },
                AppId::Qiskit30,
            ),
            &cfg(),
        )
        .unwrap();
        assert!(
            mig.throttled_time_s < 0.05 * mig.makespan_s,
            "7x1g Qiskit should not throttle"
        );
        assert!(mig.max_power_w < 700.0, "max power {}", mig.max_power_w);
        assert!(mig.max_power_w > 600.0, "max power {}", mig.max_power_w);
    }

    #[test]
    fn footprint_admission_enforced() {
        // Llama3-fp16 (16.5 GiB) cannot run on 1g.12gb without offload.
        let spec = CorunSpec::homogeneous(
            Scheme::Mig {
                profile: ProfileId::P1g12gb,
                copies: 1,
            },
            AppId::Llama3Fp16,
        );
        assert!(simulate(&spec, &cfg()).is_err());
        // With an offload plan it runs.
        let app = apps::model(AppId::Llama3Fp16);
        let plan = OffloadPlan::plan(&app, 10.94).unwrap();
        let spec = CorunSpec {
            offload: vec![Some(plan)],
            ..CorunSpec::homogeneous(
                Scheme::Mig {
                    profile: ProfileId::P1g12gb,
                    copies: 1,
                },
                AppId::Llama3Fp16,
            )
        };
        let (m, _) = simulate(&spec, &cfg()).unwrap();
        assert!(m.makespan_s > 0.0);
    }

    #[test]
    fn deterministic_across_runs() {
        let spec = CorunSpec::homogeneous(
            Scheme::Mps {
                sm_pct: 13,
                copies: 7,
            },
            AppId::Faiss,
        );
        let (a, _) = simulate(&spec, &cfg()).unwrap();
        let (b, _) = simulate(&spec, &cfg()).unwrap();
        assert_eq!(a.makespan_s, b.makespan_s);
        assert_eq!(a.energy_j, b.energy_j);
    }
}
