//! PJRT runtime: loads AOT-compiled HLO artifacts (produced by
//! `python/compile/aot.py`) and executes them on the CPU PJRT client.
//!
//! Python never runs on this path — the interchange format is HLO *text*
//! (jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids). See /opt/xla-example/README.md.
//!
//! - `Registry`: parses `artifacts/manifest.json` (name → file, input
//!   shapes/dtypes, FLOP/byte estimates).
//! - `Executor`: PJRT CPU client with a compile cache; `execute` runs an
//!   artifact with caller literals, `smoke_run` feeds synthetic inputs.

use crate::util::json::Json;
#[cfg(feature = "pjrt")]
use crate::util::Rng;
use anyhow::{anyhow, bail, Context};
#[cfg(feature = "pjrt")]
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One input tensor specification.
#[derive(Debug, Clone)]
pub struct InputSpec {
    pub shape: Vec<i64>,
    /// Only "f32" is supported end-to-end (models cast internally).
    pub dtype: String,
}

impl InputSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product::<i64>().max(1) as usize
    }
}

/// One AOT artifact entry.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<InputSpec>,
    pub description: String,
    /// Analytic cost estimates recorded by the AOT step (for roofline
    /// notes and the e2e driver's achieved-rate reporting).
    pub flops: f64,
    pub bytes: f64,
}

/// The artifact registry loaded from `manifest.json`.
#[derive(Debug, Default)]
pub struct Registry {
    artifacts: Vec<Artifact>,
    dir: PathBuf,
}

impl Registry {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> crate::Result<Registry> {
        let manifest = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest).with_context(|| {
            format!(
                "reading {} (run `make artifacts` first)",
                manifest.display()
            )
        })?;
        Self::from_json_text(&text, dir)
    }

    /// Parse a manifest document (separated for tests).
    pub fn from_json_text(text: &str, dir: &Path) -> crate::Result<Registry> {
        let json = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let arr = json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow!("manifest missing 'artifacts' array"))?;
        let mut artifacts = Vec::new();
        for entry in arr {
            let name = entry
                .get("name")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("artifact missing name"))?
                .to_string();
            let file = entry
                .get("file")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("artifact {name} missing file"))?;
            let mut inputs = Vec::new();
            for inp in entry
                .get("inputs")
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("artifact {name} missing inputs"))?
            {
                let shape: Vec<i64> = inp
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("input missing shape"))?
                    .iter()
                    .map(|d| d.as_f64().unwrap_or(0.0) as i64)
                    .collect();
                let dtype = inp
                    .get("dtype")
                    .and_then(|v| v.as_str())
                    .unwrap_or("f32")
                    .to_string();
                if dtype != "f32" {
                    bail!("artifact {name}: unsupported input dtype {dtype} (models must take f32)");
                }
                inputs.push(InputSpec { shape, dtype });
            }
            artifacts.push(Artifact {
                name,
                file: dir.join(file),
                inputs,
                description: entry
                    .get("description")
                    .and_then(|v| v.as_str())
                    .unwrap_or("")
                    .to_string(),
                flops: entry.get("flops").and_then(|v| v.as_f64()).unwrap_or(0.0),
                bytes: entry.get("bytes").and_then(|v| v.as_f64()).unwrap_or(0.0),
            });
        }
        Ok(Registry {
            artifacts,
            dir: dir.to_path_buf(),
        })
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }

    pub fn names(&self) -> Vec<String> {
        self.artifacts.iter().map(|a| a.name.clone()).collect()
    }

    pub fn get(&self, name: &str) -> Option<&Artifact> {
        self.artifacts.iter().find(|a| a.name == name)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }
}

/// Result of a smoke execution.
#[derive(Debug, Clone, Copy)]
pub struct SmokeStats {
    pub outputs: usize,
    /// Sum of the first output's elements — a cheap numeric fingerprint.
    pub checksum: f64,
    pub elements: usize,
}

/// PJRT executor with a compile cache.
#[cfg(feature = "pjrt")]
pub struct Executor {
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

/// Stub executor built when the `pjrt` feature is off (the default): the
/// whole simulator works — only real PJRT execution is unavailable.
/// Construction fails with a clear message instead of a link error, so
/// `migsim runtime` degrades gracefully on machines without the XLA
/// toolchain.
#[cfg(not(feature = "pjrt"))]
pub struct Executor {
    #[allow(dead_code)]
    private: (),
}

#[cfg(not(feature = "pjrt"))]
impl Executor {
    pub fn new() -> crate::Result<Executor> {
        bail!(
            "migsim was built without the `pjrt` feature; PJRT execution is \
             unavailable. On a machine with the XLA toolchain, add the `xla` \
             dependency in rust/Cargo.toml (see the [features] comment) and \
             rebuild with `--features pjrt`."
        )
    }

    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    pub fn compile(&mut self, _reg: &Registry, name: &str) -> crate::Result<()> {
        bail!("cannot compile '{name}': built without the `pjrt` feature")
    }

    pub fn smoke_run(&mut self, _reg: &Registry, name: &str) -> crate::Result<SmokeStats> {
        bail!("cannot execute '{name}': built without the `pjrt` feature")
    }
}

#[cfg(feature = "pjrt")]
impl Executor {
    pub fn new() -> crate::Result<Executor> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Executor {
            client,
            cache: HashMap::new(),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) an artifact.
    pub fn compile(&mut self, reg: &Registry, name: &str) -> crate::Result<()> {
        if self.cache.contains_key(name) {
            return Ok(());
        }
        let art = reg
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named '{name}'"))?;
        let path = art
            .file
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 path"))?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {}: {e}", art.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e}"))?;
        self.cache.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an artifact with the given input literals. Outputs are the
    /// decomposed result tuple (models are lowered with
    /// `return_tuple=True`).
    pub fn execute(
        &mut self,
        reg: &Registry,
        name: &str,
        inputs: &[xla::Literal],
    ) -> crate::Result<Vec<xla::Literal>> {
        self.compile(reg, name)?;
        let exe = self.cache.get(name).unwrap();
        let result = exe
            .execute::<xla::Literal>(inputs)
            .map_err(|e| anyhow!("executing {name}: {e}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e}"))?;
        result
            .to_tuple()
            .map_err(|e| anyhow!("decomposing result tuple of {name}: {e}"))
    }

    /// Build deterministic synthetic inputs for an artifact.
    pub fn synthetic_inputs(art: &Artifact, seed: u64) -> crate::Result<Vec<xla::Literal>> {
        let mut rng = Rng::new(seed);
        art.inputs
            .iter()
            .map(|spec| {
                let n = spec.elements();
                let data: Vec<f32> = (0..n).map(|_| rng.range(-0.5, 0.5) as f32).collect();
                let lit = xla::Literal::vec1(&data);
                if spec.shape.is_empty() {
                    Ok(xla::Literal::scalar(data[0]))
                } else {
                    lit.reshape(&spec.shape)
                        .map_err(|e| anyhow!("reshape {:?}: {e}", spec.shape))
                }
            })
            .collect()
    }

    /// Execute with synthetic inputs and fingerprint the first output.
    pub fn smoke_run(&mut self, reg: &Registry, name: &str) -> crate::Result<SmokeStats> {
        let art = reg
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named '{name}'"))?
            .clone();
        let inputs = Self::synthetic_inputs(&art, 0xA0_7)?;
        let outputs = self.execute(reg, name, &inputs)?;
        anyhow::ensure!(!outputs.is_empty(), "{name} returned an empty tuple");
        let first = &outputs[0];
        let v: Vec<f32> = first
            .convert(xla::PrimitiveType::F32)
            .map_err(|e| anyhow!("{e}"))?
            .to_vec()
            .map_err(|e| anyhow!("{e}"))?;
        let checksum: f64 = v.iter().map(|&x| x as f64).sum();
        anyhow::ensure!(
            checksum.is_finite(),
            "{name} produced a non-finite checksum"
        );
        Ok(SmokeStats {
            outputs: outputs.len(),
            checksum,
            elements: v.len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MANIFEST: &str = r#"{
      "artifacts": [
        {"name": "toy", "file": "toy.hlo.txt",
         "inputs": [{"shape": [2, 2], "dtype": "f32"}],
         "description": "demo", "flops": 12.0, "bytes": 32.0}
      ]
    }"#;

    #[test]
    fn manifest_parses() {
        let reg = Registry::from_json_text(MANIFEST, Path::new("/tmp/artifacts")).unwrap();
        assert_eq!(reg.len(), 1);
        let a = reg.get("toy").unwrap();
        assert_eq!(a.inputs[0].shape, vec![2, 2]);
        assert_eq!(a.inputs[0].elements(), 4);
        assert!(reg.get("missing").is_none());
    }

    #[test]
    fn manifest_rejects_bad_dtype() {
        let bad = MANIFEST.replace("f32", "s32");
        assert!(Registry::from_json_text(&bad, Path::new("/tmp")).is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_executor_fails_with_clear_message() {
        let err = Executor::new().err().expect("stub must not construct");
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn executor_builds_and_runs_builder_computation() {
        // No artifacts needed: exercise the PJRT path with XlaBuilder.
        let client = xla::PjRtClient::cpu().unwrap();
        let builder = xla::XlaBuilder::new("t");
        let p = builder
            .parameter_s(0, &xla::Shape::array::<f32>(vec![2]), "p")
            .unwrap();
        let comp = p.add_(&p).unwrap().build().unwrap();
        let exe = client.compile(&comp).unwrap();
        let x = xla::Literal::vec1(&[1.5f32, 2.5f32]);
        let out = exe.execute::<xla::Literal>(&[x]).unwrap()[0][0]
            .to_literal_sync()
            .unwrap();
        assert_eq!(out.to_vec::<f32>().unwrap(), vec![3.0f32, 5.0f32]);
    }

    #[cfg(feature = "pjrt")]
    #[test]
    fn synthetic_inputs_deterministic() {
        let art = Artifact {
            name: "x".into(),
            file: PathBuf::from("/x"),
            inputs: vec![InputSpec {
                shape: vec![3, 4],
                dtype: "f32".into(),
            }],
            description: String::new(),
            flops: 0.0,
            bytes: 0.0,
        };
        let a = Executor::synthetic_inputs(&art, 7).unwrap();
        let b = Executor::synthetic_inputs(&art, 7).unwrap();
        assert_eq!(
            a[0].to_vec::<f32>().unwrap(),
            b[0].to_vec::<f32>().unwrap()
        );
        assert_eq!(a[0].element_count(), 12);
    }

    /// Full round trip against real artifacts when they exist (after
    /// `make artifacts`); skipped otherwise so unit tests don't depend on
    /// the python toolchain.
    #[cfg(feature = "pjrt")]
    #[test]
    fn artifacts_smoke_if_present() {
        let dir = Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts/ (run `make artifacts`)");
            return;
        }
        let reg = Registry::load(dir).unwrap();
        let mut exec = Executor::new().unwrap();
        for name in reg.names() {
            let stats = exec.smoke_run(&reg, &name).unwrap();
            assert!(stats.outputs >= 1, "{name}");
        }
    }
}
