//! In-repo micro/macro benchmark harness (criterion is unavailable offline).
//!
//! `rust/benches/*.rs` are `harness = false` binaries built on this module.
//! Each bench: warms up, runs timed iterations until both a minimum
//! iteration count and a minimum wall budget are met, and reports
//! mean/median/p95/std plus throughput. Results can be appended as JSON to
//! `results/bench/*.json` for the EXPERIMENTS.md §Perf log.

use crate::util::{json::Json, stats};
use std::time::{Duration, Instant};

/// Config for one benchmark run.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: u32,
    pub min_iters: u32,
    pub min_time: Duration,
    pub max_iters: u32,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig {
            warmup_iters: 3,
            min_iters: 10,
            min_time: Duration::from_millis(300),
            max_iters: 10_000,
        }
    }
}

impl BenchConfig {
    /// Smoke-mode config: a single untimed-quality iteration per bench,
    /// so CI can execute every bench binary end-to-end (`cargo bench --
    /// --smoke`) without paying for statistics.
    pub fn smoke() -> BenchConfig {
        BenchConfig {
            warmup_iters: 0,
            min_iters: 1,
            min_time: Duration::ZERO,
            max_iters: 1,
        }
    }
}

/// Result of a benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_s: f64,
    pub median_s: f64,
    pub p95_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    /// Optional units-of-work per iteration, for throughput reporting.
    pub work_per_iter: Option<f64>,
    pub work_unit: String,
}

impl BenchResult {
    pub fn throughput(&self) -> Option<f64> {
        self.work_per_iter.map(|w| w / self.mean_s)
    }

    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("name", self.name.as_str())
            .set("iters", self.iters as u64)
            .set("mean_s", self.mean_s)
            .set("median_s", self.median_s)
            .set("p95_s", self.p95_s)
            .set("std_s", self.std_s)
            .set("min_s", self.min_s);
        if let Some(t) = self.throughput() {
            o.set("throughput", t).set("work_unit", self.work_unit.as_str());
        }
        o
    }

    pub fn report_line(&self) -> String {
        let tp = match self.throughput() {
            Some(t) => format!("  {:>12.1} {}/s", t, self.work_unit),
            None => String::new(),
        };
        format!(
            "{:<44} {:>10} {:>12} ±{:>10}  (p95 {:>10}, n={}){}",
            self.name,
            crate::util::units::human_time(self.mean_s),
            crate::util::units::human_time(self.median_s),
            crate::util::units::human_time(self.std_s),
            crate::util::units::human_time(self.p95_s),
            self.iters,
            tp
        )
    }
}

/// A group of benchmarks sharing a config, mirroring criterion's group API.
pub struct Bencher {
    config: BenchConfig,
    results: Vec<BenchResult>,
    filter: Option<String>,
    smoke: bool,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Bencher {
        // `cargo bench -- <filter>` support.
        let filter = std::env::args().skip(1).find(|a| !a.starts_with('-'));
        // `cargo bench -- --smoke` (or MIGSIM_BENCH_SMOKE=1): one
        // iteration per bench — a bit-rot check, not a measurement.
        let smoke = std::env::args().skip(1).any(|a| a == "--smoke")
            || std::env::var_os("MIGSIM_BENCH_SMOKE").is_some();
        Bencher {
            config: if smoke {
                BenchConfig::smoke()
            } else {
                BenchConfig::default()
            },
            results: Vec::new(),
            filter,
            smoke,
        }
    }

    /// Whether smoke mode is active — benches should also shrink their
    /// *workloads* (fleet sizes, job counts), not just iteration counts.
    pub fn smoke(&self) -> bool {
        self.smoke
    }

    pub fn with_config(mut self, c: BenchConfig) -> Bencher {
        self.config = if self.smoke { BenchConfig::smoke() } else { c };
        self
    }

    fn skip(&self, name: &str) -> bool {
        match &self.filter {
            Some(f) => !name.contains(f.as_str()),
            None => false,
        }
    }

    /// Time `f`, which performs one iteration of work and returns a value
    /// (returned values are black-boxed to defeat DCE).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> Option<&BenchResult> {
        self.bench_with_work(name, None, "", move || f())
    }

    /// Time `f` with a known amount of work per iteration for throughput.
    pub fn bench_with_work<T>(
        &mut self,
        name: &str,
        work_per_iter: Option<f64>,
        work_unit: &str,
        mut f: impl FnMut() -> T,
    ) -> Option<&BenchResult> {
        if self.skip(name) {
            return None;
        }
        for _ in 0..self.config.warmup_iters {
            black_box(f());
        }
        let mut samples = Vec::new();
        let started = Instant::now();
        while (samples.len() < self.config.min_iters as usize
            || started.elapsed() < self.config.min_time)
            && samples.len() < self.config.max_iters as usize
        {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut acc = stats::Accum::new();
        samples.iter().for_each(|&s| acc.push(s));
        let res = BenchResult {
            name: name.to_string(),
            iters: samples.len() as u32,
            mean_s: acc.mean(),
            median_s: stats::percentile_sorted(&samples, 50.0),
            p95_s: stats::percentile_sorted(&samples, 95.0),
            std_s: acc.std(),
            min_s: acc.min(),
            work_per_iter,
            work_unit: work_unit.to_string(),
        };
        println!("{}", res.report_line());
        self.results.push(res);
        self.results.last()
    }

    /// Write all results as JSON to `results/bench/<suite>.json`.
    pub fn finish(self, suite: &str) {
        if self.results.is_empty() {
            return;
        }
        let dir = std::path::Path::new("results/bench");
        if std::fs::create_dir_all(dir).is_err() {
            return; // benches may run from a read-only checkout; report only
        }
        let mut doc = Json::obj();
        doc.set("suite", suite).set(
            "results",
            Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
        );
        let path = dir.join(format!("{suite}.json"));
        let _ = std::fs::write(&path, doc.pretty());
        println!("-- wrote {}", path.display());
    }
}

/// Opaque value sink, same trick as `std::hint::black_box` (stable since
/// 1.66 — use the std one).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runs_and_reports() {
        let mut b = Bencher::new().with_config(BenchConfig {
            warmup_iters: 1,
            min_iters: 5,
            min_time: Duration::from_millis(1),
            max_iters: 8,
        });
        let r = b
            .bench_with_work("spin", Some(1000.0), "ops", || {
                (0..1000u64).fold(0u64, |a, x| a.wrapping_add(x * x))
            })
            .unwrap()
            .clone();
        assert!(r.iters >= 5);
        assert!(r.mean_s > 0.0);
        assert!(r.throughput().unwrap() > 0.0);
        let j = r.to_json();
        assert_eq!(j.get("name").unwrap().as_str(), Some("spin"));
    }
}
