//! The event queue proper. See module docs in `sim/mod.rs`.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Opaque token identifying a scheduled event, used for cancellation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventToken(u64);

/// A popped event with its firing time.
#[derive(Debug, Clone)]
pub struct Scheduled<E> {
    pub time_ns: u64,
    pub token: EventToken,
    pub event: E,
}

struct Entry<E> {
    time_ns: u64,
    seq: u64,
    event: E,
}

// Min-heap by (time, seq): BinaryHeap is a max-heap, so invert the ordering.
impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time_ns == other.time_ns && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        (other.time_ns, other.seq).cmp(&(self.time_ns, self.seq))
    }
}

/// Discrete-event queue with cancellation and deterministic FIFO
/// tie-breaking. Cancellation is lazy: cancelled tokens are skipped at pop
/// time, keeping `cancel` O(1).
pub struct Engine<E> {
    heap: BinaryHeap<Entry<E>>,
    now_ns: u64,
    seq: u64,
    // Sorted vec of cancelled seqs still in the heap. Typically tiny
    // (pending kernel-completion re-estimates), so a vec beats a HashSet.
    cancelled: Vec<u64>,
    popped: u64,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Engine<E> {
        Engine {
            heap: BinaryHeap::with_capacity(1024),
            now_ns: 0,
            seq: 0,
            cancelled: Vec::new(),
            popped: 0,
        }
    }

    /// Current simulation time in nanoseconds.
    pub fn now_ns(&self) -> u64 {
        self.now_ns
    }

    /// Current simulation time in seconds.
    pub fn now(&self) -> f64 {
        crate::util::units::ns_to_sec(self.now_ns)
    }

    /// Number of events dispatched so far (for the perf counters).
    pub fn popped(&self) -> u64 {
        self.popped
    }

    /// Pending (non-cancelled) event count.
    pub fn len(&self) -> usize {
        self.heap.len() - self.cancelled.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Schedule at an absolute time. Panics on scheduling into the past —
    /// that is always a simulator bug.
    pub fn schedule_at(&mut self, time_ns: u64, event: E) -> EventToken {
        assert!(
            time_ns >= self.now_ns,
            "time travel: scheduling at {time_ns} < now {}",
            self.now_ns
        );
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry {
            time_ns,
            seq,
            event,
        });
        EventToken(seq)
    }

    /// Schedule relative to now.
    pub fn schedule_in(&mut self, delta_ns: u64, event: E) -> EventToken {
        self.schedule_at(self.now_ns.saturating_add(delta_ns), event)
    }

    /// Cancel a previously scheduled event. Cancelling an already-fired or
    /// already-cancelled token is a no-op.
    pub fn cancel(&mut self, token: EventToken) {
        if let Err(i) = self.cancelled.binary_search(&token.0) {
            self.cancelled.insert(i, token.0);
        }
    }

    /// Pop the next non-cancelled event, advancing the clock.
    pub fn pop(&mut self) -> Option<Scheduled<E>> {
        while let Some(entry) = self.heap.pop() {
            if let Ok(i) = self.cancelled.binary_search(&entry.seq) {
                self.cancelled.remove(i);
                continue;
            }
            self.now_ns = entry.time_ns;
            self.popped += 1;
            return Some(Scheduled {
                time_ns: entry.time_ns,
                token: EventToken(entry.seq),
                event: entry.event,
            });
        }
        None
    }

    /// Peek the firing time of the next live event without advancing.
    pub fn peek_time_ns(&mut self) -> Option<u64> {
        // Drain cancelled heads first so the peek is accurate.
        while let Some(head) = self.heap.peek() {
            if let Ok(i) = self.cancelled.binary_search(&head.seq) {
                self.cancelled.remove(i);
                self.heap.pop();
            } else {
                return Some(head.time_ns);
            }
        }
        None
    }
}
