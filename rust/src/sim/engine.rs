//! The event queue proper. See module docs in `sim/mod.rs`.

use std::cmp::Ordering;
use std::collections::{BinaryHeap, HashSet, VecDeque};

/// Opaque token identifying a scheduled event, used for cancellation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct EventToken(u64);

/// A popped event with its firing time.
#[derive(Debug, Clone)]
pub struct Scheduled<E> {
    pub time_ns: u64,
    pub token: EventToken,
    pub event: E,
}

struct Entry<E> {
    time_ns: u64,
    seq: u64,
    event: E,
}

// Min-heap by (time, seq): BinaryHeap is a max-heap, so invert the ordering.
impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time_ns == other.time_ns && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        (other.time_ns, other.seq).cmp(&(self.time_ns, self.seq))
    }
}

/// Tracks which event seqs have *left the heap* (fired, or skipped at pop
/// time after cancellation), so `cancel` can reject stale tokens in O(1).
///
/// Seqs are dense and consumed roughly in order, so the set is a
/// watermark plus a small bitmap window: every seq below `start_seq` is
/// consumed, and `words` covers `[start_seq, start_seq + 64*words.len())`.
/// Fully-consumed leading words advance the watermark, keeping the window
/// no wider than the span of still-live events.
#[derive(Debug, Default)]
struct ConsumedSet {
    /// All seqs below this are consumed. Always a multiple of 64.
    start_seq: u64,
    words: VecDeque<u64>,
}

impl ConsumedSet {
    fn contains(&self, seq: u64) -> bool {
        if seq < self.start_seq {
            return true;
        }
        match self.words.get(((seq - self.start_seq) / 64) as usize) {
            Some(w) => w & (1u64 << (seq % 64)) != 0,
            None => false,
        }
    }

    fn insert(&mut self, seq: u64) {
        if seq < self.start_seq {
            return; // already below the watermark
        }
        let idx = ((seq - self.start_seq) / 64) as usize;
        while self.words.len() <= idx {
            self.words.push_back(0);
        }
        self.words[idx] |= 1u64 << (seq % 64);
        // Advance the watermark past fully-consumed leading words.
        while self.words.front() == Some(&u64::MAX) {
            self.words.pop_front();
            self.start_seq += 64;
        }
    }
}

/// Discrete-event queue with cancellation and deterministic FIFO
/// tie-breaking. Cancellation is lazy: cancelled tokens are skipped at pop
/// time, keeping `cancel` O(1). A fired-watermark (`ConsumedSet`) makes
/// cancelling an already-fired token a true no-op — it used to leak a
/// stale seq into the cancelled set, under-reporting `len()` until the
/// subtraction underflowed once the heap drained.
pub struct Engine<E> {
    heap: BinaryHeap<Entry<E>>,
    now_ns: u64,
    seq: u64,
    /// Cancelled seqs still sitting in the heap (invariant: a subset of
    /// the heap, enforced by the `consumed` guard in `cancel`).
    cancelled: HashSet<u64>,
    consumed: ConsumedSet,
    popped: u64,
}

impl<E> Default for Engine<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> Engine<E> {
    pub fn new() -> Engine<E> {
        Engine {
            heap: BinaryHeap::with_capacity(1024),
            now_ns: 0,
            seq: 0,
            cancelled: HashSet::new(),
            consumed: ConsumedSet::default(),
            popped: 0,
        }
    }

    /// Current simulation time in nanoseconds.
    pub fn now_ns(&self) -> u64 {
        self.now_ns
    }

    /// Current simulation time in seconds.
    pub fn now(&self) -> f64 {
        crate::util::units::ns_to_sec(self.now_ns)
    }

    /// Number of events dispatched so far (for the perf counters).
    pub fn popped(&self) -> u64 {
        self.popped
    }

    /// Pending (non-cancelled) event count.
    pub fn len(&self) -> usize {
        self.heap.len() - self.cancelled.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Schedule at an absolute time. Panics on scheduling into the past —
    /// that is always a simulator bug.
    pub fn schedule_at(&mut self, time_ns: u64, event: E) -> EventToken {
        assert!(
            time_ns >= self.now_ns,
            "time travel: scheduling at {time_ns} < now {}",
            self.now_ns
        );
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Entry {
            time_ns,
            seq,
            event,
        });
        EventToken(seq)
    }

    /// Schedule relative to now.
    pub fn schedule_in(&mut self, delta_ns: u64, event: E) -> EventToken {
        self.schedule_at(self.now_ns.saturating_add(delta_ns), event)
    }

    /// Cancel a previously scheduled event. Cancelling an already-fired,
    /// already-skipped or already-cancelled token is a no-op.
    pub fn cancel(&mut self, token: EventToken) {
        if self.consumed.contains(token.0) {
            return; // token already left the heap; nothing to cancel
        }
        self.cancelled.insert(token.0);
    }

    /// Pop the next non-cancelled event, advancing the clock.
    pub fn pop(&mut self) -> Option<Scheduled<E>> {
        while let Some(entry) = self.heap.pop() {
            self.consumed.insert(entry.seq);
            if self.cancelled.remove(&entry.seq) {
                continue;
            }
            self.now_ns = entry.time_ns;
            self.popped += 1;
            return Some(Scheduled {
                time_ns: entry.time_ns,
                token: EventToken(entry.seq),
                event: entry.event,
            });
        }
        None
    }

    /// Peek the firing time of the next live event without advancing.
    pub fn peek_time_ns(&mut self) -> Option<u64> {
        // Drain cancelled heads first so the peek is accurate.
        loop {
            let (seq, time_ns) = match self.heap.peek() {
                Some(head) => (head.seq, head.time_ns),
                None => return None,
            };
            if self.cancelled.remove(&seq) {
                self.consumed.insert(seq);
                self.heap.pop();
            } else {
                return Some(time_ns);
            }
        }
    }
}
