//! Discrete-event simulation engine.
//!
//! The engine is deliberately small and allocation-light on the hot path:
//! a binary heap of `(time_ns, seq, event)` entries with a monotonic
//! sequence number for FIFO tie-breaking (deterministic replay), plus
//! cancellable timer tokens. Cancellation is lazy and O(1); a
//! fired-watermark (`ConsumedSet`) keeps stale cancels of already-fired
//! tokens from corrupting the pending count. The GPU co-run simulator
//! (`coordinator::corun`) and the cluster serving loop (`cluster::serve`)
//! drive their state machines on top of this queue.

mod engine;

pub use engine::{Engine, EventToken, Scheduled};

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    enum Ev {
        A,
        B(u32),
    }

    #[test]
    fn orders_by_time_then_fifo() {
        let mut e: Engine<Ev> = Engine::new();
        e.schedule_at(10, Ev::B(1));
        e.schedule_at(5, Ev::A);
        e.schedule_at(10, Ev::B(2));
        let mut seen = Vec::new();
        while let Some(Scheduled { time_ns, event, .. }) = e.pop() {
            seen.push((time_ns, event));
        }
        assert_eq!(seen, vec![(5, Ev::A), (10, Ev::B(1)), (10, Ev::B(2))]);
    }

    #[test]
    fn now_advances_monotonically() {
        let mut e: Engine<Ev> = Engine::new();
        e.schedule_in(3, Ev::A);
        e.schedule_in(1, Ev::A);
        assert_eq!(e.now_ns(), 0);
        e.pop();
        assert_eq!(e.now_ns(), 1);
        e.pop();
        assert_eq!(e.now_ns(), 3);
    }

    #[test]
    fn cancellation() {
        let mut e: Engine<Ev> = Engine::new();
        let t1 = e.schedule_at(1, Ev::A);
        let _t2 = e.schedule_at(2, Ev::B(9));
        e.cancel(t1);
        let first = e.pop().unwrap();
        assert_eq!(first.event, Ev::B(9));
        assert!(e.pop().is_none());
    }

    #[test]
    fn cancel_after_fire_is_a_noop() {
        // Regression: cancelling a token that already fired used to leak
        // its seq into the cancelled set, making `len()` under-report and
        // eventually underflow once the heap drained.
        let mut e: Engine<Ev> = Engine::new();
        let t1 = e.schedule_at(1, Ev::A);
        let _t2 = e.schedule_at(2, Ev::B(1));
        assert_eq!(e.len(), 2);
        let fired = e.pop().unwrap();
        assert_eq!(fired.token, t1);
        e.cancel(t1);
        assert_eq!(e.len(), 1, "stale cancel must not shrink the queue");
        e.cancel(t1);
        assert_eq!(e.len(), 1);
        assert!(e.pop().is_some());
        assert_eq!(e.len(), 0); // underflowed (debug panic) before the fix
        assert!(e.pop().is_none());
    }

    #[test]
    fn cancel_of_already_skipped_token_is_a_noop() {
        // A cancelled token that was silently skipped at pop time is just
        // as consumed as a fired one.
        let mut e: Engine<Ev> = Engine::new();
        let t1 = e.schedule_at(1, Ev::A);
        e.schedule_at(2, Ev::A);
        e.cancel(t1);
        assert_eq!(e.len(), 1);
        assert_eq!(e.pop().unwrap().time_ns, 2); // skips + consumes t1
        e.cancel(t1);
        assert_eq!(e.len(), 0);
        assert!(e.pop().is_none());
    }

    #[test]
    fn consumed_watermark_survives_out_of_order_firing() {
        // Fire events far out of seq order, then stale-cancel every one
        // of them: len() must stay exact throughout.
        let mut e: Engine<u64> = Engine::new();
        let mut tokens = Vec::new();
        for i in 0..200u64 {
            // Later seqs fire earlier (descending times).
            tokens.push(e.schedule_at(1_000 - i, i));
        }
        for _ in 0..200 {
            e.pop().unwrap();
        }
        for t in tokens {
            e.cancel(t);
        }
        assert_eq!(e.len(), 0);
        let live = e.schedule_in(5, 999);
        assert_eq!(e.len(), 1);
        e.cancel(live);
        assert_eq!(e.len(), 0);
        assert!(e.pop().is_none());
    }

    #[test]
    #[should_panic(expected = "time travel")]
    fn rejects_past_events() {
        let mut e: Engine<Ev> = Engine::new();
        e.schedule_at(10, Ev::A);
        e.pop();
        e.schedule_at(5, Ev::A);
    }

    #[test]
    fn stress_many_events_deterministic() {
        let run = || {
            let mut e: Engine<u64> = Engine::new();
            let mut rng = crate::util::Rng::new(42);
            for i in 0..10_000u64 {
                e.schedule_at(rng.below(1_000_000), i);
            }
            let mut order = Vec::with_capacity(10_000);
            while let Some(s) = e.pop() {
                order.push(s.event);
            }
            order
        };
        assert_eq!(run(), run());
    }
}
