//! Discrete-event simulation engine.
//!
//! The engine is deliberately small and allocation-free on the hot path:
//! a binary heap of `(time_ns, seq, event)` entries with a monotonic
//! sequence number for FIFO tie-breaking (deterministic replay), plus
//! cancellable timer tokens. The GPU co-run simulator
//! (`coordinator::corun`) drives its state machine on top of this queue.

mod engine;

pub use engine::{Engine, EventToken, Scheduled};

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    enum Ev {
        A,
        B(u32),
    }

    #[test]
    fn orders_by_time_then_fifo() {
        let mut e: Engine<Ev> = Engine::new();
        e.schedule_at(10, Ev::B(1));
        e.schedule_at(5, Ev::A);
        e.schedule_at(10, Ev::B(2));
        let mut seen = Vec::new();
        while let Some(Scheduled { time_ns, event, .. }) = e.pop() {
            seen.push((time_ns, event));
        }
        assert_eq!(seen, vec![(5, Ev::A), (10, Ev::B(1)), (10, Ev::B(2))]);
    }

    #[test]
    fn now_advances_monotonically() {
        let mut e: Engine<Ev> = Engine::new();
        e.schedule_in(3, Ev::A);
        e.schedule_in(1, Ev::A);
        assert_eq!(e.now_ns(), 0);
        e.pop();
        assert_eq!(e.now_ns(), 1);
        e.pop();
        assert_eq!(e.now_ns(), 3);
    }

    #[test]
    fn cancellation() {
        let mut e: Engine<Ev> = Engine::new();
        let t1 = e.schedule_at(1, Ev::A);
        let _t2 = e.schedule_at(2, Ev::B(9));
        e.cancel(t1);
        let first = e.pop().unwrap();
        assert_eq!(first.event, Ev::B(9));
        assert!(e.pop().is_none());
    }

    #[test]
    #[should_panic(expected = "time travel")]
    fn rejects_past_events() {
        let mut e: Engine<Ev> = Engine::new();
        e.schedule_at(10, Ev::A);
        e.pop();
        e.schedule_at(5, Ev::A);
    }

    #[test]
    fn stress_many_events_deterministic() {
        let run = || {
            let mut e: Engine<u64> = Engine::new();
            let mut rng = crate::util::Rng::new(42);
            for i in 0..10_000u64 {
                e.schedule_at(rng.below(1_000_000), i);
            }
            let mut order = Vec::with_capacity(10_000);
            while let Some(s) = e.pop() {
                order.push(s.event);
            }
            order
        };
        assert_eq!(run(), run());
    }
}
