//! Streaming-multiprocessor scheduling model.
//!
//! Captures the two §IV-A causes of compute underutilization:
//! 1. the **tail effect** — the last wave of thread blocks leaves SMs idle
//!    (more severe on larger partitions), and
//! 2. **occupancy** — active warps relative to the hardware maximum.
//!
//! Also implements the §III-C SM-count probe: a fixed-duration kernel is
//! launched with increasing block counts; the first block count whose
//! runtime doubles reveals `N_SM + 1`.

/// Number of scheduling waves for `blocks` thread blocks on `sms` SMs with
/// `blocks_per_sm` concurrently resident blocks per SM.
pub fn waves(blocks: u64, sms: u32, blocks_per_sm: u32) -> u64 {
    assert!(sms > 0 && blocks_per_sm > 0);
    let slots = sms as u64 * blocks_per_sm as u64;
    blocks.div_ceil(slots)
}

/// Tail efficiency in (0,1]: mean SM-slot usage across all waves.
/// 1.0 means every wave is full; small block counts on large partitions
/// give low efficiency (the §IV-A tail effect).
pub fn tail_efficiency(blocks: u64, sms: u32, blocks_per_sm: u32) -> f64 {
    if blocks == 0 {
        return 1.0;
    }
    let slots = sms as u64 * blocks_per_sm as u64;
    let w = waves(blocks, sms, blocks_per_sm);
    blocks as f64 / (w * slots) as f64
}

/// Achieved occupancy in [0,1]: average active warps relative to the
/// hardware maximum, accounting for partially-filled waves.
///
/// `warps_per_block` is the block's warp footprint; `max_warps_per_sm` is
/// the hardware limit (64 on Hopper); `resident_limit` is how many blocks
/// an SM can host concurrently given register/smem limits.
pub fn occupancy(
    blocks: u64,
    warps_per_block: u32,
    sms: u32,
    max_warps_per_sm: u32,
    resident_limit: u32,
) -> f64 {
    if blocks == 0 {
        return 0.0;
    }
    // Warps resident per SM when the machine is saturated:
    let resident_warps =
        (resident_limit.min(max_warps_per_sm / warps_per_block.max(1)) * warps_per_block)
            .min(max_warps_per_sm);
    let full_occ = resident_warps as f64 / max_warps_per_sm as f64;
    // Scale by the tail: partially-filled waves have fewer active warps.
    full_occ * tail_efficiency(blocks, sms, resident_limit)
}

/// §III-C probe: simulate the runtime of the fixed-work kernel at block
/// count `n` on a partition with `sms` SMs, in units of Δt (the 1-block
/// runtime). One block occupies one SM fully, so runtime = wave count.
pub fn probe_runtime_units(n: u64, sms: u32) -> u64 {
    waves(n, sms, 1)
}

/// Run the §III-C measurement loop: returns the inferred SM count, i.e.
/// the smallest n whose runtime is 2Δt, minus 1.
pub fn measure_sm_count(sms: u32) -> u32 {
    let mut n = 1u64;
    loop {
        if probe_runtime_units(n, sms) >= 2 {
            return (n - 1) as u32;
        }
        n += 1;
        assert!(n < 100_000, "probe runaway");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waves_basics() {
        assert_eq!(waves(1, 132, 1), 1);
        assert_eq!(waves(132, 132, 1), 1);
        assert_eq!(waves(133, 132, 1), 2);
        assert_eq!(waves(264, 132, 2), 1);
    }

    #[test]
    fn tail_efficiency_bounds_and_shape() {
        // One extra block on a full wave halves efficiency-ish.
        let full = tail_efficiency(132, 132, 1);
        assert!((full - 1.0).abs() < 1e-12);
        let spill = tail_efficiency(133, 132, 1);
        assert!(spill < 0.51 && spill > 0.49);
        // Tail effect is worse on more SMs for a fixed small block count
        // (§IV-A: "on larger GPUs ... more SMs left idle").
        let small_gpu = tail_efficiency(40, 16, 1);
        let big_gpu = tail_efficiency(40, 132, 1);
        assert!(big_gpu < small_gpu);
    }

    #[test]
    fn occupancy_monotone_in_blocks() {
        let lo = occupancy(16, 8, 132, 64, 8);
        let hi = occupancy(4096, 8, 132, 64, 8);
        assert!(hi >= lo);
        assert!(hi <= 1.0 && lo >= 0.0);
    }

    #[test]
    fn occupancy_zero_blocks() {
        assert_eq!(occupancy(0, 8, 132, 64, 8), 0.0);
    }

    #[test]
    fn sm_probe_recovers_counts() {
        // The measured Table II SM counts must be recovered exactly.
        for sms in [16u32, 26, 32, 60, 64, 132] {
            assert_eq!(measure_sm_count(sms), sms);
        }
    }

    #[test]
    fn probe_runtime_steps() {
        // n = SMs -> 1 unit; n = SMs+1 -> 2 units (the paper's detection
        // criterion).
        assert_eq!(probe_runtime_units(16, 16), 1);
        assert_eq!(probe_runtime_units(17, 16), 2);
    }
}
