//! Compute pipelines (§III-A/III-B: per-datatype pipeline utilization).
//!
//! The paper's GPM metrics report pipeline utilization per datatype
//! (double/single/half + tensor). Each workload declares a `PipelineMix` —
//! its FLOP distribution across pipelines — which drives both kernel
//! duration and per-pipeline utilization metrics (Table III "used
//! pipelines" column).

use std::fmt;

/// GPU compute pipelines tracked by the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Pipeline {
    Fp64,
    Fp32,
    Fp16,
    /// HMMA: fp16/bf16 tensor core.
    TensorFp16,
    /// IMMA: int8 tensor core.
    TensorInt8,
}

pub const ALL_PIPELINES: [Pipeline; 5] = [
    Pipeline::Fp64,
    Pipeline::Fp32,
    Pipeline::Fp16,
    Pipeline::TensorFp16,
    Pipeline::TensorInt8,
];

impl Pipeline {
    pub fn label(&self) -> &'static str {
        match self {
            Pipeline::Fp64 => "FP64",
            Pipeline::Fp32 => "FP32",
            Pipeline::Fp16 => "FP16",
            Pipeline::TensorFp16 => "HMMA",
            Pipeline::TensorInt8 => "IMMA",
        }
    }

    pub fn index(&self) -> usize {
        match self {
            Pipeline::Fp64 => 0,
            Pipeline::Fp32 => 1,
            Pipeline::Fp16 => 2,
            Pipeline::TensorFp16 => 3,
            Pipeline::TensorInt8 => 4,
        }
    }
}

impl fmt::Display for Pipeline {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.label())
    }
}

/// Fractional FLOP distribution over pipelines; fractions sum to 1.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PipelineMix {
    fracs: [f64; 5],
}

impl PipelineMix {
    /// Build from (pipeline, fraction) pairs; normalizes to sum 1.
    pub fn new(parts: &[(Pipeline, f64)]) -> PipelineMix {
        let mut fracs = [0.0; 5];
        for &(p, f) in parts {
            assert!(f >= 0.0, "negative pipeline fraction");
            fracs[p.index()] += f;
        }
        let total: f64 = fracs.iter().sum();
        assert!(total > 0.0, "empty pipeline mix");
        fracs.iter_mut().for_each(|f| *f /= total);
        PipelineMix { fracs }
    }

    pub fn pure(p: Pipeline) -> PipelineMix {
        PipelineMix::new(&[(p, 1.0)])
    }

    pub fn frac(&self, p: Pipeline) -> f64 {
        self.fracs[p.index()]
    }

    /// Pipelines with non-zero usage, for the Table III "used pipelines"
    /// column.
    pub fn used(&self) -> Vec<Pipeline> {
        ALL_PIPELINES
            .iter()
            .copied()
            .filter(|p| self.frac(*p) > 1e-9)
            .collect()
    }

    /// Effective FLOP/s when `flops` are distributed across pipelines that
    /// run at different peaks: harmonic combination (pipelines execute the
    /// kernel's instruction stream, so time adds).
    pub fn effective_flops(&self, peak_of: impl Fn(Pipeline) -> f64) -> f64 {
        let mut inv = 0.0;
        for p in ALL_PIPELINES {
            let f = self.frac(p);
            if f > 0.0 {
                let peak = peak_of(p);
                assert!(peak > 0.0, "zero peak for used pipeline {p}");
                inv += f / peak;
            }
        }
        1.0 / inv
    }
}

impl fmt::Display for PipelineMix {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let parts: Vec<String> = self
            .used()
            .iter()
            .map(|p| format!("{}:{:.0}%", p.label(), self.frac(*p) * 100.0))
            .collect();
        f.write_str(&parts.join(" "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalizes() {
        let m = PipelineMix::new(&[(Pipeline::Fp32, 2.0), (Pipeline::Fp64, 2.0)]);
        assert!((m.frac(Pipeline::Fp32) - 0.5).abs() < 1e-12);
        assert!((m.frac(Pipeline::Fp64) - 0.5).abs() < 1e-12);
        assert_eq!(m.used().len(), 2);
    }

    #[test]
    fn effective_flops_harmonic() {
        // 50/50 split between a 10 and a 30 FLOP/s pipeline:
        // time per flop = .5/10 + .5/30 = 1/15 -> 15 FLOP/s.
        let m = PipelineMix::new(&[(Pipeline::Fp32, 0.5), (Pipeline::Fp64, 0.5)]);
        let eff = m.effective_flops(|p| match p {
            Pipeline::Fp32 => 30.0,
            Pipeline::Fp64 => 10.0,
            _ => 1.0,
        });
        assert!((eff - 15.0).abs() < 1e-9);
    }

    #[test]
    fn pure_mix() {
        let m = PipelineMix::pure(Pipeline::TensorFp16);
        assert_eq!(m.frac(Pipeline::TensorFp16), 1.0);
        assert_eq!(m.used(), vec![Pipeline::TensorFp16]);
    }

    #[test]
    #[should_panic(expected = "empty pipeline mix")]
    fn empty_mix_panics() {
        PipelineMix::new(&[]);
    }
}
