//! Power, DVFS and throttling model (§V-B1, Fig. 7).
//!
//! MIG partitions compute and memory but **not power delivery** — the
//! paper's key interference finding. The model is energy-rate based:
//!
//! ```text
//! P(f) = P_idle
//!      + (f/f_max) · C_sm · sm_busy_frac            (active SM power)
//!      + (f/f_max) · Σ_p  e_p · flop_rate_p         (per-pipeline compute)
//!      + e_hbm · hbm_byte_rate                      (HBM, own clock domain)
//!      + e_c2c · c2c_byte_rate                      (interconnect)
//! ```
//!
//! The governor polls at the NVML period (20 ms): when demand exceeds the
//! 700 W cap it steps the SM clock down (1980 → … → 1815 MHz floor); when
//! demand falls below cap·(1−hysteresis) it steps back up. Compute-bound
//! work slows proportionally with the clock; memory-bound work does not —
//! which is why Fig. 7a's memory-bound Qiskit pins the cap while Fig. 7b's
//! compute-bound LLM training oscillates.

use super::pipelines::{Pipeline, ALL_PIPELINES};
use super::spec::GpuSpec;

/// Aggregate activity across the whole GPU at an instant.
#[derive(Debug, Clone, Copy, Default)]
pub struct GpuUsage {
    /// Whether any application context is alive on the GPU (clocks boosted,
    /// memory refreshing) even if no kernel is executing right now.
    pub context_active: bool,
    /// Fraction of all SMs that are busy (0..1), summed across instances.
    pub sm_busy_frac: f64,
    /// Achieved FLOP/s per pipeline (TFLOP/s).
    pub flop_rate_tflops: [f64; 5],
    /// HBM traffic in TB/s.
    pub hbm_rate_tbs: f64,
    /// NVLink-C2C traffic in TB/s.
    pub c2c_rate_tbs: f64,
}

impl GpuUsage {
    pub fn add_flops(&mut self, pipe: Pipeline, tflops: f64) {
        self.flop_rate_tflops[pipe.index()] += tflops;
    }
}

/// Calibrated power coefficients.
#[derive(Debug, Clone)]
pub struct PowerModel {
    pub idle_w: f64,
    /// Draw while a context is alive but SMs are (partly) idle: clocks
    /// boosted, HBM refreshing. Blended by (1 − sm_busy): this is what
    /// makes a CPU-dominated app (NekRS) burn real power for 7 serial
    /// runs and gives co-running its §V-B energy win.
    pub active_idle_w: f64,
    pub cap_w: f64,
    /// Power of all SMs busy at boost clock (W).
    pub c_sm_w: f64,
    /// W per TFLOP/s per pipeline [fp64, fp32, fp16, hmma, imma].
    pub e_flop_w_per_tflops: [f64; 5],
    /// W per TB/s of HBM traffic.
    pub e_hbm_w_per_tbs: f64,
    /// W per TB/s of C2C traffic.
    pub e_c2c_w_per_tbs: f64,
    /// Governor hysteresis: step clock up only below cap*(1-hyst).
    pub hysteresis: f64,
}

impl PowerModel {
    /// Default calibration for the GH H100-96GB testbed. Chosen so the
    /// Fig. 7 traces reproduce: Qiskit full-GPU demand > 700 W (continuous
    /// throttle to ~1815 MHz), 7×1g Qiskit ≈ 670 W (no throttle), llm.c
    /// alone 500–650 W, 7×1g llm.c just above cap (periodic throttle).
    pub fn h100() -> PowerModel {
        PowerModel {
            idle_w: 90.0,
            active_idle_w: 220.0,
            cap_w: 700.0,
            c_sm_w: 260.0,
            e_flop_w_per_tflops: [6.0, 2.5, 1.2, 0.35, 0.18],
            e_hbm_w_per_tbs: 130.0,
            e_c2c_w_per_tbs: 45.0,
            hysteresis: 0.03,
        }
    }

    /// Instantaneous power demand at SM clock `clock_mhz`.
    pub fn demand_w(&self, spec: &GpuSpec, usage: &GpuUsage, clock_mhz: f64) -> f64 {
        let f = clock_mhz / spec.clock_max_mhz;
        let mut p = self.idle_w;
        if usage.context_active {
            p += (self.active_idle_w - self.idle_w) * (1.0 - usage.sm_busy_frac.clamp(0.0, 1.0));
        }
        p += f * self.c_sm_w * usage.sm_busy_frac.clamp(0.0, 1.0);
        for pipe in ALL_PIPELINES {
            p += f * self.e_flop_w_per_tflops[pipe.index()] * usage.flop_rate_tflops[pipe.index()];
        }
        p += self.e_hbm_w_per_tbs * usage.hbm_rate_tbs;
        p += self.e_c2c_w_per_tbs * usage.c2c_rate_tbs;
        p
    }

    /// Reported (measured) power: demand clamped at the cap — the hardware
    /// enforces the cap through the clock/voltage ladder, so a sensor
    /// never reads far above it.
    pub fn reported_w(&self, spec: &GpuSpec, usage: &GpuUsage, clock_mhz: f64) -> f64 {
        self.demand_w(spec, usage, clock_mhz).min(self.cap_w * 1.005)
    }
}

/// Dynamic clock state driven by the governor.
#[derive(Debug, Clone, Copy)]
pub struct PowerState {
    pub clock_mhz: f64,
    pub throttled: bool,
    /// Cumulative time spent throttled (s).
    pub throttled_time_s: f64,
    /// Count of governor down-steps (diagnostics).
    pub down_steps: u64,
}

impl PowerState {
    pub fn new(spec: &GpuSpec) -> PowerState {
        PowerState {
            clock_mhz: spec.clock_max_mhz,
            throttled: false,
            throttled_time_s: 0.0,
            down_steps: 0,
        }
    }

    /// One governor evaluation at the power-poll period. Returns true if
    /// the clock changed (the simulator must then re-rate active kernels).
    pub fn govern(
        &mut self,
        spec: &GpuSpec,
        model: &PowerModel,
        usage: &GpuUsage,
        dt_s: f64,
    ) -> bool {
        let demand = model.demand_w(spec, usage, self.clock_mhz);
        let old = self.clock_mhz;
        // The elapsed dt_s was spent at the clock held *before* this
        // evaluation: charge throttled time against the pre-update state,
        // not the one the update is about to install.
        if old < spec.clock_max_mhz - 1e-9 {
            self.throttled_time_s += dt_s;
        }
        if demand > model.cap_w {
            // Step down proportionally to the overshoot, at least one step.
            let overshoot = demand / model.cap_w;
            let steps = ((overshoot - 1.0) / 0.02).ceil().max(1.0);
            self.clock_mhz =
                (self.clock_mhz - steps * spec.clock_step_mhz).max(spec.clock_min_mhz);
            if self.clock_mhz < old {
                // Count ladder steps actually descended (the proportional
                // request clamps at the floor), not descent events.
                self.down_steps += ((old - self.clock_mhz) / spec.clock_step_mhz).round() as u64;
            }
        } else if demand < model.cap_w * (1.0 - model.hysteresis)
            && self.clock_mhz < spec.clock_max_mhz
        {
            self.clock_mhz = (self.clock_mhz + spec.clock_step_mhz).min(spec.clock_max_mhz);
        }
        self.throttled = self.clock_mhz < spec.clock_max_mhz - 1e-9;
        (self.clock_mhz - old).abs() > 1e-9
    }

    /// Clock as a fraction of boost.
    pub fn clock_frac(&self, spec: &GpuSpec) -> f64 {
        self.clock_mhz / spec.clock_max_mhz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> GpuSpec {
        GpuSpec::gh_h100_96gb()
    }

    fn mem_bound_usage() -> GpuUsage {
        // Qiskit-like: fp32, ~90% of 3175 GiB/s HBM, all SMs busy.
        let mut u = GpuUsage {
            sm_busy_frac: 0.97,
            hbm_rate_tbs: 0.90 * 3175.0 * 1.0737e9 / 1e12,
            ..Default::default()
        };
        u.add_flops(Pipeline::Fp32, 1.4 * 1e12 / 1e12 * 1.5); // ~2.1 TFLOP/s
        u
    }

    #[test]
    fn idle_power_is_idle() {
        let m = PowerModel::h100();
        let p = m.demand_w(&spec(), &GpuUsage::default(), 1980.0);
        assert!((p - m.idle_w).abs() < 1e-9);
    }

    #[test]
    fn qiskit_like_demand_exceeds_cap() {
        // Fig. 7a left: full-GPU Qiskit hits the 700 W limit.
        let m = PowerModel::h100();
        let p = m.demand_w(&spec(), &mem_bound_usage(), 1980.0);
        assert!(p > 700.0, "demand {p} should exceed the cap");
        assert!(p < 820.0, "demand {p} implausibly high");
    }

    #[test]
    fn governor_throttles_to_floor_on_mem_bound() {
        // Memory-bound demand barely drops with clock (HBM term dominates)
        // -> the governor walks to the floor, like Fig. 7a's 1980->1815.
        let s = spec();
        let m = PowerModel::h100();
        let u = mem_bound_usage();
        let mut ps = PowerState::new(&s);
        for _ in 0..100 {
            ps.govern(&s, &m, &u, 0.02);
        }
        assert!(ps.throttled);
        assert!(ps.clock_mhz <= 1830.0, "clock {}", ps.clock_mhz);
        assert!(ps.clock_mhz >= s.clock_min_mhz);
    }

    #[test]
    fn governor_recovers_when_load_drops() {
        let s = spec();
        let m = PowerModel::h100();
        let mut ps = PowerState::new(&s);
        for _ in 0..50 {
            ps.govern(&s, &m, &mem_bound_usage(), 0.02);
        }
        assert!(ps.throttled);
        let idle = GpuUsage::default();
        for _ in 0..50 {
            ps.govern(&s, &m, &idle, 0.02);
        }
        assert!(!ps.throttled);
        assert_eq!(ps.clock_mhz, s.clock_max_mhz);
        assert!(ps.throttled_time_s > 0.0);
    }

    #[test]
    fn throttled_time_attributed_to_pre_update_clock() {
        // Boost -> throttled: the interval that *ends* in the first
        // down-step was spent at boost, so no throttled time accrues.
        let s = spec();
        let m = PowerModel::h100();
        let u = mem_bound_usage();
        let mut ps = PowerState::new(&s);
        ps.govern(&s, &m, &u, 0.02);
        assert!(ps.throttled, "first over-cap evaluation must step down");
        assert_eq!(
            ps.throttled_time_s, 0.0,
            "interval before the first down-step was spent at boost"
        );
        // Second evaluation: the preceding interval ran throttled.
        ps.govern(&s, &m, &u, 0.02);
        assert!((ps.throttled_time_s - 0.02).abs() < 1e-12);

        // Throttled -> boost: the interval that ends in the recovery step
        // was spent throttled and must still be charged.
        let mut ps = PowerState::new(&s);
        ps.clock_mhz = s.clock_max_mhz - s.clock_step_mhz;
        ps.throttled = true;
        let idle = GpuUsage::default();
        ps.govern(&s, &m, &idle, 0.02);
        assert!(!ps.throttled, "idle demand must recover to boost");
        assert!(
            (ps.throttled_time_s - 0.02).abs() < 1e-12,
            "interval before the recovery step ran throttled; got {}",
            ps.throttled_time_s
        );
        // Once back at boost, no further throttled time accrues.
        ps.govern(&s, &m, &idle, 0.02);
        assert!((ps.throttled_time_s - 0.02).abs() < 1e-12);
    }

    #[test]
    fn down_steps_counts_ladder_steps_not_descents() {
        // Demand far above cap (>2x overshoot): the proportional request
        // asks for dozens of steps, the floor clamps it to the full
        // ladder — (1980 - 1815) / 15 = 11 actual steps in one descent.
        let s = spec();
        let m = PowerModel::h100();
        let mut u = mem_bound_usage();
        u.hbm_rate_tbs *= 4.0; // demand ~2.5x the 700 W cap
        assert!(m.demand_w(&s, &u, s.clock_max_mhz) > 2.0 * m.cap_w);
        let mut ps = PowerState::new(&s);
        ps.govern(&s, &m, &u, 0.02);
        assert_eq!(ps.clock_mhz, s.clock_min_mhz);
        let ladder = ((s.clock_max_mhz - s.clock_min_mhz) / s.clock_step_mhz).round() as u64;
        assert_eq!(ladder, 11);
        assert_eq!(
            ps.down_steps, ladder,
            "one clamped descent spans the whole ladder"
        );
    }

    #[test]
    fn llm_train_alone_stays_under_cap() {
        // Fig. 7b left: 500-650 W, no throttling.
        let m = PowerModel::h100();
        let mut u = GpuUsage {
            sm_busy_frac: 0.92,
            hbm_rate_tbs: 0.40 * 3175.0 * 1.0737e9 / 1e12,
            ..Default::default()
        };
        u.add_flops(Pipeline::TensorFp16, 330.0);
        u.add_flops(Pipeline::Fp32, 2.0);
        let p = m.demand_w(&spec(), &u, 1980.0);
        assert!((480.0..680.0).contains(&p), "demand {p}");
    }

    #[test]
    fn reported_power_clamped_at_cap() {
        let m = PowerModel::h100();
        let p = m.reported_w(&spec(), &mem_bound_usage(), 1980.0);
        assert!(p <= m.cap_w * 1.005 + 1e-9);
    }
}
