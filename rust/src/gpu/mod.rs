//! GPU hardware model: specs (Table I generations + the Grace Hopper
//! H100-96GB testbed), SM scheduling with the tail effect, memory
//! capacity/bandwidth, copy engines, the NVLink-C2C interconnect
//! (Table IV behaviour, including the copy-engine "bug"), and the power /
//! DVFS / throttling model behind Fig. 7.

pub mod nvlink;
pub mod pipelines;
pub mod power;
pub mod sm;
pub mod spec;

pub use nvlink::NvlinkModel;
pub use pipelines::{Pipeline, PipelineMix};
pub use power::{GpuUsage, PowerModel, PowerState};
pub use sm::{occupancy, tail_efficiency, waves};
pub use spec::GpuSpec;
