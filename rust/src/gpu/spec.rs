//! GPU hardware specifications.
//!
//! `generations()` carries the paper's Table I; `gh_h100_96gb()` is the
//! detailed model of the testbed GPU (§III): H100-96GB in a Grace Hopper
//! superchip — 132 SMs, 96 GB HBM3 (94.5 GiB usable), 700 W cap, clocks
//! 1980 MHz boost / 1815 MHz observed throttle floor (Fig. 7a).

use super::pipelines::Pipeline;

/// Static description of a GPU.
#[derive(Debug, Clone)]
pub struct GpuSpec {
    pub name: String,
    pub sms: u32,
    /// Total HBM capacity in GiB (marketing number).
    pub mem_capacity_gib: f64,
    /// Usable capacity in GiB (after reserved carve-outs; 94.5 on the
    /// testbed, per Table II's 7g.96gb row).
    pub mem_usable_gib: f64,
    /// Peak HBM bandwidth in GiB/s as partitionable by MIG (Table II's
    /// 7g.96gb row: 3175 GiB/s).
    pub mem_bw_gibs: f64,
    /// Achieved full-GPU STREAM-copy bandwidth (Table IVb "No MIG" local:
    /// 2741 GiB/s) — the efficiency the copy benchmark reaches.
    pub stream_bw_gibs: f64,
    pub l2_mib: f64,
    pub copy_engines: u32,
    /// Boost clock in MHz.
    pub clock_max_mhz: f64,
    /// Observed throttle floor in MHz (Fig. 7a: 1980 -> 1815).
    pub clock_min_mhz: f64,
    /// DVFS step granularity in MHz.
    pub clock_step_mhz: f64,
    /// Peak throughput per pipeline in TFLOPS at boost clock.
    pub fp64_tflops: f64,
    pub fp32_tflops: f64,
    pub fp16_tensor_tflops: f64,
    /// Board power cap (W) and idle draw (W).
    pub power_cap_w: f64,
    pub idle_power_w: f64,
    /// Maximum resident warps per SM (Hopper: 64).
    pub max_warps_per_sm: u32,
    pub max_threads_per_block: u32,
}

impl GpuSpec {
    /// The paper's testbed: Grace Hopper H100-96GB.
    pub fn gh_h100_96gb() -> GpuSpec {
        GpuSpec {
            name: "GH200-H100-96GB".to_string(),
            sms: 132,
            mem_capacity_gib: 96.0,
            mem_usable_gib: 94.5,
            mem_bw_gibs: 3175.0,
            stream_bw_gibs: 2741.4,
            l2_mib: 50.0,
            copy_engines: 8,
            clock_max_mhz: 1980.0,
            clock_min_mhz: 1815.0,
            clock_step_mhz: 15.0,
            fp64_tflops: 30.0,
            fp32_tflops: 60.0,
            fp16_tensor_tflops: 1000.0,
            power_cap_w: 700.0,
            idle_power_w: 90.0,
            max_warps_per_sm: 64,
            max_threads_per_block: 1024,
        }
    }

    /// Table I: the four GPU generations the paper motivates with.
    pub fn generations() -> Vec<GpuSpec> {
        let gen = |name: &str,
                   cap: f64,
                   bw_tbs: f64,
                   fp32: f64,
                   tensor: f64,
                   sms: u32| GpuSpec {
            name: name.to_string(),
            sms,
            mem_capacity_gib: cap,
            mem_usable_gib: cap,
            mem_bw_gibs: bw_tbs * 1000.0,
            stream_bw_gibs: bw_tbs * 1000.0 * 0.86,
            l2_mib: 40.0,
            copy_engines: 8,
            clock_max_mhz: 1800.0,
            clock_min_mhz: 1600.0,
            clock_step_mhz: 15.0,
            fp64_tflops: fp32 / 2.0,
            fp32_tflops: fp32,
            fp16_tensor_tflops: tensor,
            power_cap_w: 700.0,
            idle_power_w: 80.0,
            max_warps_per_sm: 64,
            max_threads_per_block: 1024,
        };
        vec![
            gen("V100", 32.0, 1.1, 16.4, 130.0, 80),
            gen("A100", 80.0, 2.0, 19.5, 312.0, 108),
            gen("H100", 144.0, 4.9, 60.0, 1000.0, 132),
            gen("B200", 192.0, 8.0, 80.0, 2500.0, 160),
        ]
    }

    /// Peak FLOP/s of a pipeline at the given clock with `sms` SMs active.
    pub fn pipeline_flops(&self, pipe: Pipeline, sms: u32, clock_mhz: f64) -> f64 {
        let peak_tflops = match pipe {
            Pipeline::Fp64 => self.fp64_tflops,
            Pipeline::Fp32 => self.fp32_tflops,
            Pipeline::Fp16 => self.fp32_tflops * 2.0,
            Pipeline::TensorFp16 => self.fp16_tensor_tflops,
            Pipeline::TensorInt8 => self.fp16_tensor_tflops * 2.0,
        };
        peak_tflops * 1e12 * (sms as f64 / self.sms as f64) * (clock_mhz / self.clock_max_mhz)
    }

    /// Usable memory in bytes.
    pub fn mem_usable_bytes(&self) -> f64 {
        crate::util::units::gib(self.mem_usable_gib)
    }

    /// Per-SM fp32 FLOPs per cycle (sanity metric for the roofline notes).
    pub fn fp32_flops_per_sm_cycle(&self) -> f64 {
        self.fp32_tflops * 1e12 / (self.sms as f64 * self.clock_max_mhz * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_values() {
        let gens = GpuSpec::generations();
        assert_eq!(gens.len(), 4);
        let h100 = &gens[2];
        assert_eq!(h100.name, "H100");
        assert_eq!(h100.sms, 132);
        assert_eq!(h100.mem_capacity_gib, 144.0);
        assert_eq!(h100.fp32_tflops, 60.0);
        assert_eq!(h100.fp16_tensor_tflops, 1000.0);
    }

    #[test]
    fn testbed_matches_paper_section3() {
        let g = GpuSpec::gh_h100_96gb();
        assert_eq!(g.sms, 132);
        assert_eq!(g.mem_capacity_gib, 96.0);
        assert_eq!(g.mem_usable_gib, 94.5);
        assert_eq!(g.power_cap_w, 700.0);
        assert_eq!(g.clock_max_mhz, 1980.0);
        assert_eq!(g.clock_min_mhz, 1815.0);
    }

    #[test]
    fn pipeline_flops_scale_linearly() {
        let g = GpuSpec::gh_h100_96gb();
        let full = g.pipeline_flops(Pipeline::Fp32, 132, 1980.0);
        assert!((full - 60e12).abs() / 60e12 < 1e-9);
        let half_sms = g.pipeline_flops(Pipeline::Fp32, 66, 1980.0);
        assert!((half_sms - 30e12).abs() / 30e12 < 1e-9);
        let throttled = g.pipeline_flops(Pipeline::Fp32, 132, 990.0);
        assert!((throttled - 30e12).abs() / 30e12 < 1e-9);
    }

    #[test]
    fn fp32_per_sm_cycle_plausible() {
        // H100 ballpark: ~230 fp32 FLOPs per SM-cycle at boost.
        let g = GpuSpec::gh_h100_96gb();
        let v = g.fp32_flops_per_sm_cycle();
        assert!(v > 150.0 && v < 300.0, "got {v}");
    }
}
