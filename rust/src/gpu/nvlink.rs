//! NVLink-C2C interconnect model (§II-C, §III-D, Table IV).
//!
//! Two CPU↔GPU transfer paths exist inside a MIG instance:
//!
//! * **cudaMemcpy / copy engines** — Table IVa. Unidirectional transfers
//!   are stuck at a *single* copy engine's rate regardless of how many CEs
//!   the profile owns (the paper calls this out as a likely driver bug:
//!   "increasing the MIG instance size does not provide bandwidth
//!   improvement"). Bidirectional transfers do use two CEs when available.
//! * **direct in-kernel access** — Table IVb. SMs read/write CPU memory at
//!   cacheline granularity; device-to-host saturates C2C even from the
//!   smallest instance, host-to-device needs enough SMs in flight (a
//!   saturation curve in the SM count).
//!
//! Local-memory bandwidth is split across MIG instances in proportion to
//! their memory slices (Table II / IVb observation).

/// Transfer direction over C2C.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dir {
    H2D,
    D2H,
    /// Simultaneous copies in both directions (aggregate bandwidth).
    Both,
}

/// Calibrated C2C + copy-engine constants (GiB/s), from Table IV.
#[derive(Debug, Clone)]
pub struct NvlinkModel {
    /// Single-CE rates (the MIG memcpy ceiling per direction).
    pub ce_d2h_gibs: f64,
    pub ce_h2d_gibs: f64,
    /// Full-GPU (no-MIG) memcpy rates — all CEs available.
    pub nomig_d2h_gibs: f64,
    pub nomig_h2d_gibs: f64,
    pub nomig_both_gibs: f64,
    /// Direct-access ceilings per direction.
    pub direct_d2h_cap_gibs: f64,
    pub direct_h2d_cap_gibs: f64,
    pub direct_both_cap_gibs: f64,
    /// H2D direct saturation curve: bw = min(cap, bmax * s / (s + k)).
    pub direct_h2d_bmax: f64,
    pub direct_h2d_k: f64,
    /// Efficiency of memcpy on local HBM relative to the profile's
    /// bandwidth allocation (Table IVa local column ≈ 0.87 × Table II BW).
    pub local_memcpy_eff: f64,
}

impl Default for NvlinkModel {
    fn default() -> Self {
        NvlinkModel {
            ce_d2h_gibs: 39.6,
            ce_h2d_gibs: 44.0,
            nomig_d2h_gibs: 276.3,
            nomig_h2d_gibs: 333.1,
            nomig_both_gibs: 329.1,
            direct_d2h_cap_gibs: 343.0,
            direct_h2d_cap_gibs: 348.0,
            direct_both_cap_gibs: 331.0,
            direct_h2d_bmax: 565.0,
            direct_h2d_k: 27.7,
            local_memcpy_eff: 0.87,
        }
    }
}

impl NvlinkModel {
    /// cudaMemcpy bandwidth over C2C for a MIG instance owning `ces` copy
    /// engines, or for the unpartitioned GPU (`ces = None`).
    pub fn memcpy_bw_gibs(&self, ces: Option<u32>, dir: Dir) -> f64 {
        match ces {
            None => match dir {
                Dir::D2H => self.nomig_d2h_gibs,
                Dir::H2D => self.nomig_h2d_gibs,
                Dir::Both => self.nomig_both_gibs,
            },
            Some(n) => {
                assert!(n >= 1, "instance with zero copy engines");
                match dir {
                    // The "CE bug": unidirectional never exceeds one CE.
                    Dir::D2H => self.ce_d2h_gibs,
                    Dir::H2D => self.ce_h2d_gibs,
                    Dir::Both => {
                        if n >= 2 {
                            // Two CEs stream concurrently, slightly below
                            // the plain sum (shared C2C arbitration).
                            (self.ce_d2h_gibs + self.ce_h2d_gibs) * 0.947
                        } else {
                            // One CE time-shares directions.
                            (self.ce_d2h_gibs + self.ce_h2d_gibs) / 2.0
                        }
                    }
                }
            }
        }
    }

    /// Direct in-kernel access bandwidth over C2C with `sms` SMs issuing.
    pub fn direct_bw_gibs(&self, sms: u32, dir: Dir) -> f64 {
        assert!(sms >= 1);
        let h2d = (self.direct_h2d_bmax * sms as f64 / (sms as f64 + self.direct_h2d_k))
            .min(self.direct_h2d_cap_gibs);
        match dir {
            Dir::D2H => self.direct_d2h_cap_gibs * self.d2h_sm_factor(sms),
            Dir::H2D => h2d,
            Dir::Both => {
                let d2h = self.direct_d2h_cap_gibs * self.d2h_sm_factor(sms);
                ((d2h + h2d) / 2.0 + 8.0).min(self.direct_both_cap_gibs)
            }
        }
    }

    /// D2H saturates even on 16 SMs; mildly declines on bigger instances
    /// (343 on 1g → 336-338 beyond), matching Table IVb.
    fn d2h_sm_factor(&self, sms: u32) -> f64 {
        if sms <= 16 {
            1.0
        } else {
            0.982
        }
    }

    /// Local HBM bandwidth achieved by a memcpy within the instance, given
    /// the instance's bandwidth allocation.
    pub fn local_memcpy_gibs(&self, alloc_bw_gibs: f64) -> f64 {
        alloc_bw_gibs * self.local_memcpy_eff
    }

    /// Local HBM bandwidth achieved by the direct (STREAM-style) kernel:
    /// the full allocation (Table IVb locals equal Table II's BW column).
    pub fn local_direct_gibs(&self, alloc_bw_gibs: f64) -> f64 {
        alloc_bw_gibs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::rel_err;

    const TOL: f64 = 0.05;

    #[test]
    fn table4a_memcpy_mig_rows() {
        let m = NvlinkModel::default();
        // Unidirectional identical for every MIG profile (the CE bug).
        for ces in [1u32, 2, 3, 4, 8] {
            assert_eq!(m.memcpy_bw_gibs(Some(ces), Dir::D2H), 39.6);
            assert_eq!(m.memcpy_bw_gibs(Some(ces), Dir::H2D), 44.0);
        }
        // 1g: BOTH 41.7; >=2 CE: 79.2.
        assert!(rel_err(m.memcpy_bw_gibs(Some(1), Dir::Both), 41.7) < TOL);
        assert!(rel_err(m.memcpy_bw_gibs(Some(2), Dir::Both), 79.2) < TOL);
        assert!(rel_err(m.memcpy_bw_gibs(Some(8), Dir::Both), 79.2) < TOL);
    }

    #[test]
    fn table4a_memcpy_nomig_row() {
        let m = NvlinkModel::default();
        assert!(rel_err(m.memcpy_bw_gibs(None, Dir::Both), 329.1) < TOL);
        assert!(rel_err(m.memcpy_bw_gibs(None, Dir::D2H), 276.3) < TOL);
        assert!(rel_err(m.memcpy_bw_gibs(None, Dir::H2D), 333.1) < TOL);
    }

    #[test]
    fn table4b_direct_access_rows() {
        let m = NvlinkModel::default();
        // (sms, both, d2h, h2d) from Table IVb.
        let rows = [
            (16u32, 282.0, 343.0, 207.0),
            (32, 334.0, 338.0, 303.0),
            (60, 331.0, 336.0, 348.0),
            (64, 330.0, 338.0, 347.0),
            (132, 331.0, 336.0, 348.0),
        ];
        for (sms, both, d2h, h2d) in rows {
            assert!(
                rel_err(m.direct_bw_gibs(sms, Dir::Both), both) < TOL,
                "both sms={sms}: {} vs {both}",
                m.direct_bw_gibs(sms, Dir::Both)
            );
            assert!(
                rel_err(m.direct_bw_gibs(sms, Dir::D2H), d2h) < TOL,
                "d2h sms={sms}: {} vs {d2h}",
                m.direct_bw_gibs(sms, Dir::D2H)
            );
            assert!(
                rel_err(m.direct_bw_gibs(sms, Dir::H2D), h2d) < TOL,
                "h2d sms={sms}: {} vs {h2d}",
                m.direct_bw_gibs(sms, Dir::H2D)
            );
        }
    }

    #[test]
    fn key_observation_direct_saturates_on_smallest_instance() {
        // §III-D: "even for the smallest MIG profile, the direct access
        // benchmark is able to saturate the Nvlink-C2C interconnect in
        // device-to-host direction" — and beats memcpy by ~8.7x.
        let m = NvlinkModel::default();
        let direct = m.direct_bw_gibs(16, Dir::D2H);
        let memcpy = m.memcpy_bw_gibs(Some(1), Dir::D2H);
        assert!(direct / memcpy > 8.0);
        assert!(direct > 340.0);
    }

    #[test]
    fn local_bandwidths() {
        let m = NvlinkModel::default();
        // Table IVa local column ~0.87x the allocation.
        assert!(rel_err(m.local_memcpy_gibs(406.0), 357.5) < TOL);
        assert!(rel_err(m.local_memcpy_gibs(3175.0), 2732.4) < TOL);
        // Table IVb local column equals the allocation.
        assert_eq!(m.local_direct_gibs(1611.0), 1611.0);
    }
}
