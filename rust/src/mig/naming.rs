//! MIG instance-name grammar (§II-B3).
//!
//! GPU instances are `"<G>g.<M>gb"` (e.g. `3g.48gb`). Compute instances
//! prefix the compute-slice count: `"<C>c.<G>g.<M>gb"` (e.g. `2c.3g.48gb`);
//! when the CI spans all of the GI's compute slices the prefix is omitted
//! (`3c.3g.48gb` ≡ `3g.48gb`).

use std::fmt;

/// A parsed instance name.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct InstanceName {
    /// Compute slices of the compute instance (defaults to `gi_slices`).
    pub ci_slices: u32,
    /// Compute slices of the underlying GPU instance.
    pub gi_slices: u32,
    /// Memory capacity label in GB (the marketing number: 12, 24, 48, 96).
    pub mem_gb: u32,
}

impl InstanceName {
    /// Parse `"2c.3g.48gb"`, `"3g.48gb"` etc.
    pub fn parse(s: &str) -> Result<InstanceName, String> {
        let parts: Vec<&str> = s.split('.').collect();
        let (ci_part, gi_part, mem_part) = match parts.as_slice() {
            [g, m] => (None, *g, *m),
            [c, g, m] => (Some(*c), *g, *m),
            _ => return Err(format!("bad instance name '{s}'")),
        };
        let gi_slices = parse_suffixed(gi_part, 'g').ok_or(format!("bad GI part in '{s}'"))?;
        let mem_gb = mem_part
            .strip_suffix("gb")
            .and_then(|n| n.parse().ok())
            .ok_or(format!("bad memory part in '{s}'"))?;
        let ci_slices = match ci_part {
            None => gi_slices,
            Some(c) => parse_suffixed(c, 'c').ok_or(format!("bad CI part in '{s}'"))?,
        };
        if ci_slices == 0 || gi_slices == 0 {
            return Err(format!("zero slices in '{s}'"));
        }
        if ci_slices > gi_slices {
            return Err(format!(
                "compute instance ({ci_slices}c) larger than GPU instance ({gi_slices}g) in '{s}'"
            ));
        }
        Ok(InstanceName {
            ci_slices,
            gi_slices,
            mem_gb,
        })
    }

    /// Canonical form: omit the CI prefix when it covers the whole GI.
    pub fn canonical(&self) -> String {
        if self.ci_slices == self.gi_slices {
            format!("{}g.{}gb", self.gi_slices, self.mem_gb)
        } else {
            format!("{}c.{}g.{}gb", self.ci_slices, self.gi_slices, self.mem_gb)
        }
    }

    /// Whether this names a full-GI compute instance.
    pub fn is_full_gi(&self) -> bool {
        self.ci_slices == self.gi_slices
    }
}

fn parse_suffixed(s: &str, suffix: char) -> Option<u32> {
    s.strip_suffix(suffix).and_then(|n| n.parse().ok())
}

impl fmt::Display for InstanceName {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.canonical())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_gi_names() {
        let n = InstanceName::parse("3g.48gb").unwrap();
        assert_eq!((n.ci_slices, n.gi_slices, n.mem_gb), (3, 3, 48));
        assert!(n.is_full_gi());
    }

    #[test]
    fn parses_ci_names() {
        let n = InstanceName::parse("2c.3g.48gb").unwrap();
        assert_eq!((n.ci_slices, n.gi_slices, n.mem_gb), (2, 3, 48));
        assert!(!n.is_full_gi());
    }

    #[test]
    fn canonical_omits_full_prefix() {
        // Paper: "3c.3g.48gb is abbreviated 3g.48gb".
        let n = InstanceName::parse("3c.3g.48gb").unwrap();
        assert_eq!(n.canonical(), "3g.48gb");
        let partial = InstanceName::parse("1c.7g.96gb").unwrap();
        assert_eq!(partial.canonical(), "1c.7g.96gb");
    }

    #[test]
    fn rejects_invalid() {
        for bad in [
            "",
            "48gb",
            "3g",
            "g.48gb",
            "3x.48gb",
            "4c.3g.48gb", // CI larger than GI
            "0g.12gb",
            "3g.48gb.extra.parts",
        ] {
            assert!(InstanceName::parse(bad).is_err(), "should reject '{bad}'");
        }
    }

    #[test]
    fn roundtrip() {
        for s in ["1g.12gb", "2g.24gb", "1c.2g.24gb", "7g.96gb", "1c.7g.96gb"] {
            let n = InstanceName::parse(s).unwrap();
            assert_eq!(n.canonical(), s);
        }
    }
}
