//! MIG GPU-instance profiles for the GH H100-96GB testbed (Table II).
//!
//! Each profile row carries the *measured* values from the paper: usable
//! SM count (via the §III-C probe), usable memory, slice shares, copy
//! engines, and per-instance memory bandwidth. The wasted-resource columns
//! are GPU-wide best case, as reported.

/// Identifier for the six GH H100-96GB GPU-instance profiles.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ProfileId {
    P1g12gb,
    P1g24gb,
    P2g24gb,
    P3g48gb,
    P4g48gb,
    P7g96gb,
}

/// Number of distinct GI profiles — the dimension of the dense
/// per-profile tables in the serving hot path (`cluster::placement`).
pub const NUM_PROFILES: usize = 6;

/// Profiles in ascending SM (and slice) order: walking this array is the
/// best-fit preference order, and `ProfileId::index` follows it.
pub const ALL_PROFILES: [ProfileId; NUM_PROFILES] = [
    ProfileId::P1g12gb,
    ProfileId::P1g24gb,
    ProfileId::P2g24gb,
    ProfileId::P3g48gb,
    ProfileId::P4g48gb,
    ProfileId::P7g96gb,
];

impl ProfileId {
    /// Dense index into `[_; NUM_PROFILES]` tables (matches `ALL_PROFILES`
    /// order).
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }
}

/// A GPU-instance profile: the unit of MIG provisioning.
#[derive(Debug, Clone)]
pub struct GiProfile {
    pub id: ProfileId,
    pub name: &'static str,
    /// Compute slices ("Ng").
    pub compute_slices: u32,
    /// Memory slices (x/8 of capacity, L2 and bandwidth).
    pub memory_slices: u32,
    /// Maximum concurrent instances of this profile.
    pub max_instances: u32,
    /// Measured usable SMs (§III-C probe; deviates from slices×(132/7)).
    pub sms: u32,
    /// Usable memory per instance (GiB).
    pub mem_gib: f64,
    /// Copy engines owned by the instance.
    pub copy_engines: u32,
    /// Per-instance HBM bandwidth allocation (GiB/s), Table II.
    pub mem_bw_gibs: f64,
    /// Paper-reported GPU-wide best-case wasted SMs (%). The paper's
    /// best-case packing accounting is not derivable from the public
    /// placement rules alone, so we carry the reported value and also
    /// compute the naive max-instances waste (`wasted_sm_naive`).
    pub wasted_sm_paper_pct: &'static str,
    /// Paper-reported GPU-wide best-case wasted memory (GiB).
    pub wasted_mem_paper_gib: f64,
}

/// Total compute slices on the device (the 7-GI limit, §III-C).
pub const TOTAL_COMPUTE_SLICES: u32 = 7;
/// Total memory slices on the device.
pub const TOTAL_MEMORY_SLICES: u32 = 8;

impl GiProfile {
    pub fn get(id: ProfileId) -> GiProfile {
        use ProfileId::*;
        match id {
            P1g12gb => GiProfile {
                id,
                name: "1g.12gb",
                compute_slices: 1,
                memory_slices: 1,
                max_instances: 7,
                sms: 16,
                mem_gib: 11.0,
                copy_engines: 1,
                mem_bw_gibs: 406.0,
                wasted_sm_paper_pct: "15%",
                wasted_mem_paper_gib: 17.5,
            },
            P1g24gb => GiProfile {
                id,
                name: "1g.24gb",
                compute_slices: 1,
                memory_slices: 2,
                max_instances: 4,
                sms: 26,
                mem_gib: 23.0,
                copy_engines: 2,
                mem_bw_gibs: 812.0,
                wasted_sm_paper_pct: "21%",
                wasted_mem_paper_gib: 2.5,
            },
            P2g24gb => GiProfile {
                id,
                name: "2g.24gb",
                compute_slices: 2,
                memory_slices: 2,
                max_instances: 3,
                sms: 32,
                mem_gib: 23.0,
                copy_engines: 2,
                mem_bw_gibs: 812.0,
                wasted_sm_paper_pct: "3%",
                wasted_mem_paper_gib: 2.5,
            },
            P3g48gb => GiProfile {
                id,
                name: "3g.48gb",
                compute_slices: 3,
                memory_slices: 4,
                max_instances: 2,
                sms: 60,
                mem_gib: 46.5,
                copy_engines: 3,
                mem_bw_gibs: 1611.0,
                wasted_sm_paper_pct: "6/9%",
                wasted_mem_paper_gib: 1.5,
            },
            P4g48gb => GiProfile {
                id,
                name: "4g.48gb",
                compute_slices: 4,
                memory_slices: 4,
                max_instances: 1,
                sms: 64,
                mem_gib: 46.5,
                copy_engines: 4,
                mem_bw_gibs: 1635.0,
                wasted_sm_paper_pct: "3%",
                wasted_mem_paper_gib: 1.5,
            },
            P7g96gb => GiProfile {
                id,
                name: "7g.96gb",
                compute_slices: 7,
                memory_slices: 8,
                max_instances: 1,
                sms: 132,
                mem_gib: 94.5,
                copy_engines: 8,
                mem_bw_gibs: 3175.0,
                wasted_sm_paper_pct: "0%",
                wasted_mem_paper_gib: 0.0,
            },
        }
    }

    pub fn by_name(name: &str) -> Option<GiProfile> {
        ALL_PROFILES
            .iter()
            .map(|&id| GiProfile::get(id))
            .find(|p| p.name == name)
    }

    pub fn all() -> Vec<GiProfile> {
        ALL_PROFILES.iter().map(|&id| GiProfile::get(id)).collect()
    }

    /// Naive GPU-wide SM waste when packing max_instances of this profile:
    /// `1 - max_inst·sms / total_sms` (this reproduces the 15% headline
    /// for 7×1g.12gb).
    pub fn wasted_sm_naive(&self, total_sms: u32) -> f64 {
        1.0 - (self.max_instances * self.sms) as f64 / total_sms as f64
    }

    /// Naive GPU-wide memory waste when packing max_instances: usable
    /// total minus what instances expose (GiB).
    pub fn wasted_mem_naive(&self, usable_total_gib: f64) -> f64 {
        usable_total_gib - self.max_instances as f64 * self.mem_gib
    }

    /// Memory-slice fraction string for the table ("x/8").
    pub fn mem_fraction_label(&self) -> String {
        format!("{}/8", self.memory_slices)
    }

    pub fn mem_bytes(&self) -> f64 {
        crate::util::units::gib(self.mem_gib)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_sm_counts() {
        let want = [16u32, 26, 32, 60, 64, 132];
        for (id, w) in ALL_PROFILES.iter().zip(want) {
            assert_eq!(GiProfile::get(*id).sms, w);
        }
    }

    #[test]
    fn table2_memory_and_bandwidth() {
        let mems = [11.0, 23.0, 23.0, 46.5, 46.5, 94.5];
        let bws = [406.0, 812.0, 812.0, 1611.0, 1635.0, 3175.0];
        for ((id, m), b) in ALL_PROFILES.iter().zip(mems).zip(bws) {
            let p = GiProfile::get(*id);
            assert_eq!(p.mem_gib, m, "{}", p.name);
            assert_eq!(p.mem_bw_gibs, b, "{}", p.name);
        }
    }

    #[test]
    fn headline_15pct_sm_waste() {
        // §III-C: 7×16 = 112 of 132 SMs -> 15% cannot be used.
        let p = GiProfile::get(ProfileId::P1g12gb);
        let waste = p.wasted_sm_naive(132);
        assert!((waste - 0.1515).abs() < 0.001, "waste={waste}");
    }

    #[test]
    fn memory_waste_examples() {
        // §III-C: seven 1g.12gb instances leave 17.5 GiB unused.
        let p = GiProfile::get(ProfileId::P1g12gb);
        assert!((p.wasted_mem_naive(94.5) - 17.5).abs() < 1e-9);
        let p4 = GiProfile::get(ProfileId::P1g24gb);
        assert!((p4.wasted_mem_naive(94.5) - 2.5).abs() < 1e-9);
    }

    #[test]
    fn bandwidth_proportional_to_memory_slices() {
        // Table IVb observation: local bandwidth fraction == memory-slice
        // fraction (1g=1/8 of ~3250, 2g=2/8, ...), within rounding.
        for p in GiProfile::all() {
            let frac = p.mem_bw_gibs / 3175.0;
            let slice_frac = p.memory_slices as f64 / 8.0;
            assert!(
                (frac - slice_frac).abs() < 0.03,
                "{}: bw frac {frac} vs slice frac {slice_frac}",
                p.name
            );
        }
    }

    #[test]
    fn dense_index_matches_all_profiles_order_and_sms_ascend() {
        // The placement hot path walks ALL_PROFILES as the best-fit
        // preference order and indexes dense tables via ProfileId::index;
        // both invariants live here.
        let mut prev_sms = 0;
        for (i, &id) in ALL_PROFILES.iter().enumerate() {
            assert_eq!(id.index(), i);
            let sms = GiProfile::get(id).sms;
            assert!(sms > prev_sms, "ALL_PROFILES must ascend by SMs");
            prev_sms = sms;
        }
        assert_eq!(ALL_PROFILES.len(), NUM_PROFILES);
    }

    #[test]
    fn lookup_by_name() {
        assert_eq!(GiProfile::by_name("3g.48gb").unwrap().sms, 60);
        assert!(GiProfile::by_name("9g.1gb").is_none());
    }

    #[test]
    fn max_instances_respect_slice_budget() {
        for p in GiProfile::all() {
            assert!(p.max_instances * p.compute_slices <= TOTAL_COMPUTE_SLICES);
            assert!(p.max_instances * p.memory_slices <= TOTAL_MEMORY_SLICES);
        }
    }
}
