//! MIG lifecycle manager: GPU instances (GI) and compute instances (CI)
//! with slice-budget placement validation and the static-reconfiguration
//! constraint (§II-B3: the configuration cannot change while work runs).

use super::profile::{GiProfile, ProfileId, TOTAL_COMPUTE_SLICES, TOTAL_MEMORY_SLICES};
use crate::gpu::GpuSpec;
use anyhow::{anyhow, bail};

/// Handle to a GPU instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GiId(pub u32);

/// Handle to a compute instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct CiId(pub u32);

/// A created GPU instance.
#[derive(Debug, Clone)]
pub struct GpuInstance {
    pub id: GiId,
    pub profile: GiProfile,
    pub cis: Vec<CiId>,
    /// Compute slices already claimed by CIs.
    pub ci_slices_used: u32,
}

/// A created compute instance — what workloads actually run on.
#[derive(Debug, Clone)]
pub struct ComputeInstance {
    pub id: CiId,
    pub gi: GiId,
    pub compute_slices: u32,
    /// SMs available to this CI.
    pub sms: u32,
    /// Memory visible to this CI (shared across CIs of the same GI).
    pub mem_gib: f64,
    /// Bandwidth allocation of the owning GI (shared across its CIs).
    pub mem_bw_gibs: f64,
    pub copy_engines: u32,
    /// True while a workload is running (blocks reconfiguration).
    pub busy: bool,
    /// Number of sibling CIs on the same GI (they share memory + L2,
    /// MPS-style — used by the contention model).
    pub siblings: u32,
}

/// The MIG manager for one physical GPU.
#[derive(Debug)]
pub struct MigManager {
    spec: GpuSpec,
    gis: Vec<GpuInstance>,
    cis: Vec<ComputeInstance>,
    next_gi: u32,
    next_ci: u32,
    compute_slices_used: u32,
    memory_slices_used: u32,
}

impl MigManager {
    pub fn new(spec: GpuSpec) -> MigManager {
        MigManager {
            spec,
            gis: Vec::new(),
            cis: Vec::new(),
            next_gi: 0,
            next_ci: 0,
            compute_slices_used: 0,
            memory_slices_used: 0,
        }
    }

    pub fn spec(&self) -> &GpuSpec {
        &self.spec
    }

    pub fn compute_slices_free(&self) -> u32 {
        TOTAL_COMPUTE_SLICES - self.compute_slices_used
    }

    pub fn memory_slices_free(&self) -> u32 {
        TOTAL_MEMORY_SLICES - self.memory_slices_used
    }

    /// Create a GPU instance of the given profile, enforcing the slice
    /// budget and the 7-GI limit.
    pub fn create_gi(&mut self, profile_id: ProfileId) -> crate::Result<GiId> {
        let p = GiProfile::get(profile_id);
        if self.gis.len() as u32 >= TOTAL_COMPUTE_SLICES {
            bail!("GI limit reached (max {} GPU instances)", TOTAL_COMPUTE_SLICES);
        }
        if p.compute_slices > self.compute_slices_free() {
            bail!(
                "not enough compute slices for {} (need {}, free {})",
                p.name,
                p.compute_slices,
                self.compute_slices_free()
            );
        }
        if p.memory_slices > self.memory_slices_free() {
            bail!(
                "not enough memory slices for {} (need {}, free {})",
                p.name,
                p.memory_slices,
                self.memory_slices_free()
            );
        }
        self.compute_slices_used += p.compute_slices;
        self.memory_slices_used += p.memory_slices;
        let id = GiId(self.next_gi);
        self.next_gi += 1;
        self.gis.push(GpuInstance {
            id,
            profile: p,
            cis: Vec::new(),
            ci_slices_used: 0,
        });
        Ok(id)
    }

    /// Create a compute instance over `slices` of the GI's compute slices.
    pub fn create_ci(&mut self, gi_id: GiId, slices: u32) -> crate::Result<CiId> {
        let gi = self
            .gis
            .iter_mut()
            .find(|g| g.id == gi_id)
            .ok_or_else(|| anyhow!("no such GPU instance {gi_id:?}"))?;
        if slices == 0 {
            bail!("compute instance needs at least one slice");
        }
        let free = gi.profile.compute_slices - gi.ci_slices_used;
        if slices > free {
            bail!(
                "GI {} has {free} free compute slices, requested {slices}",
                gi.profile.name
            );
        }
        // SMs are divided proportionally to compute slices within the GI
        // (e.g. 1c.7g.96gb -> floor(132/7) = 18 SMs).
        let sms = gi.profile.sms * slices / gi.profile.compute_slices;
        let id = CiId(self.next_ci);
        self.next_ci += 1;
        gi.ci_slices_used += slices;
        gi.cis.push(id);
        let ci = ComputeInstance {
            id,
            gi: gi_id,
            compute_slices: slices,
            sms,
            mem_gib: gi.profile.mem_gib,
            mem_bw_gibs: gi.profile.mem_bw_gibs,
            copy_engines: gi.profile.copy_engines,
            busy: false,
            siblings: 0,
        };
        self.cis.push(ci);
        self.refresh_siblings(gi_id);
        Ok(id)
    }

    /// Convenience: create a GI and one CI covering all its slices.
    pub fn create_full(&mut self, profile_id: ProfileId) -> crate::Result<CiId> {
        let gi = self.create_gi(profile_id)?;
        let slices = GiProfile::get(profile_id).compute_slices;
        self.create_ci(gi, slices)
    }

    pub fn ci(&self, id: CiId) -> Option<&ComputeInstance> {
        self.cis.iter().find(|c| c.id == id)
    }

    pub fn ci_mut(&mut self, id: CiId) -> Option<&mut ComputeInstance> {
        self.cis.iter_mut().find(|c| c.id == id)
    }

    pub fn gi(&self, id: GiId) -> Option<&GpuInstance> {
        self.gis.iter().find(|g| g.id == id)
    }

    pub fn cis(&self) -> &[ComputeInstance] {
        &self.cis
    }

    pub fn gis(&self) -> &[GpuInstance] {
        &self.gis
    }

    /// Destroy a compute instance. Fails while busy — the paper's static
    /// configuration limitation.
    pub fn destroy_ci(&mut self, id: CiId) -> crate::Result<()> {
        let idx = self
            .cis
            .iter()
            .position(|c| c.id == id)
            .ok_or_else(|| anyhow!("no such compute instance {id:?}"))?;
        if self.cis[idx].busy {
            bail!("compute instance is busy; MIG cannot be reconfigured while applications run");
        }
        let ci = self.cis.remove(idx);
        let gi = self.gis.iter_mut().find(|g| g.id == ci.gi).unwrap();
        gi.ci_slices_used -= ci.compute_slices;
        gi.cis.retain(|c| *c != id);
        self.refresh_siblings(ci.gi);
        Ok(())
    }

    /// Destroy a GPU instance. Fails if compute instances remain.
    pub fn destroy_gi(&mut self, id: GiId) -> crate::Result<()> {
        let idx = self
            .gis
            .iter()
            .position(|g| g.id == id)
            .ok_or_else(|| anyhow!("no such GPU instance {id:?}"))?;
        if !self.gis[idx].cis.is_empty() {
            bail!("GPU instance still has compute instances");
        }
        let gi = self.gis.remove(idx);
        self.compute_slices_used -= gi.profile.compute_slices;
        self.memory_slices_used -= gi.profile.memory_slices;
        Ok(())
    }

    /// Total SMs exposed by all CIs (for waste accounting).
    pub fn exposed_sms(&self) -> u32 {
        self.cis.iter().map(|c| c.sms).sum()
    }

    /// Total memory exposed by all GIs (GiB).
    pub fn exposed_mem_gib(&self) -> f64 {
        self.gis.iter().map(|g| g.profile.mem_gib).sum()
    }

    fn refresh_siblings(&mut self, gi: GiId) {
        let n = self.cis.iter().filter(|c| c.gi == gi).count() as u32;
        for c in self.cis.iter_mut().filter(|c| c.gi == gi) {
            c.siblings = n.saturating_sub(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::profile::ProfileId::*;

    fn mgr() -> MigManager {
        MigManager::new(GpuSpec::gh_h100_96gb())
    }

    #[test]
    fn seven_1g_instances_fit_and_eighth_fails() {
        let mut m = mgr();
        for _ in 0..7 {
            m.create_full(P1g12gb).unwrap();
        }
        assert_eq!(m.cis().len(), 7);
        assert_eq!(m.exposed_sms(), 112); // the §III-C headline
        assert!(m.create_full(P1g12gb).is_err());
    }

    #[test]
    fn memory_slices_limit_1g24() {
        let mut m = mgr();
        for _ in 0..4 {
            m.create_full(P1g24gb).unwrap();
        }
        // 4×2 = 8 memory slices used; a fifth must fail even though
        // compute slices remain.
        assert_eq!(m.memory_slices_free(), 0);
        assert!(m.compute_slices_free() > 0);
        assert!(m.create_full(P1g24gb).is_err());
    }

    #[test]
    fn mixed_4g_plus_3g_fits() {
        let mut m = mgr();
        m.create_full(P4g48gb).unwrap();
        m.create_full(P3g48gb).unwrap();
        assert_eq!(m.compute_slices_free(), 0);
        assert_eq!(m.memory_slices_free(), 0);
    }

    #[test]
    fn ci_subdivision_7g_into_7x1c() {
        // The paper's MIG 7×1c.7g configuration (Figs. 5/6): one 7g GI,
        // seven 1-slice CIs sharing memory.
        let mut m = mgr();
        let gi = m.create_gi(P7g96gb).unwrap();
        let mut ids = Vec::new();
        for _ in 0..7 {
            ids.push(m.create_ci(gi, 1).unwrap());
        }
        assert!(m.create_ci(gi, 1).is_err(), "8th CI must not fit");
        for id in &ids {
            let ci = m.ci(*id).unwrap();
            assert_eq!(ci.sms, 18); // floor(132/7)
            assert_eq!(ci.mem_gib, 94.5); // shared capacity
            assert_eq!(ci.siblings, 6);
        }
    }

    #[test]
    fn busy_ci_blocks_reconfiguration() {
        let mut m = mgr();
        let ci = m.create_full(P2g24gb).unwrap();
        m.ci_mut(ci).unwrap().busy = true;
        assert!(m.destroy_ci(ci).is_err());
        m.ci_mut(ci).unwrap().busy = false;
        m.destroy_ci(ci).unwrap();
    }

    #[test]
    fn destroy_gi_requires_no_cis() {
        let mut m = mgr();
        let gi = m.create_gi(P2g24gb).unwrap();
        let ci = m.create_ci(gi, 2).unwrap();
        assert!(m.destroy_gi(gi).is_err());
        m.destroy_ci(ci).unwrap();
        m.destroy_gi(gi).unwrap();
        assert_eq!(m.compute_slices_free(), TOTAL_COMPUTE_SLICES);
        assert_eq!(m.memory_slices_free(), TOTAL_MEMORY_SLICES);
    }

    #[test]
    fn slice_accounting_invariant() {
        let mut m = mgr();
        let a = m.create_full(P1g12gb).unwrap();
        let _b = m.create_full(P2g24gb).unwrap();
        let used: u32 = m.gis().iter().map(|g| g.profile.compute_slices).sum();
        assert_eq!(used, TOTAL_COMPUTE_SLICES - m.compute_slices_free());
        m.destroy_ci(a).unwrap();
        let gi_a = m.gis()[0].id;
        m.destroy_gi(gi_a).unwrap();
        let used: u32 = m.gis().iter().map(|g| g.profile.compute_slices).sum();
        assert_eq!(used, TOTAL_COMPUTE_SLICES - m.compute_slices_free());
    }
}
