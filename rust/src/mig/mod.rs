//! Multi-Instance GPU model (§II-B3, Table II).
//!
//! - `profile`: the GPU-instance profile table for the GH H100-96GB, with
//!   the paper's *measured* usable/wasted resources.
//! - `naming`: the `[Nc.]Mg.XXgb` profile-name grammar.
//! - `manager`: GPU-instance / compute-instance lifecycle with slice
//!   placement constraints (8 memory slices, 7 compute slices, max 7 GIs).

pub mod manager;
pub mod naming;
pub mod profile;

pub use manager::{ComputeInstance, GpuInstance, MigManager};
pub use naming::InstanceName;
pub use profile::{GiProfile, ProfileId};
