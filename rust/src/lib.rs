//! # migsim — GPU sharing & underutilization simulator
//!
//! Reproduction of *"Taming GPU Underutilization via Static Partitioning and
//! Fine-grained CPU Offloading"* (Schieffer, Shi, Ren, Peng — CS.DC 2026).
//!
//! The crate models a Grace Hopper H100-96GB system and its GPU-sharing
//! mechanisms (full GPU, time-slicing, MPS, MIG), a GPM-like metrics
//! sampler, an NVLink-C2C offloading scheme, and the paper's reward model —
//! driven by a discrete-event simulator calibrated to the paper's measured
//! tables. Real compute for the workload suite is executed through
//! AOT-compiled JAX/Pallas kernels via the PJRT runtime (`runtime`).
//!
//! Layering:
//! - `util`, `sim`, `bench`: from-scratch substrates (JSON, PRNG, stats,
//!   tables, bench harness, discrete-event engine).
//! - `gpu`, `mig`, `sharing`: the hardware + partitioning models.
//! - `workload`, `metrics`, `offload`, `reward`: the paper's method.
//! - `coordinator`, `experiments`: drivers that regenerate every table and
//!   figure in the paper's evaluation.
//! - `cluster`: the online serving layer — a multi-GPU fleet, an
//!   admission queue with deadlines, pluggable placement policies
//!   (first-fit / best-fit / offload-aware), and dynamic MIG
//!   reconfiguration. It consumes the four passive models below it
//!   (`mig` layouts, `offload` plans, `workload` runtimes, the `reward`
//!   metric) as policy inputs and closes the loop the paper's
//!   introduction motivates: `migsim serve`. Its event loop is
//!   O(changed state) per event (indexed placement, incremental
//!   integrals), with the naive full-rescan implementation retained as a
//!   bit-identical differential-test oracle (`ServeMode`). At cluster
//!   scale the loop shards across *nodes* (`cluster::shard`): parallel
//!   per-node event loops on worker threads, lock-stepped in
//!   lookahead-bounded epochs with a deterministic cross-node dispatcher
//!   — bit-identical for every thread count, with the single loop as the
//!   1-node oracle (`migsim serve --nodes N --threads T`). Slots batch:
//!   a MIG slice hosts up to K co-resident jobs under MPS-within-MIG
//!   semantics, costed by the `sharing::MigSharedGi` contention model
//!   (`migsim serve --batch K`; `--batch 1` is the classic system,
//!   bit-for-bit).
//! - `runtime`: PJRT loader/executor for `artifacts/*.hlo.txt`
//!   (feature-gated behind `pjrt`; a stub otherwise).

pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod gpu;
pub mod metrics;
pub mod mig;
pub mod offload;
pub mod reward;
pub mod runtime;
pub mod sharing;
pub mod sim;
pub mod util;
pub mod workload;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

/// Library version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
