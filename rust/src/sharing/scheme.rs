//! Sharing schemes and the `Partition` resource view.

use crate::gpu::GpuSpec;
use crate::mig::profile::GiProfile;
use crate::mig::{MigManager, ProfileId};
use anyhow::bail;

/// A GPU sharing configuration for a co-run experiment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Whole GPU per process, processes run back-to-back (the serial
    /// baseline of Figs. 5/6) or one process alone.
    Full,
    /// Default time-sliced scheduling: `copies` processes round-robin on
    /// the whole GPU.
    TimeSlice { copies: u32 },
    /// MPS with each client limited to `sm_pct`% of SMs.
    Mps { sm_pct: u32, copies: u32 },
    /// MIG: `copies` GPU instances of `profile`, one process each.
    Mig { profile: ProfileId, copies: u32 },
    /// MIG 7×1c.7g: one 7g GI subdivided into `copies` compute instances
    /// sharing memory capacity/bandwidth/L2 (MPS-like within the GI).
    MigSharedGi { copies: u32 },
    /// A compute instance of `ci_slices` slices on a GI of `profile`
    /// (e.g. 1c.2g.24gb in Fig. 8), `copies` CIs on the one GI.
    MigCi {
        profile: ProfileId,
        ci_slices: u32,
        copies: u32,
    },
}

impl Scheme {
    pub fn label(&self) -> String {
        match self {
            Scheme::Full => "full-GPU".to_string(),
            Scheme::TimeSlice { copies } => format!("time-slice x{copies}"),
            Scheme::Mps { sm_pct, copies } => format!("MPS {copies}x{sm_pct}%"),
            Scheme::Mig { profile, copies } => {
                format!("MIG {copies}x{}", GiProfile::get(*profile).name)
            }
            Scheme::MigSharedGi { copies } => format!("MIG {copies}x1c.7g"),
            Scheme::MigCi {
                profile,
                ci_slices,
                copies,
            } => {
                let gi = GiProfile::get(*profile);
                let name = crate::mig::InstanceName {
                    ci_slices: *ci_slices,
                    gi_slices: gi.compute_slices,
                    mem_gb: (gi.memory_slices * 12) as u32,
                };
                format!("MIG {copies}x{}", name.canonical())
            }
        }
    }

    pub fn copies(&self) -> u32 {
        match self {
            Scheme::Full => 1,
            Scheme::TimeSlice { copies }
            | Scheme::Mps { copies, .. }
            | Scheme::Mig { copies, .. }
            | Scheme::MigSharedGi { copies }
            | Scheme::MigCi { copies, .. } => *copies,
        }
    }

    /// The four co-run configurations evaluated in Figs. 5/6.
    pub fn corun_suite() -> Vec<Scheme> {
        vec![
            Scheme::Mig {
                profile: ProfileId::P1g12gb,
                copies: 7,
            },
            Scheme::MigSharedGi { copies: 7 },
            Scheme::Mps {
                sm_pct: 13,
                copies: 7,
            },
            Scheme::TimeSlice { copies: 7 },
        ]
    }
}

/// The per-process resource view under a scheme.
#[derive(Debug, Clone)]
pub struct Partition {
    pub label: String,
    /// SMs this process may schedule onto.
    pub sms: u32,
    /// Memory capacity visible to the process (GiB).
    pub mem_capacity_gib: f64,
    /// Hard bandwidth cap for this partition (GiB/s). For bandwidth-shared
    /// schemes this is the *total* pool, arbitrated at runtime.
    pub mem_bw_cap_gibs: f64,
    /// Whether HBM bandwidth / L2 are shared with co-runners (MPS,
    /// time-slice, and CIs on a shared GI) — enables the contention and
    /// cache-interference terms.
    pub bw_shared: bool,
    /// Copy engines owned (None = unpartitioned GPU, all engines).
    pub copy_engines: Option<u32>,
    /// Only one co-runner's kernels execute at a time (time-slicing).
    pub exclusive_time: bool,
    /// Relative kernel slowdown from shared-L2/memory interference when
    /// co-running (MPS: §IV-A "MPS always underperforms by 1-5%").
    pub interference: f64,
    /// Per-process context memory charged inside this partition (GiB).
    pub context_overhead_gib: f64,
    /// Whether a fault in this process kills co-runners (MPS: no error
    /// isolation — §II-B2).
    pub error_isolated: bool,
}

/// Build the per-process partitions for a scheme on the given GPU.
/// Returns one `Partition` per co-running process.
pub fn partitions(scheme: &Scheme, spec: &GpuSpec) -> crate::Result<Vec<Partition>> {
    let ctx = super::context::ContextModel::default();
    match scheme {
        Scheme::Full => Ok(vec![Partition {
            label: "full".to_string(),
            sms: spec.sms,
            mem_capacity_gib: spec.mem_usable_gib,
            mem_bw_cap_gibs: spec.mem_bw_gibs,
            bw_shared: false,
            copy_engines: None,
            exclusive_time: false,
            interference: 0.0,
            context_overhead_gib: ctx.per_process_gib(scheme),
            error_isolated: true,
        }]),
        Scheme::TimeSlice { copies } => {
            let p = Partition {
                label: "time-slice".to_string(),
                sms: spec.sms,
                mem_capacity_gib: spec.mem_usable_gib,
                mem_bw_cap_gibs: spec.mem_bw_gibs,
                bw_shared: true,
                copy_engines: None,
                exclusive_time: true,
                interference: 0.0,
                context_overhead_gib: ctx.per_process_gib(scheme),
                error_isolated: true,
            };
            Ok(vec![p; *copies as usize])
        }
        Scheme::Mps { sm_pct, copies } => {
            if *sm_pct == 0 || *sm_pct > 100 {
                bail!("MPS SM percentage must be in 1..=100");
            }
            let sms = ((spec.sms as f64 * *sm_pct as f64 / 100.0).round() as u32).max(1);
            let p = Partition {
                label: format!("mps-{sm_pct}%"),
                sms,
                mem_capacity_gib: spec.mem_usable_gib,
                mem_bw_cap_gibs: spec.mem_bw_gibs,
                bw_shared: true,
                copy_engines: None,
                exclusive_time: false,
                // §IV-A: MPS underperforms MIG by 1-5% from memory/L2
                // interference; per-co-runner increment applied to the
                // compute pipeline at runtime.
                interference: 0.02,
                context_overhead_gib: ctx.per_process_gib(scheme),
                error_isolated: false,
            };
            Ok(vec![p; *copies as usize])
        }
        Scheme::Mig { profile, copies } => {
            // Validate against the slice budget by actually creating the
            // instances through the manager.
            let mut mgr = MigManager::new(spec.clone());
            let mut out = Vec::new();
            for i in 0..*copies {
                let ci_id = mgr.create_full(*profile).map_err(|e| {
                    anyhow::anyhow!(
                        "cannot create {} instance #{}: {e}",
                        GiProfile::get(*profile).name,
                        i + 1
                    )
                })?;
                let ci = mgr.ci(ci_id).unwrap().clone();
                out.push(Partition {
                    label: format!("{}#{}", GiProfile::get(*profile).name, i),
                    sms: ci.sms,
                    mem_capacity_gib: ci.mem_gib,
                    mem_bw_cap_gibs: ci.mem_bw_gibs,
                    bw_shared: false,
                    copy_engines: Some(ci.copy_engines),
                    exclusive_time: false,
                    interference: 0.0,
                    context_overhead_gib: ctx.per_process_gib(scheme),
                    error_isolated: true,
                });
            }
            Ok(out)
        }
        Scheme::MigSharedGi { copies } => {
            if *copies == 0 || *copies > 7 {
                bail!("1c.7g compute instances must number 1..=7");
            }
            let mut mgr = MigManager::new(spec.clone());
            let gi = mgr.create_gi(ProfileId::P7g96gb)?;
            let mut out = Vec::new();
            for i in 0..*copies {
                let ci_id = mgr.create_ci(gi, 1)?;
                let ci = mgr.ci(ci_id).unwrap().clone();
                out.push(Partition {
                    label: format!("1c.7g#{i}"),
                    sms: ci.sms,
                    mem_capacity_gib: ci.mem_gib,
                    mem_bw_cap_gibs: ci.mem_bw_gibs,
                    // CIs on one GI share memory capacity and L2 — MPS-like.
                    bw_shared: true,
                    copy_engines: Some(1),
                    exclusive_time: false,
                    interference: 0.025,
                    context_overhead_gib: ctx.per_process_gib(scheme),
                    error_isolated: true,
                });
            }
            Ok(out)
        }
        Scheme::MigCi {
            profile,
            ci_slices,
            copies,
        } => {
            let mut mgr = MigManager::new(spec.clone());
            let gi = mgr.create_gi(*profile)?;
            let mut out = Vec::new();
            for i in 0..*copies {
                let ci_id = mgr.create_ci(gi, *ci_slices).map_err(|e| {
                    anyhow::anyhow!("cannot create CI #{}: {e}", i + 1)
                })?;
                let ci = mgr.ci(ci_id).unwrap().clone();
                let shared = *copies > 1;
                out.push(Partition {
                    label: format!("{}#{i}", scheme.label()),
                    sms: ci.sms,
                    mem_capacity_gib: ci.mem_gib,
                    mem_bw_cap_gibs: ci.mem_bw_gibs,
                    bw_shared: shared,
                    copy_engines: Some(1),
                    exclusive_time: false,
                    interference: if shared { 0.025 } else { 0.0 },
                    context_overhead_gib: ctx.per_process_gib(scheme),
                    error_isolated: true,
                });
            }
            Ok(out)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> GpuSpec {
        GpuSpec::gh_h100_96gb()
    }

    #[test]
    fn full_is_whole_gpu() {
        let ps = partitions(&Scheme::Full, &spec()).unwrap();
        assert_eq!(ps.len(), 1);
        assert_eq!(ps[0].sms, 132);
        assert!(!ps[0].bw_shared);
    }

    #[test]
    fn mig_7x1g() {
        let s = Scheme::Mig {
            profile: ProfileId::P1g12gb,
            copies: 7,
        };
        let ps = partitions(&s, &spec()).unwrap();
        assert_eq!(ps.len(), 7);
        for p in &ps {
            assert_eq!(p.sms, 16);
            assert_eq!(p.mem_capacity_gib, 11.0);
            assert_eq!(p.mem_bw_cap_gibs, 406.0);
            assert!(!p.bw_shared);
            assert!(p.error_isolated);
        }
    }

    #[test]
    fn mig_overcommit_rejected() {
        let s = Scheme::Mig {
            profile: ProfileId::P3g48gb,
            copies: 3,
        };
        assert!(partitions(&s, &spec()).is_err());
    }

    #[test]
    fn mps_13pct() {
        let s = Scheme::Mps {
            sm_pct: 13,
            copies: 7,
        };
        let ps = partitions(&s, &spec()).unwrap();
        assert_eq!(ps.len(), 7);
        // 13% of 132 = 17.16 -> 17 SMs.
        assert_eq!(ps[0].sms, 17);
        assert!(ps[0].bw_shared);
        assert!(!ps[0].error_isolated);
        assert!(ps[0].interference > 0.0);
    }

    #[test]
    fn shared_gi_cis() {
        let ps = partitions(&Scheme::MigSharedGi { copies: 7 }, &spec()).unwrap();
        assert_eq!(ps.len(), 7);
        assert_eq!(ps[0].sms, 18);
        assert_eq!(ps[0].mem_capacity_gib, 94.5);
        assert!(ps[0].bw_shared);
        assert!(ps[0].error_isolated, "MIG CIs keep error isolation");
    }

    #[test]
    fn timeslice_exclusive() {
        let ps = partitions(&Scheme::TimeSlice { copies: 3 }, &spec()).unwrap();
        assert!(ps.iter().all(|p| p.exclusive_time));
        assert!((ps[0].context_overhead_gib - 0.6).abs() < 1e-9);
    }

    #[test]
    fn corun_suite_is_the_papers_four() {
        let labels: Vec<String> = Scheme::corun_suite().iter().map(|s| s.label()).collect();
        assert_eq!(
            labels,
            vec!["MIG 7x1g.12gb", "MIG 7x1c.7g", "MPS 7x13%", "time-slice x7"]
        );
    }
}
