//! GPU-context memory overhead model (§IV-B).
//!
//! The paper measures, with a `cudaMalloc(NULL)`-style null-context probe:
//! ~60 MB per process under MIG 1g.12gb, ~600 MB per process under
//! time-slicing, and a fixed ~600 MB total under MPS (the server owns the
//! single shared context). This explains why time-slicing *appears* to
//! waste less memory at system level — the memory is burned by contexts,
//! not used by workloads.

use super::scheme::Scheme;

/// Context overhead constants (GiB).
#[derive(Debug, Clone)]
pub struct ContextModel {
    pub mig_per_process_gib: f64,
    pub timeslice_per_process_gib: f64,
    pub mps_total_gib: f64,
    pub full_per_process_gib: f64,
}

impl Default for ContextModel {
    fn default() -> Self {
        ContextModel {
            mig_per_process_gib: 0.060,
            timeslice_per_process_gib: 0.600,
            mps_total_gib: 0.600,
            full_per_process_gib: 0.600,
        }
    }
}

impl ContextModel {
    /// Total context memory consumed GPU-wide for `n` processes under the
    /// given scheme (GiB).
    pub fn total_gib(&self, scheme: &Scheme, n_processes: u32) -> f64 {
        match scheme {
            Scheme::Full => self.full_per_process_gib * n_processes as f64,
            Scheme::TimeSlice { .. } => self.timeslice_per_process_gib * n_processes as f64,
            Scheme::Mps { .. } => self.mps_total_gib,
            Scheme::Mig { .. } | Scheme::MigSharedGi { .. } | Scheme::MigCi { .. } => {
                self.mig_per_process_gib * n_processes as f64
            }
        }
    }

    /// Per-process context memory charged inside a single partition (GiB).
    pub fn per_process_gib(&self, scheme: &Scheme) -> f64 {
        match scheme {
            Scheme::Full => self.full_per_process_gib,
            Scheme::TimeSlice { .. } => self.timeslice_per_process_gib,
            Scheme::Mps { .. } => 0.0, // the server owns the context
            Scheme::Mig { .. } | Scheme::MigSharedGi { .. } | Scheme::MigCi { .. } => {
                self.mig_per_process_gib
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::ProfileId;

    #[test]
    fn paper_measurements() {
        let m = ContextModel::default();
        let mig = Scheme::Mig {
            profile: ProfileId::P1g12gb,
            copies: 7,
        };
        let ts = Scheme::TimeSlice { copies: 7 };
        let mps = Scheme::Mps {
            sm_pct: 13,
            copies: 7,
        };
        // ~60 MB/process MIG, ~600 MB/process time-slice, ~600 MB total MPS.
        assert!((m.total_gib(&mig, 7) - 0.42).abs() < 1e-9);
        assert!((m.total_gib(&ts, 7) - 4.2).abs() < 1e-9);
        assert!((m.total_gib(&mps, 7) - 0.6).abs() < 1e-9);
    }

    #[test]
    fn timeslice_overhead_dominates() {
        // §IV-B: time slicing has the highest context-induced overhead.
        let m = ContextModel::default();
        let ts = Scheme::TimeSlice { copies: 7 };
        let mig = Scheme::Mig {
            profile: ProfileId::P1g12gb,
            copies: 7,
        };
        assert!(m.total_gib(&ts, 7) > 5.0 * m.total_gib(&mig, 7));
    }
}
