//! Green contexts (§II-A): lightweight CUDA contexts pinned to a fixed
//! set of SMs, giving a *single application* granular control over
//! kernel→SM mapping (e.g. one 16-SM context and one 32-SM context
//! executing concurrently).
//!
//! Unlike MIG/MPS these partition only compute, inside one process:
//! memory, bandwidth and the L2 stay fully shared, and there is no
//! fault isolation to speak of (same process). The model exposes them
//! as `Partition`s so the kernel-duration model can be applied per
//! context.

use super::scheme::Partition;
use crate::gpu::GpuSpec;
use anyhow::bail;

/// A set of green contexts carved out of one GPU (or one MIG instance).
#[derive(Debug, Clone)]
pub struct GreenContextSet {
    total_sms: u32,
    used_sms: u32,
    contexts: Vec<(String, u32)>,
    /// Bandwidth/memory of the underlying device or instance.
    mem_capacity_gib: f64,
    mem_bw_gibs: f64,
}

impl GreenContextSet {
    /// Carve green contexts from the whole GPU.
    pub fn on_gpu(spec: &GpuSpec) -> GreenContextSet {
        GreenContextSet {
            total_sms: spec.sms,
            used_sms: 0,
            contexts: Vec::new(),
            mem_capacity_gib: spec.mem_usable_gib,
            mem_bw_gibs: spec.mem_bw_gibs,
        }
    }

    /// Carve green contexts inside a MIG partition.
    pub fn on_partition(part: &Partition) -> GreenContextSet {
        GreenContextSet {
            total_sms: part.sms,
            used_sms: 0,
            contexts: Vec::new(),
            mem_capacity_gib: part.mem_capacity_gib,
            mem_bw_gibs: part.mem_bw_cap_gibs,
        }
    }

    /// Add a context with `sms` SMs. SM sets are disjoint; the total may
    /// not exceed the device (the driver would reject it).
    pub fn add(&mut self, label: &str, sms: u32) -> crate::Result<()> {
        if sms == 0 {
            bail!("green context needs at least one SM");
        }
        if self.used_sms + sms > self.total_sms {
            bail!(
                "green contexts exceed device SMs: {} + {sms} > {}",
                self.used_sms,
                self.total_sms
            );
        }
        self.used_sms += sms;
        self.contexts.push((label.to_string(), sms));
        Ok(())
    }

    pub fn remaining_sms(&self) -> u32 {
        self.total_sms - self.used_sms
    }

    pub fn len(&self) -> usize {
        self.contexts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.contexts.is_empty()
    }

    /// Materialize as `Partition`s: compute split, everything else
    /// shared, no isolation (same process).
    pub fn partitions(&self) -> Vec<Partition> {
        self.contexts
            .iter()
            .map(|(label, sms)| Partition {
                label: format!("green:{label}"),
                sms: *sms,
                mem_capacity_gib: self.mem_capacity_gib,
                mem_bw_cap_gibs: self.mem_bw_gibs,
                bw_shared: true,
                copy_engines: None,
                exclusive_time: false,
                // Same process, same working set: cache interference is
                // the application's own business — modelled as zero.
                interference: 0.0,
                context_overhead_gib: 0.0,
                error_isolated: false,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuSpec;

    #[test]
    fn paper_example_16_and_32() {
        // §II-A: "an application can create two green contexts, one with
        // 16 SMs and another one with 32 SMs".
        let spec = GpuSpec::gh_h100_96gb();
        let mut g = GreenContextSet::on_gpu(&spec);
        g.add("small", 16).unwrap();
        g.add("large", 32).unwrap();
        assert_eq!(g.len(), 2);
        assert_eq!(g.remaining_sms(), 132 - 48);
        let parts = g.partitions();
        assert_eq!(parts[0].sms, 16);
        assert_eq!(parts[1].sms, 32);
        assert!(parts.iter().all(|p| p.bw_shared && !p.error_isolated));
        // Memory fully shared: both see the whole capacity.
        assert_eq!(parts[0].mem_capacity_gib, 94.5);
    }

    #[test]
    fn cannot_oversubscribe_sms() {
        let spec = GpuSpec::gh_h100_96gb();
        let mut g = GreenContextSet::on_gpu(&spec);
        g.add("a", 100).unwrap();
        assert!(g.add("b", 33).is_err());
        g.add("b", 32).unwrap();
        assert_eq!(g.remaining_sms(), 0);
        assert!(g.add("c", 1).is_err());
    }

    #[test]
    fn on_mig_partition() {
        let spec = GpuSpec::gh_h100_96gb();
        let parts = crate::sharing::scheme::partitions(
            &crate::sharing::Scheme::Mig {
                profile: crate::mig::ProfileId::P3g48gb,
                copies: 1,
            },
            &spec,
        )
        .unwrap();
        let mut g = GreenContextSet::on_partition(&parts[0]);
        g.add("x", 30).unwrap();
        g.add("y", 30).unwrap();
        assert!(g.add("z", 1).is_err(), "3g.48gb has exactly 60 SMs");
        let ps = g.partitions();
        assert_eq!(ps[0].mem_bw_cap_gibs, 1611.0);
    }

    #[test]
    fn zero_sm_context_rejected() {
        let spec = GpuSpec::gh_h100_96gb();
        let mut g = GreenContextSet::on_gpu(&spec);
        assert!(g.add("empty", 0).is_err());
    }
}
