//! GPU sharing schemes (§II-B): time-slicing, MPS, MIG, plus the
//! unpartitioned full-GPU baseline.
//!
//! Each scheme maps to a set of `Partition`s — the resource view each
//! co-running process gets — plus scheme-wide semantics (temporal
//! exclusivity, bandwidth sharing, context overhead, error isolation).

pub mod context;
pub mod green;
pub mod scheme;

pub use context::ContextModel;
pub use green::GreenContextSet;
pub use scheme::{Partition, Scheme};
