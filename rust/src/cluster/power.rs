//! Fleet power plane: co-resident slices share each GPU's power envelope.
//!
//! The source paper's key interference finding (§V-B1, Fig. 7) is that MIG
//! partitions compute and memory but **not power delivery**: every slice
//! on a board draws from the same 700 W budget, and when their aggregate
//! demand exceeds it the governor walks the SM clock down the ladder —
//! slowing *compute-bound* residents (whose service rate follows the
//! clock) while *memory-bound* ones sail on (the Fig. 7a/7b split). This
//! module turns the seed's per-GPU governor (`gpu::power`) into a cluster
//! resource plane, symmetric to `cluster::hostmem`:
//!
//! - **Per-GPU shared budget.** Aggregate demand is evaluated from the
//!   residents' `PlacementCost` activity rates at slot-churn events (a
//!   placement, completion, fault or reconfiguration — between events the
//!   resident set, and hence the demand, is constant). The governor is
//!   *history-free*: the throttle level is the smallest clock step at
//!   which demand fits the cap (`equilibrium_level`), a pure function of
//!   the resident set. That makes it deterministic, recomputable by the
//!   naive oracle bit-for-bit, monotone in co-resident demand, and
//!   invariant to how the fleet is sharded across threads.
//! - **Throttle feedback into placement.** The discrete level feeds the
//!   `Planner` cost tables exactly like C2C link contention does
//!   (`Planner::cost_at_throttled`, memoized per level; level 0 returns
//!   the pre-plane bits unchanged), so an admission is priced at the
//!   clock the GPU will actually run at once the job joins.
//! - **Node-wide cap as an admission gate.** Like the Grace host pool,
//!   a finite `node_cap_w` budget is charged in *integer milliwatts* of
//!   activity draw per admitted job (`job_draw_mw`) — integer sums are
//!   order-independent, so the indexed running counter and the oracle's
//!   scan agree exactly — and placement skips any class whose draw does
//!   not fit the headroom.
//! - **Consolidate-and-idle.** With the plane active, a fully idle,
//!   in-service GPU is *parked* at a deep-idle floor (`PARKED_IDLE_W`)
//!   instead of the powered-on idle draw — the packing policies already
//!   consolidate load, so low-load fleets see the energy win.
//!
//! The plane is **byte-inert when off**: `PowerPlaneConfig::default()`
//! schedules nothing, prices nothing, and every report reproduces the
//! pre-plane bytes exactly (the energy integral keeps the legacy clamped
//! `reported_w` sensor model). With the plane on, demand over the cap
//! **throttles — it is never silently clamped**: the energy integral uses
//! the unclamped demand at the governed clock.

use super::fleet::Fleet;
use super::{PlacementCost, ServeMode};
use crate::gpu::{GpuSpec, GpuUsage, PowerModel};
use anyhow::ensure;
use std::collections::BTreeMap;

/// Deep-idle draw (W) of a parked GPU: fully idle, in service, with the
/// plane actively consolidating — clocks dropped, contexts cold. Between
/// the paper's testbed's off state and the powered-on idle floor.
pub const PARKED_IDLE_W: f64 = 12.0;

/// Configuration of the fleet power plane. The default is inert: no cap
/// is enforced, no throttle level is ever non-zero, and every report is
/// byte-identical to the pre-plane serve loop.
#[derive(Debug, Clone, Copy)]
pub struct PowerPlaneConfig {
    /// Master switch. Off ⇒ the plane prices nothing and the legacy
    /// clamped-sensor energy model is kept bit-for-bit.
    pub enabled: bool,
    /// Shared per-GPU power budget (W). Demand above it walks the SM
    /// clock down the ladder. `f64::INFINITY` never throttles (parking
    /// still applies while the plane is enabled).
    pub gpu_cap_w: f64,
    /// Node-wide activity-draw budget (W) per node shard, gating
    /// admission like the Grace host pool. `f64::INFINITY` (the default)
    /// disables the gate.
    pub node_cap_w: f64,
}

impl Default for PowerPlaneConfig {
    fn default() -> Self {
        PowerPlaneConfig {
            enabled: false,
            gpu_cap_w: f64::INFINITY,
            node_cap_w: f64::INFINITY,
        }
    }
}

impl PowerPlaneConfig {
    /// Whether the plane does anything at all this run.
    pub fn active(&self) -> bool {
        self.enabled
    }

    /// Fail fast on nonsensical budgets (NaN, zero, negative).
    pub fn validate(&self) -> crate::Result<()> {
        ensure!(
            self.gpu_cap_w > 0.0 && !self.gpu_cap_w.is_nan(),
            "GPU power cap must be positive (or inf), got {}",
            self.gpu_cap_w
        );
        ensure!(
            self.node_cap_w > 0.0 && !self.node_cap_w.is_nan(),
            "node power cap must be positive (or inf), got {}",
            self.node_cap_w
        );
        Ok(())
    }

    /// The node budget in integer milliwatts (`u64::MAX` = no gate).
    pub fn node_cap_mw(&self) -> u64 {
        if self.enabled && self.node_cap_w.is_finite() {
            (self.node_cap_w * 1000.0).round() as u64
        } else {
            u64::MAX
        }
    }
}

/// Number of discrete throttle levels below boost on this spec's clock
/// ladder (level 0 = boost, `max_level` = the floor).
pub fn max_level(spec: &GpuSpec) -> u32 {
    ((spec.clock_max_mhz - spec.clock_min_mhz) / spec.clock_step_mhz).round() as u32
}

/// SM clock at discrete throttle level `level` (clamped at the floor).
pub fn clock_at_level(spec: &GpuSpec, level: u32) -> f64 {
    (spec.clock_max_mhz - level as f64 * spec.clock_step_mhz).max(spec.clock_min_mhz)
}

/// The history-free governor: the smallest throttle level at which the
/// residents' aggregate demand fits the cap, or the ladder floor when
/// even that cannot (memory-bound demand barely follows the clock —
/// Fig. 7a). A pure function of `(usage, cap)`: monotone non-decreasing
/// in every demand rate, identical however the fleet is sharded, and
/// recomputable by the naive oracle from raw resident lists.
pub fn equilibrium_level(spec: &GpuSpec, model: &PowerModel, usage: &GpuUsage, cap_w: f64) -> u32 {
    let floor = max_level(spec);
    for level in 0..=floor {
        if model.demand_w(spec, usage, clock_at_level(spec, level)) <= cap_w {
            return level;
        }
    }
    floor
}

/// Activity draw one admitted job charges against the node budget, in
/// integer milliwatts: the per-pipeline compute, HBM and C2C energy-rate
/// terms of its placement cost. The idle/SM-residency floor is fleet
/// overhead, not job draw, so it is deliberately not budgeted. Integer,
/// so charging and releasing in any order is exact — the indexed running
/// counter and the oracle scan can never drift.
pub fn job_draw_mw(model: &PowerModel, c: &PlacementCost) -> u64 {
    let mut w = 0.0;
    for (i, f) in c.flop_tflops.iter().enumerate() {
        w += model.e_flop_w_per_tflops[i] * f;
    }
    w += model.e_hbm_w_per_tbs * c.hbm_tbs;
    w += model.e_c2c_w_per_tbs * c.c2c_tbs;
    (w * 1000.0).round() as u64
}

/// One instantaneous reading of the plane across a shard's fleet.
#[derive(Debug, Clone, Copy)]
pub(crate) struct PowerSample {
    /// Fleet power (W): unclamped demand at each GPU's governed clock.
    pub watts: f64,
    /// GPUs currently at a throttle level > 0.
    pub throttled_gpus: u32,
    /// GPUs currently parked at the deep-idle floor.
    pub parked_gpus: u32,
}

/// Live per-GPU power bookkeeping — the plane's view of the fleet. The
/// naive oracle rebuilds every GPU's usage from the full running map on
/// each query; the indexed path recomputes only GPUs whose running set
/// changed and caches the per-GPU watts and throttle level (summed and
/// compared in the same ascending-GPU order, so the energy integral and
/// every level are bit-identical). Under slot-level batching each
/// co-resident contributes its own activity rates, keyed by job so
/// residents of one slot finish independently.
///
/// The tracker stores each resident's **level-0 (boost-clock) cost**: the
/// governor's input is the *requested* demand, and `PowerModel::demand_w`
/// applies the clock's frequency scaling itself — storing throttled rates
/// would double-count the slowdown and make the level history-dependent.
pub(crate) struct PowerTracker {
    model: PowerModel,
    plane: PowerPlaneConfig,
    node_cap_mw: u64,
    /// Activity draw of running jobs (mW), maintained incrementally on
    /// the indexed path; the naive oracle recomputes it by scan.
    node_used_mw: u64,
    /// Per-GPU aggregate usage at boost rates, refreshed lazily (indexed)
    /// or rebuilt per query (naive).
    usages: Vec<GpuUsage>,
    /// Per-GPU throttle level, valid after `refresh` when the plane is
    /// active (always 0 when off).
    levels: Vec<u32>,
    parked: Vec<bool>,
    state: TrackerState,
}

enum TrackerState {
    Naive {
        /// Activity rates of running jobs, keyed by (gpu, slot, job).
        /// BTreeMap so float summation order — and thus the energy
        /// integral — is deterministic (and, with one resident per slot,
        /// identical to the pre-batching (gpu, slot) order).
        running: BTreeMap<(usize, usize, u32), PlacementCost>,
    },
    Indexed {
        gpus: Vec<GpuPower>,
    },
}

struct GpuPower {
    /// Running-resident costs per slot, keyed by job id (iterated in slot
    /// order, then ascending job id — the same order the naive BTreeMap
    /// visits a GPU's residents in).
    costs: Vec<BTreeMap<u32, PlacementCost>>,
    dirty: bool,
}

/// Borrowed power-plane inputs of one placement decision: per-GPU boost
/// usage for prospective throttle levels, the shared GPU cap, and the
/// node budget's remaining headroom. Built by `PowerTracker::view` only
/// while the plane is active — placement with `None` runs the exact
/// pre-plane code path.
#[derive(Clone, Copy)]
pub struct PowerView<'a> {
    pub usages: &'a [GpuUsage],
    pub gpu_cap_w: f64,
    pub node_headroom_mw: u64,
}

impl PowerTracker {
    pub(crate) fn new(mode: ServeMode, fleet: &Fleet, plane: &PowerPlaneConfig) -> PowerTracker {
        let n = fleet.gpus.len();
        PowerTracker {
            model: PowerModel::h100(),
            plane: *plane,
            node_cap_mw: plane.node_cap_mw(),
            node_used_mw: 0,
            usages: vec![GpuUsage::default(); n],
            levels: vec![0; n],
            parked: vec![false; n],
            state: match mode {
                ServeMode::NaiveOracle => TrackerState::Naive {
                    running: BTreeMap::new(),
                },
                ServeMode::Indexed => TrackerState::Indexed {
                    gpus: fleet
                        .gpus
                        .iter()
                        .map(|g| GpuPower {
                            costs: vec![BTreeMap::new(); g.slots.len()],
                            dirty: true,
                        })
                        .collect(),
                },
            },
        }
    }

    pub(crate) fn plane_active(&self) -> bool {
        self.plane.active()
    }

    /// Whether the node admission gate can bite at all this run.
    pub(crate) fn node_cap_finite(&self) -> bool {
        self.node_cap_mw != u64::MAX
    }

    pub(crate) fn on_start(&mut self, gpu: usize, slot: usize, job: u32, c: PlacementCost) {
        if self.node_cap_finite() {
            self.node_used_mw += job_draw_mw(&self.model, &c);
        }
        match &mut self.state {
            TrackerState::Naive { running } => {
                running.insert((gpu, slot, job), c);
            }
            TrackerState::Indexed { gpus } => {
                gpus[gpu].costs[slot].insert(job, c);
                gpus[gpu].dirty = true;
            }
        }
    }

    pub(crate) fn on_finish(&mut self, gpu: usize, slot: usize, job: u32) {
        let gone = match &mut self.state {
            TrackerState::Naive { running } => running.remove(&(gpu, slot, job)),
            TrackerState::Indexed { gpus } => {
                gpus[gpu].dirty = true;
                gpus[gpu].costs[slot].remove(&job)
            }
        };
        if self.node_cap_finite() {
            if let Some(c) = gone {
                // The same cost bits that were charged release the same
                // integer draw — the counter can never drift.
                self.node_used_mw -= job_draw_mw(&self.model, &c);
            }
        }
    }

    /// A reconfiguration landed on `gpu`: the slot count changed (the
    /// GPU is drained, so there are no running costs to carry over).
    pub(crate) fn on_reconfig_done(&mut self, gpu: usize, slots: usize) {
        match &mut self.state {
            TrackerState::Naive { .. } => {}
            TrackerState::Indexed { gpus } => {
                gpus[gpu].costs.clear();
                gpus[gpu].costs.resize(slots, BTreeMap::new());
                gpus[gpu].dirty = true;
            }
        }
    }

    /// Remaining node-budget headroom (mW; `u64::MAX` = no gate). The
    /// naive oracle recomputes the used draw from its raw running map —
    /// integer sums, so it matches the indexed counter exactly.
    pub(crate) fn node_headroom_mw(&self) -> u64 {
        if !self.node_cap_finite() {
            return u64::MAX;
        }
        let used = match &self.state {
            TrackerState::Naive { running } => running
                .values()
                .map(|c| job_draw_mw(&self.model, c))
                .sum::<u64>(),
            TrackerState::Indexed { .. } => self.node_used_mw,
        };
        self.node_cap_mw.saturating_sub(used)
    }

    /// Rebuild the per-GPU boost usage of one GPU from cost maps, in the
    /// shared (slot, job) iteration order both modes use — the float sums
    /// are bit-identical however the rates were bookkept.
    fn build_usage<'a>(
        spec: &GpuSpec,
        busy_sms: u32,
        costs: impl Iterator<Item = &'a PlacementCost>,
    ) -> GpuUsage {
        let mut u = GpuUsage {
            context_active: busy_sms > 0,
            sm_busy_frac: busy_sms as f64 / spec.sms as f64,
            ..GpuUsage::default()
        };
        for c in costs {
            for (i, f) in c.flop_tflops.iter().enumerate() {
                u.flop_rate_tflops[i] += *f;
            }
            u.hbm_rate_tbs += c.hbm_tbs;
            u.c2c_rate_tbs += c.c2c_tbs;
        }
        u
    }

    /// Watts one GPU reports given its usage and plane state. Plane off:
    /// the legacy clamped sensor at boost (`reported_w`) — the pre-plane
    /// energy integral, bit-for-bit. Plane on: *unclamped* demand at the
    /// governed clock — over-cap demand throttles, it is never hidden by
    /// the sensor clamp — and a parked GPU reports the deep-idle floor.
    fn gpu_watts(&self, spec: &GpuSpec, usage: &GpuUsage, level: u32, parked: bool) -> f64 {
        if !self.plane.enabled {
            return self.model.reported_w(spec, usage, spec.clock_max_mhz);
        }
        if parked {
            return PARKED_IDLE_W;
        }
        self.model.demand_w(spec, usage, clock_at_level(spec, level))
    }

    /// Refresh the per-GPU usage/level/parked/watts caches. Indexed mode
    /// recomputes only dirty GPUs; the naive oracle rebuilds everything
    /// from its raw running map. Every derived quantity is a pure
    /// function of bit-identical per-GPU usage, so the two modes agree
    /// exactly.
    pub(crate) fn refresh(&mut self, fleet: &Fleet) {
        let plane = self.plane;
        let spec = &fleet.spec;
        match &mut self.state {
            TrackerState::Naive { running } => {
                for g in 0..fleet.gpus.len() {
                    let busy = fleet.gpus[g].busy_sms_scan();
                    let u = Self::build_usage(
                        spec,
                        busy,
                        running.range((g, 0, 0)..(g + 1, 0, 0)).map(|(_, c)| c),
                    );
                    self.levels[g] = if plane.enabled {
                        equilibrium_level(spec, &self.model, &u, plane.gpu_cap_w)
                    } else {
                        0
                    };
                    self.parked[g] = plane.enabled
                        && busy == 0
                        && !fleet.gpus[g].reconfiguring()
                        && !fleet.gpus[g].cordoned();
                    self.usages[g] = u;
                }
            }
            TrackerState::Indexed { gpus } => {
                for (g, gp) in gpus.iter_mut().enumerate() {
                    // Parked state depends on cordon/reconfig flags that
                    // flip without any resident churn (an idle GPU can be
                    // cordoned or drained for repartition), so it is
                    // re-read every refresh; usage and level are pure
                    // functions of the resident set and recompute only
                    // when it changed.
                    self.parked[g] = plane.enabled
                        && fleet.gpus[g].busy_sms() == 0
                        && !fleet.gpus[g].reconfiguring()
                        && !fleet.gpus[g].cordoned();
                    if !gp.dirty {
                        continue;
                    }
                    let busy = fleet.gpus[g].busy_sms();
                    let u =
                        Self::build_usage(spec, busy, gp.costs.iter().flat_map(|m| m.values()));
                    self.levels[g] = if plane.enabled {
                        equilibrium_level(spec, &self.model, &u, plane.gpu_cap_w)
                    } else {
                        0
                    };
                    self.usages[g] = u;
                    gp.dirty = false;
                }
            }
        }
    }

    /// Instantaneous fleet power (W) — the energy-integral input. With
    /// the plane off this is the legacy clamped-sensor sum, bit-for-bit.
    pub(crate) fn power_w(&mut self, fleet: &Fleet) -> f64 {
        self.sample(fleet).watts
    }

    /// One plane reading: fleet watts plus throttled/parked GPU counts.
    /// Per-GPU watts are a pure function of the refreshed usage/level and
    /// are summed in ascending-GPU order in both modes, so the energy
    /// integral is bit-identical between them.
    pub(crate) fn sample(&mut self, fleet: &Fleet) -> PowerSample {
        self.refresh(fleet);
        let spec = &fleet.spec;
        let mut watts = 0.0;
        let mut throttled = 0u32;
        let mut parked = 0u32;
        for g in 0..self.usages.len() {
            watts += self.gpu_watts(spec, &self.usages[g], self.levels[g], self.parked[g]);
            if self.levels[g] > 0 {
                throttled += 1;
            }
            if self.parked[g] {
                parked += 1;
            }
        }
        PowerSample {
            watts,
            throttled_gpus: throttled,
            parked_gpus: parked,
        }
    }

    /// Current throttle level of one GPU (valid after `refresh`).
    pub(crate) fn level(&self, gpu: usize) -> u32 {
        self.levels[gpu]
    }

    /// Current SM clocks (MHz) across the fleet, for telemetry samples
    /// (valid after `refresh`).
    pub(crate) fn clocks_into(&self, fleet: &Fleet, out: &mut Vec<f64>) {
        out.clear();
        for &lv in &self.levels {
            out.push(clock_at_level(&fleet.spec, lv));
        }
    }

    /// The placement-time view of the plane (`None` while inactive — the
    /// policies then run the exact pre-plane code path). Call `refresh`
    /// first so the borrowed usages are current.
    pub(crate) fn view(&self) -> Option<PowerView<'_>> {
        if !self.plane.enabled {
            return None;
        }
        Some(PowerView {
            usages: &self.usages,
            gpu_cap_w: self.plane.gpu_cap_w,
            node_headroom_mw: self.node_headroom_mw(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::pipelines::Pipeline;

    fn spec() -> GpuSpec {
        GpuSpec::gh_h100_96gb()
    }

    #[test]
    fn ladder_has_eleven_levels_and_clamps_at_the_floor() {
        let s = spec();
        assert_eq!(max_level(&s), 11);
        assert_eq!(clock_at_level(&s, 0), s.clock_max_mhz);
        assert_eq!(clock_at_level(&s, 11), s.clock_min_mhz);
        assert_eq!(clock_at_level(&s, 99), s.clock_min_mhz);
        assert_eq!(clock_at_level(&s, 1), s.clock_max_mhz - s.clock_step_mhz);
    }

    #[test]
    fn equilibrium_level_is_zero_under_cap_and_floor_when_hopeless() {
        let s = spec();
        let m = PowerModel::h100();
        let idle = GpuUsage::default();
        assert_eq!(equilibrium_level(&s, &m, &idle, m.cap_w), 0);
        // Memory-bound demand barely follows the clock: no level fits.
        let mut u = GpuUsage {
            context_active: true,
            sm_busy_frac: 0.97,
            hbm_rate_tbs: 0.90 * 3175.0 * 1.0737e9 / 1e12,
            ..Default::default()
        };
        u.add_flops(Pipeline::Fp32, 2.1);
        assert_eq!(equilibrium_level(&s, &m, &u, m.cap_w), max_level(&s));
        // An infinite cap never throttles anything.
        assert_eq!(equilibrium_level(&s, &m, &u, f64::INFINITY), 0);
    }

    #[test]
    fn equilibrium_level_monotone_in_demand() {
        // Randomized property: scaling every activity rate up can only
        // raise (never lower) the equilibrium throttle level.
        let s = spec();
        let m = PowerModel::h100();
        let mut rng = crate::util::Rng::new(0xB0B);
        for _ in 0..200 {
            let mut u = GpuUsage {
                context_active: true,
                sm_busy_frac: rng.f64(),
                hbm_rate_tbs: rng.f64() * 3.5,
                c2c_rate_tbs: rng.f64() * 0.4,
                ..Default::default()
            };
            u.add_flops(Pipeline::Fp32, rng.f64() * 60.0);
            u.add_flops(Pipeline::TensorFp16, rng.f64() * 600.0);
            let mut prev = 0u32;
            for k in 0..6 {
                let mut v = u;
                let f = 1.0 + 0.35 * k as f64;
                v.sm_busy_frac = (v.sm_busy_frac * f).min(1.0);
                v.hbm_rate_tbs *= f;
                v.c2c_rate_tbs *= f;
                for r in &mut v.flop_rate_tflops {
                    *r *= f;
                }
                let lv = equilibrium_level(&s, &m, &v, m.cap_w);
                assert!(
                    lv >= prev,
                    "level dropped {prev} -> {lv} as demand rose (k={k})"
                );
                prev = lv;
            }
        }
    }

    #[test]
    fn job_draw_is_integer_and_additive() {
        let m = PowerModel::h100();
        let mut c = PlacementCost {
            runtime_s: 10.0,
            resident_gib: 4.0,
            offloaded: false,
            host_gib: 0.0,
            sms_share: 16,
            occupancy: 0.9,
            flop_tflops: [0.0; 5],
            hbm_tbs: 0.25,
            c2c_tbs: 0.0,
        };
        c.flop_tflops[1] = 12.0; // fp32
        let mw = job_draw_mw(&m, &c);
        // 12 TFLOP/s × 2.5 W + 0.25 TB/s × 130 W = 62.5 W.
        assert_eq!(mw, 62_500);
        let zero = PlacementCost {
            flop_tflops: [0.0; 5],
            hbm_tbs: 0.0,
            ..c
        };
        assert_eq!(job_draw_mw(&m, &zero), 0, "idle floor is not job draw");
    }

    #[test]
    fn plane_off_clamps_plane_on_throttles() {
        // The clamp-vs-throttle split, pinned in both serve modes: with
        // the plane off the energy sensor keeps the legacy clamped
        // `reported_w` bits; with the plane on the same over-cap demand
        // throttles the clock and is integrated *unclamped* — a
        // memory-bound resident barely follows the clock, so its true
        // draw exceeds what the clamped sensor ever admitted.
        use crate::cluster::fleet::{Fleet, LayoutPreset};
        let m = PowerModel::h100();
        let mut c = PlacementCost {
            runtime_s: 10.0,
            resident_gib: 4.0,
            offloaded: false,
            host_gib: 0.0,
            sms_share: 132,
            occupancy: 0.9,
            flop_tflops: [0.0; 5],
            hbm_tbs: 6.0, // 780 W of HBM draw alone: far over the clamp
            c2c_tbs: 0.0,
        };
        c.flop_tflops[1] = 2.0;
        let off = PowerPlaneConfig::default();
        let on = PowerPlaneConfig {
            enabled: true,
            gpu_cap_w: 700.0,
            node_cap_w: f64::INFINITY,
        };
        for mode in [ServeMode::Indexed, ServeMode::NaiveOracle] {
            let mut fleet = Fleet::new(1, LayoutPreset::AllBig).unwrap();
            fleet.start_job(0, 0, 7, 0.0, 10.0, 4.0, 0);
            let busy = fleet.gpus[0].busy_sms_scan();
            let u = PowerTracker::build_usage(&fleet.spec, busy, std::iter::once(&c));
            assert!(
                m.demand_w(&fleet.spec, &u, fleet.spec.clock_max_mhz) > m.cap_w * 1.005,
                "construction: boost demand must exceed the sensor clamp"
            );
            let mut t = PowerTracker::new(mode, &fleet, &off);
            t.on_start(0, 0, 7, c);
            let w_off = t.power_w(&fleet);
            let clamped = m.reported_w(&fleet.spec, &u, fleet.spec.clock_max_mhz);
            assert_eq!(w_off.to_bits(), clamped.to_bits(), "{mode:?}");
            let mut t = PowerTracker::new(mode, &fleet, &on);
            t.on_start(0, 0, 7, c);
            let s = t.sample(&fleet);
            let lv = equilibrium_level(&fleet.spec, &m, &u, on.gpu_cap_w);
            assert!(lv > 0, "over-cap demand must throttle");
            assert_eq!(s.throttled_gpus, 1);
            let governed = m.demand_w(&fleet.spec, &u, clock_at_level(&fleet.spec, lv));
            assert_eq!(s.watts.to_bits(), governed.to_bits(), "{mode:?}");
            assert!(
                s.watts > w_off,
                "mem-bound demand throttled but unclamped ({} W) must exceed \
                 the clamped sensor ({} W)",
                s.watts,
                w_off
            );
            // Fully idle + plane on = parked at the deep-idle floor;
            // plane off keeps the legacy powered-on idle draw.
            let idle = Fleet::new(1, LayoutPreset::AllBig).unwrap();
            let mut t = PowerTracker::new(mode, &idle, &on);
            let s = t.sample(&idle);
            assert_eq!(s.parked_gpus, 1);
            assert_eq!(s.watts.to_bits(), PARKED_IDLE_W.to_bits());
            let mut t = PowerTracker::new(mode, &idle, &off);
            assert_eq!(t.power_w(&idle).to_bits(), m.idle_w.to_bits());
        }
    }

    #[test]
    fn plane_config_validates_bounds() {
        assert!(PowerPlaneConfig::default().validate().is_ok());
        for bad in [0.0, -5.0, f64::NAN] {
            let c = PowerPlaneConfig {
                enabled: true,
                gpu_cap_w: bad,
                node_cap_w: f64::INFINITY,
            };
            assert!(c.validate().is_err(), "gpu cap {bad} must be rejected");
            let c = PowerPlaneConfig {
                enabled: true,
                gpu_cap_w: 700.0,
                node_cap_w: bad,
            };
            assert!(c.validate().is_err(), "node cap {bad} must be rejected");
        }
        let inert = PowerPlaneConfig::default();
        assert_eq!(inert.node_cap_mw(), u64::MAX);
        let capped = PowerPlaneConfig {
            enabled: true,
            gpu_cap_w: 700.0,
            node_cap_w: 1.5,
        };
        assert_eq!(capped.node_cap_mw(), 1500);
    }
}
