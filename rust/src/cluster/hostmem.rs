//! The host-memory resource plane: finite Grace pools and contended
//! C2C links as first-class cluster resources.
//!
//! The paper's offloading story (§VI-A) bridges the gap between coarse
//! MIG slices and application memory by spilling data to CPU DRAM and
//! streaming it back over cache-coherent NVLink-C2C. Two physical
//! resources back that mechanism, and both are finite and shared:
//!
//! - **The Grace host pool.** Each node carries one CPU DRAM pool; every
//!   offloaded resident parks its spilled bytes there for as long as it
//!   runs. The pool is a *node*-level resource (one Grace socket per
//!   superchip node), so admission of an offloaded job must be gated on
//!   pool headroom — host DRAM is not infinite, and overcommitting it
//!   would mean paging, not serving.
//! - **The C2C link.** Each GPU has exactly one NVLink-C2C link to its
//!   Grace socket. The direct-access bandwidth (`gpu::nvlink`, Table IVb)
//!   is a property of the *link*, not of a MIG slice: when several
//!   offloading residents run on one GPU — across slices — they
//!   time-share it. Modeling the link as private to each job (as the
//!   pre-plane serving layer did) is optimistic exactly where the paper
//!   warns of shared-resource interference; MISO and the
//!   fragmentation-aware MIG schedulers report the same failure mode for
//!   other contended channels.
//!
//! This module holds the plane's configuration and the pool accounting
//! primitive. The live state lives where the rest of the serving state
//! lives: `cluster::fleet` carries the per-node `HostPool` and per-GPU
//! offload-resident counters (the link-share aggregate), and
//! `cluster::placement` folds the contention level into its cost tables
//! — a job sharing the link with `n − 1` co-offloaders sees `1/n` of the
//! direct-access rate, the classic equal-time-share model.
//!
//! ## Exactness
//!
//! Pool accounting is integer bytes (`gib_to_bytes` rounds once, at
//! admission), so charging and releasing the same residents — in any
//! order — restores the pool to its initial bytes *exactly*: no float
//! drift, and the scan oracle (`Fleet::host_used_bytes_scan`) is
//! trivially bit-equal. With `pool_gib = inf` and `c2c_contention = off`
//! (the defaults) every gate passes and every share is 1, so the serving
//! layer reproduces the pre-plane reports bit-for-bit — the golden
//! fixtures enforce that.

use anyhow::ensure;

/// Configuration of the host-memory plane for one serving run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostMemConfig {
    /// Grace host-memory pool per node (GiB). `f64::INFINITY` disables
    /// the admission gate (the pre-plane behaviour).
    pub pool_gib: f64,
    /// Time-share the per-GPU C2C link across co-offloading residents.
    /// `false` keeps the pre-plane private-link model.
    pub c2c_contention: bool,
}

impl Default for HostMemConfig {
    fn default() -> Self {
        HostMemConfig {
            pool_gib: f64::INFINITY,
            c2c_contention: false,
        }
    }
}

impl HostMemConfig {
    pub fn validate(&self) -> crate::Result<()> {
        ensure!(
            self.pool_gib > 0.0,
            "host pool must be positive GiB (or inf), got {}",
            self.pool_gib
        );
        Ok(())
    }
}

/// Parse a `--host-pool` argument: `inf` (no limit) or a positive GiB
/// count.
pub fn parse_pool_gib(s: &str) -> Option<f64> {
    if s == "inf" {
        return Some(f64::INFINITY);
    }
    let v: f64 = s.parse().ok()?;
    if v.is_finite() && v > 0.0 {
        Some(v)
    } else {
        None
    }
}

/// GiB → bytes with one deterministic rounding. All pool accounting is
/// integer bytes from here on. This is the shared `util::units`
/// converter, the same function backing `OffloadPlan::host_bytes`, so
/// plan-level and plane-level accounting agree by construction (and a
/// test below pins it).
pub use crate::util::units::gib_to_bytes;

/// One node's Grace host-memory pool: capacity + live integer-byte
/// accounting. `None` capacity means unlimited (the pre-plane model).
#[derive(Debug, Clone)]
pub struct HostPool {
    capacity_bytes: Option<u64>,
    used_bytes: u64,
}

impl HostPool {
    /// A pool of `pool_gib` GiB; `inf` builds an unlimited pool.
    pub fn new(pool_gib: f64) -> crate::Result<HostPool> {
        ensure!(
            pool_gib > 0.0,
            "host pool must be positive GiB (or inf), got {pool_gib}"
        );
        Ok(HostPool {
            capacity_bytes: if pool_gib.is_infinite() {
                None
            } else {
                Some(gib_to_bytes(pool_gib))
            },
            used_bytes: 0,
        })
    }

    pub fn capacity_bytes(&self) -> Option<u64> {
        self.capacity_bytes
    }

    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    /// Remaining headroom; `u64::MAX` when unlimited. Saturating: if a
    /// release-build caller ever overcommitted (charge only
    /// debug-asserts), an exhausted pool reports 0 headroom rather than
    /// wrapping to near-`u64::MAX` and reading as unlimited.
    pub fn headroom_bytes(&self) -> u64 {
        match self.capacity_bytes {
            None => u64::MAX,
            Some(c) => c.saturating_sub(self.used_bytes),
        }
    }

    /// Would charging `bytes` more stay within capacity?
    pub fn fits(&self, bytes: u64) -> bool {
        match self.capacity_bytes {
            None => true,
            Some(c) => self.used_bytes.saturating_add(bytes) <= c,
        }
    }

    /// Occupied fraction of the pool for the telemetry sampler: 0 for an
    /// unlimited (or pathological zero-capacity) pool, else
    /// `used / capacity`.
    pub fn occupancy_frac(&self) -> f64 {
        match self.capacity_bytes {
            None | Some(0) => 0.0,
            Some(c) => self.used_bytes as f64 / c as f64,
        }
    }

    /// Charge `bytes` (an offloaded resident's spilled data). The
    /// admission gate (`fits`) is the caller's responsibility; in debug
    /// builds overcommit is a bug, not a clamp.
    pub fn charge(&mut self, bytes: u64) {
        debug_assert!(self.fits(bytes), "host pool overcommitted");
        self.used_bytes += bytes;
    }

    /// Release `bytes` previously charged. Integer accounting: releasing
    /// exactly what was charged restores the initial bytes exactly.
    pub fn release(&mut self, bytes: u64) {
        debug_assert!(bytes <= self.used_bytes, "releasing more than charged");
        self.used_bytes -= bytes;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_defaults_are_the_preplane_model() {
        let c = HostMemConfig::default();
        assert!(c.pool_gib.is_infinite());
        assert!(!c.c2c_contention);
        c.validate().unwrap();
        let bad = |g: f64| HostMemConfig { pool_gib: g, ..Default::default() };
        assert!(bad(0.0).validate().is_err());
        assert!(bad(-3.0).validate().is_err());
    }

    #[test]
    fn pool_arg_parsing() {
        assert_eq!(parse_pool_gib("inf"), Some(f64::INFINITY));
        assert_eq!(parse_pool_gib("24"), Some(24.0));
        assert_eq!(parse_pool_gib("0.5"), Some(0.5));
        assert_eq!(parse_pool_gib("0"), None);
        assert_eq!(parse_pool_gib("-1"), None);
        assert_eq!(parse_pool_gib("nan"), None);
        assert_eq!(parse_pool_gib("bogus"), None);
    }

    #[test]
    fn bytes_conversion_is_exact_gibs() {
        assert_eq!(gib_to_bytes(0.0), 0);
        assert_eq!(gib_to_bytes(1.0), 1 << 30);
        assert_eq!(gib_to_bytes(5.5), 5 * (1 << 30) + (1 << 29));
    }

    #[test]
    fn plan_and_plane_accounting_agree() {
        // `OffloadPlan::host_bytes` and the plane's converter must be the
        // same rounding — a drift would let the planner admit a spill the
        // pool then accounts differently.
        use crate::offload::OffloadPlan;
        use crate::workload::{apps, AppId};
        for app in [AppId::Llama3Fp16, AppId::FaissLarge, AppId::Qiskit31] {
            let model = apps::model(app);
            let plan = OffloadPlan::plan(&model, model.footprint_gib * 0.6).unwrap();
            assert_eq!(plan.host_bytes(), gib_to_bytes(plan.spilled_gib), "{app:?}");
        }
    }

    #[test]
    fn pool_charge_release_restores_exactly() {
        let mut p = HostPool::new(16.0).unwrap();
        assert_eq!(p.capacity_bytes(), Some(16 << 30));
        let a = gib_to_bytes(5.5);
        let b = gib_to_bytes(3.25);
        let c = gib_to_bytes(7.25);
        assert!(p.fits(a));
        p.charge(a);
        p.charge(b);
        // Exactly at capacity: admissible, nothing more is.
        assert!(p.fits(c));
        p.charge(c);
        assert!(!p.fits(1), "pool exactly full must reject one more byte");
        // Release in a different order than charged: exact zero.
        p.release(b);
        p.release(c);
        p.release(a);
        assert_eq!(p.used_bytes(), 0);
        assert_eq!(p.headroom_bytes(), 16 << 30);
    }

    #[test]
    fn occupancy_fraction_tracks_usage() {
        let mut p = HostPool::new(8.0).unwrap();
        assert_eq!(p.occupancy_frac(), 0.0);
        p.charge(gib_to_bytes(2.0));
        assert!((p.occupancy_frac() - 0.25).abs() < 1e-12);
        p.charge(gib_to_bytes(6.0));
        assert!((p.occupancy_frac() - 1.0).abs() < 1e-12);
        let inf = HostPool::new(f64::INFINITY).unwrap();
        assert_eq!(inf.occupancy_frac(), 0.0, "unlimited pool reports 0");
    }

    #[test]
    fn infinite_pool_never_rejects() {
        let mut p = HostPool::new(f64::INFINITY).unwrap();
        assert_eq!(p.capacity_bytes(), None);
        assert_eq!(p.headroom_bytes(), u64::MAX);
        assert!(p.fits(u64::MAX));
        p.charge(1 << 40);
        assert!(p.fits(u64::MAX - (1 << 40)));
        p.release(1 << 40);
        assert_eq!(p.used_bytes(), 0);
        assert!(HostPool::new(0.0).is_err());
    }
}
