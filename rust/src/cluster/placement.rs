//! Placement policies: which idle MIG slot should an arriving job get?
//!
//! Three policies, in increasing awareness:
//! - `FirstFit`: first idle slot whose memory directly fits the job.
//! - `BestFit`: the *smallest* fitting idle slot — classic best-fit, which
//!   minimizes SM fragmentation by keeping big slices free for big jobs.
//! - `OffloadAware`: reward-maximizing admission (§VI-B). Every idle slot
//!   is a candidate — directly when the job fits, via an NVLink-C2C
//!   `OffloadPlan` when it does not — and the slot with the highest reward
//!   at the policy's α wins. This is what turns "queue for a big slice"
//!   into "run now on a small slice, spill the cold data over C2C".
//!
//! ## The indexed hot path
//!
//! All three policies share one observation: the modelled cost (and hence
//! the §VI-B reward) of a placement depends only on `(app, profile)` —
//! never on *which* slot of that profile hosts the job. So a placement
//! decision reduces to a walk over at most `NUM_PROFILES` (6) profile
//! classes against the fleet's per-profile idle-slot index
//! (`Fleet::first_idle`), instead of a full `gpus × slots` scan:
//! - first-fit: the minimum `(gpu, slot)` among each admissible class's
//!   first idle slot;
//! - best-fit: the first admissible class in `ALL_PROFILES` order (which
//!   ascends by SMs) with any idle slot;
//! - offload-aware: fold the per-class candidates in `(gpu, slot)` order
//!   with the same (reward, SMs) preference the naive scan applies per
//!   slot — provably the same choice, because all slots of a class tie.
//!
//! `Planner::place_scan` keeps the naive full scan as the
//! differential-test oracle: for any fleet state both paths return the
//! identical `(gpu, slot, cost)`.
//!
//! The `Planner` memoizes per-(app, profile, offload) costs in a dense
//! `[AppId::COUNT × NUM_PROFILES × 2]` array (no hashing on the hot
//! path), per-(app, offload) admissibility bitmasks — the precomputed
//! profile preference table — and per-(app, profile) rewards at the
//! policy's α (see `benches/placement.rs`).

use super::fleet::Fleet;
use crate::gpu::nvlink::{Dir, NvlinkModel};
use crate::gpu::{pipelines::ALL_PIPELINES, GpuSpec};
use crate::mig::profile::{GiProfile, ProfileId, ALL_PROFILES, NUM_PROFILES};
use crate::offload::OffloadPlan;
use crate::reward::{reward, ConfigEval, GpuTotals};
use crate::sharing::ContextModel;
use crate::workload::{apps, AppId, ExecEnv};

/// The dispatch policy of the serving layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    FirstFit,
    BestFit,
    /// Reward-maximizing admission with offloading, α in centi-units.
    OffloadAware { alpha_centi: u32 },
}

impl PolicyKind {
    /// Parse a policy name. `offload-aware` takes an optional α suffix
    /// (`offload-aware:0.25`); bare `offload-aware` defaults to α=0.10.
    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s {
            "first-fit" => return Some(PolicyKind::FirstFit),
            "best-fit" => return Some(PolicyKind::BestFit),
            "offload-aware" => return Some(PolicyKind::OffloadAware { alpha_centi: 10 }),
            _ => {}
        }
        let alpha: f64 = s.strip_prefix("offload-aware:")?.parse().ok()?;
        if !alpha.is_finite() || !(0.0..=100.0).contains(&alpha) {
            return None;
        }
        Some(PolicyKind::OffloadAware {
            alpha_centi: (alpha * 100.0).round() as u32,
        })
    }

    /// Canonical name; `parse(label())` round-trips.
    pub fn label(&self) -> String {
        match self {
            PolicyKind::FirstFit => "first-fit".into(),
            PolicyKind::BestFit => "best-fit".into(),
            PolicyKind::OffloadAware { alpha_centi } => {
                format!("offload-aware:{:.2}", *alpha_centi as f64 / 100.0)
            }
        }
    }

    pub fn allows_offload(&self) -> bool {
        matches!(self, PolicyKind::OffloadAware { .. })
    }
}

/// The modelled cost of running one app on one profile (possibly with
/// offloading): service time plus the average activity rates the fleet
/// power model integrates while the job runs.
#[derive(Debug, Clone, Copy)]
pub struct PlacementCost {
    pub runtime_s: f64,
    /// Resident footprint on the instance (GiB), after any offloading.
    pub resident_gib: f64,
    pub offloaded: bool,
    /// Average achieved occupancy on the instance (reward input).
    pub occupancy: f64,
    /// Average per-pipeline FLOP rates while running (TFLOP/s).
    pub flop_tflops: [f64; 5],
    /// Average HBM traffic while running (TB/s).
    pub hbm_tbs: f64,
    /// Average C2C traffic while running (TB/s).
    pub c2c_tbs: f64,
}

const N_COST: usize = AppId::COUNT * NUM_PROFILES * 2;

/// Cost evaluator + cache shared by all policies. All memo tables are
/// dense arrays indexed by `AppId::index` / `ProfileId::index` — the hot
/// path never hashes.
pub struct Planner {
    spec: GpuSpec,
    nvlink: NvlinkModel,
    ctx_gib: f64,
    scale: f64,
    /// Outer `Option` = "computed?"; inner = the (possibly impossible)
    /// placement cost. `[app × profile × offload]`.
    cost_cache: Vec<Option<Option<PlacementCost>>>,
    /// Admissible-profile bitmask per `[app × offload]` — the per-app
    /// profile preference table (bit i ⇔ `ALL_PROFILES[i]` can host).
    admissible: [Option<u8>; AppId::COUNT * 2],
    /// Whole-GPU runtime per app (the P_GPU reward basis).
    full_runtime: [Option<f64>; AppId::COUNT],
    /// §VI-B rewards `[app × profile]` at `reward_alpha_centi`.
    reward_cache: Vec<Option<f64>>,
    reward_alpha_centi: Option<u32>,
    /// Direct (unscaled) footprint per app, for reconfiguration sizing —
    /// precomputed so the dispatch hot path never rebuilds app models.
    footprint: [f64; AppId::COUNT],
}

impl Planner {
    pub fn new(workload_scale: f64) -> Planner {
        assert!(workload_scale > 0.0);
        let mut footprint = [0.0f64; AppId::COUNT];
        for app in apps::all() {
            footprint[app.index()] = apps::model(app).footprint_gib;
        }
        Planner {
            spec: GpuSpec::gh_h100_96gb(),
            nvlink: NvlinkModel::default(),
            ctx_gib: ContextModel::default().mig_per_process_gib,
            scale: workload_scale,
            cost_cache: vec![None; N_COST],
            admissible: [None; AppId::COUNT * 2],
            full_runtime: [None; AppId::COUNT],
            reward_cache: vec![None; AppId::COUNT * NUM_PROFILES],
            reward_alpha_centi: None,
            footprint,
        }
    }

    pub fn ctx_gib(&self) -> f64 {
        self.ctx_gib
    }

    /// Direct memory footprint of `app` (GiB) — the reconfiguration-sizing
    /// input.
    pub fn footprint_gib(&self, app: AppId) -> f64 {
        self.footprint[app.index()]
    }

    #[inline]
    fn cost_idx(app: AppId, profile: ProfileId, allow_offload: bool) -> usize {
        (app.index() * NUM_PROFILES + profile.index()) * 2 + allow_offload as usize
    }

    /// Cost of running `app` on `profile`. `allow_offload = false` returns
    /// `None` unless the footprint fits directly; `true` additionally
    /// tries an `OffloadPlan` (which may still fail: ≥25% must stay
    /// resident). Memoized.
    pub fn cost(
        &mut self,
        app: AppId,
        profile: ProfileId,
        allow_offload: bool,
    ) -> Option<PlacementCost> {
        let i = Self::cost_idx(app, profile, allow_offload);
        if let Some(c) = self.cost_cache[i] {
            return c;
        }
        let c = self.compute_cost(app, profile, allow_offload);
        self.cost_cache[i] = Some(c);
        c
    }

    fn compute_cost(
        &self,
        app: AppId,
        profile: ProfileId,
        allow_offload: bool,
    ) -> Option<PlacementCost> {
        let prof = GiProfile::get(profile);
        let model = apps::model(app).scaled(self.scale);
        let cap = prof.mem_gib - self.ctx_gib;
        let plan = if model.footprint_gib <= cap {
            None
        } else if allow_offload {
            match OffloadPlan::plan(&model, cap) {
                Ok(p) => Some(p),
                Err(_) => return None,
            }
        } else {
            return None;
        };
        let offloaded = plan.as_ref().map(|p| p.spilled_gib > 0.0).unwrap_or(false);
        let resident_gib = plan
            .as_ref()
            .map(|p| p.effective_footprint_gib())
            .unwrap_or(model.footprint_gib);
        let run_model = plan.as_ref().map(|p| p.apply(&model)).unwrap_or(model);
        let env = ExecEnv {
            sms: prof.sms,
            clock_frac: 1.0,
            bw_gibs: prof.mem_bw_gibs,
            // Offloaded data reads travel host→device over the shared C2C
            // link; the achievable direct rate depends on the SMs in
            // flight (Table IVb saturation curve).
            c2c_bw_gibs: self.nvlink.direct_bw_gibs(prof.sms, Dir::H2D),
            interference: 1.0,
            time_share: 1.0,
        };
        let runtime_s =
            run_model.runtime_quiet_s(&self.spec, &env) + run_model.startup_s * self.scale;
        if runtime_s <= 0.0 {
            return None;
        }
        // Average activity rates for the fleet energy model.
        let mut flop_tflops = [0.0f64; 5];
        let mut hbm_bytes = 0.0;
        let mut c2c_bytes = 0.0;
        for ph in &run_model.phases {
            let reps = ph.repeats as f64;
            for k in &ph.kernels {
                hbm_bytes += reps * k.hbm_bytes;
                c2c_bytes += reps * k.c2c_bytes;
                for p in ALL_PIPELINES {
                    flop_tflops[p.index()] += reps * k.flops * k.mix.frac(p);
                }
            }
        }
        for f in &mut flop_tflops {
            *f /= runtime_s * 1e12;
        }
        Some(PlacementCost {
            runtime_s,
            resident_gib,
            offloaded,
            occupancy: run_model.avg_occupancy_quiet(&self.spec, &env),
            flop_tflops,
            hbm_tbs: hbm_bytes / runtime_s / 1e12,
            c2c_tbs: c2c_bytes / runtime_s / 1e12,
        })
    }

    /// Bitmask of profiles that can host `app` (bit i ⇔ `ALL_PROFILES[i]`),
    /// memoized per (app, offload) — the precomputed preference table the
    /// indexed policies walk.
    fn admissible_mask(&mut self, app: AppId, allow_offload: bool) -> u8 {
        let i = app.index() * 2 + allow_offload as usize;
        if let Some(m) = self.admissible[i] {
            return m;
        }
        let mut m = 0u8;
        for pid in ALL_PROFILES {
            if self.cost(app, pid, allow_offload).is_some() {
                m |= 1 << pid.index();
            }
        }
        self.admissible[i] = Some(m);
        m
    }

    /// Runtime of `app` on the whole GPU (the P_GPU reward basis).
    pub fn full_gpu_runtime_s(&mut self, app: AppId) -> f64 {
        if let Some(t) = self.full_runtime[app.index()] {
            return t;
        }
        let model = apps::model(app).scaled(self.scale);
        let env = ExecEnv {
            sms: self.spec.sms,
            clock_frac: 1.0,
            bw_gibs: self.spec.mem_bw_gibs,
            c2c_bw_gibs: self.nvlink.direct_both_cap_gibs,
            interference: 1.0,
            time_share: 1.0,
        };
        let t = model.runtime_quiet_s(&self.spec, &env) + model.startup_s * self.scale;
        self.full_runtime[app.index()] = Some(t);
        t
    }

    /// §VI-B reward of running `app` on `profile` at cost `c`.
    pub fn reward_of(
        &mut self,
        app: AppId,
        profile: ProfileId,
        c: &PlacementCost,
        alpha: f64,
    ) -> f64 {
        let prof = GiProfile::get(profile);
        let p_gpu = 1.0 / self.full_gpu_runtime_s(app).max(1e-9);
        let eval = ConfigEval {
            config: prof.name.to_string(),
            perf: 1.0 / c.runtime_s.max(1e-9),
            occupancy: c.occupancy,
            sms: prof.sms,
            mem_instance_gib: prof.mem_gib,
            mem_app_gib: c.resident_gib + self.ctx_gib,
        };
        let totals = GpuTotals {
            sms: self.spec.sms,
            mem_gib: self.spec.mem_usable_gib,
            perf_full_gpu: p_gpu,
        };
        reward(&eval, &totals, alpha).reward
    }

    /// `reward_of` memoized per (app, profile) at a fixed α — the value
    /// depends on nothing else, so the offload-aware walk reads a dense
    /// table. Switching α (a different policy instance) flushes the table.
    fn cached_reward(
        &mut self,
        app: AppId,
        profile: ProfileId,
        alpha_centi: u32,
        c: &PlacementCost,
    ) -> f64 {
        if self.reward_alpha_centi != Some(alpha_centi) {
            self.reward_cache.iter_mut().for_each(|r| *r = None);
            self.reward_alpha_centi = Some(alpha_centi);
        }
        let i = app.index() * NUM_PROFILES + profile.index();
        if let Some(r) = self.reward_cache[i] {
            return r;
        }
        let r = self.reward_of(app, profile, c, alpha_centi as f64 / 100.0);
        self.reward_cache[i] = Some(r);
        r
    }

    /// Pick an idle slot for `app` under `policy`, via the fleet's
    /// per-profile idle index: a walk over ≤`NUM_PROFILES` classes.
    /// Returns `(gpu, slot, cost)`. Deterministic, and bit-identical to
    /// `place_scan` (ties break toward smaller instances, then lower
    /// GPU/slot index).
    pub fn place(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        policy: PolicyKind,
    ) -> Option<(usize, usize, PlacementCost)> {
        match policy {
            PolicyKind::FirstFit => {
                let mask = self.admissible_mask(app, false);
                let mut best: Option<(usize, usize, ProfileId)> = None;
                for pid in ALL_PROFILES {
                    if mask & (1 << pid.index()) == 0 {
                        continue;
                    }
                    if let Some((g, s)) = fleet.first_idle(pid) {
                        if best.map(|(bg, bs, _)| (g, s) < (bg, bs)).unwrap_or(true) {
                            best = Some((g, s, pid));
                        }
                    }
                }
                best.map(|(g, s, pid)| (g, s, self.cost(app, pid, false).unwrap()))
            }
            PolicyKind::BestFit => {
                let mask = self.admissible_mask(app, false);
                // ALL_PROFILES ascends by SMs: the first admissible class
                // with an idle slot *is* the best fit.
                for pid in ALL_PROFILES {
                    if mask & (1 << pid.index()) == 0 {
                        continue;
                    }
                    if let Some((g, s)) = fleet.first_idle(pid) {
                        return Some((g, s, self.cost(app, pid, false).unwrap()));
                    }
                }
                None
            }
            PolicyKind::OffloadAware { alpha_centi } => {
                // One candidate per admissible class with an idle slot, at
                // the class's first (gpu, slot). Folding them in (gpu,
                // slot) order with the per-slot preference of the naive
                // scan reproduces its choice exactly: within a class every
                // slot ties on (reward, SMs), so only first encounters
                // matter, and the scan encounters classes in first-slot
                // order.
                let mask = self.admissible_mask(app, true);
                let mut cands = [(0usize, 0usize, ProfileId::P1g12gb); NUM_PROFILES];
                let mut n = 0;
                for pid in ALL_PROFILES {
                    if mask & (1 << pid.index()) == 0 {
                        continue;
                    }
                    if let Some((g, s)) = fleet.first_idle(pid) {
                        cands[n] = (g, s, pid);
                        n += 1;
                    }
                }
                cands[..n].sort_unstable();
                let mut best: Option<(f64, u32, usize, usize, ProfileId)> = None;
                for &(g, s, pid) in &cands[..n] {
                    let c = self.cost(app, pid, true).unwrap();
                    let r = self.cached_reward(app, pid, alpha_centi, &c);
                    let sms = GiProfile::get(pid).sms;
                    let better = match &best {
                        None => true,
                        Some((br, bsms, ..)) => r > *br || (r == *br && sms < *bsms),
                    };
                    if better {
                        best = Some((r, sms, g, s, pid));
                    }
                }
                best.map(|(_, _, g, s, pid)| (g, s, self.cost(app, pid, true).unwrap()))
            }
        }
    }

    /// The naive full `gpus × slots` scan — the differential-test oracle
    /// for `place` (and the baseline `benches/placement.rs` measures the
    /// indexed walk against).
    pub fn place_scan(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        policy: PolicyKind,
    ) -> Option<(usize, usize, PlacementCost)> {
        match policy {
            PolicyKind::FirstFit => {
                for (g, gpu) in fleet.gpus.iter().enumerate() {
                    if gpu.reconfiguring() {
                        continue;
                    }
                    for (s, slot) in gpu.slots.iter().enumerate() {
                        if !slot.is_idle() {
                            continue;
                        }
                        if let Some(c) = self.cost(app, slot.profile.id, false) {
                            return Some((g, s, c));
                        }
                    }
                }
                None
            }
            PolicyKind::BestFit => {
                let mut best: Option<(u32, usize, usize, PlacementCost)> = None;
                for (g, gpu) in fleet.gpus.iter().enumerate() {
                    if gpu.reconfiguring() {
                        continue;
                    }
                    for (s, slot) in gpu.slots.iter().enumerate() {
                        if !slot.is_idle() {
                            continue;
                        }
                        if let Some(c) = self.cost(app, slot.profile.id, false) {
                            let sms = slot.profile.sms;
                            if best.as_ref().map(|(b, ..)| sms < *b).unwrap_or(true) {
                                best = Some((sms, g, s, c));
                            }
                        }
                    }
                }
                best.map(|(_, g, s, c)| (g, s, c))
            }
            PolicyKind::OffloadAware { alpha_centi } => {
                let mut best: Option<(f64, u32, usize, usize, PlacementCost)> = None;
                for (g, gpu) in fleet.gpus.iter().enumerate() {
                    if gpu.reconfiguring() {
                        continue;
                    }
                    for (s, slot) in gpu.slots.iter().enumerate() {
                        if !slot.is_idle() {
                            continue;
                        }
                        let c = match self.cost(app, slot.profile.id, true) {
                            Some(c) => c,
                            None => continue,
                        };
                        let r = self.cached_reward(app, slot.profile.id, alpha_centi, &c);
                        let sms = slot.profile.sms;
                        // Exact comparisons (no epsilon): tie-breaking
                        // must be order-insensitive for the class-level
                        // walk in `place` to match slot-level scanning.
                        let better = match &best {
                            None => true,
                            Some((br, bsms, ..)) => r > *br || (r == *br && sms < *bsms),
                        };
                        if better {
                            best = Some((r, sms, g, s, c));
                        }
                    }
                }
                best.map(|(_, _, g, s, c)| (g, s, c))
            }
        }
    }

    /// Whether `app` could run on *some* profile of the per-GPU layouts the
    /// fleet currently has or is reconfiguring toward — the trigger guard
    /// for dynamic reconfiguration. O(profile classes) via the fleet's
    /// layout-class counts.
    pub fn fits_current_layouts(&mut self, fleet: &Fleet, app: AppId, allow_offload: bool) -> bool {
        for pid in ALL_PROFILES {
            if fleet.has_layout_class(pid) && self.cost(app, pid, allow_offload).is_some() {
                return true;
            }
        }
        false
    }

    /// `fits_current_layouts` by full GPU×layout scan — the
    /// differential-test oracle.
    pub fn fits_current_layouts_scan(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        allow_offload: bool,
    ) -> bool {
        for gpu in &fleet.gpus {
            for &p in gpu.effective_layout() {
                if self.cost(app, p, allow_offload).is_some() {
                    return true;
                }
            }
        }
        false
    }

    /// Whether `app` is servable at all on this hardware (largest profile,
    /// offloading allowed when the policy supports it).
    pub fn servable(&mut self, app: AppId, allow_offload: bool) -> bool {
        self.cost(app, ProfileId::P7g96gb, allow_offload).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fleet::{Fleet, LayoutPreset};

    #[test]
    fn cost_direct_vs_offload() {
        let mut pl = Planner::new(0.05);
        // Small job fits 1g directly; the offload-allowed cost is identical
        // (no spill happens).
        let direct = pl.cost(AppId::Faiss, ProfileId::P1g12gb, false).unwrap();
        let relaxed = pl.cost(AppId::Faiss, ProfileId::P1g12gb, true).unwrap();
        assert!(!direct.offloaded && !relaxed.offloaded);
        assert_eq!(direct.runtime_s, relaxed.runtime_s);
        // 16.5 GiB llama does not fit 1g directly but offloads.
        assert!(pl.cost(AppId::Llama3Fp16, ProfileId::P1g12gb, false).is_none());
        let off = pl.cost(AppId::Llama3Fp16, ProfileId::P1g12gb, true).unwrap();
        assert!(off.offloaded);
        assert!(off.resident_gib <= 11.0 - pl.ctx_gib() + 1e-9);
        assert!(off.c2c_tbs > 0.0, "offloaded runs drive C2C traffic");
        // Offloading on 1g is slower than running directly on 2g.
        let two_g = pl.cost(AppId::Llama3Fp16, ProfileId::P2g24gb, false).unwrap();
        assert!(off.runtime_s > two_g.runtime_s);
    }

    #[test]
    fn first_fit_vs_best_fit_slot_choice() {
        // Mixed GPU 2 layout is [4g.48gb, 3g.48gb]; a small job should go
        // to the 3g slot under best-fit but the 4g slot under first-fit.
        let mut fleet = Fleet::new(3, LayoutPreset::Mixed).unwrap();
        // Occupy every slot on GPUs 0 and 1 so only GPU 2 is free.
        for g in 0..2 {
            for s in 0..fleet.gpus[g].slots.len() {
                fleet.start_job(g, s, 0, 0.0, 100.0);
            }
        }
        let mut pl = Planner::new(0.05);
        let (g_ff, s_ff, _) = pl.place(&fleet, AppId::Hotspot, PolicyKind::FirstFit).unwrap();
        assert_eq!((g_ff, s_ff), (2, 0), "first-fit takes the 4g slot");
        let (g_bf, s_bf, _) = pl.place(&fleet, AppId::Hotspot, PolicyKind::BestFit).unwrap();
        assert_eq!((g_bf, s_bf), (2, 1), "best-fit takes the smaller 3g slot");
    }

    #[test]
    fn offload_aware_admits_large_jobs_onto_small_slices() {
        let fleet = Fleet::new(1, LayoutPreset::AllSmall).unwrap();
        let mut pl = Planner::new(0.05);
        for policy in [PolicyKind::FirstFit, PolicyKind::BestFit] {
            assert!(
                pl.place(&fleet, AppId::Llama3Fp16, policy).is_none(),
                "{:?} must not fit 16.5 GiB into 11 GiB",
                policy
            );
        }
        let (_, _, c) = pl
            .place(&fleet, AppId::Llama3Fp16, PolicyKind::OffloadAware { alpha_centi: 10 })
            .unwrap();
        assert!(c.offloaded);
    }

    #[test]
    fn indexed_place_matches_naive_scan_across_fleet_states() {
        // Pseudo-random occupancy churn over a mixed fleet: every policy
        // must pick the identical slot through the index and the scan.
        let mut rng = crate::util::Rng::new(0x9A7E);
        let mut fleet = Fleet::new(5, LayoutPreset::Mixed).unwrap();
        let mut pl = Planner::new(0.05);
        let apps = [
            AppId::Faiss,
            AppId::Hotspot,
            AppId::Llama3Fp16,
            AppId::Qiskit31,
            AppId::NekRs,
        ];
        let policies = [
            PolicyKind::FirstFit,
            PolicyKind::BestFit,
            PolicyKind::OffloadAware { alpha_centi: 10 },
            PolicyKind::OffloadAware { alpha_centi: 60 },
        ];
        for step in 0..120u32 {
            let g = rng.below(5) as usize;
            if rng.below(2) == 0 {
                if let Some(s) = fleet.gpus[g].slots.iter().position(|s| s.is_idle()) {
                    fleet.start_job(g, s, step, step as f64, step as f64 + 9.0);
                }
            } else if let Some(s) = fleet.gpus[g].slots.iter().position(|s| !s.is_idle()) {
                fleet.finish_job(g, s, step as f64);
            }
            for &app in &apps {
                for &policy in &policies {
                    let fast = pl.place(&fleet, app, policy).map(|(g, s, _)| (g, s));
                    let slow = pl.place_scan(&fleet, app, policy).map(|(g, s, _)| (g, s));
                    assert_eq!(fast, slow, "step {step} {app:?} {policy:?}");
                }
            }
        }
    }

    #[test]
    fn servable_and_layout_fit_guards() {
        let fleet = Fleet::new(1, LayoutPreset::AllSmall).unwrap();
        let mut pl = Planner::new(0.05);
        assert!(pl.servable(AppId::Llama3Fp16, false), "fits 7g directly");
        assert!(!pl.fits_current_layouts(&fleet, AppId::Llama3Fp16, false));
        assert!(pl.fits_current_layouts(&fleet, AppId::Llama3Fp16, true));
        assert!(pl.fits_current_layouts(&fleet, AppId::Faiss, false));
        // Indexed and scan guards agree, including mid-reconfiguration.
        let mut fleet = fleet;
        fleet
            .begin_reconfig(0, crate::cluster::fleet::class_layout(ProfileId::P2g24gb), 5.0)
            .unwrap();
        for app in [AppId::Llama3Fp16, AppId::Faiss, AppId::Qiskit31] {
            for allow in [false, true] {
                assert_eq!(
                    pl.fits_current_layouts(&fleet, app, allow),
                    pl.fits_current_layouts_scan(&fleet, app, allow),
                    "{app:?} allow={allow}"
                );
            }
        }
    }

    #[test]
    fn reward_prefers_tight_fit_at_low_alpha() {
        let mut pl = Planner::new(0.05);
        // FAISS scales poorly: a 1g slice wastes far less than 7g.
        let c1 = pl.cost(AppId::Faiss, ProfileId::P1g12gb, false).unwrap();
        let c7 = pl.cost(AppId::Faiss, ProfileId::P7g96gb, false).unwrap();
        let r1 = pl.reward_of(AppId::Faiss, ProfileId::P1g12gb, &c1, 0.1);
        let r7 = pl.reward_of(AppId::Faiss, ProfileId::P7g96gb, &c7, 0.1);
        assert!(r1 > r7, "r1={r1} r7={r7}");
    }

    #[test]
    fn policy_parse_accepts_alpha_and_round_trips() {
        assert_eq!(PolicyKind::parse("first-fit"), Some(PolicyKind::FirstFit));
        assert_eq!(PolicyKind::parse("best-fit"), Some(PolicyKind::BestFit));
        assert_eq!(
            PolicyKind::parse("offload-aware"),
            Some(PolicyKind::OffloadAware { alpha_centi: 10 })
        );
        assert_eq!(
            PolicyKind::parse("offload-aware:0.25"),
            Some(PolicyKind::OffloadAware { alpha_centi: 25 })
        );
        assert_eq!(
            PolicyKind::parse("offload-aware:1"),
            Some(PolicyKind::OffloadAware { alpha_centi: 100 })
        );
        assert_eq!(PolicyKind::parse("offload-aware:-1"), None);
        assert_eq!(PolicyKind::parse("offload-aware:nan"), None);
        assert_eq!(PolicyKind::parse("offload-aware:"), None);
        assert_eq!(PolicyKind::parse("bogus"), None);
        for policy in [
            PolicyKind::FirstFit,
            PolicyKind::BestFit,
            PolicyKind::OffloadAware { alpha_centi: 10 },
            PolicyKind::OffloadAware { alpha_centi: 25 },
            PolicyKind::OffloadAware { alpha_centi: 7 },
            PolicyKind::OffloadAware { alpha_centi: 150 },
        ] {
            assert_eq!(
                PolicyKind::parse(&policy.label()),
                Some(policy),
                "label {} must round-trip",
                policy.label()
            );
        }
    }
}
