//! Placement policies: which serving-slot seat should an arriving job get?
//!
//! Three policies, in increasing awareness:
//! - `FirstFit`: first feasible seat — an empty slot whose memory directly
//!   fits the job, or (under batching) an occupied slot with a free seat
//!   and enough memory headroom.
//! - `BestFit`: the seat on the *smallest* fitting profile — classic
//!   best-fit, which minimizes SM fragmentation by keeping big slices
//!   free for big jobs; within a profile it prefers the *most occupied*
//!   open slot (densest packing keeps empty slots free).
//! - `OffloadAware`: reward-maximizing admission (§VI-B). Every feasible
//!   seat is a candidate — directly when the job fits, via an NVLink-C2C
//!   `OffloadPlan` when it does not — and the seat with the highest reward
//!   at the policy's α wins. Co-residency trades performance (the job
//!   runs slower) against SM waste (a packed slice strands fewer SMs),
//!   so well-scaling apps keep preferring empty slices while poorly
//!   scaling ones may score higher co-resident — exactly the §VI-B
//!   arbitration, now over co-residency classes too.
//!
//! ## The contention cost model (MPS-within-MIG)
//!
//! The modelled cost of a placement depends only on the co-residency
//! class `(app, profile, occupancy)` — never on *which* slot hosts the
//! job. At occupancy `n` the `n` clients share the slice exactly as the
//! paper's `Scheme::MigSharedGi` co-runs share one GI: each gets an equal
//! SM share (the MPS cap model of `sharing::scheme`), an equal share of
//! the slice's HBM bandwidth pool, and pays the per-co-runner compute
//! interference measured for shared-GI co-runs; the C2C direct rate
//! follows the reduced SMs in flight (Table IVb saturation curve). At
//! `n = 1` every term reduces to the unbatched environment bit-for-bit.
//! A job's runtime is fixed by the occupancy *at admission* (residents
//! already running are not re-fit — see ROADMAP follow-ups).
//!
//! Memory is the batching gate (`ContextModel`): a seat is only feasible
//! if the slice still holds every resident's footprint plus a per-process
//! context after the newcomer joins. Offload plans are computed against
//! the solo cap, so a spilled job's resident set fills the slice and it
//! naturally refuses co-residents.
//!
//! ## The host-memory plane (`cluster::hostmem`)
//!
//! Offloading consumes two finite shared resources the pre-plane policies
//! modeled as free: the node's Grace host pool and the GPU's single C2C
//! link. The planner folds both in:
//! - an offloaded class is only a candidate while the node's pool can
//!   park its spill (`Fleet::host_fits`) — admission is gated on host
//!   headroom, not just slice memory;
//! - with `c2c_contention` on, an offloaded placement's direct-access
//!   rate is divided by the number of offloaders time-sharing the GPU's
//!   link (the newcomer included), extending the cost tables with a
//!   per-GPU contention level (`cost_at_shared`). Within one
//!   `(profile, occupancy, share)` class all slots still tie, so the
//!   indexed walk enumerates one candidate per class per share level
//!   (`Fleet::first_open_fitting_per_share`) and stays provably equal to
//!   the slot scan. With contention off — or with no co-offloaders —
//!   every share is 1 and the pre-plane costs are reproduced bit-for-bit.
//!
//! ## The indexed hot path
//!
//! A placement decision reduces to a walk over at most
//! `NUM_PROFILES × batch` co-residency classes against the fleet's
//! per-(profile, occupancy) open-slot index (`Fleet::first_open_fitting`),
//! instead of a full `gpus × slots` scan:
//! - first-fit: the minimum `(gpu, slot)` among each feasible class's
//!   first fitting slot;
//! - best-fit: fold the class-firsts with the scan's strict preference
//!   (smaller SMs, then higher occupancy, then lower `(gpu, slot)`);
//! - offload-aware: fold the per-class candidates in `(gpu, slot)` order
//!   with the same (reward, SMs) preference the naive scan applies per
//!   slot — provably the same choice, because all slots of a class tie.
//!
//! `Planner::place_scan` keeps the naive full scan as the
//! differential-test oracle: for any fleet state both paths return the
//! identical `(gpu, slot, cost)`.
//!
//! The `Planner` memoizes per-(app, profile, offload, occupancy) costs in
//! a dense `[AppId::COUNT × NUM_PROFILES × 2 × batch]` array (no hashing
//! on the hot path), per-(app, offload) admissibility bitmasks — the
//! precomputed profile preference table; admissibility is occupancy-
//! independent, co-residency only stretches the runtime — and
//! per-(app, profile, occupancy) rewards at the policy's α (see
//! `benches/placement.rs`).

use super::estimate::CostSource;
use super::fleet::{Fleet, MAX_BATCH};
use super::hostmem::gib_to_bytes;
use super::power::{self, PowerView};
use super::telemetry::{Counter, NullSink, Sink};
use crate::gpu::nvlink::{Dir, NvlinkModel};
use crate::gpu::{pipelines::ALL_PIPELINES, GpuSpec, GpuUsage, PowerModel};
use crate::mig::profile::{GiProfile, ProfileId, ALL_PROFILES, NUM_PROFILES};
use crate::offload::OffloadPlan;
use crate::reward::{reward_energy, ConfigEval, GpuTotals};
use crate::sharing::scheme::{partitions, Scheme};
use crate::sharing::ContextModel;
use crate::workload::{apps, AppId, AppModel, ExecEnv};

/// The dispatch policy of the serving layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    FirstFit,
    BestFit,
    /// Reward-maximizing admission with offloading, α in centi-units.
    OffloadAware { alpha_centi: u32 },
}

impl PolicyKind {
    /// Parse a policy name. `offload-aware` takes an optional α suffix
    /// (`offload-aware:0.25`); bare `offload-aware` defaults to α=0.10.
    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s {
            "first-fit" => return Some(PolicyKind::FirstFit),
            "best-fit" => return Some(PolicyKind::BestFit),
            "offload-aware" => return Some(PolicyKind::OffloadAware { alpha_centi: 10 }),
            _ => {}
        }
        let alpha: f64 = s.strip_prefix("offload-aware:")?.parse().ok()?;
        if !alpha.is_finite() || !(0.0..=100.0).contains(&alpha) {
            return None;
        }
        Some(PolicyKind::OffloadAware {
            alpha_centi: (alpha * 100.0).round() as u32,
        })
    }

    /// Canonical name; `parse(label())` round-trips.
    pub fn label(&self) -> String {
        match self {
            PolicyKind::FirstFit => "first-fit".into(),
            PolicyKind::BestFit => "best-fit".into(),
            PolicyKind::OffloadAware { alpha_centi } => {
                format!("offload-aware:{:.2}", *alpha_centi as f64 / 100.0)
            }
        }
    }

    pub fn allows_offload(&self) -> bool {
        matches!(self, PolicyKind::OffloadAware { .. })
    }
}

/// The modelled cost of running one app on one profile at one co-residency
/// (possibly with offloading): service time plus the average activity
/// rates the fleet power model integrates while the job runs.
#[derive(Debug, Clone, Copy)]
pub struct PlacementCost {
    pub runtime_s: f64,
    /// Resident footprint on the instance (GiB), after any offloading.
    /// Occupancy-independent: the offload plan is sized against the solo
    /// cap, co-residency only changes how fast the data is consumed.
    pub resident_gib: f64,
    pub offloaded: bool,
    /// Spilled data parked in the node's Grace host pool while the job
    /// runs (GiB; 0.0 when not offloaded). Occupancy- and
    /// contention-independent, like the resident footprint.
    pub host_gib: f64,
    /// SMs the MPS share model allocates to this job
    /// (`prof.sms / occupancy`, min 1) — the per-job share the
    /// energy-per-job term attributes, not the whole slice.
    pub sms_share: u32,
    /// Average achieved occupancy on the instance (reward input).
    pub occupancy: f64,
    /// Average per-pipeline FLOP rates while running (TFLOP/s).
    pub flop_tflops: [f64; 5],
    /// Average HBM traffic while running (TB/s).
    pub hbm_tbs: f64,
    /// Average C2C traffic while running (TB/s).
    pub c2c_tbs: f64,
}

/// One placement decision under the fleet power plane: where the job
/// goes, what the power tracker integrates, and what the scheduler
/// charges.
#[derive(Debug, Clone, Copy)]
pub struct Placement {
    pub gpu: usize,
    pub slot: usize,
    /// Level-0 (boost-clock) cost at the admission's occupancy and link
    /// share — the activity rates the power tracker integrates and the
    /// draw the node budget charges. The governor's input is *requested*
    /// demand; `PowerModel::demand_w` applies the clock scaling itself.
    pub base: PlacementCost,
    /// The cost priced at the GPU's post-join throttle level — what the
    /// scheduler charges as service time. Bit-identical to `base` at
    /// level 0 (and always, with the plane off).
    pub priced: PlacementCost,
    /// The discrete throttle level the GPU settles at once the job joins.
    pub level: u32,
    /// The cost-class key of the decision — profile, post-join occupancy,
    /// and C2C link share — what the online estimator predicts and learns
    /// from. The share is normalized to 1 when the cost is not offloaded
    /// (such costs are share-independent, and the indexed walk and the
    /// naive scan legitimately reach them with different raw shares).
    pub pid: ProfileId,
    pub occ: u32,
    pub share: u32,
}

/// Total activity of one model run — per-pipeline FLOPs, HBM bytes, C2C
/// bytes — accumulated in phase → kernel → pipeline order. The single
/// aggregation behind both the placement-cost rates and the full-GPU
/// energy normalizer, so the two can never drift.
fn activity_totals(model: &AppModel) -> ([f64; 5], f64, f64) {
    let mut flops = [0.0f64; 5];
    let mut hbm_bytes = 0.0;
    let mut c2c_bytes = 0.0;
    for ph in &model.phases {
        let reps = ph.repeats as f64;
        for k in &ph.kernels {
            hbm_bytes += reps * k.hbm_bytes;
            c2c_bytes += reps * k.c2c_bytes;
            for p in ALL_PIPELINES {
                flops[p.index()] += reps * k.flops * k.mix.frac(p);
            }
        }
    }
    (flops, hbm_bytes, c2c_bytes)
}

/// Cost evaluator + cache shared by all policies. All memo tables are
/// dense arrays indexed by `AppId::index` / `ProfileId::index` /
/// occupancy − 1 — the hot path never hashes.
pub struct Planner {
    spec: GpuSpec,
    nvlink: NvlinkModel,
    ctx_gib: f64,
    scale: f64,
    /// Max co-resident jobs per slot this planner sizes its tables for
    /// (must match the fleet it plans over).
    batch: u32,
    /// Per-co-runner compute-pipeline interference under shared-GI MPS
    /// co-residency, pulled from the `Scheme::MigSharedGi` partition model
    /// — the co-run characterization feeding the cluster cost model.
    shared_interference: f64,
    /// Time-share the per-GPU C2C link across co-offloading residents: an
    /// offloaded placement sharing the link with `n − 1` co-offloaders
    /// sees `1/n` of the direct-access rate. Off = the pre-plane private
    /// link (every share is 1, bit-identical to the unextended planner).
    c2c_contention: bool,
    /// Weight of the energy-per-job reward term (0.0 = the paper's pure
    /// §VI-B reward, bit-identical to the unextended planner).
    energy_weight: f64,
    /// Power model backing the energy-per-job reward term.
    power_model: PowerModel,
    /// Outer `Option` = "computed?"; inner = the (possibly impossible)
    /// placement cost. `[app × profile × offload × occupancy]`.
    cost_cache: Vec<Option<Option<PlacementCost>>>,
    /// Contended offload costs at link share `s ≥ 2`:
    /// `cost_shared[s − 2]` mirrors the `allow_offload = true` plane of
    /// `cost_cache` (`[app × profile × occupancy]`), allocated lazily per
    /// share level actually observed. Non-offloaded costs never land
    /// here — they are share-independent by construction.
    cost_shared: Vec<Option<Vec<Option<Option<PlacementCost>>>>>,
    /// Throttle-priced costs at discrete clock level `l ≥ 1`:
    /// `cost_throttled[l − 1]` mirrors the full `cost_cache` shape
    /// (`[app × profile × offload × occupancy]`, link share 1), allocated
    /// lazily per level actually reached. Level 0 *is* `cost_cache` — the
    /// pre-plane bits, untouched. Contended (share ≥ 2) throttled costs
    /// are recomputed on demand, like `reward_shared` does.
    cost_throttled: Vec<Option<Vec<Option<Option<PlacementCost>>>>>,
    /// Admissible-profile bitmask per `[app × offload]` — the per-app
    /// profile preference table (bit i ⇔ `ALL_PROFILES[i]` can host).
    /// Occupancy-independent: co-residency stretches the runtime but
    /// never flips feasibility.
    admissible: [Option<u8>; AppId::COUNT * 2],
    /// Whole-GPU runtime per app (the P_GPU reward basis).
    full_runtime: [Option<f64>; AppId::COUNT],
    /// §VI-B rewards `[app × profile × occupancy]` at `reward_alpha_centi`
    /// (link share 1 only; contended rewards are recomputed on demand —
    /// same pure function, so the bits agree either way).
    reward_cache: Vec<Option<f64>>,
    reward_alpha_centi: Option<u32>,
    /// Full-GPU energy per job (the energy-term normalizer), memoized.
    full_energy: [Option<f64>; AppId::COUNT],
    /// Direct (unscaled) footprint per app, for reconfiguration sizing —
    /// precomputed so the dispatch hot path never rebuilds app models.
    footprint: [f64; AppId::COUNT],
    /// Reusable candidate buffer for the offload-aware walk
    /// (`(gpu, slot, profile, occupancy, link share)`).
    cand_scratch: Vec<(usize, usize, ProfileId, u8, u32)>,
    /// Reusable per-share class probe buffer
    /// (`Fleet::first_open_fitting_per_share` output).
    share_scratch: Vec<(usize, usize, u32)>,
}

impl Planner {
    /// A planner for the classic one-job-per-slot system (`batch = 1`).
    pub fn new(workload_scale: f64) -> Planner {
        Planner::with_batch(workload_scale, 1)
    }

    /// A planner sized for slots hosting up to `batch` co-resident jobs,
    /// with the pre-plane resource model (private C2C links, no energy
    /// term).
    pub fn with_batch(workload_scale: f64, batch: u32) -> Planner {
        Planner::with_opts(workload_scale, batch, false, 0.0)
    }

    /// A fully-configured planner: `c2c_contention` time-shares each
    /// GPU's C2C link across its co-offloading residents, and
    /// `energy_weight > 0` folds a normalized energy-per-job term into
    /// the offload-aware reward. `(false, 0.0)` reproduces the pre-plane
    /// planner bit-for-bit.
    pub fn with_opts(
        workload_scale: f64,
        batch: u32,
        c2c_contention: bool,
        energy_weight: f64,
    ) -> Planner {
        assert!(workload_scale > 0.0);
        assert!(
            energy_weight >= 0.0 && energy_weight.is_finite(),
            "energy weight must be finite and non-negative"
        );
        assert!(
            (1..=MAX_BATCH).contains(&batch),
            "per-slot batch must be 1..={MAX_BATCH}, got {batch}"
        );
        let mut footprint = [0.0f64; AppId::COUNT];
        for app in apps::all() {
            footprint[app.index()] = apps::model(app).footprint_gib;
        }
        let spec = GpuSpec::gh_h100_96gb();
        let shared_interference = partitions(&Scheme::MigSharedGi { copies: 2 }, &spec)
            .expect("MigSharedGi partition model")[0]
            .interference;
        let b = batch as usize;
        Planner {
            spec,
            nvlink: NvlinkModel::default(),
            ctx_gib: ContextModel::default().mig_per_process_gib,
            scale: workload_scale,
            batch,
            shared_interference,
            c2c_contention,
            energy_weight,
            power_model: PowerModel::h100(),
            cost_cache: vec![None; AppId::COUNT * NUM_PROFILES * 2 * b],
            cost_shared: Vec::new(),
            cost_throttled: Vec::new(),
            admissible: [None; AppId::COUNT * 2],
            full_runtime: [None; AppId::COUNT],
            reward_cache: vec![None; AppId::COUNT * NUM_PROFILES * b],
            reward_alpha_centi: None,
            full_energy: [None; AppId::COUNT],
            footprint,
            cand_scratch: Vec::new(),
            share_scratch: Vec::new(),
        }
    }

    /// Whether this planner time-shares C2C links across co-offloaders.
    pub fn c2c_contention(&self) -> bool {
        self.c2c_contention
    }

    pub fn ctx_gib(&self) -> f64 {
        self.ctx_gib
    }

    /// Max co-resident jobs per slot this planner is sized for.
    pub fn batch(&self) -> u32 {
        self.batch
    }

    /// Direct memory footprint of `app` (GiB) — the reconfiguration-sizing
    /// input.
    pub fn footprint_gib(&self, app: AppId) -> f64 {
        self.footprint[app.index()]
    }

    /// The workload scale factor this planner models runs at.
    pub fn scale(&self) -> f64 {
        self.scale
    }

    /// The `MigSharedGi` co-run interference constant per extra
    /// co-resident — the structural signal the online estimator's cold
    /// extrapolation reuses (§III-C probe methodology).
    pub fn shared_interference(&self) -> f64 {
        self.shared_interference
    }

    #[inline]
    fn cost_idx(&self, app: AppId, profile: ProfileId, allow_offload: bool, occ: u32) -> usize {
        ((app.index() * NUM_PROFILES + profile.index()) * 2 + allow_offload as usize)
            * self.batch as usize
            + (occ as usize - 1)
    }

    /// Cost of running `app` alone on `profile` — the unbatched
    /// (occupancy 1) class, which is also the admissibility gate.
    pub fn cost(
        &mut self,
        app: AppId,
        profile: ProfileId,
        allow_offload: bool,
    ) -> Option<PlacementCost> {
        self.cost_at(app, profile, allow_offload, 1)
    }

    /// Cost of running `app` on `profile` with `occ` co-residents in
    /// total (itself included; `1..=batch`). `allow_offload = false`
    /// returns `None` unless the footprint fits directly; `true`
    /// additionally tries an `OffloadPlan` (which may still fail: ≥25%
    /// must stay resident). Memoized.
    pub fn cost_at(
        &mut self,
        app: AppId,
        profile: ProfileId,
        allow_offload: bool,
        occ: u32,
    ) -> Option<PlacementCost> {
        debug_assert!((1..=self.batch).contains(&occ));
        let i = self.cost_idx(app, profile, allow_offload, occ);
        if let Some(c) = self.cost_cache[i] {
            return c;
        }
        let c = self.compute_cost(app, profile, allow_offload, occ, 1, 1.0);
        self.cost_cache[i] = Some(c);
        c
    }

    /// `cost_at` with the job's C2C link shared `share` ways (itself
    /// included). Only an *offloaded* placement depends on the share —
    /// its direct-access rate is divided by `share` — so non-offloaded
    /// costs are returned from the share-1 table unchanged, and
    /// `share = 1` is the literal `cost_at`. Contended costs are
    /// memoized per share level.
    pub fn cost_at_shared(
        &mut self,
        app: AppId,
        profile: ProfileId,
        allow_offload: bool,
        occ: u32,
        share: u32,
    ) -> Option<PlacementCost> {
        let base = self.cost_at(app, profile, allow_offload, occ)?;
        if share <= 1 || !base.offloaded {
            return Some(base);
        }
        let level = (share - 2) as usize;
        if self.cost_shared.len() <= level {
            self.cost_shared.resize(level + 1, None);
        }
        let size = AppId::COUNT * NUM_PROFILES * self.batch as usize;
        let table = self.cost_shared[level].get_or_insert_with(|| vec![None; size]);
        let i = (app.index() * NUM_PROFILES + profile.index()) * self.batch as usize
            + (occ as usize - 1);
        if let Some(c) = table[i] {
            return c;
        }
        let c = self.compute_cost(app, profile, allow_offload, occ, share, 1.0);
        self.cost_shared[level].as_mut().unwrap()[i] = Some(c);
        c
    }

    /// SM clock fraction at discrete throttle level `level` (1.0 at
    /// level 0 — the boost clock).
    fn clock_frac_at(&self, level: u32) -> f64 {
        power::clock_at_level(&self.spec, level) / self.spec.clock_max_mhz
    }

    /// `cost_at_shared` priced at discrete throttle `level`: the SM clock
    /// drops to the ladder step, which stretches compute-bound work
    /// proportionally while memory-bound work barely notices (the
    /// Fig. 7a/7b split — `ExecEnv::clock_frac` scales only the compute
    /// pipelines). Level 0 returns the unthrottled tables *unchanged* —
    /// the exact pre-plane bits. Throttled share-1 costs are memoized per
    /// level; contended (share ≥ 2) throttled offloads are recomputed on
    /// demand from the same pure function, so cache hits and fresh
    /// computations agree bit-for-bit. Admissibility (and the memory /
    /// offload plan) is level-independent: throttling stretches time,
    /// never footprints.
    pub fn cost_at_throttled(
        &mut self,
        app: AppId,
        profile: ProfileId,
        allow_offload: bool,
        occ: u32,
        share: u32,
        level: u32,
    ) -> Option<PlacementCost> {
        if level == 0 {
            return self.cost_at_shared(app, profile, allow_offload, occ, share);
        }
        let base = self.cost_at(app, profile, allow_offload, occ)?;
        let eff_share = if base.offloaded { share } else { 1 };
        if eff_share > 1 {
            return self.compute_cost(
                app,
                profile,
                allow_offload,
                occ,
                eff_share,
                self.clock_frac_at(level),
            );
        }
        let l = (level - 1) as usize;
        if self.cost_throttled.len() <= l {
            self.cost_throttled.resize(l + 1, None);
        }
        let size = AppId::COUNT * NUM_PROFILES * 2 * self.batch as usize;
        let i = self.cost_idx(app, profile, allow_offload, occ);
        let table = self.cost_throttled[l].get_or_insert_with(|| vec![None; size]);
        if let Some(c) = table[i] {
            return c;
        }
        let c = self.compute_cost(app, profile, allow_offload, occ, 1, self.clock_frac_at(level));
        self.cost_throttled[l].as_mut().unwrap()[i] = Some(c);
        c
    }

    fn compute_cost(
        &self,
        app: AppId,
        profile: ProfileId,
        allow_offload: bool,
        occ: u32,
        share: u32,
        clock_frac: f64,
    ) -> Option<PlacementCost> {
        let prof = GiProfile::get(profile);
        let model = apps::model(app).scaled(self.scale);
        let cap = prof.mem_gib - self.ctx_gib;
        let plan = if model.footprint_gib <= cap {
            None
        } else if allow_offload {
            match OffloadPlan::plan(&model, cap) {
                Ok(p) => Some(p),
                Err(_) => return None,
            }
        } else {
            return None;
        };
        let offloaded = plan.as_ref().map(|p| p.spilled_gib > 0.0).unwrap_or(false);
        let resident_gib = plan
            .as_ref()
            .map(|p| p.effective_footprint_gib())
            .unwrap_or(model.footprint_gib);
        let run_model = plan.as_ref().map(|p| p.apply(&model)).unwrap_or(model);
        // MPS-within-MIG co-residency (`occ` clients on the slice): equal
        // SM share, equal share of the slice's bandwidth pool, and the
        // per-co-runner compute interference of shared-GI co-runs. The
        // C2C direct rate follows the SMs in flight (Table IVb saturation
        // curve), so it shrinks with the SM share automatically; with the
        // host-memory plane's link contention on, it is additionally
        // divided by the number of offloaders time-sharing the GPU's one
        // C2C link (`share`, this job included — equal time share). At
        // occ = 1, share = 1 every term reduces to the unbatched,
        // private-link environment exactly (`share = 1` skips the divide
        // so not even a rounding bit can differ).
        let sms = (prof.sms / occ).max(1);
        let mut c2c_bw_gibs = self.nvlink.direct_bw_gibs(sms, Dir::H2D);
        if share > 1 {
            c2c_bw_gibs /= share as f64;
        }
        let env = ExecEnv {
            sms,
            clock_frac,
            bw_gibs: prof.mem_bw_gibs / occ as f64,
            c2c_bw_gibs,
            interference: 1.0 + self.shared_interference * (occ as f64 - 1.0),
            time_share: 1.0,
        };
        let runtime_s =
            run_model.runtime_quiet_s(&self.spec, &env) + run_model.startup_s * self.scale;
        if runtime_s <= 0.0 {
            return None;
        }
        // Average activity rates for the fleet energy model.
        let (mut flop_tflops, hbm_bytes, c2c_bytes) = activity_totals(&run_model);
        for f in &mut flop_tflops {
            *f /= runtime_s * 1e12;
        }
        Some(PlacementCost {
            runtime_s,
            resident_gib,
            offloaded,
            host_gib: plan.as_ref().map(|p| p.spilled_gib).unwrap_or(0.0),
            sms_share: sms,
            occupancy: run_model.avg_occupancy_quiet(&self.spec, &env),
            flop_tflops,
            hbm_tbs: hbm_bytes / runtime_s / 1e12,
            c2c_tbs: c2c_bytes / runtime_s / 1e12,
        })
    }

    /// Bitmask of profiles that can host `app` (bit i ⇔ `ALL_PROFILES[i]`),
    /// memoized per (app, offload) — the precomputed preference table the
    /// indexed policies walk. Occupancy-independent.
    fn admissible_mask(&mut self, app: AppId, allow_offload: bool) -> u8 {
        let i = app.index() * 2 + allow_offload as usize;
        if let Some(m) = self.admissible[i] {
            return m;
        }
        let mut m = 0u8;
        for pid in ALL_PROFILES {
            if self.cost(app, pid, allow_offload).is_some() {
                m |= 1 << pid.index();
            }
        }
        self.admissible[i] = Some(m);
        m
    }

    /// Runtime of `app` on the whole GPU (the P_GPU reward basis).
    pub fn full_gpu_runtime_s(&mut self, app: AppId) -> f64 {
        if let Some(t) = self.full_runtime[app.index()] {
            return t;
        }
        let model = apps::model(app).scaled(self.scale);
        let env = ExecEnv {
            sms: self.spec.sms,
            clock_frac: 1.0,
            bw_gibs: self.spec.mem_bw_gibs,
            c2c_bw_gibs: self.nvlink.direct_both_cap_gibs,
            interference: 1.0,
            time_share: 1.0,
        };
        let t = model.runtime_quiet_s(&self.spec, &env) + model.startup_s * self.scale;
        self.full_runtime[app.index()] = Some(t);
        t
    }

    /// Modeled energy of one `app` run on the whole GPU (J) — the
    /// normalizer of the energy-per-job reward term. Memoized.
    fn full_gpu_energy_j(&mut self, app: AppId) -> f64 {
        if let Some(e) = self.full_energy[app.index()] {
            return e;
        }
        let t = self.full_gpu_runtime_s(app).max(1e-9);
        let model = apps::model(app).scaled(self.scale);
        let (mut flops, hbm_bytes, c2c_bytes) = activity_totals(&model);
        for f in &mut flops {
            *f /= t * 1e12;
        }
        let mut u = GpuUsage {
            context_active: true,
            sm_busy_frac: 1.0,
            hbm_rate_tbs: hbm_bytes / t / 1e12,
            c2c_rate_tbs: c2c_bytes / t / 1e12,
            ..GpuUsage::default()
        };
        u.flop_rate_tflops = flops;
        let e = self.power_model.reported_w(&self.spec, &u, self.spec.clock_max_mhz) * t;
        self.full_energy[app.index()] = Some(e);
        e
    }

    /// Modeled energy of one job at placement cost `c` (J): the power
    /// demand its activity rates put on the GPU, integrated over its
    /// (contention-stretched) runtime. The SM term charges only the
    /// job's MPS share (`c.sms_share`), so co-residents split the
    /// slice's SM energy instead of each being billed the whole slice.
    fn job_energy_j(&self, c: &PlacementCost) -> f64 {
        let mut u = GpuUsage {
            context_active: true,
            sm_busy_frac: c.sms_share as f64 / self.spec.sms as f64,
            hbm_rate_tbs: c.hbm_tbs,
            c2c_rate_tbs: c.c2c_tbs,
            ..GpuUsage::default()
        };
        u.flop_rate_tflops = c.flop_tflops;
        self.power_model.reported_w(&self.spec, &u, self.spec.clock_max_mhz) * c.runtime_s
    }

    /// §VI-B reward of running `app` on `profile` at cost `c`, with the
    /// planner's energy-per-job term folded in at `energy_weight` (a
    /// weight of 0.0 skips the term entirely — the paper's pure reward,
    /// bit-for-bit).
    pub fn reward_of(
        &mut self,
        app: AppId,
        profile: ProfileId,
        c: &PlacementCost,
        alpha: f64,
    ) -> f64 {
        let prof = GiProfile::get(profile);
        let p_gpu = 1.0 / self.full_gpu_runtime_s(app).max(1e-9);
        let eval = ConfigEval {
            config: prof.name.to_string(),
            perf: 1.0 / c.runtime_s.max(1e-9),
            occupancy: c.occupancy,
            sms: prof.sms,
            mem_instance_gib: prof.mem_gib,
            mem_app_gib: c.resident_gib + self.ctx_gib,
        };
        let totals = GpuTotals {
            sms: self.spec.sms,
            mem_gib: self.spec.mem_usable_gib,
            perf_full_gpu: p_gpu,
        };
        let energy_rel = if self.energy_weight != 0.0 {
            self.job_energy_j(c) / self.full_gpu_energy_j(app).max(1e-9)
        } else {
            0.0
        };
        reward_energy(&eval, &totals, alpha, self.energy_weight, energy_rel).reward
    }

    /// `reward_of` memoized per (app, profile, occupancy) at a fixed α —
    /// the value depends on nothing else, so the offload-aware walk reads
    /// a dense table. Switching α (a different policy instance) flushes
    /// the table.
    fn cached_reward(
        &mut self,
        app: AppId,
        profile: ProfileId,
        occ: u32,
        alpha_centi: u32,
        c: &PlacementCost,
    ) -> f64 {
        if self.reward_alpha_centi != Some(alpha_centi) {
            self.reward_cache.iter_mut().for_each(|r| *r = None);
            self.reward_alpha_centi = Some(alpha_centi);
        }
        let i = (app.index() * NUM_PROFILES + profile.index()) * self.batch as usize
            + (occ as usize - 1);
        if let Some(r) = self.reward_cache[i] {
            return r;
        }
        let r = self.reward_of(app, profile, c, alpha_centi as f64 / 100.0);
        self.reward_cache[i] = Some(r);
        r
    }

    /// `cached_reward` for an arbitrary link share: non-offloaded costs
    /// and share-1 offloads read the dense cache; contended offloads are
    /// recomputed on demand — `reward_of` is a pure function of
    /// `(app, profile, c, α)`, so cache hits and fresh computations agree
    /// bit-for-bit and the indexed walk and the naive scan can mix them
    /// freely.
    fn reward_shared(
        &mut self,
        app: AppId,
        profile: ProfileId,
        occ: u32,
        share: u32,
        alpha_centi: u32,
        c: &PlacementCost,
    ) -> f64 {
        if share <= 1 || !c.offloaded {
            return self.cached_reward(app, profile, occ, alpha_centi, c);
        }
        self.reward_of(app, profile, c, alpha_centi as f64 / 100.0)
    }

    /// `reward_shared` at a throttle level: level 0 reads the cached
    /// tables (the pre-plane bits); a throttled candidate's reward is
    /// recomputed from its throttle-priced cost — `reward_of` is pure in
    /// `(app, profile, c, α)`, so the indexed walk and the naive scan
    /// agree bit-for-bit however they got here.
    fn reward_throttled(
        &mut self,
        app: AppId,
        profile: ProfileId,
        occ: u32,
        share: u32,
        level: u32,
        alpha_centi: u32,
        c: &PlacementCost,
    ) -> f64 {
        if level == 0 {
            return self.reward_shared(app, profile, occ, share, alpha_centi, c);
        }
        self.reward_of(app, profile, c, alpha_centi as f64 / 100.0)
    }

    /// The throttle level the candidate GPU settles at once this job
    /// joins: its current boost-rate usage plus the newcomer's level-0
    /// activity (and, when the seat is a fresh slot, the slot's SMs —
    /// joining an occupied slot adds no busy SMs, the slot already
    /// counts). A pure function of the power view, so both serve modes
    /// compute identical levels from their bit-identical usages.
    fn prospective_level(
        &self,
        pv: &PowerView,
        gpu: usize,
        add_sms: u32,
        c: &PlacementCost,
    ) -> u32 {
        let mut u = pv.usages[gpu];
        u.context_active = true;
        u.sm_busy_frac += add_sms as f64 / self.spec.sms as f64;
        for (i, f) in c.flop_tflops.iter().enumerate() {
            u.flop_rate_tflops[i] += *f;
        }
        u.hbm_rate_tbs += c.hbm_tbs;
        u.c2c_rate_tbs += c.c2c_tbs;
        power::equilibrium_level(&self.spec, &self.power_model, &u, pv.gpu_cap_w)
    }

    /// Activity draw (mW) a placement at cost `c` would charge against
    /// the node power budget — `power::job_draw_mw` over this planner's
    /// model.
    pub fn draw_mw(&self, c: &PlacementCost) -> u64 {
        power::job_draw_mw(&self.power_model, c)
    }

    /// The cheapest admissible class's node-budget draw for `app` (mW;
    /// `u64::MAX` when nothing admits it). Pure in the cost tables, so
    /// the answer is mode-invariant — the node power gate's starvation
    /// predicate and the reconfiguration gate both key on it.
    pub fn min_job_draw_mw(&mut self, app: AppId, allow_offload: bool) -> u64 {
        let mut min = u64::MAX;
        for pid in ALL_PROFILES {
            if let Some(c) = self.cost(app, pid, allow_offload) {
                min = min.min(power::job_draw_mw(&self.power_model, &c));
            }
        }
        min
    }

    /// Finish a placement decision: derive the GPU's post-join throttle
    /// level and the throttle-priced cost (`== base` at level 0 and
    /// whenever the plane is off).
    #[allow(clippy::too_many_arguments)]
    fn priced(
        &mut self,
        pv: Option<&PowerView>,
        app: AppId,
        g: usize,
        s: usize,
        pid: ProfileId,
        occ: u32,
        share: u32,
        allow_offload: bool,
        base: PlacementCost,
    ) -> Placement {
        let level = match pv {
            None => 0,
            Some(pv) => {
                let add_sms = if occ == 1 { GiProfile::get(pid).sms } else { 0 };
                self.prospective_level(pv, g, add_sms, &base)
            }
        };
        let priced = if level == 0 {
            base
        } else {
            self.cost_at_throttled(app, pid, allow_offload, occ, share, level)
                .expect("admissibility is level-independent")
        };
        Placement {
            gpu: g,
            slot: s,
            base,
            priced,
            level,
            pid,
            occ,
            share: if base.offloaded { share } else { 1 },
        }
    }

    /// Pick a slot seat for `app` under `policy`, via the fleet's
    /// per-(profile, occupancy) open index: a walk over
    /// ≤ `NUM_PROFILES × batch` co-residency classes. Returns
    /// `(gpu, slot, cost)` with the cost at the occupancy the job would
    /// run at. Deterministic, and bit-identical to `place_scan`.
    pub fn place(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        policy: PolicyKind,
    ) -> Option<(usize, usize, PlacementCost)> {
        self.place_traced(fleet, app, policy, &mut NullSink)
    }

    /// `place` with telemetry hooks: counts walk steps (candidate
    /// classes visited) and host-pool offload gatings into `sink`. With
    /// the inert `NullSink` every hook is a compile-time `false` branch,
    /// so `place` pays nothing for the instrumentation. Runs with the
    /// power plane inactive (`pv = None`) — the exact pre-plane walk.
    pub fn place_traced<S: Sink>(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        policy: PolicyKind,
        sink: &mut S,
    ) -> Option<(usize, usize, PlacementCost)> {
        self.place_powered_traced(fleet, app, policy, None, sink)
            .map(|p| (p.gpu, p.slot, p.priced))
    }

    /// The full placement decision under the fleet power plane. With
    /// `pv = None` this is byte-for-byte the pre-plane walk (level 0
    /// everywhere, `priced == base`). With a live [`PowerView`]:
    /// - a finite node budget gates every candidate whose admission draw
    ///   (`job_draw_mw` of its level-0 cost — exactly what `on_start`
    ///   would charge) exceeds the remaining headroom;
    /// - the offload-aware walk enumerates one candidate per
    ///   (class, GPU) — per-GPU throttle levels break the fleet-wide
    ///   class tie the unpowered walk exploits — and ranks each by the
    ///   reward of its *throttle-priced* cost at the GPU's post-join
    ///   level, so a hot board genuinely competes worse;
    /// - first-fit/best-fit stay structural (the paper's baselines don't
    ///   chase power), but their final cost is priced at the chosen
    ///   GPU's post-join level — the service time the fleet will see.
    pub fn place_powered_traced<S: Sink>(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        policy: PolicyKind,
        pv: Option<&PowerView>,
        sink: &mut S,
    ) -> Option<Placement> {
        self.place_sourced_traced(fleet, app, policy, pv, CostSource::Oracle, sink)
    }

    /// [`Self::place_powered_traced`] with an explicit [`CostSource`]:
    /// under `CostSource::Estimated`, the offload-aware ranking swaps
    /// each candidate's *oracle* service time for the estimator's
    /// prediction of its cost class before computing the reward — the
    /// decision runs on beliefs while admissibility (footprints, offload
    /// plans, host pool, power gates) and the returned [`Placement`]'s
    /// scheduled costs stay oracle physics. The estimator is
    /// clock-level-blind: a throttled candidate keeps its level-0
    /// estimate (the oracle-priced activity rates still charge the power
    /// plane truthfully). First-fit and best-fit never consult runtimes,
    /// so their decisions are source-invariant by construction — their
    /// regret is the estimator's error on seats the oracle chose anyway.
    pub fn place_sourced_traced<S: Sink>(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        policy: PolicyKind,
        pv: Option<&PowerView>,
        src: CostSource,
        sink: &mut S,
    ) -> Option<Placement> {
        debug_assert_eq!(fleet.batch(), self.batch, "planner/fleet batch mismatch");
        let mut steps: u64 = 0;
        let kmax = fleet.batch() as usize;
        let node_headroom = pv.map_or(u64::MAX, |v| v.node_headroom_mw);
        let choice = match policy {
            PolicyKind::FirstFit => {
                let mask = self.admissible_mask(app, false);
                let mut best: Option<(usize, usize, ProfileId, u32)> = None;
                for pid in ALL_PROFILES {
                    if mask & (1 << pid.index()) == 0 {
                        continue;
                    }
                    let need = self.cost(app, pid, false).unwrap().resident_gib + self.ctx_gib;
                    for m in 0..kmax {
                        if S::ENABLED {
                            steps += 1;
                        }
                        if node_headroom != u64::MAX {
                            let c = self.cost_at(app, pid, false, m as u32 + 1).unwrap();
                            if self.draw_mw(&c) > node_headroom {
                                if S::ENABLED {
                                    sink.count(Counter::PowerGated, 1);
                                }
                                continue;
                            }
                        }
                        if let Some((g, s)) = fleet.first_open_fitting(pid, m, need) {
                            if best
                                .map(|(bg, bs, _, _)| (g, s) < (bg, bs))
                                .unwrap_or(true)
                            {
                                best = Some((g, s, pid, m as u32 + 1));
                            }
                        }
                    }
                }
                best.map(|(g, s, pid, occ)| {
                    let base = self.cost_at(app, pid, false, occ).unwrap();
                    self.priced(pv, app, g, s, pid, occ, 1, false, base)
                })
            }
            PolicyKind::BestFit => {
                let mask = self.admissible_mask(app, false);
                // ALL_PROFILES ascends by SMs; within a profile prefer the
                // most occupied open slot (densest packing keeps empty
                // slots free), then the lowest (gpu, slot). Folding the
                // class-firsts with the scan's strict preference keeps the
                // two paths identical even if two profiles tie on SMs.
                let mut best: Option<(u32, usize, usize, usize, ProfileId)> = None;
                for pid in ALL_PROFILES {
                    if mask & (1 << pid.index()) == 0 {
                        continue;
                    }
                    let need = self.cost(app, pid, false).unwrap().resident_gib + self.ctx_gib;
                    let sms = GiProfile::get(pid).sms;
                    for m in 0..kmax {
                        if S::ENABLED {
                            steps += 1;
                        }
                        if node_headroom != u64::MAX {
                            let c = self.cost_at(app, pid, false, m as u32 + 1).unwrap();
                            if self.draw_mw(&c) > node_headroom {
                                if S::ENABLED {
                                    sink.count(Counter::PowerGated, 1);
                                }
                                continue;
                            }
                        }
                        if let Some((g, s)) = fleet.first_open_fitting(pid, m, need) {
                            let better = match &best {
                                None => true,
                                Some((bsms, bm, bg, bs, _)) => {
                                    sms < *bsms
                                        || (sms == *bsms
                                            && (m > *bm
                                                || (m == *bm && (g, s) < (*bg, *bs))))
                                }
                            };
                            if better {
                                best = Some((sms, m, g, s, pid));
                            }
                        }
                    }
                }
                best.map(|(_, m, g, s, pid)| {
                    let occ = m as u32 + 1;
                    let base = self.cost_at(app, pid, false, occ).unwrap();
                    self.priced(pv, app, g, s, pid, occ, 1, false, base)
                })
            }
            PolicyKind::OffloadAware { alpha_centi } => {
                // One candidate per (profile, occupancy) class with a
                // fitting open slot, at the class's first (gpu, slot) —
                // refined per C2C link-share level when contention is on
                // and the class offloads, because then slots of one class
                // only tie within one share level; refined further to one
                // candidate per (class, GPU) when the power plane is
                // live, because per-GPU throttle levels (and shares)
                // break fleet-wide class ties. Folding the candidates
                // in (gpu, slot) order with the per-slot preference of
                // the naive scan reproduces its choice exactly: within a
                // candidate's tie-group every slot ties on (reward, SMs),
                // so only first encounters matter, and the scan
                // encounters groups in first-fitting-slot order.
                // Offloaded classes are additionally gated on host-pool
                // headroom: spill with nowhere to live is not admissible.
                let mask = self.admissible_mask(app, true);
                let mut cands = std::mem::take(&mut self.cand_scratch);
                let mut shares = std::mem::take(&mut self.share_scratch);
                cands.clear();
                for pid in ALL_PROFILES {
                    if mask & (1 << pid.index()) == 0 {
                        continue;
                    }
                    let base = self.cost(app, pid, true).unwrap();
                    if base.offloaded && !fleet.host_fits(gib_to_bytes(base.host_gib)) {
                        if S::ENABLED {
                            sink.count(Counter::OffloadPoolGated, 1);
                        }
                        continue;
                    }
                    let need = base.resident_gib + self.ctx_gib;
                    let contended = self.c2c_contention && base.offloaded;
                    for m in 0..kmax {
                        if pv.is_some() {
                            // Per-GPU candidates: levels differ per GPU
                            // even when the link share does not.
                            fleet.first_open_fitting_per_gpu(pid, m, need, &mut shares);
                            for &(g, s, existing) in shares.iter() {
                                let share = if contended { existing + 1 } else { 1 };
                                cands.push((g, s, pid, m as u8, share));
                            }
                        } else if contended {
                            fleet.first_open_fitting_per_share(pid, m, need, &mut shares);
                            for &(g, s, existing) in shares.iter() {
                                cands.push((g, s, pid, m as u8, existing + 1));
                            }
                        } else if let Some((g, s)) = fleet.first_open_fitting(pid, m, need) {
                            cands.push((g, s, pid, m as u8, 1));
                        }
                    }
                }
                cands.sort_unstable();
                if S::ENABLED {
                    steps += cands.len() as u64;
                }
                let mut best: Option<(f64, u32, usize, usize, ProfileId, u8, u32)> = None;
                for &(g, s, pid, m, share) in &cands {
                    let occ = m as u32 + 1;
                    let base = self.cost_at_shared(app, pid, true, occ, share).unwrap();
                    if node_headroom != u64::MAX && self.draw_mw(&base) > node_headroom {
                        if S::ENABLED {
                            sink.count(Counter::PowerGated, 1);
                        }
                        continue;
                    }
                    let (level, c) = match pv {
                        None => (0, base),
                        Some(v) => {
                            let add_sms = if m == 0 { GiProfile::get(pid).sms } else { 0 };
                            let lv = self.prospective_level(v, g, add_sms, &base);
                            let c = if lv == 0 {
                                base
                            } else {
                                self.cost_at_throttled(app, pid, true, occ, share, lv)
                                    .unwrap()
                            };
                            (lv, c)
                        }
                    };
                    let r = match src {
                        CostSource::Oracle => {
                            self.reward_throttled(app, pid, occ, share, level, alpha_centi, &c)
                        }
                        CostSource::Estimated(est) => {
                            let mut ec = c;
                            ec.runtime_s = est.predict_s(app, pid, occ, share, c.offloaded);
                            self.reward_of(app, pid, &ec, alpha_centi as f64 / 100.0)
                        }
                    };
                    let sms = GiProfile::get(pid).sms;
                    let better = match &best {
                        None => true,
                        Some((br, bsms, ..)) => r > *br || (r == *br && sms < *bsms),
                    };
                    if better {
                        best = Some((r, sms, g, s, pid, m, share));
                    }
                }
                self.cand_scratch = cands;
                self.share_scratch = shares;
                best.map(|(_, _, g, s, pid, m, share)| {
                    let occ = m as u32 + 1;
                    let base = self.cost_at_shared(app, pid, true, occ, share).unwrap();
                    self.priced(pv, app, g, s, pid, occ, share, true, base)
                })
            }
        };
        if S::ENABLED {
            sink.count(Counter::WalkSteps, steps);
        }
        choice
    }

    /// The naive full `gpus × slots` scan — the differential-test oracle
    /// for `place` (and the baseline `benches/placement.rs` measures the
    /// indexed walk against).
    pub fn place_scan(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        policy: PolicyKind,
    ) -> Option<(usize, usize, PlacementCost)> {
        self.place_scan_traced(fleet, app, policy, &mut NullSink)
    }

    /// `place_scan` with the same telemetry hooks as `place_traced`:
    /// walk steps here count *slots visited* (the scan's unit of work),
    /// so the profiling counters legitimately differ between serve
    /// modes — they measure the work each mode actually does.
    pub fn place_scan_traced<S: Sink>(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        policy: PolicyKind,
        sink: &mut S,
    ) -> Option<(usize, usize, PlacementCost)> {
        self.place_scan_powered_traced(fleet, app, policy, None, sink)
            .map(|p| (p.gpu, p.slot, p.priced))
    }

    /// The naive full-scan oracle of [`Self::place_powered_traced`]: the
    /// same decision recomputed slot-by-slot from raw fleet state (link
    /// shares from the resident lists, throttle levels from the power
    /// view's scan-rebuilt usages — never from the live counters the
    /// oracle is checking).
    pub fn place_scan_powered_traced<S: Sink>(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        policy: PolicyKind,
        pv: Option<&PowerView>,
        sink: &mut S,
    ) -> Option<Placement> {
        self.place_scan_sourced_traced(fleet, app, policy, pv, CostSource::Oracle, sink)
    }

    /// The naive full-scan oracle of [`Self::place_sourced_traced`]: the
    /// same [`CostSource`] seam, recomputed slot-by-slot. The estimator
    /// normalizes the C2C share to 1 for non-offloaded costs, so the
    /// scan's per-GPU raw share and the indexed walk's per-candidate
    /// share hit the identical estimate cell — the two modes stay
    /// bit-identical under estimation.
    pub fn place_scan_sourced_traced<S: Sink>(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        policy: PolicyKind,
        pv: Option<&PowerView>,
        src: CostSource,
        sink: &mut S,
    ) -> Option<Placement> {
        debug_assert_eq!(fleet.batch(), self.batch, "planner/fleet batch mismatch");
        let mut steps: u64 = 0;
        let kmax = fleet.batch();
        let node_headroom = pv.map_or(u64::MAX, |v| v.node_headroom_mw);
        let choice = match policy {
            PolicyKind::FirstFit => {
                let mut found: Option<Placement> = None;
                'scan: for (g, gpu) in fleet.gpus.iter().enumerate() {
                    if gpu.out_of_service() {
                        continue;
                    }
                    for (s, slot) in gpu.slots.iter().enumerate() {
                        if S::ENABLED {
                            steps += 1;
                        }
                        let occ = slot.occupancy() as u32;
                        if occ >= kmax {
                            continue;
                        }
                        if let Some(c) = self.cost_at(app, slot.profile.id, false, occ + 1) {
                            if occ > 0 && !slot.fits(c.resident_gib + self.ctx_gib) {
                                continue;
                            }
                            if node_headroom != u64::MAX && self.draw_mw(&c) > node_headroom {
                                if S::ENABLED {
                                    sink.count(Counter::PowerGated, 1);
                                }
                                continue;
                            }
                            found = Some(self.priced(
                                pv,
                                app,
                                g,
                                s,
                                slot.profile.id,
                                occ + 1,
                                1,
                                false,
                                c,
                            ));
                            break 'scan;
                        }
                    }
                }
                found
            }
            PolicyKind::BestFit => {
                let mut best: Option<(u32, usize, usize, usize, ProfileId, PlacementCost)> = None;
                for (g, gpu) in fleet.gpus.iter().enumerate() {
                    if gpu.out_of_service() {
                        continue;
                    }
                    for (s, slot) in gpu.slots.iter().enumerate() {
                        if S::ENABLED {
                            steps += 1;
                        }
                        let occ = slot.occupancy();
                        if occ as u32 >= kmax {
                            continue;
                        }
                        if let Some(c) =
                            self.cost_at(app, slot.profile.id, false, occ as u32 + 1)
                        {
                            if occ > 0 && !slot.fits(c.resident_gib + self.ctx_gib) {
                                continue;
                            }
                            if node_headroom != u64::MAX && self.draw_mw(&c) > node_headroom {
                                if S::ENABLED {
                                    sink.count(Counter::PowerGated, 1);
                                }
                                continue;
                            }
                            let sms = slot.profile.sms;
                            let better = match &best {
                                None => true,
                                Some((bsms, bocc, ..)) => {
                                    sms < *bsms || (sms == *bsms && occ > *bocc)
                                }
                            };
                            if better {
                                best = Some((sms, occ, g, s, slot.profile.id, c));
                            }
                        }
                    }
                }
                best.map(|(_, occ, g, s, pid, c)| {
                    self.priced(pv, app, g, s, pid, occ as u32 + 1, 1, false, c)
                })
            }
            PolicyKind::OffloadAware { alpha_centi } => {
                let mut best: Option<(f64, u32, usize, usize, ProfileId, u32, u32)> = None;
                for (g, gpu) in fleet.gpus.iter().enumerate() {
                    if gpu.out_of_service() {
                        continue;
                    }
                    // The naive path recomputes the GPU's link share from
                    // the raw resident lists — the oracle never trusts
                    // the live counters it is checking.
                    let share = if self.c2c_contention {
                        gpu.offloaders_scan() + 1
                    } else {
                        1
                    };
                    for (s, slot) in gpu.slots.iter().enumerate() {
                        if S::ENABLED {
                            steps += 1;
                        }
                        let occ = slot.occupancy() as u32;
                        if occ >= kmax {
                            continue;
                        }
                        let pid = slot.profile.id;
                        let base = match self.cost_at_shared(app, pid, true, occ + 1, share) {
                            Some(c) => c,
                            None => continue,
                        };
                        if occ > 0 && !slot.fits(base.resident_gib + self.ctx_gib) {
                            continue;
                        }
                        if base.offloaded && !fleet.host_fits_scan(gib_to_bytes(base.host_gib)) {
                            if S::ENABLED {
                                sink.count(Counter::OffloadPoolGated, 1);
                            }
                            continue;
                        }
                        if node_headroom != u64::MAX && self.draw_mw(&base) > node_headroom {
                            if S::ENABLED {
                                sink.count(Counter::PowerGated, 1);
                            }
                            continue;
                        }
                        let (level, c) = match pv {
                            None => (0, base),
                            Some(v) => {
                                let add_sms = if occ == 0 { slot.profile.sms } else { 0 };
                                let lv = self.prospective_level(v, g, add_sms, &base);
                                let c = if lv == 0 {
                                    base
                                } else {
                                    self.cost_at_throttled(app, pid, true, occ + 1, share, lv)
                                        .unwrap()
                                };
                                (lv, c)
                            }
                        };
                        let r = match src {
                            CostSource::Oracle => self.reward_throttled(
                                app,
                                pid,
                                occ + 1,
                                share,
                                level,
                                alpha_centi,
                                &c,
                            ),
                            CostSource::Estimated(est) => {
                                let mut ec = c;
                                ec.runtime_s =
                                    est.predict_s(app, pid, occ + 1, share, c.offloaded);
                                self.reward_of(app, pid, &ec, alpha_centi as f64 / 100.0)
                            }
                        };
                        let sms = slot.profile.sms;
                        // Exact comparisons (no epsilon): tie-breaking
                        // must be order-insensitive for the class-level
                        // walk in `place` to match slot-level scanning.
                        let better = match &best {
                            None => true,
                            Some((br, bsms, ..)) => r > *br || (r == *br && sms < *bsms),
                        };
                        if better {
                            best = Some((r, sms, g, s, pid, occ + 1, share));
                        }
                    }
                }
                best.map(|(_, _, g, s, pid, occ, share)| {
                    let base = self.cost_at_shared(app, pid, true, occ, share).unwrap();
                    self.priced(pv, app, g, s, pid, occ, share, true, base)
                })
            }
        };
        if S::ENABLED {
            sink.count(Counter::WalkSteps, steps);
        }
        choice
    }

    /// Whether a failed placement was (at least partly) the host pool's
    /// fault: some profile class admits `app` only by offloading, and the
    /// pool cannot park that class's spill. Pure function of the cost
    /// tables and the integer pool counter, so the answer is identical in
    /// `Indexed` and `NaiveOracle` modes — the telemetry plane uses it to
    /// emit mode-invariant offload-denial events on the cold (failure)
    /// path.
    pub fn offload_pool_starved(&mut self, fleet: &Fleet, app: AppId) -> bool {
        for pid in ALL_PROFILES {
            if let Some(c) = self.cost(app, pid, true) {
                if c.offloaded && !fleet.host_fits(gib_to_bytes(c.host_gib)) {
                    return true;
                }
            }
        }
        false
    }

    /// Whether `app` could run on *some* profile of the per-GPU layouts the
    /// fleet currently has or is reconfiguring toward — the trigger guard
    /// for dynamic reconfiguration. A class that only admits the app by
    /// offloading counts only while the node's host pool can actually
    /// park the spill: with the pool exhausted, "fits by offload" would
    /// starve the job forever while blocking the repartition that could
    /// rescue it (with an unlimited pool the gate never bites — the
    /// pre-plane trigger exactly). O(profile classes) via the fleet's
    /// layout-class counts.
    pub fn fits_current_layouts(&mut self, fleet: &Fleet, app: AppId, allow_offload: bool) -> bool {
        for pid in ALL_PROFILES {
            if !fleet.has_layout_class(pid) {
                continue;
            }
            if let Some(c) = self.cost(app, pid, allow_offload) {
                if c.offloaded && !fleet.host_fits(gib_to_bytes(c.host_gib)) {
                    continue;
                }
                return true;
            }
        }
        false
    }

    /// `fits_current_layouts` by full GPU×layout scan — the
    /// differential-test oracle.
    pub fn fits_current_layouts_scan(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        allow_offload: bool,
    ) -> bool {
        for gpu in &fleet.gpus {
            if gpu.cordoned() {
                continue;
            }
            for &p in gpu.effective_layout() {
                if let Some(c) = self.cost(app, p, allow_offload) {
                    if c.offloaded && !fleet.host_fits_scan(gib_to_bytes(c.host_gib)) {
                        continue;
                    }
                    return true;
                }
            }
        }
        false
    }

    /// Whether `app` is servable at all on this hardware (largest profile,
    /// offloading allowed when the policy supports it).
    pub fn servable(&mut self, app: AppId, allow_offload: bool) -> bool {
        self.cost(app, ProfileId::P7g96gb, allow_offload).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fleet::{Fleet, LayoutPreset};

    #[test]
    fn cost_direct_vs_offload() {
        let mut pl = Planner::new(0.05);
        // Small job fits 1g directly; the offload-allowed cost is identical
        // (no spill happens).
        let direct = pl.cost(AppId::Faiss, ProfileId::P1g12gb, false).unwrap();
        let relaxed = pl.cost(AppId::Faiss, ProfileId::P1g12gb, true).unwrap();
        assert!(!direct.offloaded && !relaxed.offloaded);
        assert_eq!(direct.runtime_s, relaxed.runtime_s);
        // 16.5 GiB llama does not fit 1g directly but offloads.
        assert!(pl.cost(AppId::Llama3Fp16, ProfileId::P1g12gb, false).is_none());
        let off = pl.cost(AppId::Llama3Fp16, ProfileId::P1g12gb, true).unwrap();
        assert!(off.offloaded);
        assert!(off.resident_gib <= 11.0 - pl.ctx_gib() + 1e-9);
        assert!(off.c2c_tbs > 0.0, "offloaded runs drive C2C traffic");
        // Offloading on 1g is slower than running directly on 2g.
        let two_g = pl.cost(AppId::Llama3Fp16, ProfileId::P2g24gb, false).unwrap();
        assert!(off.runtime_s > two_g.runtime_s);
    }

    #[test]
    fn throttled_level_zero_is_the_pre_plane_bits_and_stretch_is_monotone() {
        // The power-plane feedback contract: level 0 returns the cached
        // unthrottled tables *unchanged* (the exact pre-plane bits), every
        // deeper ladder step stretches the runtime monotonically, the
        // footprint/offload plan never moves with the clock, and at the
        // floor at least one compute-bound class is strictly slower.
        let mut pl = Planner::new(0.05);
        let floor = power::max_level(&pl.spec);
        assert!(floor > 0);
        let apps = [
            AppId::Faiss,
            AppId::Hotspot,
            AppId::Llama3Fp16,
            AppId::Qiskit31,
            AppId::NekRs,
        ];
        let mut any_stretched = false;
        for app in apps {
            for pid in ALL_PROFILES {
                for allow in [false, true] {
                    let base = pl.cost_at(app, pid, allow, 1);
                    let t0 = pl.cost_at_throttled(app, pid, allow, 1, 1, 0);
                    match (base, t0) {
                        (None, None) => continue,
                        (Some(a), Some(b)) => {
                            assert_eq!(a.runtime_s.to_bits(), b.runtime_s.to_bits());
                            assert_eq!(a.resident_gib.to_bits(), b.resident_gib.to_bits());
                            assert_eq!(a.hbm_tbs.to_bits(), b.hbm_tbs.to_bits());
                            assert_eq!(a.c2c_tbs.to_bits(), b.c2c_tbs.to_bits());
                        }
                        _ => panic!("{app:?} {pid:?}: level 0 changed admissibility"),
                    }
                    let base = base.unwrap();
                    let mut prev = base.runtime_s;
                    for level in 1..=floor {
                        let c = pl
                            .cost_at_throttled(app, pid, allow, 1, 1, level)
                            .expect("throttling never changes admissibility");
                        assert!(
                            c.runtime_s >= prev,
                            "{app:?} {pid:?} level {level}: runtime shrank"
                        );
                        // Clocks stretch time, never footprints or plans.
                        assert_eq!(c.resident_gib.to_bits(), base.resident_gib.to_bits());
                        assert_eq!(c.host_gib.to_bits(), base.host_gib.to_bits());
                        assert_eq!(c.offloaded, base.offloaded);
                        assert_eq!(c.sms_share, base.sms_share);
                        // Memoized hit == fresh computation, bit-for-bit.
                        let again = pl.cost_at_throttled(app, pid, allow, 1, 1, level).unwrap();
                        assert_eq!(c.runtime_s.to_bits(), again.runtime_s.to_bits());
                        prev = c.runtime_s;
                    }
                    if prev > base.runtime_s {
                        any_stretched = true;
                    }
                }
            }
        }
        assert!(
            any_stretched,
            "the ladder floor must slow at least one compute-bound class"
        );
    }

    #[test]
    fn contention_slowdown_monotone_and_batch1_identical() {
        // The co-residency classes: runtime must be monotone
        // non-decreasing in the number of co-residents, the resident
        // footprint must not depend on occupancy, and a batch-1 planner's
        // costs must be bit-identical to a batched planner's occupancy-1
        // column (the `--batch 1` reproduction guarantee).
        let mut p1 = Planner::new(0.05);
        let mut pk = Planner::with_batch(0.05, MAX_BATCH);
        let apps = [
            AppId::Faiss,
            AppId::Hotspot,
            AppId::Llama3Fp16,
            AppId::Qiskit31,
            AppId::NekRs,
        ];
        for app in apps {
            for pid in ALL_PROFILES {
                for allow in [false, true] {
                    let solo = p1.cost(app, pid, allow);
                    let col1 = pk.cost_at(app, pid, allow, 1);
                    match (solo, col1) {
                        (None, None) => {}
                        (Some(a), Some(b)) => {
                            assert_eq!(a.runtime_s.to_bits(), b.runtime_s.to_bits());
                            assert_eq!(a.resident_gib.to_bits(), b.resident_gib.to_bits());
                            assert_eq!(a.occupancy.to_bits(), b.occupancy.to_bits());
                            assert_eq!(a.hbm_tbs.to_bits(), b.hbm_tbs.to_bits());
                        }
                        _ => panic!("{app:?} {pid:?} allow={allow}: admissibility diverged"),
                    }
                    let mut prev: Option<PlacementCost> = None;
                    for occ in 1..=MAX_BATCH {
                        let c = pk.cost_at(app, pid, allow, occ);
                        assert_eq!(
                            c.is_some(),
                            solo.is_some(),
                            "admissibility must be occupancy-independent"
                        );
                        if let Some(c) = c {
                            if let Some(p) = prev {
                                assert!(
                                    c.runtime_s >= p.runtime_s,
                                    "{app:?} {pid:?} occ={occ}: slowdown not monotone \
                                     ({} < {})",
                                    c.runtime_s,
                                    p.runtime_s
                                );
                                assert_eq!(
                                    c.resident_gib.to_bits(),
                                    p.resident_gib.to_bits(),
                                    "resident footprint is occupancy-independent"
                                );
                            }
                            prev = Some(c);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn contended_cost_shares_the_link_and_share1_is_identical() {
        let mut pl = Planner::with_opts(0.05, 1, true, 0.0);
        let mut base = Planner::new(0.05);
        // share = 1 is the literal uncontended cost, bit for bit.
        let solo = base.cost(AppId::Llama3Fp16, ProfileId::P1g12gb, true).unwrap();
        let s1 = pl.cost_at_shared(AppId::Llama3Fp16, ProfileId::P1g12gb, true, 1, 1).unwrap();
        assert_eq!(solo.runtime_s.to_bits(), s1.runtime_s.to_bits());
        assert_eq!(solo.c2c_tbs.to_bits(), s1.c2c_tbs.to_bits());
        // More co-offloaders on the link → monotone non-decreasing
        // runtime, identical resident/spill footprints.
        let pid = ProfileId::P1g12gb;
        let mut prev = s1;
        for share in 2..=4u32 {
            let c = pl.cost_at_shared(AppId::Llama3Fp16, pid, true, 1, share).unwrap();
            assert!(
                c.runtime_s >= prev.runtime_s,
                "share {share}: contention must not speed the job up"
            );
            assert_eq!(c.resident_gib.to_bits(), prev.resident_gib.to_bits());
            assert_eq!(c.host_gib.to_bits(), prev.host_gib.to_bits());
            prev = c;
        }
        assert!(
            prev.runtime_s > s1.runtime_s,
            "an offload-heavy app must actually slow under link sharing"
        );
        // Non-offloaded costs are share-independent by construction.
        let d1 = pl.cost_at_shared(AppId::Faiss, ProfileId::P1g12gb, true, 1, 1).unwrap();
        let d4 = pl.cost_at_shared(AppId::Faiss, ProfileId::P1g12gb, true, 1, 4).unwrap();
        assert_eq!(d1.runtime_s.to_bits(), d4.runtime_s.to_bits());
        assert_eq!(d1.host_gib, 0.0);
    }

    #[test]
    fn finite_pool_rejects_the_offload_an_infinite_pool_accepted() {
        // The deterministic host-pool gate: llama spills ~5.6 GiB onto a
        // 1g slice. An unlimited pool admits it; a pool smaller than the
        // spill refuses the placement outright (all-small fleet, nothing
        // else fits); a pool big enough for exactly one spill admits the
        // first job and refuses the second until the first finishes.
        let policy = PolicyKind::OffloadAware { alpha_centi: 10 };
        let mut pl = Planner::new(0.05);
        let spill = pl.cost(AppId::Llama3Fp16, ProfileId::P1g12gb, true).unwrap().host_gib;
        assert!(spill > 0.0);

        let inf = Fleet::new(1, LayoutPreset::AllSmall).unwrap();
        let placed = pl.place(&inf, AppId::Llama3Fp16, policy);
        assert!(placed.is_some(), "unlimited pool admits the offload");

        let tiny = Fleet::with_hostmem(1, LayoutPreset::AllSmall, 1, spill * 0.5).unwrap();
        assert!(
            pl.place(&tiny, AppId::Llama3Fp16, policy).is_none(),
            "a pool smaller than the spill must reject the offload"
        );
        assert!(pl.place_scan(&tiny, AppId::Llama3Fp16, policy).is_none());

        let mut one = Fleet::with_hostmem(1, LayoutPreset::AllSmall, 1, spill * 1.5).unwrap();
        let (g, s, c) = pl.place(&one, AppId::Llama3Fp16, policy).unwrap();
        one.start_job(
            g,
            s,
            0,
            0.0,
            c.runtime_s,
            c.resident_gib + pl.ctx_gib(),
            crate::cluster::hostmem::gib_to_bytes(c.host_gib),
        );
        assert!(
            pl.place(&one, AppId::Llama3Fp16, policy).is_none(),
            "pool headroom below a second spill must gate admission"
        );
        assert!(pl.place_scan(&one, AppId::Llama3Fp16, policy).is_none());
        // Draining the offloader restores the headroom and the placement.
        assert!(one.finish_job(g, s, 0, c.runtime_s));
        assert_eq!(one.host_used_bytes(), 0);
        assert!(pl.place(&one, AppId::Llama3Fp16, policy).is_some());
    }

    #[test]
    fn contended_place_matches_scan_and_prefers_quiet_links() {
        // Two whole GPUs, one already hosting an offloader: with
        // contention on, the indexed walk must agree with the naive scan
        // slot-for-slot, and the second offloader must land on the quiet
        // GPU (equal reward would pick GPU 0 — only the contention
        // penalty pushes it away).
        let policy = PolicyKind::OffloadAware { alpha_centi: 10 };
        for contention in [false, true] {
            let mut fleet = Fleet::with_batch(2, LayoutPreset::AllSmall, 1).unwrap();
            let mut pl = Planner::with_opts(0.05, 1, contention, 0.0);
            let (g0, s0, c0) = pl.place(&fleet, AppId::Llama3Fp16, policy).unwrap();
            assert_eq!((g0, s0), (0, 0));
            assert!(c0.offloaded);
            fleet.start_job(
                g0,
                s0,
                0,
                0.0,
                c0.runtime_s,
                c0.resident_gib + pl.ctx_gib(),
                crate::cluster::hostmem::gib_to_bytes(c0.host_gib),
            );
            let fast = pl.place(&fleet, AppId::Llama3Fp16, policy).unwrap();
            let scan = pl.place_scan(&fleet, AppId::Llama3Fp16, policy).unwrap();
            assert_eq!((fast.0, fast.1), (scan.0, scan.1), "contention={contention}");
            assert_eq!(fast.2.runtime_s.to_bits(), scan.2.runtime_s.to_bits());
            if contention {
                assert_eq!(fast.0, 1, "link sharing must steer to the quiet GPU");
                assert!(
                    fast.2.runtime_s.to_bits() == c0.runtime_s.to_bits(),
                    "on the quiet GPU the job runs at the share-1 rate"
                );
            } else {
                assert_eq!(fast.0, 0, "private links keep first-fit-by-reward order");
            }
        }
    }

    #[test]
    fn exhausted_pool_flips_the_reconfig_trigger() {
        // All-small fleet: llama fits the current layouts only by
        // offloading. With pool headroom that claim is true; with the
        // pool exhausted it must flip to false — unblocking the
        // repartition that can actually host the job — and the indexed
        // guard must agree with the scan in both states.
        let mut pl = Planner::new(0.05);
        let spill = pl.cost(AppId::Llama3Fp16, ProfileId::P1g12gb, true).unwrap().host_gib;
        let mut fleet = Fleet::with_hostmem(2, LayoutPreset::AllSmall, 1, spill * 1.2).unwrap();
        assert!(pl.fits_current_layouts(&fleet, AppId::Llama3Fp16, true));
        assert!(pl.fits_current_layouts_scan(&fleet, AppId::Llama3Fp16, true));
        // Park one spill: headroom drops below a second one.
        let (g, s, c) = pl
            .place(&fleet, AppId::Llama3Fp16, PolicyKind::OffloadAware { alpha_centi: 10 })
            .unwrap();
        fleet.start_job(
            g,
            s,
            0,
            0.0,
            c.runtime_s,
            c.resident_gib + pl.ctx_gib(),
            crate::cluster::hostmem::gib_to_bytes(c.host_gib),
        );
        assert!(!pl.fits_current_layouts(&fleet, AppId::Llama3Fp16, true));
        assert!(!pl.fits_current_layouts_scan(&fleet, AppId::Llama3Fp16, true));
        // Direct-fitting apps are unaffected by the pool state.
        assert!(pl.fits_current_layouts(&fleet, AppId::Faiss, true));
    }

    #[test]
    fn energy_weight_zero_keeps_rewards_identical() {
        let mut plain = Planner::new(0.05);
        let mut zero = Planner::with_opts(0.05, 1, false, 0.0);
        let mut weighted = Planner::with_opts(0.05, 1, false, 5.0);
        let c = plain.cost(AppId::Faiss, ProfileId::P1g12gb, false).unwrap();
        let a = plain.reward_of(AppId::Faiss, ProfileId::P1g12gb, &c, 0.1);
        let b = zero.reward_of(AppId::Faiss, ProfileId::P1g12gb, &c, 0.1);
        assert_eq!(a.to_bits(), b.to_bits(), "weight 0.0 must be the paper reward");
        let w = weighted.reward_of(AppId::Faiss, ProfileId::P1g12gb, &c, 0.1);
        assert!(w < a, "a positive energy weight must shrink the reward");
    }

    #[test]
    fn first_fit_vs_best_fit_slot_choice() {
        // Mixed GPU 2 layout is [4g.48gb, 3g.48gb]; a small job should go
        // to the 3g slot under best-fit but the 4g slot under first-fit.
        let mut fleet = Fleet::new(3, LayoutPreset::Mixed).unwrap();
        // Occupy every slot on GPUs 0 and 1 so only GPU 2 is free.
        for g in 0..2 {
            for s in 0..fleet.gpus[g].slots.len() {
                fleet.start_job(g, s, 0, 0.0, 100.0, 0.5, 0);
            }
        }
        let mut pl = Planner::new(0.05);
        let (g_ff, s_ff, _) = pl.place(&fleet, AppId::Hotspot, PolicyKind::FirstFit).unwrap();
        assert_eq!((g_ff, s_ff), (2, 0), "first-fit takes the 4g slot");
        let (g_bf, s_bf, _) = pl.place(&fleet, AppId::Hotspot, PolicyKind::BestFit).unwrap();
        assert_eq!((g_bf, s_bf), (2, 1), "best-fit takes the smaller 3g slot");
    }

    #[test]
    fn batching_admits_onto_occupied_slots_when_nothing_is_empty() {
        // One 7g slot, batch 3: the first job takes the empty slot; the
        // next co-locates (first-fit) with a longer modelled runtime; a
        // full slot admits nothing.
        let mut fleet = Fleet::with_batch(1, LayoutPreset::AllBig, 3).unwrap();
        let mut pl = Planner::with_batch(0.05, 3);
        let (g, s, c1) = pl.place(&fleet, AppId::Hotspot, PolicyKind::FirstFit).unwrap();
        assert_eq!((g, s), (0, 0));
        fleet.start_job(g, s, 0, 0.0, c1.runtime_s, c1.resident_gib + pl.ctx_gib(), 0);
        let (g, s, c2) = pl.place(&fleet, AppId::Hotspot, PolicyKind::FirstFit).unwrap();
        assert_eq!((g, s), (0, 0), "co-locates on the occupied slot");
        assert!(c2.runtime_s > c1.runtime_s, "co-residency slows the job");
        fleet.start_job(g, s, 1, 0.0, c2.runtime_s, c2.resident_gib + pl.ctx_gib(), 0);
        let (_, _, c3) = pl.place(&fleet, AppId::Hotspot, PolicyKind::FirstFit).unwrap();
        assert!(c3.runtime_s > c2.runtime_s);
        fleet.start_job(0, 0, 2, 0.0, c3.runtime_s, c3.resident_gib + pl.ctx_gib(), 0);
        assert!(
            pl.place(&fleet, AppId::Hotspot, PolicyKind::FirstFit).is_none(),
            "full slot admits nothing"
        );
        // An unbatched planner/fleet pair refuses the second job outright.
        let mut f1 = Fleet::new(1, LayoutPreset::AllBig).unwrap();
        let mut p1 = Planner::new(0.05);
        let (g, s, c) = p1.place(&f1, AppId::Hotspot, PolicyKind::FirstFit).unwrap();
        f1.start_job(g, s, 0, 0.0, c.runtime_s, c.resident_gib + p1.ctx_gib(), 0);
        assert!(p1.place(&f1, AppId::Hotspot, PolicyKind::FirstFit).is_none());
    }

    #[test]
    fn offload_aware_weighs_co_residency_by_reward() {
        // Two 7g slots, batch 2: the reward model arbitrates between the
        // empty slot (faster run, more SM waste for a poor scaler) and
        // co-residency (slower run, denser packing). Whatever it picks,
        // the indexed walk and the naive scan must agree at every step,
        // and once every seat is taken the policy must return None
        // rather than overcommit.
        let mut fleet = Fleet::with_batch(2, LayoutPreset::AllBig, 2).unwrap();
        let mut pl = Planner::with_batch(0.05, 2);
        let policy = PolicyKind::OffloadAware { alpha_centi: 10 };
        for job in 0..4u32 {
            let fast = pl.place(&fleet, AppId::Faiss, policy);
            let scan = pl.place_scan(&fleet, AppId::Faiss, policy);
            assert_eq!(
                fast.map(|(g, s, _)| (g, s)),
                scan.map(|(g, s, _)| (g, s)),
                "job {job}"
            );
            let (g, s, c) = fast.unwrap();
            let occ_runtime = c.runtime_s;
            // The cost handed back is the cost at the occupancy joined.
            let expect = pl
                .cost_at(
                    AppId::Faiss,
                    ProfileId::P7g96gb,
                    true,
                    fleet.gpus[g].slots[s].occupancy() as u32 + 1,
                )
                .unwrap();
            assert_eq!(occ_runtime.to_bits(), expect.runtime_s.to_bits());
            fleet.start_job(g, s, job, 0.0, c.runtime_s, c.resident_gib + pl.ctx_gib(), 0);
        }
        // 2 slots × 2 seats are gone: nothing left to offer.
        assert!(pl.place(&fleet, AppId::Faiss, policy).is_none());
        assert!(pl.place_scan(&fleet, AppId::Faiss, policy).is_none());
    }

    #[test]
    fn batching_respects_the_slice_memory_budget() {
        // Offloaded llama fills a 1g slice to its solo cap: the slice's
        // memory cannot hold a second resident, so batching never
        // overcommits it — even at batch 4.
        let mut fleet = Fleet::with_batch(1, LayoutPreset::AllSmall, 4).unwrap();
        let mut pl = Planner::with_batch(0.05, 4);
        let policy = PolicyKind::OffloadAware { alpha_centi: 10 };
        let (g, s, c) = pl.place(&fleet, AppId::Llama3Fp16, policy).unwrap();
        assert!(c.offloaded);
        fleet.start_job(g, s, 0, 0.0, c.runtime_s, c.resident_gib + pl.ctx_gib(), 0);
        // The occupied slot is memory-full; the next llama must take a
        // different (empty) slot, never co-locate.
        let (g2, s2, _) = pl.place(&fleet, AppId::Llama3Fp16, policy).unwrap();
        assert_ne!((g2, s2), (g, s), "memory-full slot refuses co-residents");
        // And both paths agree on that.
        let scan = pl.place_scan(&fleet, AppId::Llama3Fp16, policy).map(|(g, s, _)| (g, s));
        assert_eq!(scan, Some((g2, s2)));
    }

    #[test]
    fn offload_aware_admits_large_jobs_onto_small_slices() {
        let fleet = Fleet::new(1, LayoutPreset::AllSmall).unwrap();
        let mut pl = Planner::new(0.05);
        for policy in [PolicyKind::FirstFit, PolicyKind::BestFit] {
            assert!(
                pl.place(&fleet, AppId::Llama3Fp16, policy).is_none(),
                "{:?} must not fit 16.5 GiB into 11 GiB",
                policy
            );
        }
        let (_, _, c) = pl
            .place(&fleet, AppId::Llama3Fp16, PolicyKind::OffloadAware { alpha_centi: 10 })
            .unwrap();
        assert!(c.offloaded);
    }

    #[test]
    fn indexed_place_matches_naive_scan_across_fleet_states() {
        // Pseudo-random occupancy churn over a mixed fleet at several
        // batch depths: every policy must pick the identical slot through
        // the index and the scan.
        for batch in [1u32, 2, 4] {
            let mut rng = crate::util::Rng::new(0x9A7E + batch as u64);
            let mut fleet = Fleet::with_batch(5, LayoutPreset::Mixed, batch).unwrap();
            let mut pl = Planner::with_batch(0.05, batch);
            let apps = [
                AppId::Faiss,
                AppId::Hotspot,
                AppId::Llama3Fp16,
                AppId::Qiskit31,
                AppId::NekRs,
            ];
            let policies = [
                PolicyKind::FirstFit,
                PolicyKind::BestFit,
                PolicyKind::OffloadAware { alpha_centi: 10 },
                PolicyKind::OffloadAware { alpha_centi: 60 },
            ];
            let mut next_job = 0u32;
            for step in 0..120u32 {
                let g = rng.below(5) as usize;
                if rng.below(2) == 0 {
                    // Admit through the policy machinery so charged memory
                    // is realistic (memory gates stay meaningful).
                    let app = apps[rng.below(apps.len() as u64) as usize];
                    let policy = policies[rng.below(policies.len() as u64) as usize];
                    if let Some((pg, ps, c)) = pl.place(&fleet, app, policy) {
                        fleet.start_job(
                            pg,
                            ps,
                            next_job,
                            step as f64,
                            step as f64 + 9.0,
                            c.resident_gib + pl.ctx_gib(),
                            crate::cluster::hostmem::gib_to_bytes(c.host_gib),
                        );
                        next_job += 1;
                    }
                } else if let Some(s) =
                    fleet.gpus[g].slots.iter().position(|s| !s.is_idle())
                {
                    let job = fleet.gpus[g].slots[s].residents[0].job;
                    fleet.finish_job(g, s, job, step as f64);
                }
                // Fault-plane churn: flip a GPU between cordoned and
                // repaired every few steps, so the differential runs with
                // hardware missing (and coming back) mid-stream.
                if step % 7 == 3 {
                    if fleet.gpus[g].cordoned() {
                        fleet.uncordon_gpu(g);
                    } else {
                        let _ = fleet.cordon_gpu(g, step as f64);
                    }
                }
                for &app in &apps {
                    for &policy in &policies {
                        let fast = pl.place(&fleet, app, policy).map(|(g, s, _)| (g, s));
                        let slow = pl.place_scan(&fleet, app, policy).map(|(g, s, _)| (g, s));
                        assert_eq!(fast, slow, "batch {batch} step {step} {app:?} {policy:?}");
                    }
                    for allow in [false, true] {
                        assert_eq!(
                            pl.fits_current_layouts(&fleet, app, allow),
                            pl.fits_current_layouts_scan(&fleet, app, allow),
                            "batch {batch} step {step} {app:?} allow={allow}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn servable_and_layout_fit_guards() {
        let fleet = Fleet::new(1, LayoutPreset::AllSmall).unwrap();
        let mut pl = Planner::new(0.05);
        assert!(pl.servable(AppId::Llama3Fp16, false), "fits 7g directly");
        assert!(!pl.fits_current_layouts(&fleet, AppId::Llama3Fp16, false));
        assert!(pl.fits_current_layouts(&fleet, AppId::Llama3Fp16, true));
        assert!(pl.fits_current_layouts(&fleet, AppId::Faiss, false));
        // Indexed and scan guards agree, including mid-reconfiguration.
        let mut fleet = fleet;
        fleet
            .begin_reconfig(0, crate::cluster::fleet::class_layout(ProfileId::P2g24gb), 5.0)
            .unwrap();
        for app in [AppId::Llama3Fp16, AppId::Faiss, AppId::Qiskit31] {
            for allow in [false, true] {
                assert_eq!(
                    pl.fits_current_layouts(&fleet, app, allow),
                    pl.fits_current_layouts_scan(&fleet, app, allow),
                    "{app:?} allow={allow}"
                );
            }
        }
    }

    #[test]
    fn reward_prefers_tight_fit_at_low_alpha() {
        let mut pl = Planner::new(0.05);
        // FAISS scales poorly: a 1g slice wastes far less than 7g.
        let c1 = pl.cost(AppId::Faiss, ProfileId::P1g12gb, false).unwrap();
        let c7 = pl.cost(AppId::Faiss, ProfileId::P7g96gb, false).unwrap();
        let r1 = pl.reward_of(AppId::Faiss, ProfileId::P1g12gb, &c1, 0.1);
        let r7 = pl.reward_of(AppId::Faiss, ProfileId::P7g96gb, &c7, 0.1);
        assert!(r1 > r7, "r1={r1} r7={r7}");
    }

    #[test]
    fn policy_parse_accepts_alpha_and_round_trips() {
        assert_eq!(PolicyKind::parse("first-fit"), Some(PolicyKind::FirstFit));
        assert_eq!(PolicyKind::parse("best-fit"), Some(PolicyKind::BestFit));
        assert_eq!(
            PolicyKind::parse("offload-aware"),
            Some(PolicyKind::OffloadAware { alpha_centi: 10 })
        );
        assert_eq!(
            PolicyKind::parse("offload-aware:0.25"),
            Some(PolicyKind::OffloadAware { alpha_centi: 25 })
        );
        assert_eq!(
            PolicyKind::parse("offload-aware:1"),
            Some(PolicyKind::OffloadAware { alpha_centi: 100 })
        );
        assert_eq!(PolicyKind::parse("offload-aware:-1"), None);
        assert_eq!(PolicyKind::parse("offload-aware:nan"), None);
        assert_eq!(PolicyKind::parse("offload-aware:"), None);
        assert_eq!(PolicyKind::parse("bogus"), None);
        for policy in [
            PolicyKind::FirstFit,
            PolicyKind::BestFit,
            PolicyKind::OffloadAware { alpha_centi: 10 },
            PolicyKind::OffloadAware { alpha_centi: 25 },
            PolicyKind::OffloadAware { alpha_centi: 7 },
            PolicyKind::OffloadAware { alpha_centi: 150 },
        ] {
            assert_eq!(
                PolicyKind::parse(&policy.label()),
                Some(policy),
                "label {} must round-trip",
                policy.label()
            );
        }
    }
}
