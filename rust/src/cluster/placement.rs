//! Placement policies: which serving-slot seat should an arriving job get?
//!
//! Three policies, in increasing awareness:
//! - `FirstFit`: first feasible seat — an empty slot whose memory directly
//!   fits the job, or (under batching) an occupied slot with a free seat
//!   and enough memory headroom.
//! - `BestFit`: the seat on the *smallest* fitting profile — classic
//!   best-fit, which minimizes SM fragmentation by keeping big slices
//!   free for big jobs; within a profile it prefers the *most occupied*
//!   open slot (densest packing keeps empty slots free).
//! - `OffloadAware`: reward-maximizing admission (§VI-B). Every feasible
//!   seat is a candidate — directly when the job fits, via an NVLink-C2C
//!   `OffloadPlan` when it does not — and the seat with the highest reward
//!   at the policy's α wins. Co-residency trades performance (the job
//!   runs slower) against SM waste (a packed slice strands fewer SMs),
//!   so well-scaling apps keep preferring empty slices while poorly
//!   scaling ones may score higher co-resident — exactly the §VI-B
//!   arbitration, now over co-residency classes too.
//!
//! ## The contention cost model (MPS-within-MIG)
//!
//! The modelled cost of a placement depends only on the co-residency
//! class `(app, profile, occupancy)` — never on *which* slot hosts the
//! job. At occupancy `n` the `n` clients share the slice exactly as the
//! paper's `Scheme::MigSharedGi` co-runs share one GI: each gets an equal
//! SM share (the MPS cap model of `sharing::scheme`), an equal share of
//! the slice's HBM bandwidth pool, and pays the per-co-runner compute
//! interference measured for shared-GI co-runs; the C2C direct rate
//! follows the reduced SMs in flight (Table IVb saturation curve). At
//! `n = 1` every term reduces to the unbatched environment bit-for-bit.
//! A job's runtime is fixed by the occupancy *at admission* (residents
//! already running are not re-fit — see ROADMAP follow-ups).
//!
//! Memory is the batching gate (`ContextModel`): a seat is only feasible
//! if the slice still holds every resident's footprint plus a per-process
//! context after the newcomer joins. Offload plans are computed against
//! the solo cap, so a spilled job's resident set fills the slice and it
//! naturally refuses co-residents.
//!
//! ## The indexed hot path
//!
//! A placement decision reduces to a walk over at most
//! `NUM_PROFILES × batch` co-residency classes against the fleet's
//! per-(profile, occupancy) open-slot index (`Fleet::first_open_fitting`),
//! instead of a full `gpus × slots` scan:
//! - first-fit: the minimum `(gpu, slot)` among each feasible class's
//!   first fitting slot;
//! - best-fit: fold the class-firsts with the scan's strict preference
//!   (smaller SMs, then higher occupancy, then lower `(gpu, slot)`);
//! - offload-aware: fold the per-class candidates in `(gpu, slot)` order
//!   with the same (reward, SMs) preference the naive scan applies per
//!   slot — provably the same choice, because all slots of a class tie.
//!
//! `Planner::place_scan` keeps the naive full scan as the
//! differential-test oracle: for any fleet state both paths return the
//! identical `(gpu, slot, cost)`.
//!
//! The `Planner` memoizes per-(app, profile, offload, occupancy) costs in
//! a dense `[AppId::COUNT × NUM_PROFILES × 2 × batch]` array (no hashing
//! on the hot path), per-(app, offload) admissibility bitmasks — the
//! precomputed profile preference table; admissibility is occupancy-
//! independent, co-residency only stretches the runtime — and
//! per-(app, profile, occupancy) rewards at the policy's α (see
//! `benches/placement.rs`).

use super::fleet::{Fleet, MAX_BATCH};
use crate::gpu::nvlink::{Dir, NvlinkModel};
use crate::gpu::{pipelines::ALL_PIPELINES, GpuSpec};
use crate::mig::profile::{GiProfile, ProfileId, ALL_PROFILES, NUM_PROFILES};
use crate::offload::OffloadPlan;
use crate::reward::{reward, ConfigEval, GpuTotals};
use crate::sharing::scheme::{partitions, Scheme};
use crate::sharing::ContextModel;
use crate::workload::{apps, AppId, ExecEnv};

/// The dispatch policy of the serving layer.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    FirstFit,
    BestFit,
    /// Reward-maximizing admission with offloading, α in centi-units.
    OffloadAware { alpha_centi: u32 },
}

impl PolicyKind {
    /// Parse a policy name. `offload-aware` takes an optional α suffix
    /// (`offload-aware:0.25`); bare `offload-aware` defaults to α=0.10.
    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s {
            "first-fit" => return Some(PolicyKind::FirstFit),
            "best-fit" => return Some(PolicyKind::BestFit),
            "offload-aware" => return Some(PolicyKind::OffloadAware { alpha_centi: 10 }),
            _ => {}
        }
        let alpha: f64 = s.strip_prefix("offload-aware:")?.parse().ok()?;
        if !alpha.is_finite() || !(0.0..=100.0).contains(&alpha) {
            return None;
        }
        Some(PolicyKind::OffloadAware {
            alpha_centi: (alpha * 100.0).round() as u32,
        })
    }

    /// Canonical name; `parse(label())` round-trips.
    pub fn label(&self) -> String {
        match self {
            PolicyKind::FirstFit => "first-fit".into(),
            PolicyKind::BestFit => "best-fit".into(),
            PolicyKind::OffloadAware { alpha_centi } => {
                format!("offload-aware:{:.2}", *alpha_centi as f64 / 100.0)
            }
        }
    }

    pub fn allows_offload(&self) -> bool {
        matches!(self, PolicyKind::OffloadAware { .. })
    }
}

/// The modelled cost of running one app on one profile at one co-residency
/// (possibly with offloading): service time plus the average activity
/// rates the fleet power model integrates while the job runs.
#[derive(Debug, Clone, Copy)]
pub struct PlacementCost {
    pub runtime_s: f64,
    /// Resident footprint on the instance (GiB), after any offloading.
    /// Occupancy-independent: the offload plan is sized against the solo
    /// cap, co-residency only changes how fast the data is consumed.
    pub resident_gib: f64,
    pub offloaded: bool,
    /// Average achieved occupancy on the instance (reward input).
    pub occupancy: f64,
    /// Average per-pipeline FLOP rates while running (TFLOP/s).
    pub flop_tflops: [f64; 5],
    /// Average HBM traffic while running (TB/s).
    pub hbm_tbs: f64,
    /// Average C2C traffic while running (TB/s).
    pub c2c_tbs: f64,
}

/// Cost evaluator + cache shared by all policies. All memo tables are
/// dense arrays indexed by `AppId::index` / `ProfileId::index` /
/// occupancy − 1 — the hot path never hashes.
pub struct Planner {
    spec: GpuSpec,
    nvlink: NvlinkModel,
    ctx_gib: f64,
    scale: f64,
    /// Max co-resident jobs per slot this planner sizes its tables for
    /// (must match the fleet it plans over).
    batch: u32,
    /// Per-co-runner compute-pipeline interference under shared-GI MPS
    /// co-residency, pulled from the `Scheme::MigSharedGi` partition model
    /// — the co-run characterization feeding the cluster cost model.
    shared_interference: f64,
    /// Outer `Option` = "computed?"; inner = the (possibly impossible)
    /// placement cost. `[app × profile × offload × occupancy]`.
    cost_cache: Vec<Option<Option<PlacementCost>>>,
    /// Admissible-profile bitmask per `[app × offload]` — the per-app
    /// profile preference table (bit i ⇔ `ALL_PROFILES[i]` can host).
    /// Occupancy-independent: co-residency stretches the runtime but
    /// never flips feasibility.
    admissible: [Option<u8>; AppId::COUNT * 2],
    /// Whole-GPU runtime per app (the P_GPU reward basis).
    full_runtime: [Option<f64>; AppId::COUNT],
    /// §VI-B rewards `[app × profile × occupancy]` at `reward_alpha_centi`.
    reward_cache: Vec<Option<f64>>,
    reward_alpha_centi: Option<u32>,
    /// Direct (unscaled) footprint per app, for reconfiguration sizing —
    /// precomputed so the dispatch hot path never rebuilds app models.
    footprint: [f64; AppId::COUNT],
}

impl Planner {
    /// A planner for the classic one-job-per-slot system (`batch = 1`).
    pub fn new(workload_scale: f64) -> Planner {
        Planner::with_batch(workload_scale, 1)
    }

    /// A planner sized for slots hosting up to `batch` co-resident jobs.
    pub fn with_batch(workload_scale: f64, batch: u32) -> Planner {
        assert!(workload_scale > 0.0);
        assert!(
            (1..=MAX_BATCH).contains(&batch),
            "per-slot batch must be 1..={MAX_BATCH}, got {batch}"
        );
        let mut footprint = [0.0f64; AppId::COUNT];
        for app in apps::all() {
            footprint[app.index()] = apps::model(app).footprint_gib;
        }
        let spec = GpuSpec::gh_h100_96gb();
        let shared_interference = partitions(&Scheme::MigSharedGi { copies: 2 }, &spec)
            .expect("MigSharedGi partition model")[0]
            .interference;
        let b = batch as usize;
        Planner {
            spec,
            nvlink: NvlinkModel::default(),
            ctx_gib: ContextModel::default().mig_per_process_gib,
            scale: workload_scale,
            batch,
            shared_interference,
            cost_cache: vec![None; AppId::COUNT * NUM_PROFILES * 2 * b],
            admissible: [None; AppId::COUNT * 2],
            full_runtime: [None; AppId::COUNT],
            reward_cache: vec![None; AppId::COUNT * NUM_PROFILES * b],
            reward_alpha_centi: None,
            footprint,
        }
    }

    pub fn ctx_gib(&self) -> f64 {
        self.ctx_gib
    }

    /// Max co-resident jobs per slot this planner is sized for.
    pub fn batch(&self) -> u32 {
        self.batch
    }

    /// Direct memory footprint of `app` (GiB) — the reconfiguration-sizing
    /// input.
    pub fn footprint_gib(&self, app: AppId) -> f64 {
        self.footprint[app.index()]
    }

    #[inline]
    fn cost_idx(&self, app: AppId, profile: ProfileId, allow_offload: bool, occ: u32) -> usize {
        ((app.index() * NUM_PROFILES + profile.index()) * 2 + allow_offload as usize)
            * self.batch as usize
            + (occ as usize - 1)
    }

    /// Cost of running `app` alone on `profile` — the unbatched
    /// (occupancy 1) class, which is also the admissibility gate.
    pub fn cost(
        &mut self,
        app: AppId,
        profile: ProfileId,
        allow_offload: bool,
    ) -> Option<PlacementCost> {
        self.cost_at(app, profile, allow_offload, 1)
    }

    /// Cost of running `app` on `profile` with `occ` co-residents in
    /// total (itself included; `1..=batch`). `allow_offload = false`
    /// returns `None` unless the footprint fits directly; `true`
    /// additionally tries an `OffloadPlan` (which may still fail: ≥25%
    /// must stay resident). Memoized.
    pub fn cost_at(
        &mut self,
        app: AppId,
        profile: ProfileId,
        allow_offload: bool,
        occ: u32,
    ) -> Option<PlacementCost> {
        debug_assert!((1..=self.batch).contains(&occ));
        let i = self.cost_idx(app, profile, allow_offload, occ);
        if let Some(c) = self.cost_cache[i] {
            return c;
        }
        let c = self.compute_cost(app, profile, allow_offload, occ);
        self.cost_cache[i] = Some(c);
        c
    }

    fn compute_cost(
        &self,
        app: AppId,
        profile: ProfileId,
        allow_offload: bool,
        occ: u32,
    ) -> Option<PlacementCost> {
        let prof = GiProfile::get(profile);
        let model = apps::model(app).scaled(self.scale);
        let cap = prof.mem_gib - self.ctx_gib;
        let plan = if model.footprint_gib <= cap {
            None
        } else if allow_offload {
            match OffloadPlan::plan(&model, cap) {
                Ok(p) => Some(p),
                Err(_) => return None,
            }
        } else {
            return None;
        };
        let offloaded = plan.as_ref().map(|p| p.spilled_gib > 0.0).unwrap_or(false);
        let resident_gib = plan
            .as_ref()
            .map(|p| p.effective_footprint_gib())
            .unwrap_or(model.footprint_gib);
        let run_model = plan.as_ref().map(|p| p.apply(&model)).unwrap_or(model);
        // MPS-within-MIG co-residency (`occ` clients on the slice): equal
        // SM share, equal share of the slice's bandwidth pool, and the
        // per-co-runner compute interference of shared-GI co-runs. The
        // C2C direct rate follows the SMs in flight (Table IVb saturation
        // curve), so it shrinks with the SM share automatically. At
        // occ = 1 every term reduces to the unbatched environment exactly.
        let sms = (prof.sms / occ).max(1);
        let env = ExecEnv {
            sms,
            clock_frac: 1.0,
            bw_gibs: prof.mem_bw_gibs / occ as f64,
            c2c_bw_gibs: self.nvlink.direct_bw_gibs(sms, Dir::H2D),
            interference: 1.0 + self.shared_interference * (occ as f64 - 1.0),
            time_share: 1.0,
        };
        let runtime_s =
            run_model.runtime_quiet_s(&self.spec, &env) + run_model.startup_s * self.scale;
        if runtime_s <= 0.0 {
            return None;
        }
        // Average activity rates for the fleet energy model.
        let mut flop_tflops = [0.0f64; 5];
        let mut hbm_bytes = 0.0;
        let mut c2c_bytes = 0.0;
        for ph in &run_model.phases {
            let reps = ph.repeats as f64;
            for k in &ph.kernels {
                hbm_bytes += reps * k.hbm_bytes;
                c2c_bytes += reps * k.c2c_bytes;
                for p in ALL_PIPELINES {
                    flop_tflops[p.index()] += reps * k.flops * k.mix.frac(p);
                }
            }
        }
        for f in &mut flop_tflops {
            *f /= runtime_s * 1e12;
        }
        Some(PlacementCost {
            runtime_s,
            resident_gib,
            offloaded,
            occupancy: run_model.avg_occupancy_quiet(&self.spec, &env),
            flop_tflops,
            hbm_tbs: hbm_bytes / runtime_s / 1e12,
            c2c_tbs: c2c_bytes / runtime_s / 1e12,
        })
    }

    /// Bitmask of profiles that can host `app` (bit i ⇔ `ALL_PROFILES[i]`),
    /// memoized per (app, offload) — the precomputed preference table the
    /// indexed policies walk. Occupancy-independent.
    fn admissible_mask(&mut self, app: AppId, allow_offload: bool) -> u8 {
        let i = app.index() * 2 + allow_offload as usize;
        if let Some(m) = self.admissible[i] {
            return m;
        }
        let mut m = 0u8;
        for pid in ALL_PROFILES {
            if self.cost(app, pid, allow_offload).is_some() {
                m |= 1 << pid.index();
            }
        }
        self.admissible[i] = Some(m);
        m
    }

    /// Runtime of `app` on the whole GPU (the P_GPU reward basis).
    pub fn full_gpu_runtime_s(&mut self, app: AppId) -> f64 {
        if let Some(t) = self.full_runtime[app.index()] {
            return t;
        }
        let model = apps::model(app).scaled(self.scale);
        let env = ExecEnv {
            sms: self.spec.sms,
            clock_frac: 1.0,
            bw_gibs: self.spec.mem_bw_gibs,
            c2c_bw_gibs: self.nvlink.direct_both_cap_gibs,
            interference: 1.0,
            time_share: 1.0,
        };
        let t = model.runtime_quiet_s(&self.spec, &env) + model.startup_s * self.scale;
        self.full_runtime[app.index()] = Some(t);
        t
    }

    /// §VI-B reward of running `app` on `profile` at cost `c`.
    pub fn reward_of(
        &mut self,
        app: AppId,
        profile: ProfileId,
        c: &PlacementCost,
        alpha: f64,
    ) -> f64 {
        let prof = GiProfile::get(profile);
        let p_gpu = 1.0 / self.full_gpu_runtime_s(app).max(1e-9);
        let eval = ConfigEval {
            config: prof.name.to_string(),
            perf: 1.0 / c.runtime_s.max(1e-9),
            occupancy: c.occupancy,
            sms: prof.sms,
            mem_instance_gib: prof.mem_gib,
            mem_app_gib: c.resident_gib + self.ctx_gib,
        };
        let totals = GpuTotals {
            sms: self.spec.sms,
            mem_gib: self.spec.mem_usable_gib,
            perf_full_gpu: p_gpu,
        };
        reward(&eval, &totals, alpha).reward
    }

    /// `reward_of` memoized per (app, profile, occupancy) at a fixed α —
    /// the value depends on nothing else, so the offload-aware walk reads
    /// a dense table. Switching α (a different policy instance) flushes
    /// the table.
    fn cached_reward(
        &mut self,
        app: AppId,
        profile: ProfileId,
        occ: u32,
        alpha_centi: u32,
        c: &PlacementCost,
    ) -> f64 {
        if self.reward_alpha_centi != Some(alpha_centi) {
            self.reward_cache.iter_mut().for_each(|r| *r = None);
            self.reward_alpha_centi = Some(alpha_centi);
        }
        let i = (app.index() * NUM_PROFILES + profile.index()) * self.batch as usize
            + (occ as usize - 1);
        if let Some(r) = self.reward_cache[i] {
            return r;
        }
        let r = self.reward_of(app, profile, c, alpha_centi as f64 / 100.0);
        self.reward_cache[i] = Some(r);
        r
    }

    /// Pick a slot seat for `app` under `policy`, via the fleet's
    /// per-(profile, occupancy) open index: a walk over
    /// ≤ `NUM_PROFILES × batch` co-residency classes. Returns
    /// `(gpu, slot, cost)` with the cost at the occupancy the job would
    /// run at. Deterministic, and bit-identical to `place_scan`.
    pub fn place(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        policy: PolicyKind,
    ) -> Option<(usize, usize, PlacementCost)> {
        debug_assert_eq!(fleet.batch(), self.batch, "planner/fleet batch mismatch");
        let kmax = fleet.batch() as usize;
        match policy {
            PolicyKind::FirstFit => {
                let mask = self.admissible_mask(app, false);
                let mut best: Option<(usize, usize, ProfileId, u32)> = None;
                for pid in ALL_PROFILES {
                    if mask & (1 << pid.index()) == 0 {
                        continue;
                    }
                    let need = self.cost(app, pid, false).unwrap().resident_gib + self.ctx_gib;
                    for m in 0..kmax {
                        if let Some((g, s)) = fleet.first_open_fitting(pid, m, need) {
                            if best
                                .map(|(bg, bs, _, _)| (g, s) < (bg, bs))
                                .unwrap_or(true)
                            {
                                best = Some((g, s, pid, m as u32 + 1));
                            }
                        }
                    }
                }
                best.map(|(g, s, pid, occ)| {
                    (g, s, self.cost_at(app, pid, false, occ).unwrap())
                })
            }
            PolicyKind::BestFit => {
                let mask = self.admissible_mask(app, false);
                // ALL_PROFILES ascends by SMs; within a profile prefer the
                // most occupied open slot (densest packing keeps empty
                // slots free), then the lowest (gpu, slot). Folding the
                // class-firsts with the scan's strict preference keeps the
                // two paths identical even if two profiles tie on SMs.
                let mut best: Option<(u32, usize, usize, usize, ProfileId)> = None;
                for pid in ALL_PROFILES {
                    if mask & (1 << pid.index()) == 0 {
                        continue;
                    }
                    let need = self.cost(app, pid, false).unwrap().resident_gib + self.ctx_gib;
                    let sms = GiProfile::get(pid).sms;
                    for m in 0..kmax {
                        if let Some((g, s)) = fleet.first_open_fitting(pid, m, need) {
                            let better = match &best {
                                None => true,
                                Some((bsms, bm, bg, bs, _)) => {
                                    sms < *bsms
                                        || (sms == *bsms
                                            && (m > *bm
                                                || (m == *bm && (g, s) < (*bg, *bs))))
                                }
                            };
                            if better {
                                best = Some((sms, m, g, s, pid));
                            }
                        }
                    }
                }
                best.map(|(_, m, g, s, pid)| {
                    (g, s, self.cost_at(app, pid, false, m as u32 + 1).unwrap())
                })
            }
            PolicyKind::OffloadAware { alpha_centi } => {
                // One candidate per (profile, occupancy) class with a
                // fitting open slot, at the class's first (gpu, slot).
                // Folding them in (gpu, slot) order with the per-slot
                // preference of the naive scan reproduces its choice
                // exactly: within a class every slot ties on (reward,
                // SMs), so only first encounters matter, and the scan
                // encounters classes in first-fitting-slot order.
                let mask = self.admissible_mask(app, true);
                let mut cands =
                    [(0usize, 0usize, ProfileId::P1g12gb, 0u8); NUM_PROFILES * MAX_BATCH as usize];
                let mut n = 0;
                for pid in ALL_PROFILES {
                    if mask & (1 << pid.index()) == 0 {
                        continue;
                    }
                    let need = self.cost(app, pid, true).unwrap().resident_gib + self.ctx_gib;
                    for m in 0..kmax {
                        if let Some((g, s)) = fleet.first_open_fitting(pid, m, need) {
                            cands[n] = (g, s, pid, m as u8);
                            n += 1;
                        }
                    }
                }
                cands[..n].sort_unstable();
                let mut best: Option<(f64, u32, usize, usize, ProfileId, u8)> = None;
                for &(g, s, pid, m) in &cands[..n] {
                    let occ = m as u32 + 1;
                    let c = self.cost_at(app, pid, true, occ).unwrap();
                    let r = self.cached_reward(app, pid, occ, alpha_centi, &c);
                    let sms = GiProfile::get(pid).sms;
                    let better = match &best {
                        None => true,
                        Some((br, bsms, ..)) => r > *br || (r == *br && sms < *bsms),
                    };
                    if better {
                        best = Some((r, sms, g, s, pid, m));
                    }
                }
                best.map(|(_, _, g, s, pid, m)| {
                    (g, s, self.cost_at(app, pid, true, m as u32 + 1).unwrap())
                })
            }
        }
    }

    /// The naive full `gpus × slots` scan — the differential-test oracle
    /// for `place` (and the baseline `benches/placement.rs` measures the
    /// indexed walk against).
    pub fn place_scan(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        policy: PolicyKind,
    ) -> Option<(usize, usize, PlacementCost)> {
        debug_assert_eq!(fleet.batch(), self.batch, "planner/fleet batch mismatch");
        let kmax = fleet.batch();
        match policy {
            PolicyKind::FirstFit => {
                for (g, gpu) in fleet.gpus.iter().enumerate() {
                    if gpu.reconfiguring() {
                        continue;
                    }
                    for (s, slot) in gpu.slots.iter().enumerate() {
                        let occ = slot.occupancy() as u32;
                        if occ >= kmax {
                            continue;
                        }
                        if let Some(c) = self.cost_at(app, slot.profile.id, false, occ + 1) {
                            if occ > 0 && !slot.fits(c.resident_gib + self.ctx_gib) {
                                continue;
                            }
                            return Some((g, s, c));
                        }
                    }
                }
                None
            }
            PolicyKind::BestFit => {
                let mut best: Option<(u32, usize, usize, usize, PlacementCost)> = None;
                for (g, gpu) in fleet.gpus.iter().enumerate() {
                    if gpu.reconfiguring() {
                        continue;
                    }
                    for (s, slot) in gpu.slots.iter().enumerate() {
                        let occ = slot.occupancy();
                        if occ as u32 >= kmax {
                            continue;
                        }
                        if let Some(c) =
                            self.cost_at(app, slot.profile.id, false, occ as u32 + 1)
                        {
                            if occ > 0 && !slot.fits(c.resident_gib + self.ctx_gib) {
                                continue;
                            }
                            let sms = slot.profile.sms;
                            let better = match &best {
                                None => true,
                                Some((bsms, bocc, ..)) => {
                                    sms < *bsms || (sms == *bsms && occ > *bocc)
                                }
                            };
                            if better {
                                best = Some((sms, occ, g, s, c));
                            }
                        }
                    }
                }
                best.map(|(_, _, g, s, c)| (g, s, c))
            }
            PolicyKind::OffloadAware { alpha_centi } => {
                let mut best: Option<(f64, u32, usize, usize, PlacementCost)> = None;
                for (g, gpu) in fleet.gpus.iter().enumerate() {
                    if gpu.reconfiguring() {
                        continue;
                    }
                    for (s, slot) in gpu.slots.iter().enumerate() {
                        let occ = slot.occupancy() as u32;
                        if occ >= kmax {
                            continue;
                        }
                        let c = match self.cost_at(app, slot.profile.id, true, occ + 1) {
                            Some(c) => c,
                            None => continue,
                        };
                        if occ > 0 && !slot.fits(c.resident_gib + self.ctx_gib) {
                            continue;
                        }
                        let r =
                            self.cached_reward(app, slot.profile.id, occ + 1, alpha_centi, &c);
                        let sms = slot.profile.sms;
                        // Exact comparisons (no epsilon): tie-breaking
                        // must be order-insensitive for the class-level
                        // walk in `place` to match slot-level scanning.
                        let better = match &best {
                            None => true,
                            Some((br, bsms, ..)) => r > *br || (r == *br && sms < *bsms),
                        };
                        if better {
                            best = Some((r, sms, g, s, c));
                        }
                    }
                }
                best.map(|(_, _, g, s, c)| (g, s, c))
            }
        }
    }

    /// Whether `app` could run on *some* profile of the per-GPU layouts the
    /// fleet currently has or is reconfiguring toward — the trigger guard
    /// for dynamic reconfiguration. O(profile classes) via the fleet's
    /// layout-class counts.
    pub fn fits_current_layouts(&mut self, fleet: &Fleet, app: AppId, allow_offload: bool) -> bool {
        for pid in ALL_PROFILES {
            if fleet.has_layout_class(pid) && self.cost(app, pid, allow_offload).is_some() {
                return true;
            }
        }
        false
    }

    /// `fits_current_layouts` by full GPU×layout scan — the
    /// differential-test oracle.
    pub fn fits_current_layouts_scan(
        &mut self,
        fleet: &Fleet,
        app: AppId,
        allow_offload: bool,
    ) -> bool {
        for gpu in &fleet.gpus {
            for &p in gpu.effective_layout() {
                if self.cost(app, p, allow_offload).is_some() {
                    return true;
                }
            }
        }
        false
    }

    /// Whether `app` is servable at all on this hardware (largest profile,
    /// offloading allowed when the policy supports it).
    pub fn servable(&mut self, app: AppId, allow_offload: bool) -> bool {
        self.cost(app, ProfileId::P7g96gb, allow_offload).is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fleet::{Fleet, LayoutPreset};

    #[test]
    fn cost_direct_vs_offload() {
        let mut pl = Planner::new(0.05);
        // Small job fits 1g directly; the offload-allowed cost is identical
        // (no spill happens).
        let direct = pl.cost(AppId::Faiss, ProfileId::P1g12gb, false).unwrap();
        let relaxed = pl.cost(AppId::Faiss, ProfileId::P1g12gb, true).unwrap();
        assert!(!direct.offloaded && !relaxed.offloaded);
        assert_eq!(direct.runtime_s, relaxed.runtime_s);
        // 16.5 GiB llama does not fit 1g directly but offloads.
        assert!(pl.cost(AppId::Llama3Fp16, ProfileId::P1g12gb, false).is_none());
        let off = pl.cost(AppId::Llama3Fp16, ProfileId::P1g12gb, true).unwrap();
        assert!(off.offloaded);
        assert!(off.resident_gib <= 11.0 - pl.ctx_gib() + 1e-9);
        assert!(off.c2c_tbs > 0.0, "offloaded runs drive C2C traffic");
        // Offloading on 1g is slower than running directly on 2g.
        let two_g = pl.cost(AppId::Llama3Fp16, ProfileId::P2g24gb, false).unwrap();
        assert!(off.runtime_s > two_g.runtime_s);
    }

    #[test]
    fn contention_slowdown_monotone_and_batch1_identical() {
        // The co-residency classes: runtime must be monotone
        // non-decreasing in the number of co-residents, the resident
        // footprint must not depend on occupancy, and a batch-1 planner's
        // costs must be bit-identical to a batched planner's occupancy-1
        // column (the `--batch 1` reproduction guarantee).
        let mut p1 = Planner::new(0.05);
        let mut pk = Planner::with_batch(0.05, MAX_BATCH);
        let apps = [
            AppId::Faiss,
            AppId::Hotspot,
            AppId::Llama3Fp16,
            AppId::Qiskit31,
            AppId::NekRs,
        ];
        for app in apps {
            for pid in ALL_PROFILES {
                for allow in [false, true] {
                    let solo = p1.cost(app, pid, allow);
                    let col1 = pk.cost_at(app, pid, allow, 1);
                    match (solo, col1) {
                        (None, None) => {}
                        (Some(a), Some(b)) => {
                            assert_eq!(a.runtime_s.to_bits(), b.runtime_s.to_bits());
                            assert_eq!(a.resident_gib.to_bits(), b.resident_gib.to_bits());
                            assert_eq!(a.occupancy.to_bits(), b.occupancy.to_bits());
                            assert_eq!(a.hbm_tbs.to_bits(), b.hbm_tbs.to_bits());
                        }
                        _ => panic!("{app:?} {pid:?} allow={allow}: admissibility diverged"),
                    }
                    let mut prev: Option<PlacementCost> = None;
                    for occ in 1..=MAX_BATCH {
                        let c = pk.cost_at(app, pid, allow, occ);
                        assert_eq!(
                            c.is_some(),
                            solo.is_some(),
                            "admissibility must be occupancy-independent"
                        );
                        if let Some(c) = c {
                            if let Some(p) = prev {
                                assert!(
                                    c.runtime_s >= p.runtime_s,
                                    "{app:?} {pid:?} occ={occ}: slowdown not monotone \
                                     ({} < {})",
                                    c.runtime_s,
                                    p.runtime_s
                                );
                                assert_eq!(
                                    c.resident_gib.to_bits(),
                                    p.resident_gib.to_bits(),
                                    "resident footprint is occupancy-independent"
                                );
                            }
                            prev = Some(c);
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn first_fit_vs_best_fit_slot_choice() {
        // Mixed GPU 2 layout is [4g.48gb, 3g.48gb]; a small job should go
        // to the 3g slot under best-fit but the 4g slot under first-fit.
        let mut fleet = Fleet::new(3, LayoutPreset::Mixed).unwrap();
        // Occupy every slot on GPUs 0 and 1 so only GPU 2 is free.
        for g in 0..2 {
            for s in 0..fleet.gpus[g].slots.len() {
                fleet.start_job(g, s, 0, 0.0, 100.0, 0.5);
            }
        }
        let mut pl = Planner::new(0.05);
        let (g_ff, s_ff, _) = pl.place(&fleet, AppId::Hotspot, PolicyKind::FirstFit).unwrap();
        assert_eq!((g_ff, s_ff), (2, 0), "first-fit takes the 4g slot");
        let (g_bf, s_bf, _) = pl.place(&fleet, AppId::Hotspot, PolicyKind::BestFit).unwrap();
        assert_eq!((g_bf, s_bf), (2, 1), "best-fit takes the smaller 3g slot");
    }

    #[test]
    fn batching_admits_onto_occupied_slots_when_nothing_is_empty() {
        // One 7g slot, batch 3: the first job takes the empty slot; the
        // next co-locates (first-fit) with a longer modelled runtime; a
        // full slot admits nothing.
        let mut fleet = Fleet::with_batch(1, LayoutPreset::AllBig, 3).unwrap();
        let mut pl = Planner::with_batch(0.05, 3);
        let (g, s, c1) = pl.place(&fleet, AppId::Hotspot, PolicyKind::FirstFit).unwrap();
        assert_eq!((g, s), (0, 0));
        fleet.start_job(g, s, 0, 0.0, c1.runtime_s, c1.resident_gib + pl.ctx_gib());
        let (g, s, c2) = pl.place(&fleet, AppId::Hotspot, PolicyKind::FirstFit).unwrap();
        assert_eq!((g, s), (0, 0), "co-locates on the occupied slot");
        assert!(c2.runtime_s > c1.runtime_s, "co-residency slows the job");
        fleet.start_job(g, s, 1, 0.0, c2.runtime_s, c2.resident_gib + pl.ctx_gib());
        let (_, _, c3) = pl.place(&fleet, AppId::Hotspot, PolicyKind::FirstFit).unwrap();
        assert!(c3.runtime_s > c2.runtime_s);
        fleet.start_job(0, 0, 2, 0.0, c3.runtime_s, c3.resident_gib + pl.ctx_gib());
        assert!(
            pl.place(&fleet, AppId::Hotspot, PolicyKind::FirstFit).is_none(),
            "full slot admits nothing"
        );
        // An unbatched planner/fleet pair refuses the second job outright.
        let mut f1 = Fleet::new(1, LayoutPreset::AllBig).unwrap();
        let mut p1 = Planner::new(0.05);
        let (g, s, c) = p1.place(&f1, AppId::Hotspot, PolicyKind::FirstFit).unwrap();
        f1.start_job(g, s, 0, 0.0, c.runtime_s, c.resident_gib + p1.ctx_gib());
        assert!(p1.place(&f1, AppId::Hotspot, PolicyKind::FirstFit).is_none());
    }

    #[test]
    fn offload_aware_weighs_co_residency_by_reward() {
        // Two 7g slots, batch 2: the reward model arbitrates between the
        // empty slot (faster run, more SM waste for a poor scaler) and
        // co-residency (slower run, denser packing). Whatever it picks,
        // the indexed walk and the naive scan must agree at every step,
        // and once every seat is taken the policy must return None
        // rather than overcommit.
        let mut fleet = Fleet::with_batch(2, LayoutPreset::AllBig, 2).unwrap();
        let mut pl = Planner::with_batch(0.05, 2);
        let policy = PolicyKind::OffloadAware { alpha_centi: 10 };
        for job in 0..4u32 {
            let fast = pl.place(&fleet, AppId::Faiss, policy);
            let scan = pl.place_scan(&fleet, AppId::Faiss, policy);
            assert_eq!(
                fast.map(|(g, s, _)| (g, s)),
                scan.map(|(g, s, _)| (g, s)),
                "job {job}"
            );
            let (g, s, c) = fast.unwrap();
            let occ_runtime = c.runtime_s;
            // The cost handed back is the cost at the occupancy joined.
            let expect = pl
                .cost_at(
                    AppId::Faiss,
                    ProfileId::P7g96gb,
                    true,
                    fleet.gpus[g].slots[s].occupancy() as u32 + 1,
                )
                .unwrap();
            assert_eq!(occ_runtime.to_bits(), expect.runtime_s.to_bits());
            fleet.start_job(g, s, job, 0.0, c.runtime_s, c.resident_gib + pl.ctx_gib());
        }
        // 2 slots × 2 seats are gone: nothing left to offer.
        assert!(pl.place(&fleet, AppId::Faiss, policy).is_none());
        assert!(pl.place_scan(&fleet, AppId::Faiss, policy).is_none());
    }

    #[test]
    fn batching_respects_the_slice_memory_budget() {
        // Offloaded llama fills a 1g slice to its solo cap: the slice's
        // memory cannot hold a second resident, so batching never
        // overcommits it — even at batch 4.
        let mut fleet = Fleet::with_batch(1, LayoutPreset::AllSmall, 4).unwrap();
        let mut pl = Planner::with_batch(0.05, 4);
        let policy = PolicyKind::OffloadAware { alpha_centi: 10 };
        let (g, s, c) = pl.place(&fleet, AppId::Llama3Fp16, policy).unwrap();
        assert!(c.offloaded);
        fleet.start_job(g, s, 0, 0.0, c.runtime_s, c.resident_gib + pl.ctx_gib());
        // The occupied slot is memory-full; the next llama must take a
        // different (empty) slot, never co-locate.
        let (g2, s2, _) = pl.place(&fleet, AppId::Llama3Fp16, policy).unwrap();
        assert_ne!((g2, s2), (g, s), "memory-full slot refuses co-residents");
        // And both paths agree on that.
        let scan = pl.place_scan(&fleet, AppId::Llama3Fp16, policy).map(|(g, s, _)| (g, s));
        assert_eq!(scan, Some((g2, s2)));
    }

    #[test]
    fn offload_aware_admits_large_jobs_onto_small_slices() {
        let fleet = Fleet::new(1, LayoutPreset::AllSmall).unwrap();
        let mut pl = Planner::new(0.05);
        for policy in [PolicyKind::FirstFit, PolicyKind::BestFit] {
            assert!(
                pl.place(&fleet, AppId::Llama3Fp16, policy).is_none(),
                "{:?} must not fit 16.5 GiB into 11 GiB",
                policy
            );
        }
        let (_, _, c) = pl
            .place(&fleet, AppId::Llama3Fp16, PolicyKind::OffloadAware { alpha_centi: 10 })
            .unwrap();
        assert!(c.offloaded);
    }

    #[test]
    fn indexed_place_matches_naive_scan_across_fleet_states() {
        // Pseudo-random occupancy churn over a mixed fleet at several
        // batch depths: every policy must pick the identical slot through
        // the index and the scan.
        for batch in [1u32, 2, 4] {
            let mut rng = crate::util::Rng::new(0x9A7E + batch as u64);
            let mut fleet = Fleet::with_batch(5, LayoutPreset::Mixed, batch).unwrap();
            let mut pl = Planner::with_batch(0.05, batch);
            let apps = [
                AppId::Faiss,
                AppId::Hotspot,
                AppId::Llama3Fp16,
                AppId::Qiskit31,
                AppId::NekRs,
            ];
            let policies = [
                PolicyKind::FirstFit,
                PolicyKind::BestFit,
                PolicyKind::OffloadAware { alpha_centi: 10 },
                PolicyKind::OffloadAware { alpha_centi: 60 },
            ];
            let mut next_job = 0u32;
            for step in 0..120u32 {
                let g = rng.below(5) as usize;
                if rng.below(2) == 0 {
                    // Admit through the policy machinery so charged memory
                    // is realistic (memory gates stay meaningful).
                    let app = apps[rng.below(apps.len() as u64) as usize];
                    let policy = policies[rng.below(policies.len() as u64) as usize];
                    if let Some((pg, ps, c)) = pl.place(&fleet, app, policy) {
                        fleet.start_job(
                            pg,
                            ps,
                            next_job,
                            step as f64,
                            step as f64 + 9.0,
                            c.resident_gib + pl.ctx_gib(),
                        );
                        next_job += 1;
                    }
                } else if let Some(s) =
                    fleet.gpus[g].slots.iter().position(|s| !s.is_idle())
                {
                    let job = fleet.gpus[g].slots[s].residents[0].job;
                    fleet.finish_job(g, s, job, step as f64);
                }
                for &app in &apps {
                    for &policy in &policies {
                        let fast = pl.place(&fleet, app, policy).map(|(g, s, _)| (g, s));
                        let slow = pl.place_scan(&fleet, app, policy).map(|(g, s, _)| (g, s));
                        assert_eq!(fast, slow, "batch {batch} step {step} {app:?} {policy:?}");
                    }
                }
            }
        }
    }

    #[test]
    fn servable_and_layout_fit_guards() {
        let fleet = Fleet::new(1, LayoutPreset::AllSmall).unwrap();
        let mut pl = Planner::new(0.05);
        assert!(pl.servable(AppId::Llama3Fp16, false), "fits 7g directly");
        assert!(!pl.fits_current_layouts(&fleet, AppId::Llama3Fp16, false));
        assert!(pl.fits_current_layouts(&fleet, AppId::Llama3Fp16, true));
        assert!(pl.fits_current_layouts(&fleet, AppId::Faiss, false));
        // Indexed and scan guards agree, including mid-reconfiguration.
        let mut fleet = fleet;
        fleet
            .begin_reconfig(0, crate::cluster::fleet::class_layout(ProfileId::P2g24gb), 5.0)
            .unwrap();
        for app in [AppId::Llama3Fp16, AppId::Faiss, AppId::Qiskit31] {
            for allow in [false, true] {
                assert_eq!(
                    pl.fits_current_layouts(&fleet, app, allow),
                    pl.fits_current_layouts_scan(&fleet, app, allow),
                    "{app:?} allow={allow}"
                );
            }
        }
    }

    #[test]
    fn reward_prefers_tight_fit_at_low_alpha() {
        let mut pl = Planner::new(0.05);
        // FAISS scales poorly: a 1g slice wastes far less than 7g.
        let c1 = pl.cost(AppId::Faiss, ProfileId::P1g12gb, false).unwrap();
        let c7 = pl.cost(AppId::Faiss, ProfileId::P7g96gb, false).unwrap();
        let r1 = pl.reward_of(AppId::Faiss, ProfileId::P1g12gb, &c1, 0.1);
        let r7 = pl.reward_of(AppId::Faiss, ProfileId::P7g96gb, &c7, 0.1);
        assert!(r1 > r7, "r1={r1} r7={r7}");
    }

    #[test]
    fn policy_parse_accepts_alpha_and_round_trips() {
        assert_eq!(PolicyKind::parse("first-fit"), Some(PolicyKind::FirstFit));
        assert_eq!(PolicyKind::parse("best-fit"), Some(PolicyKind::BestFit));
        assert_eq!(
            PolicyKind::parse("offload-aware"),
            Some(PolicyKind::OffloadAware { alpha_centi: 10 })
        );
        assert_eq!(
            PolicyKind::parse("offload-aware:0.25"),
            Some(PolicyKind::OffloadAware { alpha_centi: 25 })
        );
        assert_eq!(
            PolicyKind::parse("offload-aware:1"),
            Some(PolicyKind::OffloadAware { alpha_centi: 100 })
        );
        assert_eq!(PolicyKind::parse("offload-aware:-1"), None);
        assert_eq!(PolicyKind::parse("offload-aware:nan"), None);
        assert_eq!(PolicyKind::parse("offload-aware:"), None);
        assert_eq!(PolicyKind::parse("bogus"), None);
        for policy in [
            PolicyKind::FirstFit,
            PolicyKind::BestFit,
            PolicyKind::OffloadAware { alpha_centi: 10 },
            PolicyKind::OffloadAware { alpha_centi: 25 },
            PolicyKind::OffloadAware { alpha_centi: 7 },
            PolicyKind::OffloadAware { alpha_centi: 150 },
        ] {
            assert_eq!(
                PolicyKind::parse(&policy.label()),
                Some(policy),
                "label {} must round-trip",
                policy.label()
            );
        }
    }
}
