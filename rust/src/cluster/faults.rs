//! The fault-injection and recovery plane.
//!
//! A production fleet is defined by how it degrades: MIG's isolation
//! story (the source paper's §II) says nothing about the GPU *dying* —
//! ECC double-bit errors and Xid faults kill a single instance's
//! residents, whole-board failures take every slice out for
//! minutes-to-hours, and even a MIG repartition can fail transiently at
//! the driver level. This module models all three as deterministic,
//! seeded virtual-time events injected through the existing `sim::Engine`:
//!
//! - **Whole-GPU hard failures** (`FaultKind::Gpu`): the GPU is
//!   cordoned (`Fleet::cordon_gpu` — every placement surface excludes
//!   it), its residents are orphaned, and it returns after an
//!   exponential repair time (MTTR).
//! - **Slice-level ECC/Xid errors** (`FaultKind::Slice`): one
//!   rng-chosen slot's resident set dies (`Fleet::drain_slot`); the
//!   slot itself survives and keeps serving.
//! - **Transient reconfiguration failures** (`FaultKind::Reconfig`): an
//!   in-flight repartition aborts — the latency is paid but the old
//!   layout survives. A fault of this kind drawn while the GPU is not
//!   repartitioning hits nothing (the hazard only bites the driver
//!   operation).
//!
//! Orphaned jobs are requeued as **bounded retries**: a job keeps its
//! original arrival time and absolute deadline (retries compete honestly
//! for admission), gains `JobState::Retrying` transitions up to
//! `retries` times, and dies `JobState::Failed` after that. The restart
//! cost comes from the checkpoint/restore model: with `--checkpoint-dt`
//! set, work up to the last checkpoint boundary is preserved as a
//! *fraction of the job* and the retry's service time shrinks
//! accordingly; without it (`dt = inf`, the default) a retry restarts
//! from scratch.
//!
//! ## Correlated failures and graceful degradation
//!
//! Beyond independent per-GPU hazards, the plane models the *system*
//! structure failures actually follow:
//!
//! - **Fault domains** ([`FaultDomains`], `--fault-domains node|rack:R`):
//!   a domain-level event (a node losing power, a rack losing cooling)
//!   cordons every in-service member GPU at once. Domain streams key on
//!   the fleet-global domain id, so correlated failures stay
//!   bit-identical across thread counts too.
//! - **Finite repair crews** (`repair_crews`, `--repair-crews N`):
//!   repair stops being instant capacity. Each node has `N` crews; a
//!   cordoned board whose crews are all busy waits in a deterministic
//!   FIFO queue, and its MTTR draw becomes *service time* — a failure
//!   burst leaves boards out far longer than MTTR. `0` (the default)
//!   keeps the PR 7 unlimited-repair behavior bit-for-bit.
//! - **Brown-out shedding** ([`ShedPolicy`], `--shed-policy
//!   watermark:F`): when a capacity-loss event leaves fewer than `F` of
//!   a node's boards in service, admission sheds the lowest-slack
//!   pending jobs (terminal `JobState::Shed`) instead of letting the
//!   whole queue rot to deadline expiry.
//!
//! ## Inertness and determinism
//!
//! The plane is **inert by default**, the same contract as the telemetry
//! plane's `NullSink`: an inactive `FaultConfig` schedules *no* events
//! (any scheduled event would change the engine's popped-event count and
//! therefore the report), so every ServeReport and golden fixture stays
//! byte-identical with the plane compiled in. When active, per-GPU fault
//! streams are drawn from `Rng::new(mix(seed, global gpu id))` — a pure
//! function of the serve seed and the *global* GPU id, never of the
//! shard partitioning — so the merged report is bit-identical across
//! `--threads 1/2/4/8`. Domain streams follow the same pattern keyed on
//! the fleet-global domain id, and every degradation knob defaults off,
//! so a config that sets none of them reproduces the PR 7 fault plane
//! byte-for-byte.

use crate::util::Rng;
use anyhow::{bail, ensure};

/// The three modeled failure classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Whole-board hard failure: cordon-and-drain, repair after MTTR.
    Gpu,
    /// Slice-level ECC/Xid error: one slot's resident set dies.
    Slice,
    /// Transient repartition failure: the in-flight reconfiguration
    /// aborts (latency paid, old layout kept).
    Reconfig,
}

impl FaultKind {
    pub fn label(&self) -> &'static str {
        match self {
            FaultKind::Gpu => "gpu",
            FaultKind::Slice => "slice",
            FaultKind::Reconfig => "reconfig",
        }
    }
}

/// Correlated fault-domain scoping (`--fault-domains`). A domain-level
/// event cordons every in-service member GPU at once.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultDomains {
    /// No correlated failures (the default): only independent per-GPU
    /// hazards fire.
    None,
    /// One domain per node shard: a domain event takes the whole node's
    /// boards down together.
    Node,
    /// Fixed-width racks of `R` consecutive fleet-global GPUs (the last
    /// rack may be narrower). Racks can straddle node boundaries; every
    /// owning shard draws the identical domain stream, so the cordons
    /// still land at identical virtual times.
    Rack(u32),
}

impl FaultDomains {
    /// Parse the `--fault-domains` grammar: `none` | `node` | `rack:R`.
    pub fn parse(spec: &str) -> crate::Result<FaultDomains> {
        let spec = spec.trim();
        match spec {
            "" | "none" => Ok(FaultDomains::None),
            "node" => Ok(FaultDomains::Node),
            _ => match spec.strip_prefix("rack:") {
                Some(r) => {
                    let width: u32 = r.parse().map_err(|_| {
                        anyhow::anyhow!("--fault-domains: '{r}' is not a rack width (in '{spec}')")
                    })?;
                    ensure!(
                        width >= 1,
                        "--fault-domains: rack width must be >= 1, got {width}"
                    );
                    Ok(FaultDomains::Rack(width))
                }
                None => bail!("--fault-domains: unknown grammar '{spec}' (want none|node|rack:R)"),
            },
        }
    }

    pub fn active(&self) -> bool {
        !matches!(self, FaultDomains::None)
    }

    pub fn label(&self) -> String {
        match self {
            FaultDomains::None => "none".to_string(),
            FaultDomains::Node => "node".to_string(),
            FaultDomains::Rack(w) => format!("rack:{w}"),
        }
    }
}

/// Brown-out backpressure (`--shed-policy`). Checked at every
/// capacity-loss event (a GPU or domain cordon).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ShedPolicy {
    /// Never shed (the default): pending jobs only leave the queue by
    /// placement, expiry, or handoff.
    None,
    /// When fewer than this fraction of a node's boards remain in
    /// service, trim the pending queue proportionally to the surviving
    /// fraction, shedding lowest-slack (earliest-deadline) jobs first.
    Watermark(f64),
}

impl ShedPolicy {
    /// Parse the `--shed-policy` grammar: `none` | `watermark:F`,
    /// `F` in (0, 1].
    pub fn parse(spec: &str) -> crate::Result<ShedPolicy> {
        let spec = spec.trim();
        match spec {
            "" | "none" => Ok(ShedPolicy::None),
            _ => match spec.strip_prefix("watermark:") {
                Some(f) => {
                    let frac: f64 = f.parse().map_err(|_| {
                        anyhow::anyhow!("--shed-policy: '{f}' is not a fraction (in '{spec}')")
                    })?;
                    ensure!(
                        frac > 0.0 && frac <= 1.0,
                        "--shed-policy: watermark must be in (0, 1], got {frac}"
                    );
                    Ok(ShedPolicy::Watermark(frac))
                }
                None => {
                    bail!("--shed-policy: unknown grammar '{spec}' (want none|watermark:F)")
                }
            },
        }
    }

    pub fn active(&self) -> bool {
        !matches!(self, ShedPolicy::None)
    }

    pub fn label(&self) -> String {
        match self {
            ShedPolicy::None => "none".to_string(),
            ShedPolicy::Watermark(f) => format!("watermark:{f}"),
        }
    }
}

/// Fault-plane configuration. `Default` is **inert**: no fault kind
/// enabled, so the plane schedules nothing and every report reproduces
/// the pre-plane bytes exactly.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultConfig {
    /// Relative draw weight of whole-GPU failures (0 = disabled).
    pub gpu_w: f64,
    /// Relative draw weight of slice-level ECC/Xid errors.
    pub slice_w: f64,
    /// Relative draw weight of transient reconfiguration failures.
    pub reconfig_w: f64,
    /// Mean time to failure per GPU (s): fault inter-arrivals are
    /// exponential with this mean, drawn per GPU.
    pub mttf_s: f64,
    /// Mean time to repair a hard-failed GPU (s; exponential).
    pub mttr_s: f64,
    /// Bounded retry budget per job: admission `1 + retries` is the last
    /// one — the next fault kills the job (`JobState::Failed`).
    pub retries: u32,
    /// Checkpoint interval (s of service time). Work up to the last
    /// checkpoint boundary survives a fault; `inf` (the default) means
    /// no checkpointing — a retry restarts from scratch.
    pub checkpoint_dt_s: f64,
    /// Correlated fault-domain scoping. `None` (the default) keeps the
    /// PR 7 independent-hazard behavior bit-for-bit.
    pub domains: FaultDomains,
    /// Repair crews per node: `0` (the default) models unlimited instant
    /// repair capacity (PR 7 behavior, bit-for-bit); `N >= 1` makes
    /// repair a FIFO-queued service with `N` concurrent servers.
    pub repair_crews: u32,
    /// Brown-out shedding policy under capacity loss.
    pub shed: ShedPolicy,
}

impl Default for FaultConfig {
    fn default() -> FaultConfig {
        FaultConfig {
            gpu_w: 0.0,
            slice_w: 0.0,
            reconfig_w: 0.0,
            mttf_s: 3600.0,
            mttr_s: 60.0,
            retries: 2,
            checkpoint_dt_s: f64::INFINITY,
            domains: FaultDomains::None,
            repair_crews: 0,
            shed: ShedPolicy::None,
        }
    }
}

/// Parse a fault spec: comma-separated `kind[:weight]` items, kinds
/// `gpu` | `slice` | `reconfig`, weight defaulting to 1 — e.g.
/// `gpu`, `gpu,slice:2`, `gpu:1,slice:0.5,reconfig:0.25`. `none` (or
/// the empty string) is the explicit inert spec. Returns
/// `(gpu_w, slice_w, reconfig_w)`.
pub fn parse_spec(spec: &str) -> crate::Result<(f64, f64, f64)> {
    let spec = spec.trim();
    if spec.is_empty() || spec == "none" {
        return Ok((0.0, 0.0, 0.0));
    }
    let (mut gpu_w, mut slice_w, mut reconfig_w) = (None, None, None);
    for item in spec.split(',') {
        let (kind, w) = match item.split_once(':') {
            Some((k, w)) => {
                let w: f64 = w
                    .parse()
                    .map_err(|_| anyhow::anyhow!("--faults: '{w}' is not a weight (in '{item}')"))?;
                ensure!(
                    w.is_finite() && w >= 0.0,
                    "--faults: weight must be finite and >= 0, got {w} (in '{item}')"
                );
                (k, w)
            }
            None => (item, 1.0),
        };
        let slot = match kind.trim() {
            "gpu" => &mut gpu_w,
            "slice" => &mut slice_w,
            "reconfig" => &mut reconfig_w,
            other => bail!("--faults: unknown fault kind '{other}' (want gpu|slice|reconfig)"),
        };
        ensure!(slot.is_none(), "--faults: duplicate fault kind '{}'", kind.trim());
        *slot = Some(w);
    }
    Ok((
        gpu_w.unwrap_or(0.0),
        slice_w.unwrap_or(0.0),
        reconfig_w.unwrap_or(0.0),
    ))
}

impl FaultConfig {
    /// Build a config from a CLI spec plus the knob values.
    pub fn from_spec(
        spec: &str,
        mttf_s: f64,
        mttr_s: f64,
        retries: u32,
        checkpoint_dt_s: f64,
    ) -> crate::Result<FaultConfig> {
        let (gpu_w, slice_w, reconfig_w) = parse_spec(spec)?;
        let cfg = FaultConfig {
            gpu_w,
            slice_w,
            reconfig_w,
            mttf_s,
            mttr_s,
            retries,
            checkpoint_dt_s,
            ..FaultConfig::default()
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Builder for the degradation knobs on top of [`from_spec`]:
    /// correlated fault domains, finite repair crews, brown-out
    /// shedding. Re-validates, so a degradation knob on an inert spec is
    /// rejected here rather than silently ignored.
    ///
    /// [`from_spec`]: FaultConfig::from_spec
    pub fn with_degrade(
        mut self,
        domains: FaultDomains,
        repair_crews: u32,
        shed: ShedPolicy,
    ) -> crate::Result<FaultConfig> {
        ensure!(
            self.active() || (!domains.active() && repair_crews == 0 && !shed.active()),
            "degradation knobs (--fault-domains/--repair-crews/--shed-policy) \
             have no effect without an active --faults SPEC"
        );
        self.domains = domains;
        self.repair_crews = repair_crews;
        self.shed = shed;
        self.validate()?;
        Ok(self)
    }

    /// Whether the plane injects anything at all. Inactive ⇒ the serve
    /// loop schedules no fault events and the report bytes are identical
    /// to the plane being absent.
    pub fn active(&self) -> bool {
        self.total_w() > 0.0
    }

    /// Whether any graceful-degradation knob is set (fault domains,
    /// finite repair crews, or brown-out shedding). Gates the report's
    /// degrade counters on the wire, so a faulted run with the knobs at
    /// their defaults keeps its pre-degrade bytes exactly.
    pub fn degraded(&self) -> bool {
        self.domains.active() || self.repair_crews > 0 || self.shed.active()
    }

    fn total_w(&self) -> f64 {
        self.gpu_w + self.slice_w + self.reconfig_w
    }

    pub fn validate(&self) -> crate::Result<()> {
        for (name, w) in [
            ("gpu", self.gpu_w),
            ("slice", self.slice_w),
            ("reconfig", self.reconfig_w),
        ] {
            ensure!(
                w.is_finite() && w >= 0.0,
                "fault weight '{name}' must be finite and >= 0, got {w}"
            );
        }
        if !self.active() {
            return Ok(());
        }
        ensure!(
            self.mttf_s.is_finite() && self.mttf_s > 0.0,
            "--mttf must be a positive number of seconds, got {}",
            self.mttf_s
        );
        ensure!(
            self.mttr_s.is_finite() && self.mttr_s > 0.0,
            "--mttr must be a positive number of seconds, got {}",
            self.mttr_s
        );
        ensure!(
            self.checkpoint_dt_s > 0.0,
            "--checkpoint-dt must be positive seconds (inf = no checkpointing), got {}",
            self.checkpoint_dt_s
        );
        if let FaultDomains::Rack(w) = self.domains {
            ensure!(w >= 1, "--fault-domains: rack width must be >= 1, got {w}");
        }
        if let ShedPolicy::Watermark(f) = self.shed {
            ensure!(
                f > 0.0 && f <= 1.0,
                "--shed-policy: watermark must be in (0, 1], got {f}"
            );
        }
        Ok(())
    }

    /// The fault stream of one GPU: a pure function of the serve seed
    /// and the *global* GPU id (splitmix-style mixing decorrelates
    /// adjacent ids), so every shard partitioning — and the unsharded
    /// loop — draws the identical sequence for the same hardware.
    pub fn gpu_stream(seed: u64, global_gpu: usize) -> Rng {
        let mix = (global_gpu as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(seed ^ mix ^ 0xFA17_0000_0000_0000)
    }

    /// The event stream of one fault domain: the same construction as
    /// [`gpu_stream`] under a different salt, keyed on the fleet-global
    /// domain id — every shard owning a slice of the domain derives the
    /// identical stream, so correlated cordons land at identical virtual
    /// times whatever the partitioning or thread count.
    ///
    /// [`gpu_stream`]: FaultConfig::gpu_stream
    pub fn domain_stream(seed: u64, domain: usize) -> Rng {
        let mix = (domain as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        Rng::new(seed ^ mix ^ 0xD03A_0000_0000_0000)
    }

    /// Time to the next fault on one GPU (exponential, mean MTTF).
    pub fn draw_ttf(&self, rng: &mut Rng) -> f64 {
        -self.mttf_s * (1.0 - rng.f64()).ln()
    }

    /// Repair time of a hard-failed GPU (exponential, mean MTTR).
    pub fn draw_ttr(&self, rng: &mut Rng) -> f64 {
        -self.mttr_s * (1.0 - rng.f64()).ln()
    }

    /// Which failure class this fault is (weighted draw; only enabled
    /// kinds can come out). Must not be called on an inactive config.
    pub fn draw_kind(&self, rng: &mut Rng) -> FaultKind {
        debug_assert!(self.active(), "drawing a fault kind from an inert plane");
        let mut pick = rng.f64() * self.total_w();
        if pick < self.gpu_w {
            return FaultKind::Gpu;
        }
        pick -= self.gpu_w;
        if pick < self.slice_w {
            return FaultKind::Slice;
        }
        FaultKind::Reconfig
    }

    /// Service seconds preserved when an attempt is killed after
    /// `elapsed_s` of service: the last checkpoint boundary at
    /// `checkpoint_dt_s` granularity, 0 with checkpointing off. The
    /// caller converts this to job-progress fraction by dividing by the
    /// attempt's full-job runtime.
    pub fn preserved_s(&self, elapsed_s: f64) -> f64 {
        if !self.checkpoint_dt_s.is_finite() {
            return 0.0;
        }
        (elapsed_s.max(0.0) / self.checkpoint_dt_s).floor() * self.checkpoint_dt_s
    }

    /// Compact label for reports/telemetry, e.g. `gpu:1+slice:0.5`,
    /// `off` when inert.
    pub fn label(&self) -> String {
        if !self.active() {
            return "off".to_string();
        }
        let mut parts = Vec::new();
        for (name, w) in [
            ("gpu", self.gpu_w),
            ("slice", self.slice_w),
            ("reconfig", self.reconfig_w),
        ] {
            if w > 0.0 {
                parts.push(format!("{name}:{w}"));
            }
        }
        parts.join("+")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_inert_and_valid() {
        let c = FaultConfig::default();
        assert!(!c.active());
        c.validate().unwrap();
        assert_eq!(c.label(), "off");
    }

    #[test]
    fn spec_grammar_round_trips() {
        assert_eq!(parse_spec("").unwrap(), (0.0, 0.0, 0.0));
        assert_eq!(parse_spec("none").unwrap(), (0.0, 0.0, 0.0));
        assert_eq!(parse_spec("gpu").unwrap(), (1.0, 0.0, 0.0));
        assert_eq!(parse_spec("gpu,slice:2").unwrap(), (1.0, 2.0, 0.0));
        assert_eq!(
            parse_spec("gpu:0.5,slice:2,reconfig:0.25").unwrap(),
            (0.5, 2.0, 0.25)
        );
        assert_eq!(parse_spec(" slice ").unwrap(), (0.0, 1.0, 0.0));
        for bad in ["disk", "gpu:x", "gpu:-1", "gpu,gpu:2", "gpu:inf"] {
            assert!(parse_spec(bad).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn validation_matrix() {
        let active = |f: fn(&mut FaultConfig)| {
            let mut c = FaultConfig { gpu_w: 1.0, ..FaultConfig::default() };
            f(&mut c);
            c.validate()
        };
        assert!(active(|_| {}).is_ok());
        assert!(active(|c| c.mttf_s = 0.0).is_err());
        assert!(active(|c| c.mttf_s = f64::INFINITY).is_err());
        assert!(active(|c| c.mttr_s = -1.0).is_err());
        assert!(active(|c| c.checkpoint_dt_s = 0.0).is_err());
        assert!(active(|c| c.checkpoint_dt_s = f64::INFINITY).is_ok());
        assert!(active(|c| c.slice_w = f64::NAN).is_err());
        // An inert config never trips the knob checks (defaults must
        // stay valid whatever the unused knobs hold).
        let mut inert = FaultConfig { mttf_s: 0.0, ..FaultConfig::default() };
        inert.validate().unwrap();
        inert.gpu_w = 1.0;
        assert!(inert.validate().is_err());
    }

    #[test]
    fn per_gpu_streams_are_deterministic_and_decorrelated() {
        let c = FaultConfig { gpu_w: 1.0, mttf_s: 100.0, ..FaultConfig::default() };
        let mut a = FaultConfig::gpu_stream(7, 3);
        let mut b = FaultConfig::gpu_stream(7, 3);
        let seq_a: Vec<f64> = (0..8).map(|_| c.draw_ttf(&mut a)).collect();
        let seq_b: Vec<f64> = (0..8).map(|_| c.draw_ttf(&mut b)).collect();
        assert_eq!(seq_a, seq_b, "same (seed, gpu) ⇒ same stream");
        assert!(seq_a.iter().all(|&t| t > 0.0 && t.is_finite()));
        let mut other_gpu = FaultConfig::gpu_stream(7, 4);
        let mut other_seed = FaultConfig::gpu_stream(8, 3);
        assert_ne!(seq_a[0], c.draw_ttf(&mut other_gpu));
        assert_ne!(seq_a[0], c.draw_ttf(&mut other_seed));
    }

    #[test]
    fn kind_draw_respects_weights() {
        let mut rng = FaultConfig::gpu_stream(1, 0);
        let only_gpu = FaultConfig { gpu_w: 3.0, ..FaultConfig::default() };
        for _ in 0..32 {
            assert_eq!(only_gpu.draw_kind(&mut rng), FaultKind::Gpu);
        }
        let only_slice = FaultConfig { slice_w: 0.1, ..FaultConfig::default() };
        for _ in 0..32 {
            assert_eq!(only_slice.draw_kind(&mut rng), FaultKind::Slice);
        }
        let mixed = FaultConfig { gpu_w: 1.0, slice_w: 1.0, reconfig_w: 1.0, ..FaultConfig::default() };
        let mut seen = [false; 3];
        for _ in 0..256 {
            match mixed.draw_kind(&mut rng) {
                FaultKind::Gpu => seen[0] = true,
                FaultKind::Slice => seen[1] = true,
                FaultKind::Reconfig => seen[2] = true,
            }
        }
        assert_eq!(seen, [true; 3], "every enabled kind eventually drawn");
    }

    #[test]
    fn checkpoint_model_preserves_boundary_work() {
        let off = FaultConfig::default();
        assert_eq!(off.preserved_s(123.0), 0.0, "no checkpointing ⇒ scratch");
        let on = FaultConfig { checkpoint_dt_s: 10.0, ..FaultConfig::default() };
        assert_eq!(on.preserved_s(0.0), 0.0);
        assert_eq!(on.preserved_s(9.99), 0.0);
        assert_eq!(on.preserved_s(10.0), 10.0);
        assert_eq!(on.preserved_s(25.0), 20.0);
        assert_eq!(on.preserved_s(-1.0), 0.0, "clock skew clamps to 0");
    }

    #[test]
    fn domain_grammar_round_trips() {
        assert_eq!(FaultDomains::parse("none").unwrap(), FaultDomains::None);
        assert_eq!(FaultDomains::parse("").unwrap(), FaultDomains::None);
        assert_eq!(FaultDomains::parse("node").unwrap(), FaultDomains::Node);
        assert_eq!(FaultDomains::parse(" rack:4 ").unwrap(), FaultDomains::Rack(4));
        assert_eq!(FaultDomains::parse("rack:1").unwrap().label(), "rack:1");
        assert!(!FaultDomains::None.active());
        assert!(FaultDomains::Node.active());
        for bad in ["rack", "rack:0", "rack:-1", "rack:x", "pod:2", "rack:1.5"] {
            assert!(FaultDomains::parse(bad).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn shed_grammar_round_trips() {
        assert_eq!(ShedPolicy::parse("none").unwrap(), ShedPolicy::None);
        assert_eq!(
            ShedPolicy::parse("watermark:0.75").unwrap(),
            ShedPolicy::Watermark(0.75)
        );
        assert_eq!(
            ShedPolicy::parse("watermark:1").unwrap().label(),
            "watermark:1"
        );
        assert!(!ShedPolicy::None.active());
        assert!(ShedPolicy::Watermark(0.5).active());
        for bad in [
            "watermark",
            "watermark:0",
            "watermark:-0.5",
            "watermark:1.5",
            "watermark:nan",
            "drop:0.5",
        ] {
            assert!(ShedPolicy::parse(bad).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn domain_streams_are_deterministic_and_distinct_from_gpu_streams() {
        let c = FaultConfig { gpu_w: 1.0, mttf_s: 50.0, ..FaultConfig::default() };
        let mut a = FaultConfig::domain_stream(7, 0);
        let mut b = FaultConfig::domain_stream(7, 0);
        let seq_a: Vec<f64> = (0..8).map(|_| c.draw_ttf(&mut a)).collect();
        let seq_b: Vec<f64> = (0..8).map(|_| c.draw_ttf(&mut b)).collect();
        assert_eq!(seq_a, seq_b, "same (seed, domain) ⇒ same stream");
        // A domain stream must not collide with the same-id GPU stream:
        // domain 0's cordons would otherwise mirror GPU 0's hazards.
        let mut gpu = FaultConfig::gpu_stream(7, 0);
        assert_ne!(seq_a[0], c.draw_ttf(&mut gpu));
        let mut other = FaultConfig::domain_stream(7, 1);
        assert_ne!(seq_a[0], c.draw_ttf(&mut other));
    }

    #[test]
    fn with_degrade_wires_and_gates_the_knobs() {
        let base = FaultConfig::from_spec("gpu", 10.0, 2.0, 1, f64::INFINITY).unwrap();
        let c = base
            .with_degrade(FaultDomains::Rack(2), 1, ShedPolicy::Watermark(0.5))
            .unwrap();
        assert_eq!(c.domains, FaultDomains::Rack(2));
        assert_eq!(c.repair_crews, 1);
        assert_eq!(c.shed, ShedPolicy::Watermark(0.5));
        // Defaults pass through unchanged (and stay inert-compatible).
        let same = base
            .with_degrade(FaultDomains::None, 0, ShedPolicy::None)
            .unwrap();
        assert_eq!(same, base);
        // Degradation knobs on an inert plane are refused, not ignored.
        let inert = FaultConfig::default();
        assert!(inert
            .with_degrade(FaultDomains::Node, 0, ShedPolicy::None)
            .is_err());
        assert!(inert
            .with_degrade(FaultDomains::None, 2, ShedPolicy::None)
            .is_err());
        assert!(inert
            .with_degrade(FaultDomains::None, 0, ShedPolicy::Watermark(0.9))
            .is_err());
        // An inert degrade on an inert plane is fine (the default path).
        assert!(inert
            .with_degrade(FaultDomains::None, 0, ShedPolicy::None)
            .is_ok());
    }

    #[test]
    fn from_spec_wires_knobs_and_validates() {
        let c = FaultConfig::from_spec("gpu,slice:2", 500.0, 30.0, 3, 5.0).unwrap();
        assert!(c.active());
        assert_eq!((c.gpu_w, c.slice_w, c.reconfig_w), (1.0, 2.0, 0.0));
        assert_eq!(c.retries, 3);
        assert_eq!(c.label(), "gpu:1+slice:2");
        assert!(FaultConfig::from_spec("gpu", 0.0, 30.0, 3, 5.0).is_err());
        let inert = FaultConfig::from_spec("none", 500.0, 30.0, 3, 5.0).unwrap();
        assert!(!inert.active());
    }
}
