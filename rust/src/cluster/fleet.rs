//! The GPU fleet: N statically-partitioned GPUs, each carrying a MIG
//! layout (a list of GI profiles validated against the slice budget) whose
//! instances act as serving slots.
//!
//! ## Slot-level continuous batching (MPS-within-MIG)
//!
//! A slot hosts up to `batch` co-resident jobs under MPS semantics (the
//! paper's `MigSharedGi`-style sharing, applied inside one instance): each
//! resident keeps running until its own completion, and the slice's
//! memory must hold every resident's footprint plus a per-process context
//! (§IV-B). `batch = 1` is *exactly* the classic one-job-per-slot system —
//! every index, counter and report it produces is bit-identical to the
//! pre-batching code.
//!
//! A GPU can be *repartitioned* while fully idle (the §II-B3 static-
//! configuration constraint, lifted to the fleet level: reconfiguration is
//! allowed, but only on a drained GPU and only through layouts that the
//! `MigManager` slice-budget validation accepts). While a reconfiguration
//! is in flight the GPU serves nothing.
//!
//! ("Node" here means a *shard* of the sharded serving control plane —
//! see `cluster::shard` — never an individual GPU; a `Fleet` is the GPU
//! set owned by one such node.)
//!
//! ## The incremental index
//!
//! `Fleet` maintains a `FleetIndex` alongside the raw GPUs so the serving
//! hot path is O(changed state), not O(fleet):
//! - per-`(ProfileId, occupancy)` open-slot sets in deterministic
//!   `(gpu, slot)` order — a placement decision becomes a walk over
//!   ≤ `6 × batch` co-residency classes instead of a full `gpus × slots`
//!   scan (`open[m][p]` holds slots of profile `p` with exactly `m`
//!   residents; full slots — `m == batch` — are in no set);
//! - the set of fully-idle, non-reconfiguring GPUs (the reconfiguration
//!   planner's candidates);
//! - per-profile effective-layout GPU counts (the O(classes)
//!   `fits_current_layouts` guard);
//! - a live fleet busy-SM counter (the utilization integral; a slot's SMs
//!   count busy while it has *any* resident — MPS shares the SMs, it does
//!   not partition them);
//! - an availability *epoch* that bumps whenever capacity comes back
//!   (a resident finishing frees a seat, reconfig completion frees a
//!   GPU), so the dispatcher can memoize placement failures until the
//!   fleet could possibly satisfy them.
//!
//! ## The host-memory plane
//!
//! A `Fleet` is the GPU set of one node, and a node carries one Grace
//! host-memory pool (`cluster::hostmem::HostPool`): every offloaded
//! resident charges its spilled bytes against it for as long as it runs
//! (integer-byte accounting — draining the fleet restores the pool to its
//! initial bytes exactly). Each GPU additionally keeps a live count of
//! its *offloading* residents (`FleetGpu::offloaders`) — the C2C
//! link-share aggregate the contention-aware cost model divides the
//! direct-access bandwidth by. Both have `*_scan` oracles recomputed from
//! the raw resident lists.
//!
//! ## The fault plane
//!
//! `cluster::faults` injects hardware failures as virtual-time events; the
//! fleet's side is *cordon-and-drain*: `cordon_gpu` evicts every resident
//! (unwinding pool/link/occupancy accounting exactly), pulls the GPU's
//! slots from the open index, drops it from the idle set, and uncounts
//! its layout from `has_layout_class` — until `uncordon_gpu` repairs it.
//! `drain_slot` is the slice-level (ECC/Xid) variant: one resident set
//! dies, the slot survives. Every `*_scan` oracle filters on
//! `FleetGpu::out_of_service` (cordoned **or** reconfiguring) so the
//! naive paths exclude exactly the hardware the index excludes.
//!
//! Mutations must flow through the `Fleet` methods (`start_job`,
//! `finish_job`, `begin_reconfig`, `finish_reconfig`, `cordon_gpu`,
//! `uncordon_gpu`, `drain_slot`); mutating `fleet.gpus[..]` directly
//! bypasses the index. The `*_scan` variants recompute the same
//! quantities from the raw slots and serve as the differential-test
//! oracle.

use super::hostmem::HostPool;
use crate::gpu::GpuSpec;
use crate::mig::profile::{GiProfile, ProfileId, ALL_PROFILES, NUM_PROFILES};
use crate::mig::MigManager;
use anyhow::{bail, ensure};
use std::collections::BTreeSet;

/// Largest supported per-slot co-residency (the paper's co-run studies
/// share one GI between at most seven clients — `Scheme::MigSharedGi`
/// tops out at 7×1c.7g).
pub const MAX_BATCH: u32 = 7;

/// A resident evicted from a cordoned GPU (or a faulted slice) before it
/// finished: everything the fault plane needs to requeue it as a retry.
/// Produced by `Fleet::cordon_gpu` / `Fleet::drain_slot` in deterministic
/// `(slot, admission)` order; the pool/link/occupancy accounting has
/// already been unwound by the time the caller sees one.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Orphan {
    pub job: u32,
    pub slot: usize,
    pub started_s: f64,
    pub until_s: f64,
}

/// One job resident on a serving slot.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Resident {
    pub job: u32,
    pub started_s: f64,
    pub until_s: f64,
    /// Memory charged to the slice for this job: resident footprint after
    /// any offloading, plus the per-process MIG context (GiB).
    pub charged_gib: f64,
    /// Bytes parked in the node's Grace host pool while this job runs
    /// (its offload spill; 0 for a job running fully resident). A
    /// resident with `host_bytes > 0` is an *offloader* and time-shares
    /// the GPU's C2C link.
    pub host_bytes: u64,
}

/// One MIG instance acting as a serving slot for up to `Fleet::batch`
/// co-resident jobs.
#[derive(Debug, Clone)]
pub struct Slot {
    pub profile: GiProfile,
    /// Co-resident jobs, in admission order.
    pub residents: Vec<Resident>,
    /// Cumulative per-job service time (job-seconds; may exceed wall time
    /// when residents overlap).
    pub busy_accum_s: f64,
}

impl Slot {
    fn new(profile_id: ProfileId) -> Slot {
        Slot {
            profile: GiProfile::get(profile_id),
            residents: Vec::new(),
            busy_accum_s: 0.0,
        }
    }

    pub fn is_idle(&self) -> bool {
        self.residents.is_empty()
    }

    /// Number of co-resident jobs.
    pub fn occupancy(&self) -> usize {
        self.residents.len()
    }

    /// Memory charged by the current residents (GiB). Recomputed from the
    /// resident list on demand — no incremental float state — so fully
    /// draining a slot restores exactly 0.0 and the scan paths are
    /// trivially bit-equal.
    pub fn charged_gib(&self) -> f64 {
        self.residents.iter().map(|r| r.charged_gib).sum()
    }

    /// Batched-slot memory admission: can the slice still charge
    /// `need_gib` more (a candidate's resident footprint + per-process
    /// context)? This is the **single source** of the comparison — the
    /// indexed walk (`Fleet::first_open_fitting`) and the naive
    /// `Planner::place_scan` must evaluate the literally identical
    /// expression for their bit-identity to hold. Exact comparison, no
    /// epsilon. Callers skip it for empty slots: the cost model's solo
    /// cap already gated those, and re-checking could disagree with that
    /// gate by a rounding bit (`batch = 1` must reproduce the unbatched
    /// system exactly).
    pub fn fits(&self, need_gib: f64) -> bool {
        self.charged_gib() + need_gib <= self.profile.mem_gib
    }
}

/// Initial per-GPU layout assignment for a fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayoutPreset {
    /// Cycle through four complementary layouts (fine slices on GPU 0,
    /// progressively coarser on the rest) — the operator's hedge when the
    /// job mix is unknown.
    Mixed,
    /// Every GPU split into 7x1g.12gb — maximum slot count, no slice
    /// admits a >11 GiB job without offloading or reconfiguration.
    AllSmall,
    /// Every GPU left whole (1x7g.96gb).
    AllBig,
}

impl LayoutPreset {
    pub fn parse(s: &str) -> Option<LayoutPreset> {
        match s {
            "mixed" => Some(LayoutPreset::Mixed),
            "small" => Some(LayoutPreset::AllSmall),
            "big" => Some(LayoutPreset::AllBig),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            LayoutPreset::Mixed => "mixed",
            LayoutPreset::AllSmall => "small",
            LayoutPreset::AllBig => "big",
        }
    }

    /// The layout for GPU `idx` under this preset.
    pub fn layout_for(&self, idx: usize) -> Vec<ProfileId> {
        use ProfileId::*;
        match self {
            LayoutPreset::AllSmall => class_layout(P1g12gb),
            LayoutPreset::AllBig => class_layout(P7g96gb),
            LayoutPreset::Mixed => match idx % 4 {
                0 => class_layout(P1g12gb),
                1 => class_layout(P2g24gb),
                2 => class_layout(P4g48gb),
                _ => class_layout(P3g48gb),
            },
        }
    }
}

/// The canonical packed whole-GPU layout whose *largest* instance is
/// `class`: the single source of truth shared by the fleet presets and by
/// `reconfig::plan_for_footprint`, so reconfiguration targets always match
/// the preset shapes (`plan_reconfig` compares layouts for equality).
pub fn class_layout(class: ProfileId) -> Vec<ProfileId> {
    use ProfileId::*;
    match class {
        P1g12gb => vec![P1g12gb; 7],
        P1g24gb => vec![P1g24gb; 4],
        P2g24gb => vec![P2g24gb, P2g24gb, P2g24gb, P1g12gb],
        P3g48gb => vec![P3g48gb, P3g48gb],
        P4g48gb => vec![P4g48gb, P3g48gb],
        P7g96gb => vec![P7g96gb],
    }
}

/// Check a layout against the MIG slice budget by actually creating the
/// instances through the manager (the single source of placement truth).
pub fn validate_layout(layout: &[ProfileId]) -> crate::Result<()> {
    ensure!(!layout.is_empty(), "a GPU layout needs at least one instance");
    let mut mgr = MigManager::new(GpuSpec::gh_h100_96gb());
    for p in layout {
        mgr.create_full(*p)?;
    }
    Ok(())
}

/// One GPU of the fleet.
#[derive(Debug)]
pub struct FleetGpu {
    pub id: usize,
    pub layout: Vec<ProfileId>,
    pub slots: Vec<Slot>,
    /// `Some(t)` while a MIG reconfiguration completes at time `t`.
    pub reconfiguring_until: Option<f64>,
    /// The layout being installed by the in-flight reconfiguration.
    pub pending_layout: Option<Vec<ProfileId>>,
    /// Completed reconfigurations (diagnostics).
    pub reconfigs: u32,
    /// True while the fault plane has this GPU out of service: its slots
    /// are out of the open index, the planner never targets it, and
    /// `fits_current_layouts` does not count its layout. Set/cleared only
    /// through `Fleet::cordon_gpu` / `Fleet::uncordon_gpu`.
    cordoned: bool,
    /// Live counter of occupied slots (≥1 resident; maintained by `Fleet`).
    busy_slots: u32,
    /// Live counter of SMs running jobs (maintained by `Fleet`).
    busy_sms_count: u32,
    /// Live count of offloading residents across this GPU's slices — the
    /// C2C link-share aggregate (maintained by `Fleet`). The single
    /// NVLink-C2C link is time-shared by all of them.
    offloaders_count: u32,
}

impl FleetGpu {
    pub fn new(id: usize, layout: Vec<ProfileId>) -> crate::Result<FleetGpu> {
        validate_layout(&layout)?;
        let slots = layout.iter().map(|&p| Slot::new(p)).collect();
        Ok(FleetGpu {
            id,
            layout,
            slots,
            reconfiguring_until: None,
            pending_layout: None,
            reconfigs: 0,
            cordoned: false,
            busy_slots: 0,
            busy_sms_count: 0,
            offloaders_count: 0,
        })
    }

    pub fn reconfiguring(&self) -> bool {
        self.reconfiguring_until.is_some()
    }

    /// True while the fault plane has this GPU cordoned.
    pub fn cordoned(&self) -> bool {
        self.cordoned
    }

    /// True when this GPU currently serves nothing — cordoned by the
    /// fault plane or mid-reconfiguration. The single predicate every
    /// `*_scan` oracle filters on, so the naive paths exclude exactly the
    /// hardware the incremental index excludes.
    pub fn out_of_service(&self) -> bool {
        self.cordoned || self.reconfiguring()
    }

    /// True when every slot is empty (a precondition for reconfiguration).
    pub fn all_idle(&self) -> bool {
        self.busy_slots == 0
    }

    /// SMs currently running jobs on this GPU (O(1) live counter). A slot
    /// counts with any resident — MPS shares SMs, it does not split them.
    pub fn busy_sms(&self) -> u32 {
        self.busy_sms_count
    }

    /// SMs currently running jobs, recomputed from the slots — the
    /// differential-test oracle for `busy_sms`.
    pub fn busy_sms_scan(&self) -> u32 {
        self.slots
            .iter()
            .filter(|s| !s.is_idle())
            .map(|s| s.profile.sms)
            .sum()
    }

    /// Offloading residents currently sharing this GPU's C2C link (O(1)
    /// live counter). A newcomer that offloads would share the link
    /// `offloaders() + 1` ways.
    pub fn offloaders(&self) -> u32 {
        self.offloaders_count
    }

    /// Offloading residents recomputed from the slots — the
    /// differential-test oracle for `offloaders`.
    pub fn offloaders_scan(&self) -> u32 {
        self.slots
            .iter()
            .flat_map(|s| s.residents.iter())
            .filter(|r| r.host_bytes > 0)
            .count() as u32
    }

    /// The layout this GPU will have once any in-flight reconfiguration
    /// lands (used when deciding whether yet another reconfiguration is
    /// needed for a queued job).
    pub fn effective_layout(&self) -> &[ProfileId] {
        self.pending_layout.as_deref().unwrap_or(&self.layout)
    }

    /// Start repartitioning to `target`; the GPU serves nothing until
    /// `until_s`. Fails on a busy or already-reconfiguring GPU and on an
    /// invalid target layout — MIG cannot change under running work.
    /// Prefer `Fleet::begin_reconfig`, which also maintains the index.
    pub fn begin_reconfig(&mut self, target: Vec<ProfileId>, until_s: f64) -> crate::Result<()> {
        if !self.all_idle() {
            bail!("GPU {} has running jobs; MIG cannot be reconfigured", self.id);
        }
        if self.reconfiguring() {
            bail!("GPU {} is already reconfiguring", self.id);
        }
        if self.cordoned {
            bail!("GPU {} is cordoned; it cannot be reconfigured", self.id);
        }
        validate_layout(&target)?;
        self.pending_layout = Some(target);
        self.reconfiguring_until = Some(until_s);
        Ok(())
    }

    /// Complete the in-flight reconfiguration: install the pending layout
    /// and rebuild the (empty) slots. Prefer `Fleet::finish_reconfig`,
    /// which also maintains the index.
    pub fn finish_reconfig(&mut self) {
        if let Some(layout) = self.pending_layout.take() {
            self.slots = layout.iter().map(|&p| Slot::new(p)).collect();
            self.layout = layout;
            self.reconfigs += 1;
        }
        self.reconfiguring_until = None;
    }

    /// Abort the in-flight reconfiguration after a transient driver
    /// fault: the pending layout is dropped and the installed one (whose
    /// slots never changed) survives. Prefer `Fleet::abort_reconfig`,
    /// which also maintains the index.
    pub fn abort_reconfig(&mut self) {
        self.pending_layout = None;
        self.reconfiguring_until = None;
    }
}

/// Incremental placement/aggregate index over the fleet — see the module
/// docs for what each piece buys the serving hot path.
#[derive(Debug)]
struct FleetIndex {
    /// Open slots bucketed by `[occupancy][profile]`, in deterministic
    /// `(gpu, slot)` order: `open[m][p]` holds slots of profile `p` with
    /// exactly `m` residents (`m < batch`; full slots are in no set).
    /// Slots of reconfiguring GPUs are excluded (they serve nothing).
    open: Vec<[BTreeSet<(usize, usize)>; NUM_PROFILES]>,
    /// Fully-idle, non-reconfiguring GPUs (reconfiguration candidates).
    idle_gpus: BTreeSet<usize>,
    /// Number of GPUs whose *effective* layout contains each profile.
    layout_gpus: [u32; NUM_PROFILES],
    /// SMs currently running jobs across the fleet.
    busy_sms: u32,
    /// Bumped whenever capacity comes back (a resident finishing frees a
    /// seat / reconfig done frees a GPU): a placement that failed at
    /// epoch E keeps failing while the epoch stays E, because every other
    /// mutation only removes capacity.
    epoch: u64,
}

impl FleetIndex {
    fn new(batch: u32) -> FleetIndex {
        FleetIndex {
            open: (0..batch)
                .map(|_| std::array::from_fn(|_| BTreeSet::new()))
                .collect(),
            idle_gpus: BTreeSet::new(),
            layout_gpus: [0; NUM_PROFILES],
            busy_sms: 0,
            epoch: 0,
        }
    }

    /// Adjust the per-profile GPU counts for the *distinct* profiles of
    /// one GPU's layout.
    fn adjust_layout_gpus(&mut self, layout: &[ProfileId], add: bool) {
        let mut seen = [false; NUM_PROFILES];
        for p in layout {
            seen[p.index()] = true;
        }
        for (i, s) in seen.iter().enumerate() {
            if *s {
                if add {
                    self.layout_gpus[i] += 1;
                } else {
                    self.layout_gpus[i] -= 1;
                }
            }
        }
    }
}

/// Per-profile-class fleet census for the telemetry sampler: empty
/// slots and open seats per profile, by direct slot scan. Read-only and
/// index-free, so the numbers are identical in `Indexed` and
/// `NaiveOracle` serve modes by construction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ClassCensus {
    /// Empty slots per profile class (dense `ProfileId::index`).
    pub idle_slots: [u32; NUM_PROFILES],
    /// Open seats per profile class: `batch − occupancy` summed over
    /// non-full slots of GPUs not mid-reconfiguration.
    pub open_seats: [u32; NUM_PROFILES],
}

/// The multi-GPU fleet.
#[derive(Debug)]
pub struct Fleet {
    pub gpus: Vec<FleetGpu>,
    pub spec: GpuSpec,
    /// Max co-resident jobs per slot (1 = classic one-job-per-slot).
    batch: u32,
    /// The node's Grace host-memory pool (offload spill lives here).
    host_pool: HostPool,
    index: FleetIndex,
}

impl Fleet {
    /// A classic one-job-per-slot fleet (`batch = 1`).
    pub fn new(gpus: u32, preset: LayoutPreset) -> crate::Result<Fleet> {
        Fleet::with_batch(gpus, preset, 1)
    }

    /// A fleet whose slots host up to `batch` co-resident jobs under MPS
    /// semantics, with an unlimited host pool. `batch = 1` reproduces the
    /// unbatched system exactly.
    pub fn with_batch(gpus: u32, preset: LayoutPreset, batch: u32) -> crate::Result<Fleet> {
        Fleet::with_hostmem(gpus, preset, batch, f64::INFINITY)
    }

    /// A fleet whose node carries a finite Grace host pool of
    /// `host_pool_gib` GiB (`inf` = unlimited, the pre-plane model).
    pub fn with_hostmem(
        gpus: u32,
        preset: LayoutPreset,
        batch: u32,
        host_pool_gib: f64,
    ) -> crate::Result<Fleet> {
        ensure!(gpus >= 1, "fleet needs at least one GPU");
        ensure!(
            (1..=MAX_BATCH).contains(&batch),
            "per-slot batch must be 1..={MAX_BATCH}, got {batch}"
        );
        let gpus = (0..gpus as usize)
            .map(|i| FleetGpu::new(i, preset.layout_for(i)))
            .collect::<crate::Result<Vec<_>>>()?;
        let mut index = FleetIndex::new(batch);
        for (g, gpu) in gpus.iter().enumerate() {
            for (s, slot) in gpu.slots.iter().enumerate() {
                index.open[0][slot.profile.id.index()].insert((g, s));
            }
            index.idle_gpus.insert(g);
            index.adjust_layout_gpus(&gpu.layout, true);
        }
        Ok(Fleet {
            gpus,
            spec: GpuSpec::gh_h100_96gb(),
            batch,
            host_pool: HostPool::new(host_pool_gib)?,
            index,
        })
    }

    /// Max co-resident jobs per slot.
    pub fn batch(&self) -> u32 {
        self.batch
    }

    /// Node host-pool capacity (`None` = unlimited).
    pub fn host_capacity_bytes(&self) -> Option<u64> {
        self.host_pool.capacity_bytes()
    }

    /// Bytes currently parked in the node's host pool (O(1) live
    /// counter).
    pub fn host_used_bytes(&self) -> u64 {
        self.host_pool.used_bytes()
    }

    /// `host_used_bytes` recomputed from the raw resident lists — the
    /// differential-test oracle. Integer bytes, so equality is exact.
    pub fn host_used_bytes_scan(&self) -> u64 {
        self.gpus
            .iter()
            .flat_map(|g| g.slots.iter())
            .flat_map(|s| s.residents.iter())
            .map(|r| r.host_bytes)
            .sum()
    }

    /// Remaining host-pool headroom (`u64::MAX` when unlimited).
    pub fn host_headroom_bytes(&self) -> u64 {
        self.host_pool.headroom_bytes()
    }

    /// Host-pool admission gate: can `bytes` more spill be parked?
    pub fn host_fits(&self, bytes: u64) -> bool {
        self.host_pool.fits(bytes)
    }

    /// `host_fits` evaluated against the scanned (not live) pool usage —
    /// the naive oracle's gate.
    pub fn host_fits_scan(&self, bytes: u64) -> bool {
        match self.host_pool.capacity_bytes() {
            None => true,
            Some(c) => self.host_used_bytes_scan().saturating_add(bytes) <= c,
        }
    }

    /// Physical SMs across the fleet.
    pub fn total_sms(&self) -> u32 {
        self.spec.sms * self.gpus.len() as u32
    }

    /// SMs currently running jobs (O(1) live counter).
    pub fn busy_sms(&self) -> u32 {
        self.index.busy_sms
    }

    /// SMs currently running jobs, recomputed from the slots — the
    /// differential-test oracle for `busy_sms`.
    pub fn busy_sms_scan(&self) -> u32 {
        self.gpus.iter().map(|n| n.busy_sms_scan()).sum()
    }

    /// Availability epoch: bumps whenever a seat (or a whole GPU) comes
    /// back. A placement failure memoized at epoch E stays valid while the
    /// epoch is still E.
    pub fn epoch(&self) -> u64 {
        self.index.epoch
    }

    /// Per-profile-class idle-slot and open-seat counts for the
    /// telemetry sampler (O(slots) scan; samples are opt-in and
    /// periodic, so the scan never sits on the serve hot path).
    pub fn class_census(&self) -> ClassCensus {
        let mut census = ClassCensus {
            idle_slots: [0; NUM_PROFILES],
            open_seats: [0; NUM_PROFILES],
        };
        for gpu in &self.gpus {
            if gpu.out_of_service() {
                continue;
            }
            for slot in &gpu.slots {
                let i = slot.profile.id.index();
                let occ = slot.occupancy() as u32;
                if occ == 0 {
                    census.idle_slots[i] += 1;
                }
                if occ < self.batch {
                    census.open_seats[i] += self.batch - occ;
                }
            }
        }
        census
    }

    /// First *empty* slot of `profile` in `(gpu, slot)` order, excluding
    /// reconfiguring GPUs.
    pub fn first_idle(&self, profile: ProfileId) -> Option<(usize, usize)> {
        self.index.open[0][profile.index()].iter().next().copied()
    }

    /// Number of empty slots of `profile` (reconfiguring GPUs excluded).
    pub fn idle_count(&self, profile: ProfileId) -> usize {
        self.index.open[0][profile.index()].len()
    }

    /// Number of slots of `profile` holding exactly `occ` residents
    /// (`occ < batch`; reconfiguring GPUs excluded).
    pub fn open_count(&self, profile: ProfileId, occ: usize) -> usize {
        self.index.open[occ][profile.index()].len()
    }

    /// First slot of `profile` holding exactly `occ` residents — in
    /// `(gpu, slot)` order, reconfiguring GPUs excluded — whose slice can
    /// still charge `need_gib` more memory (`Slot::fits`; empty slots
    /// skip the check — see there).
    ///
    /// Worst case this walks the whole `(profile, occ)` set: occupied
    /// slots whose residents fill the slice (e.g. offloaded jobs at
    /// their solo cap) stay in the set while failing every memory check,
    /// so a class probe degrades from O(1) toward O(open slots of the
    /// class). Bucketing the sets by remaining headroom would restore
    /// O(1) — a ROADMAP follow-up.
    pub fn first_open_fitting(
        &self,
        profile: ProfileId,
        occ: usize,
        need_gib: f64,
    ) -> Option<(usize, usize)> {
        self.index.open[occ][profile.index()]
            .iter()
            .copied()
            .find(|&(g, s)| occ == 0 || self.gpus[g].slots[s].fits(need_gib))
    }

    /// Like `first_open_fitting`, but one candidate per distinct C2C
    /// link-share level: walking the `(profile, occ)` open set in
    /// `(gpu, slot)` order, record the first fitting slot for each
    /// distinct offloader count among the slots' GPUs. The contended
    /// offload-aware walk needs this because slots of one class no longer
    /// tie on cost when their GPUs host different numbers of
    /// co-offloaders — but within one share level they still do.
    /// Output entries `(gpu, slot, existing_offloaders)` come out in
    /// ascending `(gpu, slot)` order.
    pub fn first_open_fitting_per_share(
        &self,
        profile: ProfileId,
        occ: usize,
        need_gib: f64,
        out: &mut Vec<(usize, usize, u32)>,
    ) {
        out.clear();
        for &(g, s) in self.index.open[occ][profile.index()].iter() {
            if occ != 0 && !self.gpus[g].slots[s].fits(need_gib) {
                continue;
            }
            let share = self.gpus[g].offloaders();
            if out.iter().any(|&(_, _, sh)| sh == share) {
                continue;
            }
            out.push((g, s, share));
        }
    }

    /// Like `first_open_fitting_per_share`, but one candidate per
    /// distinct *GPU*: the first fitting slot on each board that has
    /// one. The power-aware offload walk needs this because throttle
    /// levels (and link shares) are per-GPU state — slots of one class
    /// only tie on cost within a single board. The open set iterates in
    /// ascending `(gpu, slot)` order, so a `last`-entry check suffices
    /// for the dedup. Output entries are `(gpu, slot,
    /// existing_offloaders)` in ascending `(gpu, slot)` order.
    pub fn first_open_fitting_per_gpu(
        &self,
        profile: ProfileId,
        occ: usize,
        need_gib: f64,
        out: &mut Vec<(usize, usize, u32)>,
    ) {
        out.clear();
        for &(g, s) in self.index.open[occ][profile.index()].iter() {
            if occ != 0 && !self.gpus[g].slots[s].fits(need_gib) {
                continue;
            }
            if out.last().map_or(false, |&(lg, _, _)| lg == g) {
                continue;
            }
            out.push((g, s, self.gpus[g].offloaders()));
        }
    }

    /// SMs of empty serving slots (reconfiguring GPUs excluded).
    /// O(profile classes) via the index.
    pub fn idle_slot_sms(&self) -> u32 {
        ALL_PROFILES
            .into_iter()
            .map(|p| self.idle_count(p) as u32 * GiProfile::get(p).sms)
            .sum()
    }

    /// Open SM-*seats* across the fleet: every non-reconfiguring slot
    /// contributes `sms × (batch − occupancy)` — the fractional-occupancy
    /// load signal the cross-node dispatcher balances on. At `batch = 1`
    /// this is exactly the idle-slot SM count. O(classes × batch).
    pub fn open_sm_seats(&self) -> u32 {
        let mut total = 0u32;
        for (m, sets) in self.index.open.iter().enumerate() {
            for p in ALL_PROFILES {
                total += sets[p.index()].len() as u32
                    * GiProfile::get(p).sms
                    * (self.batch - m as u32);
            }
        }
        total
    }

    /// `open_sm_seats` recomputed by a full slot scan — the
    /// differential-test oracle.
    pub fn open_sm_seats_scan(&self) -> u32 {
        self.gpus
            .iter()
            .filter(|g| !g.out_of_service())
            .flat_map(|g| g.slots.iter())
            .map(|s| s.profile.sms * (self.batch - s.occupancy() as u32))
            .sum()
    }

    /// Memory of the largest *empty* serving slot (GiB; 0 when nothing is
    /// idle, reconfiguring GPUs excluded). O(profile classes).
    pub fn largest_idle_slot_gib(&self) -> f64 {
        ALL_PROFILES
            .into_iter()
            .filter(|&p| self.idle_count(p) > 0)
            .map(|p| GiProfile::get(p).mem_gib)
            .fold(0.0f64, f64::max)
    }

    /// Memory of the largest slot that can still accept a co-resident
    /// (any occupancy `< batch`; GiB; 0 when every slot is full or
    /// reconfiguring) — the cross-node placement-compatibility signal
    /// under batching. At `batch = 1` this equals `largest_idle_slot_gib`
    /// exactly. O(classes × batch).
    pub fn largest_open_slot_gib(&self) -> f64 {
        ALL_PROFILES
            .into_iter()
            .filter(|&p| {
                self.index
                    .open
                    .iter()
                    .any(|sets| !sets[p.index()].is_empty())
            })
            .map(|p| GiProfile::get(p).mem_gib)
            .fold(0.0f64, f64::max)
    }

    /// `largest_open_slot_gib` recomputed by a full slot scan — the
    /// differential-test oracle.
    pub fn largest_open_slot_gib_scan(&self) -> f64 {
        self.gpus
            .iter()
            .filter(|g| !g.out_of_service())
            .flat_map(|g| g.slots.iter())
            .filter(|s| (s.occupancy() as u32) < self.batch)
            .map(|s| s.profile.mem_gib)
            .fold(0.0f64, f64::max)
    }

    /// Largest remaining memory headroom (GiB) among *occupied* slots
    /// that still have a free seat — the `Slot::fits`-based cross-node
    /// compatibility signal for forwarding a job onto a partially-filled
    /// slot: a target shard whose only open seats sit on memory-full
    /// slots must not receive jobs that would bounce on arrival. 0 when
    /// no occupied slot has a seat (always at `batch = 1`). Walks the
    /// occupied open sets (O(open occupied slots); barrier-time only).
    pub fn max_open_headroom_gib(&self) -> f64 {
        let mut best = 0.0f64;
        for sets in self.index.open.iter().skip(1) {
            for p in ALL_PROFILES {
                for &(g, s) in sets[p.index()].iter() {
                    let slot = &self.gpus[g].slots[s];
                    best = best.max(slot.profile.mem_gib - slot.charged_gib());
                }
            }
        }
        best
    }

    /// `max_open_headroom_gib` recomputed by a full slot scan — the
    /// differential-test oracle.
    pub fn max_open_headroom_gib_scan(&self) -> f64 {
        self.gpus
            .iter()
            .filter(|g| !g.out_of_service())
            .flat_map(|g| g.slots.iter())
            .filter(|s| s.occupancy() >= 1 && (s.occupancy() as u32) < self.batch)
            .map(|s| s.profile.mem_gib - s.charged_gib())
            .fold(0.0f64, f64::max)
    }

    /// Whether any GPU's *effective* layout (post-reconfiguration if one
    /// is in flight) contains `profile`.
    pub fn has_layout_class(&self, profile: ProfileId) -> bool {
        self.index.layout_gpus[profile.index()] > 0
    }

    /// Fully-idle, non-reconfiguring GPUs in ascending id order — the
    /// reconfiguration planner's candidate walk.
    pub fn idle_gpus(&self) -> impl Iterator<Item = usize> + '_ {
        self.index.idle_gpus.iter().copied()
    }

    /// Admit `job` onto a slot seat until `until_s`, charging
    /// `charged_gib` (resident footprint + per-process context) against
    /// the slice's memory and `host_bytes` of offload spill against the
    /// node's Grace pool (0 for a fully-resident job). The slot must have
    /// a free seat; memory-fit and host-pool headroom are the placement
    /// policy's responsibility (`first_open_fitting`, `host_fits`).
    #[allow(clippy::too_many_arguments)]
    pub fn start_job(
        &mut self,
        gpu: usize,
        slot: usize,
        job: u32,
        now: f64,
        until_s: f64,
        charged_gib: f64,
        host_bytes: u64,
    ) {
        let batch = self.batch as usize;
        debug_assert!(self.host_pool.fits(host_bytes), "host pool overcommitted");
        let g = &mut self.gpus[gpu];
        debug_assert!(!g.cordoned, "placing onto a cordoned GPU");
        let s = &mut g.slots[slot];
        let occ = s.residents.len();
        assert!(occ < batch, "placing onto a full slot");
        debug_assert!(
            occ == 0 || s.charged_gib() + charged_gib <= s.profile.mem_gib + 1e-9,
            "slot memory overcommitted"
        );
        s.residents.push(Resident {
            job,
            started_s: now,
            until_s,
            charged_gib,
            host_bytes,
        });
        let sms = s.profile.sms;
        let pid = s.profile.id;
        if occ == 0 {
            g.busy_slots += 1;
            g.busy_sms_count += sms;
            self.index.busy_sms += sms;
        }
        if host_bytes > 0 {
            g.offloaders_count += 1;
            self.host_pool.charge(host_bytes);
        }
        self.index.open[occ][pid.index()].remove(&(gpu, slot));
        if occ + 1 < batch {
            self.index.open[occ + 1][pid.index()].insert((gpu, slot));
        }
        self.index.idle_gpus.remove(&gpu);
    }

    /// Remove resident `job` from a slot; returns whether it was found
    /// (false makes a double finish a no-op).
    pub fn finish_job(&mut self, gpu: usize, slot: usize, job: u32, now: f64) -> bool {
        let batch = self.batch as usize;
        let g = &mut self.gpus[gpu];
        let s = &mut g.slots[slot];
        let occ = s.residents.len();
        let pos = match s.residents.iter().position(|r| r.job == job) {
            Some(p) => p,
            None => return false,
        };
        let r = s.residents.remove(pos);
        s.busy_accum_s += now - r.started_s;
        let sms = s.profile.sms;
        let pid = s.profile.id;
        if r.host_bytes > 0 {
            g.offloaders_count -= 1;
            self.host_pool.release(r.host_bytes);
        }
        if occ < batch {
            self.index.open[occ][pid.index()].remove(&(gpu, slot));
        }
        self.index.open[occ - 1][pid.index()].insert((gpu, slot));
        if occ == 1 {
            g.busy_slots -= 1;
            g.busy_sms_count -= sms;
            self.index.busy_sms -= sms;
            if g.busy_slots == 0 && !g.reconfiguring() {
                self.index.idle_gpus.insert(gpu);
            }
        }
        self.index.epoch += 1;
        true
    }

    /// Start repartitioning `gpu` to `target` (index-maintaining wrapper
    /// around `FleetGpu::begin_reconfig`). While the reconfiguration is in
    /// flight the GPU's slots leave the open index — it serves nothing.
    pub fn begin_reconfig(
        &mut self,
        gpu: usize,
        target: Vec<ProfileId>,
        until_s: f64,
    ) -> crate::Result<()> {
        self.gpus[gpu].begin_reconfig(target, until_s)?;
        // Success implies the GPU was fully idle: every slot was in the
        // occupancy-0 open set and comes out of it now.
        for (s, slot) in self.gpus[gpu].slots.iter().enumerate() {
            self.index.open[0][slot.profile.id.index()].remove(&(gpu, s));
        }
        self.index.idle_gpus.remove(&gpu);
        // The effective layout flips from the installed one to the pending
        // target (`effective_layout` returns the pending layout while the
        // reconfiguration is in flight).
        let g = &self.gpus[gpu];
        self.index.adjust_layout_gpus(&g.layout, false);
        self.index.adjust_layout_gpus(g.effective_layout(), true);
        Ok(())
    }

    /// Complete an in-flight reconfiguration on `gpu` (index-maintaining
    /// wrapper around `FleetGpu::finish_reconfig`). No-op when the GPU is
    /// not reconfiguring.
    pub fn finish_reconfig(&mut self, gpu: usize) {
        if !self.gpus[gpu].reconfiguring() {
            return;
        }
        self.gpus[gpu].finish_reconfig();
        if self.gpus[gpu].cordoned {
            // A fault cordoned the GPU while the repartition was in
            // flight: the new layout installs, but the hardware stays out
            // of service — no open slots, no idle candidacy, no epoch
            // bump (no capacity came back). `uncordon_gpu` restores it.
            return;
        }
        for (s, slot) in self.gpus[gpu].slots.iter().enumerate() {
            self.index.open[0][slot.profile.id.index()].insert((gpu, s));
        }
        self.index.idle_gpus.insert(gpu);
        self.index.epoch += 1;
    }

    /// Abort an in-flight reconfiguration after a transient driver fault
    /// (index-maintaining wrapper around `FleetGpu::abort_reconfig`): the
    /// latency was already paid, but the pending layout never lands — the
    /// installed layout's (empty, unchanged) slots return to the open
    /// index and the GPU becomes an idle reconfiguration candidate again.
    /// No-op when the GPU is not reconfiguring. If the GPU was cordoned
    /// mid-flight only the pending layout is dropped; `uncordon_gpu`
    /// restores the rest.
    pub fn abort_reconfig(&mut self, gpu: usize) {
        if !self.gpus[gpu].reconfiguring() {
            return;
        }
        if !self.gpus[gpu].cordoned {
            // The effective layout flips back from the pending target to
            // the installed one.
            let g = &self.gpus[gpu];
            self.index.adjust_layout_gpus(g.effective_layout(), false);
            self.index.adjust_layout_gpus(&g.layout, true);
        }
        self.gpus[gpu].abort_reconfig();
        if self.gpus[gpu].cordoned {
            return;
        }
        for (s, slot) in self.gpus[gpu].slots.iter().enumerate() {
            self.index.open[0][slot.profile.id.index()].insert((gpu, s));
        }
        self.index.idle_gpus.insert(gpu);
        self.index.epoch += 1;
    }

    /// Take `gpu` out of service after a hard fault: every resident is
    /// evicted (their pool/link/occupancy accounting unwound exactly, as
    /// if they had finished at `now`), the GPU's slots leave the open
    /// index, it stops being a reconfiguration candidate, and its layout
    /// no longer counts toward `has_layout_class`. Returns the evicted
    /// residents in deterministic `(slot, admission)` order so the fault
    /// plane can requeue them. Idempotence is the caller's job: cordoning
    /// an already-cordoned GPU is a bug.
    pub fn cordon_gpu(&mut self, gpu: usize, now: f64) -> Vec<Orphan> {
        assert!(!self.gpus[gpu].cordoned, "GPU {gpu} is already cordoned");
        let orphans: Vec<Orphan> = self.gpus[gpu]
            .slots
            .iter()
            .enumerate()
            .flat_map(|(s, slot)| {
                slot.residents.iter().map(move |r| Orphan {
                    job: r.job,
                    slot: s,
                    started_s: r.started_s,
                    until_s: r.until_s,
                })
            })
            .collect();
        for o in &orphans {
            let evicted = self.finish_job(gpu, o.slot, o.job, now);
            debug_assert!(evicted, "orphan {} vanished mid-cordon", o.job);
        }
        // Fully drained now: every slot sits in the occupancy-0 open set
        // (unless a reconfiguration already holds them out of the index).
        if !self.gpus[gpu].reconfiguring() {
            for (s, slot) in self.gpus[gpu].slots.iter().enumerate() {
                self.index.open[0][slot.profile.id.index()].remove(&(gpu, s));
            }
        }
        self.index.idle_gpus.remove(&gpu);
        self.index
            .adjust_layout_gpus(self.gpus[gpu].effective_layout(), false);
        self.gpus[gpu].cordoned = true;
        orphans
    }

    /// Return a repaired GPU to service: slots re-enter the open index
    /// empty, the GPU becomes a reconfiguration candidate again, its
    /// layout counts toward `has_layout_class`, and the availability
    /// epoch bumps (capacity came back). If a reconfiguration was in
    /// flight across the whole outage the GPU stays out of the open index
    /// until `finish_reconfig` lands it.
    pub fn uncordon_gpu(&mut self, gpu: usize) {
        assert!(self.gpus[gpu].cordoned, "GPU {gpu} is not cordoned");
        self.gpus[gpu].cordoned = false;
        self.index
            .adjust_layout_gpus(self.gpus[gpu].effective_layout(), true);
        if !self.gpus[gpu].reconfiguring() {
            for (s, slot) in self.gpus[gpu].slots.iter().enumerate() {
                self.index.open[0][slot.profile.id.index()].insert((gpu, s));
            }
            self.index.idle_gpus.insert(gpu);
        }
        self.index.epoch += 1;
    }

    /// Evict every resident of one slot after a slice-level (ECC/Xid)
    /// fault — the slot itself survives and immediately returns to the
    /// open index as empty capacity. Returns the evicted residents in
    /// admission order.
    pub fn drain_slot(&mut self, gpu: usize, slot: usize, now: f64) -> Vec<Orphan> {
        let orphans: Vec<Orphan> = self.gpus[gpu].slots[slot]
            .residents
            .iter()
            .map(|r| Orphan {
                job: r.job,
                slot,
                started_s: r.started_s,
                until_s: r.until_s,
            })
            .collect();
        for o in &orphans {
            let evicted = self.finish_job(gpu, slot, o.job, now);
            debug_assert!(evicted, "orphan {} vanished mid-drain", o.job);
        }
        orphans
    }

    /// Instantaneous fragmentation: the fraction of *idle* SMs stranded in
    /// slots whose memory cannot directly host the smallest pending job
    /// (`needed_gib` = footprint + context). 0 when nothing is pending or
    /// nothing is idle — idle capacity only counts as fragmented while
    /// work is actually waiting for it. Partially-occupied slots are not
    /// idle capacity: their SMs are already serving. O(profile classes)
    /// via the index.
    pub fn fragmentation(&self, needed_gib: Option<f64>) -> f64 {
        let needed = match needed_gib {
            Some(n) => n,
            None => return 0.0,
        };
        let mut idle_sms = 0u32;
        let mut stranded_sms = 0u32;
        for pid in ALL_PROFILES {
            let n = self.index.open[0][pid.index()].len() as u32;
            if n == 0 {
                continue;
            }
            let prof = GiProfile::get(pid);
            idle_sms += n * prof.sms;
            if prof.mem_gib < needed {
                stranded_sms += n * prof.sms;
            }
        }
        if idle_sms == 0 {
            0.0
        } else {
            stranded_sms as f64 / idle_sms as f64
        }
    }

    /// Fragmentation recomputed by a full slot scan — the
    /// differential-test oracle for `fragmentation`.
    pub fn fragmentation_scan(&self, needed_gib: Option<f64>) -> f64 {
        let needed = match needed_gib {
            Some(n) => n,
            None => return 0.0,
        };
        let mut idle_sms = 0u32;
        let mut stranded_sms = 0u32;
        for g in &self.gpus {
            if g.out_of_service() {
                continue;
            }
            for s in &g.slots {
                if s.is_idle() {
                    idle_sms += s.profile.sms;
                    if s.profile.mem_gib < needed {
                        stranded_sms += s.profile.sms;
                    }
                }
            }
        }
        if idle_sms == 0 {
            0.0
        } else {
            stranded_sms as f64 / idle_sms as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::profile::ProfileId::*;

    #[test]
    fn presets_build_valid_fleets() {
        for preset in [LayoutPreset::Mixed, LayoutPreset::AllSmall, LayoutPreset::AllBig] {
            let f = Fleet::new(5, preset).unwrap();
            assert_eq!(f.gpus.len(), 5);
            assert_eq!(f.batch(), 1);
            for n in &f.gpus {
                assert!(!n.slots.is_empty());
                validate_layout(&n.layout).unwrap();
            }
        }
        assert!(Fleet::new(0, LayoutPreset::Mixed).is_err());
        assert!(Fleet::with_batch(1, LayoutPreset::Mixed, 0).is_err());
        assert!(Fleet::with_batch(1, LayoutPreset::Mixed, MAX_BATCH + 1).is_err());
    }

    #[test]
    fn every_class_layout_is_valid_and_led_by_its_class() {
        for class in crate::mig::profile::ALL_PROFILES {
            let layout = class_layout(class);
            validate_layout(&layout).unwrap();
            assert_eq!(layout[0], class, "largest instance leads the layout");
        }
    }

    #[test]
    fn invalid_layout_rejected() {
        // 3x3g overflows the 8 memory slices.
        assert!(validate_layout(&[P3g48gb, P3g48gb, P3g48gb]).is_err());
        assert!(FleetGpu::new(0, vec![]).is_err());
    }

    #[test]
    fn job_lifecycle_accounting() {
        let mut f = Fleet::new(1, LayoutPreset::AllSmall).unwrap();
        assert_eq!(f.busy_sms(), 0);
        f.start_job(0, 2, 42, 1.0, 5.0, 0.5, 0);
        assert_eq!(f.busy_sms(), 16);
        assert!(!f.gpus[0].all_idle());
        assert!(f.finish_job(0, 2, 42, 5.0));
        assert_eq!(f.busy_sms(), 0);
        assert!((f.gpus[0].slots[2].busy_accum_s - 4.0).abs() < 1e-12);
        assert!(!f.finish_job(0, 2, 42, 5.0), "double finish is a no-op");
    }

    #[test]
    fn batched_slot_lifecycle_and_memory_accounting() {
        let mut f = Fleet::with_batch(1, LayoutPreset::AllBig, 3).unwrap();
        assert_eq!(f.batch(), 3);
        assert_eq!(f.open_sm_seats(), 132 * 3);
        f.start_job(0, 0, 1, 0.0, 10.0, 2.0, 0);
        // Occupied slot: SMs fully busy, GPU no longer idle, seat count
        // down by one, still open to co-residents.
        assert_eq!(f.busy_sms(), 132);
        assert_eq!(f.open_sm_seats(), 132 * 2);
        assert_eq!(f.idle_gpus().count(), 0);
        assert_eq!(f.first_idle(P7g96gb), None, "no empty slot left");
        assert_eq!(f.first_open_fitting(P7g96gb, 1, 3.0), Some((0, 0)));
        f.start_job(0, 0, 2, 1.0, 8.0, 3.0, 0);
        assert_eq!(f.gpus[0].slots[0].occupancy(), 2);
        assert!((f.gpus[0].slots[0].charged_gib() - 5.0).abs() < 1e-12);
        assert_eq!(f.busy_sms(), 132, "co-residents share the same SMs");
        assert_eq!(f.open_sm_seats(), 132);
        // Memory gate: a co-resident that would overflow the slice is not
        // offered the slot.
        assert_eq!(f.first_open_fitting(P7g96gb, 2, 90.0), None);
        assert_eq!(f.first_open_fitting(P7g96gb, 2, 80.0), Some((0, 0)));
        f.start_job(0, 0, 3, 1.5, 9.0, 1.0, 0);
        assert_eq!(f.open_sm_seats(), 0, "slot full");
        // Finishing the middle resident frees a seat and bumps the epoch.
        let e = f.epoch();
        assert!(f.finish_job(0, 0, 2, 4.0));
        assert!(f.epoch() > e);
        assert_eq!(f.open_sm_seats(), 132);
        assert_eq!(f.gpus[0].slots[0].occupancy(), 2);
        assert!((f.gpus[0].slots[0].charged_gib() - 3.0).abs() < 1e-12);
        // Draining restores the empty-slot state exactly.
        assert!(f.finish_job(0, 0, 1, 10.0));
        assert!(f.finish_job(0, 0, 3, 9.0));
        assert_eq!(f.busy_sms(), 0);
        assert_eq!(f.gpus[0].slots[0].charged_gib(), 0.0, "drained slot charges 0.0 exactly");
        assert_eq!(f.open_sm_seats(), 132 * 3);
        assert_eq!(f.first_idle(P7g96gb), Some((0, 0)));
        assert_eq!(f.idle_gpus().collect::<Vec<_>>(), vec![0]);
    }

    #[test]
    fn reconfig_requires_idle_and_validates() {
        let mut f = Fleet::new(1, LayoutPreset::AllSmall).unwrap();
        f.start_job(0, 0, 1, 0.0, 10.0, 0.5, 0);
        assert!(f
            .begin_reconfig(0, vec![P2g24gb, P2g24gb, P2g24gb, P1g12gb], 5.0)
            .is_err());
        f.finish_job(0, 0, 1, 10.0);
        // Invalid target rejected even on an idle GPU.
        assert!(f.begin_reconfig(0, vec![P4g48gb, P4g48gb], 12.0).is_err());
        f.begin_reconfig(0, vec![P2g24gb, P2g24gb, P2g24gb, P1g12gb], 12.0)
            .unwrap();
        assert!(f.gpus[0].reconfiguring());
        assert_eq!(f.gpus[0].effective_layout().len(), 4);
        // Cannot stack a second reconfiguration.
        assert!(f.begin_reconfig(0, vec![P7g96gb], 13.0).is_err());
        f.finish_reconfig(0);
        assert!(!f.gpus[0].reconfiguring());
        assert_eq!(f.gpus[0].slots.len(), 4);
        assert_eq!(f.gpus[0].reconfigs, 1);
        assert_eq!(f.gpus[0].slots[0].profile.name, "2g.24gb");
    }

    #[test]
    fn abort_reconfig_keeps_old_layout_and_restores_index() {
        let mut f = Fleet::new(1, LayoutPreset::AllSmall).unwrap();
        let before_epoch = f.epoch();
        f.begin_reconfig(0, vec![P7g96gb], 5.0).unwrap();
        assert!(f.gpus[0].reconfiguring());
        f.abort_reconfig(0);
        // Old layout (7x1g) survives, its empty slots are placeable again,
        // and the reconfig counter never moved (nothing landed).
        assert!(!f.gpus[0].reconfiguring());
        assert_eq!(f.gpus[0].slots.len(), 7);
        assert_eq!(f.gpus[0].reconfigs, 0);
        assert!(f.epoch() > before_epoch);
        assert_index_matches_scan(&f);
        // Idempotent on a GPU that is not reconfiguring.
        f.abort_reconfig(0);
        assert_index_matches_scan(&f);
        // Abort while cordoned only drops the pending layout; the GPU
        // stays out of service until uncordoned.
        f.begin_reconfig(0, vec![P7g96gb], 9.0).unwrap();
        let _ = f.cordon_gpu(0, 6.0);
        f.abort_reconfig(0);
        assert!(!f.gpus[0].reconfiguring());
        assert!(f.gpus[0].cordoned());
        assert_index_matches_scan(&f);
        f.uncordon_gpu(0);
        assert_eq!(f.gpus[0].slots.len(), 7);
        assert_index_matches_scan(&f);
    }

    #[test]
    fn fragmentation_counts_stranded_idle_sms() {
        let mut f = Fleet::new(1, LayoutPreset::Mixed).unwrap(); // 7x1g
        // A 16 GiB job cannot use any idle 1g slot: everything stranded.
        assert!((f.fragmentation(Some(16.0)) - 1.0).abs() < 1e-12);
        // A small job fits everywhere: no fragmentation.
        assert_eq!(f.fragmentation(Some(4.0)), 0.0);
        // Nothing pending: no fragmentation by definition.
        assert_eq!(f.fragmentation(None), 0.0);
        // All busy: nothing idle to strand.
        for i in 0..7 {
            f.start_job(0, i, i as u32, 0.0, 1.0, 0.5, 0);
        }
        assert_eq!(f.fragmentation(Some(16.0)), 0.0);
    }

    /// Scan-derived truth for the open index (first slot of a profile at
    /// an exact occupancy, excluding reconfiguring GPUs; no memory check).
    fn first_open_scan(f: &Fleet, pid: ProfileId, occ: usize) -> Option<(usize, usize)> {
        for (g, gpu) in f.gpus.iter().enumerate() {
            if gpu.out_of_service() {
                continue;
            }
            for (s, slot) in gpu.slots.iter().enumerate() {
                if slot.occupancy() == occ && slot.profile.id == pid {
                    return Some((g, s));
                }
            }
        }
        None
    }

    fn assert_index_matches_scan(f: &Fleet) {
        assert_eq!(f.busy_sms(), f.busy_sms_scan());
        for pid in ALL_PROFILES {
            assert_eq!(f.first_idle(pid), first_open_scan(f, pid, 0), "{pid:?}");
            for occ in 0..f.batch() as usize {
                let count_scan = f
                    .gpus
                    .iter()
                    .filter(|g| !g.out_of_service())
                    .flat_map(|g| g.slots.iter())
                    .filter(|s| s.occupancy() == occ && s.profile.id == pid)
                    .count();
                assert_eq!(f.open_count(pid, occ), count_scan, "{pid:?} occ={occ}");
                // A large need never matches an occupied slot; need 0.0
                // accepts any open slot — both must agree with the scan.
                assert_eq!(
                    f.first_open_fitting(pid, occ, 0.0),
                    first_open_scan(f, pid, occ),
                    "{pid:?} occ={occ}"
                );
            }
        }
        for needed in [0.5, 12.0, 24.0, 47.0, 95.0] {
            assert_eq!(
                f.fragmentation(Some(needed)),
                f.fragmentation_scan(Some(needed)),
                "needed={needed}"
            );
        }
        let idle_scan: Vec<usize> = f
            .gpus
            .iter()
            .enumerate()
            .filter(|(_, n)| !n.out_of_service() && n.all_idle())
            .map(|(g, _)| g)
            .collect();
        assert_eq!(f.idle_gpus().collect::<Vec<_>>(), idle_scan);
        let idle_sms_scan: u32 = f
            .gpus
            .iter()
            .filter(|g| !g.out_of_service())
            .flat_map(|g| g.slots.iter())
            .filter(|s| s.is_idle())
            .map(|s| s.profile.sms)
            .sum();
        assert_eq!(f.idle_slot_sms(), idle_sms_scan);
        assert_eq!(f.open_sm_seats(), f.open_sm_seats_scan());
        assert_eq!(f.largest_open_slot_gib(), f.largest_open_slot_gib_scan());
        assert_eq!(f.max_open_headroom_gib(), f.max_open_headroom_gib_scan());
        assert_eq!(f.host_used_bytes(), f.host_used_bytes_scan());
        for gpu in &f.gpus {
            assert_eq!(gpu.offloaders(), gpu.offloaders_scan(), "gpu {}", gpu.id);
        }
        if f.batch() == 1 {
            // The batched headroom signals must degenerate to the idle
            // signals exactly — the two API families may never drift.
            assert_eq!(f.open_sm_seats(), f.idle_slot_sms());
            assert_eq!(f.largest_open_slot_gib(), f.largest_idle_slot_gib());
        }
        let largest_scan = f
            .gpus
            .iter()
            .filter(|g| !g.out_of_service())
            .flat_map(|g| g.slots.iter())
            .filter(|s| s.is_idle())
            .map(|s| s.profile.mem_gib)
            .fold(0.0f64, f64::max);
        assert_eq!(f.largest_idle_slot_gib(), largest_scan);
        for pid in ALL_PROFILES {
            let present_scan = f
                .gpus
                .iter()
                .filter(|n| !n.cordoned())
                .any(|n| n.effective_layout().contains(&pid));
            assert_eq!(f.has_layout_class(pid), present_scan, "{pid:?}");
        }
    }

    #[test]
    fn host_pool_and_offloader_accounting_lifecycle() {
        // Finite pool: charges at start, releases at finish, exact zero
        // after a full drain; per-GPU offloader counts track residents
        // with host bytes.
        let mut f = Fleet::with_hostmem(2, LayoutPreset::AllSmall, 1, 8.0).unwrap();
        assert_eq!(f.host_capacity_bytes(), Some(8 << 30));
        let spill_a = 5 << 30;
        let spill_b = 2 << 30;
        assert!(f.host_fits(spill_a));
        f.start_job(0, 0, 1, 0.0, 10.0, 10.9, spill_a);
        assert_eq!(f.gpus[0].offloaders(), 1);
        assert_eq!(f.host_used_bytes(), spill_a);
        assert!(f.host_fits(spill_b));
        assert!(!f.host_fits(4 << 30), "8 GiB pool refuses 5 + 4");
        f.start_job(1, 0, 2, 0.0, 10.0, 10.9, spill_b);
        assert_eq!(f.gpus[1].offloaders(), 1);
        // A fully-resident job is no offloader and charges nothing.
        f.start_job(0, 1, 3, 0.0, 10.0, 0.5, 0);
        assert_eq!(f.gpus[0].offloaders(), 1);
        assert_eq!(f.host_used_bytes(), spill_a + spill_b);
        assert_eq!(f.host_used_bytes(), f.host_used_bytes_scan());
        assert!(f.finish_job(0, 0, 1, 5.0));
        assert_eq!(f.gpus[0].offloaders(), 0);
        assert_eq!(f.host_used_bytes(), spill_b);
        assert!(f.finish_job(1, 0, 2, 6.0));
        assert!(f.finish_job(0, 1, 3, 7.0));
        assert_eq!(f.host_used_bytes(), 0, "drain restores the pool exactly");
        assert_eq!(f.host_headroom_bytes(), 8 << 30);
        // The unlimited pool never gates.
        let inf = Fleet::new(1, LayoutPreset::AllSmall).unwrap();
        assert_eq!(inf.host_capacity_bytes(), None);
        assert!(inf.host_fits(u64::MAX));
        assert!(Fleet::with_hostmem(1, LayoutPreset::AllSmall, 1, 0.0).is_err());
    }

    #[test]
    fn cordon_drains_residents_and_restores_accounting_exactly() {
        // Two all-small GPUs, finite pool; GPU 0 carries a resident job
        // and an offloader when the fault hits.
        let mut f = Fleet::with_hostmem(2, LayoutPreset::AllSmall, 1, 8.0).unwrap();
        f.start_job(0, 0, 1, 0.0, 10.0, 0.5, 0);
        f.start_job(0, 3, 2, 1.0, 12.0, 10.9, 2 << 30);
        f.start_job(1, 0, 3, 0.0, 10.0, 0.5, 0);
        assert_eq!(f.host_used_bytes(), 2 << 30);
        assert_eq!(f.gpus[0].offloaders(), 1);

        let orphans = f.cordon_gpu(0, 4.0);
        assert_eq!(orphans.len(), 2);
        assert_eq!(orphans[0], Orphan { job: 1, slot: 0, started_s: 0.0, until_s: 10.0 });
        assert_eq!(orphans[1], Orphan { job: 2, slot: 3, started_s: 1.0, until_s: 12.0 });
        // Accounting unwound exactly: pool, link share, SMs, busy slots.
        assert_eq!(f.host_used_bytes(), 0, "orphan spill released");
        assert_eq!(f.gpus[0].offloaders(), 0);
        assert_eq!(f.busy_sms(), f.busy_sms_scan());
        assert!(f.gpus[0].all_idle());
        assert!(f.gpus[0].cordoned());
        assert!(f.gpus[0].out_of_service());
        // The cordoned GPU is invisible to every placement surface: no
        // open slots, not an idle candidate, layout uncounted.
        assert_eq!(f.first_idle(P1g12gb), Some((1, 1)), "only GPU 1 serves");
        assert_eq!(f.idle_gpus().count(), 0, "GPU 0 cordoned, GPU 1 busy");
        assert!(f.has_layout_class(P1g12gb), "GPU 1 still carries the class");
        assert_index_matches_scan(&f);
        let _ = f.cordon_gpu(1, 4.0);
        assert!(!f.has_layout_class(P1g12gb), "whole class cordoned away");
        assert_eq!(f.open_sm_seats(), 0);
        f.uncordon_gpu(1);

        // Repair returns the GPU empty and bumps the epoch.
        let e = f.epoch();
        f.uncordon_gpu(0);
        assert!(f.epoch() > e);
        assert!(!f.gpus[0].cordoned());
        assert_eq!(f.first_idle(P1g12gb), Some((0, 0)));
        assert_index_matches_scan(&f);
    }

    #[test]
    fn cordon_across_inflight_reconfig_installs_layout_out_of_service() {
        let mut f = Fleet::new(2, LayoutPreset::AllSmall).unwrap();
        f.begin_reconfig(0, class_layout(P7g96gb), 5.0).unwrap();
        // Fault mid-repartition: no residents to orphan; the GPU stays
        // invisible after the reconfiguration lands because it is still
        // cordoned.
        assert!(f.cordon_gpu(0, 2.0).is_empty());
        assert_index_matches_scan(&f);
        f.finish_reconfig(0);
        assert!(!f.gpus[0].reconfiguring());
        assert_eq!(f.gpus[0].slots.len(), 1, "new layout installed");
        assert_eq!(f.first_idle(P7g96gb), None, "still cordoned");
        assert!(!f.has_layout_class(P7g96gb));
        assert_index_matches_scan(&f);
        f.uncordon_gpu(0);
        assert_eq!(f.first_idle(P7g96gb), Some((0, 0)));
        assert!(f.has_layout_class(P7g96gb));
        assert_index_matches_scan(&f);
        // A cordoned GPU refuses reconfiguration outright.
        let _ = f.cordon_gpu(1, 6.0);
        assert!(f.begin_reconfig(1, class_layout(P7g96gb), 8.0).is_err());
    }

    #[test]
    fn drain_slot_evicts_one_resident_set_only() {
        let mut f = Fleet::with_batch(1, LayoutPreset::AllSmall, 2).unwrap();
        f.start_job(0, 2, 7, 0.0, 10.0, 0.5, 0);
        f.start_job(0, 2, 8, 1.0, 11.0, 0.5, 0);
        f.start_job(0, 4, 9, 0.0, 10.0, 0.5, 0);
        let orphans = f.drain_slot(0, 2, 3.0);
        assert_eq!(
            orphans.iter().map(|o| o.job).collect::<Vec<_>>(),
            vec![7, 8],
            "both co-residents of the faulted slice die"
        );
        assert!(f.gpus[0].slots[2].is_idle());
        assert_eq!(f.gpus[0].slots[4].occupancy(), 1, "other slices unharmed");
        // The slot itself survives and returns to the open index.
        assert_eq!(f.first_idle(P1g12gb), Some((0, 0)));
        assert!(f.drain_slot(0, 3, 3.5).is_empty(), "empty slice drains empty");
        assert_index_matches_scan(&f);
    }

    #[test]
    fn per_share_open_walk_matches_scan_truth() {
        // Three all-big GPUs with 0 / 1 / 2 offloaders: the per-share walk
        // must surface the first open slot of each distinct link-share
        // level, in (gpu, slot) order.
        let mut f = Fleet::with_batch(3, LayoutPreset::AllBig, 4).unwrap();
        f.start_job(1, 0, 1, 0.0, 10.0, 20.0, 1 << 30);
        f.start_job(2, 0, 2, 0.0, 10.0, 20.0, 1 << 30);
        f.start_job(2, 0, 3, 0.0, 10.0, 20.0, 1 << 30);
        let mut out = Vec::new();
        // Empty slots (occ 0): only GPU 0's slot is empty.
        f.first_open_fitting_per_share(P7g96gb, 0, 5.0, &mut out);
        assert_eq!(out, vec![(0, 0, 0)]);
        // Occupied open seats (occ 1 / 2) carry their GPU's share level.
        f.first_open_fitting_per_share(P7g96gb, 1, 5.0, &mut out);
        assert_eq!(out, vec![(1, 0, 1)]);
        f.first_open_fitting_per_share(P7g96gb, 2, 5.0, &mut out);
        assert_eq!(out, vec![(2, 0, 2)]);
        // The memory gate still applies to occupied slots.
        f.first_open_fitting_per_share(P7g96gb, 1, 90.0, &mut out);
        assert!(out.is_empty());
        // Duplicate share levels keep only the first (gpu, slot).
        let mut g = Fleet::with_batch(2, LayoutPreset::AllBig, 2).unwrap();
        g.start_job(0, 0, 1, 0.0, 10.0, 20.0, 1 << 30);
        g.start_job(1, 0, 2, 0.0, 10.0, 20.0, 1 << 30);
        g.first_open_fitting_per_share(P7g96gb, 1, 5.0, &mut out);
        assert_eq!(out, vec![(0, 0, 1)]);
    }

    #[test]
    fn per_gpu_candidates_keep_one_slot_per_board() {
        let mut f = Fleet::with_batch(3, LayoutPreset::AllBig, 4).unwrap();
        f.start_job(1, 0, 1, 0.0, 10.0, 20.0, 1 << 30);
        f.start_job(2, 0, 2, 0.0, 10.0, 20.0, 1 << 30);
        f.start_job(2, 0, 3, 0.0, 10.0, 20.0, 1 << 30);
        let mut out = Vec::new();
        // Unlike the per-share dedup, identical share levels on
        // different boards each keep a candidate.
        let mut g = Fleet::with_batch(2, LayoutPreset::AllBig, 2).unwrap();
        g.start_job(0, 0, 1, 0.0, 10.0, 20.0, 1 << 30);
        g.start_job(1, 0, 2, 0.0, 10.0, 20.0, 1 << 30);
        g.first_open_fitting_per_gpu(P7g96gb, 1, 5.0, &mut out);
        assert_eq!(out, vec![(0, 0, 1), (1, 0, 1)]);
        // The memory gate still applies, and each surviving board's
        // first fitting slot wins.
        f.first_open_fitting_per_gpu(P7g96gb, 1, 90.0, &mut out);
        assert!(out.is_empty());
        f.first_open_fitting_per_gpu(P7g96gb, 0, 5.0, &mut out);
        assert_eq!(out, vec![(0, 0, 0)]);
    }

    #[test]
    fn index_tracks_scan_truth_through_randomized_lifecycle() {
        for batch in [1u32, 3] {
            let mut rng = crate::util::Rng::new(0x1D7E + batch as u64);
            let mut f = Fleet::with_batch(4, LayoutPreset::Mixed, batch).unwrap();
            let mut epoch = f.epoch();
            let mut next_job = 0u32;
            for step in 0..400u32 {
                let g = rng.below(4) as usize;
                match rng.below(6) {
                    0 => {
                        // Start a job on the first open seat of GPU g.
                        if !f.gpus[g].out_of_service() {
                            if let Some(s) = f.gpus[g]
                                .slots
                                .iter()
                                .position(|s| (s.occupancy() as u32) < batch)
                            {
                                f.start_job(
                                    g,
                                    s,
                                    next_job,
                                    step as f64,
                                    step as f64 + 5.0,
                                    0.25,
                                    // Every third job parks spill in the
                                    // host pool (exercises the offloader
                                    // counters through the lifecycle).
                                    if next_job % 3 == 0 { 1 << 28 } else { 0 },
                                );
                                next_job += 1;
                            }
                        }
                    }
                    1 => {
                        // Finish the oldest resident of the first occupied
                        // slot of GPU g.
                        if let Some(s) =
                            f.gpus[g].slots.iter().position(|s| !s.is_idle())
                        {
                            let job = f.gpus[g].slots[s].residents[0].job;
                            let before = f.epoch();
                            assert!(f.finish_job(g, s, job, step as f64));
                            assert!(f.epoch() > before, "finish must bump the epoch");
                        }
                    }
                    2 => {
                        let target = class_layout(ALL_PROFILES[rng.below(6) as usize]);
                        let _ = f.begin_reconfig(g, target, step as f64 + 3.0);
                    }
                    3 => {
                        let was = f.gpus[g].reconfiguring();
                        let cordoned = f.gpus[g].cordoned();
                        f.finish_reconfig(g);
                        if was && !cordoned {
                            assert!(f.epoch() > epoch, "reconfig done must bump the epoch");
                        }
                    }
                    4 => {
                        // Fault: cordon-and-drain GPU g (legal even while
                        // it is mid-reconfiguration).
                        if !f.gpus[g].cordoned() {
                            let residents: Vec<u32> = f.gpus[g]
                                .slots
                                .iter()
                                .flat_map(|s| s.residents.iter().map(|r| r.job))
                                .collect();
                            let orphans = f.cordon_gpu(g, step as f64);
                            let got: Vec<u32> = orphans.iter().map(|o| o.job).collect();
                            assert_eq!(got, residents, "orphans in (slot, admission) order");
                            assert!(f.gpus[g].all_idle(), "cordon drains the GPU");
                        }
                    }
                    _ => {
                        // Repair: return GPU g to service.
                        if f.gpus[g].cordoned() {
                            f.uncordon_gpu(g);
                            assert!(f.epoch() > epoch, "repair must bump the epoch");
                        }
                    }
                }
                epoch = f.epoch();
                for gpu in &f.gpus {
                    for s in &gpu.slots {
                        assert!((s.occupancy() as u32) <= batch, "occupancy over batch");
                    }
                }
                assert_index_matches_scan(&f);
            }
        }
    }
}
