//! The GPU fleet: N statically-partitioned GPUs, each carrying a MIG
//! layout (a list of GI profiles validated against the slice budget) whose
//! instances act as serving slots.
//!
//! A node can be *repartitioned* while fully idle (the §II-B3 static-
//! configuration constraint, lifted to the fleet level: reconfiguration is
//! allowed, but only on a drained GPU and only through layouts that the
//! `MigManager` slice-budget validation accepts). While a reconfiguration
//! is in flight the node serves nothing.

use crate::gpu::GpuSpec;
use crate::mig::profile::{GiProfile, ProfileId};
use crate::mig::MigManager;
use anyhow::{bail, ensure};

/// What a serving slot (one MIG instance) is doing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SlotState {
    Idle,
    Busy {
        job: u32,
        started_s: f64,
        until_s: f64,
    },
}

/// One MIG instance acting as a serving slot.
#[derive(Debug, Clone)]
pub struct Slot {
    pub profile: GiProfile,
    pub state: SlotState,
    /// Cumulative busy time (slot-seconds of service).
    pub busy_accum_s: f64,
}

impl Slot {
    fn new(profile_id: ProfileId) -> Slot {
        Slot {
            profile: GiProfile::get(profile_id),
            state: SlotState::Idle,
            busy_accum_s: 0.0,
        }
    }

    pub fn is_idle(&self) -> bool {
        self.state == SlotState::Idle
    }
}

/// Initial per-GPU layout assignment for a fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LayoutPreset {
    /// Cycle through four complementary layouts (fine slices on GPU 0,
    /// progressively coarser on the rest) — the operator's hedge when the
    /// job mix is unknown.
    Mixed,
    /// Every GPU split into 7x1g.12gb — maximum slot count, no slice
    /// admits a >11 GiB job without offloading or reconfiguration.
    AllSmall,
    /// Every GPU left whole (1x7g.96gb).
    AllBig,
}

impl LayoutPreset {
    pub fn parse(s: &str) -> Option<LayoutPreset> {
        match s {
            "mixed" => Some(LayoutPreset::Mixed),
            "small" => Some(LayoutPreset::AllSmall),
            "big" => Some(LayoutPreset::AllBig),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            LayoutPreset::Mixed => "mixed",
            LayoutPreset::AllSmall => "small",
            LayoutPreset::AllBig => "big",
        }
    }

    /// The layout for GPU `idx` under this preset.
    pub fn layout_for(&self, idx: usize) -> Vec<ProfileId> {
        use ProfileId::*;
        match self {
            LayoutPreset::AllSmall => class_layout(P1g12gb),
            LayoutPreset::AllBig => class_layout(P7g96gb),
            LayoutPreset::Mixed => match idx % 4 {
                0 => class_layout(P1g12gb),
                1 => class_layout(P2g24gb),
                2 => class_layout(P4g48gb),
                _ => class_layout(P3g48gb),
            },
        }
    }
}

/// The canonical packed whole-GPU layout whose *largest* instance is
/// `class`: the single source of truth shared by the fleet presets and by
/// `reconfig::plan_for_footprint`, so reconfiguration targets always match
/// the preset shapes (`plan_reconfig` compares layouts for equality).
pub fn class_layout(class: ProfileId) -> Vec<ProfileId> {
    use ProfileId::*;
    match class {
        P1g12gb => vec![P1g12gb; 7],
        P1g24gb => vec![P1g24gb; 4],
        P2g24gb => vec![P2g24gb, P2g24gb, P2g24gb, P1g12gb],
        P3g48gb => vec![P3g48gb, P3g48gb],
        P4g48gb => vec![P4g48gb, P3g48gb],
        P7g96gb => vec![P7g96gb],
    }
}

/// Check a layout against the MIG slice budget by actually creating the
/// instances through the manager (the single source of placement truth).
pub fn validate_layout(layout: &[ProfileId]) -> crate::Result<()> {
    ensure!(!layout.is_empty(), "a GPU layout needs at least one instance");
    let mut mgr = MigManager::new(GpuSpec::gh_h100_96gb());
    for p in layout {
        mgr.create_full(*p)?;
    }
    Ok(())
}

/// One GPU of the fleet.
#[derive(Debug)]
pub struct GpuNode {
    pub id: usize,
    pub layout: Vec<ProfileId>,
    pub slots: Vec<Slot>,
    /// `Some(t)` while a MIG reconfiguration completes at time `t`.
    pub reconfiguring_until: Option<f64>,
    /// The layout being installed by the in-flight reconfiguration.
    pub pending_layout: Option<Vec<ProfileId>>,
    /// Completed reconfigurations (diagnostics).
    pub reconfigs: u32,
}

impl GpuNode {
    pub fn new(id: usize, layout: Vec<ProfileId>) -> crate::Result<GpuNode> {
        validate_layout(&layout)?;
        let slots = layout.iter().map(|&p| Slot::new(p)).collect();
        Ok(GpuNode {
            id,
            layout,
            slots,
            reconfiguring_until: None,
            pending_layout: None,
            reconfigs: 0,
        })
    }

    pub fn reconfiguring(&self) -> bool {
        self.reconfiguring_until.is_some()
    }

    /// True when every slot is idle (a precondition for reconfiguration).
    pub fn all_idle(&self) -> bool {
        self.slots.iter().all(|s| s.is_idle())
    }

    /// SMs currently running jobs on this node.
    pub fn busy_sms(&self) -> u32 {
        self.slots
            .iter()
            .filter(|s| !s.is_idle())
            .map(|s| s.profile.sms)
            .sum()
    }

    /// The layout this node will have once any in-flight reconfiguration
    /// lands (used when deciding whether yet another reconfiguration is
    /// needed for a queued job).
    pub fn effective_layout(&self) -> &[ProfileId] {
        self.pending_layout.as_deref().unwrap_or(&self.layout)
    }

    /// Start repartitioning to `target`; the node serves nothing until
    /// `until_s`. Fails on a busy or already-reconfiguring node and on an
    /// invalid target layout — MIG cannot change under running work.
    pub fn begin_reconfig(&mut self, target: Vec<ProfileId>, until_s: f64) -> crate::Result<()> {
        if !self.all_idle() {
            bail!("GPU {} has running jobs; MIG cannot be reconfigured", self.id);
        }
        if self.reconfiguring() {
            bail!("GPU {} is already reconfiguring", self.id);
        }
        validate_layout(&target)?;
        self.pending_layout = Some(target);
        self.reconfiguring_until = Some(until_s);
        Ok(())
    }

    /// Complete the in-flight reconfiguration: install the pending layout
    /// and rebuild the (empty) slots.
    pub fn finish_reconfig(&mut self) {
        if let Some(layout) = self.pending_layout.take() {
            self.slots = layout.iter().map(|&p| Slot::new(p)).collect();
            self.layout = layout;
            self.reconfigs += 1;
        }
        self.reconfiguring_until = None;
    }
}

/// The multi-GPU fleet.
#[derive(Debug)]
pub struct Fleet {
    pub nodes: Vec<GpuNode>,
    pub spec: GpuSpec,
}

impl Fleet {
    pub fn new(gpus: u32, preset: LayoutPreset) -> crate::Result<Fleet> {
        ensure!(gpus >= 1, "fleet needs at least one GPU");
        let nodes = (0..gpus as usize)
            .map(|i| GpuNode::new(i, preset.layout_for(i)))
            .collect::<crate::Result<Vec<_>>>()?;
        Ok(Fleet {
            nodes,
            spec: GpuSpec::gh_h100_96gb(),
        })
    }

    /// Physical SMs across the fleet.
    pub fn total_sms(&self) -> u32 {
        self.spec.sms * self.nodes.len() as u32
    }

    pub fn busy_sms(&self) -> u32 {
        self.nodes.iter().map(|n| n.busy_sms()).sum()
    }

    /// Mark a slot busy with `job` until `until_s`.
    pub fn start_job(&mut self, gpu: usize, slot: usize, job: u32, now: f64, until_s: f64) {
        let s = &mut self.nodes[gpu].slots[slot];
        assert!(s.is_idle(), "placing onto a busy slot");
        s.state = SlotState::Busy {
            job,
            started_s: now,
            until_s,
        };
    }

    /// Free a slot; returns the job that was running there.
    pub fn finish_job(&mut self, gpu: usize, slot: usize, now: f64) -> Option<u32> {
        let s = &mut self.nodes[gpu].slots[slot];
        match s.state {
            SlotState::Busy { job, started_s, .. } => {
                s.busy_accum_s += now - started_s;
                s.state = SlotState::Idle;
                Some(job)
            }
            SlotState::Idle => None,
        }
    }

    /// Instantaneous fragmentation: the fraction of *idle* SMs stranded in
    /// slots whose memory cannot directly host the smallest pending job
    /// (`needed_gib` = footprint + context). 0 when nothing is pending or
    /// nothing is idle — idle capacity only counts as fragmented while
    /// work is actually waiting for it.
    pub fn fragmentation(&self, needed_gib: Option<f64>) -> f64 {
        let needed = match needed_gib {
            Some(n) => n,
            None => return 0.0,
        };
        let mut idle_sms = 0u32;
        let mut stranded_sms = 0u32;
        for node in &self.nodes {
            if node.reconfiguring() {
                continue;
            }
            for s in &node.slots {
                if s.is_idle() {
                    idle_sms += s.profile.sms;
                    if s.profile.mem_gib < needed {
                        stranded_sms += s.profile.sms;
                    }
                }
            }
        }
        if idle_sms == 0 {
            0.0
        } else {
            stranded_sms as f64 / idle_sms as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mig::profile::ProfileId::*;

    #[test]
    fn presets_build_valid_fleets() {
        for preset in [LayoutPreset::Mixed, LayoutPreset::AllSmall, LayoutPreset::AllBig] {
            let f = Fleet::new(5, preset).unwrap();
            assert_eq!(f.nodes.len(), 5);
            for n in &f.nodes {
                assert!(!n.slots.is_empty());
                validate_layout(&n.layout).unwrap();
            }
        }
        assert!(Fleet::new(0, LayoutPreset::Mixed).is_err());
    }

    #[test]
    fn every_class_layout_is_valid_and_led_by_its_class() {
        for class in crate::mig::profile::ALL_PROFILES {
            let layout = class_layout(class);
            validate_layout(&layout).unwrap();
            assert_eq!(layout[0], class, "largest instance leads the layout");
        }
    }

    #[test]
    fn invalid_layout_rejected() {
        // 3x3g overflows the 8 memory slices.
        assert!(validate_layout(&[P3g48gb, P3g48gb, P3g48gb]).is_err());
        assert!(GpuNode::new(0, vec![]).is_err());
    }

    #[test]
    fn job_lifecycle_accounting() {
        let mut f = Fleet::new(1, LayoutPreset::AllSmall).unwrap();
        assert_eq!(f.busy_sms(), 0);
        f.start_job(0, 2, 42, 1.0, 5.0);
        assert_eq!(f.busy_sms(), 16);
        assert!(!f.nodes[0].all_idle());
        assert_eq!(f.finish_job(0, 2, 5.0), Some(42));
        assert_eq!(f.busy_sms(), 0);
        assert!((f.nodes[0].slots[2].busy_accum_s - 4.0).abs() < 1e-12);
        assert_eq!(f.finish_job(0, 2, 5.0), None, "double finish is a no-op");
    }

    #[test]
    fn reconfig_requires_idle_and_validates() {
        let mut f = Fleet::new(1, LayoutPreset::AllSmall).unwrap();
        f.start_job(0, 0, 1, 0.0, 10.0);
        assert!(f.nodes[0]
            .begin_reconfig(vec![P2g24gb, P2g24gb, P2g24gb, P1g12gb], 5.0)
            .is_err());
        f.finish_job(0, 0, 10.0);
        // Invalid target rejected even on an idle node.
        assert!(f.nodes[0].begin_reconfig(vec![P4g48gb, P4g48gb], 12.0).is_err());
        f.nodes[0]
            .begin_reconfig(vec![P2g24gb, P2g24gb, P2g24gb, P1g12gb], 12.0)
            .unwrap();
        assert!(f.nodes[0].reconfiguring());
        assert_eq!(f.nodes[0].effective_layout().len(), 4);
        // Cannot stack a second reconfiguration.
        assert!(f.nodes[0].begin_reconfig(vec![P7g96gb], 13.0).is_err());
        f.nodes[0].finish_reconfig();
        assert!(!f.nodes[0].reconfiguring());
        assert_eq!(f.nodes[0].slots.len(), 4);
        assert_eq!(f.nodes[0].reconfigs, 1);
        assert_eq!(f.nodes[0].slots[0].profile.name, "2g.24gb");
    }

    #[test]
    fn fragmentation_counts_stranded_idle_sms() {
        let mut f = Fleet::new(1, LayoutPreset::Mixed).unwrap(); // 7x1g
        // A 16 GiB job cannot use any idle 1g slot: everything stranded.
        assert!((f.fragmentation(Some(16.0)) - 1.0).abs() < 1e-12);
        // A small job fits everywhere: no fragmentation.
        assert_eq!(f.fragmentation(Some(4.0)), 0.0);
        // Nothing pending: no fragmentation by definition.
        assert_eq!(f.fragmentation(None), 0.0);
        // All busy: nothing idle to strand.
        for i in 0..7 {
            f.start_job(0, i, i as u32, 0.0, 1.0);
        }
        assert_eq!(f.fragmentation(Some(16.0)), 0.0);
    }
}
