//! Online profiling plane: a learned cost model with measured regret.
//!
//! The planner's cost tables are an oracle — every app's slowdown on
//! every profile/occupancy/share is known upfront, which no production
//! fleet has. This module is the MISO-style alternative: an
//! [`EstimatorState`] that starts *cold* (an unknown app carries only its
//! declared footprint), routes each app's first `probe_n` admissions
//! through a probe phase whose completions train the model, and fits
//! per-`[app × profile × occupancy × share × offload]` cost estimates
//! from observed completions. The oracle tables are *retained*: every
//! placement decision under estimation also evaluates the oracle cost of
//! the chosen seat, and the absolute difference — the regret — is a
//! first-class measured quantity (per policy, per app, aggregated into
//! the `ServeReport` and the telemetry histograms).
//!
//! ## Two-tier prediction
//!
//! - **Warm cells** (`count ≥ warmup` observations): the estimate is the
//!   integer running mean `sum_ns / count`. The serve loop feeds the
//!   scheduled level-0 service time of clean completions, which is a pure
//!   function of the cell key — so every observation in a cell is the
//!   same nanosecond value, the mean is exact, and an oracle-seeded
//!   estimator (`seed_oracle`, a debugging anchor) has regret exactly 0.
//! - **Cold cells**: a structural extrapolation built from the paper's
//!   §III-C probe signal — `gpu::sm::measure_sm_count` on the per-
//!   occupant SM share — times the `MigSharedGi` co-run interference and
//!   a C2C share penalty for offloaded seats, scaled by a per-app *unit
//!   work* learned from probe completions (or, before any probe lands,
//!   a declared-footprint prior). The factor table is fixed-point
//!   (`FACTOR_SCALE`) and the unit work accumulates in integers, so
//!   estimates can never depend on shard merge order.
//!
//! ## Determinism across shards and threads
//!
//! Each node shard owns a full estimator and applies its own
//! observations immediately (a 1-node sharded run therefore reproduces
//! the single-loop run bit-for-bit). Cross-shard learning happens only
//! at epoch barriers: each shard drains a sparse [`EstimatorDelta`]
//! (integer counts and sums, keyed by cell index), the coordinator
//! accumulates the shard deltas in shard-id order into a [`DeltaAcc`],
//! and each shard receives "everyone else's" delta (total minus own)
//! with the next epoch's input. All merged quantities are `u64` sums, so
//! every worker-thread count produces the identical estimator — and the
//! identical placements.

use super::placement::Planner;
use crate::gpu::sm;
use crate::mig::profile::{GiProfile, ProfileId, ALL_PROFILES, NUM_PROFILES};
use crate::util::units::{ns_to_sec, sec_to_ns};
use crate::workload::{apps, AppId};
use anyhow::ensure;
use std::collections::BTreeMap;

/// Fixed-point scale of the structural slowdown factors (and the learned
/// unit-work accumulator): 4096 ≈ 3 decimal digits of fraction, leaving
/// ~50 bits of integer headroom for nanosecond runtimes.
pub const FACTOR_SCALE: u64 = 4096;

/// Floor of the C2C link-share dimension. Each estimator instance sizes
/// the dimension as `max(SHARE_CAP, 7 × batch)` — a GH200 board has at
/// most 7 MIG slices and each slot seats at most `batch` residents, so
/// every reachable co-offloader count gets its own cell and clamping
/// (`norm_share`) never actually bites; it exists only as a safety rail.
pub const SHARE_CAP: usize = 8;

/// Most MIG slices one board can carve (7 × 1g on a GH200 96 GB).
const MAX_SLICES: usize = 7;

/// Configuration of the online profiling plane. The default is inert —
/// `enabled: false` runs the oracle planner and reproduces every
/// pre-plane report byte-for-byte.
#[derive(Debug, Clone)]
pub struct EstimatorConfig {
    /// Run all policies on *estimated* cost tables (the oracle tables
    /// are retained as the regret baseline).
    pub enabled: bool,
    /// Each app's first `probe_n` admissions per node shard are probe
    /// jobs: their completions train the structural extrapolation's
    /// per-app unit work (cell means learn from every clean completion).
    pub probe_n: u32,
    /// Observations a cell needs before its running mean replaces the
    /// structural extrapolation.
    pub warmup: u32,
    /// Pre-fill every cell from the oracle cost tables (`warmup`
    /// synthetic observations at the oracle value). A debugging anchor
    /// (`--seed-oracle`) — the regret-is-exactly-zero differential
    /// contract.
    pub seed_oracle: bool,
}

impl Default for EstimatorConfig {
    fn default() -> Self {
        EstimatorConfig {
            enabled: false,
            probe_n: 2,
            warmup: 2,
            seed_oracle: false,
        }
    }
}

impl EstimatorConfig {
    /// Whether the plane is on (gates the estimator block in the report).
    pub fn active(&self) -> bool {
        self.enabled
    }

    pub fn validate(&self) -> crate::Result<()> {
        if !self.enabled {
            return Ok(());
        }
        ensure!(
            self.probe_n >= 1,
            "estimator probe count must be >= 1, got {}",
            self.probe_n
        );
        ensure!(
            self.warmup >= 1,
            "estimator warmup must be >= 1, got {}",
            self.warmup
        );
        Ok(())
    }
}

/// Which cost tables a placement decision ranks candidates on: the
/// oracle tables (the pre-plane planner, bit-for-bit) or a learned
/// estimator. Only the *ranking* consults the estimate — admissibility
/// (declared footprints, offload plans, host pool) and the scheduled
/// service time stay oracle physics, so the world evolves truthfully
/// while the decision is taken on beliefs.
#[derive(Clone, Copy)]
pub enum CostSource<'a> {
    Oracle,
    Estimated(&'a EstimatorState),
}

/// One completion measurement waiting for its job to finish: recorded at
/// placement, applied to the estimator at the `JobDone` event (and
/// dropped if a fault kills the run first).
#[derive(Debug, Clone, Copy)]
pub struct PendingObs {
    pub app: AppId,
    pub pid: ProfileId,
    pub occ: u32,
    pub share: u32,
    pub offloaded: bool,
    /// The scheduled level-0 service time (ns) — the measurement.
    pub ns: u64,
    /// Whether the job was a probe admission (trains the unit work).
    pub probe: bool,
}

/// Per-shard estimator accounting, summed into the `ServeReport`.
#[derive(Debug, Clone, Default)]
pub struct EstimatorStats {
    /// Probe admissions routed through the probe phase.
    pub probes: u64,
    /// Placement decisions taken under estimation (regret samples).
    pub decisions: u64,
    /// Σ |estimated − oracle| service time over all decisions (ns).
    pub regret_sum_ns: u64,
    pub regret_max_ns: u64,
    pub decisions_by_app: [u64; AppId::COUNT],
    pub regret_by_app_ns: [u64; AppId::COUNT],
}

impl EstimatorStats {
    /// Record one placement decision's regret sample.
    pub fn record(&mut self, app: AppId, regret_ns: u64) {
        self.decisions += 1;
        self.regret_sum_ns += regret_ns;
        self.regret_max_ns = self.regret_max_ns.max(regret_ns);
        self.decisions_by_app[app.index()] += 1;
        self.regret_by_app_ns[app.index()] += regret_ns;
    }

    /// Fold another shard's stats in (all sums and a max — order-free).
    pub fn absorb(&mut self, o: &EstimatorStats) {
        self.probes += o.probes;
        self.decisions += o.decisions;
        self.regret_sum_ns += o.regret_sum_ns;
        self.regret_max_ns = self.regret_max_ns.max(o.regret_max_ns);
        for i in 0..AppId::COUNT {
            self.decisions_by_app[i] += o.decisions_by_app[i];
            self.regret_by_app_ns[i] += o.regret_by_app_ns[i];
        }
    }
}

/// A sparse batch of estimator observations drained at an epoch barrier:
/// integer `(index, count, sum)` triples for cell means and per-app unit
/// work. Addition of deltas is commutative and associative, so any merge
/// order produces the same table.
#[derive(Debug, Clone, Default)]
pub struct EstimatorDelta {
    /// `(cell index, observation count, Σ ns)`, ascending by index.
    pub cells: Vec<(u32, u64, u64)>,
    /// `(app index, probe count, Σ unit work fp)`, ascending by index.
    pub work: Vec<(u32, u64, u64)>,
}

/// The coordinator's barrier-time accumulator over shard deltas: builds
/// the fleet total, then hands each shard `total − own` so local state
/// (which already includes `own`) converges to the fleet table.
#[derive(Debug, Clone, Default)]
pub struct DeltaAcc {
    cells: BTreeMap<u32, (u64, u64)>,
    work: BTreeMap<u32, (u64, u64)>,
}

impl DeltaAcc {
    pub fn add(&mut self, d: &EstimatorDelta) {
        for &(k, n, s) in &d.cells {
            let e = self.cells.entry(k).or_insert((0, 0));
            e.0 += n;
            e.1 += s;
        }
        for &(k, n, s) in &d.work {
            let e = self.work.entry(k).or_insert((0, 0));
            e.0 += n;
            e.1 += s;
        }
    }

    /// The total minus one shard's own contribution — what that shard
    /// still needs to apply. `None` when nothing remains.
    pub fn minus(&self, own: Option<&EstimatorDelta>) -> Option<Box<EstimatorDelta>> {
        let mut cells = self.cells.clone();
        let mut work = self.work.clone();
        if let Some(own) = own {
            sub_sparse(&mut cells, &own.cells);
            sub_sparse(&mut work, &own.work);
        }
        if cells.is_empty() && work.is_empty() {
            return None;
        }
        Some(Box::new(EstimatorDelta {
            cells: cells.iter().map(|(&k, &(n, s))| (k, n, s)).collect(),
            work: work.iter().map(|(&k, &(n, s))| (k, n, s)).collect(),
        }))
    }
}

fn sub_sparse(total: &mut BTreeMap<u32, (u64, u64)>, own: &[(u32, u64, u64)]) {
    for &(k, n, s) in own {
        let drained = {
            let e = total
                .get_mut(&k)
                .expect("a shard's own delta is a subset of the barrier total");
            e.0 -= n;
            e.1 -= s;
            e.0 == 0 && e.1 == 0
        };
        if drained {
            total.remove(&k);
        }
    }
}

/// The learned cost model of one node shard. See the module docs for the
/// prediction tiers and the determinism contract.
#[derive(Debug, Clone)]
pub struct EstimatorState {
    probe_n: u64,
    warmup: u64,
    batch: usize,
    /// Width of the link-share dimension: `max(SHARE_CAP, 7 × batch)`,
    /// covering every reachable co-offloader count on one board.
    share_cap: usize,
    /// `(count, Σ ns)` per `[app × profile × occ × share × offload]`.
    cells: Vec<(u64, u64)>,
    /// Structural slowdown per `[profile × occ × share × offload]`,
    /// `FACTOR_SCALE` fixed-point. App-independent by construction.
    factors: Vec<u64>,
    /// Learned per-app unit work: `(probe completions, Σ ns·FS/factor)`.
    work: [(u64, u64); AppId::COUNT],
    /// Declared-footprint cold prior (unit-work ns) — all an unknown app
    /// carries before its first probe completes.
    prior_unit_ns: [u64; AppId::COUNT],
    /// Local admissions per app — the probe-phase counter. Deliberately
    /// per-shard (each node probes its own first `probe_n` admissions).
    admits: [u64; AppId::COUNT],
    /// Journal of local observations since the last `take_delta`.
    d_cells: BTreeMap<u32, (u64, u64)>,
    d_work: BTreeMap<u32, (u64, u64)>,
}

impl EstimatorState {
    /// Build a cold estimator sized for `planner`'s batch, deriving the
    /// structural factor table from the §III-C SM-count probe and the
    /// planner's `MigSharedGi` interference constant. Identical inputs
    /// produce identical tables, so every shard constructs the same
    /// estimator.
    pub fn new(planner: &Planner, cfg: &EstimatorConfig) -> EstimatorState {
        let batch = planner.batch() as usize;
        let share_cap = SHARE_CAP.max(MAX_SLICES * batch);
        let interference = planner.shared_interference();
        let full = sm::measure_sm_count(GiProfile::get(ProfileId::P7g96gb).sms).max(1) as f64;
        let mut factors = vec![0u64; NUM_PROFILES * batch * share_cap * 2];
        for pid in ALL_PROFILES {
            let prof = GiProfile::get(pid);
            for occ in 1..=batch as u32 {
                let meas = sm::measure_sm_count((prof.sms / occ).max(1)).max(1) as f64;
                let slow = full / meas * (1.0 + interference * (occ as f64 - 1.0));
                for share in 1..=share_cap as u32 {
                    for off in [false, true] {
                        // Offloaded work pays the C2C round trip, divided
                        // across the link's time shares.
                        let x = if off { slow * 2.0 * share as f64 } else { slow };
                        factors[Self::fidx_raw(batch, share_cap, pid, occ, share, off)] =
                            ((x * FACTOR_SCALE as f64).round() as u64).max(1);
                    }
                }
            }
        }
        let mut prior_unit_ns = [0u64; AppId::COUNT];
        for app in apps::all() {
            // The declared footprint is all a cold estimator knows about
            // an app: assume unit work grows with the model size.
            prior_unit_ns[app.index()] =
                sec_to_ns(planner.scale() * (1.0 + planner.footprint_gib(app)));
        }
        EstimatorState {
            probe_n: cfg.probe_n as u64,
            warmup: cfg.warmup.max(1) as u64,
            batch,
            share_cap,
            cells: vec![(0, 0); AppId::COUNT * NUM_PROFILES * batch * share_cap * 2],
            factors,
            work: [(0, 0); AppId::COUNT],
            prior_unit_ns,
            admits: [0; AppId::COUNT],
            d_cells: BTreeMap::new(),
            d_work: BTreeMap::new(),
        }
    }

    /// Normalized link share: only offloaded placements depend on the
    /// share (mirrors `Planner::cost_at_shared`), so non-offloaded cells
    /// collapse to share 1 — the indexed walk and the naive scan may
    /// legitimately pass different shares for such candidates. The
    /// clamp to `share_cap` is a safety rail that no reachable
    /// placement actually hits (the dimension is sized for the board).
    fn norm_share(cap: usize, share: u32, offloaded: bool) -> usize {
        if offloaded {
            (share.max(1) as usize).min(cap)
        } else {
            1
        }
    }

    fn fidx_raw(
        batch: usize,
        share_cap: usize,
        pid: ProfileId,
        occ: u32,
        share: u32,
        off: bool,
    ) -> usize {
        ((pid.index() * batch + (occ as usize - 1)) * share_cap + (share as usize - 1)) * 2
            + off as usize
    }

    fn fidx(&self, pid: ProfileId, occ: u32, share: u32, offloaded: bool) -> usize {
        let share = Self::norm_share(self.share_cap, share, offloaded) as u32;
        Self::fidx_raw(self.batch, self.share_cap, pid, occ, share, offloaded)
    }

    fn cell(&self, app: AppId, pid: ProfileId, occ: u32, share: u32, offloaded: bool) -> usize {
        let share = Self::norm_share(self.share_cap, share, offloaded);
        (((app.index() * NUM_PROFILES + pid.index()) * self.batch + (occ as usize - 1))
            * self.share_cap
            + (share - 1))
            * 2
            + offloaded as usize
    }

    /// Register one admission of `app`; returns whether it falls in the
    /// probe phase (the app's first `probe_n` admissions on this shard).
    pub fn note_admit(&mut self, app: AppId) -> bool {
        let i = app.index();
        let seen = self.admits[i];
        self.admits[i] += 1;
        seen < self.probe_n
    }

    /// Feed one completed run's measurement into the model: the cell's
    /// running mean always learns; a probe completion additionally
    /// trains the per-app unit work behind the structural extrapolation.
    /// Journaled for the next barrier delta.
    pub fn observe(&mut self, o: &PendingObs) {
        let ci = self.cell(o.app, o.pid, o.occ, o.share, o.offloaded);
        self.cells[ci].0 += 1;
        self.cells[ci].1 += o.ns;
        let e = self.d_cells.entry(ci as u32).or_insert((0, 0));
        e.0 += 1;
        e.1 += o.ns;
        if o.probe {
            let f = self.factors[self.fidx(o.pid, o.occ, o.share, o.offloaded)];
            let w = o.ns.saturating_mul(FACTOR_SCALE) / f;
            let ai = o.app.index() as u32;
            self.work[o.app.index()].0 += 1;
            self.work[o.app.index()].1 += w;
            let e = self.d_work.entry(ai).or_insert((0, 0));
            e.0 += 1;
            e.1 += w;
        }
    }

    /// The estimated service time (ns) of one placement class. Pure —
    /// safe to consult from an immutable borrow on the ranking hot path.
    pub fn predict_ns(
        &self,
        app: AppId,
        pid: ProfileId,
        occ: u32,
        share: u32,
        offloaded: bool,
    ) -> u64 {
        let (n, sum) = self.cells[self.cell(app, pid, occ, share, offloaded)];
        if n >= self.warmup {
            return sum / n;
        }
        let f = self.factors[self.fidx(pid, occ, share, offloaded)];
        let (wn, wsum) = self.work[app.index()];
        let unit = if wn > 0 {
            wsum / wn
        } else {
            self.prior_unit_ns[app.index()]
        };
        unit.saturating_mul(f) / FACTOR_SCALE
    }

    /// `predict_ns` in seconds — what the estimated reward ranks on.
    pub fn predict_s(&self, app: AppId, pid: ProfileId, occ: u32, share: u32, off: bool) -> f64 {
        ns_to_sec(self.predict_ns(app, pid, occ, share, off))
    }

    /// Whether the cell behind this class is warm (mean-backed).
    pub fn is_warm(&self, app: AppId, pid: ProfileId, occ: u32, share: u32, off: bool) -> bool {
        self.cells[self.cell(app, pid, occ, share, off)].0 >= self.warmup
    }

    /// Pre-fill every admissible cell with `warmup` synthetic
    /// observations at the oracle value — the regret==0 differential
    /// anchor (`EstimatorConfig::seed_oracle`). Assignment, not
    /// accumulation, so the non-offloaded cells the two `allow_offload`
    /// passes share are written with identical values twice. Seeded
    /// state is never journaled: every shard seeds itself identically.
    pub fn seed_from_oracle(&mut self, planner: &mut Planner) {
        for app in apps::all() {
            for pid in ALL_PROFILES {
                for occ in 1..=self.batch as u32 {
                    for allow in [false, true] {
                        let Some(c) = planner.cost_at_shared(app, pid, allow, occ, 1) else {
                            continue;
                        };
                        let ci = self.cell(app, pid, occ, 1, c.offloaded);
                        self.cells[ci] = (self.warmup, self.warmup * sec_to_ns(c.runtime_s));
                        if !c.offloaded {
                            continue;
                        }
                        for share in 2..=self.share_cap as u32 {
                            if let Some(cs) =
                                planner.cost_at_shared(app, pid, true, occ, share)
                            {
                                let ci = self.cell(app, pid, occ, share, true);
                                self.cells[ci] =
                                    (self.warmup, self.warmup * sec_to_ns(cs.runtime_s));
                            }
                        }
                    }
                }
            }
        }
    }

    /// Drain the journal of observations since the last drain, for the
    /// epoch-barrier exchange. `None` when nothing was observed.
    pub fn take_delta(&mut self) -> Option<Box<EstimatorDelta>> {
        if self.d_cells.is_empty() && self.d_work.is_empty() {
            return None;
        }
        let d = EstimatorDelta {
            cells: self.d_cells.iter().map(|(&k, &(n, s))| (k, n, s)).collect(),
            work: self.d_work.iter().map(|(&k, &(n, s))| (k, n, s)).collect(),
        };
        self.d_cells.clear();
        self.d_work.clear();
        Some(Box::new(d))
    }

    /// Apply another shard's (merged) observations. Not journaled — the
    /// coordinator already routed these to every other shard.
    pub fn apply_delta(&mut self, d: &EstimatorDelta) {
        for &(k, n, s) in &d.cells {
            let c = &mut self.cells[k as usize];
            c.0 += n;
            c.1 += s;
        }
        for &(k, n, w) in &d.work {
            let e = &mut self.work[k as usize];
            e.0 += n;
            e.1 += w;
        }
    }
}

/// The estimator plane's full per-shard runtime state, boxed onto the
/// shard only when `--estimator on`: the learned tables, the
/// completion measurements in flight (keyed by queue id), and the
/// regret accounting. Off-path code never allocates one, so the
/// default run stays byte-identical to the pre-plane serve loop.
pub struct EstPlane {
    pub state: EstimatorState,
    /// Placement-time measurements waiting for `JobDone`, keyed by
    /// queue id. A fault that kills the run drops the entry — only
    /// clean completions train the tables.
    pub pending: std::collections::BTreeMap<u32, PendingObs>,
    pub stats: EstimatorStats,
}

impl EstPlane {
    /// Build the plane for one shard: a cold estimator, or an
    /// oracle-seeded one when the config anchors it (`seed_oracle`).
    pub fn new(planner: &mut Planner, cfg: &EstimatorConfig) -> EstPlane {
        let mut state = EstimatorState::new(planner, cfg);
        if cfg.seed_oracle {
            state.seed_from_oracle(planner);
        }
        EstPlane {
            state,
            pending: std::collections::BTreeMap::new(),
            stats: EstimatorStats::default(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn state(batch: u32) -> (Planner, EstimatorState) {
        let pl = Planner::with_batch(0.05, batch);
        let est = EstimatorState::new(&pl, &EstimatorConfig::default());
        (pl, est)
    }

    #[test]
    fn cold_predictions_are_structural_and_monotone() {
        let (_, est) = state(2);
        // Bigger slices predict faster, co-residency predicts slower,
        // offloading predicts slower still — before a single observation.
        let app = AppId::Llama3Fp16;
        let small = est.predict_ns(app, ProfileId::P1g12gb, 1, 1, false);
        let big = est.predict_ns(app, ProfileId::P7g96gb, 1, 1, false);
        assert!(big < small, "7g must predict faster than 1g ({big} vs {small})");
        let solo = est.predict_ns(app, ProfileId::P3g48gb, 1, 1, false);
        let packed = est.predict_ns(app, ProfileId::P3g48gb, 2, 1, false);
        assert!(packed > solo, "co-residency must predict slower");
        let direct = est.predict_ns(app, ProfileId::P1g12gb, 1, 1, false);
        let off1 = est.predict_ns(app, ProfileId::P1g12gb, 1, 1, true);
        let off3 = est.predict_ns(app, ProfileId::P1g12gb, 1, 3, true);
        assert!(off1 > direct && off3 > off1, "offload and link shares cost");
        // A heavier declared footprint predicts more unit work.
        let light = est.predict_ns(AppId::Hotspot, ProfileId::P1g12gb, 1, 1, false);
        let heavy = est.predict_ns(AppId::Llama3Fp16, ProfileId::P1g12gb, 1, 1, false);
        assert!(heavy > light);
    }

    #[test]
    fn share_is_normalized_for_non_offloaded_cells() {
        // The naive scan passes the GPU's link share even for candidates
        // whose cost is not offloaded; the indexed walk passes 1. The
        // estimator must collapse both to the same cell or the two serve
        // modes would diverge.
        let (_, mut est) = state(1);
        let app = AppId::Faiss;
        let a = est.predict_ns(app, ProfileId::P1g12gb, 1, 1, false);
        let b = est.predict_ns(app, ProfileId::P1g12gb, 1, 5, false);
        assert_eq!(a, b);
        est.observe(&PendingObs {
            app,
            pid: ProfileId::P1g12gb,
            occ: 1,
            share: 3, // scan-side share for a non-offloaded candidate
            offloaded: false,
            ns: 1_000,
            probe: false,
        });
        let (n, _) = est.cells[est.cell(app, ProfileId::P1g12gb, 1, 1, false)];
        assert_eq!(n, 1, "the observation must land in the share-1 cell");
    }

    #[test]
    fn warm_cell_mean_is_exact_and_overrides_the_prior() {
        let (_, mut est) = state(1);
        let app = AppId::Faiss;
        let pid = ProfileId::P2g24gb;
        let obs = PendingObs {
            app,
            pid,
            occ: 1,
            share: 1,
            offloaded: false,
            ns: 123_456_789,
            probe: true,
        };
        est.observe(&obs);
        assert!(!est.is_warm(app, pid, 1, 1, false), "warmup is 2");
        est.observe(&obs);
        assert!(est.is_warm(app, pid, 1, 1, false));
        assert_eq!(est.predict_ns(app, pid, 1, 1, false), 123_456_789);
    }

    #[test]
    fn probe_completions_train_the_unit_work_extrapolation() {
        let (_, mut est) = state(1);
        let app = AppId::Faiss;
        let cold = est.predict_ns(app, ProfileId::P7g96gb, 1, 1, false);
        // One probe completion on 1g re-anchors the 7g prediction too —
        // the structural factor carries the measurement across profiles.
        est.observe(&PendingObs {
            app,
            pid: ProfileId::P1g12gb,
            occ: 1,
            share: 1,
            offloaded: false,
            ns: 40 * cold, // the app is much slower than the prior thought
            probe: true,
        });
        let after = est.predict_ns(app, ProfileId::P7g96gb, 1, 1, false);
        assert!(after > cold, "a slow probe must raise the whole surface");
    }

    #[test]
    fn probe_phase_counts_the_first_admissions() {
        let pl = Planner::new(0.05);
        let cfg = EstimatorConfig {
            enabled: true,
            probe_n: 2,
            ..EstimatorConfig::default()
        };
        let mut est = EstimatorState::new(&pl, &cfg);
        assert!(est.note_admit(AppId::Faiss));
        assert!(est.note_admit(AppId::Faiss));
        assert!(!est.note_admit(AppId::Faiss), "probe phase is over");
        assert!(est.note_admit(AppId::Hotspot), "per-app counters");
    }

    #[test]
    fn oracle_seeding_predicts_the_oracle_exactly() {
        let (mut pl, mut est) = state(2);
        est.seed_from_oracle(&mut pl);
        for app in apps::all() {
            for pid in ALL_PROFILES {
                for occ in 1..=2u32 {
                    for allow in [false, true] {
                        let Some(c) = pl.cost_at_shared(app, pid, allow, occ, 1) else {
                            continue;
                        };
                        assert_eq!(
                            est.predict_ns(app, pid, occ, 1, c.offloaded),
                            sec_to_ns(c.runtime_s),
                            "{app:?} {pid:?} occ {occ}"
                        );
                        if c.offloaded {
                            // Covers shares past the SHARE_CAP floor:
                            // at batch 2 the instance cap is 14.
                            for share in 2..=est.share_cap as u32 {
                                let cs = pl.cost_at_shared(app, pid, true, occ, share).unwrap();
                                assert_eq!(
                                    est.predict_ns(app, pid, occ, share, true),
                                    sec_to_ns(cs.runtime_s)
                                );
                            }
                        }
                    }
                }
            }
        }
        // And the mean stays exact as matching observations stream in.
        let c = pl
            .cost_at_shared(AppId::Faiss, ProfileId::P1g12gb, false, 1, 1)
            .unwrap();
        est.observe(&PendingObs {
            app: AppId::Faiss,
            pid: ProfileId::P1g12gb,
            occ: 1,
            share: 1,
            offloaded: false,
            ns: sec_to_ns(c.runtime_s),
            probe: false,
        });
        assert_eq!(
            est.predict_ns(AppId::Faiss, ProfileId::P1g12gb, 1, 1, false),
            sec_to_ns(c.runtime_s)
        );
    }

    #[test]
    fn delta_exchange_is_order_free_and_total_minus_own() {
        let (_, mut a) = state(1);
        let (_, mut b) = state(1);
        let (_, mut c) = state(1);
        let mk = |app, ns| PendingObs {
            app,
            pid: ProfileId::P1g12gb,
            occ: 1,
            share: 1,
            offloaded: false,
            ns,
            probe: true,
        };
        a.observe(&mk(AppId::Faiss, 100));
        b.observe(&mk(AppId::Faiss, 300));
        b.observe(&mk(AppId::Hotspot, 50));
        // c observes nothing this epoch.
        let da = a.take_delta();
        let db = b.take_delta();
        let dc = c.take_delta();
        assert!(dc.is_none());
        let mut acc = DeltaAcc::default();
        for d in [&da, &db, &dc].into_iter().flatten() {
            acc.add(d);
        }
        a.apply_delta(&acc.minus(da.as_deref()).unwrap());
        b.apply_delta(&acc.minus(db.as_deref()).unwrap());
        c.apply_delta(&acc.minus(dc.as_deref()).unwrap());
        // All three shards converge to the identical table.
        for (x, y) in [(&a, &b), (&a, &c)] {
            assert_eq!(x.cells, y.cells);
            assert_eq!(x.work, y.work);
        }
        let (n, sum) = a.cells[a.cell(AppId::Faiss, ProfileId::P1g12gb, 1, 1, false)];
        assert_eq!((n, sum), (2, 400));
        // The journals drained — a second take is empty.
        assert!(a.take_delta().is_none());
    }

    #[test]
    fn config_validation() {
        assert!(EstimatorConfig::default().validate().is_ok());
        let on = EstimatorConfig {
            enabled: true,
            ..EstimatorConfig::default()
        };
        assert!(on.validate().is_ok());
        assert!(EstimatorConfig {
            probe_n: 0,
            ..on.clone()
        }
        .validate()
        .is_err());
        assert!(EstimatorConfig {
            warmup: 0,
            ..on
        }
        .validate()
        .is_err());
    }
}
