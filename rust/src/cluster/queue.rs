//! The admission queue: arriving jobs wait FIFO with a queueing deadline.
//!
//! A job is *admitted* when a placement policy assigns it a slot; it is
//! *expired* if its deadline passes while it still waits (the client gave
//! up), *rejected* immediately when no layout this fleet could ever
//! reconfigure to — offloading included — can host it, and *forwarded*
//! when the sharded control plane hands it off to another node's queue
//! (terminal here; the destination queue owns it from then on and admits
//! it via `admit_handoff`, preserving the original arrival time and
//! absolute deadline).
//!
//! The queue keeps live counters alongside the raw job list so the
//! serving hot path never rescans it: pending ids live in a `BTreeSet`
//! (admission order == id order, so ascending iteration is FIFO with
//! O(log n) removal), resolution is a counter (`all_resolved` is O(1)),
//! and pending jobs are bucketed per app so the smallest pending
//! footprint — the fragmentation reference — is an O(apps) lookup over
//! footprints precomputed at construction. The `*_scan` variants
//! recompute the same quantities from the raw list and serve as the
//! differential-test oracle.
//!
//! State transitions are *typed errors*, not panics: the queue is fed by
//! the CLI's trace-replay path, so a malformed trace must surface as an
//! `Err` the caller can print, never a panic (a DoS on the CLI).

use crate::workload::apps;
use crate::workload::trace::Job;
use crate::workload::AppId;
use anyhow::{bail, ensure};
use std::collections::BTreeSet;

/// Lifecycle state of a job in the serving system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    Pending,
    Running,
    Completed,
    Expired,
    Rejected,
    /// Handed off to another node shard's queue (terminal in this queue;
    /// the destination accounts the job's real outcome).
    Forwarded,
    /// A fault killed this running instance and the job re-entered the
    /// queue as a fresh retry admission (terminal at *this* id; the retry
    /// id accounts the job's real outcome — the fault-plane analogue of
    /// `Forwarded`).
    Retrying,
    /// A fault killed the job after its retry budget was exhausted
    /// (terminal, with an outcome: the job is lost).
    Failed,
    /// Dropped by brown-out backpressure while still pending: surviving
    /// capacity fell below the shed watermark and admission chose to
    /// fail this job fast instead of letting it rot to deadline expiry
    /// (terminal, with an outcome: the job is refused under degradation).
    Shed,
}

/// A job plus its serving metadata.
#[derive(Debug, Clone)]
pub struct QueuedJob {
    pub job: Job,
    /// Absolute time at which the job abandons the queue.
    pub deadline_s: f64,
    pub state: JobState,
    pub placed_s: Option<f64>,
    pub finished_s: Option<f64>,
    pub offloaded: bool,
    pub gpu: Option<usize>,
    /// Arrived here via a cross-node handoff (never forwarded again).
    pub handoff: bool,
    /// Admitted during the estimator's probe phase (one of its app's
    /// first `--probe-n` admissions on this shard): its completion trains
    /// the learned cost model's per-app unit work. Always `false` with
    /// the profiling plane off.
    pub probe: bool,
}

/// FIFO admission queue with deadline accounting.
#[derive(Debug)]
pub struct AdmissionQueue {
    /// All jobs ever admitted, indexed by job id (ids are dense 0..n).
    pub jobs: Vec<QueuedJob>,
    pending: BTreeSet<u32>,
    /// Pending job count per app (dense, `AppId::index`).
    pending_by_app: [u32; AppId::COUNT],
    /// Direct memory footprint per app (GiB), precomputed once.
    footprints: [f64; AppId::COUNT],
    /// Jobs in a terminal state (completed/expired/rejected).
    resolved: u32,
}

impl Default for AdmissionQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl AdmissionQueue {
    pub fn new() -> AdmissionQueue {
        let mut footprints = [0.0f64; AppId::COUNT];
        for app in apps::all() {
            footprints[app.index()] = apps::model(app).footprint_gib;
        }
        AdmissionQueue {
            jobs: Vec::new(),
            pending: BTreeSet::new(),
            pending_by_app: [0; AppId::COUNT],
            footprints,
            resolved: 0,
        }
    }

    /// Register an arriving job with a relative queueing deadline. Job ids
    /// must arrive in order (they index `jobs`).
    pub fn admit(&mut self, job: Job, deadline_rel_s: f64) -> crate::Result<()> {
        let deadline_s = job.arrival_s + deadline_rel_s;
        self.admit_at(job, deadline_s, false)
    }

    /// Register a job handed off from another node shard: its deadline is
    /// the absolute instant fixed at the original admission (the clock
    /// does not restart on migration), and it is marked so it never
    /// forwards again.
    pub fn admit_handoff(&mut self, job: Job, deadline_abs_s: f64) -> crate::Result<()> {
        self.admit_at(job, deadline_abs_s, true)
    }

    /// Register a fault-plane retry of a killed running job: the deadline
    /// is the absolute instant fixed at the original admission, and the
    /// prior handoff mark is carried so a once-handed-off job still never
    /// forwards again.
    pub fn admit_retry(
        &mut self,
        job: Job,
        deadline_abs_s: f64,
        handoff: bool,
    ) -> crate::Result<()> {
        self.admit_at(job, deadline_abs_s, handoff)
    }

    fn admit_at(&mut self, job: Job, deadline_s: f64, handoff: bool) -> crate::Result<()> {
        ensure!(
            job.id as usize == self.jobs.len(),
            "job ids must be dense: admitting id {} into a queue of {}",
            job.id,
            self.jobs.len()
        );
        self.pending_by_app[job.app.index()] += 1;
        self.jobs.push(QueuedJob {
            job,
            deadline_s,
            state: JobState::Pending,
            placed_s: None,
            finished_s: None,
            offloaded: false,
            gpu: None,
            handoff,
            probe: false,
        });
        self.pending.insert(self.jobs.len() as u32 - 1);
        Ok(())
    }

    /// A transition demanded on a job in the wrong state: a typed error,
    /// with enough context to point at the offending trace record.
    fn bad_transition(&self, id: u32, wanted: &str, op: &str) -> anyhow::Error {
        let state = self.jobs.get(id as usize).map(|j| j.state);
        anyhow::anyhow!("{op} requires a {wanted} job, but job {id} is {state:?}")
    }

    /// Pending job ids, oldest first (ids are dense and admitted in
    /// arrival order, so ascending id order *is* FIFO order).
    pub fn pending_ids(&self) -> impl Iterator<Item = u32> + '_ {
        self.pending.iter().copied()
    }

    pub fn pending_len(&self) -> usize {
        self.pending.len()
    }

    /// Live pending-count-per-app buckets (dense `AppId::index`) — read
    /// by the telemetry sampler to attribute queue depth to workloads.
    pub fn pending_by_app(&self) -> &[u32; AppId::COUNT] {
        &self.pending_by_app
    }

    fn unqueue(&mut self, id: u32) {
        let app = self.jobs[id as usize].job.app;
        if self.pending.remove(&id) {
            self.pending_by_app[app.index()] -= 1;
        }
    }

    /// Transition a pending job to running on `gpu`.
    pub fn mark_running(
        &mut self,
        id: u32,
        now: f64,
        gpu: usize,
        offloaded: bool,
    ) -> crate::Result<()> {
        if self.jobs.get(id as usize).map(|j| j.state) != Some(JobState::Pending) {
            bail!(self.bad_transition(id, "pending", "place"));
        }
        let j = &mut self.jobs[id as usize];
        j.state = JobState::Running;
        j.placed_s = Some(now);
        j.gpu = Some(gpu);
        j.offloaded = offloaded;
        self.unqueue(id);
        Ok(())
    }

    pub fn mark_completed(&mut self, id: u32, now: f64) -> crate::Result<()> {
        if self.jobs.get(id as usize).map(|j| j.state) != Some(JobState::Running) {
            bail!(self.bad_transition(id, "running", "complete"));
        }
        let j = &mut self.jobs[id as usize];
        j.state = JobState::Completed;
        j.finished_s = Some(now);
        self.resolved += 1;
        Ok(())
    }

    /// A fault killed this running instance and the job retries under a
    /// fresh id: terminal here, no outcome, `finished_s` stays `None` so
    /// the kill instant never extends this shard's horizon (exactly the
    /// `Forwarded` accounting).
    pub fn mark_retrying(&mut self, id: u32) -> crate::Result<()> {
        if self.jobs.get(id as usize).map(|j| j.state) != Some(JobState::Running) {
            bail!(self.bad_transition(id, "running", "retry"));
        }
        self.jobs[id as usize].state = JobState::Retrying;
        self.resolved += 1;
        Ok(())
    }

    /// A fault killed this running instance with the retry budget spent:
    /// terminal, with an outcome — the job is lost at `now`.
    pub fn mark_failed(&mut self, id: u32, now: f64) -> crate::Result<()> {
        if self.jobs.get(id as usize).map(|j| j.state) != Some(JobState::Running) {
            bail!(self.bad_transition(id, "running", "fail"));
        }
        let j = &mut self.jobs[id as usize];
        j.state = JobState::Failed;
        j.finished_s = Some(now);
        self.resolved += 1;
        Ok(())
    }

    /// Expire a job if it is still pending; returns whether it expired.
    pub fn expire_if_pending(&mut self, id: u32, now: f64) -> bool {
        if self.jobs[id as usize].state != JobState::Pending {
            return false;
        }
        let j = &mut self.jobs[id as usize];
        j.state = JobState::Expired;
        j.finished_s = Some(now);
        self.resolved += 1;
        self.unqueue(id);
        true
    }

    /// Shed a pending job under brown-out backpressure: terminal, with
    /// an outcome — the job is refused at `now` because surviving
    /// capacity no longer justifies keeping it queued. Mirrors the
    /// expiry transition (the job resolves and leaves the pending set)
    /// but is accounted separately so degradation is measurable.
    pub fn mark_shed(&mut self, id: u32, now: f64) -> crate::Result<()> {
        if self.jobs.get(id as usize).map(|j| j.state) != Some(JobState::Pending) {
            bail!(self.bad_transition(id, "pending", "shed"));
        }
        let j = &mut self.jobs[id as usize];
        j.state = JobState::Shed;
        j.finished_s = Some(now);
        self.resolved += 1;
        self.unqueue(id);
        Ok(())
    }

    /// Reject a just-admitted job outright (unservable footprint).
    pub fn reject(&mut self, id: u32, now: f64) -> crate::Result<()> {
        if self.jobs.get(id as usize).map(|j| j.state) != Some(JobState::Pending) {
            bail!(self.bad_transition(id, "pending", "reject"));
        }
        let j = &mut self.jobs[id as usize];
        j.state = JobState::Rejected;
        j.finished_s = Some(now);
        self.resolved += 1;
        self.unqueue(id);
        Ok(())
    }

    /// Hand a pending job off to another node shard: terminal here (it no
    /// longer pends, counts as resolved for this queue's loop-termination
    /// accounting) but contributes to no outcome metric — the destination
    /// queue records the job's completion or expiry. `finished_s` stays
    /// `None` so the handoff instant never extends this shard's horizon.
    pub fn mark_forwarded(&mut self, id: u32) -> crate::Result<()> {
        if self.jobs.get(id as usize).map(|j| j.state) != Some(JobState::Pending) {
            bail!(self.bad_transition(id, "pending", "forward"));
        }
        ensure!(
            !self.jobs[id as usize].handoff,
            "a handed-off job never forwards again (job {id})"
        );
        let j = &mut self.jobs[id as usize];
        j.state = JobState::Forwarded;
        self.resolved += 1;
        self.unqueue(id);
        Ok(())
    }

    pub fn count(&self, state: JobState) -> u32 {
        self.jobs.iter().filter(|j| j.state == state).count() as u32
    }

    /// Whether every admitted job reached a terminal state (O(1)).
    pub fn all_resolved(&self) -> bool {
        self.resolved as usize == self.jobs.len()
    }

    /// Admitted jobs not yet in a terminal state (O(1)).
    pub fn unresolved(&self) -> u32 {
        self.jobs.len() as u32 - self.resolved
    }

    /// `all_resolved` recomputed from the raw states — the
    /// differential-test oracle.
    pub fn all_resolved_scan(&self) -> bool {
        self.jobs.iter().all(|j| {
            matches!(
                j.state,
                JobState::Completed
                    | JobState::Expired
                    | JobState::Rejected
                    | JobState::Forwarded
                    | JobState::Retrying
                    | JobState::Failed
                    | JobState::Shed
            )
        })
    }

    /// Smallest direct memory footprint among pending jobs (GiB) — the
    /// fleet fragmentation reference. O(apps) over the pending buckets;
    /// the min of a multiset is order-independent, so this is bit-equal
    /// to the full scan.
    pub fn smallest_pending_footprint_gib(&self) -> Option<f64> {
        let mut best: Option<f64> = None;
        for (i, &n) in self.pending_by_app.iter().enumerate() {
            if n == 0 {
                continue;
            }
            let f = self.footprints[i];
            best = Some(match best {
                Some(b) => b.min(f),
                None => f,
            });
        }
        best
    }

    /// `smallest_pending_footprint_gib` recomputed by scanning every
    /// pending job — the differential-test oracle.
    pub fn smallest_pending_footprint_scan(&self) -> Option<f64> {
        self.pending
            .iter()
            .map(|&id| apps::model(self.jobs[id as usize].job.app).footprint_gib)
            .reduce(f64::min)
    }

    /// Queueing waits of completed jobs (seconds).
    pub fn completed_waits(&self) -> Vec<f64> {
        self.jobs
            .iter()
            .filter(|j| j.state == JobState::Completed)
            .map(|j| j.placed_s.unwrap() - j.job.arrival_s)
            .collect()
    }

    /// Latest resolution instant (completion/expiry/rejection) — the
    /// serving horizon for throughput accounting.
    pub fn horizon_s(&self) -> f64 {
        self.jobs
            .iter()
            .filter_map(|j| j.finished_s)
            .fold(0.0f64, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::AppId;

    fn job(id: u32, arrival: f64, app: AppId) -> Job {
        Job {
            id,
            app,
            arrival_s: arrival,
        }
    }

    #[test]
    fn fifo_order_and_transitions() {
        let mut q = AdmissionQueue::new();
        q.admit(job(0, 0.0, AppId::Faiss), 10.0).unwrap();
        q.admit(job(1, 1.0, AppId::Hotspot), 10.0).unwrap();
        q.admit(job(2, 2.0, AppId::Lammps), 10.0).unwrap();
        assert_eq!(q.pending_ids().collect::<Vec<_>>(), vec![0, 1, 2]);
        q.mark_running(1, 1.5, 0, false).unwrap();
        assert_eq!(q.pending_ids().collect::<Vec<_>>(), vec![0, 2]);
        q.mark_completed(1, 4.0).unwrap();
        assert_eq!(q.count(JobState::Completed), 1);
        assert!(!q.all_resolved());
        q.mark_running(0, 2.0, 1, true).unwrap();
        q.mark_completed(0, 9.0).unwrap();
        assert!(q.expire_if_pending(2, 12.0));
        assert!(q.all_resolved());
        assert_eq!(q.horizon_s(), 12.0);
        // Wait of job 0 is placed - arrival = 2.0.
        let waits = q.completed_waits();
        assert_eq!(waits.len(), 2);
        assert!(waits.iter().any(|w| (*w - 2.0).abs() < 1e-12));
    }

    #[test]
    fn expiry_only_hits_pending() {
        let mut q = AdmissionQueue::new();
        q.admit(job(0, 0.0, AppId::Faiss), 5.0).unwrap();
        q.mark_running(0, 1.0, 0, false).unwrap();
        assert!(!q.expire_if_pending(0, 5.0), "running jobs never expire");
        assert_eq!(q.jobs[0].deadline_s, 5.0);
    }

    #[test]
    fn smallest_pending_footprint() {
        let mut q = AdmissionQueue::new();
        assert_eq!(q.smallest_pending_footprint_gib(), None);
        q.admit(job(0, 0.0, AppId::Llama3Fp16), 5.0).unwrap(); // 16.5 GiB
        q.admit(job(1, 0.0, AppId::Hotspot), 5.0).unwrap(); // 0.05 GiB
        let f = q.smallest_pending_footprint_gib().unwrap();
        assert!((f - 0.05).abs() < 1e-12);
        q.mark_running(1, 0.0, 0, false).unwrap();
        let f = q.smallest_pending_footprint_gib().unwrap();
        assert!((f - 16.5).abs() < 1e-9);
    }

    #[test]
    fn reject_resolves_job() {
        let mut q = AdmissionQueue::new();
        q.admit(job(0, 3.0, AppId::Faiss), 5.0).unwrap();
        q.reject(0, 3.0).unwrap();
        assert_eq!(q.count(JobState::Rejected), 1);
        assert_eq!(q.pending_len(), 0);
        assert!(q.all_resolved());
    }

    #[test]
    fn handoff_lifecycle_and_forward_accounting() {
        let mut q = AdmissionQueue::new();
        q.admit(job(0, 1.0, AppId::Llama3Fp16), 10.0).unwrap(); // abandons at 11.0
        assert_eq!(q.unresolved(), 1);
        q.mark_forwarded(0).unwrap();
        assert_eq!(q.pending_len(), 0);
        assert!(q.all_resolved());
        assert!(q.all_resolved_scan());
        assert_eq!(q.unresolved(), 0);
        assert_eq!(q.count(JobState::Forwarded), 1);
        assert_eq!(q.count(JobState::Expired), 0);
        assert_eq!(q.horizon_s(), 0.0, "forwarding never extends the horizon");

        // Destination queue: absolute deadline preserved, wait accounting
        // spans the handoff (original arrival, not re-arrival).
        let mut dst = AdmissionQueue::new();
        dst.admit_handoff(job(0, 1.0, AppId::Llama3Fp16), 11.0).unwrap();
        assert!(dst.jobs[0].handoff);
        assert_eq!(dst.jobs[0].deadline_s, 11.0);
        dst.mark_running(0, 5.0, 0, false).unwrap();
        dst.mark_completed(0, 9.0).unwrap();
        let waits = dst.completed_waits();
        assert_eq!(waits.len(), 1);
        assert!((waits[0] - 4.0).abs() < 1e-12, "wait = placed - arrival");
    }

    #[test]
    fn retry_lifecycle_mirrors_forwarding() {
        // A faulted running job resolves as `Retrying` (no outcome, no
        // horizon) and the retry id owns the real outcome — admitted with
        // the original arrival and absolute deadline.
        let mut q = AdmissionQueue::new();
        q.admit(job(0, 1.0, AppId::Faiss), 10.0).unwrap();
        q.mark_running(0, 2.0, 0, false).unwrap();
        q.mark_retrying(0).unwrap();
        assert!(q.all_resolved() && q.all_resolved_scan());
        assert_eq!(q.count(JobState::Retrying), 1);
        assert_eq!(q.horizon_s(), 0.0, "a retry never extends the horizon");
        q.admit_retry(job(1, 1.0, AppId::Faiss), 11.0, false).unwrap();
        assert!(!q.all_resolved());
        q.mark_running(1, 6.0, 1, false).unwrap();
        q.mark_completed(1, 9.0).unwrap();
        assert!(q.all_resolved());
        let waits = q.completed_waits();
        assert_eq!(waits.len(), 1);
        assert!((waits[0] - 5.0).abs() < 1e-12, "wait spans the retry");
    }

    #[test]
    fn failed_is_a_terminal_outcome() {
        let mut q = AdmissionQueue::new();
        q.admit(job(0, 0.0, AppId::Faiss), 10.0).unwrap();
        q.mark_running(0, 1.0, 0, false).unwrap();
        q.mark_failed(0, 4.0).unwrap();
        assert!(q.all_resolved() && q.all_resolved_scan());
        assert_eq!(q.count(JobState::Failed), 1);
        assert_eq!(q.horizon_s(), 4.0, "a lost job resolves at the fault");
        // Terminal: nothing else may touch it.
        assert!(q.mark_completed(0, 5.0).is_err());
        assert!(q.mark_retrying(0).is_err());
        assert!(!q.expire_if_pending(0, 20.0));
    }

    #[test]
    fn shed_is_a_terminal_outcome_for_pending_jobs() {
        let mut q = AdmissionQueue::new();
        q.admit(job(0, 0.0, AppId::Faiss), 10.0).unwrap();
        q.admit(job(1, 0.5, AppId::Hotspot), 10.0).unwrap();
        q.mark_running(0, 1.0, 0, false).unwrap();
        // Only pending jobs shed — a running job is refused as a typed error.
        assert!(q.mark_shed(0, 2.0).is_err(), "shed a running job");
        q.mark_shed(1, 2.0).unwrap();
        assert_eq!(q.count(JobState::Shed), 1);
        assert_eq!(q.pending_len(), 0);
        assert_eq!(q.horizon_s(), 2.0, "a shed job resolves at the shed instant");
        // Terminal: nothing else may touch it, and its stale deadline
        // event must no-op.
        assert!(q.mark_shed(1, 3.0).is_err(), "double shed");
        assert!(q.mark_running(1, 3.0, 0, false).is_err());
        assert!(!q.expire_if_pending(1, 20.0));
        q.mark_completed(0, 4.0).unwrap();
        assert!(q.all_resolved() && q.all_resolved_scan());
        assert_eq!(
            q.smallest_pending_footprint_gib(),
            q.smallest_pending_footprint_scan()
        );
    }

    #[test]
    fn illegal_transitions_are_typed_errors() {
        // Every transition demanded on a job in the wrong state must come
        // back as an `Err` — a malformed trace must never panic the CLI.
        let mut q = AdmissionQueue::new();
        q.admit(job(0, 0.0, AppId::Faiss), 10.0).unwrap();
        // Pending: only place/reject/forward/expire are legal.
        assert!(q.mark_completed(0, 1.0).is_err(), "complete a pending job");
        assert!(q.mark_retrying(0).is_err(), "retry a pending job");
        assert!(q.mark_failed(0, 1.0).is_err(), "fail a pending job");
        // Out-of-range ids are errors too, not index panics.
        assert!(q.mark_running(99, 1.0, 0, false).is_err());
        assert!(q.reject(99, 1.0).is_err());
        assert!(q.mark_forwarded(99).is_err());
        q.mark_running(0, 1.0, 0, false).unwrap();
        // Running: only complete/retry/fail are legal.
        assert!(q.mark_running(0, 2.0, 0, false).is_err(), "double place");
        assert!(q.reject(0, 2.0).is_err(), "reject a running job");
        assert!(q.mark_forwarded(0).is_err(), "forward a running job");
        q.mark_completed(0, 3.0).unwrap();
        // Completed: terminal.
        assert!(q.mark_completed(0, 4.0).is_err(), "double complete");
        assert!(q.mark_running(0, 4.0, 0, false).is_err());
        let err = q.mark_completed(0, 4.0).unwrap_err().to_string();
        assert!(
            err.contains("job 0") && err.contains("running"),
            "error must name the job and the wanted state: {err}"
        );
    }

    #[test]
    fn interleaved_handoffs_keep_dense_fifo_ids() {
        // admit / admit_handoff interleave freely (a handoff can fire
        // between pre-scheduled arrivals); the queue only requires ids
        // dense in admission order, and ascending-id iteration stays FIFO
        // by that order.
        let mut q = AdmissionQueue::new();
        q.admit(job(0, 1.0, AppId::Faiss), 10.0).unwrap();
        q.admit_handoff(job(1, 0.25, AppId::Hotspot), 9.0).unwrap(); // older arrival, later admission
        q.admit(job(2, 2.0, AppId::Lammps), 10.0).unwrap();
        q.admit_handoff(job(3, 0.75, AppId::NekRs), 9.5).unwrap();
        assert_eq!(q.pending_ids().collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        for (i, j) in q.jobs.iter().enumerate() {
            assert_eq!(j.job.id as usize, i, "ids must stay dense");
        }
        assert!(q.jobs[1].handoff && q.jobs[3].handoff);
        assert_eq!(q.jobs[1].deadline_s, 9.0, "handoff deadline is absolute");
        assert_eq!(q.jobs[0].deadline_s, 11.0, "local deadline is relative");
        assert_eq!(
            q.smallest_pending_footprint_gib(),
            q.smallest_pending_footprint_scan()
        );
    }

    #[test]
    fn non_dense_admission_id_is_rejected() {
        let mut q = AdmissionQueue::new();
        let err = q.admit(job(1, 0.0, AppId::Faiss), 5.0); // id 1 into an empty queue
        assert!(err.is_err(), "non-dense id must be a typed error");
        assert!(err.unwrap_err().to_string().contains("dense"));
        assert_eq!(q.pending_len(), 0, "failed admission leaves no residue in the pending set");
    }

    #[test]
    fn forwarded_job_rejected_at_destination_counts_exactly_once() {
        // A job that is forwarded by its origin shard and then rejected at
        // the destination must appear exactly once in the global
        // completed/expired/rejected totals — the origin's Forwarded state
        // resolves its loop accounting but contributes no outcome.
        let mut origin = AdmissionQueue::new();
        origin.admit(job(0, 1.0, AppId::Llama3Fp16), 10.0).unwrap();
        origin.mark_forwarded(0).unwrap();
        assert!(origin.all_resolved() && origin.all_resolved_scan());
        assert_eq!(origin.count(JobState::Forwarded), 1);

        let mut dst = AdmissionQueue::new();
        dst.admit_handoff(job(0, 1.0, AppId::Llama3Fp16), 11.0).unwrap();
        dst.reject(0, 4.0).unwrap();
        assert!(dst.all_resolved());

        let outcomes = |q: &AdmissionQueue| {
            q.count(JobState::Completed) + q.count(JobState::Expired) + q.count(JobState::Rejected)
        };
        assert_eq!(outcomes(&origin), 0, "origin contributes no outcome");
        assert_eq!(outcomes(&dst), 1, "destination owns the single outcome");
        assert_eq!(origin.horizon_s(), 0.0, "forwarding never extends the horizon");
        assert_eq!(dst.horizon_s(), 4.0);
        // A handed-off job never forwards again — the one-hop invariant,
        // refused as a typed error (not a panic).
        let mut twice = AdmissionQueue::new();
        twice.admit_handoff(job(0, 1.0, AppId::Faiss), 11.0).unwrap();
        let r = twice.mark_forwarded(0);
        assert!(r.is_err(), "double forward must be refused");
        assert_eq!(
            twice.jobs[0].state,
            JobState::Pending,
            "a refused forward leaves the job untouched"
        );
    }

    #[test]
    fn counters_track_scan_truth_through_lifecycle() {
        let mut q = AdmissionQueue::new();
        let apps = [
            AppId::Faiss,
            AppId::Llama3Fp16,
            AppId::Hotspot,
            AppId::Faiss,
            AppId::NekRs,
            AppId::Qiskit31,
        ];
        for (i, app) in apps.iter().enumerate() {
            q.admit(job(i as u32, i as f64, *app), 20.0).unwrap();
            assert_eq!(
                q.smallest_pending_footprint_gib(),
                q.smallest_pending_footprint_scan()
            );
        }
        q.mark_running(2, 2.5, 0, false).unwrap();
        q.mark_running(0, 3.0, 1, false).unwrap();
        q.reject(5, 5.0).unwrap();
        assert_eq!(
            q.smallest_pending_footprint_gib(),
            q.smallest_pending_footprint_scan()
        );
        assert_eq!(q.all_resolved(), q.all_resolved_scan());
        q.mark_completed(2, 6.0).unwrap();
        q.mark_completed(0, 7.0).unwrap();
        assert!(q.expire_if_pending(1, 25.0));
        assert!(q.expire_if_pending(3, 25.0));
        assert!(q.expire_if_pending(4, 25.0));
        assert_eq!(
            q.smallest_pending_footprint_gib(),
            q.smallest_pending_footprint_scan()
        );
        assert_eq!(q.all_resolved(), q.all_resolved_scan());
        assert!(q.all_resolved());
    }
}
