//! Dynamic MIG reconfiguration for the serving fleet.
//!
//! MIG layouts are static while work runs (§II-B3), so the serving layer
//! can only repartition a *fully drained* GPU, and doing so costs real
//! time: destroying the old GIs and creating the new ones is a sequence of
//! driver operations, each in the hundreds-of-milliseconds to seconds
//! range (`nvidia-smi mig -dgi/-cgi`). The latency model below charges a
//! base cost plus a per-instance cost in both directions; during that
//! window the GPU serves nothing, which is exactly the trade-off the
//! offload-aware policy avoids by squeezing jobs into existing slices.
//!
//! Target layouts are *valid-partition-preserving*: `plan_for_footprint`
//! only ever proposes layouts that the `MigManager` slice budget accepts
//! (re-validated at `FleetGpu::begin_reconfig` time).
//!
//! The host-memory plane feeds the *trigger* side: a job that fits the
//! current layouts only by offloading no longer suppresses
//! reconfiguration once the node's Grace pool cannot park its spill
//! (`Planner::fits_current_layouts` consults `Fleet::host_fits`), so a
//! drained GPU can be repartitioned toward a direct-fit class instead of
//! letting the job starve behind an exhausted pool.

use super::fleet::{class_layout, Fleet};
use crate::mig::profile::{GiProfile, ProfileId};

/// Fixed driver/setup cost of any reconfiguration (s).
pub const RECONFIG_BASE_S: f64 = 1.0;
/// Cost per GPU instance destroyed or created (s).
pub const RECONFIG_PER_INSTANCE_S: f64 = 0.5;

/// Modeled latency of switching a drained GPU from `old` to `new`.
pub fn latency_s(old: &[ProfileId], new: &[ProfileId]) -> f64 {
    RECONFIG_BASE_S + RECONFIG_PER_INSTANCE_S * (old.len() + new.len()) as f64
}

/// Compact human label for an arbitrary per-GPU layout, e.g.
/// `4x1g.12gb+2g.24gb` — used by the telemetry plane to describe
/// old → new layouts in reconfiguration trace events. Instances are
/// grouped in `ALL_PROFILES` (ascending-SM) order, so the label is a
/// canonical function of the layout multiset.
pub fn layout_label(layout: &[ProfileId]) -> String {
    use crate::mig::profile::{ALL_PROFILES, NUM_PROFILES};
    let mut counts = [0u32; NUM_PROFILES];
    for &p in layout {
        counts[p.index()] += 1;
    }
    let mut parts: Vec<String> = Vec::new();
    for p in ALL_PROFILES {
        let n = counts[p.index()];
        let name = GiProfile::get(p).name;
        match n {
            0 => {}
            1 => parts.push(name.to_string()),
            _ => parts.push(format!("{n}x{name}")),
        }
    }
    if parts.is_empty() {
        "empty".to_string()
    } else {
        parts.join("+")
    }
}

/// The canonical target layout for hosting a job whose footprint (plus
/// context overhead) is `need_gib`: the smallest profile class that fits
/// it directly, packed out with complementary instances so the rest of the
/// GPU keeps serving small jobs. `None` when nothing fits (the job is
/// unservable without offloading).
pub fn plan_for_footprint(need_gib: f64) -> Option<Vec<ProfileId>> {
    use ProfileId::*;
    [P1g12gb, P2g24gb, P3g48gb, P7g96gb]
        .into_iter()
        .find(|&class| need_gib <= GiProfile::get(class).mem_gib)
        .map(class_layout)
}

/// Choose a reconfiguration that would let a job of `need_gib` run: the
/// first fully-idle, not-already-reconfiguring GPU whose layout would
/// change. Returns `(gpu index, target layout)`. Walks the fleet's
/// idle-GPU index (ascending id order — the same order the full scan
/// visits eligible GPUs in).
pub fn plan_reconfig(fleet: &Fleet, need_gib: f64) -> Option<(usize, Vec<ProfileId>)> {
    let target = plan_for_footprint(need_gib)?;
    for g in fleet.idle_gpus() {
        if fleet.gpus[g].layout == target {
            continue; // already shaped right; the job fits without change
        }
        return Some((g, target));
    }
    None
}

/// Whether the node power budget forecloses reconfiguring for a job:
/// when even the job's *cheapest* admissible placement draws more than
/// the remaining node headroom, repartitioning a GPU cannot help —
/// layouts change slot shapes, not the power budget — so the latency
/// (and the drained GPU) would be wasted. Pure integer-milliwatt
/// compare, so both serve modes decide identically.
pub fn power_gates_reconfig(node_headroom_mw: u64, min_job_draw_mw: u64) -> bool {
    min_job_draw_mw > node_headroom_mw
}

/// `plan_reconfig` by full fleet scan — the differential-test oracle.
pub fn plan_reconfig_scan(fleet: &Fleet, need_gib: f64) -> Option<(usize, Vec<ProfileId>)> {
    let target = plan_for_footprint(need_gib)?;
    for (g, gpu) in fleet.gpus.iter().enumerate() {
        if gpu.out_of_service() || !gpu.all_idle() {
            continue;
        }
        if gpu.layout == target {
            continue;
        }
        return Some((g, target));
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::fleet::{validate_layout, Fleet, LayoutPreset};

    #[test]
    fn footprint_classes_map_to_valid_layouts() {
        for need in [0.5, 10.9, 11.1, 22.9, 23.1, 46.0, 60.0, 94.5] {
            let layout = plan_for_footprint(need).unwrap();
            validate_layout(&layout).unwrap();
            // The target actually hosts the footprint.
            let max_mem = layout
                .iter()
                .map(|&p| GiProfile::get(p).mem_gib)
                .fold(0.0f64, f64::max);
            assert!(max_mem >= need, "need {need} vs max slot {max_mem}");
        }
        assert!(plan_for_footprint(95.0).is_none());
    }

    #[test]
    fn layout_labels_are_canonical() {
        use ProfileId::*;
        assert_eq!(layout_label(&[]), "empty");
        assert_eq!(layout_label(&[P7g96gb]), "7g.96gb");
        assert_eq!(layout_label(&[P1g12gb; 7]), "7x1g.12gb");
        // Order-insensitive: the label is a function of the multiset.
        assert_eq!(
            layout_label(&[P2g24gb, P1g12gb, P1g12gb]),
            layout_label(&[P1g12gb, P2g24gb, P1g12gb])
        );
        assert_eq!(
            layout_label(&[P1g12gb, P2g24gb, P1g12gb]),
            "2x1g.12gb+2g.24gb"
        );
    }

    #[test]
    fn latency_scales_with_instance_churn() {
        use ProfileId::*;
        let small = vec![P1g12gb; 7];
        let big = vec![P7g96gb];
        let l = latency_s(&small, &big);
        assert!((l - (1.0 + 0.5 * 8.0)).abs() < 1e-12);
        assert!(latency_s(&big, &small) > latency_s(&big, &big));
    }

    #[test]
    fn power_gate_bites_exactly_when_draw_exceeds_headroom() {
        assert!(!power_gates_reconfig(100, 100));
        assert!(power_gates_reconfig(100, 101));
        assert!(!power_gates_reconfig(u64::MAX, u64::MAX), "no gate, no bite");
        // An unservable app reports u64::MAX draw: always gated.
        assert!(power_gates_reconfig(0, 1));
    }

    #[test]
    fn plan_reconfig_picks_idle_gpu_and_skips_matching_layout() {
        let mut fleet = Fleet::new(2, LayoutPreset::AllSmall).unwrap();
        // A 16 GiB job needs the 2g class; GPU 0 is busy, GPU 1 idle.
        fleet.start_job(0, 0, 1, 0.0, 10.0, 0.5, 0);
        let (g, target) = plan_reconfig(&fleet, 16.0).unwrap();
        assert_eq!(g, 1);
        assert_eq!(target[0], ProfileId::P2g24gb);
        assert_eq!(plan_reconfig(&fleet, 16.0), plan_reconfig_scan(&fleet, 16.0));
        // Once GPU 1 already has the target layout, no reconfig is planned.
        fleet.begin_reconfig(1, target.clone(), 5.0).unwrap();
        // Mid-reconfiguration, GPU 1 is no candidate either way.
        assert_eq!(plan_reconfig(&fleet, 16.0), plan_reconfig_scan(&fleet, 16.0));
        fleet.finish_reconfig(1);
        assert!(plan_reconfig(&fleet, 16.0).is_none());
        assert!(plan_reconfig_scan(&fleet, 16.0).is_none());
        // Unservable footprints never produce a plan.
        assert!(plan_reconfig(&fleet, 95.0).is_none());
        // A cordoned GPU is no repartition candidate: GPU 1 (already the
        // 2g class) goes out of service, GPU 0 drains — only GPU 0 is
        // plannable, and the index and the scan agree on that.
        let _ = fleet.cordon_gpu(1, 11.0);
        fleet.finish_job(0, 0, 1, 11.0);
        assert_eq!(plan_reconfig(&fleet, 16.0), Some((0, target.clone())));
        assert_eq!(plan_reconfig(&fleet, 16.0), plan_reconfig_scan(&fleet, 16.0));
        let _ = fleet.cordon_gpu(0, 12.0);
        assert!(plan_reconfig(&fleet, 16.0).is_none());
        assert_eq!(plan_reconfig(&fleet, 16.0), plan_reconfig_scan(&fleet, 16.0));
        fleet.uncordon_gpu(0);
        assert_eq!(plan_reconfig(&fleet, 16.0), plan_reconfig_scan(&fleet, 16.0));
    }
}
