//! Sharded multi-node serving: per-node event loops with deterministic
//! cross-node dispatch.
//!
//! A *node* is one shard of the serving control plane: a partition of the
//! fleet's GPUs with its own `Fleet` (idle index + power cache), its own
//! `AdmissionQueue`, its own `Planner` cost caches, and — crucially — its
//! own `sim::Engine`. Nothing is shared between shards while they run, so
//! N shards execute on up to N worker threads with no locks on the hot
//! path.
//!
//! ## Conservative time-window synchronization
//!
//! Shards advance in lock-stepped virtual-time *epochs* of length
//! `lookahead_s`, the modeled cross-node dispatch latency. Within an
//! epoch a shard processes only its local events; all cross-shard
//! influence — arrival routing and overflow handoffs — is decided by the
//! coordinator at the *epoch barrier*, strictly from state the shards
//! reported at the previous barrier. An event sent to a shard for epoch k
//! is therefore known before epoch k starts, which is exactly the
//! classical conservative-lookahead invariant: cross-node state is
//! observed with a staleness of at most one epoch, and the simulation is
//! **bit-identical for every thread count, including 1** (the coordinator
//! always merges barrier data in shard-id order; shard execution is pure
//! w.r.t. everything outside the shard).
//!
//! ## The dispatcher
//!
//! - *Arrival routing*: `RouteKind::RoundRobin` assigns job → shard by
//!   `id % nodes` (static, so every arrival is pre-scheduled upfront,
//!   exactly like the single-loop oracle); `RouteKind::LeastLoaded`
//!   routes each epoch's arrival window at the barrier to the shard with
//!   the fewest pending-or-undelivered jobs as of the previous barrier.
//! - *Overflow handoffs*: a pending job that sat through a full epoch
//!   without placing, and has deadline slack left, is offered back to the
//!   coordinator, which forwards it (at most one hop) to the shard with
//!   the most open SM-seats (batched headroom: `sms × (batch − occupancy)`
//!   summed over slots — exactly idle-slot SMs at batch 1) that can host
//!   it under the run's policy: an empty slot big enough for a direct
//!   run, an empty slot for the offloaded run *plus host-pool headroom
//!   for its spill*, or a partially-filled slot whose remaining memory
//!   passes `Slot::fits` for the job's direct charge — or, when
//!   reconfiguration is enabled, to any shard with open headroom (the
//!   destination can repartition); with neither,
//!   the job stays put rather than migrate toward certain expiry. The job
//!   leaves its origin queue as `JobState::Forwarded` and re-arrives at
//!   the target at the next epoch start — paying the lookahead as
//!   dispatch latency — keeping its original arrival time (for wait
//!   accounting) and absolute deadline. Handoffs are injected in
//!   ascending global-id order, so equal re-arrival timestamps preserve
//!   global arrival order.
//!
//! ## Oracles
//!
//! The single-loop `cluster::serve` path *is* a 1-node run of this
//! machinery (`run_single`), so `nodes = 1` is differentially tested
//! bit-for-bit against it, and `nodes > 1` runs are differentially tested
//! across thread counts (see `tests/integration.rs`).

use super::estimate::{CostSource, DeltaAcc, EstPlane, EstimatorDelta, EstimatorStats, PendingObs};
use super::faults::{FaultDomains, FaultKind, ShedPolicy};
use super::fleet::{Fleet, Orphan};
use super::power::PowerTracker;
use super::queue::{AdmissionQueue, JobState};
use super::reconfig;
use super::telemetry::{
    ChunkCollector, Counter, EventKind, FleetSample, HandoffReason, NullSink, Recorder, Sink,
    TelemetryChunk, TelemetryConfig, TelemetryReport, TelemetryStreamer,
};
use super::{PlacementCost, Planner, PolicyKind, ServeConfig, ServeMode, ServeReport};
use crate::gpu::nvlink::{Dir, NvlinkModel};
use crate::mig::profile::{GiProfile, ProfileId};
use crate::sim::{Engine, EventToken};
use crate::util::Rng;
use crate::util::json::Json;
use crate::util::stats::{percentile, Accum};
use crate::util::units::{ns_to_sec, sec_to_ns};
use crate::workload::trace::{Job, JobTrace};
use crate::workload::AppId;
use anyhow::{bail, ensure};
use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc;
use std::thread;
use std::time::Duration;

/// Serving events, all local to one shard. `JobDone` names the finishing
/// job: under slot-level batching several residents share one slot and
/// complete independently. `Fault`/`Recover` exist only when the fault
/// plane is active — an inert plane schedules neither, so the engine's
/// popped-event count (and hence every report byte) is untouched.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    Arrival(u32),
    Deadline(u32),
    JobDone { gpu: usize, slot: usize, job: u32 },
    ReconfigDone(usize),
    /// The fault plane's next failure draw lands on this (local) GPU.
    /// `gen` is the GPU's fault generation at scheduling time: a domain
    /// cordon bumps the generation, so a pending per-GPU draw the cordon
    /// superseded is dropped stale instead of firing on a cordoned board.
    Fault { gpu: usize, gen: u64 },
    /// A hard-failed GPU finishes repair and rejoins the fleet.
    Recover(usize),
    /// The next correlated event of one fault domain (index into
    /// `Shard::domains`): every in-service member GPU is cordoned at
    /// once. Exists only under `--fault-domains`.
    DomainFault(usize),
}

/// Reusable dispatch state: the pending-id snapshot buffer and the
/// per-app placement-failure memo. A placement that failed at fleet
/// epoch E keeps failing while the epoch stays E — every mutation since
/// only *removed* capacity — so repeat attempts for the same app are
/// skipped without touching the planner.
struct DispatchScratch {
    ids: Vec<u32>,
    failed_at_epoch: [Option<u64>; AppId::COUNT],
}

impl DispatchScratch {
    fn new() -> DispatchScratch {
        DispatchScratch {
            ids: Vec::new(),
            failed_at_epoch: [None; AppId::COUNT],
        }
    }
}

/// Per-job metadata the queue does not carry: the fleet-global job id,
/// for cross-node handoffs the absolute deadline fixed at the original
/// admission, and for fault-plane retries the re-admission terms.
#[derive(Debug, Clone, Copy)]
struct JobMeta {
    global_id: u32,
    handoff_deadline_s: Option<f64>,
    /// `Some` when this scheduling entry is a fault-plane re-admission
    /// of a killed running job.
    retry: Option<RetryMeta>,
}

/// Re-admission terms of a fault-plane retry.
#[derive(Debug, Clone, Copy)]
struct RetryMeta {
    /// Absolute abandonment deadline, unchanged by the restart (retries
    /// compete honestly: the clock does not restart on a fault).
    deadline_abs_s: f64,
    /// Whether the job had already hopped shards before the fault — the
    /// mark survives re-admission so it still never hops again.
    handoff: bool,
}

/// Restart bookkeeping for one fleet-global job under the fault plane,
/// carried across its re-admissions (which get fresh queue ids).
#[derive(Debug, Clone, Copy)]
struct RetryState {
    /// Killed attempts so far (bounded by `FaultConfig::retries`).
    attempts: u32,
    /// Fraction of the job's full runtime preserved by checkpoints
    /// across all killed attempts (0 = restart from scratch). The next
    /// placement serves only the remaining `1 - preserved` of the job.
    preserved: f64,
    /// Restore-transfer seconds the next placement pays before serving
    /// resumes: 0 while the checkpoint is local, priced off the CE-copy
    /// H2D rate when the retry shipped cross-shard through a handoff.
    restore_s: f64,
}

/// A job being handed off between shards at an epoch barrier.
#[derive(Debug, Clone)]
struct Handoff {
    global_id: u32,
    origin: usize,
    /// Queue id at the origin shard (the removal target).
    origin_local: u32,
    app: AppId,
    /// Original arrival time (wait accounting spans the handoff).
    arrival_s: f64,
    /// Absolute abandonment deadline, unchanged by the handoff.
    deadline_abs_s: f64,
    /// Memory of the smallest slot class that can host this job under the
    /// run's policy (offloading included when the policy allows it) — the
    /// dispatcher's placement-compatibility requirement for a target.
    min_host_gib: f64,
    /// Memory of the smallest slot class that hosts this job *without*
    /// offloading (`f64::INFINITY` when none — the job can only run
    /// spilled). Admissibility is monotone in slice memory, so any empty
    /// slot at least this large admits the job directly.
    min_direct_gib: f64,
    /// Slice memory the job would charge on a direct (non-offloaded)
    /// placement: footprint + per-process context — the `Slot::fits`
    /// requirement for joining a partially-filled slot.
    direct_need_gib: f64,
    /// Host-pool bytes the job parks when admitted at its smallest class
    /// (0 when that class fits it directly) — the target shard must have
    /// this much Grace headroom for the offload path to be viable.
    host_need_bytes: u64,
    /// `Some` when the pending job is a fault-plane retry: its restart
    /// bookkeeping ships with the handoff so the destination restores
    /// the checkpoint (paying a restore transfer) instead of the retry
    /// staying pinned to the shard holding its state.
    retry: Option<HandoffRetry>,
}

/// Restart bookkeeping a fault-plane retry carries through a cross-shard
/// handoff.
#[derive(Debug, Clone, Copy)]
struct HandoffRetry {
    /// Killed attempts so far (the bounded budget travels with the job).
    attempts: u32,
    /// Checkpoint-preserved fraction of the job; the destination prices
    /// the restore transfer off this state's size.
    preserved: f64,
}

/// One correlated fault domain as seen by a shard: the member GPUs this
/// shard owns plus the domain's deterministic event stream. Rack domains
/// can straddle shard boundaries: every straddling shard derives the
/// identical stream from the fleet-global domain id and replays the
/// identical draw sequence, so the correlated cordons land at identical
/// virtual times on every shard — but only the `owner` (the shard
/// holding the domain's lowest global GPU) counts and reports the event.
struct DomainState {
    /// Fleet-global domain id (the stream key).
    id: u32,
    /// First fleet-global GPU id the domain spans.
    start: u32,
    /// GPUs the domain spans fleet-wide (the last rack may be narrower).
    width: u32,
    /// Local ids of the member GPUs this shard owns, ascending.
    local: Vec<usize>,
    /// Whether this shard owns the domain's lowest global GPU id (the
    /// unique reporter, so merged counts never double-count an event).
    owner: bool,
    /// The domain's event stream (identical on every straddling shard).
    rng: Rng,
}

/// What a shard reports at an epoch barrier — the only state the
/// coordinator (and hence any other shard) ever observes.
struct BarrierInfo {
    shard: usize,
    pending: u32,
    /// Admitted jobs not yet in a terminal state.
    unresolved: u32,
    /// Arrivals scheduled into the shard's engine but not yet admitted.
    arrivals_pending: u32,
    /// Open SM-seats — every non-reconfiguring slot contributes
    /// `sms × (batch − occupancy)`, the batched-headroom load signal
    /// (exactly the idle-slot SM count at batch 1).
    open_sm_seats: u32,
    /// Memory of the largest *empty* slot (GiB; 0 when none) — the
    /// empty-slot arm of the placement-compatibility check. At batch 1
    /// this is exactly the pre-plane largest-open signal.
    largest_empty_gib: f64,
    /// Largest remaining memory headroom among occupied slots with a
    /// free seat (GiB; 0 at batch 1) — the `Slot::fits` arm: a forwarded
    /// job can join a partially-filled slot only where its direct charge
    /// actually fits, so full slots never bounce arrivals.
    max_open_headroom_gib: f64,
    /// Remaining Grace host-pool headroom (`u64::MAX` when unlimited) —
    /// the offload arm of the compatibility check.
    host_headroom_bytes: u64,
    candidates: Vec<Handoff>,
    /// Telemetry recorded during the epoch, drained from the shard's
    /// sink at the barrier (`None` when the plane is off).
    telemetry: Option<Box<TelemetryChunk>>,
    /// Estimator observations journaled during the epoch, drained at
    /// the barrier for the all-to-all exchange (`None` when the plane
    /// is off or nothing was observed).
    est_delta: Option<Box<EstimatorDelta>>,
}

/// Everything the coordinator sends a shard for one epoch.
struct EpochInput {
    start_ns: u64,
    end_ns: u64,
    /// The cross-node stream may still deliver arrivals or handoffs after
    /// this epoch (keeps the idle-power integral honest while the cluster
    /// as a whole still has work).
    stream_open: bool,
    /// Jobs leaving this shard as handoffs (mark `Forwarded`):
    /// `(origin queue id, destination shard, why the dispatcher picked
    /// it)` — the destination/reason exist purely for the telemetry
    /// plane's `Handoff` events.
    removals: Vec<(u32, u32, HandoffReason)>,
    /// Handoffs arriving at this shard, ascending global id.
    handoffs: Vec<Handoff>,
    /// Fresh arrivals routed to this shard, ascending global id.
    arrivals: Vec<Job>,
    /// The other shards' estimator observations since the last barrier
    /// (`total − own`), applied before any of this epoch's events so
    /// every shard ranks placements on the identical fleet table.
    est_others: Option<Box<EstimatorDelta>>,
}

/// One node shard: a self-contained serving loop over a fleet partition.
/// The single-loop `cluster::serve` is exactly one of these run to
/// completion (`run_single`). Generic over the telemetry [`Sink`]: with
/// the inert [`NullSink`] every hook monomorphizes to nothing, so the
/// untraced build is byte-identical to the pre-telemetry serve loop.
pub(crate) struct Shard<S: Sink> {
    id: usize,
    params: ServeConfig,
    mode: ServeMode,
    lookahead_s: f64,
    forward: bool,
    fleet: Fleet,
    queue: AdmissionQueue,
    planner: Planner,
    engine: Engine<Ev>,
    power: PowerTracker,
    scratch: DispatchScratch,
    /// Pending deadline events, indexed by *queue id* (grown at
    /// admission, like the queue itself).
    deadline_tokens: Vec<Option<EventToken>>,
    /// Scheduling-side job table (scheduling id = index). Queue ids are
    /// assigned separately at admission time: with cross-node handoffs,
    /// admission order need not match scheduling order (a handoff
    /// scheduled last can fire before pre-scheduled future arrivals), and
    /// the queue requires dense ids in admission order.
    jobs: Vec<Job>,
    metas: Vec<JobMeta>,
    /// Queue id → scheduling id (dense, grown at admission).
    qid_to_lid: Vec<u32>,
    /// Arrivals scheduled into this shard's engine so far.
    expected: u32,
    stream_open: bool,
    energy_j: f64,
    frag_integral: f64,
    busy_sm_integral: f64,
    /// GPU-seconds spent throttled below boost (power plane only).
    throttled_gpu_s: f64,
    /// GPU-seconds spent parked by consolidate-and-idle (power plane
    /// only).
    parked_gpu_s: f64,
    /// Pending-job visits that found the node power budget too tight
    /// for the app's cheapest admissible placement.
    power_starved: u64,
    /// Last throttle level emitted per local GPU (telemetry only).
    last_levels: Vec<u32>,
    last_t: f64,
    handoffs_in: u32,
    handoffs_out: u32,
    /// Per-GPU fault streams, seeded from the *fleet-global* GPU id
    /// (`gpu_base + local id`) so the draws are invariant to the shard
    /// partitioning. Empty when the fault plane is inert.
    fault_rngs: Vec<Rng>,
    /// First fleet-global GPU id owned by this shard.
    gpu_base: u32,
    /// Per-GPU flag: a transient fault poisoned the in-flight
    /// reconfiguration, which must be redone when it lands.
    reconfig_poisoned: Vec<bool>,
    /// Per-GPU fault generation: bumped when a domain cordon supersedes
    /// the GPU's pending per-GPU draw, so the stale event drops instead
    /// of firing on a cordoned board. Sized with `fault_rngs`.
    fault_gen: Vec<u64>,
    /// Correlated fault domains overlapping this shard's GPU range
    /// (empty unless `--fault-domains` is set).
    domains: Vec<DomainState>,
    /// Domain-level events fired (counted by owner shards only).
    domain_faults: u32,
    /// Repair crews currently working (tracked only with finite crews).
    crews_busy: u32,
    /// Cordoned GPUs waiting for a free crew: `(local gpu, ttr_s)` in
    /// failure order — deterministic FIFO service.
    repair_queue: VecDeque<(usize, f64)>,
    /// Pending jobs shed by brown-out backpressure.
    shed_count: u32,
    /// Fault-plane restart bookkeeping, keyed by fleet-global job id.
    retry: BTreeMap<u32, RetryState>,
    faults_injected: u32,
    retries_done: u32,
    /// The online profiling plane (`None` when `--estimator off`): the
    /// learned cost tables, in-flight measurements, and regret stats.
    est: Option<Box<EstPlane>>,
    /// Telemetry hook; reads simulator state, never writes it.
    sink: S,
}

impl<S: Sink> Shard<S> {
    fn new(
        id: usize,
        gpus: u32,
        cfg: &ServeConfig,
        mode: ServeMode,
        lookahead_s: f64,
        forward: bool,
        sink: S,
    ) -> crate::Result<Shard<S>> {
        let fleet = Fleet::with_hostmem(gpus, cfg.layout, cfg.batch, cfg.host_pool_gib)?;
        let power = PowerTracker::new(mode, &fleet, &cfg.power);
        let mut planner = Planner::with_opts(
            cfg.workload_scale,
            cfg.batch,
            cfg.c2c_contention,
            cfg.energy_weight,
        );
        // The estimator is built from the shard's own planner, so every
        // shard derives the identical cold (or oracle-seeded) tables.
        let est = if cfg.estimator.active() {
            Some(Box::new(EstPlane::new(&mut planner, &cfg.estimator)))
        } else {
            None
        };
        Ok(Shard {
            id,
            params: cfg.clone(),
            mode,
            lookahead_s,
            forward,
            fleet,
            queue: AdmissionQueue::new(),
            planner,
            engine: Engine::new(),
            power,
            scratch: DispatchScratch::new(),
            deadline_tokens: Vec::new(),
            jobs: Vec::new(),
            metas: Vec::new(),
            qid_to_lid: Vec::new(),
            expected: 0,
            stream_open: false,
            energy_j: 0.0,
            frag_integral: 0.0,
            busy_sm_integral: 0.0,
            throttled_gpu_s: 0.0,
            parked_gpu_s: 0.0,
            power_starved: 0,
            last_levels: vec![0; gpus as usize],
            last_t: 0.0,
            handoffs_in: 0,
            handoffs_out: 0,
            fault_rngs: Vec::new(),
            gpu_base: 0,
            reconfig_poisoned: Vec::new(),
            fault_gen: Vec::new(),
            domains: Vec::new(),
            domain_faults: 0,
            crews_busy: 0,
            repair_queue: VecDeque::new(),
            shed_count: 0,
            retry: BTreeMap::new(),
            faults_injected: 0,
            retries_done: 0,
            est,
            sink,
        })
    }

    /// Arm the fault plane: derive one stream per GPU from the serve
    /// seed and its fleet-global id (never the shard partitioning), and
    /// schedule each GPU's first failure. An inert config arms nothing —
    /// no event is scheduled, so the run is byte-identical to the plane
    /// being absent. Must be called before any event is processed.
    fn arm_faults(&mut self, gpu_base: u32) {
        self.gpu_base = gpu_base;
        if !self.params.faults.active() {
            return;
        }
        let n = self.fleet.gpus.len();
        self.reconfig_poisoned = vec![false; n];
        self.fault_gen = vec![0; n];
        for g in 0..n {
            let mut rng = super::faults::FaultConfig::gpu_stream(
                self.params.seed,
                (gpu_base as usize) + g,
            );
            let ttf = self.params.faults.draw_ttf(&mut rng);
            self.engine
                .schedule_at(sec_to_ns(ttf).max(1), Ev::Fault { gpu: g, gen: 0 });
            self.fault_rngs.push(rng);
        }
        self.arm_domains(n);
    }

    /// Build this shard's view of the correlated fault domains and
    /// schedule each one's first event. A node domain covers exactly
    /// this shard (domain id = shard id); rack domains are fixed-width
    /// windows of fleet-global GPU ids, so a rack straddling shards is
    /// armed on each with the identical stream.
    fn arm_domains(&mut self, n: usize) {
        let base = self.gpu_base as usize;
        let total = self.params.gpus as usize;
        // (fleet-global domain id, global start, global end).
        let spans: Vec<(usize, usize, usize)> = match self.params.faults.domains {
            FaultDomains::None => return,
            FaultDomains::Node => vec![(self.id, base, base + n)],
            FaultDomains::Rack(w) => {
                let w = w as usize;
                ((base / w)..=((base + n - 1) / w))
                    .map(|d| (d, d * w, ((d + 1) * w).min(total)))
                    .collect()
            }
        };
        for (id, start, end) in spans {
            let local: Vec<usize> =
                (start.max(base)..end.min(base + n)).map(|g| g - base).collect();
            debug_assert!(!local.is_empty(), "a domain span overlaps its shard");
            let mut rng = super::faults::FaultConfig::domain_stream(self.params.seed, id);
            let ttf = self.params.faults.draw_ttf(&mut rng);
            let d = self.domains.len();
            self.domains.push(DomainState {
                id: id as u32,
                start: start as u32,
                width: (end - start) as u32,
                local,
                owner: start >= base,
                rng,
            });
            self.engine.schedule_at(sec_to_ns(ttf).max(1), Ev::DomainFault(d));
        }
    }

    /// Schedule a fresh arrival (fires at its own arrival time). The job's
    /// id is relabelled to the shard's scheduling id; the global id lives
    /// in the meta table, and the queue id is assigned when the arrival
    /// event fires.
    fn push_arrival(&mut self, mut job: Job) {
        let gid = job.id;
        let lid = self.jobs.len() as u32;
        job.id = lid;
        let fire_ns = sec_to_ns(job.arrival_s);
        self.jobs.push(job);
        self.metas.push(JobMeta {
            global_id: gid,
            handoff_deadline_s: None,
            retry: None,
        });
        self.engine.schedule_at(fire_ns, Ev::Arrival(lid));
        self.expected += 1;
    }

    /// Schedule a handed-off job: it re-arrives at `fire_at_s` (the epoch
    /// start after the barrier that decided the handoff) but keeps its
    /// original arrival time and absolute deadline.
    fn push_handoff(&mut self, h: Handoff, fire_at_s: f64) {
        let lid = self.jobs.len() as u32;
        self.jobs.push(Job {
            id: lid,
            app: h.app,
            arrival_s: h.arrival_s,
        });
        if let Some(hr) = h.retry {
            // A cross-shard checkpoint restore: the preserved fraction of
            // the job's footprint must stream host-to-device over the
            // destination's CE copy path before serving resumes — the
            // same engine rate the offload model charges for H2D staging.
            let state_gib = hr.preserved * self.planner.footprint_gib(h.app);
            let restore_s =
                state_gib / NvlinkModel::default().memcpy_bw_gibs(Some(1), Dir::H2D);
            self.retry.insert(
                h.global_id,
                RetryState {
                    attempts: hr.attempts,
                    preserved: hr.preserved,
                    restore_s,
                },
            );
        }
        self.metas.push(JobMeta {
            global_id: h.global_id,
            handoff_deadline_s: Some(h.deadline_abs_s),
            retry: None,
        });
        self.engine.schedule_at(sec_to_ns(fire_at_s), Ev::Arrival(lid));
        self.expected += 1;
        self.handoffs_in += 1;
    }

    /// This job is leaving for another shard: cancel its deadline and
    /// resolve it locally as `Forwarded` (the destination owns it now).
    /// `t_ns` is the barrier instant the dispatcher decided at — the
    /// `Handoff` event's timestamp.
    fn remove_for_handoff(&mut self, t_ns: u64, qid: u32, dest: u32, reason: HandoffReason) {
        if let Some(tok) = self.deadline_tokens[qid as usize].take() {
            self.engine.cancel(tok);
        }
        let lid = self.qid_to_lid[qid as usize];
        let gid = self.metas[lid as usize].global_id;
        if S::ENABLED {
            let app = self.queue.jobs[qid as usize].job.app;
            self.sink
                .emit(t_ns, Some(gid), EventKind::Handoff { app, dest, reason });
        }
        // A forwarded retry's checkpoint state travels in the handoff
        // payload; the local copy is gone once the job leaves.
        if !self.retry.is_empty() {
            self.retry.remove(&gid);
        }
        self.queue
            .mark_forwarded(qid)
            .expect("the dispatcher only forwards pending, never-hopped jobs");
        self.handoffs_out += 1;
    }

    /// Process local events strictly before `end_ns` (all of them when
    /// `None`), advancing the incremental integrals exactly as the
    /// single-loop serve does: epoch boundaries add no integration points,
    /// so chopping time into epochs cannot change any float result.
    fn run_until(&mut self, end_ns: Option<u64>) {
        loop {
            let t = match self.engine.peek_time_ns() {
                Some(t) => t,
                None => break,
            };
            if let Some(end) = end_ns {
                if t >= end {
                    break;
                }
            }
            let ev = self.engine.pop().expect("peeked event vanished");
            self.step(ev.time_ns, ev.event);
        }
    }

    fn step(&mut self, time_ns: u64, ev: Ev) {
        if S::ENABLED {
            self.flush_samples(time_ns);
        }
        let now = ns_to_sec(time_ns);
        let dt = now - self.last_t;
        // Integrate only while serving work remains (arrivals still to
        // fire, unresolved jobs, or the cross-node stream still open).
        // Once the final job resolves, the only events left are trailing
        // reconfig completions, and charging idle power past the horizon
        // would skew the energy comparison between runs (the metrics all
        // cover [0, horizon]). Mid-run idle gaps between arrivals still
        // count — the fleet is powered on, waiting.
        let resolved = match self.mode {
            ServeMode::Indexed => self.queue.all_resolved(),
            ServeMode::NaiveOracle => self.queue.all_resolved_scan(),
        };
        let work_remains =
            self.queue.jobs.len() < self.expected as usize || !resolved || self.stream_open;
        if dt > 0.0 && work_remains {
            if self.power.plane_active() {
                let smp = self.power.sample(&self.fleet);
                self.energy_j += dt * smp.watts;
                self.throttled_gpu_s += dt * smp.throttled_gpus as f64;
                self.parked_gpu_s += dt * smp.parked_gpus as f64;
            } else {
                self.energy_j += dt * self.power.power_w(&self.fleet);
            }
            let smallest = match self.mode {
                ServeMode::Indexed => self.queue.smallest_pending_footprint_gib(),
                ServeMode::NaiveOracle => self.queue.smallest_pending_footprint_scan(),
            };
            let needed = smallest.map(|f| f + self.planner.ctx_gib());
            let frag = match self.mode {
                ServeMode::Indexed => self.fleet.fragmentation(needed),
                ServeMode::NaiveOracle => self.fleet.fragmentation_scan(needed),
            };
            self.frag_integral += dt * frag;
            let busy = match self.mode {
                ServeMode::Indexed => self.fleet.busy_sms(),
                ServeMode::NaiveOracle => self.fleet.busy_sms_scan(),
            };
            self.busy_sm_integral += dt * busy as f64;
        }
        self.last_t = now;
        match ev {
            Ev::Arrival(lid) => {
                // Queue ids are dense in admission order; with handoffs in
                // play that order can differ from scheduling order, so the
                // id is assigned here, when the arrival actually fires.
                let mut job = self.jobs[lid as usize].clone();
                let app = job.app;
                let qid = self.queue.jobs.len() as u32;
                job.id = qid;
                self.qid_to_lid.push(lid);
                self.deadline_tokens.push(None);
                let meta = self.metas[lid as usize];
                match (meta.retry, meta.handoff_deadline_s) {
                    (Some(r), _) => self.queue.admit_retry(job, r.deadline_abs_s, r.handoff),
                    (None, None) => self.queue.admit(job, self.params.deadline_s),
                    (None, Some(abs)) => self.queue.admit_handoff(job, abs),
                }
                .expect("a fresh queue id admits exactly once");
                if S::ENABLED {
                    let deadline_ns = sec_to_ns(self.queue.jobs[qid as usize].deadline_s);
                    self.sink.emit(
                        time_ns,
                        Some(meta.global_id),
                        EventKind::Admit {
                            app,
                            deadline_ns,
                            handoff: meta.handoff_deadline_s.is_some(),
                        },
                    );
                }
                if self.planner.servable(app, self.params.policy.allows_offload()) {
                    // Probe phase: each shard's first `probe_n` servable
                    // admissions per app are flagged — their completions
                    // train the per-app unit work. Rejected apps never
                    // reach here, so they burn no probe budget.
                    if let Some(est) = &mut self.est {
                        if est.state.note_admit(app) {
                            self.queue.jobs[qid as usize].probe = true;
                            est.stats.probes += 1;
                            if S::ENABLED {
                                self.sink.emit(
                                    time_ns,
                                    Some(meta.global_id),
                                    EventKind::Probe { app },
                                );
                            }
                        }
                    }
                    // The queue's deadline_s is the single source of truth
                    // for when this job abandons.
                    let abandon_s = self.queue.jobs[qid as usize].deadline_s;
                    let abandon_ns = sec_to_ns(abandon_s);
                    if abandon_ns >= time_ns {
                        self.deadline_tokens[qid as usize] = Some(
                            self.engine.schedule_at(abandon_ns, Ev::Deadline(qid)),
                        );
                    } else {
                        // Only a fault-plane retry can re-admit past its
                        // absolute deadline: the client already gave up
                        // while the killed attempt was running.
                        let expired = self.queue.expire_if_pending(qid, now);
                        if S::ENABLED && expired {
                            self.sink
                                .emit(time_ns, Some(meta.global_id), EventKind::Expire { app });
                        }
                    }
                    dispatch(
                        &self.params,
                        self.mode,
                        now,
                        time_ns,
                        &mut self.fleet,
                        &mut self.queue,
                        &mut self.planner,
                        &mut self.engine,
                        &mut self.power,
                        &mut self.power_starved,
                        &mut self.deadline_tokens,
                        &mut self.scratch,
                        &mut self.sink,
                        &self.metas,
                        &self.qid_to_lid,
                        &self.retry,
                        self.est.as_deref_mut(),
                    );
                } else {
                    self.queue
                        .reject(qid, now)
                        .expect("a just-admitted job is pending");
                    if S::ENABLED {
                        self.sink
                            .emit(time_ns, Some(meta.global_id), EventKind::Reject { app });
                    }
                }
            }
            Ev::Deadline(qid) => {
                self.deadline_tokens[qid as usize] = None;
                let expired = self.queue.expire_if_pending(qid, now);
                if S::ENABLED && expired {
                    let gid = self.metas[self.qid_to_lid[qid as usize] as usize].global_id;
                    let app = self.queue.jobs[qid as usize].job.app;
                    self.sink.emit(time_ns, Some(gid), EventKind::Expire { app });
                }
            }
            Ev::JobDone { gpu, slot, job } => {
                if self.fleet.finish_job(gpu, slot, job, now) {
                    self.queue
                        .mark_completed(job, now)
                        .expect("a resident finishing in the fleet is running");
                    self.power.on_finish(gpu, slot, job);
                    if !self.retry.is_empty() {
                        // A retried job that finally completed no longer
                        // needs its checkpoint state.
                        let gid = self.metas[self.qid_to_lid[job as usize] as usize].global_id;
                        self.retry.remove(&gid);
                    }
                    if S::ENABLED {
                        let qj = &self.queue.jobs[job as usize];
                        let (app, arrival_s, placed_s, deadline_s, offloaded) = (
                            qj.job.app,
                            qj.job.arrival_s,
                            qj.placed_s,
                            qj.deadline_s,
                            qj.offloaded,
                        );
                        let gid =
                            self.metas[self.qid_to_lid[job as usize] as usize].global_id;
                        let placed_ns = sec_to_ns(placed_s.unwrap_or(arrival_s));
                        let wait_ns = placed_ns.saturating_sub(sec_to_ns(arrival_s));
                        let service_ns = time_ns.saturating_sub(placed_ns);
                        let slack_ns = sec_to_ns(deadline_s).saturating_sub(time_ns);
                        self.sink.emit(
                            time_ns,
                            Some(gid),
                            EventKind::Complete {
                                app,
                                wait_ns,
                                service_ns,
                                slack_ns,
                                offloaded,
                            },
                        );
                        self.sink.observe_latency(wait_ns, service_ns, slack_ns);
                    }
                    if let Some(est) = &mut self.est {
                        // Land the measurement stashed at placement;
                        // faults drop the stash, so only clean,
                        // full-service completions train the tables.
                        if let Some(obs) = est.pending.remove(&job) {
                            est.state.observe(&obs);
                        }
                    }
                    dispatch(
                        &self.params,
                        self.mode,
                        now,
                        time_ns,
                        &mut self.fleet,
                        &mut self.queue,
                        &mut self.planner,
                        &mut self.engine,
                        &mut self.power,
                        &mut self.power_starved,
                        &mut self.deadline_tokens,
                        &mut self.scratch,
                        &mut self.sink,
                        &self.metas,
                        &self.qid_to_lid,
                        &self.retry,
                        self.est.as_deref_mut(),
                    );
                }
            }
            Ev::ReconfigDone(gpu) => {
                if !self.reconfig_poisoned.is_empty() && self.reconfig_poisoned[gpu] {
                    // A transient driver fault poisoned this repartition:
                    // the latency was paid but the pending layout never
                    // lands — the old layout returns to service and the
                    // planner re-plans on the next dispatch if the need
                    // persists.
                    self.reconfig_poisoned[gpu] = false;
                    self.fleet.abort_reconfig(gpu);
                } else {
                    self.fleet.finish_reconfig(gpu);
                }
                self.power.on_reconfig_done(gpu, self.fleet.gpus[gpu].slots.len());
                dispatch(
                    &self.params,
                    self.mode,
                    now,
                    time_ns,
                    &mut self.fleet,
                    &mut self.queue,
                    &mut self.planner,
                    &mut self.engine,
                    &mut self.power,
                    &mut self.power_starved,
                    &mut self.deadline_tokens,
                    &mut self.scratch,
                    &mut self.sink,
                    &self.metas,
                    &self.qid_to_lid,
                    &self.retry,
                    self.est.as_deref_mut(),
                );
            }
            Ev::Fault { gpu, gen } => {
                // A domain cordon supersedes a pending per-GPU draw: the
                // stale event (an older generation) drops silently, and a
                // fresh draw is scheduled when the board recovers.
                if self.fault_gen[gpu] == gen {
                    self.on_fault(time_ns, now, gpu);
                }
            }
            Ev::Recover(g) => self.on_recover(time_ns, now, g),
            Ev::DomainFault(d) => self.on_domain_fault(time_ns, now, d),
        }
        if S::ENABLED && self.power.plane_active() {
            self.emit_throttle_changes(time_ns);
        }
    }

    /// Emit a `Throttle` trace event for every GPU whose governed level
    /// moved during this event. Levels are a pure function of the
    /// resident set, so the stream is identical across serve modes and
    /// thread counts (GPU ids are reported fleet-global).
    fn emit_throttle_changes(&mut self, time_ns: u64) {
        self.power.refresh(&self.fleet);
        for g in 0..self.last_levels.len() {
            let lv = self.power.level(g);
            if lv != self.last_levels[g] {
                self.sink.emit(
                    time_ns,
                    None,
                    EventKind::Throttle {
                        gpu: self.gpu_base + g as u32,
                        from: self.last_levels[g],
                        to: lv,
                    },
                );
                self.last_levels[g] = lv;
            }
        }
    }

    /// Whether serving work remains (arrivals still to fire, unresolved
    /// jobs, or the cross-node stream still open). The fault plane winds
    /// down when this goes false: no further failure or next-fault event
    /// is scheduled, so the engine drains and `run_until(None)`
    /// terminates.
    fn work_remains(&self) -> bool {
        let resolved = match self.mode {
            ServeMode::Indexed => self.queue.all_resolved(),
            ServeMode::NaiveOracle => self.queue.all_resolved_scan(),
        };
        self.queue.jobs.len() < self.expected as usize || !resolved || self.stream_open
    }

    /// The fault plane's next failure lands on local GPU `g`.
    fn on_fault(&mut self, time_ns: u64, now: f64, g: usize) {
        if !self.work_remains() {
            return; // plane winds down with the run
        }
        debug_assert!(
            !self.fleet.gpus[g].cordoned(),
            "a cordoned GPU draws no faults until it recovers"
        );
        let global_gpu = self.gpu_base + g as u32;
        match self.params.faults.draw_kind(&mut self.fault_rngs[g]) {
            FaultKind::Gpu => {
                self.faults_injected += 1;
                if S::ENABLED {
                    self.sink.emit(
                        time_ns,
                        None,
                        EventKind::Fault {
                            gpu: global_gpu,
                            kind: FaultKind::Gpu,
                            slot: None,
                        },
                    );
                }
                let orphans = self.fleet.cordon_gpu(g, now);
                if S::ENABLED {
                    self.sink
                        .emit(time_ns, None, EventKind::Cordon { gpu: global_gpu });
                }
                self.reap_orphans(time_ns, now, g, &orphans);
                let ttr = self.params.faults.draw_ttr(&mut self.fault_rngs[g]);
                self.enqueue_repair(time_ns, g, ttr);
                self.shed_check(time_ns, now);
            }
            FaultKind::Slice => {
                self.faults_injected += 1;
                let nslots = self.fleet.gpus[g].slots.len();
                if nslots == 0 {
                    // Mid-repartition there may be no slices to hit; the
                    // ECC error lands on a GPU with nothing to kill.
                    if S::ENABLED {
                        self.sink.emit(
                            time_ns,
                            None,
                            EventKind::Fault {
                                gpu: global_gpu,
                                kind: FaultKind::Slice,
                                slot: None,
                            },
                        );
                    }
                } else {
                    let slot = self.fault_rngs[g].below(nslots as u64) as usize;
                    if S::ENABLED {
                        self.sink.emit(
                            time_ns,
                            None,
                            EventKind::Fault {
                                gpu: global_gpu,
                                kind: FaultKind::Slice,
                                slot: Some(slot as u32),
                            },
                        );
                    }
                    let orphans = self.fleet.drain_slot(g, slot, now);
                    self.reap_orphans(time_ns, now, g, &orphans);
                }
                self.schedule_next_fault(time_ns, g);
            }
            FaultKind::Reconfig => {
                // The transient hazard only bites a driver operation in
                // flight: the repartition aborts and must be redone.
                if self.fleet.gpus[g].reconfiguring() {
                    self.faults_injected += 1;
                    self.reconfig_poisoned[g] = true;
                    if S::ENABLED {
                        self.sink.emit(
                            time_ns,
                            None,
                            EventKind::Fault {
                                gpu: global_gpu,
                                kind: FaultKind::Reconfig,
                                slot: None,
                            },
                        );
                    }
                }
                self.schedule_next_fault(time_ns, g);
            }
        }
    }

    /// A correlated domain event fires: cordon every in-service member
    /// GPU this shard owns. The draw order is fixed — every member's
    /// repair time in global id order, then the gap to the next domain
    /// event — so straddling shards' copies of the stream stay in
    /// lockstep whatever slice of the domain each holds; only the owner
    /// shard counts and reports the event.
    fn on_domain_fault(&mut self, time_ns: u64, now: f64, d: usize) {
        if !self.work_remains() {
            return; // plane winds down with the run
        }
        let width = self.domains[d].width as usize;
        let mut ttrs = Vec::with_capacity(width);
        for _ in 0..width {
            let ttr = self.params.faults.draw_ttr(&mut self.domains[d].rng);
            ttrs.push(ttr);
        }
        let ttf = self.params.faults.draw_ttf(&mut self.domains[d].rng);
        if self.domains[d].owner {
            self.domain_faults += 1;
            if S::ENABLED {
                self.sink.emit(
                    time_ns,
                    None,
                    EventKind::DomainFault {
                        domain: self.domains[d].id,
                        members: self.domains[d].width,
                    },
                );
            }
        }
        let start = self.domains[d].start;
        let members = self.domains[d].local.clone();
        for g in members {
            if self.fleet.gpus[g].cordoned() {
                // Already down (an earlier per-GPU or domain fault): the
                // in-flight repair stands — no second cordon, and the
                // board's drawn repair time goes unused.
                continue;
            }
            // The domain cordon supersedes any pending per-GPU draw.
            self.fault_gen[g] += 1;
            let global_gpu = self.gpu_base + g as u32;
            let orphans = self.fleet.cordon_gpu(g, now);
            if S::ENABLED {
                self.sink
                    .emit(time_ns, None, EventKind::Cordon { gpu: global_gpu });
            }
            self.reap_orphans(time_ns, now, g, &orphans);
            let ttr = ttrs[(global_gpu - start) as usize];
            self.enqueue_repair(time_ns, g, ttr);
        }
        self.shed_check(time_ns, now);
        self.engine.schedule_at(
            time_ns.saturating_add(sec_to_ns(ttf).max(1)),
            Ev::DomainFault(d),
        );
    }

    /// Schedule a cordoned GPU's repair. With unlimited crews (the
    /// default, `repair_crews == 0`) repair starts immediately —
    /// bit-identical to the pre-crew plane. With `N >= 1` crews per
    /// node shard, repair is a FIFO-queued service: the drawn MTTR
    /// becomes service time, paid only once a crew picks the board up.
    fn enqueue_repair(&mut self, time_ns: u64, g: usize, ttr_s: f64) {
        let crews = self.params.faults.repair_crews;
        if crews == 0 {
            self.engine.schedule_at(
                time_ns.saturating_add(sec_to_ns(ttr_s).max(1)),
                Ev::Recover(g),
            );
            return;
        }
        if self.crews_busy < crews {
            self.crews_busy += 1;
            if S::ENABLED {
                self.sink.emit(
                    time_ns,
                    None,
                    EventKind::RepairStart {
                        gpu: self.gpu_base + g as u32,
                    },
                );
            }
            self.engine.schedule_at(
                time_ns.saturating_add(sec_to_ns(ttr_s).max(1)),
                Ev::Recover(g),
            );
        } else {
            if S::ENABLED {
                self.sink.emit(
                    time_ns,
                    None,
                    EventKind::RepairQueued {
                        gpu: self.gpu_base + g as u32,
                    },
                );
            }
            self.repair_queue.push_back((g, ttr_s));
        }
    }

    /// Brown-out backpressure: when a capacity-loss event leaves fewer
    /// than the watermark fraction of this node's boards in service,
    /// trim the pending queue proportionally to the surviving fraction,
    /// shedding lowest-slack (earliest-deadline) jobs first. Purely
    /// node-local and deterministic (ties break on queue id).
    fn shed_check(&mut self, time_ns: u64, now: f64) {
        let ShedPolicy::Watermark(watermark) = self.params.faults.shed else {
            return;
        };
        let total = self.fleet.gpus.len();
        let up = self.fleet.gpus.iter().filter(|g| !g.cordoned()).count();
        let frac = up as f64 / total as f64;
        if frac >= watermark {
            return;
        }
        let mut victims: Vec<(f64, u32)> = self
            .queue
            .pending_ids()
            .map(|qid| (self.queue.jobs[qid as usize].deadline_s, qid))
            .collect();
        let keep = (victims.len() as f64 * frac).floor() as usize;
        let drop = victims.len() - keep;
        if drop == 0 {
            return;
        }
        victims.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        victims.truncate(drop);
        for (_, qid) in victims {
            if let Some(tok) = self.deadline_tokens[qid as usize].take() {
                self.engine.cancel(tok);
            }
            let lid = self.qid_to_lid[qid as usize];
            let gid = self.metas[lid as usize].global_id;
            let app = self.queue.jobs[qid as usize].job.app;
            self.queue
                .mark_shed(qid, now)
                .expect("shedding only visits pending ids");
            if !self.retry.is_empty() {
                // A shed retry is terminal: its checkpoint dies with it.
                self.retry.remove(&gid);
            }
            self.shed_count += 1;
            if S::ENABLED {
                self.sink.emit(time_ns, Some(gid), EventKind::Shed { app });
            }
        }
    }

    /// A hard-failed GPU finished repair: it rejoins every placement
    /// surface (the epoch bump invalidates the dispatch memo, so pending
    /// jobs immediately retry against the returned capacity).
    fn on_recover(&mut self, time_ns: u64, now: f64, g: usize) {
        self.fleet.uncordon_gpu(g);
        if S::ENABLED {
            self.sink.emit(
                time_ns,
                None,
                EventKind::Recover {
                    gpu: self.gpu_base + g as u32,
                },
            );
        }
        if self.params.faults.repair_crews > 0 {
            // The crew that finished here picks up the next queued board
            // — even when the run is winding down, so every cordoned GPU
            // is eventually repaired and the engine still drains.
            self.crews_busy -= 1;
            if let Some((next, ttr_s)) = self.repair_queue.pop_front() {
                self.crews_busy += 1;
                if S::ENABLED {
                    self.sink.emit(
                        time_ns,
                        None,
                        EventKind::RepairStart {
                            gpu: self.gpu_base + next as u32,
                        },
                    );
                }
                self.engine.schedule_at(
                    time_ns.saturating_add(sec_to_ns(ttr_s).max(1)),
                    Ev::Recover(next),
                );
            }
        }
        if self.work_remains() {
            self.schedule_next_fault(time_ns, g);
        }
        dispatch(
            &self.params,
            self.mode,
            now,
            time_ns,
            &mut self.fleet,
            &mut self.queue,
            &mut self.planner,
            &mut self.engine,
            &mut self.power,
            &mut self.power_starved,
            &mut self.deadline_tokens,
            &mut self.scratch,
            &mut self.sink,
            &self.metas,
            &self.qid_to_lid,
            &self.retry,
            self.est.as_deref_mut(),
        );
    }

    fn schedule_next_fault(&mut self, time_ns: u64, g: usize) {
        let ttf = self.params.faults.draw_ttf(&mut self.fault_rngs[g]);
        self.engine.schedule_at(
            time_ns.saturating_add(sec_to_ns(ttf).max(1)),
            Ev::Fault { gpu: g, gen: self.fault_gen[g] },
        );
    }

    /// Resolve every job a fault just killed: requeue it as a bounded
    /// retry (fresh scheduling id, original arrival time and absolute
    /// deadline, checkpoint-preserved progress) or fail it terminally
    /// when the budget is spent. Orphans arrive in (slot, admission)
    /// order from the fleet, so the re-admission order is deterministic.
    fn reap_orphans(&mut self, time_ns: u64, now: f64, g: usize, orphans: &[Orphan]) {
        for o in orphans {
            self.power.on_finish(g, o.slot, o.job);
            if let Some(est) = &mut self.est {
                // A killed attempt never trains the estimator: the
                // measurement stashed at placement is discarded.
                est.pending.remove(&o.job);
            }
            let lid = self.qid_to_lid[o.job as usize];
            let gid = self.metas[lid as usize].global_id;
            let qj = &self.queue.jobs[o.job as usize];
            let (app, arrival_s, deadline_abs_s, was_handoff) =
                (qj.job.app, qj.job.arrival_s, qj.deadline_s, qj.handoff);
            // Fold this attempt's checkpointed progress into the job's
            // preserved fraction: the attempt served the remaining
            // `1 - f` of the job in `until - started` seconds, so
            // `preserved_s / attempt_s` of that remainder survives.
            let entry = self.retry.entry(gid).or_insert(RetryState {
                attempts: 0,
                preserved: 0.0,
                restore_s: 0.0,
            });
            let attempt_s = o.until_s - o.started_s;
            if attempt_s > 0.0 {
                let kept = self.params.faults.preserved_s(now - o.started_s).min(attempt_s);
                entry.preserved += kept / attempt_s * (1.0 - entry.preserved);
            }
            entry.attempts += 1;
            // Whatever survived now lives on this shard: a placement here
            // restores locally at no transfer cost (a later cross-shard
            // handoff re-prices the move).
            entry.restore_s = 0.0;
            let attempt = entry.attempts;
            if attempt <= self.params.faults.retries {
                self.queue
                    .mark_retrying(o.job)
                    .expect("a fault orphan is always a running job");
                self.retries_done += 1;
                if S::ENABLED {
                    self.sink
                        .emit(time_ns, Some(gid), EventKind::Retry { app, attempt });
                }
                let new_lid = self.jobs.len() as u32;
                self.jobs.push(Job {
                    id: new_lid,
                    app,
                    arrival_s,
                });
                self.metas.push(JobMeta {
                    global_id: gid,
                    handoff_deadline_s: None,
                    retry: Some(RetryMeta {
                        deadline_abs_s,
                        handoff: was_handoff,
                    }),
                });
                self.engine.schedule_at(time_ns, Ev::Arrival(new_lid));
                self.expected += 1;
            } else {
                self.retry.remove(&gid);
                self.queue
                    .mark_failed(o.job, now)
                    .expect("a fault orphan is always a running job");
                if S::ENABLED {
                    self.sink.emit(time_ns, Some(gid), EventKind::Fail { app });
                }
            }
        }
    }

    /// Emit every pending sample boundary strictly before the event now
    /// being processed. A boundary at exactly the event instant waits
    /// for the next event, so a sample at `t` reflects every event at or
    /// before `t`. State is constant between events, so the cached fleet
    /// power is read once per flush and serves every boundary the
    /// current gap crosses.
    fn flush_samples(&mut self, now_ns: u64) {
        if !self.sink.sample_due(now_ns) {
            return;
        }
        let power_w = self.power.power_w(&self.fleet);
        let mut clocks = Vec::new();
        if self.power.plane_active() {
            self.power.clocks_into(&self.fleet, &mut clocks);
        }
        while self.sink.sample_due(now_ns) {
            let t_ns = self.sink.next_sample_ns();
            self.sink.push_sample(FleetSample::capture(
                t_ns,
                self.id as u32,
                &self.fleet,
                &self.queue,
                power_w,
                clocks.clone(),
            ));
        }
    }

    /// Apply one epoch's inputs, run it, and report the barrier state.
    fn run_epoch(&mut self, input: EpochInput) -> BarrierInfo {
        // Converge the learned tables before any of this epoch's events:
        // every shard starts the epoch on the identical fleet table, so
        // the merged outcome is invariant to the worker mapping.
        if let Some(d) = &input.est_others {
            if let Some(est) = &mut self.est {
                est.state.apply_delta(d);
            }
        }
        for &(qid, dest, reason) in &input.removals {
            self.remove_for_handoff(input.start_ns, qid, dest, reason);
        }
        let start_s = ns_to_sec(input.start_ns);
        for h in input.handoffs {
            self.push_handoff(h, start_s);
        }
        for job in input.arrivals {
            self.push_arrival(job);
        }
        self.stream_open = input.stream_open;
        self.run_until(Some(input.end_ns));
        self.barrier_info(ns_to_sec(input.end_ns))
    }

    /// The dispatcher's placement-compatibility requirements for `app`
    /// under this run's policy: `(min_host_gib, min_direct_gib,
    /// direct_need_gib, host_need_bytes)` — see the `Handoff` fields.
    /// Memoized inside the planner's cost cache, so this is an O(classes)
    /// table walk after the first call per app.
    fn handoff_reqs(&mut self, app: AppId) -> (f64, f64, f64, u64) {
        let allow = self.params.policy.allows_offload();
        // Unservable apps are rejected at arrival and never pend, so the
        // infinite fallbacks below are never actually consulted.
        let mut min_host = f64::INFINITY;
        let mut host_need = 0u64;
        for pid in crate::mig::profile::ALL_PROFILES {
            if let Some(c) = self.planner.cost(app, pid, allow) {
                min_host = GiProfile::get(pid).mem_gib;
                host_need = super::hostmem::gib_to_bytes(c.host_gib);
                break;
            }
        }
        let mut min_direct = f64::INFINITY;
        for pid in crate::mig::profile::ALL_PROFILES {
            if self.planner.cost(app, pid, false).is_some() {
                min_direct = GiProfile::get(pid).mem_gib;
                break;
            }
        }
        let direct_need = self.planner.footprint_gib(app) + self.planner.ctx_gib();
        (min_host, min_direct, direct_need, host_need)
    }

    /// Barrier snapshot at time `barrier_s` (the end of the epoch that
    /// just ran). Handoff candidates: pending jobs that sat through at
    /// least one full epoch without placing, have not hopped before, and
    /// still have deadline slack beyond the barrier.
    fn barrier_info(&mut self, barrier_s: f64) -> BarrierInfo {
        let mut candidates = Vec::new();
        if self.forward {
            let pending: Vec<u32> = self.queue.pending_ids().collect();
            for qid in pending {
                let qj = &self.queue.jobs[qid as usize];
                let lid = self.qid_to_lid[qid as usize];
                let meta = &self.metas[lid as usize];
                if meta.handoff_deadline_s.is_some() || qj.handoff {
                    continue; // at most one hop per job
                }
                if qj.job.arrival_s > barrier_s - self.lookahead_s {
                    continue; // has not waited a full epoch yet
                }
                if qj.deadline_s <= barrier_s {
                    continue; // would abandon before the handoff lands
                }
                let (global_id, app, arrival_s, deadline_abs_s) =
                    (meta.global_id, qj.job.app, qj.job.arrival_s, qj.deadline_s);
                let (min_host_gib, min_direct_gib, direct_need_gib, host_need_bytes) =
                    self.handoff_reqs(app);
                // A pending fault-plane retry is no longer pinned home:
                // its checkpoint state ships with the handoff, and the
                // destination pays the restore transfer.
                let retry = self.retry.get(&global_id).map(|r| HandoffRetry {
                    attempts: r.attempts,
                    preserved: r.preserved,
                });
                candidates.push(Handoff {
                    global_id,
                    origin: self.id,
                    origin_local: qid,
                    app,
                    arrival_s,
                    deadline_abs_s,
                    min_host_gib,
                    min_direct_gib,
                    direct_need_gib,
                    host_need_bytes,
                    retry,
                });
            }
        }
        BarrierInfo {
            shard: self.id,
            pending: self.queue.pending_len() as u32,
            unresolved: self.queue.unresolved(),
            arrivals_pending: self.expected - self.queue.jobs.len() as u32,
            open_sm_seats: self.fleet.open_sm_seats(),
            largest_empty_gib: self.fleet.largest_idle_slot_gib(),
            max_open_headroom_gib: self.fleet.max_open_headroom_gib(),
            host_headroom_bytes: self.fleet.host_headroom_bytes(),
            candidates,
            telemetry: self.sink.take_chunk().map(Box::new),
            est_delta: self.est.as_mut().and_then(|e| e.state.take_delta()),
        }
    }

    fn summary(&self) -> ShardSummary {
        ShardSummary {
            shard: self.id,
            gpus: self.fleet.gpus.len() as u32,
            completed: self.queue.count(JobState::Completed),
            expired: self.queue.count(JobState::Expired),
            rejected: self.queue.count(JobState::Rejected),
            handoffs_in: self.handoffs_in,
            handoffs_out: self.handoffs_out,
            events: self.engine.popped(),
        }
    }
}

/// Run the whole trace through one shard — the single-loop serve. This is
/// the code path `cluster::serve` has always exposed, and the oracle the
/// sharded runner is differentially tested against.
pub(crate) fn run_single(
    cfg: &ServeConfig,
    mode: ServeMode,
    jobs: &[Job],
) -> crate::Result<ServeReport> {
    Ok(run_single_impl(cfg, mode, jobs, NullSink)?.0)
}

/// `run_single` with the telemetry plane on: the same simulation (the
/// `ServeReport` is byte-identical to the untraced run) plus the merged
/// trace/samples/histograms.
pub(crate) fn run_single_traced(
    cfg: &ServeConfig,
    mode: ServeMode,
    jobs: &[Job],
    tcfg: &TelemetryConfig,
) -> crate::Result<(ServeReport, TelemetryReport)> {
    tcfg.validate()?;
    let (report, tel) = run_single_impl(cfg, mode, jobs, Recorder::new(0, tcfg))?;
    Ok((report, tel.expect("recorder sink always yields telemetry")))
}

fn run_single_impl<S: Sink>(
    cfg: &ServeConfig,
    mode: ServeMode,
    jobs: &[Job],
    sink: S,
) -> crate::Result<(ServeReport, Option<TelemetryReport>)> {
    let mut shard = Shard::new(0, cfg.gpus, cfg, mode, 0.0, false, sink)?;
    shard.arm_faults(0);
    for job in jobs {
        shard.push_arrival(job.clone());
    }
    shard.run_until(None);
    let report = merge_report(cfg, std::slice::from_ref(&shard));
    let tel = if S::ENABLED {
        let mut t = TelemetryReport::new();
        if let Some(chunk) = shard.sink.take_chunk() {
            t.absorb(chunk);
        }
        t.finalize();
        Some(t)
    } else {
        None
    };
    Ok((report, tel))
}

/// Merge per-shard outcomes into one fleet-level `ServeReport`. Shards are
/// visited in id order, so the result is independent of the thread count;
/// for a single shard every expression reduces to the single-loop form
/// bit-for-bit.
fn merge_report<S: Sink>(cfg: &ServeConfig, shards: &[Shard<S>]) -> ServeReport {
    for s in shards {
        debug_assert!(s.queue.all_resolved(), "events drained with unresolved jobs");
        debug_assert!(s.queue.all_resolved_scan(), "resolution counter diverged");
    }
    let horizon = shards
        .iter()
        .map(|s| s.queue.horizon_s())
        .fold(0.0f64, f64::max)
        .max(1e-9);
    let mut waits: Vec<f64> = Vec::new();
    for s in shards {
        waits.extend(s.queue.completed_waits());
    }
    let pct = |p: f64| {
        if waits.is_empty() {
            0.0
        } else {
            percentile(&waits, p)
        }
    };
    let mut wacc = Accum::new();
    waits.iter().for_each(|&w| wacc.push(w));
    let count = |st: JobState| shards.iter().map(|s| s.queue.count(st)).sum::<u32>();
    let completed = count(JobState::Completed);
    let offloaded = shards
        .iter()
        .map(|s| {
            s.queue
                .jobs
                .iter()
                .filter(|j| j.state == JobState::Completed && j.offloaded)
                .count() as u32
        })
        .sum();
    let total_sms: u32 = shards.iter().map(|s| s.fleet.total_sms()).sum();
    let busy_integral: f64 = shards.iter().map(|s| s.busy_sm_integral).sum();
    // Fleet fragmentation is the SM-weighted mean of the per-shard
    // time-averaged fractions; with one shard this is exactly the
    // single-loop `frag_integral / horizon`.
    let fragmentation = if shards.len() == 1 {
        shards[0].frag_integral / horizon
    } else {
        shards
            .iter()
            .map(|s| s.frag_integral * s.fleet.total_sms() as f64)
            .sum::<f64>()
            / (total_sms as f64 * horizon)
    };
    let mut estimator = EstimatorStats::default();
    for s in shards {
        if let Some(e) = &s.est {
            estimator.absorb(&e.stats);
        }
    }
    ServeReport {
        policy: cfg.policy.label(),
        layout: cfg.layout.label().to_string(),
        gpus: cfg.gpus,
        jobs: cfg.jobs,
        arrival_rate_hz: cfg.arrival_rate_hz,
        completed,
        expired: count(JobState::Expired),
        rejected: count(JobState::Rejected),
        failed: count(JobState::Failed),
        shed: count(JobState::Shed),
        offloaded,
        faults: shards.iter().map(|s| s.faults_injected).sum(),
        domain_faults: shards.iter().map(|s| s.domain_faults).sum(),
        retries: shards.iter().map(|s| s.retries_done).sum(),
        faults_active: cfg.faults.active(),
        degrade_active: cfg.faults.degraded(),
        power_active: cfg.power.active(),
        power_cap_w: cfg.power.gpu_cap_w,
        node_power_cap_w: cfg.power.node_cap_w,
        throttled_gpu_s: shards.iter().map(|s| s.throttled_gpu_s).sum(),
        parked_gpu_s: shards.iter().map(|s| s.parked_gpu_s).sum(),
        power_starved: shards.iter().map(|s| s.power_starved).sum(),
        estimator_active: cfg.estimator.active(),
        estimator,
        reconfigs: shards
            .iter()
            .map(|s| s.fleet.gpus.iter().map(|g| g.reconfigs).sum::<u32>())
            .sum(),
        events: shards.iter().map(|s| s.engine.popped()).sum(),
        makespan_s: horizon,
        throughput_jobs_s: completed as f64 / horizon,
        wait_mean_s: wacc.mean(),
        wait_p50_s: pct(50.0),
        wait_p95_s: pct(95.0),
        wait_p99_s: pct(99.0),
        utilization: busy_integral / (total_sms as f64 * horizon),
        fragmentation,
        energy_j: shards.iter().map(|s| s.energy_j).sum(),
    }
}

/// Try to place every pending job (FIFO with backfilling: a blocked head
/// does not starve smaller jobs behind it). When a job fits no layout the
/// fleet currently has — or is already reconfiguring toward — and
/// reconfiguration is enabled, repartition one drained GPU toward the
/// job's profile class.
#[allow(clippy::too_many_arguments)]
fn dispatch<S: Sink>(
    cfg: &ServeConfig,
    mode: ServeMode,
    now: f64,
    now_ns: u64,
    fleet: &mut Fleet,
    queue: &mut AdmissionQueue,
    planner: &mut Planner,
    engine: &mut Engine<Ev>,
    power: &mut PowerTracker,
    power_starved: &mut u64,
    deadline_tokens: &mut [Option<EventToken>],
    scratch: &mut DispatchScratch,
    sink: &mut S,
    metas: &[JobMeta],
    qid_to_lid: &[u32],
    retry: &BTreeMap<u32, RetryState>,
    mut est: Option<&mut EstPlane>,
) {
    let DispatchScratch {
        ids,
        failed_at_epoch,
    } = scratch;
    ids.clear();
    ids.extend(queue.pending_ids());
    for &id in ids.iter() {
        let app = queue.jobs[id as usize].job.app;
        // Which cost tables rank this decision: the oracle (plane off,
        // bit-for-bit the pre-plane planner) or the learned estimator.
        let src = match est.as_deref() {
            Some(e) => CostSource::Estimated(&e.state),
            None => CostSource::Oracle,
        };
        let placed = match mode {
            ServeMode::Indexed => {
                if failed_at_epoch[app.index()] == Some(fleet.epoch()) {
                    // Provably still fails: no capacity came back since
                    // the last failed attempt for this app.
                    if S::ENABLED {
                        sink.count(Counter::PlaceDecisions, 1);
                        sink.count(Counter::MemoHits, 1);
                    }
                    None
                } else {
                    if S::ENABLED {
                        sink.count(Counter::PlaceDecisions, 1);
                        sink.count(Counter::MemoMisses, 1);
                    }
                    let r = if power.plane_active() {
                        power.refresh(fleet);
                        let pv = power.view();
                        planner.place_sourced_traced(
                            fleet,
                            app,
                            cfg.policy,
                            pv.as_ref(),
                            src,
                            sink,
                        )
                    } else {
                        planner.place_sourced_traced(fleet, app, cfg.policy, None, src, sink)
                    };
                    if r.is_none() {
                        failed_at_epoch[app.index()] = Some(fleet.epoch());
                    }
                    r
                }
            }
            ServeMode::NaiveOracle => {
                if S::ENABLED {
                    sink.count(Counter::PlaceDecisions, 1);
                }
                if power.plane_active() {
                    power.refresh(fleet);
                    let pv = power.view();
                    planner.place_scan_sourced_traced(
                        fleet,
                        app,
                        cfg.policy,
                        pv.as_ref(),
                        src,
                        sink,
                    )
                } else {
                    planner.place_scan_sourced_traced(fleet, app, cfg.policy, None, src, sink)
                }
            }
        };
        if let Some(p) = placed {
            let (g, s) = (p.gpu, p.slot);
            // `base` carries the level-0 (boost) bits the power tracker and
            // memory planes account in; `priced` is the same placement at
            // the prospective throttle level and is what the job's service
            // time is scheduled from. At level 0 the two are identical.
            let c = p.priced;
            queue
                .mark_running(id, now, g, p.base.offloaded)
                .expect("dispatch only visits pending ids");
            if let Some(tok) = deadline_tokens[id as usize].take() {
                engine.cancel(tok);
            }
            // `c` is the cost at the occupancy — and, under the
            // host-memory plane, the C2C link share — the job joins the
            // slot at; residents already running keep their
            // admission-time runtime (the deterministic static-slowdown
            // model: a later offloader joining the link does not re-fit
            // those already streaming over it — see ROADMAP follow-ups).
            // A retry restores from its last checkpoint: the preserved
            // fraction of the job is already done, so only the remainder
            // is served — plus the restore transfer when the checkpoint
            // shipped cross-shard (the branch keeps inert-path runtimes
            // bit-identical — no float arithmetic sneaks in).
            let (frac, restore_s) = retry
                .get(&metas[qid_to_lid[id as usize] as usize].global_id)
                .map_or((0.0, 0.0), |r| (r.preserved, r.restore_s));
            let runtime_s = if frac > 0.0 {
                c.runtime_s * (1.0 - frac) + restore_s
            } else {
                c.runtime_s
            };
            if let Some(est) = est.as_deref_mut() {
                // Measured regret of this decision: the model's belief
                // about the chosen class vs the retained oracle's level-0
                // truth. Logged per decision whatever the policy — the
                // structural policies ignore the estimate when ranking,
                // so their regret traces the model's accuracy alone.
                let oracle_ns = sec_to_ns(p.base.runtime_s);
                let est_ns =
                    est.state.predict_ns(app, p.pid, p.occ, p.share, p.base.offloaded);
                let regret_ns = est_ns.abs_diff(oracle_ns);
                est.stats.record(app, regret_ns);
                if S::ENABLED {
                    let gid = metas[qid_to_lid[id as usize] as usize].global_id;
                    sink.emit(
                        now_ns,
                        Some(gid),
                        EventKind::Regret { app, est_ns, oracle_ns },
                    );
                    sink.observe_regret(regret_ns);
                }
                // Stash the measurement for `JobDone`: only clean runs
                // (boost clocks, no checkpoint-restored remainder) are
                // level-0 truth — anything else would poison the cells.
                if p.level == 0 && frac == 0.0 {
                    est.pending.insert(
                        id,
                        PendingObs {
                            app,
                            pid: p.pid,
                            occ: p.occ,
                            share: p.share,
                            offloaded: p.base.offloaded,
                            ns: oracle_ns,
                            probe: queue.jobs[id as usize].probe,
                        },
                    );
                }
            }
            let until = now + runtime_s;
            fleet.start_job(
                g,
                s,
                id,
                now,
                until,
                p.base.resident_gib + planner.ctx_gib(),
                super::hostmem::gib_to_bytes(p.base.host_gib),
            );
            power.on_start(g, s, id, p.base);
            engine.schedule_at(sec_to_ns(until), Ev::JobDone { gpu: g, slot: s, job: id });
            if S::ENABLED {
                let gid = metas[qid_to_lid[id as usize] as usize].global_id;
                let sl = &fleet.gpus[g].slots[s];
                // Co-offloaders sharing the GPU's one C2C link, this job
                // included; a direct placement never touches the link.
                let share = if c.offloaded { fleet.gpus[g].offloaders() } else { 1 };
                sink.emit(
                    now_ns,
                    Some(gid),
                    EventKind::Place {
                        app,
                        gpu: g as u32,
                        slot: s as u32,
                        class: sl.profile.name,
                        occupancy: sl.occupancy() as u32,
                        offloaded: c.offloaded,
                        share,
                        runtime_ns: sec_to_ns(runtime_s),
                    },
                );
            }
        } else {
            // Unified node-budget starvation predicate: even the app's
            // cheapest admissible placement exceeds the remaining node
            // power headroom. Mode-invariant — integer-milliwatt compare
            // over mode-independent planner costs — and it also gates
            // reconfiguration below: repartitioning cannot create power.
            let power_blocked = power.plane_active()
                && power.node_cap_finite()
                && reconfig::power_gates_reconfig(
                    power.node_headroom_mw(),
                    planner.min_job_draw_mw(app, cfg.policy.allows_offload()),
                );
            if power_blocked {
                *power_starved += 1;
            }
            if S::ENABLED
                && cfg.policy.allows_offload()
                && planner.offload_pool_starved(fleet, app)
            {
                let gid = metas[qid_to_lid[id as usize] as usize].global_id;
                sink.emit(now_ns, Some(gid), EventKind::OffloadDenied { app });
            }
            if cfg.reconfig && !power_blocked {
                let fits = match mode {
                    ServeMode::Indexed => {
                        planner.fits_current_layouts(fleet, app, cfg.policy.allows_offload())
                    }
                    ServeMode::NaiveOracle => {
                        planner.fits_current_layouts_scan(fleet, app, cfg.policy.allows_offload())
                    }
                };
                if !fits {
                    // Memoized footprint: same constant either mode would
                    // compute, without rebuilding the app model per attempt.
                    let need = planner.footprint_gib(app) + planner.ctx_gib();
                    let plan = match mode {
                        ServeMode::Indexed => reconfig::plan_reconfig(fleet, need),
                        ServeMode::NaiveOracle => reconfig::plan_reconfig_scan(fleet, need),
                    };
                    if let Some((g, target)) = plan {
                        let until = now + reconfig::latency_s(&fleet.gpus[g].layout, &target);
                        let labels = if S::ENABLED {
                            Some((
                                reconfig::layout_label(&fleet.gpus[g].layout),
                                reconfig::layout_label(&target),
                            ))
                        } else {
                            None
                        };
                        if fleet.begin_reconfig(g, target, until).is_ok() {
                            engine.schedule_at(sec_to_ns(until), Ev::ReconfigDone(g));
                            if let Some((from, to)) = labels {
                                sink.emit(
                                    now_ns,
                                    None,
                                    EventKind::Reconfig {
                                        gpu: g as u32,
                                        from,
                                        to,
                                        trigger: app,
                                    },
                                );
                            }
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The sharded runner: coordinator, worker pool, public config/report types.
// ---------------------------------------------------------------------------

/// How the cross-node dispatcher routes fresh arrivals to shards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouteKind {
    /// Static `global id % nodes` — every arrival is pre-scheduled
    /// upfront, exactly like the single-loop serve.
    RoundRobin,
    /// Each epoch's arrival window goes to the shard with the fewest
    /// pending-or-undelivered jobs as of the previous barrier (ties break
    /// toward the lower shard id).
    LeastLoaded,
}

impl RouteKind {
    pub fn parse(s: &str) -> Option<RouteKind> {
        match s {
            "round-robin" => Some(RouteKind::RoundRobin),
            "least-loaded" => Some(RouteKind::LeastLoaded),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RouteKind::RoundRobin => "round-robin",
            RouteKind::LeastLoaded => "least-loaded",
        }
    }
}

/// Configuration of a sharded multi-node serving run.
#[derive(Debug, Clone)]
pub struct ShardServeConfig {
    /// The fleet-level serving parameters; `base.gpus` is the total GPU
    /// count, split as evenly as possible across the node shards.
    pub base: ServeConfig,
    /// Node shards (each gets its own fleet partition and event loop).
    pub nodes: u32,
    /// Worker threads; shards map to workers round-robin. The report is
    /// bit-identical for every value, including 1 (inline execution).
    pub threads: u32,
    /// Epoch length = modeled cross-node dispatch latency (s).
    pub lookahead_s: f64,
    pub route: RouteKind,
    /// Enable overflow handoffs between shards at epoch barriers.
    pub forward: bool,
}

impl ShardServeConfig {
    /// Canonical defaults for a given base config: epoch length an eighth
    /// of the queueing deadline (a handoff costs well under the patience
    /// budget), round-robin routing, forwarding on.
    pub fn new(base: ServeConfig, nodes: u32, threads: u32) -> ShardServeConfig {
        let lookahead_s = (base.deadline_s / 8.0).max(1e-3);
        ShardServeConfig {
            base,
            nodes,
            threads,
            lookahead_s,
            route: RouteKind::RoundRobin,
            forward: true,
        }
    }
}

/// Per-shard slice of a sharded run's outcome.
#[derive(Debug, Clone)]
pub struct ShardSummary {
    pub shard: usize,
    pub gpus: u32,
    pub completed: u32,
    pub expired: u32,
    pub rejected: u32,
    pub handoffs_in: u32,
    pub handoffs_out: u32,
    pub events: u64,
}

impl ShardSummary {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("shard", self.shard)
            .set("gpus", self.gpus)
            .set("completed", self.completed)
            .set("expired", self.expired)
            .set("rejected", self.rejected)
            .set("handoffs_in", self.handoffs_in)
            .set("handoffs_out", self.handoffs_out)
            .set("events", self.events);
        o
    }
}

/// Outcome of a sharded run: the canonical merged `ServeReport` (bit-
/// identical across thread counts — thread count and wall-clock live out
/// here, never inside it) plus dispatcher diagnostics.
#[derive(Debug, Clone)]
pub struct ShardedServeReport {
    pub report: ServeReport,
    pub nodes: u32,
    /// Worker threads that actually ran (the configured count clamped to
    /// the shard count — extra workers would own no shards).
    pub threads: u32,
    pub lookahead_s: f64,
    pub route: RouteKind,
    pub forward: bool,
    /// Cross-node handoffs performed.
    pub handoffs: u32,
    /// Lock-step epochs executed (excluding the final drain).
    pub epochs: u64,
    pub shards: Vec<ShardSummary>,
}

impl ShardedServeReport {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("report", self.report.to_json())
            .set("nodes", self.nodes)
            .set("threads", self.threads)
            .set("lookahead_s", self.lookahead_s)
            .set("route", self.route.label())
            .set("forward", self.forward)
            .set("handoffs", self.handoffs)
            .set("epochs", self.epochs)
            .set(
                "shards",
                Json::Arr(self.shards.iter().map(|s| s.to_json()).collect()),
            );
        o
    }

    pub fn summary(&self) -> String {
        format!(
            "sharded serve: {} nodes x {} threads, lookahead {:.3} s, route {} \
             ({} handoffs over {} epochs)\n{}",
            self.nodes,
            self.threads,
            self.lookahead_s,
            self.route.label(),
            self.handoffs,
            self.epochs,
            self.report.summary()
        )
    }
}

/// GPUs owned by shard `s` when `total` GPUs split across `nodes` shards:
/// as even as possible, earlier shards taking the remainder.
fn gpus_for_shard(total: u32, nodes: u32, s: u32) -> u32 {
    total / nodes + u32::from(s < total % nodes)
}

/// Run a sharded multi-node serve over a synthetic Poisson trace.
pub fn serve_sharded(cfg: &ShardServeConfig) -> crate::Result<ShardedServeReport> {
    serve_sharded_impl(cfg, None, |_| NullSink, None::<&mut TelemetryReport>)
}

/// Run a sharded multi-node serve over a replayed arrival trace.
pub fn serve_sharded_replay(
    cfg: &ShardServeConfig,
    trace: &JobTrace,
) -> crate::Result<ShardedServeReport> {
    serve_sharded_impl(cfg, Some(trace), |_| NullSink, None::<&mut TelemetryReport>)
}

/// Sharded serve with the telemetry plane on. The `ShardedServeReport`
/// is byte-identical to the untraced run on the same config; the
/// telemetry report is bit-identical for every `--threads` value (chunks
/// are absorbed in shard-id order at every barrier, and all merges are
/// integer-associative).
pub fn serve_sharded_traced(
    cfg: &ShardServeConfig,
    tcfg: &TelemetryConfig,
) -> crate::Result<(ShardedServeReport, TelemetryReport)> {
    tcfg.validate()?;
    let t = *tcfg;
    let mut tel = TelemetryReport::new();
    let report = serve_sharded_impl(
        cfg,
        None,
        move |shard| Recorder::new(shard as u32, &t),
        Some(&mut tel),
    )?;
    tel.finalize();
    Ok((report, tel))
}

/// Sharded serve streaming its telemetry to `out` as JSONL: events are
/// written incrementally at every epoch barrier instead of buffered for
/// the whole run. The bytes written are identical to rendering the
/// buffered run's [`TelemetryReport::to_jsonl`], and the returned
/// `ShardedServeReport` is byte-identical to the untraced run.
pub fn serve_sharded_streamed<W: std::io::Write>(
    cfg: &ShardServeConfig,
    tcfg: &TelemetryConfig,
    out: W,
) -> crate::Result<ShardedServeReport> {
    tcfg.validate()?;
    let t = *tcfg;
    let mut streamer = TelemetryStreamer::new(out);
    let report = serve_sharded_impl(
        cfg,
        None,
        move |shard| Recorder::new(shard as u32, &t),
        Some(&mut streamer),
    )?;
    streamer.finish()?;
    Ok(report)
}

fn serve_sharded_impl<S: Sink, C: ChunkCollector>(
    scfg: &ShardServeConfig,
    trace: Option<&JobTrace>,
    mk_sink: impl Fn(usize) -> S,
    mut tel: Option<&mut C>,
) -> crate::Result<ShardedServeReport> {
    let base = &scfg.base;
    ensure!(scfg.nodes >= 1, "sharded serve needs at least one node");
    ensure!(scfg.threads >= 1, "sharded serve needs at least one thread");
    ensure!(
        base.gpus >= scfg.nodes,
        "need at least one GPU per node shard ({} GPUs < {} nodes)",
        base.gpus,
        scfg.nodes
    );
    ensure!(scfg.lookahead_s > 0.0, "lookahead must be positive");
    ensure!(base.arrival_rate_hz > 0.0, "arrival rate must be positive");
    ensure!(base.deadline_s > 0.0, "deadline must be positive");
    base.validate_hostmem()?;
    let jobs: Vec<Job> = match trace {
        Some(t) => t.canonicalized()?.jobs,
        None => {
            ensure!(base.jobs >= 1, "serve needs at least one job");
            JobTrace::poisson(
                base.jobs,
                1.0 / base.arrival_rate_hz,
                &super::serve_mix(),
                base.seed,
            )
            .jobs
        }
    };
    ensure!(!jobs.is_empty(), "serve needs at least one job");
    let mut cfg = base.clone();
    cfg.jobs = jobs.len() as u32;

    let nodes = scfg.nodes as usize;
    let mut shards = Vec::with_capacity(nodes);
    // Fault streams are seeded by *fleet-global* GPU id — a prefix sum of
    // the per-shard widths — so the merged report is bit-identical no
    // matter how the fleet is sharded or threaded.
    let mut gpu_base = 0u32;
    for s in 0..nodes {
        let g = gpus_for_shard(cfg.gpus, scfg.nodes, s as u32);
        let mut sh = Shard::new(
            s,
            g,
            &cfg,
            ServeMode::Indexed,
            scfg.lookahead_s,
            // With one node the coordinator can never use handoff
            // candidates — don't pay the per-barrier collection.
            scfg.forward && scfg.nodes > 1,
            mk_sink(s),
        )?;
        sh.arm_faults(gpu_base);
        gpu_base += g;
        shards.push(sh);
    }

    // Static routing is known upfront: pre-schedule every arrival in
    // global-id order, exactly like the single-loop serve does.
    let mut next_job = 0usize;
    if scfg.route == RouteKind::RoundRobin {
        for job in &jobs {
            shards[job.id as usize % nodes].push_arrival(job.clone());
        }
        next_job = jobs.len();
    }

    // Synthetic pre-first-epoch barrier state: nothing admitted yet.
    let mut infos: Vec<BarrierInfo> = shards
        .iter()
        .map(|s| BarrierInfo {
            shard: s.id,
            pending: 0,
            unresolved: 0,
            arrivals_pending: s.expected,
            open_sm_seats: s.fleet.open_sm_seats(),
            largest_empty_gib: s.fleet.largest_idle_slot_gib(),
            max_open_headroom_gib: s.fleet.max_open_headroom_gib(),
            host_headroom_bytes: s.fleet.host_headroom_bytes(),
            candidates: Vec::new(),
            telemetry: None,
            est_delta: None,
        })
        .collect();

    // More workers than shards cannot help — clamp, and report the count
    // that actually ran so scaling numbers are never attributed to a
    // configuration that never executed.
    let threads = (scfg.threads as usize).min(nodes);
    let mut pool = ShardPool::new(shards, threads);
    // Estimator observations drained at the last barrier, waiting to be
    // applied (as `total − own`) at each shard's next epoch start.
    let mut est_pending: Vec<Option<Box<EstimatorDelta>>> = vec![None; nodes];
    let lookahead_ns = sec_to_ns(scfg.lookahead_s).max(1);
    let handoff_slice_sms = GiProfile::get(ProfileId::P1g12gb).sms as i64;
    let mut epoch: u64 = 0;
    let mut handoffs_total: u64 = 0;
    loop {
        if epoch > 50_000_000 {
            bail!("sharded serve exceeded the epoch budget — lookahead too small?");
        }
        let start_ns = epoch
            .checked_mul(lookahead_ns)
            .ok_or_else(|| anyhow::anyhow!("epoch clock overflow — lookahead too large"))?;
        let end_ns = start_ns
            .checked_add(lookahead_ns)
            .ok_or_else(|| anyhow::anyhow!("epoch clock overflow — lookahead too large"))?;
        let mut inputs: Vec<EpochInput> = (0..nodes)
            .map(|s| EpochInput {
                start_ns,
                end_ns,
                stream_open: false,
                removals: Vec::new(),
                handoffs: Vec::new(),
                arrivals: Vec::new(),
                est_others: est_pending[s].take(),
            })
            .collect();

        // 1. Overflow handoffs, decided strictly from last-barrier state:
        // candidates in ascending global-id order go to the shard with
        // the most open SM-seats (batched headroom; ties toward the lower
        // id) *among shards that can actually host the job*: an empty
        // slot big enough for a direct run, an empty slot big enough for
        // the offloaded run plus Grace-pool headroom for its spill, or —
        // via `Slot::fits` — a partially-filled slot whose remaining
        // memory holds the job's direct charge (so a forwarded job is
        // never bounced by a memory-full slot on arrival). The fallback
        // to any shard with open headroom fires only when reconfiguration
        // is enabled (the target can repartition toward the job). Each
        // assignment debits one smallest-slice seat and the job's host
        // need from the target so a single barrier cannot dogpile one
        // shard or oversubscribe its pool.
        if scfg.forward && nodes > 1 {
            let mut cands: Vec<Handoff> = Vec::new();
            for info in &infos {
                cands.extend(info.candidates.iter().cloned());
            }
            cands.sort_by_key(|h| h.global_id);
            if let Some(tr) = tel.as_mut() {
                tr.count(Counter::HandoffAttempts, cands.len() as u64);
            }
            let mut idle_left: Vec<i64> =
                infos.iter().map(|i| i.open_sm_seats as i64).collect();
            let mut host_left: Vec<u64> =
                infos.iter().map(|i| i.host_headroom_bytes).collect();
            for h in cands {
                let pick = |strict: bool, idle: &[i64], host: &[u64]| -> Option<usize> {
                    let mut best: Option<usize> = None;
                    for (s, &left) in idle.iter().enumerate() {
                        if s == h.origin || left < handoff_slice_sms {
                            continue;
                        }
                        if strict {
                            let empty_direct = infos[s].largest_empty_gib >= h.min_direct_gib;
                            let empty_offload = infos[s].largest_empty_gib >= h.min_host_gib
                                && host[s] >= h.host_need_bytes;
                            let open_seat =
                                infos[s].max_open_headroom_gib >= h.direct_need_gib;
                            if !empty_direct && !empty_offload && !open_seat {
                                continue;
                            }
                        }
                        if best.map(|b| left > idle[b]).unwrap_or(true) {
                            best = Some(s);
                        }
                    }
                    best
                };
                let target = pick(true, &idle_left, &host_left)
                    .map(|t| (t, HandoffReason::OpenSeat))
                    .or_else(|| {
                        // No shard has a compatible seat right now; only
                        // forward blind if the destination could
                        // repartition.
                        if cfg.reconfig {
                            pick(false, &idle_left, &host_left)
                                .map(|t| (t, HandoffReason::Reconfig))
                        } else {
                            None
                        }
                    });
                if let Some((t, reason)) = target {
                    idle_left[t] -= handoff_slice_sms;
                    host_left[t] = host_left[t].saturating_sub(h.host_need_bytes);
                    inputs[h.origin].removals.push((h.origin_local, t as u32, reason));
                    inputs[t].handoffs.push(h);
                    handoffs_total += 1;
                }
            }
        }

        // 2. Route this epoch's arrival window (dynamic routing only).
        if scfg.route == RouteKind::LeastLoaded {
            let mut load: Vec<u64> = infos
                .iter()
                .map(|i| (i.pending + i.arrivals_pending) as u64)
                .collect();
            for (s, inp) in inputs.iter().enumerate() {
                load[s] += inp.handoffs.len() as u64;
            }
            while next_job < jobs.len() && sec_to_ns(jobs[next_job].arrival_s) < end_ns {
                let mut best = 0usize;
                for (s, &l) in load.iter().enumerate().skip(1) {
                    if l < load[best] {
                        best = s;
                    }
                }
                inputs[best].arrivals.push(jobs[next_job].clone());
                load[best] += 1;
                next_job += 1;
            }
        }

        // 3. Keep each shard's integration window open while the rest of
        // the cluster can still send it work.
        let all_delivered = next_job == jobs.len();
        let active: Vec<u64> = infos
            .iter()
            .zip(inputs.iter())
            .map(|(i, inp)| {
                (i.unresolved + i.arrivals_pending) as u64
                    + (inp.handoffs.len() + inp.arrivals.len()) as u64
            })
            .collect();
        let total_active: u64 = active.iter().sum();
        for (s, inp) in inputs.iter_mut().enumerate() {
            let other_active = total_active - active[s] > 0;
            inp.stream_open = !all_delivered || (scfg.forward && nodes > 1 && other_active);
        }

        infos = pool.epoch(inputs);
        // Absorb this epoch's telemetry in shard-id order (infos are
        // already ordered by shard) — the thread-invariance anchor.
        if let Some(tr) = tel.as_mut() {
            for info in infos.iter_mut() {
                if let Some(chunk) = info.telemetry.take() {
                    tr.absorb_chunk(*chunk);
                }
            }
            tr.at_barrier(end_ns)?;
        }
        // All-to-all estimator exchange: total the barrier's deltas in
        // shard-id order (integer sums — order-free anyway), then queue
        // `total − own` for each shard's next epoch. One node needs no
        // exchange: its local table already is the fleet table.
        if cfg.estimator.active() && nodes > 1 {
            let mut acc = DeltaAcc::default();
            let mut any = false;
            for info in &infos {
                if let Some(d) = &info.est_delta {
                    acc.add(d);
                    any = true;
                }
            }
            if any {
                for (s, info) in infos.iter().enumerate() {
                    est_pending[s] = acc.minus(info.est_delta.as_deref());
                }
            }
        }
        epoch += 1;

        let remaining: u64 = infos
            .iter()
            .map(|i| (i.unresolved + i.arrivals_pending) as u64)
            .sum();
        if next_job == jobs.len() && remaining == 0 {
            break;
        }
    }
    // Trailing reconfig completions (work is done; nothing integrates).
    pool.drain();
    let mut shards = pool.finish();
    // Telemetry recorded after the last barrier (the drain) is still in
    // the shards' sinks; `finish` hands them back in id order.
    if let Some(tr) = tel.as_mut() {
        for s in shards.iter_mut() {
            if let Some(chunk) = s.sink.take_chunk() {
                tr.absorb_chunk(chunk);
            }
        }
    }
    let report = merge_report(&cfg, &shards);
    Ok(ShardedServeReport {
        report,
        nodes: scfg.nodes,
        threads: threads as u32,
        lookahead_s: scfg.lookahead_s,
        route: scfg.route,
        forward: scfg.forward,
        handoffs: handoffs_total as u32,
        epochs: epoch,
        shards: shards.iter().map(|s| s.summary()).collect(),
    })
}

/// Messages from the coordinator to a worker thread.
enum WorkerMsg {
    Epoch(Vec<EpochInput>),
    Drain,
    Finish,
}

/// The shard executor: inline for one thread, otherwise persistent worker
/// threads each owning the shards with `id % threads == worker`. Shard
/// execution is pure w.r.t. anything outside the shard, so the mapping of
/// shards to workers cannot change any result — only the wall clock.
enum ShardPool<S: Sink> {
    Inline(Vec<Shard<S>>),
    Threads {
        to_workers: Vec<mpsc::Sender<WorkerMsg>>,
        from_workers: mpsc::Receiver<(usize, Vec<BarrierInfo>)>,
        handles: Vec<thread::JoinHandle<Vec<Shard<S>>>>,
        nshards: usize,
    },
}

impl<S: Sink> ShardPool<S> {
    fn new(shards: Vec<Shard<S>>, threads: usize) -> ShardPool<S> {
        if threads <= 1 {
            return ShardPool::Inline(shards);
        }
        let nshards = shards.len();
        let (res_tx, from_workers) = mpsc::channel();
        let mut owned: Vec<Vec<Shard<S>>> = (0..threads).map(|_| Vec::new()).collect();
        for (i, shard) in shards.into_iter().enumerate() {
            owned[i % threads].push(shard);
        }
        let mut to_workers = Vec::with_capacity(threads);
        let mut handles = Vec::with_capacity(threads);
        for (w, shardset) in owned.into_iter().enumerate() {
            let (tx, rx) = mpsc::channel::<WorkerMsg>();
            let res = res_tx.clone();
            handles.push(thread::spawn(move || worker_loop(shardset, rx, res, w)));
            to_workers.push(tx);
        }
        ShardPool::Threads {
            to_workers,
            from_workers,
            handles,
            nshards,
        }
    }

    /// Run one epoch on every shard. `inputs` and the returned infos are
    /// in shard-id order regardless of the worker mapping.
    fn epoch(&mut self, inputs: Vec<EpochInput>) -> Vec<BarrierInfo> {
        match self {
            ShardPool::Inline(shards) => shards
                .iter_mut()
                .zip(inputs)
                .map(|(s, i)| s.run_epoch(i))
                .collect(),
            ShardPool::Threads {
                to_workers,
                from_workers,
                handles,
                nshards,
            } => {
                let threads = to_workers.len();
                let mut per: Vec<Vec<EpochInput>> = (0..threads).map(|_| Vec::new()).collect();
                for (i, input) in inputs.into_iter().enumerate() {
                    per[i % threads].push(input);
                }
                for (tx, batch) in to_workers.iter().zip(per) {
                    tx.send(WorkerMsg::Epoch(batch)).expect("worker thread died");
                }
                let mut out: Vec<Option<BarrierInfo>> = (0..*nshards).map(|_| None).collect();
                for _ in 0..threads {
                    let (_w, batch) = recv_or_die(from_workers, handles);
                    for info in batch {
                        let s = info.shard;
                        out[s] = Some(info);
                    }
                }
                out.into_iter()
                    .map(|o| o.expect("missing shard barrier info"))
                    .collect()
            }
        }
    }

    /// Run every shard's engine dry (trailing reconfig completions after
    /// the last job resolved).
    fn drain(&mut self) {
        match self {
            ShardPool::Inline(shards) => {
                for s in shards.iter_mut() {
                    s.stream_open = false;
                    s.run_until(None);
                }
            }
            ShardPool::Threads {
                to_workers,
                from_workers,
                handles,
                ..
            } => {
                for tx in to_workers.iter() {
                    tx.send(WorkerMsg::Drain).expect("worker thread died");
                }
                for _ in 0..to_workers.len() {
                    recv_or_die(from_workers, handles);
                }
            }
        }
    }

    /// Tear down the pool and hand back every shard in id order.
    fn finish(self) -> Vec<Shard<S>> {
        match self {
            ShardPool::Inline(shards) => shards,
            ShardPool::Threads {
                to_workers,
                handles,
                ..
            } => {
                for tx in &to_workers {
                    let _ = tx.send(WorkerMsg::Finish);
                }
                let mut shards: Vec<Shard<S>> = Vec::new();
                for h in handles {
                    shards.extend(h.join().expect("worker thread panicked"));
                }
                shards.sort_by_key(|s| s.id);
                shards
            }
        }
    }
}

/// Receive one barrier message, surfacing a worker's death as a panic
/// instead of a hang: a worker that panics mid-epoch drops its sender,
/// but its siblings keep result-sender clones alive while parked on
/// their own queues, so a plain `recv()` would block forever. The
/// timeout only paces the liveness probe — it never aborts a slow epoch.
fn recv_or_die<S: Sink>(
    rx: &mpsc::Receiver<(usize, Vec<BarrierInfo>)>,
    handles: &[thread::JoinHandle<Vec<Shard<S>>>],
) -> (usize, Vec<BarrierInfo>) {
    loop {
        match rx.recv_timeout(Duration::from_millis(200)) {
            Ok(msg) => return msg,
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // Before `finish`, no worker exits on its own: a finished
                // handle here means the worker panicked.
                if handles.iter().any(|h| h.is_finished()) {
                    panic!("sharded serve worker thread died mid-epoch");
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                panic!("sharded serve worker channels disconnected");
            }
        }
    }
}

fn worker_loop<S: Sink>(
    mut shards: Vec<Shard<S>>,
    rx: mpsc::Receiver<WorkerMsg>,
    tx: mpsc::Sender<(usize, Vec<BarrierInfo>)>,
    wid: usize,
) -> Vec<Shard<S>> {
    while let Ok(msg) = rx.recv() {
        match msg {
            WorkerMsg::Epoch(inputs) => {
                debug_assert_eq!(inputs.len(), shards.len());
                let infos: Vec<BarrierInfo> = shards
                    .iter_mut()
                    .zip(inputs)
                    .map(|(s, i)| s.run_epoch(i))
                    .collect();
                if tx.send((wid, infos)).is_err() {
                    break;
                }
            }
            WorkerMsg::Drain => {
                for s in shards.iter_mut() {
                    s.stream_open = false;
                    s.run_until(None);
                }
                if tx.send((wid, Vec::new())).is_err() {
                    break;
                }
            }
            WorkerMsg::Finish => break,
        }
    }
    shards
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::LayoutPreset;

    fn base_cfg() -> ServeConfig {
        ServeConfig {
            gpus: 4,
            policy: PolicyKind::FirstFit,
            layout: LayoutPreset::Mixed,
            arrival_rate_hz: 2.0,
            jobs: 60,
            deadline_s: 30.0,
            reconfig: true,
            seed: 11,
            workload_scale: 0.05,
            batch: 1,
            ..ServeConfig::default()
        }
    }

    fn shard_cfg(nodes: u32, threads: u32) -> ShardServeConfig {
        ShardServeConfig::new(base_cfg(), nodes, threads)
    }

    #[test]
    fn gpu_split_is_even_and_exhaustive() {
        for (total, nodes) in [(4u32, 2u32), (7, 3), (16, 5), (3, 3), (512, 8)] {
            let per: Vec<u32> = (0..nodes).map(|s| gpus_for_shard(total, nodes, s)).collect();
            assert_eq!(per.iter().sum::<u32>(), total, "{total}/{nodes}");
            let lo = *per.iter().min().unwrap();
            let hi = *per.iter().max().unwrap();
            assert!(hi - lo <= 1, "{per:?}");
        }
    }

    #[test]
    fn sharded_run_resolves_every_job() {
        let r = serve_sharded(&shard_cfg(2, 1)).unwrap();
        let rep = &r.report;
        assert_eq!(rep.completed + rep.expired + rep.rejected, rep.jobs);
        assert!(rep.completed > 0);
        assert!(rep.events > 0);
        assert!((0.0..=1.0).contains(&rep.utilization));
        assert!((0.0..=1.0).contains(&rep.fragmentation));
        assert!(rep.energy_j.is_finite() && rep.energy_j > 0.0);
        assert_eq!(r.shards.len(), 2);
        assert_eq!(
            r.shards.iter().map(|s| s.gpus).sum::<u32>(),
            rep.gpus,
            "shards partition the fleet"
        );
    }

    #[test]
    fn one_node_matches_single_loop_oracle_bit_for_bit() {
        for route in [RouteKind::RoundRobin, RouteKind::LeastLoaded] {
            let mut scfg = shard_cfg(1, 1);
            scfg.route = route;
            let sharded = serve_sharded(&scfg).unwrap();
            let single = super::super::serve(&base_cfg()).unwrap();
            assert_eq!(
                sharded.report.to_json().pretty(),
                single.to_json().pretty(),
                "route {route:?}"
            );
            assert_eq!(sharded.handoffs, 0, "no self-handoffs on one node");
        }
    }

    #[test]
    fn thread_count_never_changes_the_report() {
        for nodes in [2u32, 4] {
            let mut reports = Vec::new();
            for threads in [1u32, 2, 4] {
                let mut scfg = shard_cfg(nodes, threads);
                scfg.route = RouteKind::LeastLoaded;
                reports.push(serve_sharded(&scfg).unwrap());
            }
            let first = reports[0].report.to_json().pretty();
            for r in &reports[1..] {
                assert_eq!(
                    first,
                    r.report.to_json().pretty(),
                    "nodes={nodes} threads={}",
                    r.threads
                );
            }
            // The outer diagnostics are thread-invariant too.
            let h0 = reports[0].handoffs;
            assert!(reports.iter().all(|r| r.handoffs == h0));
        }
    }

    #[test]
    fn blocked_jobs_hand_off_and_accounting_stays_exact() {
        // Two lightly-loaded all-small 1-GPU shards under first-fit with
        // reconfiguration on: a large job can only ever place after a
        // ~6.5 s repartition, so it pends well past the 1 s lookahead and
        // becomes a handoff candidate at the next barrier; no shard has a
        // compatible idle slot (all-small), so the reconfig-enabled
        // fallback forwards it to the idle sibling. Forwarding must
        // trigger, hop each job at most once, and keep the global
        // completed/expired/rejected accounting exact.
        let base = ServeConfig {
            gpus: 2,
            layout: LayoutPreset::AllSmall,
            arrival_rate_hz: 0.05,
            jobs: 40,
            deadline_s: 30.0,
            reconfig: true,
            ..base_cfg()
        };
        let mut with = ShardServeConfig::new(base, 2, 1);
        with.forward = true;
        with.lookahead_s = 1.0;
        let mut without = with.clone();
        without.forward = false;
        let w = serve_sharded(&with).unwrap();
        let wo = serve_sharded(&without).unwrap();
        assert!(w.handoffs > 0, "stranded large jobs must trigger handoffs");
        assert_eq!(wo.handoffs, 0);
        for r in [&w, &wo] {
            let rep = &r.report;
            assert_eq!(
                rep.completed + rep.expired + rep.rejected,
                rep.jobs,
                "every job resolves exactly once despite migration"
            );
        }
        // One-hop invariant: handoffs in == handoffs out, and each shard's
        // events are part of the merged total.
        let inn: u32 = w.shards.iter().map(|s| s.handoffs_in).sum();
        let out: u32 = w.shards.iter().map(|s| s.handoffs_out).sum();
        assert_eq!(inn, w.handoffs);
        assert_eq!(out, w.handoffs);
        assert_eq!(w.shards.iter().map(|s| s.events).sum::<u64>(), w.report.events);
    }

    #[test]
    fn incompatible_handoffs_are_suppressed_without_reconfig() {
        // Same stranded-large-job setup but with reconfiguration off: no
        // shard can ever host the large jobs (all-small, no offload), so
        // the dispatcher must not forward them — a doomed migration only
        // delays the inevitable expiry on a different queue.
        let base = ServeConfig {
            gpus: 2,
            layout: LayoutPreset::AllSmall,
            arrival_rate_hz: 0.05,
            jobs: 30,
            deadline_s: 30.0,
            reconfig: false,
            ..base_cfg()
        };
        let mut scfg = ShardServeConfig::new(base, 2, 1);
        scfg.lookahead_s = 1.0;
        let r = serve_sharded(&scfg).unwrap();
        assert_eq!(r.handoffs, 0, "no compatible target, no reconfig: stay put");
        assert!(r.report.expired > 0, "the large jobs still expire locally");
    }

    #[test]
    fn handoffs_preserve_global_arrival_order_at_equal_timestamps() {
        // Property: handoffs re-arriving at the same barrier instant are
        // admitted in ascending global-id order (the coordinator injects
        // them sorted; engine ties break by insertion order).
        let cfg = base_cfg();
        let mut shard = Shard::new(0, 2, &cfg, ServeMode::Indexed, 1.0, true, NullSink).unwrap();
        let gids = [9u32, 3, 17, 5, 11];
        let mut sorted = gids.to_vec();
        sorted.sort_unstable();
        for &gid in &sorted {
            shard.push_handoff(
                Handoff {
                    global_id: gid,
                    origin: 1,
                    origin_local: 0,
                    app: AppId::Faiss,
                    arrival_s: 0.25,
                    deadline_abs_s: 50.0,
                    min_host_gib: 11.0,
                    min_direct_gib: 11.0,
                    direct_need_gib: 1.0,
                    host_need_bytes: 0,
                    retry: None,
                },
                2.0,
            );
        }
        shard.run_until(None);
        // Local admission order == local id order == injection order.
        let admitted: Vec<u32> = shard.metas.iter().map(|m| m.global_id).collect();
        assert_eq!(admitted, sorted);
        assert!(shard.queue.all_resolved());
        for j in &shard.queue.jobs {
            // Wait accounting spans the handoff: placed at/after the 2.0 s
            // re-arrival against the 0.25 s original arrival.
            if j.state == JobState::Completed {
                assert!(j.placed_s.unwrap() >= 2.0 - 1e-12);
                assert!((j.job.arrival_s - 0.25).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn batched_sharded_runs_are_thread_invariant_and_exact() {
        // Slot-level batching under the sharded control plane: the merged
        // report stays bit-identical across thread counts, and the global
        // accounting stays exact, for batch depths > 1.
        for batch in [2u32, 4] {
            let base = ServeConfig {
                batch,
                ..base_cfg()
            };
            let mut first: Option<String> = None;
            for threads in [1u32, 2] {
                let mut scfg = ShardServeConfig::new(base.clone(), 2, threads);
                scfg.route = RouteKind::LeastLoaded;
                let r = serve_sharded(&scfg).unwrap();
                let rep = &r.report;
                assert_eq!(rep.completed + rep.expired + rep.rejected, rep.jobs);
                let key = format!("{}|{}", rep.to_json().pretty(), r.handoffs);
                match &first {
                    None => first = Some(key),
                    Some(f) => assert_eq!(*f, key, "batch={batch} threads={threads}"),
                }
            }
        }
    }

    #[test]
    fn handoffs_interleaved_with_prescheduled_arrivals_keep_dense_fifo_ids() {
        // Queue ids are assigned when arrivals *fire*, not when they are
        // scheduled: a handoff injected after far-future arrivals were
        // pre-scheduled fires first and must take the next dense queue id.
        // The invariant under test: queue ids are dense 0..n in admission
        // (fire) order, every arrival admits exactly once, and the
        // qid→lid mapping stays a bijection.
        let cfg = base_cfg();
        let mut shard = Shard::new(0, 2, &cfg, ServeMode::Indexed, 1.0, true, NullSink).unwrap();
        // Pre-scheduled synthetic arrivals at t = 5, 6, 7 (global ids 0..3).
        for (i, t) in [5.0f64, 6.0, 7.0].iter().enumerate() {
            shard.push_arrival(Job {
                id: i as u32,
                app: AppId::Faiss,
                arrival_s: *t,
            });
        }
        // A handoff decided at an earlier barrier fires at t = 2 — before
        // every pre-scheduled arrival — with an older original arrival.
        shard.push_handoff(
            Handoff {
                global_id: 99,
                origin: 1,
                origin_local: 0,
                app: AppId::Hotspot,
                arrival_s: 0.5,
                deadline_abs_s: 60.0,
                min_host_gib: 11.0,
                min_direct_gib: 11.0,
                direct_need_gib: 1.0,
                host_need_bytes: 0,
                retry: None,
            },
            2.0,
        );
        shard.run_until(None);
        // Dense ids in fire order: the handoff (global 99) admits first.
        assert_eq!(shard.queue.jobs.len(), 4);
        for (qid, qj) in shard.queue.jobs.iter().enumerate() {
            assert_eq!(qj.job.id as usize, qid, "queue ids must stay dense");
        }
        let fired_gids: Vec<u32> = shard
            .queue
            .jobs
            .iter()
            .enumerate()
            .map(|(qid, _)| shard.metas[shard.qid_to_lid[qid] as usize].global_id)
            .collect();
        assert_eq!(fired_gids, vec![99, 0, 1, 2]);
        assert!(shard.queue.jobs[0].handoff);
        assert!((shard.queue.jobs[0].job.arrival_s - 0.5).abs() < 1e-12);
        // qid→lid is a bijection over 0..4.
        let mut lids: Vec<u32> = shard.qid_to_lid.clone();
        lids.sort_unstable();
        assert_eq!(lids, vec![0, 1, 2, 3]);
        assert!(shard.queue.all_resolved());
        assert!(shard.queue.all_resolved_scan());
    }

    #[test]
    fn inert_fault_spec_matches_default_bit_for_bit() {
        // `--faults none` must be indistinguishable from never having a
        // fault plane: zero weight ⇒ zero scheduled events ⇒ identical
        // popped-event counts and identical report bytes.
        let mut with_none = base_cfg();
        with_none.faults =
            super::super::faults::FaultConfig::from_spec("none", 40.0, 5.0, 3, 1.0).unwrap();
        let a = super::super::serve(&base_cfg()).unwrap();
        let b = super::super::serve(&with_none).unwrap();
        assert_eq!(a.to_json().pretty(), b.to_json().pretty());
        assert!(!b.faults_active);
    }

    #[test]
    fn faulted_runs_conserve_jobs_and_inject_faults() {
        // A hot fault plane (MTTF well under the run length) must inject
        // failures, retry orphans, and still resolve every admitted job
        // exactly once: completed + expired + rejected + failed == jobs.
        let mut cfg = base_cfg();
        cfg.faults = super::super::faults::FaultConfig::from_spec(
            "gpu,slice:2,reconfig",
            10.0,
            3.0,
            2,
            1.0,
        )
        .unwrap();
        for mode in [ServeMode::Indexed, ServeMode::NaiveOracle] {
            let r = super::super::serve_with(&cfg, mode).unwrap();
            assert!(r.faults_active);
            assert!(r.faults > 0, "MTTF 10 s/GPU over a ~30 s run must fire");
            assert_eq!(
                r.completed + r.expired + r.rejected + r.failed,
                r.jobs,
                "mode {mode:?}"
            );
            assert!(r.completed > 0, "the fleet still serves between faults");
        }
        // Indexed and the naive oracle agree bit-for-bit under faults.
        let i = super::super::serve_with(&cfg, ServeMode::Indexed).unwrap();
        let n = super::super::serve_with(&cfg, ServeMode::NaiveOracle).unwrap();
        assert_eq!(i.to_json().pretty(), n.to_json().pretty());
    }

    #[test]
    fn faulted_sharded_runs_are_thread_invariant() {
        // Fault streams are keyed by fleet-global GPU id, so the merged
        // report must not depend on the worker count.
        let mut base = base_cfg();
        base.faults =
            super::super::faults::FaultConfig::from_spec("gpu,slice", 50.0, 4.0, 2, 2.0).unwrap();
        let mut first: Option<String> = None;
        for threads in [1u32, 2, 4] {
            let mut scfg = ShardServeConfig::new(base.clone(), 4, threads);
            scfg.route = RouteKind::LeastLoaded;
            let r = serve_sharded(&scfg).unwrap();
            let rep = &r.report;
            assert_eq!(
                rep.completed + rep.expired + rep.rejected + rep.failed,
                rep.jobs
            );
            let key = rep.to_json().pretty();
            match &first {
                None => first = Some(key),
                Some(f) => assert_eq!(*f, key, "threads={threads}"),
            }
        }
    }

    #[test]
    fn exhausted_retry_budget_fails_terminally() {
        // With zero retries every orphan dies `Failed` on its first
        // fault; with a generous budget and fast repair, strictly fewer
        // jobs fail (retries get another chance to finish).
        let mut none = base_cfg();
        none.faults =
            super::super::faults::FaultConfig::from_spec("gpu", 8.0, 2.0, 0, 1.0).unwrap();
        let mut many = none.clone();
        many.faults.retries = 5;
        let r0 = super::super::serve(&none).unwrap();
        let r5 = super::super::serve(&many).unwrap();
        assert!(r0.faults > 0);
        assert_eq!(r0.retries, 0, "no budget, no retries");
        assert!(r5.retries > 0, "budget spent on requeues");
        assert_eq!(
            r0.completed + r0.expired + r0.rejected + r0.failed,
            r0.jobs
        );
        assert_eq!(
            r5.completed + r5.expired + r5.rejected + r5.failed,
            r5.jobs
        );
        assert!(
            r5.failed <= r0.failed,
            "a retry budget never fails more jobs: {} vs {}",
            r5.failed,
            r0.failed
        );
    }

    #[test]
    fn route_kind_parses_and_round_trips() {
        for r in [RouteKind::RoundRobin, RouteKind::LeastLoaded] {
            assert_eq!(RouteKind::parse(r.label()), Some(r));
        }
        assert_eq!(RouteKind::parse("bogus"), None);
    }

    #[test]
    fn replayed_trace_matches_synthetic_sharded_run() {
        let scfg = shard_cfg(2, 2);
        let synth = serve_sharded(&scfg).unwrap();
        let trace = JobTrace::poisson(
            scfg.base.jobs,
            1.0 / scfg.base.arrival_rate_hz,
            &super::super::serve_mix(),
            scfg.base.seed,
        );
        let replay = serve_sharded_replay(&scfg, &trace).unwrap();
        assert_eq!(synth.to_json().pretty(), replay.to_json().pretty());
    }

    #[test]
    fn inert_degrade_knobs_keep_the_faulted_report_bit_identical() {
        // An active fault plane with every degradation knob at its
        // default reproduces the pre-degrade plane byte-for-byte: no
        // domain events, unlimited instant repair, no shedding.
        let mut cfg = base_cfg();
        cfg.faults =
            super::super::faults::FaultConfig::from_spec("gpu,slice", 10.0, 3.0, 2, 1.0).unwrap();
        let plain = super::super::serve(&cfg).unwrap();
        let mut knobs = cfg.clone();
        knobs.faults = knobs
            .faults
            .with_degrade(FaultDomains::None, 0, ShedPolicy::None)
            .unwrap();
        let k = super::super::serve(&knobs).unwrap();
        assert_eq!(plain.to_json().pretty(), k.to_json().pretty());
        assert_eq!(k.shed, 0);
        assert_eq!(k.domain_faults, 0);
    }

    #[test]
    fn degraded_runs_conserve_jobs_and_match_the_oracle() {
        // Rack domains (uneven last rack), one repair crew, and a shed
        // watermark all at once: every admitted job still resolves
        // exactly once under the extended conservation equation, and
        // Indexed agrees with the naive oracle bit-for-bit.
        let mut cfg = base_cfg();
        cfg.faults = super::super::faults::FaultConfig::from_spec("gpu", 8.0, 6.0, 2, 1.0)
            .unwrap()
            .with_degrade(FaultDomains::Rack(3), 1, ShedPolicy::Watermark(0.75))
            .unwrap();
        for mode in [ServeMode::Indexed, ServeMode::NaiveOracle] {
            let r = super::super::serve_with(&cfg, mode).unwrap();
            assert!(r.domain_faults > 0, "rack events must fire (mode {mode:?})");
            assert_eq!(
                r.completed + r.expired + r.rejected + r.failed + r.shed,
                r.jobs,
                "mode {mode:?}"
            );
        }
        let i = super::super::serve_with(&cfg, ServeMode::Indexed).unwrap();
        let n = super::super::serve_with(&cfg, ServeMode::NaiveOracle).unwrap();
        assert_eq!(i.to_json().pretty(), n.to_json().pretty());
    }

    #[test]
    fn fewer_crews_never_complete_more_jobs_under_a_burst() {
        // Node-wide domain events with long repairs: one crew serializes
        // the burst's repairs (boards stay cordoned far beyond MTTR),
        // four crews clear it in parallel — strictly more jobs complete.
        let mut base = base_cfg();
        base.jobs = 80;
        base.faults =
            super::super::faults::FaultConfig::from_spec("gpu", 12.0, 15.0, 2, 1.0).unwrap();
        let mk = |crews: u32| {
            let mut c = base.clone();
            c.faults = c
                .faults
                .with_degrade(FaultDomains::Node, crews, ShedPolicy::None)
                .unwrap();
            super::super::serve(&c).unwrap()
        };
        let one = mk(1);
        let four = mk(4);
        assert!(one.domain_faults > 0, "the burst must actually happen");
        for r in [&one, &four] {
            assert_eq!(
                r.completed + r.expired + r.rejected + r.failed + r.shed,
                r.jobs
            );
        }
        assert!(
            one.completed < four.completed,
            "1 crew vs 4 crews: {} vs {} completed",
            one.completed,
            four.completed
        );
    }

    #[test]
    fn degraded_sharded_runs_are_thread_invariant() {
        // Racks straddling shard boundaries (4 shards x 1 GPU, rack
        // width 2), one crew per shard, and shedding: domain streams key
        // on the fleet-global domain id, so the merged report must not
        // depend on the worker count.
        let mut base = base_cfg();
        base.faults = super::super::faults::FaultConfig::from_spec("gpu", 25.0, 5.0, 2, 2.0)
            .unwrap()
            .with_degrade(FaultDomains::Rack(2), 1, ShedPolicy::Watermark(0.5))
            .unwrap();
        let mut first: Option<String> = None;
        for threads in [1u32, 2, 4] {
            let mut scfg = ShardServeConfig::new(base.clone(), 4, threads);
            scfg.route = RouteKind::LeastLoaded;
            let r = serve_sharded(&scfg).unwrap();
            let rep = &r.report;
            assert_eq!(
                rep.completed + rep.expired + rep.rejected + rep.failed + rep.shed,
                rep.jobs
            );
            assert!(rep.domain_faults > 0, "straddling racks must fire");
            let key = rep.to_json().pretty();
            match &first {
                None => first = Some(key),
                Some(f) => assert_eq!(*f, key, "threads={threads}"),
            }
        }
    }
}
