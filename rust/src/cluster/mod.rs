//! Online cluster serving: offload-aware admission, placement, and
//! dynamic MIG reconfiguration over a multi-GPU fleet.
//!
//! This is the closed loop the rest of the crate feeds: a Poisson stream
//! of Table III jobs (plus the §VI large variants) arrives at a fleet of
//! statically-partitioned GH200 GPUs; an admission queue holds them
//! against a deadline; a placement policy (`placement::PolicyKind`) maps
//! each job to a MIG slot — directly, or through an NVLink-C2C
//! `OffloadPlan` onto a smaller slice; and, when a job fits no current
//! layout, a drained GPU can be repartitioned at a modeled latency cost
//! (`reconfig`). The loop is event-driven over `sim::Engine` and fully
//! deterministic for a fixed seed.
//!
//! Module map:
//! - `fleet`: GPUs, layouts, slots, the reconfiguration state machine,
//!   and the incremental per-profile idle index.
//! - `queue`: FIFO admission with deadlines, lifecycle accounting, and
//!   live pending/resolution counters.
//! - `placement`: first-fit / best-fit / offload-aware policies over a
//!   dense memoized cost model (runtime + power rates per app×profile);
//!   placement decisions walk ≤6 profile classes via the fleet index.
//! - `reconfig`: valid-partition-preserving layout planning + latency.
//!
//! ## The hot path, and its oracle
//!
//! Per-event cost is O(changed state), not O(fleet): placement walks the
//! per-profile idle index; the energy/fragmentation/utilization integrals
//! consume live counters (fleet busy-SMs, per-class idle counts, per-app
//! pending buckets) and a per-GPU power cache that only recomputes GPUs
//! whose running set changed; dispatch reuses scratch buffers and
//! memoizes placement failures per app until the fleet epoch shows
//! capacity returning. `ServeMode::NaiveOracle` keeps the original
//! full-rescan implementation of every one of those decisions; both modes
//! produce bit-identical `ServeReport`s for a fixed seed (differentially
//! tested in `tests/integration.rs`).
//!
//! Outputs (`ServeReport`): admitted throughput, p50/p95/p99 queueing
//! latency, fleet utilization, fragmentation, and energy integrated
//! through the `gpu::PowerModel`.

pub mod fleet;
pub mod placement;
pub mod queue;
pub mod reconfig;

pub use fleet::{Fleet, LayoutPreset};
pub use placement::{PlacementCost, Planner, PolicyKind};
pub use queue::{AdmissionQueue, JobState};

use crate::gpu::{GpuUsage, PowerModel};
use crate::sim::{Engine, EventToken};
use crate::util::json::Json;
use crate::util::stats::{percentile, Accum};
use crate::util::units::{ns_to_sec, sec_to_ns};
use crate::workload::trace::JobTrace;
use crate::workload::AppId;
use anyhow::ensure;
use std::collections::BTreeMap;

/// Configuration of one serving run.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub gpus: u32,
    pub policy: PolicyKind,
    pub layout: LayoutPreset,
    /// Mean job arrival rate (jobs/s of simulated time).
    pub arrival_rate_hz: f64,
    /// Number of jobs in the arrival stream.
    pub jobs: u32,
    /// Queueing deadline: a job abandons after waiting this long (s).
    pub deadline_s: f64,
    /// Allow dynamic MIG reconfiguration of drained GPUs.
    pub reconfig: bool,
    pub seed: u64,
    pub workload_scale: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            gpus: 4,
            policy: PolicyKind::FirstFit,
            layout: LayoutPreset::Mixed,
            arrival_rate_hz: 1.0,
            jobs: 60,
            deadline_s: 600.0,
            reconfig: true,
            seed: 0x5EED,
            workload_scale: 1.0,
        }
    }
}

/// Which serve implementation runs: the indexed O(changed-state) hot path
/// (the default) or the naive full-rescan oracle kept for differential
/// testing — for a fixed config both produce bit-identical reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeMode {
    Indexed,
    NaiveOracle,
}

/// The serving job mix: the paper's suite plus the §VI large variants
/// (which exceed a 1g.12gb slice and make offloading matter).
pub fn serve_mix() -> Vec<(AppId, f64)> {
    let mut mix = JobTrace::suite_mix();
    mix.push((AppId::Llama3Fp16, 2.0));
    mix.push((AppId::Qiskit31, 1.5));
    mix.push((AppId::FaissLarge, 1.5));
    mix
}

/// Aggregate outcome of one serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub policy: String,
    pub layout: String,
    pub gpus: u32,
    pub jobs: u32,
    pub arrival_rate_hz: f64,
    pub completed: u32,
    pub expired: u32,
    pub rejected: u32,
    /// Completed jobs that ran with C2C offloading.
    pub offloaded: u32,
    /// MIG reconfigurations performed across the fleet.
    pub reconfigs: u32,
    /// Simulation events dispatched by the serving loop.
    pub events: u64,
    /// Serving horizon: last completion/expiry instant (s).
    pub makespan_s: f64,
    /// Admitted throughput: completed jobs per second of horizon.
    pub throughput_jobs_s: f64,
    pub wait_mean_s: f64,
    pub wait_p50_s: f64,
    pub wait_p95_s: f64,
    pub wait_p99_s: f64,
    /// Time-averaged fraction of fleet SMs running jobs.
    pub utilization: f64,
    /// Time-averaged fraction of idle SMs stranded in slots too small for
    /// the smallest waiting job.
    pub fragmentation: f64,
    /// Fleet energy integrated over the run (J), via `gpu::PowerModel`.
    pub energy_j: f64,
}

impl ServeReport {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("policy", self.policy.as_str())
            .set("layout", self.layout.as_str())
            .set("gpus", self.gpus)
            .set("jobs", self.jobs)
            .set("arrival_rate_hz", self.arrival_rate_hz)
            .set("completed", self.completed)
            .set("expired", self.expired)
            .set("rejected", self.rejected)
            .set("offloaded", self.offloaded)
            .set("reconfigs", self.reconfigs)
            .set("events", self.events)
            .set("makespan_s", self.makespan_s)
            .set("throughput_jobs_s", self.throughput_jobs_s)
            .set("wait_mean_s", self.wait_mean_s)
            .set("wait_p50_s", self.wait_p50_s)
            .set("wait_p95_s", self.wait_p95_s)
            .set("wait_p99_s", self.wait_p99_s)
            .set("utilization", self.utilization)
            .set("fragmentation", self.fragmentation)
            .set("energy_j", self.energy_j);
        o
    }

    pub fn summary(&self) -> String {
        format!(
            "serve {} on {} x{} @ {:.2} jobs/s\n\
             jobs: {} completed, {} expired, {} rejected ({} offloaded, {} reconfigs)\n\
             throughput {:.3} jobs/s over {:.1} s  wait p50/p95/p99 {:.2}/{:.2}/{:.2} s\n\
             utilization {:.1}%  fragmentation {:.1}%  energy {:.1} kJ  ({} events)",
            self.policy,
            self.layout,
            self.gpus,
            self.arrival_rate_hz,
            self.completed,
            self.expired,
            self.rejected,
            self.offloaded,
            self.reconfigs,
            self.throughput_jobs_s,
            self.makespan_s,
            self.wait_p50_s,
            self.wait_p95_s,
            self.wait_p99_s,
            self.utilization * 100.0,
            self.fragmentation * 100.0,
            self.energy_j / 1e3,
            self.events,
        )
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    Arrival(u32),
    Deadline(u32),
    JobDone { gpu: usize, slot: usize },
    ReconfigDone(usize),
}

/// Run one serving simulation on the indexed hot path. Deterministic for
/// a fixed config.
pub fn serve(cfg: &ServeConfig) -> crate::Result<ServeReport> {
    serve_with(cfg, ServeMode::Indexed)
}

/// Run one serving simulation under an explicit `ServeMode`.
pub fn serve_with(cfg: &ServeConfig, mode: ServeMode) -> crate::Result<ServeReport> {
    ensure!(cfg.gpus >= 1, "serve needs at least one GPU");
    ensure!(cfg.jobs >= 1, "serve needs at least one job");
    ensure!(cfg.arrival_rate_hz > 0.0, "arrival rate must be positive");
    ensure!(cfg.deadline_s > 0.0, "deadline must be positive");

    let mut planner = Planner::new(cfg.workload_scale);
    let mut fleet = Fleet::new(cfg.gpus, cfg.layout)?;
    let trace = JobTrace::poisson(cfg.jobs, 1.0 / cfg.arrival_rate_hz, &serve_mix(), cfg.seed);
    let mut queue = AdmissionQueue::new();
    let mut engine: Engine<Ev> = Engine::new();
    for job in &trace.jobs {
        engine.schedule_at(sec_to_ns(job.arrival_s), Ev::Arrival(job.id));
    }

    let power_model = PowerModel::h100();
    let mut power = PowerTracker::new(mode, &fleet);
    let mut scratch = DispatchScratch::new();
    // Pending deadline events, cancelled on placement so the event loop
    // (and the energy integral) ends at the last real state change
    // instead of idling until `last arrival + deadline`.
    let mut deadline_tokens: Vec<Option<EventToken>> = vec![None; cfg.jobs as usize];
    let mut energy_j = 0.0f64;
    let mut frag_integral = 0.0f64;
    let mut busy_sm_integral = 0.0f64;
    let mut last_t = 0.0f64;

    while let Some(ev) = engine.pop() {
        let now = ns_to_sec(ev.time_ns);
        let dt = now - last_t;
        // Integrate only while serving work remains (jobs still to arrive
        // or unresolved). Once the final job resolves, the only events
        // left are trailing reconfig completions, and charging idle power
        // past the horizon would skew the energy comparison between runs
        // (the metrics all cover [0, horizon]). Mid-run idle gaps between
        // arrivals still count — the fleet is powered on, waiting.
        let resolved = match mode {
            ServeMode::Indexed => queue.all_resolved(),
            ServeMode::NaiveOracle => queue.all_resolved_scan(),
        };
        let work_remains = queue.jobs.len() < cfg.jobs as usize || !resolved;
        if dt > 0.0 && work_remains {
            energy_j += dt * power.power_w(&fleet, &power_model);
            let smallest = match mode {
                ServeMode::Indexed => queue.smallest_pending_footprint_gib(),
                ServeMode::NaiveOracle => queue.smallest_pending_footprint_scan(),
            };
            let needed = smallest.map(|f| f + planner.ctx_gib());
            let frag = match mode {
                ServeMode::Indexed => fleet.fragmentation(needed),
                ServeMode::NaiveOracle => fleet.fragmentation_scan(needed),
            };
            frag_integral += dt * frag;
            let busy = match mode {
                ServeMode::Indexed => fleet.busy_sms(),
                ServeMode::NaiveOracle => fleet.busy_sms_scan(),
            };
            busy_sm_integral += dt * busy as f64;
        }
        last_t = now;
        match ev.event {
            Ev::Arrival(id) => {
                let job = trace.jobs[id as usize].clone();
                let app = job.app;
                queue.admit(job, cfg.deadline_s);
                if planner.servable(app, cfg.policy.allows_offload()) {
                    // The queue's deadline_s is the single source of truth
                    // for when this job abandons.
                    let abandon_s = queue.jobs[id as usize].deadline_s;
                    deadline_tokens[id as usize] =
                        Some(engine.schedule_at(sec_to_ns(abandon_s), Ev::Deadline(id)));
                    dispatch(
                        cfg,
                        mode,
                        now,
                        &mut fleet,
                        &mut queue,
                        &mut planner,
                        &mut engine,
                        &mut power,
                        &mut deadline_tokens,
                        &mut scratch,
                    );
                } else {
                    queue.reject(id, now);
                }
            }
            Ev::Deadline(id) => {
                deadline_tokens[id as usize] = None;
                queue.expire_if_pending(id, now);
            }
            Ev::JobDone { gpu, slot } => {
                if let Some(job) = fleet.finish_job(gpu, slot, now) {
                    queue.mark_completed(job, now);
                    power.on_finish(gpu, slot);
                    dispatch(
                        cfg,
                        mode,
                        now,
                        &mut fleet,
                        &mut queue,
                        &mut planner,
                        &mut engine,
                        &mut power,
                        &mut deadline_tokens,
                        &mut scratch,
                    );
                }
            }
            Ev::ReconfigDone(gpu) => {
                fleet.finish_reconfig(gpu);
                power.on_reconfig_done(gpu, fleet.nodes[gpu].slots.len());
                dispatch(
                    cfg,
                    mode,
                    now,
                    &mut fleet,
                    &mut queue,
                    &mut planner,
                    &mut engine,
                    &mut power,
                    &mut deadline_tokens,
                    &mut scratch,
                );
            }
        }
    }

    debug_assert!(queue.all_resolved(), "events drained with unresolved jobs");
    debug_assert!(queue.all_resolved_scan(), "resolution counter diverged");
    let horizon = queue.horizon_s().max(1e-9);
    let waits = queue.completed_waits();
    let pct = |p: f64| {
        if waits.is_empty() {
            0.0
        } else {
            percentile(&waits, p)
        }
    };
    let mut wacc = Accum::new();
    waits.iter().for_each(|&w| wacc.push(w));
    let completed = queue.count(JobState::Completed);
    let offloaded = queue
        .jobs
        .iter()
        .filter(|j| j.state == JobState::Completed && j.offloaded)
        .count() as u32;
    Ok(ServeReport {
        policy: cfg.policy.label(),
        layout: cfg.layout.label().to_string(),
        gpus: cfg.gpus,
        jobs: cfg.jobs,
        arrival_rate_hz: cfg.arrival_rate_hz,
        completed,
        expired: queue.count(JobState::Expired),
        rejected: queue.count(JobState::Rejected),
        offloaded,
        reconfigs: fleet.nodes.iter().map(|n| n.reconfigs).sum(),
        events: engine.popped(),
        makespan_s: horizon,
        throughput_jobs_s: completed as f64 / horizon,
        wait_mean_s: wacc.mean(),
        wait_p50_s: pct(50.0),
        wait_p95_s: pct(95.0),
        wait_p99_s: pct(99.0),
        utilization: busy_sm_integral / (fleet.total_sms() as f64 * horizon),
        fragmentation: frag_integral / horizon,
        energy_j,
    })
}

/// Reusable dispatch state: the pending-id snapshot buffer and the
/// per-app placement-failure memo. A placement that failed at fleet
/// epoch E keeps failing while the epoch stays E — every mutation since
/// only *removed* capacity — so repeat attempts for the same app are
/// skipped without touching the planner.
struct DispatchScratch {
    ids: Vec<u32>,
    failed_at_epoch: [Option<u64>; AppId::COUNT],
}

impl DispatchScratch {
    fn new() -> DispatchScratch {
        DispatchScratch {
            ids: Vec::new(),
            failed_at_epoch: [None; AppId::COUNT],
        }
    }
}

/// Try to place every pending job (FIFO with backfilling: a blocked head
/// does not starve smaller jobs behind it). When a job fits no layout the
/// fleet currently has — or is already reconfiguring toward — and
/// reconfiguration is enabled, repartition one drained GPU toward the
/// job's profile class.
#[allow(clippy::too_many_arguments)]
fn dispatch(
    cfg: &ServeConfig,
    mode: ServeMode,
    now: f64,
    fleet: &mut Fleet,
    queue: &mut AdmissionQueue,
    planner: &mut Planner,
    engine: &mut Engine<Ev>,
    power: &mut PowerTracker,
    deadline_tokens: &mut [Option<EventToken>],
    scratch: &mut DispatchScratch,
) {
    let DispatchScratch {
        ids,
        failed_at_epoch,
    } = scratch;
    ids.clear();
    ids.extend(queue.pending_ids());
    for &id in ids.iter() {
        let app = queue.jobs[id as usize].job.app;
        let placed = match mode {
            ServeMode::Indexed => {
                if failed_at_epoch[app.index()] == Some(fleet.epoch()) {
                    // Provably still fails: no capacity came back since
                    // the last failed attempt for this app.
                    None
                } else {
                    let r = planner.place(fleet, app, cfg.policy);
                    if r.is_none() {
                        failed_at_epoch[app.index()] = Some(fleet.epoch());
                    }
                    r
                }
            }
            ServeMode::NaiveOracle => planner.place_scan(fleet, app, cfg.policy),
        };
        if let Some((g, s, c)) = placed {
            queue.mark_running(id, now, g, c.offloaded);
            if let Some(tok) = deadline_tokens[id as usize].take() {
                engine.cancel(tok);
            }
            let until = now + c.runtime_s;
            fleet.start_job(g, s, id, now, until);
            power.on_start(g, s, c);
            engine.schedule_at(sec_to_ns(until), Ev::JobDone { gpu: g, slot: s });
        } else if cfg.reconfig {
            let fits = match mode {
                ServeMode::Indexed => {
                    planner.fits_current_layouts(fleet, app, cfg.policy.allows_offload())
                }
                ServeMode::NaiveOracle => {
                    planner.fits_current_layouts_scan(fleet, app, cfg.policy.allows_offload())
                }
            };
            if !fits {
                // Memoized footprint: same constant either mode would
                // compute, without rebuilding the app model per attempt.
                let need = planner.footprint_gib(app) + planner.ctx_gib();
                let plan = match mode {
                    ServeMode::Indexed => reconfig::plan_reconfig(fleet, need),
                    ServeMode::NaiveOracle => reconfig::plan_reconfig_scan(fleet, need),
                };
                if let Some((g, target)) = plan {
                    let until = now + reconfig::latency_s(&fleet.nodes[g].layout, &target);
                    if fleet.begin_reconfig(g, target, until).is_ok() {
                        engine.schedule_at(sec_to_ns(until), Ev::ReconfigDone(g));
                    }
                }
            }
        }
    }
}

/// Live per-GPU power bookkeeping. The naive oracle rebuilds every GPU's
/// usage from the full running map on each integration step; the indexed
/// path recomputes only GPUs whose running set changed and caches the
/// per-GPU reported watts (summed in the same ascending-GPU order, so the
/// energy integral is bit-identical).
enum PowerTracker {
    Naive {
        /// Activity rates of running jobs, keyed by (gpu, slot). BTreeMap
        /// so float summation order — and thus the energy integral — is
        /// deterministic.
        running: BTreeMap<(usize, usize), PlacementCost>,
    },
    Indexed {
        nodes: Vec<NodePower>,
    },
}

struct NodePower {
    /// Running-job costs by slot index (iterated in slot order — the same
    /// order the naive BTreeMap visits a GPU's jobs in).
    costs: Vec<Option<PlacementCost>>,
    dirty: bool,
    watts: f64,
}

impl PowerTracker {
    fn new(mode: ServeMode, fleet: &Fleet) -> PowerTracker {
        match mode {
            ServeMode::NaiveOracle => PowerTracker::Naive {
                running: BTreeMap::new(),
            },
            ServeMode::Indexed => PowerTracker::Indexed {
                nodes: fleet
                    .nodes
                    .iter()
                    .map(|n| NodePower {
                        costs: vec![None; n.slots.len()],
                        dirty: true,
                        watts: 0.0,
                    })
                    .collect(),
            },
        }
    }

    fn on_start(&mut self, gpu: usize, slot: usize, c: PlacementCost) {
        match self {
            PowerTracker::Naive { running } => {
                running.insert((gpu, slot), c);
            }
            PowerTracker::Indexed { nodes } => {
                nodes[gpu].costs[slot] = Some(c);
                nodes[gpu].dirty = true;
            }
        }
    }

    fn on_finish(&mut self, gpu: usize, slot: usize) {
        match self {
            PowerTracker::Naive { running } => {
                running.remove(&(gpu, slot));
            }
            PowerTracker::Indexed { nodes } => {
                nodes[gpu].costs[slot] = None;
                nodes[gpu].dirty = true;
            }
        }
    }

    /// A reconfiguration landed on `gpu`: the slot count changed (the
    /// node is drained, so there are no running costs to carry over).
    fn on_reconfig_done(&mut self, gpu: usize, slots: usize) {
        match self {
            PowerTracker::Naive { .. } => {}
            PowerTracker::Indexed { nodes } => {
                nodes[gpu].costs.clear();
                nodes[gpu].costs.resize(slots, None);
                nodes[gpu].dirty = true;
            }
        }
    }

    /// Instantaneous fleet power (W).
    fn power_w(&mut self, fleet: &Fleet, model: &PowerModel) -> f64 {
        match self {
            PowerTracker::Naive { running } => fleet_power_w_scan(fleet, model, running),
            PowerTracker::Indexed { nodes } => {
                for (g, np) in nodes.iter_mut().enumerate() {
                    if np.dirty {
                        np.watts = node_power_w(fleet, model, g, &np.costs);
                        np.dirty = false;
                    }
                }
                nodes.iter().map(|np| np.watts).sum()
            }
        }
    }
}

/// Per-GPU `PowerModel` demand from one node's running jobs (indexed
/// path). Accumulation order matches the naive scan: rates added in
/// ascending slot order into a fresh `GpuUsage`.
fn node_power_w(
    fleet: &Fleet,
    model: &PowerModel,
    gpu: usize,
    costs: &[Option<PlacementCost>],
) -> f64 {
    let spec = &fleet.spec;
    let busy = fleet.nodes[gpu].busy_sms();
    let mut u = GpuUsage {
        context_active: busy > 0,
        sm_busy_frac: busy as f64 / spec.sms as f64,
        ..GpuUsage::default()
    };
    for c in costs.iter().flatten() {
        for (i, f) in c.flop_tflops.iter().enumerate() {
            u.flop_rate_tflops[i] += *f;
        }
        u.hbm_rate_tbs += c.hbm_tbs;
        u.c2c_rate_tbs += c.c2c_tbs;
    }
    model.reported_w(spec, &u, spec.clock_max_mhz)
}

/// Instantaneous fleet power, rebuilt from scratch — the oracle (no DVFS
/// governor here — serving jobs on MIG slices stays under the cap, which
/// `reported_w` enforces anyway).
fn fleet_power_w_scan(
    fleet: &Fleet,
    model: &PowerModel,
    running: &BTreeMap<(usize, usize), PlacementCost>,
) -> f64 {
    let spec = &fleet.spec;
    let mut usages: Vec<GpuUsage> = vec![GpuUsage::default(); fleet.nodes.len()];
    for (g, node) in fleet.nodes.iter().enumerate() {
        let busy = node.busy_sms_scan();
        usages[g].context_active = busy > 0;
        usages[g].sm_busy_frac = busy as f64 / spec.sms as f64;
    }
    for (&(g, _), c) in running {
        let u = &mut usages[g];
        for (i, f) in c.flop_tflops.iter().enumerate() {
            u.flop_rate_tflops[i] += *f;
        }
        u.hbm_rate_tbs += c.hbm_tbs;
        u.c2c_rate_tbs += c.c2c_tbs;
    }
    usages
        .iter()
        .map(|u| model.reported_w(spec, u, spec.clock_max_mhz))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg() -> ServeConfig {
        ServeConfig {
            gpus: 2,
            policy: PolicyKind::FirstFit,
            layout: LayoutPreset::Mixed,
            arrival_rate_hz: 0.5,
            jobs: 30,
            deadline_s: 40.0,
            reconfig: true,
            seed: 7,
            workload_scale: 0.05,
        }
    }

    #[test]
    fn serve_resolves_every_job_and_reports_sane_metrics() {
        let r = serve(&base_cfg()).unwrap();
        assert_eq!(r.completed + r.expired + r.rejected, 30);
        assert!(r.completed > 0);
        assert!(r.events > 0);
        assert!(r.makespan_s > 0.0);
        assert!(r.throughput_jobs_s > 0.0);
        assert!((0.0..=1.0).contains(&r.utilization), "{}", r.utilization);
        assert!((0.0..=1.0).contains(&r.fragmentation));
        assert!(r.energy_j.is_finite() && r.energy_j > 0.0);
        assert!(r.wait_p99_s >= r.wait_p95_s && r.wait_p95_s >= r.wait_p50_s);
        assert!(r.wait_p99_s <= 40.0 + 1e-9, "waits bounded by the deadline");
    }

    #[test]
    fn offload_aware_beats_first_fit_on_small_slices_under_load() {
        // All-small fleet, saturated, no reconfiguration: first-fit can
        // never place the ~1/3 of jobs that exceed 11 GiB; offload-aware
        // admits them onto 1g slices over C2C — the paper's §VI story as
        // an online policy.
        let cfg = ServeConfig {
            layout: LayoutPreset::AllSmall,
            arrival_rate_hz: 4.0,
            jobs: 40,
            deadline_s: 20.0,
            reconfig: false,
            ..base_cfg()
        };
        let ff = serve(&cfg).unwrap();
        let off = serve(&ServeConfig {
            policy: PolicyKind::OffloadAware { alpha_centi: 10 },
            ..cfg.clone()
        })
        .unwrap();
        assert!(
            off.completed > ff.completed,
            "offload-aware {} vs first-fit {}",
            off.completed,
            ff.completed
        );
        assert!(off.throughput_jobs_s > ff.throughput_jobs_s);
        assert!(off.offloaded > 0);
        assert_eq!(ff.offloaded, 0);
    }

    #[test]
    fn reconfiguration_rescues_large_jobs_on_small_layouts() {
        // Lightly-loaded all-small fleet with first-fit: large jobs fit
        // nothing until a drained GPU is repartitioned.
        let cfg = ServeConfig {
            layout: LayoutPreset::AllSmall,
            arrival_rate_hz: 0.2,
            jobs: 20,
            deadline_s: 60.0,
            reconfig: true,
            ..base_cfg()
        };
        let dynamic = serve(&cfg).unwrap();
        let static_ = serve(&ServeConfig {
            reconfig: false,
            ..cfg.clone()
        })
        .unwrap();
        assert!(dynamic.reconfigs > 0, "reconfiguration must trigger");
        assert_eq!(static_.reconfigs, 0);
        assert!(
            dynamic.completed > static_.completed,
            "reconfig {} vs static {}",
            dynamic.completed,
            static_.completed
        );
        assert!(static_.expired > 0, "static small layout strands large jobs");
    }

    #[test]
    fn indexed_and_oracle_modes_agree_bit_for_bit() {
        // The full policy × layout × seed grid lives in
        // tests/integration.rs; this is the in-module smoke version.
        let cfg = ServeConfig {
            policy: PolicyKind::OffloadAware { alpha_centi: 10 },
            arrival_rate_hz: 2.0,
            ..base_cfg()
        };
        let fast = serve_with(&cfg, ServeMode::Indexed).unwrap();
        let oracle = serve_with(&cfg, ServeMode::NaiveOracle).unwrap();
        assert_eq!(fast.to_json().pretty(), oracle.to_json().pretty());
    }

    #[test]
    fn report_json_round_trips() {
        let r = serve(&ServeConfig {
            jobs: 10,
            ..base_cfg()
        })
        .unwrap();
        let doc = r.to_json();
        let back = Json::parse(&doc.pretty()).unwrap();
        assert_eq!(back, doc);
        assert_eq!(
            back.get("completed").unwrap().as_u64(),
            Some(r.completed as u64)
        );
    }
}
