//! Online cluster serving: offload-aware admission, placement, and
//! dynamic MIG reconfiguration over a multi-GPU fleet.
//!
//! This is the closed loop the rest of the crate feeds: a stream of
//! Table III jobs (plus the §VI large variants) — synthetic Poisson by
//! default, or a replayed `JobTrace` arrival log — arrives at a fleet of
//! statically-partitioned GH200 GPUs; an admission queue holds them
//! against a deadline; a placement policy (`placement::PolicyKind`) maps
//! each job to a MIG slot — directly, or through an NVLink-C2C
//! `OffloadPlan` onto a smaller slice; and, when a job fits no current
//! layout, a drained GPU can be repartitioned at a modeled latency cost
//! (`reconfig`). The loop is event-driven over `sim::Engine` and fully
//! deterministic for a fixed seed.
//!
//! Module map:
//! - `fleet`: GPUs, layouts, slots (each hosting up to `batch`
//!   co-resident jobs — MPS-within-MIG continuous batching), the
//!   reconfiguration state machine, and the incremental
//!   per-(profile, occupancy) open index.
//! - `hostmem`: the host-memory resource plane — finite per-node Grace
//!   pools (offload spill is charged in integer bytes and gated at
//!   admission) and contended C2C links (each GPU's one link is
//!   time-shared across its co-offloading residents). The defaults
//!   (`--host-pool inf --c2c-contention off`) reproduce the pre-plane
//!   reports bit-for-bit.
//! - `queue`: FIFO admission with deadlines, lifecycle accounting, and
//!   live pending/resolution counters.
//! - `placement`: first-fit / best-fit / offload-aware policies over a
//!   dense memoized cost model (runtime + power rates per
//!   app×profile×occupancy, the co-residency slowdown derived from the
//!   `sharing::MigSharedGi` co-run model); placement decisions walk
//!   ≤ 6×batch co-residency classes via the fleet index.
//! - `reconfig`: valid-partition-preserving layout planning + latency.
//! - `shard`: the serving event loop itself (one `Shard` = one node of
//!   the control plane), plus the sharded multi-node runner: N parallel
//!   per-node event loops lock-stepped in lookahead-bounded epochs with a
//!   deterministic cross-node dispatcher (`serve_sharded`).
//!
//! ## The hot path, and its oracles
//!
//! Per-event cost is O(changed state), not O(fleet): placement walks the
//! per-(profile, occupancy) open index; the energy/fragmentation/utilization integrals
//! consume live counters (fleet busy-SMs, per-class idle counts, per-app
//! pending buckets) and a per-GPU power cache that only recomputes GPUs
//! whose running set changed; dispatch reuses scratch buffers and
//! memoizes placement failures per app until the fleet epoch shows
//! capacity returning. `ServeMode::NaiveOracle` keeps the original
//! full-rescan implementation of every one of those decisions; both modes
//! produce bit-identical `ServeReport`s for a fixed seed (differentially
//! tested in `tests/integration.rs`).
//!
//! Beyond one node: `serve` *is* a single-shard run of the `shard`
//! machinery, which makes it the oracle for the sharded path — a 1-node
//! sharded run reproduces it bit-for-bit, and an N-node run is
//! bit-identical for every worker thread count.
//!
//! Outputs (`ServeReport`): admitted throughput, p50/p95/p99 queueing
//! latency, fleet utilization, fragmentation, and energy integrated
//! through the `gpu::PowerModel`.

pub mod estimate;
pub mod faults;
pub mod fleet;
pub mod hostmem;
pub mod placement;
pub mod power;
pub mod queue;
pub mod reconfig;
pub mod shard;
pub mod telemetry;

pub use estimate::{CostSource, EstimatorConfig, EstimatorState, EstimatorStats};
pub use faults::{FaultConfig, FaultDomains, FaultKind, ShedPolicy};
pub use fleet::{Fleet, LayoutPreset, MAX_BATCH};
pub use hostmem::{HostMemConfig, HostPool};
pub use placement::{Placement, PlacementCost, Planner, PolicyKind};
pub use power::{PowerPlaneConfig, PowerView};
pub use queue::{AdmissionQueue, JobState};
pub use shard::{
    serve_sharded, serve_sharded_replay, serve_sharded_streamed, serve_sharded_traced, RouteKind,
    ShardServeConfig, ShardSummary, ShardedServeReport,
};
pub use telemetry::{TelemetryConfig, TelemetryReport, TelemetryStreamer};

use crate::util::json::Json;
use crate::util::units::ns_to_sec;
use crate::workload::trace::JobTrace;
use crate::workload::{apps, AppId};
use anyhow::ensure;

/// Configuration of one serving run.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub gpus: u32,
    pub policy: PolicyKind,
    pub layout: LayoutPreset,
    /// Mean job arrival rate (jobs/s of simulated time).
    pub arrival_rate_hz: f64,
    /// Number of jobs in the arrival stream.
    pub jobs: u32,
    /// Queueing deadline: a job abandons after waiting this long (s).
    pub deadline_s: f64,
    /// Allow dynamic MIG reconfiguration of drained GPUs.
    pub reconfig: bool,
    pub seed: u64,
    pub workload_scale: f64,
    /// Max co-resident jobs per MIG slot under MPS-within-MIG semantics
    /// (`1..=MAX_BATCH`). `1` is the classic one-job-per-slot system and
    /// reproduces its reports bit-for-bit; `K > 1` lets a slice host up
    /// to `K` jobs, each slowed by the `MigSharedGi`-derived contention
    /// model and admitted only while the slice's memory holds every
    /// resident (footprint + per-process context).
    pub batch: u32,
    /// Grace host-memory pool per node shard (GiB; `f64::INFINITY` — the
    /// default — disables the gate). Every offloaded job parks its spill
    /// here while it runs; admission of an offload is gated on pool
    /// headroom. See `cluster::hostmem`.
    pub host_pool_gib: f64,
    /// Time-share each GPU's single C2C link across its co-offloading
    /// residents (an offloaded job sharing with `n − 1` others sees `1/n`
    /// of the direct-access rate). `false` — the default — keeps the
    /// pre-plane private-link model and reproduces its reports
    /// bit-for-bit.
    pub c2c_contention: bool,
    /// Weight of the energy-per-job term in the offload-aware reward
    /// (`0.0` — the default — is the paper's pure §VI-B reward,
    /// bit-for-bit).
    pub energy_weight: f64,
    /// The fault-injection plane (`cluster::faults`). The default is
    /// inert — no fault events are scheduled and every report reproduces
    /// the pre-plane bytes exactly.
    pub faults: FaultConfig,
    /// The fleet power plane (`cluster::power`). The default is inert —
    /// no cap is priced, the legacy clamped-sensor energy model is kept,
    /// and every report reproduces the pre-plane bytes exactly.
    pub power: PowerPlaneConfig,
    /// The online profiling plane (`cluster::estimate`). The default is
    /// inert — every placement runs on the oracle cost tables and every
    /// report reproduces the pre-plane bytes exactly. When enabled, all
    /// policies rank candidates on learned cost estimates while the
    /// oracle is retained as the regret baseline.
    pub estimator: EstimatorConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            gpus: 4,
            policy: PolicyKind::FirstFit,
            layout: LayoutPreset::Mixed,
            arrival_rate_hz: 1.0,
            jobs: 60,
            deadline_s: 600.0,
            reconfig: true,
            seed: 0x5EED,
            workload_scale: 1.0,
            batch: 1,
            host_pool_gib: f64::INFINITY,
            c2c_contention: false,
            energy_weight: 0.0,
            faults: FaultConfig::default(),
            power: PowerPlaneConfig::default(),
            estimator: EstimatorConfig::default(),
        }
    }
}

impl ServeConfig {
    /// Validate the host-memory-plane knobs (the rest of the config is
    /// validated where it is consumed).
    fn validate_hostmem(&self) -> crate::Result<()> {
        HostMemConfig {
            pool_gib: self.host_pool_gib,
            c2c_contention: self.c2c_contention,
        }
        .validate()?;
        ensure!(
            self.energy_weight >= 0.0 && self.energy_weight.is_finite(),
            "energy weight must be finite and non-negative, got {}",
            self.energy_weight
        );
        self.faults.validate()?;
        self.power.validate()?;
        self.estimator.validate()?;
        Ok(())
    }
}

/// Which serve implementation runs: the indexed O(changed-state) hot path
/// (the default) or the naive full-rescan oracle kept for differential
/// testing — for a fixed config both produce bit-identical reports.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServeMode {
    Indexed,
    NaiveOracle,
}

/// The serving job mix: the paper's suite plus the §VI large variants
/// (which exceed a 1g.12gb slice and make offloading matter).
pub fn serve_mix() -> Vec<(AppId, f64)> {
    let mut mix = JobTrace::suite_mix();
    mix.push((AppId::Llama3Fp16, 2.0));
    mix.push((AppId::Qiskit31, 1.5));
    mix.push((AppId::FaissLarge, 1.5));
    mix
}

/// Aggregate outcome of one serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub policy: String,
    pub layout: String,
    pub gpus: u32,
    pub jobs: u32,
    pub arrival_rate_hz: f64,
    pub completed: u32,
    pub expired: u32,
    pub rejected: u32,
    /// Jobs lost to hardware faults after exhausting their retry budget
    /// (terminal `JobState::Failed`; 0 with the fault plane inert).
    pub failed: u32,
    /// Pending jobs dropped by brown-out backpressure (terminal
    /// `JobState::Shed`; 0 without `--shed-policy`).
    pub shed: u32,
    /// Completed jobs that ran with C2C offloading.
    pub offloaded: u32,
    /// MIG reconfigurations performed across the fleet.
    pub reconfigs: u32,
    /// Hardware faults injected by the fault plane (all kinds).
    pub faults: u32,
    /// Correlated domain-level fault events fired (0 without
    /// `--fault-domains`; each one cordons a whole node or rack).
    pub domain_faults: u32,
    /// Fault-orphaned jobs requeued as retries.
    pub retries: u32,
    /// Whether the fault plane was active for this run. Gates the
    /// serialization of the fault counters above: an inert run emits
    /// exactly the pre-plane JSON, byte-for-byte (the golden-fixture
    /// contract). Not itself serialized.
    pub faults_active: bool,
    /// Whether any graceful-degradation knob (domains, finite crews,
    /// shedding) was set. Gates `shed`/`domain_faults` on the wire, so a
    /// knobless faulted run keeps its pre-degrade bytes. Not serialized.
    pub degrade_active: bool,
    /// Whether the power plane was active. Gates the power block on the
    /// wire, so an uncapped run keeps its pre-plane bytes. Not itself
    /// serialized.
    pub power_active: bool,
    /// Shared per-GPU power budget (W; `inf` = never throttles).
    pub power_cap_w: f64,
    /// Node-wide activity-draw budget (W; `inf` = no admission gate).
    pub node_power_cap_w: f64,
    /// GPU-seconds spent at a throttle level > 0.
    pub throttled_gpu_s: f64,
    /// GPU-seconds spent parked at the deep-idle floor.
    pub parked_gpu_s: f64,
    /// Failed placement visits where even the cheapest admissible class
    /// exceeded the node budget's headroom.
    pub power_starved: u64,
    /// Whether the online profiling plane was active. Gates the
    /// estimator block on the wire, so an oracle run keeps its pre-plane
    /// bytes. Not itself serialized.
    pub estimator_active: bool,
    /// Probe counts, placement decisions taken on estimated tables, and
    /// the measured estimate-vs-oracle regret (total/max/per-app).
    pub estimator: EstimatorStats,
    /// Simulation events dispatched by the serving loop.
    pub events: u64,
    /// Serving horizon: last completion/expiry instant (s).
    pub makespan_s: f64,
    /// Admitted throughput: completed jobs per second of horizon.
    pub throughput_jobs_s: f64,
    pub wait_mean_s: f64,
    pub wait_p50_s: f64,
    pub wait_p95_s: f64,
    pub wait_p99_s: f64,
    /// Time-averaged fraction of fleet SMs running jobs.
    pub utilization: f64,
    /// Time-averaged fraction of idle SMs stranded in slots too small for
    /// the smallest waiting job.
    pub fragmentation: f64,
    /// Fleet energy integrated over the run (J), via `gpu::PowerModel`.
    pub energy_j: f64,
}

impl ServeReport {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("policy", self.policy.as_str())
            .set("layout", self.layout.as_str())
            .set("gpus", self.gpus)
            .set("jobs", self.jobs)
            .set("arrival_rate_hz", self.arrival_rate_hz)
            .set("completed", self.completed)
            .set("expired", self.expired)
            .set("rejected", self.rejected)
            .set("offloaded", self.offloaded)
            .set("reconfigs", self.reconfigs);
        if self.faults_active {
            // Fault counters only exist on the wire when the plane is
            // active: an inert run's JSON is byte-identical to the
            // pre-plane format (golden fixtures depend on this).
            o.set("failed", self.failed)
                .set("faults", self.faults)
                .set("retries", self.retries);
            if self.degrade_active {
                // Degrade counters likewise only appear once a
                // degradation knob is set: a knobless faulted run keeps
                // its pre-degrade bytes exactly.
                o.set("shed", self.shed)
                    .set("domain_faults", self.domain_faults);
            }
        }
        if self.power_active {
            // The power block likewise only exists on the wire while the
            // plane is active. JSON has no literal for infinity, so an
            // unbounded cap serializes as the string "inf".
            fn cap(w: f64) -> Json {
                if w.is_finite() {
                    Json::from(w)
                } else {
                    Json::from("inf")
                }
            }
            o.set("power_cap_w", cap(self.power_cap_w))
                .set("node_power_cap_w", cap(self.node_power_cap_w))
                .set("throttled_gpu_s", self.throttled_gpu_s)
                .set("parked_gpu_s", self.parked_gpu_s)
                .set("power_starved", self.power_starved);
        }
        if self.estimator_active {
            // The estimator block likewise only exists on the wire while
            // the profiling plane is active. Regret totals are exact
            // integer nanoseconds; the mean is also offered in seconds
            // for human eyes and jq one-liners.
            let st = &self.estimator;
            let mean_ns = if st.decisions > 0 {
                st.regret_sum_ns / st.decisions
            } else {
                0
            };
            let mut by_app = Json::obj();
            for app in apps::all() {
                let i = app.index();
                if st.decisions_by_app[i] == 0 {
                    continue;
                }
                let mut a = Json::obj();
                a.set("decisions", st.decisions_by_app[i])
                    .set("regret_total_ns", st.regret_by_app_ns[i])
                    .set(
                        "regret_mean_s",
                        ns_to_sec(st.regret_by_app_ns[i] / st.decisions_by_app[i]),
                    );
                by_app.set(app.name(), a);
            }
            o.set("probes", st.probes)
                .set("est_decisions", st.decisions)
                .set("regret_total_ns", st.regret_sum_ns)
                .set("regret_mean_s", ns_to_sec(mean_ns))
                .set("regret_max_s", ns_to_sec(st.regret_max_ns))
                .set("regret_by_app", by_app);
        }
        o.set("events", self.events)
            .set("makespan_s", self.makespan_s)
            .set("throughput_jobs_s", self.throughput_jobs_s)
            .set("wait_mean_s", self.wait_mean_s)
            .set("wait_p50_s", self.wait_p50_s)
            .set("wait_p95_s", self.wait_p95_s)
            .set("wait_p99_s", self.wait_p99_s)
            .set("utilization", self.utilization)
            .set("fragmentation", self.fragmentation)
            .set("energy_j", self.energy_j);
        o
    }

    pub fn summary(&self) -> String {
        let fault_line = if self.faults_active {
            let degrade = if self.degrade_active {
                format!(
                    " ({} domain events, {} jobs shed)",
                    self.domain_faults, self.shed
                )
            } else {
                String::new()
            };
            format!(
                "\nfaults: {} injected, {} retries, {} jobs failed{}",
                self.faults, self.retries, self.failed, degrade
            )
        } else {
            String::new()
        };
        let power_line = if self.power_active {
            format!(
                "\npower: cap {}/GPU, {:.1} GPU-s throttled, {:.1} GPU-s parked, {} power-starved",
                if self.power_cap_w.is_finite() {
                    format!("{:.0} W", self.power_cap_w)
                } else {
                    "inf".to_string()
                },
                self.throttled_gpu_s,
                self.parked_gpu_s,
                self.power_starved,
            )
        } else {
            String::new()
        };
        let est_line = if self.estimator_active {
            let st = &self.estimator;
            let mean_ns = if st.decisions > 0 {
                st.regret_sum_ns / st.decisions
            } else {
                0
            };
            format!(
                "\nestimator: {} probes, {} decisions, regret mean {:.4} s / max {:.4} s",
                st.probes,
                st.decisions,
                ns_to_sec(mean_ns),
                ns_to_sec(st.regret_max_ns),
            )
        } else {
            String::new()
        };
        format!(
            "serve {} on {} x{} @ {:.2} jobs/s\n\
             jobs: {} completed, {} expired, {} rejected ({} offloaded, {} reconfigs)\n\
             throughput {:.3} jobs/s over {:.1} s  wait p50/p95/p99 {:.2}/{:.2}/{:.2} s\n\
             utilization {:.1}%  fragmentation {:.1}%  energy {:.1} kJ  ({} events){}",
            self.policy,
            self.layout,
            self.gpus,
            self.arrival_rate_hz,
            self.completed,
            self.expired,
            self.rejected,
            self.offloaded,
            self.reconfigs,
            self.throughput_jobs_s,
            self.makespan_s,
            self.wait_p50_s,
            self.wait_p95_s,
            self.wait_p99_s,
            self.utilization * 100.0,
            self.fragmentation * 100.0,
            self.energy_j / 1e3,
            self.events,
            fault_line,
        ) + &power_line
            + &est_line
    }
}

/// Run one serving simulation on the indexed hot path. Deterministic for
/// a fixed config.
pub fn serve(cfg: &ServeConfig) -> crate::Result<ServeReport> {
    serve_with(cfg, ServeMode::Indexed)
}

/// Run one serving simulation under an explicit `ServeMode`.
pub fn serve_with(cfg: &ServeConfig, mode: ServeMode) -> crate::Result<ServeReport> {
    ensure!(cfg.gpus >= 1, "serve needs at least one GPU");
    ensure!(cfg.jobs >= 1, "serve needs at least one job");
    ensure!(cfg.arrival_rate_hz > 0.0, "arrival rate must be positive");
    ensure!(cfg.deadline_s > 0.0, "deadline must be positive");
    cfg.validate_hostmem()?;
    let trace = JobTrace::poisson(cfg.jobs, 1.0 / cfg.arrival_rate_hz, &serve_mix(), cfg.seed);
    shard::run_single(cfg, mode, &trace.jobs)
}

/// Run one serving simulation with the telemetry plane on: the same
/// simulation as `serve_with` (the `ServeReport` is byte-identical),
/// plus the merged event trace, fleet samples and latency histograms.
/// Everything but the hot-path profiling counters is additionally
/// mode-invariant (`TelemetryReport::oracle_view`).
pub fn serve_traced(
    cfg: &ServeConfig,
    mode: ServeMode,
    tcfg: &TelemetryConfig,
) -> crate::Result<(ServeReport, TelemetryReport)> {
    ensure!(cfg.gpus >= 1, "serve needs at least one GPU");
    ensure!(cfg.jobs >= 1, "serve needs at least one job");
    ensure!(cfg.arrival_rate_hz > 0.0, "arrival rate must be positive");
    ensure!(cfg.deadline_s > 0.0, "deadline must be positive");
    cfg.validate_hostmem()?;
    let trace = JobTrace::poisson(cfg.jobs, 1.0 / cfg.arrival_rate_hz, &serve_mix(), cfg.seed);
    shard::run_single_traced(cfg, mode, &trace.jobs, tcfg)
}

/// Run one serving simulation over a replayed arrival trace instead of
/// the synthetic Poisson stream. The trace is canonicalized (sorted by
/// arrival, densely re-id'd); `cfg.jobs` and `cfg.seed` are ignored —
/// the trace *is* the arrival process. Replaying the trace a synthetic
/// run was built from reproduces that run's `ServeReport` bit-for-bit.
pub fn serve_replay(cfg: &ServeConfig, trace: &JobTrace) -> crate::Result<ServeReport> {
    ensure!(cfg.gpus >= 1, "serve needs at least one GPU");
    ensure!(cfg.arrival_rate_hz > 0.0, "arrival rate must be positive");
    ensure!(cfg.deadline_s > 0.0, "deadline must be positive");
    cfg.validate_hostmem()?;
    let jobs = trace.canonicalized()?.jobs;
    ensure!(!jobs.is_empty(), "replay trace has no jobs");
    let mut cfg = cfg.clone();
    cfg.jobs = jobs.len() as u32;
    shard::run_single(&cfg, ServeMode::Indexed, &jobs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg() -> ServeConfig {
        ServeConfig {
            gpus: 2,
            policy: PolicyKind::FirstFit,
            layout: LayoutPreset::Mixed,
            arrival_rate_hz: 0.5,
            jobs: 30,
            deadline_s: 40.0,
            reconfig: true,
            seed: 7,
            workload_scale: 0.05,
            batch: 1,
            ..ServeConfig::default()
        }
    }

    #[test]
    fn serve_resolves_every_job_and_reports_sane_metrics() {
        let r = serve(&base_cfg()).unwrap();
        assert_eq!(r.completed + r.expired + r.rejected, 30);
        assert!(r.completed > 0);
        assert!(r.events > 0);
        assert!(r.makespan_s > 0.0);
        assert!(r.throughput_jobs_s > 0.0);
        assert!((0.0..=1.0).contains(&r.utilization), "{}", r.utilization);
        assert!((0.0..=1.0).contains(&r.fragmentation));
        assert!(r.energy_j.is_finite() && r.energy_j > 0.0);
        assert!(r.wait_p99_s >= r.wait_p95_s && r.wait_p95_s >= r.wait_p50_s);
        assert!(r.wait_p99_s <= 40.0 + 1e-9, "waits bounded by the deadline");
    }

    #[test]
    fn offload_aware_beats_first_fit_on_small_slices_under_load() {
        // All-small fleet, saturated, no reconfiguration: first-fit can
        // never place the ~1/3 of jobs that exceed 11 GiB; offload-aware
        // admits them onto 1g slices over C2C — the paper's §VI story as
        // an online policy.
        let cfg = ServeConfig {
            layout: LayoutPreset::AllSmall,
            arrival_rate_hz: 4.0,
            jobs: 40,
            deadline_s: 20.0,
            reconfig: false,
            ..base_cfg()
        };
        let ff = serve(&cfg).unwrap();
        let off = serve(&ServeConfig {
            policy: PolicyKind::OffloadAware { alpha_centi: 10 },
            ..cfg.clone()
        })
        .unwrap();
        assert!(
            off.completed > ff.completed,
            "offload-aware {} vs first-fit {}",
            off.completed,
            ff.completed
        );
        assert!(off.throughput_jobs_s > ff.throughput_jobs_s);
        assert!(off.offloaded > 0);
        assert_eq!(ff.offloaded, 0);
    }

    #[test]
    fn reconfiguration_rescues_large_jobs_on_small_layouts() {
        // Lightly-loaded all-small fleet with first-fit: large jobs fit
        // nothing until a drained GPU is repartitioned.
        let cfg = ServeConfig {
            layout: LayoutPreset::AllSmall,
            arrival_rate_hz: 0.2,
            jobs: 20,
            deadline_s: 60.0,
            reconfig: true,
            ..base_cfg()
        };
        let dynamic = serve(&cfg).unwrap();
        let static_ = serve(&ServeConfig {
            reconfig: false,
            ..cfg.clone()
        })
        .unwrap();
        assert!(dynamic.reconfigs > 0, "reconfiguration must trigger");
        assert_eq!(static_.reconfigs, 0);
        assert!(
            dynamic.completed > static_.completed,
            "reconfig {} vs static {}",
            dynamic.completed,
            static_.completed
        );
        assert!(static_.expired > 0, "static small layout strands large jobs");
    }

    #[test]
    fn batching_completes_jobs_that_queueing_expires() {
        // The continuous-batching value proposition, made deterministic:
        // one whole-GPU slot, two jobs arriving at the same instant, and
        // a deadline shorter than one solo service time. Unbatched, job 2
        // must wait a full service time and abandons; with batch 2 it
        // co-locates immediately and both complete. The deadline is
        // derived from the planner's own cost model, so the construction
        // cannot rot as the model evolves.
        use crate::workload::trace::{Job, JobTrace};
        let mut pl = Planner::new(0.05);
        let solo = pl
            .cost(crate::workload::AppId::Hotspot, crate::mig::ProfileId::P7g96gb, false)
            .unwrap()
            .runtime_s;
        let trace = JobTrace {
            jobs: (0..2)
                .map(|id| Job {
                    id,
                    app: crate::workload::AppId::Hotspot,
                    arrival_s: 0.0, // duplicate timestamps, deliberately
                })
                .collect(),
        };
        let cfg = ServeConfig {
            gpus: 1,
            policy: PolicyKind::FirstFit,
            layout: LayoutPreset::AllBig,
            deadline_s: solo * 0.5,
            reconfig: false,
            workload_scale: 0.05,
            ..ServeConfig::default()
        };
        let unbatched = serve_replay(&cfg, &trace).unwrap();
        assert_eq!(unbatched.completed, 1, "slot busy, deadline < solo runtime");
        assert_eq!(unbatched.expired, 1);
        let batched = serve_replay(
            &ServeConfig {
                batch: 2,
                ..cfg.clone()
            },
            &trace,
        )
        .unwrap();
        assert_eq!(batched.completed, 2, "co-residency rescues the second job");
        assert_eq!(batched.expired, 0);
        // The co-resident ran slower than solo — the makespan shows the
        // contention model at work (both jobs end at the occ-2 runtime,
        // later than the solo completion but far earlier than serial).
        assert!(batched.makespan_s > solo * (1.0 - 1e-9));
        // Co-residency at occ 2 can at most double the compute term (plus
        // the 2.5% interference): far cheaper than serial execution.
        assert!(batched.makespan_s < 2.1 * solo);
    }

    #[test]
    fn finite_pool_starves_the_offload_an_infinite_pool_serves() {
        // The host-pool gate end-to-end, made deterministic: one
        // all-small GPU, two llama jobs arriving together, a deadline
        // shorter than one offloaded service time, no reconfiguration.
        // With an unlimited pool both offload immediately onto separate
        // 1g slices and complete; with a pool that holds exactly one
        // spill the second job cannot park its overflow anywhere and
        // expires waiting for the first to release the pool.
        use crate::workload::trace::{Job, JobTrace};
        let mut pl = Planner::new(0.05);
        let c = pl
            .cost(crate::workload::AppId::Llama3Fp16, crate::mig::ProfileId::P1g12gb, true)
            .unwrap();
        assert!(c.offloaded && c.host_gib > 0.0);
        let trace = JobTrace {
            jobs: (0..2)
                .map(|id| Job {
                    id,
                    app: crate::workload::AppId::Llama3Fp16,
                    arrival_s: 0.0,
                })
                .collect(),
        };
        let cfg = ServeConfig {
            gpus: 1,
            policy: PolicyKind::OffloadAware { alpha_centi: 10 },
            layout: LayoutPreset::AllSmall,
            deadline_s: c.runtime_s * 0.5,
            reconfig: false,
            workload_scale: 0.05,
            ..ServeConfig::default()
        };
        let unlimited = serve_replay(&cfg, &trace).unwrap();
        assert_eq!(unlimited.completed, 2, "unlimited pool serves both");
        assert_eq!(unlimited.offloaded, 2);
        let finite = serve_replay(
            &ServeConfig {
                host_pool_gib: c.host_gib * 1.5,
                ..cfg.clone()
            },
            &trace,
        )
        .unwrap();
        assert_eq!(finite.completed, 1, "one spill fits, the second starves");
        assert_eq!(finite.offloaded, 1);
        assert_eq!(finite.expired, 1);
    }

    #[test]
    fn hostmem_plane_is_inert_for_non_offloading_policies() {
        // First-fit never offloads, so the plane's knobs must not move a
        // single bit of its report — finite pool and link contention
        // included. This is the structural half of the fixture-compat
        // guarantee (the byte-for-byte half lives in tests/golden.rs).
        let base = base_cfg();
        let plain = serve(&base).unwrap().to_json().pretty();
        let planed = serve(&ServeConfig {
            host_pool_gib: 4.0,
            c2c_contention: true,
            ..base
        })
        .unwrap()
        .to_json()
        .pretty();
        assert_eq!(plain, planed);
    }

    #[test]
    fn hostmem_config_bounds_are_enforced() {
        for bad in [0.0, -1.0, f64::NAN] {
            let r = serve(&ServeConfig {
                host_pool_gib: bad,
                ..base_cfg()
            });
            assert!(r.is_err(), "host pool {bad} must be rejected");
        }
        for bad in [-0.5, f64::INFINITY, f64::NAN] {
            let r = serve(&ServeConfig {
                energy_weight: bad,
                ..base_cfg()
            });
            assert!(r.is_err(), "energy weight {bad} must be rejected");
        }
    }

    #[test]
    fn batch_bounds_are_enforced() {
        for bad in [0u32, MAX_BATCH + 1] {
            let r = serve(&ServeConfig {
                batch: bad,
                ..base_cfg()
            });
            assert!(r.is_err(), "batch={bad} must be rejected");
        }
    }

    #[test]
    fn indexed_and_oracle_modes_agree_bit_for_bit() {
        // The full policy × layout × seed grid lives in
        // tests/integration.rs; this is the in-module smoke version.
        let cfg = ServeConfig {
            policy: PolicyKind::OffloadAware { alpha_centi: 10 },
            arrival_rate_hz: 2.0,
            ..base_cfg()
        };
        let fast = serve_with(&cfg, ServeMode::Indexed).unwrap();
        let oracle = serve_with(&cfg, ServeMode::NaiveOracle).unwrap();
        assert_eq!(fast.to_json().pretty(), oracle.to_json().pretty());
    }

    #[test]
    fn unbounded_plane_preserves_outcomes_and_only_reprices_energy() {
        // `--power-plane on` with infinite caps: every throttle level is
        // 0 and the node gate is off, so placement, runtimes and every
        // job outcome are identical to the pre-plane run — only the
        // energy accounting moves (unclamped demand, parked idle floor)
        // and the report grows the power block.
        let base = base_cfg();
        let plain = serve(&base).unwrap();
        let powered = serve(&ServeConfig {
            power: PowerPlaneConfig {
                enabled: true,
                gpu_cap_w: f64::INFINITY,
                node_cap_w: f64::INFINITY,
            },
            ..base
        })
        .unwrap();
        assert_eq!(plain.completed, powered.completed);
        assert_eq!(plain.expired, powered.expired);
        assert_eq!(plain.rejected, powered.rejected);
        assert_eq!(plain.reconfigs, powered.reconfigs);
        assert_eq!(plain.events, powered.events);
        assert_eq!(plain.makespan_s.to_bits(), powered.makespan_s.to_bits());
        assert_eq!(plain.wait_p99_s.to_bits(), powered.wait_p99_s.to_bits());
        assert_eq!(plain.utilization.to_bits(), powered.utilization.to_bits());
        assert_eq!(powered.throttled_gpu_s, 0.0, "infinite cap never throttles");
        assert_eq!(powered.power_starved, 0);
        assert!(
            powered.parked_gpu_s > 0.0,
            "a lightly-loaded fleet must park idle boards"
        );
        assert_ne!(
            plain.energy_j.to_bits(),
            powered.energy_j.to_bits(),
            "the plane reprices the energy integral"
        );
        // The wire only grows keys while the plane is active.
        assert!(powered.to_json().get("power_cap_w").is_some());
        assert!(plain.to_json().get("power_cap_w").is_none());
    }

    #[test]
    fn power_cap_throttles_a_neighbor_past_its_deadline() {
        // The acceptance scenario, made deterministic and self-deriving:
        // one whole-GPU slot, two identical jobs arriving together, and a
        // queueing deadline placed *between* the unthrottled and the
        // throttled service time of the first job. With an infinite cap
        // job 1 finishes in time and job 2 runs; with a cap just below
        // the job's boost demand the governor stretches job 1 past the
        // deadline and job 2 expires waiting — nonzero throttled time
        // flips a completion outcome. Every number is derived from the
        // planner/power model, so the construction cannot rot.
        use crate::gpu::{GpuSpec, GpuUsage, PowerModel};
        use crate::workload::trace::{Job, JobTrace};
        let app = crate::workload::AppId::Hotspot;
        let pid = crate::mig::ProfileId::P7g96gb;
        let spec = GpuSpec::gh_h100_96gb();
        let model = PowerModel::h100();
        let mut pl = Planner::new(0.05);
        let c = pl.cost(app, pid, false).unwrap();
        // Reconstruct the prospective usage placement will evaluate: an
        // empty board plus the job's own boost activity (same arithmetic,
        // same bits, same level).
        let mut u = GpuUsage {
            context_active: true,
            sm_busy_frac: crate::mig::profile::GiProfile::get(pid).sms as f64
                / spec.sms as f64,
            hbm_rate_tbs: c.hbm_tbs,
            c2c_rate_tbs: c.c2c_tbs,
            ..GpuUsage::default()
        };
        u.flop_rate_tflops = c.flop_tflops;
        let boost_w = model.demand_w(&spec, &u, spec.clock_max_mhz);
        let cap_w = boost_w - 1.0;
        assert!(cap_w > 0.0, "construction: boost demand {boost_w} W too small");
        let level = power::equilibrium_level(&spec, &model, &u, cap_w);
        assert!(level >= 1, "a cap below boost demand must throttle");
        let solo = c.runtime_s;
        let throttled = pl
            .cost_at_throttled(app, pid, false, 1, 1, level)
            .unwrap()
            .runtime_s;
        assert!(
            throttled > solo,
            "construction: compute-bound work must stretch with the clock"
        );
        let trace = JobTrace {
            jobs: (0..2)
                .map(|id| Job {
                    id,
                    app,
                    arrival_s: 0.0,
                })
                .collect(),
        };
        let cfg = ServeConfig {
            gpus: 1,
            policy: PolicyKind::FirstFit,
            layout: LayoutPreset::AllBig,
            deadline_s: 0.5 * (solo + throttled),
            reconfig: false,
            workload_scale: 0.05,
            power: PowerPlaneConfig {
                enabled: true,
                gpu_cap_w: f64::INFINITY,
                node_cap_w: f64::INFINITY,
            },
            ..ServeConfig::default()
        };
        let uncapped = serve_replay(&cfg, &trace).unwrap();
        assert_eq!(uncapped.completed, 2, "under no cap both jobs make the deadline");
        assert_eq!(uncapped.throttled_gpu_s, 0.0);
        let capped = serve_replay(
            &ServeConfig {
                power: PowerPlaneConfig {
                    enabled: true,
                    gpu_cap_w: cap_w,
                    node_cap_w: f64::INFINITY,
                },
                ..cfg.clone()
            },
            &trace,
        )
        .unwrap();
        assert_eq!(capped.completed, 1, "throttled job 1 overruns the deadline");
        assert_eq!(capped.expired, 1);
        assert!(
            capped.throttled_gpu_s > 0.0,
            "the flip must be attributable to throttled time"
        );
    }

    #[test]
    fn capped_plane_indexed_and_oracle_agree_bit_for_bit() {
        // The power-plane differential smoke: finite GPU and node caps,
        // offload-aware placement under load — the indexed counters and
        // the oracle's scan-sums must produce the identical report. The
        // full grid (policies × caps × threads) lives in
        // tests/integration.rs.
        let cfg = ServeConfig {
            policy: PolicyKind::OffloadAware { alpha_centi: 10 },
            arrival_rate_hz: 2.0,
            power: PowerPlaneConfig {
                enabled: true,
                gpu_cap_w: 450.0,
                node_cap_w: 180.0,
            },
            ..base_cfg()
        };
        let fast = serve_with(&cfg, ServeMode::Indexed).unwrap();
        let oracle = serve_with(&cfg, ServeMode::NaiveOracle).unwrap();
        assert_eq!(fast.to_json().pretty(), oracle.to_json().pretty());
        // With batching and link contention layered on top.
        let cfg2 = ServeConfig {
            batch: 2,
            c2c_contention: true,
            host_pool_gib: 64.0,
            ..cfg
        };
        let fast = serve_with(&cfg2, ServeMode::Indexed).unwrap();
        let oracle = serve_with(&cfg2, ServeMode::NaiveOracle).unwrap();
        assert_eq!(fast.to_json().pretty(), oracle.to_json().pretty());
    }

    #[test]
    fn report_json_round_trips() {
        let r = serve(&ServeConfig {
            jobs: 10,
            ..base_cfg()
        })
        .unwrap();
        let doc = r.to_json();
        let back = Json::parse(&doc.pretty()).unwrap();
        assert_eq!(back, doc);
        assert_eq!(
            back.get("completed").unwrap().as_u64(),
            Some(r.completed as u64)
        );
    }

    #[test]
    fn replay_of_the_synthetic_trace_reproduces_the_report() {
        // The trace-replay round trip: persist the arrival log a
        // synthetic run draws, reload it, replay — identical report.
        let cfg = base_cfg();
        let synth = serve(&cfg).unwrap();
        let trace = JobTrace::poisson(cfg.jobs, 1.0 / cfg.arrival_rate_hz, &serve_mix(), cfg.seed);
        let reloaded = JobTrace::from_json(&trace.to_json()).unwrap();
        let replay = serve_replay(&cfg, &reloaded).unwrap();
        assert_eq!(synth.to_json().pretty(), replay.to_json().pretty());
    }
}
