//! Online cluster serving: offload-aware admission, placement, and
//! dynamic MIG reconfiguration over a multi-GPU fleet.
//!
//! This is the closed loop the rest of the crate feeds: a Poisson stream
//! of Table III jobs (plus the §VI large variants) arrives at a fleet of
//! statically-partitioned GH200 GPUs; an admission queue holds them
//! against a deadline; a placement policy (`placement::PolicyKind`) maps
//! each job to a MIG slot — directly, or through an NVLink-C2C
//! `OffloadPlan` onto a smaller slice; and, when a job fits no current
//! layout, a drained GPU can be repartitioned at a modeled latency cost
//! (`reconfig`). The loop is event-driven over `sim::Engine` and fully
//! deterministic for a fixed seed.
//!
//! Module map:
//! - `fleet`: GPUs, layouts, slots, the reconfiguration state machine.
//! - `queue`: FIFO admission with deadlines and lifecycle accounting.
//! - `placement`: first-fit / best-fit / offload-aware policies over a
//!   memoized cost model (runtime + power rates per app×profile).
//! - `reconfig`: valid-partition-preserving layout planning + latency.
//!
//! Outputs (`ServeReport`): admitted throughput, p50/p95/p99 queueing
//! latency, fleet utilization, fragmentation, and energy integrated
//! through the `gpu::PowerModel`.

pub mod fleet;
pub mod placement;
pub mod queue;
pub mod reconfig;

pub use fleet::{Fleet, LayoutPreset};
pub use placement::{PlacementCost, Planner, PolicyKind};
pub use queue::{AdmissionQueue, JobState};

use crate::gpu::{GpuUsage, PowerModel};
use crate::sim::{Engine, EventToken};
use crate::util::json::Json;
use crate::util::stats::{percentile, Accum};
use crate::util::units::{ns_to_sec, sec_to_ns};
use crate::workload::trace::JobTrace;
use crate::workload::{apps, AppId};
use anyhow::ensure;
use std::collections::BTreeMap;

/// Configuration of one serving run.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub gpus: u32,
    pub policy: PolicyKind,
    pub layout: LayoutPreset,
    /// Mean job arrival rate (jobs/s of simulated time).
    pub arrival_rate_hz: f64,
    /// Number of jobs in the arrival stream.
    pub jobs: u32,
    /// Queueing deadline: a job abandons after waiting this long (s).
    pub deadline_s: f64,
    /// Allow dynamic MIG reconfiguration of drained GPUs.
    pub reconfig: bool,
    pub seed: u64,
    pub workload_scale: f64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            gpus: 4,
            policy: PolicyKind::FirstFit,
            layout: LayoutPreset::Mixed,
            arrival_rate_hz: 1.0,
            jobs: 60,
            deadline_s: 600.0,
            reconfig: true,
            seed: 0x5EED,
            workload_scale: 1.0,
        }
    }
}

/// The serving job mix: the paper's suite plus the §VI large variants
/// (which exceed a 1g.12gb slice and make offloading matter).
pub fn serve_mix() -> Vec<(AppId, f64)> {
    let mut mix = JobTrace::suite_mix();
    mix.push((AppId::Llama3Fp16, 2.0));
    mix.push((AppId::Qiskit31, 1.5));
    mix.push((AppId::FaissLarge, 1.5));
    mix
}

/// Aggregate outcome of one serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub policy: String,
    pub layout: String,
    pub gpus: u32,
    pub jobs: u32,
    pub arrival_rate_hz: f64,
    pub completed: u32,
    pub expired: u32,
    pub rejected: u32,
    /// Completed jobs that ran with C2C offloading.
    pub offloaded: u32,
    /// MIG reconfigurations performed across the fleet.
    pub reconfigs: u32,
    /// Serving horizon: last completion/expiry instant (s).
    pub makespan_s: f64,
    /// Admitted throughput: completed jobs per second of horizon.
    pub throughput_jobs_s: f64,
    pub wait_mean_s: f64,
    pub wait_p50_s: f64,
    pub wait_p95_s: f64,
    pub wait_p99_s: f64,
    /// Time-averaged fraction of fleet SMs running jobs.
    pub utilization: f64,
    /// Time-averaged fraction of idle SMs stranded in slots too small for
    /// the smallest waiting job.
    pub fragmentation: f64,
    /// Fleet energy integrated over the run (J), via `gpu::PowerModel`.
    pub energy_j: f64,
}

impl ServeReport {
    pub fn to_json(&self) -> Json {
        let mut o = Json::obj();
        o.set("policy", self.policy.as_str())
            .set("layout", self.layout.as_str())
            .set("gpus", self.gpus)
            .set("jobs", self.jobs)
            .set("arrival_rate_hz", self.arrival_rate_hz)
            .set("completed", self.completed)
            .set("expired", self.expired)
            .set("rejected", self.rejected)
            .set("offloaded", self.offloaded)
            .set("reconfigs", self.reconfigs)
            .set("makespan_s", self.makespan_s)
            .set("throughput_jobs_s", self.throughput_jobs_s)
            .set("wait_mean_s", self.wait_mean_s)
            .set("wait_p50_s", self.wait_p50_s)
            .set("wait_p95_s", self.wait_p95_s)
            .set("wait_p99_s", self.wait_p99_s)
            .set("utilization", self.utilization)
            .set("fragmentation", self.fragmentation)
            .set("energy_j", self.energy_j);
        o
    }

    pub fn summary(&self) -> String {
        format!(
            "serve {} on {} x{} @ {:.2} jobs/s\n\
             jobs: {} completed, {} expired, {} rejected ({} offloaded, {} reconfigs)\n\
             throughput {:.3} jobs/s over {:.1} s  wait p50/p95/p99 {:.2}/{:.2}/{:.2} s\n\
             utilization {:.1}%  fragmentation {:.1}%  energy {:.1} kJ",
            self.policy,
            self.layout,
            self.gpus,
            self.arrival_rate_hz,
            self.completed,
            self.expired,
            self.rejected,
            self.offloaded,
            self.reconfigs,
            self.throughput_jobs_s,
            self.makespan_s,
            self.wait_p50_s,
            self.wait_p95_s,
            self.wait_p99_s,
            self.utilization * 100.0,
            self.fragmentation * 100.0,
            self.energy_j / 1e3,
        )
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Ev {
    Arrival(u32),
    Deadline(u32),
    JobDone { gpu: usize, slot: usize },
    ReconfigDone(usize),
}

/// Run one serving simulation. Deterministic for a fixed config.
pub fn serve(cfg: &ServeConfig) -> crate::Result<ServeReport> {
    ensure!(cfg.gpus >= 1, "serve needs at least one GPU");
    ensure!(cfg.jobs >= 1, "serve needs at least one job");
    ensure!(cfg.arrival_rate_hz > 0.0, "arrival rate must be positive");
    ensure!(cfg.deadline_s > 0.0, "deadline must be positive");

    let mut planner = Planner::new(cfg.workload_scale);
    let mut fleet = Fleet::new(cfg.gpus, cfg.layout)?;
    let trace = JobTrace::poisson(cfg.jobs, 1.0 / cfg.arrival_rate_hz, &serve_mix(), cfg.seed);
    let mut queue = AdmissionQueue::new();
    let mut engine: Engine<Ev> = Engine::new();
    for job in &trace.jobs {
        engine.schedule_at(sec_to_ns(job.arrival_s), Ev::Arrival(job.id));
    }

    let power_model = PowerModel::h100();
    // Activity rates of running jobs, keyed by (gpu, slot). BTreeMap so
    // float summation order — and thus the energy integral — is
    // deterministic.
    let mut running: BTreeMap<(usize, usize), PlacementCost> = BTreeMap::new();
    // Pending deadline events, cancelled on placement so the event loop
    // (and the energy integral) ends at the last real state change
    // instead of idling until `last arrival + deadline`.
    let mut deadline_tokens: Vec<Option<EventToken>> = vec![None; cfg.jobs as usize];
    let mut energy_j = 0.0f64;
    let mut frag_integral = 0.0f64;
    let mut busy_sm_integral = 0.0f64;
    let mut last_t = 0.0f64;

    while let Some(ev) = engine.pop() {
        let now = ns_to_sec(ev.time_ns);
        let dt = now - last_t;
        // Integrate only while serving work remains (jobs still to arrive
        // or unresolved). Once the final job resolves, the only events
        // left are trailing reconfig completions, and charging idle power
        // past the horizon would skew the energy comparison between runs
        // (the metrics all cover [0, horizon]). Mid-run idle gaps between
        // arrivals still count — the fleet is powered on, waiting.
        let work_remains =
            queue.jobs.len() < cfg.jobs as usize || !queue.all_resolved();
        if dt > 0.0 && work_remains {
            energy_j += dt * fleet_power_w(&fleet, &power_model, &running);
            let needed = queue
                .smallest_pending_footprint_gib()
                .map(|f| f + planner.ctx_gib());
            frag_integral += dt * fleet.fragmentation(needed);
            busy_sm_integral += dt * fleet.busy_sms() as f64;
        }
        last_t = now;
        match ev.event {
            Ev::Arrival(id) => {
                let job = trace.jobs[id as usize].clone();
                let app = job.app;
                queue.admit(job, cfg.deadline_s);
                if planner.servable(app, cfg.policy.allows_offload()) {
                    // The queue's deadline_s is the single source of truth
                    // for when this job abandons.
                    let abandon_s = queue.jobs[id as usize].deadline_s;
                    deadline_tokens[id as usize] =
                        Some(engine.schedule_at(sec_to_ns(abandon_s), Ev::Deadline(id)));
                    dispatch(
                        cfg,
                        now,
                        &mut fleet,
                        &mut queue,
                        &mut planner,
                        &mut engine,
                        &mut running,
                        &mut deadline_tokens,
                    );
                } else {
                    queue.reject(id, now);
                }
            }
            Ev::Deadline(id) => {
                deadline_tokens[id as usize] = None;
                queue.expire_if_pending(id, now);
            }
            Ev::JobDone { gpu, slot } => {
                if let Some(job) = fleet.finish_job(gpu, slot, now) {
                    queue.mark_completed(job, now);
                    running.remove(&(gpu, slot));
                    dispatch(
                        cfg,
                        now,
                        &mut fleet,
                        &mut queue,
                        &mut planner,
                        &mut engine,
                        &mut running,
                        &mut deadline_tokens,
                    );
                }
            }
            Ev::ReconfigDone(gpu) => {
                fleet.nodes[gpu].finish_reconfig();
                dispatch(
                    cfg,
                    now,
                    &mut fleet,
                    &mut queue,
                    &mut planner,
                    &mut engine,
                    &mut running,
                    &mut deadline_tokens,
                );
            }
        }
    }

    debug_assert!(queue.all_resolved(), "events drained with unresolved jobs");
    let horizon = queue.horizon_s().max(1e-9);
    let waits = queue.completed_waits();
    let pct = |p: f64| {
        if waits.is_empty() {
            0.0
        } else {
            percentile(&waits, p)
        }
    };
    let mut wacc = Accum::new();
    waits.iter().for_each(|&w| wacc.push(w));
    let completed = queue.count(JobState::Completed);
    let offloaded = queue
        .jobs
        .iter()
        .filter(|j| j.state == JobState::Completed && j.offloaded)
        .count() as u32;
    Ok(ServeReport {
        policy: cfg.policy.label(),
        layout: cfg.layout.label().to_string(),
        gpus: cfg.gpus,
        jobs: cfg.jobs,
        arrival_rate_hz: cfg.arrival_rate_hz,
        completed,
        expired: queue.count(JobState::Expired),
        rejected: queue.count(JobState::Rejected),
        offloaded,
        reconfigs: fleet.nodes.iter().map(|n| n.reconfigs).sum(),
        makespan_s: horizon,
        throughput_jobs_s: completed as f64 / horizon,
        wait_mean_s: wacc.mean(),
        wait_p50_s: pct(50.0),
        wait_p95_s: pct(95.0),
        wait_p99_s: pct(99.0),
        utilization: busy_sm_integral / (fleet.total_sms() as f64 * horizon),
        fragmentation: frag_integral / horizon,
        energy_j,
    })
}

/// Try to place every pending job (FIFO with backfilling: a blocked head
/// does not starve smaller jobs behind it). When a job fits no layout the
/// fleet currently has — or is already reconfiguring toward — and
/// reconfiguration is enabled, repartition one drained GPU toward the
/// job's profile class.
#[allow(clippy::too_many_arguments)]
fn dispatch(
    cfg: &ServeConfig,
    now: f64,
    fleet: &mut Fleet,
    queue: &mut AdmissionQueue,
    planner: &mut Planner,
    engine: &mut Engine<Ev>,
    running: &mut BTreeMap<(usize, usize), PlacementCost>,
    deadline_tokens: &mut [Option<EventToken>],
) {
    let ids: Vec<u32> = queue.pending_ids().collect();
    for id in ids {
        let app = queue.jobs[id as usize].job.app;
        if let Some((g, s, c)) = planner.place(fleet, app, cfg.policy) {
            queue.mark_running(id, now, g, c.offloaded);
            if let Some(tok) = deadline_tokens[id as usize].take() {
                engine.cancel(tok);
            }
            let until = now + c.runtime_s;
            fleet.start_job(g, s, id, now, until);
            running.insert((g, s), c);
            engine.schedule_at(sec_to_ns(until), Ev::JobDone { gpu: g, slot: s });
        } else if cfg.reconfig
            && !planner.fits_current_layouts(fleet, app, cfg.policy.allows_offload())
        {
            let need = apps::model(app).footprint_gib + planner.ctx_gib();
            if let Some((g, target)) = reconfig::plan_reconfig(fleet, need) {
                let until = now + reconfig::latency_s(&fleet.nodes[g].layout, &target);
                if fleet.nodes[g].begin_reconfig(target, until).is_ok() {
                    engine.schedule_at(sec_to_ns(until), Ev::ReconfigDone(g));
                }
            }
        }
    }
}

/// Instantaneous fleet power: per-GPU `PowerModel` demand from the running
/// jobs' average activity rates (no DVFS governor here — serving jobs on
/// MIG slices stays under the cap, which `reported_w` enforces anyway).
fn fleet_power_w(
    fleet: &Fleet,
    model: &PowerModel,
    running: &BTreeMap<(usize, usize), PlacementCost>,
) -> f64 {
    let spec = &fleet.spec;
    let mut usages: Vec<GpuUsage> = vec![GpuUsage::default(); fleet.nodes.len()];
    for (g, node) in fleet.nodes.iter().enumerate() {
        let busy = node.busy_sms();
        usages[g].context_active = busy > 0;
        usages[g].sm_busy_frac = busy as f64 / spec.sms as f64;
    }
    for (&(g, _), c) in running {
        let u = &mut usages[g];
        for (i, f) in c.flop_tflops.iter().enumerate() {
            u.flop_rate_tflops[i] += *f;
        }
        u.hbm_rate_tbs += c.hbm_tbs;
        u.c2c_rate_tbs += c.c2c_tbs;
    }
    usages
        .iter()
        .map(|u| model.reported_w(spec, u, spec.clock_max_mhz))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base_cfg() -> ServeConfig {
        ServeConfig {
            gpus: 2,
            policy: PolicyKind::FirstFit,
            layout: LayoutPreset::Mixed,
            arrival_rate_hz: 0.5,
            jobs: 30,
            deadline_s: 40.0,
            reconfig: true,
            seed: 7,
            workload_scale: 0.05,
        }
    }

    #[test]
    fn serve_resolves_every_job_and_reports_sane_metrics() {
        let r = serve(&base_cfg()).unwrap();
        assert_eq!(r.completed + r.expired + r.rejected, 30);
        assert!(r.completed > 0);
        assert!(r.makespan_s > 0.0);
        assert!(r.throughput_jobs_s > 0.0);
        assert!((0.0..=1.0).contains(&r.utilization), "{}", r.utilization);
        assert!((0.0..=1.0).contains(&r.fragmentation));
        assert!(r.energy_j.is_finite() && r.energy_j > 0.0);
        assert!(r.wait_p99_s >= r.wait_p95_s && r.wait_p95_s >= r.wait_p50_s);
        assert!(r.wait_p99_s <= 40.0 + 1e-9, "waits bounded by the deadline");
    }

    #[test]
    fn offload_aware_beats_first_fit_on_small_slices_under_load() {
        // All-small fleet, saturated, no reconfiguration: first-fit can
        // never place the ~1/3 of jobs that exceed 11 GiB; offload-aware
        // admits them onto 1g slices over C2C — the paper's §VI story as
        // an online policy.
        let cfg = ServeConfig {
            layout: LayoutPreset::AllSmall,
            arrival_rate_hz: 4.0,
            jobs: 40,
            deadline_s: 20.0,
            reconfig: false,
            ..base_cfg()
        };
        let ff = serve(&cfg).unwrap();
        let off = serve(&ServeConfig {
            policy: PolicyKind::OffloadAware { alpha_centi: 10 },
            ..cfg.clone()
        })
        .unwrap();
        assert!(
            off.completed > ff.completed,
            "offload-aware {} vs first-fit {}",
            off.completed,
            ff.completed
        );
        assert!(off.throughput_jobs_s > ff.throughput_jobs_s);
        assert!(off.offloaded > 0);
        assert_eq!(ff.offloaded, 0);
    }

    #[test]
    fn reconfiguration_rescues_large_jobs_on_small_layouts() {
        // Lightly-loaded all-small fleet with first-fit: large jobs fit
        // nothing until a drained GPU is repartitioned.
        let cfg = ServeConfig {
            layout: LayoutPreset::AllSmall,
            arrival_rate_hz: 0.2,
            jobs: 20,
            deadline_s: 60.0,
            reconfig: true,
            ..base_cfg()
        };
        let dynamic = serve(&cfg).unwrap();
        let static_ = serve(&ServeConfig {
            reconfig: false,
            ..cfg.clone()
        })
        .unwrap();
        assert!(dynamic.reconfigs > 0, "reconfiguration must trigger");
        assert_eq!(static_.reconfigs, 0);
        assert!(
            dynamic.completed > static_.completed,
            "reconfig {} vs static {}",
            dynamic.completed,
            static_.completed
        );
        assert!(static_.expired > 0, "static small layout strands large jobs");
    }

    #[test]
    fn report_json_round_trips() {
        let r = serve(&ServeConfig {
            jobs: 10,
            ..base_cfg()
        })
        .unwrap();
        let doc = r.to_json();
        let back = Json::parse(&doc.pretty()).unwrap();
        assert_eq!(back, doc);
        assert_eq!(
            back.get("completed").unwrap().as_u64(),
            Some(r.completed as u64)
        );
    }
}
