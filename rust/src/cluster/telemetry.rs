//! Fleet telemetry plane: structured serve-loop tracing, time-series
//! sampling, and mergeable latency histograms.
//!
//! The paper's whole method is observability — GPM samples at 0.2 s,
//! NVML polls power at 20 ms, energy comes from integrating the power
//! trace (§III-A, §V-B) — but the cluster serving stack built on top of
//! the co-run model was a black box: one terminal `ServeReport` per run,
//! with no way to see *when* fragmentation spiked, *which* shard
//! starved, or *why* a reconfiguration fired. This module cures that
//! with three opt-in planes:
//!
//! 1. **Structured event tracing** — every admission, placement,
//!    rejection, expiry, handoff, reconfiguration, offload denial and
//!    completion is a typed [`TraceEvent`] with a virtual timestamp and
//!    shard id, buffered per shard and merged deterministically at
//!    epoch barriers.
//! 2. **Periodic fleet sampling** — a GPM-style virtual-time sampler
//!    ([`FleetSample`]) records SM utilization, per-profile-class
//!    idle/open-seat counts, fragmentation, queue depth, host-pool
//!    occupancy, per-GPU C2C co-offloader counts and cached power every
//!    `sample_dt_s` of virtual time.
//! 3. **Mergeable latency histograms + hot-path counters** — log-bucketed
//!    ([`hist`]) queue-wait / service / slack distributions and per-shard
//!    profiling counters, all integer-valued so shard-wise merges are
//!    exactly associative and the combined output is bit-identical for
//!    every `--threads` value.
//!
//! The whole plane is **zero-cost when off**: every hook in the serve
//! hot path is generic over [`Sink`], and the inert [`NullSink`]
//! (`ENABLED == false`) monomorphizes each `if S::ENABLED { .. }` guard
//! away. The plane is also **inert when on**: it only ever *reads*
//! simulator state — it never schedules events, never touches the float
//! accumulators, and never perturbs a decision — so a traced run's
//! `ServeReport` is byte-identical to an untraced one.

use crate::cluster::faults::FaultKind;
use crate::cluster::fleet::Fleet;
use crate::cluster::queue::AdmissionQueue;
use crate::mig::profile::{ALL_PROFILES, NUM_PROFILES};
use crate::util::json::Json;
use crate::util::units::ns_to_sec;
use crate::workload::{apps, AppId};

// ---------------------------------------------------------------------------
// Trace events
// ---------------------------------------------------------------------------

/// Why the cross-node dispatcher picked a handoff destination.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HandoffReason {
    /// The destination advertised an open seat (or empty slot) the job's
    /// class fits without repartitioning.
    OpenSeat,
    /// No shard fits the job today; the destination could host it after
    /// a reconfiguration toward a suitable layout.
    Reconfig,
}

impl HandoffReason {
    pub fn label(&self) -> &'static str {
        match self {
            HandoffReason::OpenSeat => "open-seat",
            HandoffReason::Reconfig => "reconfig",
        }
    }
}

/// What happened. Variants carry the decision context that is invisible
/// in the terminal `ServeReport`.
#[derive(Debug, Clone, PartialEq)]
pub enum EventKind {
    /// Job entered a shard's admission queue. `handoff` marks a re-arrival
    /// via cross-node handoff (the deadline is then the original absolute
    /// one — the clock does not restart on migration).
    Admit {
        app: AppId,
        deadline_ns: u64,
        handoff: bool,
    },
    /// Unservable on this hardware even by offloading: refused outright.
    Reject { app: AppId },
    /// Placement decision: the job starts on `(gpu, slot)` in profile
    /// `class` at seat occupancy `occupancy`, with `share` co-offloaders
    /// on the GPU's C2C link (1 = private link).
    Place {
        app: AppId,
        gpu: u32,
        slot: u32,
        class: &'static str,
        occupancy: u32,
        offloaded: bool,
        share: u32,
        runtime_ns: u64,
    },
    /// Queueing deadline passed while still pending: the client gave up.
    Expire { app: AppId },
    /// Job finished. Latencies in virtual ns: `wait` = placed − arrival,
    /// `service` = finished − placed, `slack` = deadline − finished
    /// floored at zero (a running job may outlive its queueing deadline).
    Complete {
        app: AppId,
        wait_ns: u64,
        service_ns: u64,
        slack_ns: u64,
        offloaded: bool,
    },
    /// A pending job was handed off to node shard `dest` at an epoch
    /// barrier.
    Handoff { app: AppId, dest: u32, reason: HandoffReason },
    /// Dynamic repartition began on `gpu`, triggered by a pending
    /// `trigger` job no current layout could host.
    Reconfig {
        gpu: u32,
        from: String,
        to: String,
        trigger: AppId,
    },
    /// A placement walk failed while at least one profile class would
    /// have admitted the job by offloading — but the host pool could not
    /// park the spill.
    OffloadDenied { app: AppId },
    /// The fault plane injected a failure on `gpu`. `slot` names the
    /// victim slice for `FaultKind::Slice`; whole-GPU and reconfig
    /// faults carry `None`.
    Fault {
        gpu: u32,
        kind: FaultKind,
        slot: Option<u32>,
    },
    /// `gpu` went out of service after a hard failure: every placement
    /// surface excludes it until the matching `Recover`.
    Cordon { gpu: u32 },
    /// `gpu` finished repair and rejoined the placement surfaces.
    Recover { gpu: u32 },
    /// A fault killed this job's running instance and it re-enters the
    /// queue for attempt `attempt + 1` (of `1 + retries`).
    Retry { app: AppId, attempt: u32 },
    /// A fault killed this job's running instance with the retry budget
    /// spent: the job is lost.
    Fail { app: AppId },
    /// A correlated fault took down fault domain `domain` (node- or
    /// rack-scoped, spanning `members` GPUs), cordoning every member
    /// still in service at once. A per-GPU `Cordon` event follows for
    /// each board actually taken down. Emitted once per domain event, by
    /// the shard owning the domain's lowest global GPU id.
    DomainFault { domain: u32, members: u32 },
    /// Every repair crew is busy: `gpu` joined the FIFO repair backlog
    /// and stays cordoned until a crew frees up (only emitted when
    /// `--repair-crews` bounds repair concurrency).
    RepairQueued { gpu: u32 },
    /// A repair crew began servicing `gpu`; the matching `Recover` is
    /// the repair-done event (only emitted when `--repair-crews` bounds
    /// repair concurrency).
    RepairStart { gpu: u32 },
    /// Brown-out backpressure dropped this pending job: surviving
    /// capacity fell below the shed watermark (terminal outcome).
    Shed { app: AppId },
    /// The power governor moved `gpu`'s throttle level (clock-ladder
    /// steps below boost; 0 = unthrottled) after a slot-churn event.
    /// Only emitted while the power plane is active.
    Throttle { gpu: u32, from: u32, to: u32 },
    /// The estimator routed this admission through its app's probe
    /// phase: the completion will train the learned cost model's
    /// per-app unit work. Only emitted while the profiling plane is
    /// active.
    Probe { app: AppId },
    /// One placement decision's estimate-vs-oracle regret: the chosen
    /// seat's estimated service time against the retained oracle's.
    /// Only emitted while the profiling plane is active.
    Regret {
        app: AppId,
        est_ns: u64,
        oracle_ns: u64,
    },
}

impl EventKind {
    pub fn tag(&self) -> &'static str {
        match self {
            EventKind::Admit { .. } => "admit",
            EventKind::Reject { .. } => "reject",
            EventKind::Place { .. } => "place",
            EventKind::Expire { .. } => "expire",
            EventKind::Complete { .. } => "complete",
            EventKind::Handoff { .. } => "handoff",
            EventKind::Reconfig { .. } => "reconfig",
            EventKind::OffloadDenied { .. } => "offload_denied",
            EventKind::Fault { .. } => "fault",
            EventKind::Cordon { .. } => "cordon",
            EventKind::Recover { .. } => "recover",
            EventKind::Retry { .. } => "retry",
            EventKind::Fail { .. } => "fail",
            EventKind::DomainFault { .. } => "domain_fault",
            EventKind::RepairQueued { .. } => "repair_queued",
            EventKind::RepairStart { .. } => "repair_start",
            EventKind::Shed { .. } => "shed",
            EventKind::Throttle { .. } => "throttle",
            EventKind::Probe { .. } => "probe",
            EventKind::Regret { .. } => "regret",
        }
    }
}

/// One structured serve-loop event: virtual timestamp (ns), originating
/// shard, per-shard sequence number (total order within a shard), the
/// fleet-global job id where applicable, and the typed payload.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub t_ns: u64,
    pub shard: u32,
    pub seq: u64,
    pub job: Option<u32>,
    pub kind: EventKind,
}

impl TraceEvent {
    /// Serialize to one JSONL object (`"type":"event"`).
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("type", "event")
            .set("t_s", ns_to_sec(self.t_ns))
            .set("shard", self.shard)
            .set("seq", self.seq)
            .set("kind", self.kind.tag());
        if let Some(id) = self.job {
            j.set("job", id);
        }
        match &self.kind {
            EventKind::Admit {
                app,
                deadline_ns,
                handoff,
            } => {
                j.set("app", app.name())
                    .set("deadline_s", ns_to_sec(*deadline_ns))
                    .set("handoff", *handoff);
            }
            EventKind::Reject { app } | EventKind::Expire { app } | EventKind::OffloadDenied { app } => {
                j.set("app", app.name());
            }
            EventKind::Place {
                app,
                gpu,
                slot,
                class,
                occupancy,
                offloaded,
                share,
                runtime_ns,
            } => {
                j.set("app", app.name())
                    .set("gpu", *gpu)
                    .set("slot", *slot)
                    .set("class", *class)
                    .set("occupancy", *occupancy)
                    .set("offloaded", *offloaded)
                    .set("share", *share)
                    .set("runtime_s", ns_to_sec(*runtime_ns));
            }
            EventKind::Complete {
                app,
                wait_ns,
                service_ns,
                slack_ns,
                offloaded,
            } => {
                j.set("app", app.name())
                    .set("wait_s", ns_to_sec(*wait_ns))
                    .set("service_s", ns_to_sec(*service_ns))
                    .set("slack_s", ns_to_sec(*slack_ns))
                    .set("offloaded", *offloaded);
            }
            EventKind::Handoff { app, dest, reason } => {
                j.set("app", app.name())
                    .set("dest", *dest)
                    .set("reason", reason.label());
            }
            EventKind::Reconfig {
                gpu,
                from,
                to,
                trigger,
            } => {
                j.set("gpu", *gpu)
                    .set("from", from.as_str())
                    .set("to", to.as_str())
                    .set("trigger", trigger.name());
            }
            EventKind::Fault { gpu, kind, slot } => {
                j.set("gpu", *gpu).set("fault", kind.label());
                if let Some(s) = slot {
                    j.set("slot", *s);
                }
            }
            EventKind::Cordon { gpu } | EventKind::Recover { gpu } => {
                j.set("gpu", *gpu);
            }
            EventKind::Retry { app, attempt } => {
                j.set("app", app.name()).set("attempt", *attempt);
            }
            EventKind::Fail { app } | EventKind::Shed { app } => {
                j.set("app", app.name());
            }
            EventKind::DomainFault { domain, members } => {
                j.set("domain", *domain).set("members", *members);
            }
            EventKind::RepairQueued { gpu } | EventKind::RepairStart { gpu } => {
                j.set("gpu", *gpu);
            }
            EventKind::Throttle { gpu, from, to } => {
                j.set("gpu", *gpu).set("from", *from).set("to", *to);
            }
            EventKind::Probe { app } => {
                j.set("app", app.name());
            }
            EventKind::Regret {
                app,
                est_ns,
                oracle_ns,
            } => {
                j.set("app", app.name())
                    .set("est_s", ns_to_sec(*est_ns))
                    .set("oracle_s", ns_to_sec(*oracle_ns))
                    .set("regret_s", ns_to_sec(est_ns.abs_diff(*oracle_ns)));
            }
        }
        j
    }
}

// ---------------------------------------------------------------------------
// Hot-path profiling counters
// ---------------------------------------------------------------------------

/// Profiling counters for the serve hot path. Mode-dependent by design
/// (the indexed walk and the naive oracle count different work), so they
/// live outside the oracle-comparable sections of the report.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Counter {
    /// Placement decisions attempted (one per pending job per dispatch
    /// round that reached a walk or a memo hit).
    PlaceDecisions,
    /// Candidate classes / slots visited by placement walks.
    WalkSteps,
    /// Dispatch rounds that skipped a walk because the app already
    /// failed at this fleet epoch.
    MemoHits,
    /// Walks performed because no memo entry applied.
    MemoMisses,
    /// Jobs considered for cross-node forwarding at epoch barriers
    /// (whether or not a destination was found).
    HandoffAttempts,
    /// Placement failures where an offload-admissible class was gated
    /// out by host-pool headroom.
    OffloadPoolGated,
    /// Placement candidates gated out by node power-budget headroom
    /// (only counted while the power plane's node cap is finite).
    PowerGated,
}

pub const NUM_COUNTERS: usize = 7;

pub const ALL_COUNTERS: [Counter; NUM_COUNTERS] = [
    Counter::PlaceDecisions,
    Counter::WalkSteps,
    Counter::MemoHits,
    Counter::MemoMisses,
    Counter::HandoffAttempts,
    Counter::OffloadPoolGated,
    Counter::PowerGated,
];

impl Counter {
    pub fn index(self) -> usize {
        match self {
            Counter::PlaceDecisions => 0,
            Counter::WalkSteps => 1,
            Counter::MemoHits => 2,
            Counter::MemoMisses => 3,
            Counter::HandoffAttempts => 4,
            Counter::OffloadPoolGated => 5,
            Counter::PowerGated => 6,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Counter::PlaceDecisions => "place_decisions",
            Counter::WalkSteps => "walk_steps",
            Counter::MemoHits => "memo_hits",
            Counter::MemoMisses => "memo_misses",
            Counter::HandoffAttempts => "handoff_attempts",
            Counter::OffloadPoolGated => "offload_pool_gated",
            Counter::PowerGated => "power_gated",
        }
    }
}

/// A dense set of [`Counter`] values. Merging is element-wise `u64`
/// addition — exactly associative and commutative, so shard-wise merges
/// are order-insensitive and bit-identical across thread counts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CounterSet([u64; NUM_COUNTERS]);

impl CounterSet {
    pub fn new() -> CounterSet {
        CounterSet::default()
    }

    pub fn add(&mut self, c: Counter, n: u64) {
        self.0[c.index()] += n;
    }

    pub fn get(&self, c: Counter) -> u64 {
        self.0[c.index()]
    }

    pub fn merge(&mut self, other: &CounterSet) {
        for (a, b) in self.0.iter_mut().zip(other.0.iter()) {
            *a += *b;
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        for c in ALL_COUNTERS {
            j.set(c.label(), self.get(c));
        }
        j
    }
}

// ---------------------------------------------------------------------------
// Mergeable log-bucketed histograms
// ---------------------------------------------------------------------------

/// HDR-style log-bucketed histograms over `u64` virtual nanoseconds.
///
/// Values 0–7 get unit buckets; larger values keep the top 3 significant
/// bits (8 sub-buckets per octave), bounding relative quantile error at
/// 12.5%. Counts are integers, so [`Hist::merge`] — element-wise `u64`
/// addition — is exactly associative and commutative: any shard/epoch
/// merge order yields bit-identical output.
pub mod hist {
    use crate::util::json::Json;
    use crate::util::units::{ns_to_sec, sec_to_ns};

    /// 8 linear buckets + 61 octaves × 8 sub-buckets (bit lengths 4–64).
    pub const NUM_BUCKETS: usize = 8 + 61 * 8;

    /// Bucket index of a value.
    pub fn bucket_of(v_ns: u64) -> usize {
        if v_ns < 8 {
            return v_ns as usize;
        }
        let n = 64 - v_ns.leading_zeros() as usize; // bit length, ≥ 4
        let sub = ((v_ns >> (n - 4)) & 7) as usize;
        8 + (n - 4) * 8 + sub
    }

    /// Inclusive lower bound of a bucket (its reported value).
    pub fn bucket_low_ns(idx: usize) -> u64 {
        if idx < 8 {
            return idx as u64;
        }
        let o = (idx - 8) / 8;
        let s = ((idx - 8) % 8) as u64;
        (1u64 << (o + 3)) + (s << o)
    }

    /// One mergeable latency histogram.
    #[derive(Debug, Clone, PartialEq, Eq)]
    pub struct Hist {
        counts: Vec<u64>,
        count: u64,
        sum_ns: u64,
    }

    impl Default for Hist {
        fn default() -> Self {
            Hist::new()
        }
    }

    impl Hist {
        pub fn new() -> Hist {
            Hist {
                counts: vec![0; NUM_BUCKETS],
                count: 0,
                sum_ns: 0,
            }
        }

        pub fn record_ns(&mut self, v_ns: u64) {
            self.counts[bucket_of(v_ns)] += 1;
            self.count += 1;
            self.sum_ns = self.sum_ns.saturating_add(v_ns);
        }

        /// Record a duration in seconds; negatives clamp to zero.
        pub fn record_s(&mut self, v_s: f64) {
            self.record_ns(sec_to_ns(v_s.max(0.0)));
        }

        pub fn count(&self) -> u64 {
            self.count
        }

        pub fn sum_ns(&self) -> u64 {
            self.sum_ns
        }

        pub fn is_empty(&self) -> bool {
            self.count == 0
        }

        /// Element-wise merge — exactly associative/commutative.
        pub fn merge(&mut self, other: &Hist) {
            for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
                *a += *b;
            }
            self.count += other.count;
            self.sum_ns = self.sum_ns.saturating_add(other.sum_ns);
        }

        /// Quantile estimate (bucket lower bound), `q` in [0, 1].
        pub fn quantile_ns(&self, q: f64) -> u64 {
            if self.count == 0 {
                return 0;
            }
            let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
            let mut seen = 0u64;
            for (i, &c) in self.counts.iter().enumerate() {
                seen += c;
                if seen >= rank {
                    return bucket_low_ns(i);
                }
            }
            bucket_low_ns(NUM_BUCKETS - 1)
        }

        pub fn mean_s(&self) -> f64 {
            if self.count == 0 {
                0.0
            } else {
                ns_to_sec(self.sum_ns) / self.count as f64
            }
        }

        /// Sparse JSON: summary stats plus `[bucket, count]` pairs for
        /// non-empty buckets.
        pub fn to_json(&self) -> Json {
            let mut j = Json::obj();
            j.set("count", self.count)
                .set("mean_s", self.mean_s())
                .set("p50_s", ns_to_sec(self.quantile_ns(0.50)))
                .set("p95_s", ns_to_sec(self.quantile_ns(0.95)))
                .set("p99_s", ns_to_sec(self.quantile_ns(0.99)));
            let buckets: Vec<Json> = self
                .counts
                .iter()
                .enumerate()
                .filter(|(_, &c)| c > 0)
                .map(|(i, &c)| Json::from(vec![i as u64, c]))
                .collect();
            j.set("buckets", buckets);
            j
        }
    }
}

use hist::Hist;

/// The three serve-latency histograms.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct HistSet {
    /// Queue wait: placement − arrival (spans handoffs).
    pub wait: Hist,
    /// Service: completion − placement.
    pub service: Hist,
    /// Slack at completion: deadline − completion, floored at zero.
    pub slack: Hist,
    /// Per-decision estimate-vs-oracle regret (|estimated − oracle|
    /// service time, ns). Only ever non-empty while the profiling plane
    /// is active — and only serialized then, so plane-off telemetry
    /// bytes are unchanged.
    pub regret: Hist,
}

impl HistSet {
    pub fn new() -> HistSet {
        HistSet::default()
    }

    pub fn merge(&mut self, other: &HistSet) {
        self.wait.merge(&other.wait);
        self.service.merge(&other.service);
        self.slack.merge(&other.slack);
        self.regret.merge(&other.regret);
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("wait", self.wait.to_json())
            .set("service", self.service.to_json())
            .set("slack", self.slack.to_json());
        if !self.regret.is_empty() {
            j.set("regret", self.regret.to_json());
        }
        j
    }
}

// ---------------------------------------------------------------------------
// Fleet time-series sampling
// ---------------------------------------------------------------------------

/// One GPM-style fleet sample at a virtual-time boundary. Captured by
/// pure reads of shard state, so sampling can never perturb the
/// simulation.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetSample {
    pub t_ns: u64,
    pub shard: u32,
    pub busy_sms: u32,
    pub total_sms: u32,
    pub queue_depth: u32,
    pub pending_by_app: [u32; AppId::COUNT],
    /// Idle slots per profile class (dense `ProfileId::index`).
    pub idle_by_class: [u32; NUM_PROFILES],
    /// Open seats per profile class (slots below the batch ceiling).
    pub open_seats_by_class: [u32; NUM_PROFILES],
    pub fragmentation: f64,
    pub host_used_bytes: u64,
    pub host_capacity_bytes: Option<u64>,
    /// Per-GPU C2C co-offloader counts.
    pub offloaders: Vec<u32>,
    /// Cached fleet power at the sample instant (W).
    pub power_w: f64,
    /// Per-GPU governed clocks (MHz); empty when the power plane is
    /// off, so plane-off sample JSON stays byte-identical.
    pub clocks_mhz: Vec<f64>,
}

impl FleetSample {
    /// Capture the shard's fleet/queue state at boundary `t_ns`.
    /// `power_w` is the shard's cached fleet power (state is constant
    /// between events, so one read serves every boundary the current
    /// event crosses).
    pub fn capture(
        t_ns: u64,
        shard: u32,
        fleet: &Fleet,
        queue: &AdmissionQueue,
        power_w: f64,
        clocks_mhz: Vec<f64>,
    ) -> FleetSample {
        let census = fleet.class_census();
        FleetSample {
            t_ns,
            shard,
            busy_sms: fleet.busy_sms(),
            total_sms: fleet.total_sms(),
            queue_depth: queue.pending_len() as u32,
            pending_by_app: *queue.pending_by_app(),
            idle_by_class: census.idle_slots,
            open_seats_by_class: census.open_seats,
            fragmentation: fleet.fragmentation(queue.smallest_pending_footprint_gib()),
            host_used_bytes: fleet.host_used_bytes(),
            host_capacity_bytes: fleet.host_capacity_bytes(),
            offloaders: fleet.gpus.iter().map(|g| g.offloaders()).collect(),
            power_w,
            clocks_mhz,
        }
    }

    /// Serialize to one JSONL object (`"type":"sample"`). Per-app and
    /// per-class maps only list non-zero entries.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("type", "sample")
            .set("t_s", ns_to_sec(self.t_ns))
            .set("shard", self.shard)
            .set(
                "sm_util",
                if self.total_sms == 0 {
                    0.0
                } else {
                    self.busy_sms as f64 / self.total_sms as f64
                },
            )
            .set("busy_sms", self.busy_sms)
            .set("queue_depth", self.queue_depth)
            .set("fragmentation", self.fragmentation)
            .set("host_used_bytes", self.host_used_bytes)
            .set("power_w", self.power_w);
        if let Some(cap) = self.host_capacity_bytes {
            j.set(
                "host_frac",
                if cap == 0 {
                    0.0
                } else {
                    self.host_used_bytes as f64 / cap as f64
                },
            );
        }
        let mut pending = Json::obj();
        for app in apps::all() {
            let n = self.pending_by_app[app.index()];
            if n > 0 {
                pending.set(app.name(), n);
            }
        }
        j.set("pending", pending);
        let mut idle = Json::obj();
        let mut open = Json::obj();
        for p in ALL_PROFILES {
            let name = crate::mig::profile::GiProfile::get(p).name;
            if self.idle_by_class[p.index()] > 0 {
                idle.set(name, self.idle_by_class[p.index()]);
            }
            if self.open_seats_by_class[p.index()] > 0 {
                open.set(name, self.open_seats_by_class[p.index()]);
            }
        }
        j.set("idle_slots", idle).set("open_seats", open);
        j.set(
            "offloaders",
            self.offloaders.iter().map(|&n| n as u64).collect::<Vec<u64>>(),
        );
        if !self.clocks_mhz.is_empty() {
            j.set("clocks_mhz", self.clocks_mhz.clone());
        }
        j
    }
}

// ---------------------------------------------------------------------------
// Sink: the generic instrumentation hook
// ---------------------------------------------------------------------------

/// Per-epoch batch of telemetry drained from one shard at a barrier.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetryChunk {
    pub shard: u32,
    pub events: Vec<TraceEvent>,
    pub samples: Vec<FleetSample>,
    pub counters: CounterSet,
    pub hists: HistSet,
}

impl TelemetryChunk {
    fn new(shard: u32) -> TelemetryChunk {
        TelemetryChunk {
            shard,
            events: Vec::new(),
            samples: Vec::new(),
            counters: CounterSet::new(),
            hists: HistSet::new(),
        }
    }
}

/// The instrumentation hook the serve hot path is generic over.
///
/// Every call site guards with `if S::ENABLED { .. }`; with the inert
/// [`NullSink`] the guard is a compile-time `false` and the hook —
/// including construction of its arguments — monomorphizes to nothing.
pub trait Sink: Send + 'static {
    const ENABLED: bool;

    /// Record a trace event at virtual time `t_ns`.
    fn emit(&mut self, t_ns: u64, job: Option<u32>, kind: EventKind);
    /// Bump a profiling counter.
    fn count(&mut self, c: Counter, n: u64);
    /// Record a completed job's latency triple (virtual ns).
    fn observe_latency(&mut self, wait_ns: u64, service_ns: u64, slack_ns: u64);
    /// Record one placement decision's estimate-vs-oracle regret (ns).
    /// Only ever called while the profiling plane is active.
    fn observe_regret(&mut self, regret_ns: u64);
    /// Whether a sample boundary lies strictly before `now_ns`.
    fn sample_due(&self, now_ns: u64) -> bool;
    /// The next pending sample boundary (only meaningful when due).
    fn next_sample_ns(&self) -> u64;
    /// Store a captured sample and advance to the next boundary.
    fn push_sample(&mut self, s: FleetSample);
    /// Drain everything recorded since the last drain (epoch barrier /
    /// end of run). `None` for inert sinks.
    fn take_chunk(&mut self) -> Option<TelemetryChunk>;
}

/// The inert default sink: telemetry off.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl Sink for NullSink {
    const ENABLED: bool = false;

    #[inline(always)]
    fn emit(&mut self, _t_ns: u64, _job: Option<u32>, _kind: EventKind) {}
    #[inline(always)]
    fn count(&mut self, _c: Counter, _n: u64) {}
    #[inline(always)]
    fn observe_latency(&mut self, _wait_ns: u64, _service_ns: u64, _slack_ns: u64) {}
    #[inline(always)]
    fn observe_regret(&mut self, _regret_ns: u64) {}
    #[inline(always)]
    fn sample_due(&self, _now_ns: u64) -> bool {
        false
    }
    #[inline(always)]
    fn next_sample_ns(&self) -> u64 {
        0
    }
    #[inline(always)]
    fn push_sample(&mut self, _s: FleetSample) {}
    #[inline(always)]
    fn take_chunk(&mut self) -> Option<TelemetryChunk> {
        None
    }
}

/// Telemetry plane configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TelemetryConfig {
    /// Virtual-time sampling period (seconds). The paper's GPM cadence
    /// (0.2 s, §III-A) is the default.
    pub sample_dt_s: f64,
}

impl Default for TelemetryConfig {
    fn default() -> Self {
        TelemetryConfig { sample_dt_s: 0.2 }
    }
}

impl TelemetryConfig {
    pub fn validate(&self) -> crate::Result<()> {
        anyhow::ensure!(
            self.sample_dt_s > 0.0 && self.sample_dt_s.is_finite(),
            "--sample-dt must be a positive number of seconds"
        );
        Ok(())
    }
}

/// The live sink: buffers one shard's telemetry between barriers.
#[derive(Debug)]
pub struct Recorder {
    shard: u32,
    seq: u64,
    sample_dt_ns: u64,
    next_sample_ns: u64,
    chunk: TelemetryChunk,
}

impl Recorder {
    pub fn new(shard: u32, cfg: &TelemetryConfig) -> Recorder {
        Recorder {
            shard,
            seq: 0,
            sample_dt_ns: crate::util::units::sec_to_ns(cfg.sample_dt_s).max(1),
            next_sample_ns: 0,
            chunk: TelemetryChunk::new(shard),
        }
    }
}

impl Sink for Recorder {
    const ENABLED: bool = true;

    fn emit(&mut self, t_ns: u64, job: Option<u32>, kind: EventKind) {
        self.chunk.events.push(TraceEvent {
            t_ns,
            shard: self.shard,
            seq: self.seq,
            job,
            kind,
        });
        self.seq += 1;
    }

    fn count(&mut self, c: Counter, n: u64) {
        self.chunk.counters.add(c, n);
    }

    fn observe_latency(&mut self, wait_ns: u64, service_ns: u64, slack_ns: u64) {
        self.chunk.hists.wait.record_ns(wait_ns);
        self.chunk.hists.service.record_ns(service_ns);
        self.chunk.hists.slack.record_ns(slack_ns);
    }

    fn observe_regret(&mut self, regret_ns: u64) {
        self.chunk.hists.regret.record_ns(regret_ns);
    }

    fn sample_due(&self, now_ns: u64) -> bool {
        self.next_sample_ns < now_ns
    }

    fn next_sample_ns(&self) -> u64 {
        self.next_sample_ns
    }

    fn push_sample(&mut self, s: FleetSample) {
        debug_assert_eq!(s.t_ns, self.next_sample_ns);
        self.chunk.samples.push(s);
        self.next_sample_ns += self.sample_dt_ns;
    }

    fn take_chunk(&mut self) -> Option<TelemetryChunk> {
        Some(std::mem::replace(
            &mut self.chunk,
            TelemetryChunk::new(self.shard),
        ))
    }
}

// ---------------------------------------------------------------------------
// Merged report
// ---------------------------------------------------------------------------

/// The merged telemetry of a whole run. Chunks are absorbed in shard-id
/// order at every epoch barrier; since per-shard streams are
/// deterministic and all merges are integer-associative, the finalized
/// report is bit-identical for every `--threads` value.
#[derive(Debug, Clone, Default)]
pub struct TelemetryReport {
    pub events: Vec<TraceEvent>,
    pub samples: Vec<FleetSample>,
    pub counters: CounterSet,
    pub hists: HistSet,
}

impl TelemetryReport {
    pub fn new() -> TelemetryReport {
        TelemetryReport::default()
    }

    /// Merge one shard-epoch chunk (associative: any barrier/shard order
    /// that is consistent per shard yields the same finalized report).
    pub fn absorb(&mut self, chunk: TelemetryChunk) {
        self.events.extend(chunk.events);
        self.samples.extend(chunk.samples);
        self.counters.merge(&chunk.counters);
        self.hists.merge(&chunk.hists);
    }

    /// Impose the canonical global order: `(t_ns, shard, seq)` for
    /// events, `(t_ns, shard)` for samples.
    pub fn finalize(&mut self) {
        self.events
            .sort_by_key(|e| (e.t_ns, e.shard, e.seq));
        self.samples.sort_by_key(|s| (s.t_ns, s.shard));
    }

    /// Full canonical JSON document (tests compare this byte-for-byte).
    pub fn to_json(&self) -> Json {
        let mut j = self.oracle_view();
        j.set("profile", self.counters.to_json());
        j
    }

    /// The mode-invariant sections: everything except the profiling
    /// counters (which legitimately differ between the indexed walk and
    /// the `NaiveOracle` scan). Byte-identical across serve modes.
    pub fn oracle_view(&self) -> Json {
        let mut j = Json::obj();
        j.set("schema", "migsim.telemetry.v1")
            .set("hist", self.hists.to_json())
            .set(
                "events",
                self.events.iter().map(|e| e.to_json()).collect::<Vec<Json>>(),
            )
            .set(
                "samples",
                self.samples.iter().map(|s| s.to_json()).collect::<Vec<Json>>(),
            );
        j
    }

    /// JSONL rendering: one compact object per event and sample, then a
    /// histogram line and a profile line. `jq 'select(.type=="event")'`
    /// etc. slice it.
    pub fn to_jsonl(&self) -> String {
        let mut out = String::new();
        for e in &self.events {
            out.push_str(&e.to_json().compact());
            out.push('\n');
        }
        for s in &self.samples {
            out.push_str(&s.to_json().compact());
            out.push('\n');
        }
        let mut h = Json::obj();
        h.set("type", "hist").set("hist", self.hists.to_json());
        out.push_str(&h.compact());
        out.push('\n');
        let mut p = Json::obj();
        p.set("type", "profile")
            .set("profile", self.counters.to_json());
        out.push_str(&p.compact());
        out.push('\n');
        out
    }

    pub fn summary(&self) -> String {
        format!(
            "telemetry: {} events, {} samples, {} completions (wait p95 {:.3}s)",
            self.events.len(),
            self.samples.len(),
            self.hists.wait.count(),
            ns_to_sec(self.hists.wait.quantile_ns(0.95)),
        )
    }
}

// ---------------------------------------------------------------------------
// Streaming JSONL writer
// ---------------------------------------------------------------------------

/// Incremental JSONL writer: absorbs shard-epoch chunks as they drain at
/// barriers and flushes every event strictly below the epoch-end
/// watermark, so a million-job trace never holds its full event stream
/// resident. The output is byte-identical to buffering the whole run in
/// a [`TelemetryReport`] and rendering [`TelemetryReport::to_jsonl`]:
///
/// - Events are written in the canonical `(t_ns, shard, seq)` order.
///   The watermark makes the prefix final — every event of epoch `k` is
///   stamped inside the epoch (barrier-stamped stragglers carry the next
///   epoch's start, which *is* the watermark, and the strict `<` cut
///   holds them back), so nothing that arrives later can sort below
///   what was already flushed.
/// - Samples, the histogram line and the profile line trail the events
///   in `to_jsonl`'s layout, so they are held until [`Self::finish`].
///   They are summaries and per-0.2 s series — bounded by horizon, not
///   by job count.
pub struct TelemetryStreamer<W: std::io::Write> {
    out: W,
    /// Events at or above every watermark seen so far.
    pending: Vec<TraceEvent>,
    samples: Vec<FleetSample>,
    counters: CounterSet,
    hists: HistSet,
}

impl<W: std::io::Write> TelemetryStreamer<W> {
    pub fn new(out: W) -> TelemetryStreamer<W> {
        TelemetryStreamer {
            out,
            pending: Vec::new(),
            samples: Vec::new(),
            counters: CounterSet::new(),
            hists: HistSet::new(),
        }
    }

    /// Merge one shard-epoch chunk (associative, like
    /// [`TelemetryReport::absorb`]).
    pub fn absorb(&mut self, chunk: TelemetryChunk) {
        self.pending.extend(chunk.events);
        self.samples.extend(chunk.samples);
        self.counters.merge(&chunk.counters);
        self.hists.merge(&chunk.hists);
    }

    /// Write out every buffered event with `t_ns < end_ns` in canonical
    /// order. Call with the epoch's end once all of the epoch's chunks
    /// are absorbed.
    pub fn flush_below(&mut self, end_ns: u64) -> crate::Result<()> {
        // (t_ns, shard, seq) is unique per event, so the sort is total
        // and the streamed prefix equals the buffered path's global sort.
        self.pending.sort_by_key(|e| (e.t_ns, e.shard, e.seq));
        let cut = self.pending.partition_point(|e| e.t_ns < end_ns);
        for e in self.pending.drain(..cut) {
            writeln!(self.out, "{}", e.to_json().compact())?;
        }
        Ok(())
    }

    /// Flush every remaining event, then the samples and the trailing
    /// hist/profile lines — the exact tail `to_jsonl` renders.
    pub fn finish(mut self) -> crate::Result<()> {
        self.pending.sort_by_key(|e| (e.t_ns, e.shard, e.seq));
        for e in self.pending.drain(..) {
            writeln!(self.out, "{}", e.to_json().compact())?;
        }
        self.samples.sort_by_key(|s| (s.t_ns, s.shard));
        for s in &self.samples {
            writeln!(self.out, "{}", s.to_json().compact())?;
        }
        let mut h = Json::obj();
        h.set("type", "hist").set("hist", self.hists.to_json());
        writeln!(self.out, "{}", h.compact())?;
        let mut p = Json::obj();
        p.set("type", "profile")
            .set("profile", self.counters.to_json());
        writeln!(self.out, "{}", p.compact())?;
        self.out.flush()?;
        Ok(())
    }
}

/// Where the sharded coordinator pours barrier chunks: the buffered
/// [`TelemetryReport`] (everything held until a final sort) or the
/// incremental [`TelemetryStreamer`] (the barrier hook advances the
/// write-out watermark). The two produce byte-identical JSONL.
pub(crate) trait ChunkCollector {
    fn absorb_chunk(&mut self, chunk: TelemetryChunk);
    fn count(&mut self, c: Counter, n: u64);
    /// All of an epoch's chunks are in; `end_ns` is the epoch's end.
    fn at_barrier(&mut self, end_ns: u64) -> crate::Result<()>;
}

impl ChunkCollector for TelemetryReport {
    fn absorb_chunk(&mut self, chunk: TelemetryChunk) {
        self.absorb(chunk);
    }
    fn count(&mut self, c: Counter, n: u64) {
        self.counters.add(c, n);
    }
    fn at_barrier(&mut self, _end_ns: u64) -> crate::Result<()> {
        Ok(())
    }
}

impl<W: std::io::Write> ChunkCollector for TelemetryStreamer<W> {
    fn absorb_chunk(&mut self, chunk: TelemetryChunk) {
        self.absorb(chunk);
    }
    fn count(&mut self, c: Counter, n: u64) {
        self.counters.add(c, n);
    }
    fn at_barrier(&mut self, end_ns: u64) -> crate::Result<()> {
        self.flush_below(end_ns)
    }
}

// ---------------------------------------------------------------------------
// Trace-conservation audit
// ---------------------------------------------------------------------------

/// Conservation checks over a merged event trace: every admitted job
/// terminates exactly once, placed jobs complete (or are killed by a
/// fault with a matching retry/fail), forwarded jobs re-arrive exactly
/// once, and retried jobs re-admit exactly `retries` times.
pub mod audit {
    use super::{EventKind, TraceEvent};
    use crate::util::json::Json;
    use anyhow::{bail, ensure, Context};
    use std::collections::BTreeMap;
    use std::io::BufRead;

    /// The reduced per-job view the audit runs over.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum AuditKind {
        Admit { handoff: bool },
        Place,
        Complete,
        Expire,
        Reject,
        Handoff,
        Retry,
        Fail,
        Shed,
    }

    /// Totals of a passing audit.
    #[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
    pub struct AuditReport {
        pub jobs: u64,
        pub completed: u64,
        pub expired: u64,
        pub rejected: u64,
        pub handoffs: u64,
        pub failed: u64,
        pub retries: u64,
        pub shed: u64,
    }

    impl AuditReport {
        pub fn summary(&self) -> String {
            let mut s = format!(
                "audit ok: {} jobs conserved ({} completed, {} expired, {} rejected, {} handoffs)",
                self.jobs, self.completed, self.expired, self.rejected, self.handoffs
            );
            if self.failed > 0 || self.retries > 0 {
                s.push_str(&format!(
                    " [faults: {} retries, {} failed]",
                    self.retries, self.failed
                ));
            }
            if self.shed > 0 {
                s.push_str(&format!(" [degraded: {} shed]", self.shed));
            }
            s
        }
    }

    #[derive(Debug, Clone, Copy, Default)]
    struct JobLedger {
        admits: u64,
        readmits: u64,
        places: u64,
        completes: u64,
        expires: u64,
        rejects: u64,
        handoffs: u64,
        retries: u64,
        fails: u64,
        sheds: u64,
    }

    fn check(jobs: BTreeMap<u32, JobLedger>) -> crate::Result<AuditReport> {
        let mut r = AuditReport::default();
        for (id, l) in &jobs {
            // Each fault-plane retry re-enters the queue through a fresh
            // primary admission, so a job admits exactly 1 + retries
            // times (and once more per handoff, tracked separately).
            ensure!(
                l.admits == 1 + l.retries,
                "job {id}: {} primary admissions vs {} retries (exactly 1 + retries required)",
                l.admits,
                l.retries
            );
            ensure!(
                l.handoffs <= 1,
                "job {id}: forwarded {} times (one-hop invariant)",
                l.handoffs
            );
            ensure!(
                l.readmits == l.handoffs,
                "job {id}: {} handoffs but {} re-arrivals (forwarded jobs must re-arrive exactly once)",
                l.handoffs,
                l.readmits
            );
            let terminals = l.completes + l.expires + l.rejects + l.fails + l.sheds;
            ensure!(
                terminals == 1,
                "job {id}: {terminals} terminal events (exactly one of complete/expire/reject/fail/shed required)"
            );
            // Every placement ends exactly one way: it completes, or a
            // fault kills it into a retry, or into a terminal fail.
            ensure!(
                l.places == l.completes + l.retries + l.fails,
                "job {id}: {} placements vs {} completions + {} retries + {} fails",
                l.places,
                l.completes,
                l.retries,
                l.fails
            );
            r.jobs += 1;
            r.completed += l.completes;
            r.expired += l.expires;
            r.rejected += l.rejects;
            r.handoffs += l.handoffs;
            r.failed += l.fails;
            r.retries += l.retries;
            r.shed += l.sheds;
        }
        Ok(r)
    }

    fn ledger_add(jobs: &mut BTreeMap<u32, JobLedger>, id: u32, kind: AuditKind) {
        let l = jobs.entry(id).or_default();
        match kind {
            AuditKind::Admit { handoff: false } => l.admits += 1,
            AuditKind::Admit { handoff: true } => l.readmits += 1,
            AuditKind::Place => l.places += 1,
            AuditKind::Complete => l.completes += 1,
            AuditKind::Expire => l.expires += 1,
            AuditKind::Reject => l.rejects += 1,
            AuditKind::Handoff => l.handoffs += 1,
            AuditKind::Retry => l.retries += 1,
            AuditKind::Fail => l.fails += 1,
            AuditKind::Shed => l.sheds += 1,
        }
    }

    /// Audit an in-memory event trace.
    pub fn audit(events: &[TraceEvent]) -> crate::Result<AuditReport> {
        let mut jobs: BTreeMap<u32, JobLedger> = BTreeMap::new();
        for e in events {
            let kind = match &e.kind {
                EventKind::Admit { handoff, .. } => AuditKind::Admit { handoff: *handoff },
                EventKind::Place { .. } => AuditKind::Place,
                EventKind::Complete { .. } => AuditKind::Complete,
                EventKind::Expire { .. } => AuditKind::Expire,
                EventKind::Reject { .. } => AuditKind::Reject,
                EventKind::Handoff { .. } => AuditKind::Handoff,
                EventKind::Retry { .. } => AuditKind::Retry,
                EventKind::Fail { .. } => AuditKind::Fail,
                EventKind::Shed { .. } => AuditKind::Shed,
                EventKind::Reconfig { .. }
                | EventKind::OffloadDenied { .. }
                | EventKind::Fault { .. }
                | EventKind::Cordon { .. }
                | EventKind::Recover { .. }
                | EventKind::DomainFault { .. }
                | EventKind::RepairQueued { .. }
                | EventKind::RepairStart { .. }
                | EventKind::Throttle { .. }
                | EventKind::Probe { .. }
                | EventKind::Regret { .. } => continue,
            };
            let id = match e.job {
                Some(id) => id,
                None => bail!("trace event '{}' carries no job id", e.kind.tag()),
            };
            ledger_add(&mut jobs, id, kind);
        }
        check(jobs)
    }

    /// Audit a JSONL trace already in memory. Thin wrapper over
    /// [`audit_jsonl_reader`] for callers that hold the whole text.
    pub fn audit_jsonl(text: &str) -> crate::Result<AuditReport> {
        audit_jsonl_reader(text.as_bytes())
    }

    /// Audit a JSONL trace streamed line-by-line from any reader
    /// (`migsim audit-trace` feeds a buffered file handle, so traces
    /// larger than memory audit in one pass). Lines whose `type` is not
    /// `event`, and event kinds without lifecycle meaning, are skipped.
    pub fn audit_jsonl_reader<R: BufRead>(reader: R) -> crate::Result<AuditReport> {
        let mut jobs: BTreeMap<u32, JobLedger> = BTreeMap::new();
        let mut saw_event = false;
        for (lineno, line) in reader.lines().enumerate() {
            let line = line.with_context(|| format!("line {}: read failed", lineno + 1))?;
            if line.trim().is_empty() {
                continue;
            }
            let doc = Json::parse(&line)
                .map_err(anyhow::Error::from)
                .with_context(|| format!("line {}: invalid JSON", lineno + 1))?;
            if doc.get("type").and_then(|t| t.as_str()) != Some("event") {
                continue;
            }
            let kind_tag = doc
                .get("kind")
                .and_then(|k| k.as_str())
                .with_context(|| format!("line {}: event without kind", lineno + 1))?;
            let kind = match kind_tag {
                "admit" => AuditKind::Admit {
                    handoff: doc.get("handoff").and_then(|h| h.as_bool()).unwrap_or(false),
                },
                "place" => AuditKind::Place,
                "complete" => AuditKind::Complete,
                "expire" => AuditKind::Expire,
                "reject" => AuditKind::Reject,
                "handoff" => AuditKind::Handoff,
                "retry" => AuditKind::Retry,
                "fail" => AuditKind::Fail,
                "shed" => AuditKind::Shed,
                _ => continue,
            };
            let id = doc
                .get("job")
                .and_then(|j| j.as_u64())
                .with_context(|| format!("line {}: '{kind_tag}' event without job id", lineno + 1))?;
            saw_event = true;
            ledger_add(&mut jobs, id as u32, kind);
        }
        ensure!(saw_event, "no lifecycle events found in trace");
        check(jobs)
    }
}

#[cfg(test)]
mod tests {
    use super::hist::{bucket_low_ns, bucket_of, Hist, NUM_BUCKETS};
    use super::*;

    #[test]
    fn hist_buckets_are_monotone_and_contain_their_values() {
        let mut prev = 0usize;
        for v in [0u64, 1, 7, 8, 9, 100, 1023, 1024, 1_000_000, u64::MAX] {
            let b = bucket_of(v);
            assert!(b < NUM_BUCKETS, "bucket {b} out of range for {v}");
            assert!(bucket_low_ns(b) <= v, "lower bound above value for {v}");
            if b + 1 < NUM_BUCKETS {
                assert!(bucket_low_ns(b + 1) > v, "value {v} beyond bucket end");
            }
            assert!(b >= prev, "buckets must be monotone in value");
            prev = b;
        }
        // Every bucket's lower bound maps back to that bucket.
        for i in 0..NUM_BUCKETS {
            assert_eq!(bucket_of(bucket_low_ns(i)), i, "bucket {i} roundtrip");
        }
    }

    #[test]
    fn hist_relative_error_is_bounded() {
        for v in [10u64, 999, 12_345, 7_777_777, 1 << 40] {
            let low = bucket_low_ns(bucket_of(v));
            assert!((v - low) as f64 / v as f64 <= 0.125, "err > 12.5% for {v}");
        }
    }

    #[test]
    fn hist_merge_matches_sequential_and_is_associative() {
        let vals: Vec<u64> = (0..500u64).map(|i| i.wrapping_mul(2654435761) >> 16).collect();
        let mut whole = Hist::new();
        vals.iter().for_each(|&v| whole.record_ns(v));
        let (mut a, mut b, mut c) = (Hist::new(), Hist::new(), Hist::new());
        vals[..100].iter().for_each(|&v| a.record_ns(v));
        vals[100..300].iter().for_each(|&v| b.record_ns(v));
        vals[300..].iter().for_each(|&v| c.record_ns(v));
        // (a ⊕ b) ⊕ c
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        // a ⊕ (b ⊕ c)
        let mut right = b.clone();
        right.merge(&c);
        let mut right2 = a.clone();
        right2.merge(&right);
        assert_eq!(left, right2);
        assert_eq!(left, whole);
        assert_eq!(left.to_json().pretty(), whole.to_json().pretty());
    }

    #[test]
    fn quantiles_bracket_the_distribution() {
        let mut h = Hist::new();
        for v in 1..=1000u64 {
            h.record_ns(v * 1000);
        }
        let p50 = h.quantile_ns(0.50);
        let p99 = h.quantile_ns(0.99);
        assert!(p50 <= 500_000 && p50 >= 400_000, "p50 {p50}");
        assert!(p99 <= 990_000 && p99 >= 850_000, "p99 {p99}");
        assert!(h.quantile_ns(0.0) >= 875, "min within bucket error of 1000");
        assert_eq!(Hist::new().quantile_ns(0.5), 0);
    }

    #[test]
    fn counter_set_merges_elementwise() {
        let mut a = CounterSet::new();
        a.add(Counter::PlaceDecisions, 3);
        a.add(Counter::WalkSteps, 10);
        let mut b = CounterSet::new();
        b.add(Counter::WalkSteps, 5);
        b.add(Counter::MemoHits, 2);
        a.merge(&b);
        assert_eq!(a.get(Counter::PlaceDecisions), 3);
        assert_eq!(a.get(Counter::WalkSteps), 15);
        assert_eq!(a.get(Counter::MemoHits), 2);
        assert_eq!(a.get(Counter::HandoffAttempts), 0);
    }

    fn ev(t_ns: u64, seq: u64, job: u32, kind: EventKind) -> TraceEvent {
        TraceEvent {
            t_ns,
            shard: 0,
            seq,
            job: Some(job),
            kind,
        }
    }

    fn admit(t: u64, seq: u64, job: u32, handoff: bool) -> TraceEvent {
        ev(
            t,
            seq,
            job,
            EventKind::Admit {
                app: AppId::Faiss,
                deadline_ns: t + 1000,
                handoff,
            },
        )
    }

    #[test]
    fn audit_accepts_a_conserved_trace() {
        let place = EventKind::Place {
            app: AppId::Faiss,
            gpu: 0,
            slot: 0,
            class: "1g.12gb",
            occupancy: 1,
            offloaded: false,
            share: 1,
            runtime_ns: 500,
        };
        let complete = EventKind::Complete {
            app: AppId::Faiss,
            wait_ns: 10,
            service_ns: 500,
            slack_ns: 490,
            offloaded: false,
        };
        let events = vec![
            admit(0, 0, 0, false),
            ev(5, 1, 0, place),
            admit(1, 2, 1, false),
            ev(
                2,
                3,
                1,
                EventKind::Handoff {
                    app: AppId::Faiss,
                    dest: 1,
                    reason: HandoffReason::OpenSeat,
                },
            ),
            admit(3, 4, 1, true),
            ev(505, 5, 0, complete),
            ev(900, 6, 1, EventKind::Expire { app: AppId::Faiss }),
        ];
        let r = audit::audit(&events).unwrap();
        assert_eq!(r.jobs, 2);
        assert_eq!(r.completed, 1);
        assert_eq!(r.expired, 1);
        assert_eq!(r.handoffs, 1);
    }

    #[test]
    fn audit_rejects_lost_and_duplicated_jobs() {
        // Admitted but never terminated.
        let events = vec![admit(0, 0, 0, false)];
        assert!(audit::audit(&events).is_err(), "lost job must fail");
        // Terminated twice.
        let events = vec![
            admit(0, 0, 0, false),
            ev(1, 1, 0, EventKind::Expire { app: AppId::Faiss }),
            ev(2, 2, 0, EventKind::Expire { app: AppId::Faiss }),
        ];
        assert!(audit::audit(&events).is_err(), "double expiry must fail");
        // Forwarded but never re-admitted.
        let events = vec![
            admit(0, 0, 0, false),
            ev(
                1,
                1,
                0,
                EventKind::Handoff {
                    app: AppId::Faiss,
                    dest: 1,
                    reason: HandoffReason::Reconfig,
                },
            ),
            ev(2, 2, 0, EventKind::Expire { app: AppId::Faiss }),
        ];
        assert!(audit::audit(&events).is_err(), "vanished handoff must fail");
    }

    #[test]
    fn audit_tracks_fault_retries_and_failures() {
        let place = |t: u64, seq: u64, job: u32| {
            ev(
                t,
                seq,
                job,
                EventKind::Place {
                    app: AppId::Faiss,
                    gpu: 0,
                    slot: 0,
                    class: "1g.12gb",
                    occupancy: 1,
                    offloaded: false,
                    share: 1,
                    runtime_ns: 500,
                },
            )
        };
        let complete = EventKind::Complete {
            app: AppId::Faiss,
            wait_ns: 10,
            service_ns: 500,
            slack_ns: 490,
            offloaded: false,
        };
        // Job 0: admit → place → fault retry → re-admit → place → complete.
        // Job 1: admit → place → fault with budget spent → fail.
        // Cordon/recover/fault events carry no job and are skipped.
        let events = vec![
            admit(0, 0, 0, false),
            place(5, 1, 0),
            ev(7, 2, 0, EventKind::Retry { app: AppId::Faiss, attempt: 1 }),
            admit(7, 3, 0, false),
            place(8, 4, 0),
            ev(500, 5, 0, complete.clone()),
            admit(1, 6, 1, false),
            place(6, 7, 1),
            ev(9, 8, 1, EventKind::Fail { app: AppId::Faiss }),
            TraceEvent {
                t_ns: 7,
                shard: 0,
                seq: 9,
                job: None,
                kind: EventKind::Cordon { gpu: 0 },
            },
            TraceEvent {
                t_ns: 90,
                shard: 0,
                seq: 10,
                job: None,
                kind: EventKind::Recover { gpu: 0 },
            },
        ];
        let r = audit::audit(&events).unwrap();
        assert_eq!(r.jobs, 2);
        assert_eq!(r.completed, 1);
        assert_eq!(r.failed, 1);
        assert_eq!(r.retries, 1);
        assert!(r.summary().contains("1 retries, 1 failed"));

        // A retry event without the matching re-admission must fail.
        let events = vec![
            admit(0, 0, 0, false),
            place(5, 1, 0),
            ev(7, 2, 0, EventKind::Retry { app: AppId::Faiss, attempt: 1 }),
        ];
        assert!(audit::audit(&events).is_err(), "retry without re-admission");
        // A fail is terminal: a completion after it is a double-terminal.
        let events = vec![
            admit(0, 0, 0, false),
            place(5, 1, 0),
            place(6, 2, 0),
            ev(7, 3, 0, EventKind::Fail { app: AppId::Faiss }),
            ev(8, 4, 0, complete.clone()),
        ];
        assert!(audit::audit(&events).is_err(), "fail then complete");
    }

    #[test]
    fn audit_tracks_degraded_outcomes() {
        // Job 0 is shed by brown-out backpressure: a terminal outcome the
        // ledger balances like fail/expire. Domain-fault and repair-crew
        // events carry no job lifecycle and are skipped.
        let events = vec![
            admit(0, 0, 0, false),
            TraceEvent {
                t_ns: 3,
                shard: 0,
                seq: 1,
                job: None,
                kind: EventKind::DomainFault { domain: 0, members: 2 },
            },
            TraceEvent {
                t_ns: 3,
                shard: 0,
                seq: 2,
                job: None,
                kind: EventKind::RepairQueued { gpu: 1 },
            },
            TraceEvent {
                t_ns: 9,
                shard: 0,
                seq: 3,
                job: None,
                kind: EventKind::RepairStart { gpu: 1 },
            },
            ev(4, 4, 0, EventKind::Shed { app: AppId::Faiss }),
        ];
        let r = audit::audit(&events).unwrap();
        assert_eq!(r.jobs, 1);
        assert_eq!(r.shed, 1);
        assert_eq!(r.completed + r.expired + r.rejected + r.failed, 0);
        assert!(r.summary().contains("1 shed"));
        // Shed is terminal: a later completion is a double-terminal.
        let events = vec![
            admit(0, 0, 0, false),
            ev(4, 1, 0, EventKind::Shed { app: AppId::Faiss }),
            ev(
                9,
                2,
                0,
                EventKind::Complete {
                    app: AppId::Faiss,
                    wait_ns: 1,
                    service_ns: 5,
                    slack_ns: 0,
                    offloaded: false,
                },
            ),
        ];
        assert!(audit::audit(&events).is_err(), "shed then complete");
        // And the JSONL path recognizes the shed tag.
        let mut report = TelemetryReport::new();
        let mut chunk = TelemetryChunk::new(0);
        chunk.events.push(admit(0, 0, 0, false));
        chunk.events.push(ev(4, 1, 0, EventKind::Shed { app: AppId::Faiss }));
        report.absorb(chunk);
        report.finalize();
        let r = audit::audit_jsonl(&report.to_jsonl()).unwrap();
        assert_eq!(r.shed, 1);
        assert_eq!(r, audit::audit(&report.events).unwrap());
    }

    #[test]
    fn audit_jsonl_streams_from_a_reader() {
        let mut report = TelemetryReport::new();
        let mut chunk = TelemetryChunk::new(0);
        chunk.events.push(admit(0, 0, 0, false));
        chunk.events.push(ev(
            7,
            1,
            0,
            EventKind::Reject { app: AppId::Faiss },
        ));
        report.absorb(chunk);
        report.finalize();
        let text = report.to_jsonl();
        let via_reader =
            audit::audit_jsonl_reader(std::io::BufReader::new(text.as_bytes())).unwrap();
        assert_eq!(via_reader, audit::audit_jsonl(&text).unwrap());
        assert!(audit::audit_jsonl_reader("not json\n".as_bytes()).is_err());
    }

    #[test]
    fn audit_jsonl_roundtrips_through_the_report() {
        let mut report = TelemetryReport::new();
        let mut chunk = TelemetryChunk::new(0);
        chunk.events.push(admit(0, 0, 0, false));
        chunk.events.push(ev(
            7,
            1,
            0,
            EventKind::Reject { app: AppId::Faiss },
        ));
        report.absorb(chunk);
        report.finalize();
        let text = report.to_jsonl();
        let r = audit::audit_jsonl(&text).unwrap();
        assert_eq!(r.jobs, 1);
        assert_eq!(r.rejected, 1);
        // And the audit agrees with the in-memory path.
        assert_eq!(r, audit::audit(&report.events).unwrap());
    }

    #[test]
    fn report_merge_is_shard_order_deterministic() {
        let mk = |shard: u32, t: u64| {
            let mut c = TelemetryChunk::new(shard);
            c.events.push(TraceEvent {
                t_ns: t,
                shard,
                seq: 0,
                job: Some(shard),
                kind: EventKind::Expire { app: AppId::Faiss },
            });
            c.counters.add(Counter::PlaceDecisions, 1);
            c.hists.wait.record_ns(t);
            c
        };
        let mut a = TelemetryReport::new();
        a.absorb(mk(0, 50));
        a.absorb(mk(1, 10));
        a.finalize();
        let mut b = TelemetryReport::new();
        b.absorb(mk(1, 10));
        b.absorb(mk(0, 50));
        b.finalize();
        assert_eq!(a.to_json().pretty(), b.to_json().pretty());
        assert_eq!(a.events[0].shard, 1, "finalize orders by (t, shard, seq)");
    }
}
