//! Measurement probes from the paper's methodology:
//! - §III-C: the SM-count probe (fixed-work kernel, increasing block
//!   count, detect the runtime doubling at N_SM + 1);
//! - §IV-B: the null-context probe quantifying per-process context memory
//!   overhead under each sharing scheme.

use crate::gpu::sm;
use crate::mig::profile::{GiProfile, ALL_PROFILES};
use crate::sharing::{ContextModel, Scheme};

/// Result of probing one MIG profile.
#[derive(Debug, Clone)]
pub struct SmProbeResult {
    pub profile: &'static str,
    /// SM count reported by the (modelled) driver.
    pub reported_sms: u32,
    /// SM count recovered by the runtime-doubling probe.
    pub measured_sms: u32,
    /// Block count at which runtime first doubled.
    pub doubling_n: u64,
}

/// Run the §III-C probe across all MIG profiles. In the paper "those two
/// values matched in all situations" — the test below asserts the same.
pub fn probe_all_profiles() -> Vec<SmProbeResult> {
    ALL_PROFILES
        .iter()
        .map(|&id| {
            let p = GiProfile::get(id);
            let measured = sm::measure_sm_count(p.sms);
            SmProbeResult {
                profile: p.name,
                reported_sms: p.sms,
                measured_sms: measured,
                doubling_n: measured as u64 + 1,
            }
        })
        .collect()
}

/// Result of the context-overhead probe for one scheme.
#[derive(Debug, Clone)]
pub struct ContextProbeResult {
    pub scheme: String,
    pub processes: u32,
    pub per_process_gib: f64,
    pub total_gib: f64,
}

/// Run the §IV-B null-context probe for the co-run schemes.
pub fn probe_context_overhead(processes: u32) -> Vec<ContextProbeResult> {
    let model = ContextModel::default();
    let schemes = [
        Scheme::Mig {
            profile: crate::mig::ProfileId::P1g12gb,
            copies: processes,
        },
        Scheme::TimeSlice { copies: processes },
        Scheme::Mps {
            sm_pct: 13,
            copies: processes,
        },
    ];
    schemes
        .iter()
        .map(|s| ContextProbeResult {
            scheme: s.label(),
            processes,
            per_process_gib: model.per_process_gib(s),
            total_gib: model.total_gib(s, processes),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn probe_matches_reported_everywhere() {
        for r in probe_all_profiles() {
            assert_eq!(
                r.measured_sms, r.reported_sms,
                "{}: probe disagrees with driver",
                r.profile
            );
            assert_eq!(r.doubling_n, r.reported_sms as u64 + 1);
        }
    }

    #[test]
    fn context_probe_reproduces_section4b() {
        let rs = probe_context_overhead(7);
        let mig = &rs[0];
        let ts = &rs[1];
        let mps = &rs[2];
        assert!((mig.per_process_gib - 0.060).abs() < 1e-9);
        assert!((ts.per_process_gib - 0.600).abs() < 1e-9);
        assert!((mps.total_gib - 0.600).abs() < 1e-9);
        assert!(ts.total_gib > mig.total_gib);
    }
}
