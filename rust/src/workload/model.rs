//! Workload data types and the kernel performance model.
//!
//! A kernel's duration on a partition is a three-way roofline:
//!
//! ```text
//! t_c   = flops / (pipeline_peak(sms, clock) · tail_eff)
//! t_m   = hbm_bytes / (bw_alloc · bw_eff)
//! t_x   = c2c_bytes / c2c_bw
//! t_mem = max(t_m, t_x) + ½·min(t_m, t_x)   (partial MLP overlap of the
//!                                            local and remote streams)
//! t     = max(t_c, t_mem)
//! ```
//!
//! `tail_eff` is the §IV-A wave-quantization term from `gpu::sm`; `bw_eff`
//! is the application's achievable fraction of its bandwidth allocation
//! (coalescing quality). Clock only scales the compute term — memory and
//! C2C run off their own clock domains, which is what makes memory-bound
//! workloads insensitive to DVFS throttling (Fig. 7a).

use crate::gpu::{occupancy, tail_efficiency, GpuSpec, PipelineMix};

/// Per-SM memory-issue ceiling (GiB/s): a partition cannot draw more HBM
/// bandwidth than its SMs can issue requests for. Calibrated from Table
/// II, whose per-profile bandwidths track SM counts at ~25-27 GiB/s/SM;
/// this is what makes a 1c.2g.24gb CI (16 SMs on a 812 GiB/s GI) perform
/// like a 1g instance on memory-bound work.
pub const SM_BW_ISSUE_GIBS: f64 = 27.5;

/// One GPU kernel launch (aggregated: a model kernel may stand for a fused
/// sequence of real launches with the same signature).
#[derive(Debug, Clone)]
pub struct KernelSpec {
    pub name: &'static str,
    pub mix: PipelineMix,
    /// Total FLOPs per launch.
    pub flops: f64,
    /// HBM traffic per launch (bytes).
    pub hbm_bytes: f64,
    /// NVLink-C2C traffic per launch (bytes); non-zero for STREAM-Nvlink
    /// and for offloaded workloads.
    pub c2c_bytes: f64,
    /// Whether C2C traffic is read-dominant (offloaded data reads travel
    /// host→device, capped at the H2D direct rate — 207 GiB/s on 16 SMs,
    /// Table IVb). STREAM-Nvlink streams both directions.
    pub c2c_read_only: bool,
    /// Launch geometry for occupancy/tail modelling.
    pub blocks: u64,
    pub warps_per_block: u32,
    /// Blocks concurrently resident per SM (register/smem limit).
    pub resident_per_sm: u32,
    /// Achievable fraction of the bandwidth allocation (0..1].
    pub bw_eff: f64,
}

/// The execution environment a kernel currently sees.
#[derive(Debug, Clone, Copy)]
pub struct ExecEnv {
    /// SMs available to this process.
    pub sms: u32,
    /// SM clock as a fraction of boost (DVFS state).
    pub clock_frac: f64,
    /// HBM bandwidth actually granted (GiB/s) — the partition cap, reduced
    /// by contention on shared schemes.
    pub bw_gibs: f64,
    /// C2C bandwidth granted (GiB/s); 0 forbids C2C traffic.
    pub c2c_bw_gibs: f64,
    /// Multiplicative slowdown of the *compute* pipeline from shared-L2 /
    /// cache interference (1.0 = none). Memory-bound streaming traffic is
    /// unaffected — which is why Qiskit/NekRS favour MPS's flexible
    /// bandwidth over MIG's hard caps (§V-A) while compute-bound apps
    /// favour MIG's isolation.
    pub interference: f64,
    /// Temporal share factor (>= 1): time-slicing serializes kernels, so
    /// the whole kernel (compute and memory) stretches by this factor.
    pub time_share: f64,
}

impl KernelSpec {
    /// Kernel duration in seconds under `env` on `spec`.
    pub fn duration_s(&self, spec: &GpuSpec, env: &ExecEnv) -> f64 {
        assert!(env.sms >= 1, "kernel with no SMs");
        let tail = tail_efficiency(self.blocks, env.sms, self.resident_per_sm);
        let peak = self.mix.effective_flops(|p| {
            spec.pipeline_flops(p, env.sms, env.clock_frac * spec.clock_max_mhz)
        });
        let t_compute = if self.flops > 0.0 {
            self.flops / (peak * tail)
        } else {
            0.0
        };
        let t_mem = if self.hbm_bytes > 0.0 {
            let bw = env.bw_gibs.min(env.sms as f64 * SM_BW_ISSUE_GIBS);
            self.hbm_bytes / (crate::util::units::gibs(bw) * self.bw_eff)
        } else {
            0.0
        };
        let t_c2c = if self.c2c_bytes > 0.0 {
            assert!(env.c2c_bw_gibs > 0.0, "C2C traffic with no C2C bandwidth");
            self.c2c_bytes / crate::util::units::gibs(env.c2c_bw_gibs)
        } else {
            0.0
        };
        // Local HBM and remote C2C streams overlap only partially: memory-
        // level parallelism hides 40% of the shorter stream (calibrated
        // against §VI-C's offloading slowdowns).
        let t_memory = t_mem.max(t_c2c) + 0.6 * t_mem.min(t_c2c);
        (t_compute * env.interference.max(1.0)).max(t_memory) * env.time_share.max(1.0)
    }

    /// Achieved warp occupancy while this kernel runs on `sms` SMs.
    pub fn occupancy(&self, spec: &GpuSpec, sms: u32) -> f64 {
        occupancy(
            self.blocks,
            self.warps_per_block,
            sms,
            spec.max_warps_per_sm,
            self.resident_per_sm,
        )
    }

    /// Achieved FLOP rate by pipeline while running (TFLOP/s), for the
    /// power model.
    pub fn flop_rate_tflops(&self, duration_s: f64) -> f64 {
        if duration_s <= 0.0 {
            0.0
        } else {
            self.flops / duration_s / 1e12
        }
    }

    /// Achieved HBM byte rate while running (TB/s).
    pub fn hbm_rate_tbs(&self, duration_s: f64) -> f64 {
        if duration_s <= 0.0 {
            0.0
        } else {
            self.hbm_bytes / duration_s / 1e12
        }
    }

    /// Achieved C2C byte rate while running (TB/s).
    pub fn c2c_rate_tbs(&self, duration_s: f64) -> f64 {
        if duration_s <= 0.0 {
            0.0
        } else {
            self.c2c_bytes / duration_s / 1e12
        }
    }
}

/// A macro-iteration: CPU-side work followed by GPU kernels, repeated.
#[derive(Debug, Clone)]
pub struct MacroPhase {
    /// CPU-side time per iteration (s) — does not scale with GPU size.
    pub cpu_s: f64,
    pub kernels: Vec<KernelSpec>,
    pub repeats: u32,
}

/// A modelled application.
#[derive(Debug, Clone)]
pub struct AppModel {
    pub name: &'static str,
    pub description: &'static str,
    pub input: &'static str,
    /// Peak GPU memory footprint (GiB) — Table III problem sizes fit the
    /// 11 GiB of 1g.12gb; §VI large variants exceed it.
    pub footprint_gib: f64,
    /// Fraction of the footprint that is "cold" (spillable with little
    /// traffic) — drives the §VI offload cost (e.g. FAISS's short burst).
    pub cold_frac: f64,
    /// Relative CPU-time inflation when 7 copies co-run (host contention).
    pub cpu_corun_inflation: f64,
    /// Offloading mode (§VI-A): `None` uses direct C2C access
    /// (cudaMallocManaged-style, the default); `Some(f)` models a native
    /// chunked-swap strategy (Qiskit) that transfers `f` of the spilled
    /// data per iteration over a copy engine, stalling the GPU.
    pub swap_frac: Option<f64>,
    /// One-time startup (context init, data/model load) during which the
    /// GPU idles — the inter-job idle the serial baseline of Figs. 5/6
    /// pays seven times but a co-run pays only once per copy,
    /// concurrently.
    pub startup_s: f64,
    pub phases: Vec<MacroPhase>,
    /// Unit label for the performance metric P (§VI-C): "runs/s" uses
    /// inverse runtime; "tok/s" scales by work per iteration.
    pub perf_unit: &'static str,
}

impl AppModel {
    /// Total kernel launches across the run.
    pub fn total_kernels(&self) -> u64 {
        self.phases
            .iter()
            .map(|p| p.repeats as u64 * p.kernels.len() as u64)
            .sum()
    }

    /// Analytic runtime on a quiet partition (no contention, boost clock).
    pub fn runtime_quiet_s(&self, spec: &GpuSpec, env: &ExecEnv) -> f64 {
        self.phases
            .iter()
            .map(|ph| {
                let per_iter: f64 = ph.cpu_s
                    + ph.kernels
                        .iter()
                        .map(|k| k.duration_s(spec, env))
                        .sum::<f64>();
                per_iter * ph.repeats as f64
            })
            .sum()
    }

    /// Time-weighted SM occupancy over the whole quiet run — the Fig. 2
    /// metric (CPU gaps count as zero occupancy).
    pub fn avg_occupancy_quiet(&self, spec: &GpuSpec, env: &ExecEnv) -> f64 {
        let total = self.runtime_quiet_s(spec, env);
        if total <= 0.0 {
            return 0.0;
        }
        let weighted: f64 = self
            .phases
            .iter()
            .map(|ph| {
                ph.repeats as f64
                    * ph.kernels
                        .iter()
                        .map(|k| k.duration_s(spec, env) * k.occupancy(spec, env.sms))
                        .sum::<f64>()
            })
            .sum();
        weighted / total
    }

    /// Average HBM bandwidth utilization relative to `total_bw_gibs` over
    /// the quiet run — the Fig. 3 (lower) metric.
    pub fn avg_bw_util_quiet(&self, spec: &GpuSpec, env: &ExecEnv, total_bw_gibs: f64) -> f64 {
        let total = self.runtime_quiet_s(spec, env);
        if total <= 0.0 {
            return 0.0;
        }
        let bytes: f64 = self
            .phases
            .iter()
            .map(|ph| {
                ph.repeats as f64 * ph.kernels.iter().map(|k| k.hbm_bytes).sum::<f64>()
            })
            .sum();
        bytes / total / crate::util::units::gibs(total_bw_gibs)
    }

    /// Scale iteration counts (for fast tests / longer runs).
    pub fn scaled(&self, factor: f64) -> AppModel {
        assert!(factor > 0.0);
        let mut out = self.clone();
        for ph in &mut out.phases {
            ph.repeats = ((ph.repeats as f64 * factor).round() as u32).max(1);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::{Pipeline, PipelineMix};

    fn spec() -> GpuSpec {
        GpuSpec::gh_h100_96gb()
    }

    fn full_env() -> ExecEnv {
        ExecEnv {
            sms: 132,
            clock_frac: 1.0,
            bw_gibs: 3175.0,
            c2c_bw_gibs: 340.0,
            interference: 1.0,
            time_share: 1.0,
        }
    }

    fn compute_kernel() -> KernelSpec {
        KernelSpec {
            name: "k",
            mix: PipelineMix::pure(Pipeline::Fp32),
            flops: 6e12,
            hbm_bytes: 1e9,
            c2c_bytes: 0.0,
            c2c_read_only: true,
            blocks: 1 << 16,
            warps_per_block: 8,
            resident_per_sm: 8,
            bw_eff: 0.8,
        }
    }

    #[test]
    fn compute_bound_scales_with_sms_and_clock() {
        let s = spec();
        let k = compute_kernel();
        let t_full = k.duration_s(&s, &full_env());
        // ~0.1 s on 60 TFLOP/s.
        assert!((t_full - 0.1).abs() / 0.1 < 0.05, "t_full={t_full}");
        let t_half_clock = k.duration_s(
            &s,
            &ExecEnv {
                clock_frac: 0.5,
                ..full_env()
            },
        );
        assert!((t_half_clock / t_full - 2.0).abs() < 0.02);
        let t_16sm = k.duration_s(
            &s,
            &ExecEnv {
                sms: 16,
                ..full_env()
            },
        );
        assert!(t_16sm / t_full > 7.0, "strong scaling ratio");
    }

    #[test]
    fn memory_bound_ignores_clock() {
        let s = spec();
        let k = KernelSpec {
            flops: 1e9,
            hbm_bytes: 64e9,
            ..compute_kernel()
        };
        let t1 = k.duration_s(&s, &full_env());
        let t2 = k.duration_s(
            &s,
            &ExecEnv {
                clock_frac: 0.92,
                ..full_env()
            },
        );
        assert_eq!(t1, t2, "memory-bound kernels are DVFS-insensitive");
    }

    #[test]
    fn c2c_bound_kernel() {
        let s = spec();
        let k = KernelSpec {
            flops: 0.0,
            hbm_bytes: 0.0,
            c2c_bytes: 34e9,
            ..compute_kernel()
        };
        let t = k.duration_s(&s, &full_env());
        // 34 GB over ~340 GiB/s ≈ 93 ms.
        assert!((t - 0.0931).abs() < 0.01, "t={t}");
    }

    #[test]
    fn runtime_and_occupancy_aggregate() {
        let s = spec();
        let app = AppModel {
            name: "toy",
            description: "",
            input: "",
            footprint_gib: 1.0,
            cold_frac: 0.0,
            cpu_corun_inflation: 1.0,
            swap_frac: None,
            startup_s: 0.0,
            phases: vec![MacroPhase {
                cpu_s: 0.1,
                kernels: vec![compute_kernel()],
                repeats: 10,
            }],
            perf_unit: "runs/s",
        };
        let t = app.runtime_quiet_s(&s, &full_env());
        assert!((t - 10.0 * (0.1 + 0.1)).abs() < 0.02, "t={t}");
        let occ = app.avg_occupancy_quiet(&s, &full_env());
        // Kernel occupancy 1.0 (full residency) × ~50% busy.
        assert!((occ - 0.5).abs() < 0.05, "occ={occ}");
        assert_eq!(app.total_kernels(), 10);
    }

    #[test]
    fn scaled_preserves_at_least_one_iter() {
        let s = AppModel {
            name: "toy",
            description: "",
            input: "",
            footprint_gib: 1.0,
            cold_frac: 0.0,
            cpu_corun_inflation: 1.0,
            swap_frac: None,
            startup_s: 0.0,
            phases: vec![MacroPhase {
                cpu_s: 0.0,
                kernels: vec![compute_kernel()],
                repeats: 7,
            }],
            perf_unit: "runs/s",
        };
        assert_eq!(s.scaled(0.01).phases[0].repeats, 1);
        assert_eq!(s.scaled(2.0).phases[0].repeats, 14);
    }
}
