//! The calibrated application models (Table III).
//!
//! Calibration sources, per app:
//! - full-GPU SM occupancy and bandwidth/capacity utilization: Figs. 2-3;
//! - CPU-vs-GPU balance: §IV-A's root-cause notes (NekRS CPU-dominated,
//!   AutoDock tail-effect-limited, time-slicing context-switch costs);
//! - co-run gains: Fig. 5 (NekRS 2.4x, FAISS 2.5x, Qiskit/hotspot ~flat);
//! - power signatures: Fig. 7 (Qiskit memory-bound at the cap, llm.c
//!   tensor-heavy oscillating 500-650 W);
//! - §VI large variants: Qiskit 31-qubit (16 GiB), FAISS IVF16384
//!   (bursty, >12 GiB), Llama3-8B fp16 (16 GiB).
//!
//! The numbers are synthetic but dimensionally real: FLOPs, bytes and
//! launch geometries are chosen to land the paper's measured utilization
//! signatures on the modelled H100, then everything downstream is
//! emergent.

use super::model::{AppModel, KernelSpec, MacroPhase};
use crate::gpu::{Pipeline, PipelineMix};

/// Application identifiers, including the §VI large variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum AppId {
    Qiskit30,
    Qiskit31,
    Faiss,
    FaissLarge,
    NekRs,
    Lammps,
    Autodock3er5,
    Autodock2vaa,
    LlmcTinystories,
    LlmcShakespeare,
    Llama3Q8,
    Llama3Fp16,
    Hotspot,
    StreamGpu,
    StreamNvlink,
}

impl AppId {
    /// Number of application models — the dimension of dense per-app
    /// tables in the serving hot path (`cluster::placement`).
    pub const COUNT: usize = 15;

    /// Dense index into `[_; AppId::COUNT]` tables (matches `all()` order).
    #[inline]
    pub fn index(self) -> usize {
        self as usize
    }

    pub fn name(&self) -> &'static str {
        model(*self).name
    }

    pub fn by_name(name: &str) -> Option<AppId> {
        all().into_iter().find(|a| a.name() == name)
    }
}

/// The Fig. 2 suite (ten application runs).
pub fn suite() -> Vec<AppId> {
    vec![
        AppId::Qiskit30,
        AppId::Faiss,
        AppId::NekRs,
        AppId::Lammps,
        AppId::Autodock3er5,
        AppId::Autodock2vaa,
        AppId::LlmcTinystories,
        AppId::LlmcShakespeare,
        AppId::Llama3Q8,
        AppId::Hotspot,
    ]
}

/// The full measured set: suite + STREAM microbenchmarks (Figs. 3/5/6).
pub fn suite_with_stream() -> Vec<AppId> {
    let mut s = suite();
    s.push(AppId::StreamGpu);
    s.push(AppId::StreamNvlink);
    s
}

/// §VI offloading study apps (large variants + their base profiles).
pub fn offload_study() -> Vec<(AppId, AppId)> {
    vec![
        (AppId::Qiskit30, AppId::Qiskit31),
        (AppId::Faiss, AppId::FaissLarge),
        (AppId::Llama3Q8, AppId::Llama3Fp16),
    ]
}

pub fn all() -> Vec<AppId> {
    vec![
        AppId::Qiskit30,
        AppId::Qiskit31,
        AppId::Faiss,
        AppId::FaissLarge,
        AppId::NekRs,
        AppId::Lammps,
        AppId::Autodock3er5,
        AppId::Autodock2vaa,
        AppId::LlmcTinystories,
        AppId::LlmcShakespeare,
        AppId::Llama3Q8,
        AppId::Llama3Fp16,
        AppId::Hotspot,
        AppId::StreamGpu,
        AppId::StreamNvlink,
    ]
}

const GIB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Build the model for an application.
pub fn model(id: AppId) -> AppModel {
    match id {
        // ------------------------------------------------------------------
        // Qiskit Aer statevector simulation, Quantum Volume.
        // Memory-bound fp32 sweeps over the 2^n-amplitude state vector.
        // Full-GPU: occ ~0.62, bw util ~0.88, pins the 700 W cap (Fig 7a).
        AppId::Qiskit30 => qiskit(30, "qiskit", "Quantum Volume, 30 qubits", 8.5, 2400),
        AppId::Qiskit31 => qiskit(31, "qiskit-31q", "Quantum Volume, 31 qubits", 16.5, 1200),

        // ------------------------------------------------------------------
        // FAISS ANN query: CPU-heavy orchestration + short memory-bound
        // ADC scans. Low occupancy (~0.10), big co-run gain (2.5x).
        AppId::Faiss => AppModel {
            name: "faiss",
            description: "Data analytics (ANN search)",
            input: "sift1M IVF4096,PQ64",
            footprint_gib: 2.5,
            cold_frac: 0.2,
            cpu_corun_inflation: 1.8,
            swap_frac: None,
            startup_s: 8.0,
            phases: vec![MacroPhase {
                cpu_s: 0.040,
                kernels: vec![KernelSpec {
                    name: "adc_scan",
                    mix: PipelineMix::new(&[(Pipeline::Fp32, 0.7), (Pipeline::Fp16, 0.3)]),
                    flops: 1.0e10,
                    hbm_bytes: 20.0 * GIB,
                    c2c_bytes: 0.0,
                    c2c_read_only: true,
                    blocks: 60_000,
                    warps_per_block: 8,
                    resident_per_sm: 4,
                    bw_eff: 0.70,
                }],
                repeats: 700,
            }],
            perf_unit: "queries/s",
        },
        // §VI variant: larger index (IVF16384); the footprint exceeds
        // 12 GiB only during a short burst -> offload is nearly free.
        AppId::FaissLarge => AppModel {
            name: "faiss-ivf16384",
            description: "Data analytics (ANN search, large index)",
            input: "sift1M IVF16384",
            footprint_gib: 14.0,
            cold_frac: 0.90,
            cpu_corun_inflation: 1.8,
            swap_frac: None,
            startup_s: 8.0,
            phases: vec![MacroPhase {
                cpu_s: 0.042,
                kernels: vec![KernelSpec {
                    name: "adc_scan_large",
                    mix: PipelineMix::new(&[(Pipeline::Fp32, 0.7), (Pipeline::Fp16, 0.3)]),
                    flops: 1.2e10,
                    hbm_bytes: 22.0 * GIB,
                    c2c_bytes: 0.0,
                    c2c_read_only: true,
                    blocks: 70_000,
                    warps_per_block: 8,
                    resident_per_sm: 4,
                    bw_eff: 0.70,
                }],
                repeats: 700,
            }],
            perf_unit: "queries/s",
        },

        // ------------------------------------------------------------------
        // NekRS spectral-element CFD: CPU-side execution dominates and
        // keeps the GPU idle (§IV-A); kernels are bandwidth-bound fp64.
        // Full-GPU occ ~0.12; co-run 2.4x; energy < 0.5x serial.
        AppId::NekRs => AppModel {
            name: "nekrs",
            description: "CFD (spectral elements)",
            input: "turbPipePeriodic",
            footprint_gib: 6.0,
            cold_frac: 0.3,
            cpu_corun_inflation: 1.50,
            swap_frac: None,
            startup_s: 12.0,
            phases: vec![MacroPhase {
                cpu_s: 0.060,
                kernels: vec![KernelSpec {
                    name: "helmholtz_ax",
                    mix: PipelineMix::new(&[(Pipeline::Fp64, 0.6), (Pipeline::Fp32, 0.4)]),
                    flops: 9.6e10,
                    hbm_bytes: 44.6 * GIB,
                    c2c_bytes: 0.0,
                    c2c_read_only: true,
                    blocks: 80_000,
                    warps_per_block: 8,
                    resident_per_sm: 4,
                    bw_eff: 0.78,
                }],
                repeats: 600,
            }],
            perf_unit: "steps/s",
        },

        // ------------------------------------------------------------------
        // LAMMPS ReaxFF: fp64 compute-bound with moderate bandwidth;
        // occ ~0.40, halves under time-slicing (Fig 2).
        AppId::Lammps => AppModel {
            name: "lammps",
            description: "Molecular dynamics",
            input: "ReaxFF",
            footprint_gib: 3.0,
            cold_frac: 0.2,
            cpu_corun_inflation: 1.10,
            swap_frac: None,
            startup_s: 4.0,
            phases: vec![MacroPhase {
                cpu_s: 0.0005,
                kernels: vec![KernelSpec {
                    name: "reaxff_forces",
                    mix: PipelineMix::pure(Pipeline::Fp64),
                    flops: 6.0e10,
                    hbm_bytes: 2.0 * GIB,
                    c2c_bytes: 0.0,
                    c2c_read_only: true,
                    blocks: 8_000,
                    warps_per_block: 8,
                    resident_per_sm: 4,
                    bw_eff: 0.75,
                }],
                repeats: 12_000,
            }],
            perf_unit: "steps/s",
        },

        // ------------------------------------------------------------------
        // AutoDock-GPU: fp32 genetic-algorithm docking with few, fat
        // thread blocks -> severe tail effect on the full GPU (§IV-A);
        // occupancy doubles on small instances (0.20 -> ~0.38).
        AppId::Autodock3er5 => autodock("autodock-3er5", "PDBID: 3er5", 400, 4.0e10, 30_000),
        AppId::Autodock2vaa => autodock("autodock-2vaa", "PDBID: 2vaa", 420, 3.6e10, 26_000),

        // ------------------------------------------------------------------
        // llm.c GPT-2 training: HMMA-dominated steps with an fp32
        // optimizer pass; alone 500-650 W (no throttle), seven 1g copies
        // collectively exceed the cap (Fig 7b).
        AppId::LlmcTinystories => llmc("llmc-tinystories", "tinystories", 3000),
        AppId::LlmcShakespeare => llmc("llmc-shakespeare", "shakespeare", 2200),

        // ------------------------------------------------------------------
        // llama.cpp Llama3-8B inference: decode is a weight-streaming,
        // memory-bound loop (Q8: ~8 GiB weights read per token batch).
        AppId::Llama3Q8 => llama3("llama3", "Llama 3 8B Q8", 9.0, 8.0, 3000),
        AppId::Llama3Fp16 => llama3("llama3-fp16", "Llama 3 8B FP16", 16.5, 15.0, 1600),

        // ------------------------------------------------------------------
        // Rodinia hotspot: compute-bound fp32/fp64 stencil, high occupancy
        // (0.61), near-ideal scaling, tiny footprint.
        AppId::Hotspot => AppModel {
            name: "hotspot",
            description: "Differential-equation solver (stencil)",
            input: "1024x1024, 1M iterations",
            footprint_gib: 0.05,
            cold_frac: 0.0,
            cpu_corun_inflation: 1.0,
            swap_frac: None,
            startup_s: 0.5,
            phases: vec![MacroPhase {
                cpu_s: 0.0002,
                kernels: vec![KernelSpec {
                    name: "hotspot_stencil",
                    mix: PipelineMix::new(&[(Pipeline::Fp32, 0.7), (Pipeline::Fp64, 0.3)]),
                    flops: 2.0e11,
                    hbm_bytes: 1.0 * GIB,
                    c2c_bytes: 0.0,
                    c2c_read_only: true,
                    blocks: 40_960,
                    warps_per_block: 8,
                    resident_per_sm: 5,
                    bw_eff: 0.80,
                }],
                repeats: 6_000,
            }],
            perf_unit: "iters/s",
        },

        // ------------------------------------------------------------------
        // STREAM on local GPU memory: measures the instance's bandwidth
        // allocation (Table II / IVb locals).
        AppId::StreamGpu => AppModel {
            name: "stream-gpu",
            description: "Memory bandwidth (local HBM)",
            input: "512 MB array",
            footprint_gib: 1.5,
            cold_frac: 0.0,
            cpu_corun_inflation: 1.0,
            swap_frac: None,
            startup_s: 0.3,
            phases: vec![MacroPhase {
                cpu_s: 0.0001,
                kernels: vec![KernelSpec {
                    name: "stream_triad",
                    mix: PipelineMix::pure(Pipeline::Fp64),
                    flops: 1.34e8, // 2 flops per 8-byte element, triad
                    hbm_bytes: 1.5 * GIB,
                    c2c_bytes: 0.0,
                    c2c_read_only: true,
                    blocks: 65_536,
                    warps_per_block: 8,
                    resident_per_sm: 6,
                    bw_eff: 0.93,
                }],
                repeats: 20_000,
            }],
            perf_unit: "GiB/s",
        },

        // ------------------------------------------------------------------
        // STREAM over NVLink-C2C: GPU kernel reads one CPU-resident array
        // and writes another (direct access, both directions) — loads the
        // *shared* C2C link (§III-B).
        AppId::StreamNvlink => AppModel {
            name: "stream-nvlink",
            description: "Memory bandwidth (C2C direct access)",
            input: "512 MB array",
            footprint_gib: 0.2,
            cold_frac: 0.0,
            cpu_corun_inflation: 1.0,
            swap_frac: None,
            startup_s: 0.3,
            phases: vec![MacroPhase {
                cpu_s: 0.0001,
                kernels: vec![KernelSpec {
                    name: "stream_c2c",
                    mix: PipelineMix::pure(Pipeline::Fp64),
                    flops: 1.34e8,
                    hbm_bytes: 0.0,
                    c2c_bytes: 1.0 * GIB,
                    c2c_read_only: false,
                    blocks: 65_536,
                    warps_per_block: 8,
                    resident_per_sm: 6,
                    bw_eff: 0.95,
                }],
                repeats: 6_000,
            }],
            perf_unit: "GiB/s",
        },
    }
}

fn qiskit(
    qubits: u32,
    name: &'static str,
    input: &'static str,
    footprint_gib: f64,
    iters: u32,
) -> AppModel {
    // State vector: 2^n complex64. A fused gate batch sweeps the state a
    // few times; traffic scales with the state size.
    let state_gib = (1u64 << qubits) as f64 * 8.0 / GIB;
    let bytes_per_iter = state_gib * 2.5 * GIB;
    AppModel {
        name,
        description: "Quantum circuit simulation (statevector)",
        input,
        footprint_gib,
        cold_frac: 0.5, // Qiskit's native swap keeps hot pages resident
        cpu_corun_inflation: 1.05,
        // §VI-A: Qiskit's natively-supported chunked swapping outperforms
        // managed memory; it moves ~50% of the spilled state per gate
        // batch over a copy engine.
        swap_frac: Some(0.5),
        startup_s: 1.5,
        phases: vec![MacroPhase {
            cpu_s: 0.0001,
            kernels: vec![KernelSpec {
                name: "gate_batch",
                mix: PipelineMix::pure(Pipeline::Fp32),
                flops: bytes_per_iter * 0.5,
                hbm_bytes: bytes_per_iter,
                c2c_bytes: 0.0,
                c2c_read_only: true,
                blocks: 500_000,
                warps_per_block: 8,
                resident_per_sm: 5,
                bw_eff: 0.90,
            }],
            repeats: iters,
        }],
        perf_unit: "gates/s",
    }
}

fn autodock(
    name: &'static str,
    input: &'static str,
    blocks: u64,
    flops: f64,
    iters: u32,
) -> AppModel {
    AppModel {
        name,
        description: "Molecular docking (genetic algorithm)",
        input,
        footprint_gib: 0.6,
        cold_frac: 0.0,
        cpu_corun_inflation: 1.2,
        swap_frac: None,
        startup_s: 1.5,
        phases: vec![MacroPhase {
            cpu_s: 0.0002,
            kernels: vec![KernelSpec {
                name: "ga_scoring",
                mix: PipelineMix::pure(Pipeline::Fp32),
                flops,
                hbm_bytes: 0.02 * GIB,
                c2c_bytes: 0.0,
                c2c_read_only: true,
                blocks,
                warps_per_block: 9,
                resident_per_sm: 3,
                bw_eff: 0.6,
            }],
            repeats: iters,
        }],
        perf_unit: "evals/s",
    }
}

fn llmc(name: &'static str, input: &'static str, steps: u32) -> AppModel {
    AppModel {
        name,
        description: "GPT-2 training (llm.c)",
        input,
        footprint_gib: 2.2,
        cold_frac: 0.1,
        cpu_corun_inflation: 1.15,
        swap_frac: None,
        startup_s: 4.0,
        phases: vec![MacroPhase {
            cpu_s: 0.003,
            kernels: vec![
                // Fused fwd+bwd matmul-dominated step.
                KernelSpec {
                    name: "train_step",
                    mix: PipelineMix::new(&[(Pipeline::TensorFp16, 0.97), (Pipeline::Fp32, 0.03)]),
                    flops: 2.2e12,
                    hbm_bytes: 5.0 * GIB,
                    c2c_bytes: 0.0,
                    c2c_read_only: true,
                    blocks: 180,
                    warps_per_block: 16,
                    resident_per_sm: 1,
                    bw_eff: 0.55,
                },
                // AdamW update: fp32, bandwidth-heavy.
                KernelSpec {
                    name: "adamw",
                    mix: PipelineMix::pure(Pipeline::Fp32),
                    flops: 2.0e9,
                    hbm_bytes: 4.0 * GIB,
                    c2c_bytes: 0.0,
                    c2c_read_only: true,
                    blocks: 20_000,
                    warps_per_block: 8,
                    resident_per_sm: 6,
                    bw_eff: 0.60,
                },
            ],
            repeats: steps,
        }],
        perf_unit: "steps/s",
    }
}

fn llama3(
    name: &'static str,
    input: &'static str,
    footprint_gib: f64,
    weights_gib: f64,
    tokens: u32,
) -> AppModel {
    AppModel {
        name,
        description: "LLM inference (llama.cpp)",
        input,
        footprint_gib,
        cold_frac: 0.0, // weights are read every token: nothing is cold
        cpu_corun_inflation: 1.1,
        swap_frac: None,
        startup_s: 8.0,
        phases: vec![MacroPhase {
            cpu_s: 0.0005,
            kernels: vec![KernelSpec {
                name: "decode_token",
                mix: PipelineMix::new(&[
                    (Pipeline::TensorInt8, 0.5),
                    (Pipeline::TensorFp16, 0.3),
                    (Pipeline::Fp16, 0.1),
                    (Pipeline::Fp32, 0.1),
                ]),
                flops: 1.6e10,
                hbm_bytes: weights_gib * GIB,
                c2c_bytes: 0.0,
                c2c_read_only: true,
                blocks: 30_000,
                warps_per_block: 8,
                resident_per_sm: 3,
                bw_eff: 0.80,
            }],
            repeats: tokens,
        }],
        perf_unit: "tok/s",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuSpec;
    use crate::workload::model::ExecEnv;

    #[test]
    fn dense_index_covers_every_app_once() {
        let apps = all();
        assert_eq!(apps.len(), AppId::COUNT);
        let mut seen = [false; AppId::COUNT];
        for app in apps {
            assert!(!seen[app.index()], "duplicate index for {:?}", app);
            seen[app.index()] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    fn spec() -> GpuSpec {
        GpuSpec::gh_h100_96gb()
    }

    fn full() -> ExecEnv {
        ExecEnv {
            sms: 132,
            clock_frac: 1.0,
            bw_gibs: 3175.0,
            c2c_bw_gibs: 331.0,
            interference: 1.0,
            time_share: 1.0,
        }
    }

    fn env_1g() -> ExecEnv {
        ExecEnv {
            sms: 16,
            clock_frac: 1.0,
            bw_gibs: 406.0,
            c2c_bw_gibs: 282.0,
            interference: 1.0,
            time_share: 1.0,
        }
    }

    #[test]
    fn all_models_build_and_fit_constraints() {
        for id in all() {
            let m = model(id);
            assert!(!m.phases.is_empty(), "{}", m.name);
            assert!(m.footprint_gib > 0.0);
            assert!(m.total_kernels() > 0);
            // Base suite problems fit the 11 GiB of 1g.12gb (§III-B).
            let large = matches!(
                id,
                AppId::Qiskit31 | AppId::FaissLarge | AppId::Llama3Fp16
            );
            if !large {
                assert!(
                    m.footprint_gib <= 11.0,
                    "{} footprint {} must fit 1g.12gb",
                    m.name,
                    m.footprint_gib
                );
            } else {
                assert!(m.footprint_gib > 11.0, "{} must exceed 1g.12gb", m.name);
            }
        }
    }

    #[test]
    fn full_gpu_occupancy_matches_fig2() {
        // (app, paper occupancy, abs tolerance)
        let targets = [
            (AppId::Qiskit30, 0.62, 0.08),
            (AppId::Hotspot, 0.61, 0.08),
            (AppId::Lammps, 0.40, 0.08),
            (AppId::NekRs, 0.125, 0.04),
            (AppId::Faiss, 0.10, 0.04),
            (AppId::Autodock3er5, 0.20, 0.05),
            (AppId::Autodock2vaa, 0.20, 0.05),
        ];
        for (id, want, tol) in targets {
            let m = model(id);
            let occ = m.avg_occupancy_quiet(&spec(), &full());
            assert!(
                (occ - want).abs() < tol,
                "{}: occ {occ:.3} vs paper {want} (±{tol})",
                m.name
            );
        }
    }

    #[test]
    fn occupancy_rises_on_small_instances_for_underutilizers() {
        // §IV-A: NekRS doubles 0.12 -> ~0.25; AutoDock 0.20 -> 0.38-0.39.
        for (id, min_ratio) in [
            (AppId::NekRs, 1.8),
            (AppId::Autodock3er5, 1.7),
            (AppId::Autodock2vaa, 1.7),
            (AppId::Faiss, 1.8),
        ] {
            let m = model(id);
            let occ_full = m.avg_occupancy_quiet(&spec(), &full());
            let occ_1g = m.avg_occupancy_quiet(&spec(), &env_1g());
            assert!(
                occ_1g / occ_full > min_ratio,
                "{}: {occ_full:.3} -> {occ_1g:.3}",
                m.name
            );
        }
    }

    #[test]
    fn high_occupancy_apps_stay_flat_or_drop_on_1g() {
        for id in [AppId::Qiskit30, AppId::Hotspot] {
            let m = model(id);
            let occ_full = m.avg_occupancy_quiet(&spec(), &full());
            let occ_1g = m.avg_occupancy_quiet(&spec(), &env_1g());
            assert!(
                occ_1g < occ_full * 1.15,
                "{}: {occ_full:.3} -> {occ_1g:.3} should not rise much",
                m.name
            );
        }
    }

    #[test]
    fn qiskit_bw_util_matches_fig3() {
        // "nearly 90% memory bandwidth usage" (§IV-C).
        let m = model(AppId::Qiskit30);
        let util = m.avg_bw_util_quiet(&spec(), &full(), 3175.0);
        assert!((util - 0.88).abs() < 0.06, "util={util:.3}");
    }

    #[test]
    fn runtimes_are_tens_of_seconds() {
        for id in suite_with_stream() {
            let m = model(id);
            let t = m.runtime_quiet_s(&spec(), &full());
            assert!(
                (5.0..240.0).contains(&t),
                "{}: full-GPU runtime {t:.1}s out of range",
                m.name
            );
        }
    }

    #[test]
    fn scaling_classes_match_fig4() {
        // Relative speedup from 1g to 7g: Qiskit/hotspot near-ideal (>6x),
        // NekRS/FAISS poor (<2.2x).
        for (id, lo, hi) in [
            (AppId::Qiskit30, 6.0, 9.0),
            (AppId::Hotspot, 6.0, 9.5),
            (AppId::NekRs, 1.2, 2.6),
            (AppId::Faiss, 1.2, 2.6),
        ] {
            let m = model(id);
            let t1 = m.runtime_quiet_s(&spec(), &env_1g());
            let t7 = m.runtime_quiet_s(&spec(), &full());
            let s = t1 / t7;
            assert!(
                (lo..hi).contains(&s),
                "{}: 1g->7g speedup {s:.2} outside [{lo},{hi}]",
                m.name
            );
        }
    }

    #[test]
    fn stream_nvlink_is_c2c_bound() {
        let m = model(AppId::StreamNvlink);
        let t_full = m.runtime_quiet_s(&spec(), &full());
        let t_1g = m.runtime_quiet_s(&spec(), &env_1g());
        // C2C direct access saturates even on 1g: near-identical runtimes.
        assert!(t_1g / t_full < 1.35, "ratio {}", t_1g / t_full);
    }

    #[test]
    fn name_lookup_roundtrip() {
        for id in all() {
            assert_eq!(AppId::by_name(id.name()), Some(id));
        }
        assert_eq!(AppId::by_name("nope"), None);
    }
}
