//! Workload models for the paper's application suite (Table III).
//!
//! Each application is modelled as repeated macro-iterations of a CPU
//! phase followed by one or more GPU kernels, parameterized by FLOPs,
//! HBM traffic, C2C traffic, launch geometry and pipeline mix. Parameters
//! are calibrated so the *full-GPU* behaviour matches the paper's Figs.
//! 2–3 (occupancy, bandwidth/capacity utilization) — everything else
//! (scaling, co-run throughput, energy, throttling) is then emergent from
//! the hardware model.
//!
//! `apps` holds the twelve calibrated models (10 suite members + the §VI
//! large variants), `model` the data types and the kernel-duration model,
//! `probe` the §III-C SM probe and §IV-B context probe.

pub mod apps;
pub mod model;
pub mod probe;
pub mod trace;

pub use apps::{suite, AppId};
pub use model::{AppModel, ExecEnv, KernelSpec, MacroPhase};
