//! Job-arrival traces for the cluster-level scheduler experiments.
//!
//! The paper's motivation is multi-tenant sharing: jobs of the Table III
//! mix arriving over time onto one statically-partitioned GPU. No public
//! trace exists for this setting, so traces are synthesized (Poisson
//! arrivals over a configurable app mix) with the deterministic in-repo
//! PRNG, and can be persisted/loaded as JSON for reproducible runs.

use crate::util::json::Json;
use crate::util::Rng;
use crate::workload::{apps, AppId};
use anyhow::{anyhow, ensure};

/// One job in a trace.
#[derive(Debug, Clone)]
pub struct Job {
    pub id: u32,
    pub app: AppId,
    pub arrival_s: f64,
}

/// A job-arrival trace.
#[derive(Debug, Clone, Default)]
pub struct JobTrace {
    pub jobs: Vec<Job>,
}

impl JobTrace {
    /// Synthesize `n` jobs with exponential inter-arrivals (mean
    /// `mean_interarrival_s`) drawn from `mix` (app, weight) pairs.
    pub fn poisson(
        n: u32,
        mean_interarrival_s: f64,
        mix: &[(AppId, f64)],
        seed: u64,
    ) -> JobTrace {
        assert!(!mix.is_empty() && mean_interarrival_s > 0.0);
        let total_w: f64 = mix.iter().map(|(_, w)| w).sum();
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        let mut jobs = Vec::with_capacity(n as usize);
        for id in 0..n {
            // Exponential inter-arrival.
            t += -mean_interarrival_s * (1.0 - rng.f64()).ln();
            let mut pick = rng.f64() * total_w;
            let mut app = mix[0].0;
            for (a, w) in mix {
                if pick < *w {
                    app = *a;
                    break;
                }
                pick -= w;
            }
            jobs.push(Job {
                id,
                app,
                arrival_s: t,
            });
        }
        JobTrace { jobs }
    }

    /// The paper's suite as a uniform mix.
    pub fn suite_mix() -> Vec<(AppId, f64)> {
        apps::suite().into_iter().map(|a| (a, 1.0)).collect()
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// A copy of this trace normalized for replay: arrivals validated
    /// (finite, non-negative), jobs stably sorted by arrival time, and
    /// ids re-labelled densely 0..n in that order — the shape the serving
    /// queues require. A trace synthesized by `poisson` is already
    /// canonical, so on it this is an exact copy (replay round-trips
    /// bit-for-bit).
    pub fn canonicalized(&self) -> crate::Result<JobTrace> {
        let mut jobs = self.jobs.clone();
        for j in &jobs {
            ensure!(
                j.arrival_s.is_finite() && j.arrival_s >= 0.0,
                "job {} has invalid arrival {}",
                j.id,
                j.arrival_s
            );
        }
        jobs.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        for (i, j) in jobs.iter_mut().enumerate() {
            j.id = i as u32;
        }
        Ok(JobTrace { jobs })
    }

    pub fn to_json(&self) -> Json {
        let mut doc = Json::obj();
        doc.set(
            "jobs",
            Json::Arr(
                self.jobs
                    .iter()
                    .map(|j| {
                        let mut o = Json::obj();
                        o.set("id", j.id)
                            .set("app", j.app.name())
                            .set("arrival_s", j.arrival_s);
                        o
                    })
                    .collect(),
            ),
        );
        doc
    }

    pub fn from_json(doc: &Json) -> crate::Result<JobTrace> {
        let arr = doc
            .get("jobs")
            .and_then(|j| j.as_arr())
            .ok_or_else(|| anyhow!("trace missing 'jobs'"))?;
        let mut jobs = Vec::with_capacity(arr.len());
        for j in arr {
            let name = j
                .get("app")
                .and_then(|a| a.as_str())
                .ok_or_else(|| anyhow!("job missing app"))?;
            jobs.push(Job {
                id: j.get("id").and_then(|v| v.as_u64()).unwrap_or(0) as u32,
                app: AppId::by_name(name).ok_or_else(|| anyhow!("unknown app '{name}'"))?,
                arrival_s: j
                    .get("arrival_s")
                    .and_then(|v| v.as_f64())
                    .ok_or_else(|| anyhow!("job missing arrival"))?,
            });
        }
        Ok(JobTrace { jobs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_trace_properties() {
        let trace = JobTrace::poisson(500, 10.0, &JobTrace::suite_mix(), 42);
        assert_eq!(trace.len(), 500);
        // Arrivals strictly increasing.
        for w in trace.jobs.windows(2) {
            assert!(w[1].arrival_s > w[0].arrival_s);
        }
        // Mean inter-arrival within 15% of requested.
        let span = trace.jobs.last().unwrap().arrival_s;
        let mean = span / 500.0;
        assert!((mean - 10.0).abs() / 10.0 < 0.15, "mean={mean}");
        // All suite apps appear.
        for app in apps::suite() {
            assert!(
                trace.jobs.iter().any(|j| j.app == app),
                "{} missing from mix",
                app.name()
            );
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = JobTrace::poisson(50, 5.0, &JobTrace::suite_mix(), 7);
        let b = JobTrace::poisson(50, 5.0, &JobTrace::suite_mix(), 7);
        assert_eq!(a.jobs.len(), b.jobs.len());
        for (x, y) in a.jobs.iter().zip(&b.jobs) {
            assert_eq!(x.app, y.app);
            assert_eq!(x.arrival_s, y.arrival_s);
        }
        let c = JobTrace::poisson(50, 5.0, &JobTrace::suite_mix(), 8);
        assert!(a.jobs.iter().zip(&c.jobs).any(|(x, y)| x.arrival_s != y.arrival_s));
    }

    #[test]
    fn canonicalized_sorts_relabels_and_validates() {
        // A poisson trace is already canonical: exact copy.
        let p = JobTrace::poisson(30, 2.0, &JobTrace::suite_mix(), 5);
        let c = p.canonicalized().unwrap();
        for (a, b) in p.jobs.iter().zip(&c.jobs) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.app, b.app);
            assert_eq!(a.arrival_s, b.arrival_s);
        }
        // Out-of-order external traces are sorted and re-id'd densely.
        let messy = JobTrace {
            jobs: vec![
                Job { id: 7, app: AppId::Faiss, arrival_s: 5.0 },
                Job { id: 2, app: AppId::Hotspot, arrival_s: 1.0 },
                Job { id: 4, app: AppId::Lammps, arrival_s: 3.0 },
            ],
        };
        let c = messy.canonicalized().unwrap();
        assert_eq!(c.jobs.iter().map(|j| j.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(c.jobs[0].app, AppId::Hotspot);
        assert_eq!(c.jobs[2].arrival_s, 5.0);
        // Invalid arrivals are rejected.
        let bad = JobTrace {
            jobs: vec![Job { id: 0, app: AppId::Faiss, arrival_s: -1.0 }],
        };
        assert!(bad.canonicalized().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let a = JobTrace::poisson(20, 3.0, &JobTrace::suite_mix(), 9);
        let doc = a.to_json();
        let b = JobTrace::from_json(&doc).unwrap();
        assert_eq!(a.len(), b.len());
        assert_eq!(a.jobs[7].app, b.jobs[7].app);
        assert_eq!(a.jobs[7].arrival_s, b.jobs[7].arrival_s);
    }

    #[test]
    fn empty_and_single_job_traces_canonicalize_and_round_trip() {
        let empty = JobTrace { jobs: vec![] };
        let c = empty.canonicalized().unwrap();
        assert!(c.is_empty());
        assert_eq!(
            JobTrace::from_json(&empty.to_json()).unwrap().len(),
            0,
            "empty trace survives serialization"
        );
        let single = JobTrace {
            jobs: vec![Job {
                id: 17, // sparse id: canonicalization must densify
                app: AppId::Hotspot,
                arrival_s: 0.0, // arrival exactly at t = 0 is valid
            }],
        };
        let c = single.canonicalized().unwrap();
        assert_eq!(c.jobs[0].id, 0);
        assert_eq!(c.jobs[0].arrival_s, 0.0);
        let back = JobTrace::from_json(&c.to_json()).unwrap();
        assert_eq!(back.to_json().pretty(), c.to_json().pretty());
    }

    #[test]
    fn duplicate_timestamps_keep_stable_order() {
        // Equal arrivals are a legal trace (simultaneous submissions);
        // canonicalization must keep their relative order (stable sort),
        // so replay admission order is well-defined and reproducible.
        let t = JobTrace {
            jobs: vec![
                Job { id: 3, app: AppId::Faiss, arrival_s: 1.0 },
                Job { id: 9, app: AppId::Hotspot, arrival_s: 1.0 },
                Job { id: 1, app: AppId::Lammps, arrival_s: 1.0 },
                Job { id: 0, app: AppId::NekRs, arrival_s: 0.5 },
            ],
        };
        let c = t.canonicalized().unwrap();
        assert_eq!(c.jobs.iter().map(|j| j.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        assert_eq!(c.jobs[0].app, AppId::NekRs);
        assert_eq!(c.jobs[1].app, AppId::Faiss, "ties keep input order");
        assert_eq!(c.jobs[2].app, AppId::Hotspot);
        assert_eq!(c.jobs[3].app, AppId::Lammps);
        // Canonicalization is idempotent on its own output.
        let cc = c.canonicalized().unwrap();
        assert_eq!(cc.to_json().pretty(), c.to_json().pretty());
    }

    #[test]
    fn non_finite_arrivals_rejected() {
        for bad in [f64::NAN, f64::INFINITY, -0.001] {
            let t = JobTrace {
                jobs: vec![Job { id: 0, app: AppId::Faiss, arrival_s: bad }],
            };
            assert!(t.canonicalized().is_err(), "arrival {bad} must be rejected");
        }
    }
}
