//! The paper's reward model (§VI-B):
//!
//! ```text
//! W_SM  = (N_SM / N_SM,GPU) · (1 − Occ)
//! W_MEM = (M_instance − M_app) / M_GPU
//! R     = (P / P_GPU) / (α + W_MEM + W_SM)
//! ```
//!
//! α = 0 prioritizes reducing resource underutilization; α → 1 shifts to a
//! performance-first policy. Both waste terms are in [0, 1], so α is
//! swept over the same range (the paper uses {0, 0.1, 0.5, 1}).
//!
//! ## The energy-per-job extension
//!
//! `reward_energy` adds an optional power-aware waste term to the
//! denominator:
//!
//! ```text
//! R = (P / P_GPU) / (α + W_MEM + W_SM + w_E · E_rel)
//! ```
//!
//! where `E_rel` is the job's modeled energy normalized by its full-GPU
//! run (≈1 for an energy-neutral placement) and `w_E` is the operator's
//! `--energy-weight`. At `w_E = 0` the term is skipped entirely — not
//! merely zero-valued — so the paper's reward is reproduced bit-for-bit.

use crate::util::table::{fnum, Table};

/// Measured quantities for one (app, configuration) pair.
#[derive(Debug, Clone)]
pub struct ConfigEval {
    /// Configuration label, e.g. "MIG 1g.12gb + offloading".
    pub config: String,
    /// Application performance on this configuration (any unit, higher is
    /// better — inverse runtime or tokens/s).
    pub perf: f64,
    /// Average GPU-level occupancy achieved on the instance.
    pub occupancy: f64,
    /// SMs of the instance.
    pub sms: u32,
    /// Instance memory capacity (GiB).
    pub mem_instance_gib: f64,
    /// Peak memory used by the app on this instance (GiB) — after
    /// offloading this is the *resident* footprint.
    pub mem_app_gib: f64,
}

/// GPU-level constants for normalization.
#[derive(Debug, Clone, Copy)]
pub struct GpuTotals {
    pub sms: u32,
    pub mem_gib: f64,
    /// Performance of the app on the full GPU (P_GPU).
    pub perf_full_gpu: f64,
}

/// The reward-model outputs for one configuration.
#[derive(Debug, Clone)]
pub struct Reward {
    pub config: String,
    pub rel_perf: f64,
    pub w_sm: f64,
    pub w_mem: f64,
    /// The weighted energy term added to the denominator (0.0 at
    /// `energy_weight = 0`).
    pub w_energy: f64,
    pub reward: f64,
}

/// Compute W_SM, W_MEM and R for one configuration.
pub fn reward(eval: &ConfigEval, totals: &GpuTotals, alpha: f64) -> Reward {
    reward_energy(eval, totals, alpha, 0.0, 0.0)
}

/// `reward` with the energy-per-job term: the denominator additionally
/// carries `energy_weight × energy_rel` (job energy normalized by its
/// full-GPU run). A zero weight skips the addition — `reward` is the
/// literal special case, bit-for-bit.
pub fn reward_energy(
    eval: &ConfigEval,
    totals: &GpuTotals,
    alpha: f64,
    energy_weight: f64,
    energy_rel: f64,
) -> Reward {
    assert!(alpha >= 0.0, "alpha must be non-negative");
    assert!(energy_weight >= 0.0, "energy weight must be non-negative");
    assert!(totals.perf_full_gpu > 0.0, "P_GPU must be positive");
    let w_sm = (eval.sms as f64 / totals.sms as f64) * (1.0 - eval.occupancy.clamp(0.0, 1.0));
    let w_mem = ((eval.mem_instance_gib - eval.mem_app_gib) / totals.mem_gib).max(0.0);
    let rel_perf = eval.perf / totals.perf_full_gpu;
    let mut denom = alpha + w_sm + w_mem;
    let mut w_energy = 0.0;
    if energy_weight != 0.0 {
        w_energy = energy_weight * energy_rel.max(0.0);
        denom += w_energy;
    }
    // α = 0 with zero waste would divide by zero; the paper's terms never
    // both vanish for real workloads, but guard for robustness.
    let reward = rel_perf / denom.max(1e-6);
    Reward {
        config: eval.config.clone(),
        rel_perf,
        w_sm,
        w_mem,
        w_energy,
        reward,
    }
}

/// Evaluate all configurations at one α and return them with the argmax
/// flagged first in the returned index.
pub fn select_best(evals: &[ConfigEval], totals: &GpuTotals, alpha: f64) -> (usize, Vec<Reward>) {
    assert!(!evals.is_empty());
    let rewards: Vec<Reward> = evals.iter().map(|e| reward(e, totals, alpha)).collect();
    let best = rewards
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.reward.partial_cmp(&b.1.reward).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    (best, rewards)
}

/// Render a reward sweep as a table (one row per config, one column per α).
pub fn sweep_table(
    app: &str,
    evals: &[ConfigEval],
    totals: &GpuTotals,
    alphas: &[f64],
) -> Table {
    let mut header: Vec<String> = vec![
        "config".to_string(),
        "P/P_GPU".into(),
        "W_SM".into(),
        "W_MEM".into(),
    ];
    for a in alphas {
        header.push(format!("R(α={a})"));
    }
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&format!("Reward sweep — {app}")).header(&header_refs);
    let best_per_alpha: Vec<usize> = alphas
        .iter()
        .map(|&a| select_best(evals, totals, a).0)
        .collect();
    for (i, e) in evals.iter().enumerate() {
        let r0 = reward(e, totals, alphas[0]);
        let mut row = vec![
            e.config.clone(),
            fnum(r0.rel_perf, 3),
            fnum(r0.w_sm, 3),
            fnum(r0.w_mem, 3),
        ];
        for (ai, &a) in alphas.iter().enumerate() {
            let r = reward(e, totals, a);
            let marker = if best_per_alpha[ai] == i { " *" } else { "" };
            row.push(format!("{}{}", fnum(r.reward, 3), marker));
        }
        t.row(row);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn totals() -> GpuTotals {
        GpuTotals {
            sms: 132,
            mem_gib: 94.5,
            perf_full_gpu: 1.0,
        }
    }

    fn eval(config: &str, perf: f64, occ: f64, sms: u32, m_inst: f64, m_app: f64) -> ConfigEval {
        ConfigEval {
            config: config.into(),
            perf,
            occupancy: occ,
            sms,
            mem_instance_gib: m_inst,
            mem_app_gib: m_app,
        }
    }

    #[test]
    fn formula_matches_paper_definitions() {
        let e = eval("1g", 0.2, 0.5, 16, 11.0, 8.0);
        let r = reward(&e, &totals(), 0.1);
        let w_sm = (16.0 / 132.0) * 0.5;
        let w_mem = 3.0 / 94.5;
        assert!((r.w_sm - w_sm).abs() < 1e-12);
        assert!((r.w_mem - w_mem).abs() < 1e-12);
        assert!((r.reward - 0.2 / (0.1 + w_sm + w_mem)).abs() < 1e-9);
    }

    #[test]
    fn alpha_zero_prefers_low_waste() {
        // Same performance; the config with less waste wins at α=0.
        let evals = vec![
            eval("wasteful", 0.5, 0.3, 132, 94.5, 8.0),
            eval("tight", 0.5, 0.9, 16, 11.0, 10.9),
        ];
        let (best, _) = select_best(&evals, &totals(), 0.0);
        assert_eq!(evals[best].config, "tight");
    }

    #[test]
    fn alpha_one_prefers_performance() {
        // 3x faster but wasteful vs slow-and-tight: α=1 flips the choice.
        let evals = vec![
            eval("fast-wasteful", 1.0, 0.4, 132, 94.5, 8.0),
            eval("slow-tight", 0.15, 0.95, 16, 11.0, 10.9),
        ];
        let (best0, _) = select_best(&evals, &totals(), 0.0);
        let (best1, _) = select_best(&evals, &totals(), 1.0);
        assert_eq!(evals[best0].config, "slow-tight");
        assert_eq!(evals[best1].config, "fast-wasteful");
    }

    #[test]
    fn zero_energy_weight_is_the_paper_reward_bit_for_bit() {
        let e = eval("1g", 0.2, 0.5, 16, 11.0, 8.0);
        for alpha in [0.0, 0.1, 0.5, 1.0] {
            let base = reward(&e, &totals(), alpha);
            let ext = reward_energy(&e, &totals(), alpha, 0.0, 7.5);
            assert_eq!(base.reward.to_bits(), ext.reward.to_bits());
            assert_eq!(ext.w_energy, 0.0);
        }
    }

    #[test]
    fn energy_term_penalizes_energy_hungry_configs() {
        let e = eval("1g", 0.2, 0.5, 16, 11.0, 8.0);
        let cheap = reward_energy(&e, &totals(), 0.1, 0.5, 0.4);
        let hungry = reward_energy(&e, &totals(), 0.1, 0.5, 2.0);
        assert!(cheap.reward > hungry.reward);
        assert!(hungry.w_energy > cheap.w_energy);
        // Negative normalized energy cannot inflate the reward.
        let weird = reward_energy(&e, &totals(), 0.1, 0.5, -3.0);
        let zero = reward_energy(&e, &totals(), 0.1, 0.5, 0.0);
        assert_eq!(weird.reward.to_bits(), zero.reward.to_bits());
    }

    #[test]
    fn w_mem_clamped_nonnegative() {
        // Offloaded apps can "use" exactly the instance capacity.
        let e = eval("offload", 0.3, 0.8, 16, 11.0, 11.0);
        let r = reward(&e, &totals(), 0.0);
        assert_eq!(r.w_mem, 0.0);
    }

    #[test]
    fn sweep_table_marks_winners() {
        let evals = vec![
            eval("a", 1.0, 0.4, 132, 94.5, 8.0),
            eval("b", 0.15, 0.95, 16, 11.0, 10.9),
        ];
        let t = sweep_table("demo", &evals, &totals(), &[0.0, 1.0]);
        let s = t.render();
        assert!(s.contains('*'), "winner marker missing:\n{s}");
    }
}
