//! NVLink-C2C memory offloading (§VI-A).
//!
//! When a workload's footprint slightly exceeds a MIG instance's memory,
//! the paper offloads part of the data to CPU memory and accesses it over
//! the cache-coherent C2C link instead of provisioning the next (2x)
//! profile.
//!
//! Two pieces:
//! - `OffloadPlan`: the cost model — how much data spills, what fraction
//!   of the kernel's memory traffic moves to C2C (cold-first placement,
//!   mirroring cudaMallocManaged/Qiskit-swap behaviour), applied as a
//!   rewrite of the `AppModel` kernels.
//! - `SpillAllocator`: a page-granular allocator with device-capacity
//!   enforcement and cold-first spilling, used by the runtime examples.

use crate::workload::{AppModel, KernelSpec};
use anyhow::bail;
use std::collections::BTreeMap;

/// Fraction of an app's HBM traffic attributable to its *cold* data.
/// Hot data dominates traffic; spilling cold pages first is what makes
/// offloading cheap for bursty apps like FAISS.
const COLD_TRAFFIC_SHARE: f64 = 0.10;

/// Copy-engine bandwidth used by swap-mode offloading (GiB/s): a single
/// CE moving chunks bidirectionally (Table IVa, 1g row).
const SWAP_CE_BW_GIBS: f64 = 41.7;

/// The offload decision for one app on one instance size.
#[derive(Debug, Clone)]
pub struct OffloadPlan {
    /// Data left in GPU memory (GiB).
    pub resident_gib: f64,
    /// Data spilled to CPU memory (GiB).
    pub spilled_gib: f64,
    /// Fraction of memory traffic redirected over C2C (direct mode).
    pub c2c_traffic_frac: f64,
    /// Swap mode only: GPU-idle time per iteration spent moving chunks
    /// over a copy engine (Qiskit's native strategy, §VI-A).
    pub swap_gap_s: f64,
}

impl OffloadPlan {
    /// Plan offloading of `app` onto an instance with `capacity_gib`
    /// usable memory (after context overhead). Fails if even full
    /// offloading of spillable data cannot make the resident set fit
    /// (the model only spills data, not activations/workspace: at least
    /// 25% of the footprint must stay resident).
    pub fn plan(app: &AppModel, capacity_gib: f64) -> crate::Result<OffloadPlan> {
        let f = app.footprint_gib;
        if f <= capacity_gib {
            return Ok(OffloadPlan {
                resident_gib: f,
                spilled_gib: 0.0,
                c2c_traffic_frac: 0.0,
                swap_gap_s: 0.0,
            });
        }
        let overflow = f - capacity_gib;
        // Boundary semantics: a capacity of *exactly* 25% of the footprint
        // is admissible (strict `<` rejects only capacities below the
        // minimum resident set).
        let min_resident = f * 0.25;
        if capacity_gib < min_resident {
            bail!(
                "{}: footprint {:.1} GiB cannot be offloaded into {:.1} GiB (needs ≥{:.1} resident)",
                app.name,
                f,
                capacity_gib,
                min_resident
            );
        }
        // Swap mode (Qiskit): chunked CE transfers between kernels; the
        // GPU idles during the swap instead of stalling on remote loads.
        if let Some(swap_frac) = app.swap_frac {
            return Ok(OffloadPlan {
                resident_gib: capacity_gib,
                spilled_gib: overflow,
                c2c_traffic_frac: 0.0,
                swap_gap_s: overflow * swap_frac / SWAP_CE_BW_GIBS,
            });
        }
        // Direct mode: cold-first placement — spill cold pages, then hot.
        let cold_gib = f * app.cold_frac;
        let hot_gib = f - cold_gib;
        let spill_cold = overflow.min(cold_gib);
        let spill_hot = (overflow - spill_cold).max(0.0);
        let mut frac = 0.0;
        if cold_gib > 0.0 {
            frac += COLD_TRAFFIC_SHARE * (spill_cold / cold_gib);
        }
        if hot_gib > 0.0 {
            let hot_share = if app.cold_frac > 0.0 {
                1.0 - COLD_TRAFFIC_SHARE
            } else {
                1.0
            };
            frac += hot_share * (spill_hot / hot_gib);
        }
        Ok(OffloadPlan {
            resident_gib: capacity_gib,
            spilled_gib: overflow,
            c2c_traffic_frac: frac.clamp(0.0, 1.0),
            swap_gap_s: 0.0,
        })
    }

    /// Rewrite the app's kernels: move `c2c_traffic_frac` of HBM traffic
    /// onto the C2C link. Kernel geometry is unchanged — the same SMs now
    /// stall on remote cachelines instead (direct-access path, §III-D).
    pub fn apply(&self, app: &AppModel) -> AppModel {
        if self.spilled_gib == 0.0 {
            return app.clone();
        }
        let mut out = app.clone();
        for ph in &mut out.phases {
            ph.cpu_s += self.swap_gap_s;
            for k in &mut ph.kernels {
                let moved = k.hbm_bytes * self.c2c_traffic_frac;
                k.hbm_bytes -= moved;
                k.c2c_bytes += moved;
            }
        }
        out
    }

    /// Effective footprint on the instance after offloading.
    pub fn effective_footprint_gib(&self) -> f64 {
        self.resident_gib
    }

    /// Bytes this plan parks in the node's Grace host pool while the job
    /// runs — the integer the host-memory resource plane
    /// (`cluster::hostmem`) charges and releases, via the one shared
    /// `util::units::gib_to_bytes` conversion so plan-level and
    /// plane-level accounting can never drift.
    pub fn host_bytes(&self) -> u64 {
        crate::util::units::gib_to_bytes(self.spilled_gib)
    }
}

/// Rewrites a kernel directly (used by property tests).
pub fn offload_kernel(k: &KernelSpec, frac: f64) -> KernelSpec {
    let mut out = k.clone();
    let moved = out.hbm_bytes * frac.clamp(0.0, 1.0);
    out.hbm_bytes -= moved;
    out.c2c_bytes += moved;
    out
}

// ---------------------------------------------------------------------------
// Spill allocator
// ---------------------------------------------------------------------------

/// Where an allocation currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    Device,
    Host,
}

#[derive(Debug, Clone)]
struct Alloc {
    bytes: u64,
    placement: Placement,
    /// Logical access clock for cold-first eviction.
    last_touch: u64,
    /// Pinned allocations never spill (workspace/activations).
    pinned: bool,
}

/// Handle to an allocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct AllocId(u64);

/// A device allocator that transparently spills the coldest unpinned
/// allocations to host memory when capacity is exceeded — the
/// `cudaMallocManaged`-style mechanism of §VI-A.
#[derive(Debug)]
pub struct SpillAllocator {
    capacity: u64,
    device_used: u64,
    host_used: u64,
    clock: u64,
    next_id: u64,
    allocs: BTreeMap<AllocId, Alloc>,
    /// Counters for tests/diagnostics.
    pub spill_events: u64,
    pub spilled_bytes_total: u64,
}

impl SpillAllocator {
    pub fn new(capacity_bytes: u64) -> SpillAllocator {
        SpillAllocator {
            capacity: capacity_bytes,
            device_used: 0,
            host_used: 0,
            clock: 0,
            next_id: 0,
            allocs: BTreeMap::new(),
            spill_events: 0,
            spilled_bytes_total: 0,
        }
    }

    pub fn device_used(&self) -> u64 {
        self.device_used
    }

    pub fn host_used(&self) -> u64 {
        self.host_used
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    /// Allocate on device, spilling cold data if needed. `pinned`
    /// allocations must fit on device or the call fails.
    pub fn alloc(&mut self, bytes: u64, pinned: bool) -> crate::Result<AllocId> {
        if bytes > self.capacity {
            bail!("allocation of {bytes} B exceeds device capacity {}", self.capacity);
        }
        self.make_room(bytes, pinned)?;
        let id = AllocId(self.next_id);
        self.next_id += 1;
        self.clock += 1;
        self.allocs.insert(
            id,
            Alloc {
                bytes,
                placement: Placement::Device,
                last_touch: self.clock,
                pinned,
            },
        );
        self.device_used += bytes;
        Ok(id)
    }

    fn make_room(&mut self, bytes: u64, for_pinned: bool) -> crate::Result<()> {
        while self.device_used + bytes > self.capacity {
            // Evict the coldest unpinned device-resident allocation.
            let victim = self
                .allocs
                .iter()
                .filter(|(_, a)| a.placement == Placement::Device && !a.pinned)
                .min_by_key(|(_, a)| a.last_touch)
                .map(|(id, _)| *id);
            match victim {
                Some(id) => {
                    let a = self.allocs.get_mut(&id).unwrap();
                    a.placement = Placement::Host;
                    self.device_used -= a.bytes;
                    self.host_used += a.bytes;
                    self.spill_events += 1;
                    self.spilled_bytes_total += a.bytes;
                }
                None => {
                    if for_pinned {
                        bail!("cannot make room for pinned allocation of {bytes} B");
                    }
                    bail!("device full of pinned allocations; cannot spill");
                }
            }
        }
        Ok(())
    }

    /// Record an access; hot data migrates back when there is room.
    pub fn touch(&mut self, id: AllocId) -> crate::Result<Placement> {
        self.clock += 1;
        let clock = self.clock;
        let (bytes, placement) = {
            let a = self
                .allocs
                .get_mut(&id)
                .ok_or_else(|| anyhow::anyhow!("touch of unknown allocation"))?;
            a.last_touch = clock;
            (a.bytes, a.placement)
        };
        if placement == Placement::Host && self.device_used + bytes <= self.capacity {
            let a = self.allocs.get_mut(&id).unwrap();
            a.placement = Placement::Device;
            self.host_used -= bytes;
            self.device_used += bytes;
            return Ok(Placement::Device);
        }
        Ok(placement)
    }

    pub fn placement(&self, id: AllocId) -> Option<Placement> {
        self.allocs.get(&id).map(|a| a.placement)
    }

    pub fn free(&mut self, id: AllocId) -> crate::Result<()> {
        let a = self
            .allocs
            .remove(&id)
            .ok_or_else(|| anyhow::anyhow!("double free"))?;
        match a.placement {
            Placement::Device => self.device_used -= a.bytes,
            Placement::Host => self.host_used -= a.bytes,
        }
        Ok(())
    }

    /// Invariant check for property tests.
    pub fn check_invariants(&self) {
        let dev: u64 = self
            .allocs
            .values()
            .filter(|a| a.placement == Placement::Device)
            .map(|a| a.bytes)
            .sum();
        let host: u64 = self
            .allocs
            .values()
            .filter(|a| a.placement == Placement::Host)
            .map(|a| a.bytes)
            .sum();
        assert_eq!(dev, self.device_used, "device accounting drift");
        assert_eq!(host, self.host_used, "host accounting drift");
        assert!(self.device_used <= self.capacity, "over capacity");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::apps::{self, AppId};

    #[test]
    fn no_offload_when_it_fits() {
        let app = apps::model(AppId::Qiskit30);
        let p = OffloadPlan::plan(&app, 11.0).unwrap();
        assert_eq!(p.spilled_gib, 0.0);
        assert_eq!(p.c2c_traffic_frac, 0.0);
    }

    #[test]
    fn faiss_large_offload_is_cheap() {
        // §VI-C: FAISS offloads a small, cold fraction -> tiny penalty.
        let app = apps::model(AppId::FaissLarge);
        let p = OffloadPlan::plan(&app, 10.94).unwrap();
        assert!(p.spilled_gib > 2.9 && p.spilled_gib < 3.2, "{}", p.spilled_gib);
        assert!(
            p.c2c_traffic_frac < 0.05,
            "cold-first spill should be cheap: {}",
            p.c2c_traffic_frac
        );
    }

    #[test]
    fn llama_fp16_offload_is_expensive() {
        // Weights are all hot: the traffic fraction ~ overflow/footprint.
        let app = apps::model(AppId::Llama3Fp16);
        let p = OffloadPlan::plan(&app, 10.94).unwrap();
        let expect = (16.5 - 10.94) / 16.5;
        assert!((p.c2c_traffic_frac - expect).abs() < 0.01);
    }

    #[test]
    fn apply_conserves_traffic() {
        let app = apps::model(AppId::Llama3Fp16);
        let p = OffloadPlan::plan(&app, 10.94).unwrap();
        let off = p.apply(&app);
        let orig = &app.phases[0].kernels[0];
        let new = &off.phases[0].kernels[0];
        let before = orig.hbm_bytes + orig.c2c_bytes;
        let after = new.hbm_bytes + new.c2c_bytes;
        assert!((before - after).abs() < 1.0);
        assert!(new.c2c_bytes > 0.0);
    }

    #[test]
    fn host_bytes_matches_the_spill() {
        let app = apps::model(AppId::Llama3Fp16);
        let fits = OffloadPlan::plan(&app, 20.0).unwrap();
        assert_eq!(fits.host_bytes(), 0, "no spill, no host charge");
        let spilled = OffloadPlan::plan(&app, 10.94).unwrap();
        let expect = (spilled.spilled_gib * (1u64 << 30) as f64).round() as u64;
        assert_eq!(spilled.host_bytes(), expect);
        assert!(spilled.host_bytes() > 5 << 30, "llama spills over 5 GiB");
    }

    #[test]
    fn refuses_hopeless_offload() {
        let app = apps::model(AppId::Llama3Fp16); // 16.5 GiB
        assert!(OffloadPlan::plan(&app, 3.0).is_err());
    }

    #[test]
    fn exact_quarter_capacity_is_accepted() {
        // Regression: capacity == footprint * 0.25 sits exactly on the
        // minimum-resident boundary and must be accepted — only strictly
        // smaller capacities fail.
        let app = apps::model(AppId::Llama3Fp16); // 16.5 GiB, direct mode
        let cap = app.footprint_gib * 0.25;
        let p = OffloadPlan::plan(&app, cap).unwrap();
        assert_eq!(p.resident_gib, cap);
        assert!((p.spilled_gib - app.footprint_gib * 0.75).abs() < 1e-9);
        assert!((p.c2c_traffic_frac - 0.75).abs() < 1e-9);
        assert!(OffloadPlan::plan(&app, cap - 1e-6).is_err());
        // Swap-mode apps honour the same boundary.
        let qiskit = apps::model(AppId::Qiskit31);
        let qcap = qiskit.footprint_gib * 0.25;
        let qp = OffloadPlan::plan(&qiskit, qcap).unwrap();
        assert!(qp.swap_gap_s > 0.0);
        assert!(OffloadPlan::plan(&qiskit, qcap - 1e-6).is_err());
    }

    #[test]
    fn allocator_spills_cold_first() {
        let mut a = SpillAllocator::new(100);
        let cold = a.alloc(40, false).unwrap();
        let warm = a.alloc(40, false).unwrap();
        a.touch(warm).unwrap();
        // 30 more bytes force one eviction: `cold` is the victim.
        let hot = a.alloc(30, false).unwrap();
        assert_eq!(a.placement(cold), Some(Placement::Host));
        assert_eq!(a.placement(warm), Some(Placement::Device));
        assert_eq!(a.placement(hot), Some(Placement::Device));
        a.check_invariants();
    }

    #[test]
    fn pinned_never_spills() {
        let mut a = SpillAllocator::new(100);
        let pinned = a.alloc(80, true).unwrap();
        let data = a.alloc(20, false).unwrap();
        // Pinned + no spillable room: next pinned alloc fails.
        assert!(a.alloc(30, true).is_err());
        // Unpinned alloc spills `data`.
        let more = a.alloc(20, false).unwrap();
        assert_eq!(a.placement(pinned), Some(Placement::Device));
        assert_eq!(a.placement(data), Some(Placement::Host));
        assert_eq!(a.placement(more), Some(Placement::Device));
        a.check_invariants();
    }

    #[test]
    fn touch_migrates_back() {
        let mut a = SpillAllocator::new(100);
        let x = a.alloc(60, false).unwrap();
        let y = a.alloc(60, false).unwrap(); // spills x
        assert_eq!(a.placement(x), Some(Placement::Host));
        a.free(y).unwrap();
        assert_eq!(a.touch(x).unwrap(), Placement::Device);
        a.check_invariants();
    }

    #[test]
    fn free_and_errors() {
        let mut a = SpillAllocator::new(10);
        assert!(a.alloc(11, false).is_err());
        let x = a.alloc(10, false).unwrap();
        a.free(x).unwrap();
        assert!(a.free(x).is_err(), "double free must fail");
        assert_eq!(a.device_used(), 0);
    }
}
