//! ASCII table renderer for the experiment reports.
//!
//! Every `migsim experiment <id>` prints its paper table/figure through this
//! renderer so outputs are uniform and easy to diff against the paper.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    Left,
    Right,
}

/// A simple ASCII table with a title, a header row and data rows.
#[derive(Debug, Clone, Default)]
pub struct Table {
    title: String,
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
    separators: Vec<usize>, // row indices after which to draw a rule
}

impl Table {
    pub fn new(title: &str) -> Table {
        Table {
            title: title.to_string(),
            ..Default::default()
        }
    }

    /// Set the header; all columns default to Right alignment except col 0.
    pub fn header(mut self, cols: &[&str]) -> Table {
        self.header = cols.iter().map(|s| s.to_string()).collect();
        self.aligns = (0..cols.len())
            .map(|i| if i == 0 { Align::Left } else { Align::Right })
            .collect();
        self
    }

    pub fn align(mut self, col: usize, a: Align) -> Table {
        if col < self.aligns.len() {
            self.aligns[col] = a;
        }
        self
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width must match header"
        );
        self.rows.push(cells);
        self
    }

    /// Draw a horizontal rule after the last pushed row.
    pub fn rule(&mut self) -> &mut Self {
        self.separators.push(self.rows.len());
        self
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let rule: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], aligns: &[Align]| -> String {
            let mut s = String::from("|");
            for i in 0..ncols {
                let w = widths[i];
                let cell = &cells[i];
                let pad = w - cell.chars().count();
                match aligns[i] {
                    Align::Left => s.push_str(&format!(" {}{} |", cell, " ".repeat(pad))),
                    Align::Right => s.push_str(&format!(" {}{} |", " ".repeat(pad), cell)),
                }
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("## {}\n", self.title));
        }
        out.push_str(&rule);
        out.push('\n');
        out.push_str(&fmt_row(&self.header, &vec![Align::Left; ncols]));
        out.push('\n');
        out.push_str(&rule);
        out.push('\n');
        for (i, row) in self.rows.iter().enumerate() {
            out.push_str(&fmt_row(row, &self.aligns));
            out.push('\n');
            if self.separators.contains(&(i + 1)) && i + 1 != self.rows.len() {
                out.push_str(&rule);
                out.push('\n');
            }
        }
        out.push_str(&rule);
        out.push('\n');
        out
    }
}

/// Format a float with `prec` decimals, trimming "-0".
pub fn fnum(x: f64, prec: usize) -> String {
    let s = format!("{x:.prec$}");
    if s.starts_with("-0.") && s[1..].parse::<f64>() == Ok(0.0) {
        s[1..].to_string()
    } else {
        s
    }
}

/// Format a ratio as a percentage string, e.g. 0.153 -> "15.3%".
pub fn pct(x: f64, prec: usize) -> String {
    format!("{}%", fnum(x * 100.0, prec))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo").header(&["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["bbbb".into(), "22.5".into()]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("| a    |     1 |"));
        assert!(s.contains("| bbbb |  22.5 |"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("x").header(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn pct_and_fnum() {
        assert_eq!(pct(0.153, 1), "15.3%");
        assert_eq!(fnum(2.0, 2), "2.00");
        assert_eq!(fnum(-0.0001, 2), "0.00");
    }
}
